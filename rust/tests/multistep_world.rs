//! Communication-avoiding super-step parity: a rank world advancing
//! `k` timesteps per halo exchange (depth-`2k` ghost blocks + the
//! trapezoid-blocked local sweep) must be **bit-identical** to the
//! classic depth-1 world and to the single-domain fused `FullStep`
//! engine — for every rank count, both exchange schedules, both lattice
//! models, both transports, and step counts the depth does not divide.
//! The payoff is pinned too: the per-rank message count drops from
//! `6 * steps` tagged planes to `4 * ceil(steps / k)` ghost blocks.

use std::thread;

use targetdp::comms::launcher::{connect_rank, RankServer};
use targetdp::comms::{run_decomposed, serve_rank, CommsConfig,
                      CommsWorld, SocketTransport, Transport};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::LbEngine;
use targetdp::lb::init::init_spinodal;
use targetdp::lb::model::LatticeModel;
use targetdp::targetdp::tlp::TlpPool;
use targetdp::targetdp::HostTarget;

/// Odd step count on purpose: depth 2 leaves a 1-step remainder
/// super-step and depth 4 a 1-step one, exercising the shrunk trapezoid.
const STEPS: u64 = 5;

fn initial_state(model: LatticeModel, geom: &Geometry)
                 -> (Vec<f64>, Vec<f64>) {
    let vs = model.velset();
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init_spinodal(vs, &FeParams::default(), geom, &mut f, &mut g, 0.05, 9);
    (f, g)
}

/// Single-domain reference through the engine's fused `FullStep` tier.
fn fullstep_reference(model: LatticeModel, geom: &Geometry, steps: u64)
                      -> (Vec<f64>, Vec<f64>) {
    let (f0, g0) = initial_state(model, geom);
    let mut target = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let mut engine =
        LbEngine::new(&mut target, *geom, model, FeParams::default())
            .unwrap();
    assert!(engine.fused_active(), "host target must take the fused tier");
    engine.load_state(&f0, &g0).unwrap();
    engine.run(steps).unwrap();
    let mut f = vec![0.0; f0.len()];
    let mut g = vec![0.0; g0.len()];
    engine.fetch_state(&mut f, &mut g).unwrap();
    (f, g)
}

fn check_model(model: LatticeModel, geom: Geometry) {
    let vs = model.velset();
    let (f_want, g_want) = fullstep_reference(model, &geom, STEPS);
    // lx = 32 over 4 ranks -> 8-plane slabs: depth 4 (8 ghost planes per
    // side) is exactly the deepest legal super-step on the narrowest slab
    for depth in [1usize, 2, 4] {
        for ranks in [1usize, 2, 4] {
            for overlap in [false, true] {
                let cfg = CommsConfig {
                    ranks,
                    overlap,
                    depth,
                    threads: 2, // shared budget across the ranks
                    ..CommsConfig::default()
                };
                let (mut f, mut g) = initial_state(model, &geom);
                let rep = run_decomposed(&geom, vs, &FeParams::default(),
                                         &mut f, &mut g, STEPS, &cfg)
                    .unwrap();
                assert!(rep.ranks.iter().all(|r| r.steps == STEPS));
                assert_eq!(
                    f, f_want,
                    "{} depth={depth} ranks={ranks} overlap={overlap}: \
                     f diverged from the fused engine",
                    model.name()
                );
                assert_eq!(
                    g, g_want,
                    "{} depth={depth} ranks={ranks} overlap={overlap}: \
                     g diverged from the fused engine",
                    model.name()
                );
            }
        }
    }
}

#[test]
fn d2q9_depth_k_worlds_match_fullstep_bitwise() {
    check_model(LatticeModel::D2Q9, Geometry::new(32, 6, 1));
}

#[test]
fn d3q19_depth_k_worlds_match_fullstep_bitwise() {
    check_model(LatticeModel::D3Q19, Geometry::new(32, 4, 3));
}

/// The communication-avoidance payoff, pinned exactly: depth 1 sends 6
/// tagged planes per rank per step; depth k sends 4 ghost blocks per
/// super-step — `4 * ceil(steps / k)` messages, a ~2k-fold drop.
#[test]
fn super_steps_cut_message_counts_by_the_depth() {
    let model = LatticeModel::D2Q9;
    let geom = Geometry::new(32, 6, 1);
    let vs = model.velset();
    for (depth, want) in [(1usize, 6 * STEPS),
                          (2, 4 * STEPS.div_ceil(2)),
                          (4, 4 * STEPS.div_ceil(4))] {
        let cfg = CommsConfig { ranks: 2, depth,
                                ..CommsConfig::default() };
        let (mut f, mut g) = initial_state(model, &geom);
        let rep = run_decomposed(&geom, vs, &FeParams::default(), &mut f,
                                 &mut g, STEPS, &cfg)
            .unwrap();
        for r in &rep.ranks {
            assert_eq!(r.msgs_sent, want,
                       "depth={depth}: rank {} message count", r.rank);
            assert!(r.bytes_sent > 0);
        }
    }
}

/// A resident session splits the run into pause/resume blocks; each
/// `Advance` re-chunks its own steps into super-steps, with a
/// distributed reduction at every boundary — still bit-identical, and
/// core pinning must not perturb anything either.
#[test]
fn resident_blocks_and_pinning_stay_bit_identical() {
    let model = LatticeModel::D2Q9;
    let geom = Geometry::new(32, 6, 1);
    let vs = model.velset();
    let n = geom.nsites();
    let (f_want, g_want) = fullstep_reference(model, &geom, STEPS);
    for pin in [false, true] {
        let cfg = CommsConfig { ranks: 2, depth: 2, pin,
                                ..CommsConfig::default() };
        let world = CommsWorld::new(geom, cfg).unwrap();
        let (f0, g0) = initial_state(model, &geom);
        let mut session =
            world.session(vs, &FeParams::default(), f0, g0).unwrap();
        // 5 = 3 + 2: the first block ends on a 1-step remainder
        // super-step, the second starts a fresh depth-2 one
        for block in [3u64, 2] {
            session.advance(block).unwrap();
            session.observables().unwrap();
        }
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        session.gather(&mut f, &mut g).unwrap();
        let rep = session.finish().unwrap();
        assert!(rep.ranks.iter().all(|r| r.steps == STEPS));
        // blocks of 3 and 2 at depth 2: (2 + 2) super-steps of 4 msgs
        assert!(rep.ranks.iter().all(|r| r.msgs_sent == 16));
        assert_eq!(f, f_want, "pin={pin}: resident f diverged");
        assert_eq!(g, g_want, "pin={pin}: resident g diverged");
    }
}

/// Assemble an N-rank + controller socket world on loopback (the
/// production rendezvous, rank endpoints in threads of this process).
fn loopback_world(nranks: usize)
                  -> (Vec<SocketTransport>, SocketTransport) {
    let server = RankServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let joins: Vec<_> = (0..nranks)
        .map(|r| {
            let addr = addr.clone();
            thread::spawn(move || connect_rank(&addr, Some(r)).unwrap())
        })
        .collect();
    let ctl = server.rendezvous(nranks, b"").unwrap();
    let mut ranks: Vec<Option<SocketTransport>> =
        (0..nranks).map(|_| None).collect();
    for j in joins {
        let (t, _payload) = j.join().unwrap();
        let r = t.rank();
        assert!(ranks[r].is_none());
        ranks[r] = Some(t);
    }
    (ranks.into_iter().map(Option::unwrap).collect(), ctl)
}

/// Depth-k ghost blocks over real TCP: the batched block frames cross
/// the socket transport bit-identically to the channel world and the
/// fused engine, with the same 4-messages-per-super-step accounting.
#[test]
fn socket_depth_k_worlds_match_channel_and_engine() {
    let model = LatticeModel::D2Q9;
    let vs = model.velset();
    let geom = Geometry::new(17, 4, 1); // uneven 9+8 slab split
    let n = geom.nsites();
    let p = FeParams::default();
    let (f_want, g_want) = fullstep_reference(model, &geom, STEPS);
    for depth in [2usize, 4] {
        let cfg = CommsConfig { ranks: 2, depth,
                                ..CommsConfig::default() };
        let (f0, g0) = initial_state(model, &geom);

        let (rank_transports, ctl) = loopback_world(2);
        let world = CommsWorld::new(geom, cfg.clone()).unwrap();
        let mut servers = Vec::new();
        for t in rank_transports {
            let d = world.dec.domains[t.rank()].clone();
            let (f0, g0) = (f0.clone(), g0.clone());
            let cfg = cfg.clone();
            servers.push(thread::spawn(move || {
                serve_rank(d, vs, &p, f0, g0, &cfg, 1, Box::new(t))
            }));
        }
        let mut session = world.remote_session(vs, Box::new(ctl)).unwrap();
        session.advance(STEPS).unwrap();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        session.gather(&mut f, &mut g).unwrap();
        let rep = session.finish().unwrap();
        for s in servers {
            s.join().unwrap().unwrap();
        }
        assert_eq!(f, f_want, "depth={depth}: socket f diverged");
        assert_eq!(g, g_want, "depth={depth}: socket g diverged");
        for r in &rep.ranks {
            assert_eq!(r.msgs_sent,
                       4 * STEPS.div_ceil(depth as u64),
                       "depth={depth}: rank {} message count", r.rank);
        }
    }
}
