//! Fault injection → supervised recovery: a rank killed deterministically
//! at a step, mid-step, mid-super-step, or at the command barrier takes
//! the world down with a *named* error (never a hang — every receive is
//! bounded by `wait_timeout`), and the supervised driver relaunches from
//! the last checkpoint and finishes **bit-identical** to a run that was
//! never interrupted. Retry exhaustion surfaces a named error too.

use std::time::Duration;

use targetdp::comms::{run_decomposed, CommsConfig, FaultPoint, FaultSpec};
use targetdp::config::Config;
use targetdp::coordinator::run_simulation;
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::init::init_spinodal;
use targetdp::lb::model::d2q9;

/// An 8x8 D2Q9 config: 2 ranks, 8 steps in blocks of 2, a checkpoint
/// after every block, gather observables (decomposition-independent, so
/// finals compare bitwise even across elastic rank-count changes).
fn base_cfg() -> Config {
    let mut cfg = Config::from_toml_str(
        "[simulation]\nlattice = \"d2q9\"\nlx = 8\nly = 8\nlz = 1\n\
         steps = 8\n\n[target]\nranks = 2\nobservables = \"gather\"\n\n\
         [output]\nevery = 2\ncheckpoint_every = 1\n\n[fault]\n\
         kill_rank = 1\nkill_step = 5\nmax_restarts = 2\n\
         backoff_ms = 1\nwait_timeout_s = 2\n",
    )
    .unwrap();
    cfg.output.checkpoint_out = std::env::temp_dir()
        .join(format!("tdpk-fault-{}.tdpk", std::process::id()))
        .to_string_lossy()
        .into_owned();
    cfg
}

/// The same run with nothing armed: no fault, no checkpointing.
fn uninterrupted(cfg: &Config) -> Config {
    let mut c = cfg.clone();
    c.fault.kill_step = 0;
    c.fault.max_restarts = 0;
    c.output.checkpoint_every = 0;
    c
}

/// An injected kill in a channel world surfaces as the *root cause* —
/// the session's error filter reports the fault text, not the timeout /
/// hangup wreckage on the surviving rank.
#[test]
fn channel_fault_error_is_the_root_cause() {
    let vs = d2q9();
    let geom = Geometry::new(10, 4, 1);
    let p = FeParams::default();
    let n = geom.nsites();
    for point in [FaultPoint::Step, FaultPoint::Mid, FaultPoint::Barrier] {
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        init_spinodal(vs, &p, &geom, &mut f, &mut g, 0.05, 5);
        let cfg = CommsConfig {
            ranks: 2,
            fault: Some(FaultSpec { rank: 1, step: 2, point }),
            wait_timeout: Duration::from_secs(5),
            ..CommsConfig::default()
        };
        let err = run_decomposed(&geom, vs, &p, &mut f, &mut g, 4, &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("fault: injected kill of rank 1"),
                "{point:?} death must surface the injected fault, \
                 got: {err}");
        assert!(!err.contains("timed out") && !err.contains("hung up"),
                "{point:?} must not be blamed on the transport: {err}");
    }
}

/// The headline recovery invariant: rank 1 killed at step 5 — in the
/// step loop, mid-step between exchange and compute, or at the command
/// barrier — and the supervised driver resumes from the step-4
/// checkpoint and finishes bitwise identical to the uninterrupted run.
#[test]
fn supervised_recovery_is_bitwise_across_fault_points() {
    let base = base_cfg();
    let full = run_simulation(&uninterrupted(&base)).unwrap();

    for point in ["step", "mid", "barrier"] {
        let mut cfg = base.clone();
        cfg.fault.kill_point = point.into();
        cfg.output.checkpoint_out = format!("{}.{point}",
                                            base.output.checkpoint_out);
        let s = run_simulation(&cfg).unwrap_or_else(|e| {
            panic!("supervised run must recover from a {point} kill: {e}")
        });
        assert_eq!(s.r#final.mass.to_bits(), full.r#final.mass.to_bits(),
                   "{point}: recovered mass differs");
        assert_eq!(s.r#final.phi_total.to_bits(),
                   full.r#final.phi_total.to_bits(),
                   "{point}: recovered phi differs");
        assert_eq!(s.r#final.phi_variance.to_bits(),
                   full.r#final.phi_variance.to_bits(),
                   "{point}: recovered variance differs");
        let _ = std::fs::remove_file(&cfg.output.checkpoint_out);
    }
}

/// Depth-2 super-steps: the fault fires *inside* a ghost-block exchange
/// window (mid-super-step), and recovery still lands bitwise.
#[test]
fn supervised_recovery_survives_a_mid_super_step_kill() {
    let mut base = base_cfg();
    base.target.comms_depth = 2;
    base.output.checkpoint_out = format!("{}.d2",
                                         base.output.checkpoint_out);
    let full = run_simulation(&uninterrupted(&base)).unwrap();

    let mut cfg = base.clone();
    cfg.fault.kill_point = "mid".into();
    let s = run_simulation(&cfg).unwrap();
    assert_eq!(s.r#final.mass.to_bits(), full.r#final.mass.to_bits());
    assert_eq!(s.r#final.phi_total.to_bits(),
               full.r#final.phi_total.to_bits());
    assert_eq!(s.r#final.phi_variance.to_bits(),
               full.r#final.phi_variance.to_bits());
    let _ = std::fs::remove_file(&cfg.output.checkpoint_out);
}

/// Elastic recovery: the 2-rank world dies and is relaunched as a
/// *1-rank* world (`retry_ranks`) from the checkpoint — sound because
/// checkpoints are decomposition-independent — and still finishes
/// bitwise identical.
#[test]
fn supervised_recovery_can_shrink_the_world() {
    let mut base = base_cfg();
    base.output.checkpoint_out = format!("{}.elastic",
                                         base.output.checkpoint_out);
    let full = run_simulation(&uninterrupted(&base)).unwrap();

    let mut cfg = base.clone();
    cfg.fault.kill_step = 3; // dies in block [2,4); checkpoint at step 2
    cfg.fault.retry_ranks = 1;
    let s = run_simulation(&cfg).unwrap();
    assert_eq!(s.r#final.mass.to_bits(), full.r#final.mass.to_bits());
    assert_eq!(s.r#final.phi_total.to_bits(),
               full.r#final.phi_total.to_bits());
    assert_eq!(s.r#final.phi_variance.to_bits(),
               full.r#final.phi_variance.to_bits());
    let _ = std::fs::remove_file(&cfg.output.checkpoint_out);
}

/// A fault that stays armed (`kill_repeat`) drives every incarnation
/// into the ground; exhaustion is a *named* error naming the retry count
/// and wrapping the injected fault — never a hang.
#[test]
fn retry_exhaustion_surfaces_a_named_error() {
    let mut cfg = base_cfg();
    cfg.output.checkpoint_out = format!("{}.exhaust",
                                        cfg.output.checkpoint_out);
    cfg.fault.kill_step = 1; // dies in the first block, no checkpoint yet
    cfg.fault.kill_repeat = true;
    let err = run_simulation(&cfg).unwrap_err().to_string();
    assert!(err.contains("after 2 restart(s)"),
            "exhaustion must name the retry count: {err}");
    assert!(err.contains("fault: injected kill"),
            "exhaustion must wrap the root cause: {err}");
    let _ = std::fs::remove_file(&cfg.output.checkpoint_out);
}
