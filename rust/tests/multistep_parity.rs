//! Host MultiStep parity: k temporal-blocked timesteps per launch must be
//! **bit-identical** to k successive `FullStep` launches — streaming is a
//! permutation and every per-site update is chunk-position independent,
//! so there is no tolerance to hide behind. Covered axes: lattice model
//! (D3Q19 / D2Q9), blocked depth k ∈ {1, 2, 4}, TLP pool shape (serial,
//! static, dynamic), slab width (auto, narrow, uneven, wrap-overlapping),
//! scalar mode, and step counts not divisible by k (the remainder must
//! fall through to `FullStep` with exact `steps_done` accounting).

use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::LbEngine;
use targetdp::lb::init;
use targetdp::lb::model::LatticeModel;
use targetdp::targetdp::constant::Constant;
use targetdp::targetdp::target::KernelId;
use targetdp::targetdp::tlp::{Schedule, TlpPool};
use targetdp::targetdp::{HostTarget, Target};

const POOLS: [&str; 3] = ["serial", "static4", "dyn2"];

fn pool_by_name(name: &str) -> TlpPool {
    match name {
        "serial" => TlpPool::serial(),
        "static4" => TlpPool::new(4, Schedule::Static),
        "dyn2" => TlpPool::new(2, Schedule::Dynamic { batch: 2 }),
        other => unreachable!("unknown pool {other}"),
    }
}

fn spinodal_state(model: LatticeModel, geom: &Geometry)
                  -> (Vec<f64>, Vec<f64>) {
    let vs = model.velset();
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &FeParams::default(), geom, &mut f, &mut g,
                        0.05, 777);
    (f, g)
}

/// Run `nsteps` on a host target. `k == 0` leaves the multi_step knob
/// unset, which on these small lattices means pure `FullStep`; `k > 0`
/// forces the temporal-blocked tier at that depth (`slab > 0` also pins
/// the slab width).
fn run_host(target: &mut HostTarget, k: u64, slab: u64,
            model: LatticeModel, geom: Geometry, nsteps: u64)
            -> (Vec<f64>, Vec<f64>) {
    if k > 0 {
        target
            .copy_constant("multi_step", Constant::Int(k as i64))
            .unwrap();
    }
    if slab > 0 {
        target
            .copy_constant("multi_step_slab", Constant::Int(slab as i64))
            .unwrap();
    }
    let vs = model.velset();
    let n = geom.nsites();
    let (f0, g0) = spinodal_state(model, &geom);
    let mut engine =
        LbEngine::new(target, geom, model, FeParams::default()).unwrap();
    assert!(engine.fused_active());
    if k > 0 {
        assert_eq!(engine.fused_tier(),
                   Some((KernelId::MultiStep, k)),
                   "forced knob must select the blocked tier");
    } else {
        assert_eq!(engine.fused_tier(), Some((KernelId::FullStep, 1)),
                   "auto heuristic must stay off on this small lattice");
    }
    engine.load_state(&f0, &g0).unwrap();
    engine.run(nsteps).unwrap();
    assert_eq!(engine.steps_done(), nsteps);
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    engine.fetch_state(&mut f, &mut g).unwrap();
    (f, g)
}

#[test]
fn multi_step_matches_full_step_bitwise() {
    for (model, geom) in [(LatticeModel::D3Q19, Geometry::new(12, 5, 4)),
                          (LatticeModel::D2Q9, Geometry::new(16, 7, 1))] {
        for pname in POOLS {
            for k in [1u64, 2, 4] {
                let nsteps = 2 * k; // two MultiStep launches, no remainder
                let mut t_ref =
                    HostTarget::simd(8, pool_by_name(pname)).unwrap();
                let (f_ref, g_ref) =
                    run_host(&mut t_ref, 0, 0, model, geom, nsteps);
                let mut t_blk =
                    HostTarget::simd(8, pool_by_name(pname)).unwrap();
                let (f, g) =
                    run_host(&mut t_blk, k, 0, model, geom, nsteps);
                assert_eq!(f, f_ref, "{} k={k} pool={pname}: f diverged",
                           model.name());
                assert_eq!(g, g_ref, "{} k={k} pool={pname}: g diverged",
                           model.name());
            }
        }
    }
}

#[test]
fn remainder_falls_through_to_full_step() {
    let model = LatticeModel::D3Q19;
    let geom = Geometry::new(10, 4, 3);
    // 6 = 4 + 2: one MultiStep launch + two FullStep remainder steps;
    // 3 < 4: no MultiStep launch at all
    for nsteps in [6u64, 3] {
        let mut t_ref = HostTarget::simd(8, TlpPool::serial()).unwrap();
        let (f_ref, g_ref) = run_host(&mut t_ref, 0, 0, model, geom, nsteps);
        let mut t_blk = HostTarget::simd(8, TlpPool::serial()).unwrap();
        let (f, g) = run_host(&mut t_blk, 4, 0, model, geom, nsteps);
        assert_eq!(f, f_ref, "nsteps={nsteps}: f");
        assert_eq!(g, g_ref, "nsteps={nsteps}: g");
    }
}

#[test]
fn slab_widths_including_wrap_overlap_agree() {
    // w=12 → one slab covering the lattice; w=5 → uneven last slab;
    // w=3 with k=2 → extended slab (3 + 8 = 11 planes) nearly wraps;
    // w=1 → extended slab (9 planes) per single interior plane
    let model = LatticeModel::D3Q19;
    let geom = Geometry::new(12, 4, 3);
    let nsteps = 4u64;
    let mut t_ref = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let (f_ref, g_ref) = run_host(&mut t_ref, 0, 0, model, geom, nsteps);
    for pname in ["serial", "dyn2"] {
        for w in [12u64, 5, 3, 1] {
            let mut t =
                HostTarget::simd(8, pool_by_name(pname)).unwrap();
            let (f, g) = run_host(&mut t, 2, w, model, geom, nsteps);
            assert_eq!(f, f_ref, "pool={pname} w={w}: f diverged");
            assert_eq!(g, g_ref, "pool={pname} w={w}: g diverged");
        }
    }
}

#[test]
fn scalar_mode_multi_step_parity() {
    let model = LatticeModel::D2Q9;
    let geom = Geometry::new(14, 6, 1);
    let nsteps = 4u64;
    let mut t_ref = HostTarget::scalar(TlpPool::serial());
    let (f_ref, g_ref) = run_host(&mut t_ref, 0, 0, model, geom, nsteps);
    let mut t_blk = HostTarget::scalar(TlpPool::serial());
    let (f, g) = run_host(&mut t_blk, 2, 4, model, geom, nsteps);
    assert_eq!(f, f_ref, "scalar mode: f diverged");
    assert_eq!(g, g_ref, "scalar mode: g diverged");
}

#[test]
fn multi_step_matches_unfused_pipeline() {
    // transitivity check straight to the reference 5-kernel pipeline
    let model = LatticeModel::D3Q19;
    let geom = Geometry::new(9, 5, 3);
    let nsteps = 4u64;
    let vs = model.velset();
    let n = geom.nsites();
    let (f0, g0) = spinodal_state(model, &geom);

    let mut t_unf = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let mut e = LbEngine::new(&mut t_unf, geom, model, FeParams::default())
        .unwrap();
    e.set_fusion(false);
    e.load_state(&f0, &g0).unwrap();
    e.run(nsteps).unwrap();
    let mut f_ref = vec![0.0; vs.nvel * n];
    let mut g_ref = vec![0.0; vs.nvel * n];
    e.fetch_state(&mut f_ref, &mut g_ref).unwrap();
    drop(e);

    let mut t_blk = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let (f, g) = run_host(&mut t_blk, 2, 0, model, geom, nsteps);
    assert_eq!(f, f_ref, "multi-step vs unfused: f diverged");
    assert_eq!(g, g_ref, "multi-step vs unfused: g diverged");
}
