//! Distribution-level parity: the comms rank world — concurrent slab
//! ranks exchanging serialized halo planes, with or without
//! compute/communication overlap — must be **bit-identical** to the
//! single-domain fused `FullStep` engine run. If any of scatter, wire
//! encode/decode, overlap scheduling, edge-plane completion or gather
//! moved a single ULP, these `assert_eq!`s on raw f64 vectors would see
//! it.

use targetdp::comms::{run_decomposed, CommsConfig, PlaneMsg};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::LbEngine;
use targetdp::lb::init;
use targetdp::lb::model::LatticeModel;
use targetdp::targetdp::tlp::TlpPool;
use targetdp::targetdp::HostTarget;

const STEPS: u64 = 10;

fn initial_state(model: LatticeModel, geom: &Geometry)
                 -> (Vec<f64>, Vec<f64>) {
    let vs = model.velset();
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &FeParams::default(), geom, &mut f, &mut g,
                        0.06, 2024);
    (f, g)
}

/// Single-domain reference through the engine's fused `FullStep` tier.
fn fullstep_reference(model: LatticeModel, geom: &Geometry)
                      -> (Vec<f64>, Vec<f64>) {
    let (f0, g0) = initial_state(model, geom);
    let mut target = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let mut engine =
        LbEngine::new(&mut target, *geom, model, FeParams::default())
            .unwrap();
    assert!(engine.fused_active(), "host target must take the fused tier");
    engine.load_state(&f0, &g0).unwrap();
    engine.run(STEPS).unwrap();
    let mut f = vec![0.0; f0.len()];
    let mut g = vec![0.0; g0.len()];
    engine.fetch_state(&mut f, &mut g).unwrap();
    (f, g)
}

fn check_model(model: LatticeModel, geom: Geometry) {
    let vs = model.velset();
    let (f_want, g_want) = fullstep_reference(model, &geom);
    for ranks in [1usize, 2, 4] {
        for overlap in [false, true] {
            let (mut f, mut g) = initial_state(model, &geom);
            let cfg = CommsConfig {
                ranks,
                overlap,
                threads: 4, // shared budget: ranks get 4/ranks workers
                ..CommsConfig::default()
            };
            let rep = run_decomposed(&geom, vs, &FeParams::default(),
                                     &mut f, &mut g, STEPS, &cfg)
                .unwrap();
            assert_eq!(rep.ranks.len(), ranks);
            assert!(rep.ranks.iter().all(|r| r.steps == STEPS));
            assert_eq!(
                f, f_want,
                "{} ranks={ranks} overlap={overlap}: f diverged",
                model.name()
            );
            assert_eq!(
                g, g_want,
                "{} ranks={ranks} overlap={overlap}: g diverged",
                model.name()
            );
        }
    }
}

#[test]
fn d3q19_ranks_match_fullstep_bitwise() {
    // lx = 13 over 4 ranks -> slabs of 4,3,3,3: uneven split exercised
    check_model(LatticeModel::D3Q19, Geometry::new(13, 4, 4));
}

#[test]
fn d2q9_ranks_match_fullstep_bitwise() {
    // lx = 10 over 4 ranks -> slabs of 3,3,2,2
    check_model(LatticeModel::D2Q9, Geometry::new(10, 12, 1));
}

#[test]
fn scalar_rank_kernels_match_too() {
    // host-scalar analog inside the ranks (vvl only sets the chunk grain)
    let model = LatticeModel::D3Q19;
    let geom = Geometry::new(8, 3, 5);
    let vs = model.velset();
    let (f_want, g_want) = fullstep_reference(model, &geom);
    let (mut f, mut g) = initial_state(model, &geom);
    let cfg = CommsConfig {
        ranks: 2,
        scalar: true,
        vvl: 5, // arbitrary grain is fine in scalar mode
        ..CommsConfig::default()
    };
    run_decomposed(&geom, vs, &FeParams::default(), &mut f, &mut g, STEPS,
                   &cfg)
        .unwrap();
    assert_eq!(f, f_want);
    assert_eq!(g, g_want);
}

#[test]
fn overlap_vs_bulk_sync_report_same_traffic() {
    // both schedules exchange exactly the same planes: 2 moments + 4
    // stream messages per rank per step, identical byte counts
    let model = LatticeModel::D2Q9;
    let geom = Geometry::new(12, 6, 1);
    let vs = model.velset();
    let mut traffic = vec![];
    for overlap in [false, true] {
        let (mut f, mut g) = initial_state(model, &geom);
        let cfg = CommsConfig { ranks: 3, overlap,
                                ..CommsConfig::default() };
        let rep = run_decomposed(&geom, vs, &FeParams::default(), &mut f,
                                 &mut g, STEPS, &cfg)
            .unwrap();
        for r in &rep.ranks {
            assert_eq!(r.msgs_sent, 6 * STEPS, "overlap={overlap}");
        }
        traffic.push(rep.ranks.iter().map(|r| r.bytes_sent).sum::<u64>());
    }
    assert_eq!(traffic[0], traffic[1]);
}

#[test]
fn wire_round_trip_preserves_halo_planes_bitwise() {
    // the serialized plane format must be lossless for arbitrary f64
    // payloads — the property the in-process transport exercises on
    // every message and a socket transport will inherit
    use targetdp::comms::{Axis, FieldId, Phase, Side, Tag};
    let payload: Vec<f64> = (0..19 * 16)
        .map(|i| {
            let x = (i as f64 * 0.7351).sin() * 1e3;
            x.powi(3) / 7.0 // irrational-looking, full-mantissa values
        })
        .chain([0.0, -0.0, f64::MIN_POSITIVE, f64::MAX, 1e-308])
        .collect();
    let msg = PlaneMsg {
        src: 2,
        tag: Tag {
            step: 123_456_789,
            phase: Phase::Stream,
            field: FieldId::F,
            side: Side::Low,
            axis: Axis::Z,
        },
        data: payload,
    };
    let bytes = msg.encode();
    let back = PlaneMsg::decode(&bytes).unwrap();
    assert_eq!(back.tag, msg.tag);
    assert_eq!(back.src, msg.src);
    assert_eq!(back.data.len(), msg.data.len());
    for (k, (a, b)) in back.data.iter().zip(&msg.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "payload element {k}");
    }
}
