//! Checkpoint/restart parity: a TDPK snapshot captured at step `s`
//! through `Command::Checkpoint` is **decomposition-independent** — it
//! restores into any rank count, grid shape, transport, or comms depth,
//! and into the single-domain fused engine, and the resumed run always
//! finishes bit-identical to an uninterrupted reference. Snapshot steps
//! that do not divide the total (remainder cases) are included, and the
//! restored state is the *decoded image* of the encoded bytes, so the
//! codec itself sits inside every parity path here.

use std::thread;

use targetdp::comms::launcher::{connect_rank, RankServer};
use targetdp::comms::{run_decomposed, serve_rank, Checkpoint,
                      CheckpointField, CommsConfig, CommsWorld,
                      SocketTransport, Transport};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::LbEngine;
use targetdp::lb::init::init_spinodal;
use targetdp::lb::model::{d2q9, d3q19, LatticeModel, VelSet};
use targetdp::targetdp::tlp::TlpPool;
use targetdp::targetdp::HostTarget;

fn spinodal(vs: &VelSet, geom: &Geometry, seed: u64)
            -> (Vec<f64>, Vec<f64>) {
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init_spinodal(vs, &FeParams::default(), geom, &mut f, &mut g, 0.05,
                  seed);
    (f, g)
}

/// Advance a resident channel world `snap` steps, capture the
/// `Command::Checkpoint` snapshot of the global state, and return the
/// decoded image of its encoded bytes.
fn snapshot(geom: &Geometry, vs: &'static VelSet, f0: &[f64], g0: &[f64],
            cfg: &CommsConfig, snap: u64) -> Checkpoint {
    let p = FeParams::default();
    let world = CommsWorld::new(*geom, cfg.clone()).unwrap();
    let mut session =
        world.session(vs, &p, f0.to_vec(), g0.to_vec()).unwrap();
    session.advance(snap).unwrap();
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    session.checkpoint(&mut f, &mut g).unwrap();
    session.finish().unwrap();
    let nvel = vs.nvel as u32;
    let ck = Checkpoint {
        step: snap,
        dims: [geom.lx as u64, geom.ly as u64, geom.lz as u64],
        nvel,
        config_toml: "checkpoint-restart-test".into(),
        fields: vec![
            CheckpointField { name: "f".into(), ncomp: nvel, data: f },
            CheckpointField { name: "g".into(), ncomp: nvel, data: g },
        ],
    };
    Checkpoint::decode(&ck.encode()).unwrap()
}

/// Pull bit-exact f/g copies out of a snapshot without consuming it.
fn take_fg(ck: &Checkpoint, want: usize) -> (Vec<f64>, Vec<f64>) {
    let mut ck = ck.clone();
    let f = ck.take_field("f", want).unwrap();
    let g = ck.take_field("g", want).unwrap();
    (f, g)
}

/// D2Q9: a snapshot taken at step 4 of 7 (3 remainder steps — the
/// snapshot step does not divide the run) by a 4-rank slab world
/// restores into the decomposition it came from, a 2-rank y-split grid,
/// a depth-2 communication-avoiding slab (3 = one full + one remainder
/// super-step), a single rank, and the fused single-domain engine —
/// every one finishing bit-identical to the uninterrupted reference.
#[test]
fn d2q9_snapshot_restores_across_decompositions() {
    let vs = d2q9();
    let geom = Geometry::new(12, 6, 1);
    let n = geom.nsites();
    let want = vs.nvel * n;
    let p = FeParams::default();
    let (f0, g0) = spinodal(vs, &geom, 11);
    let (steps, snap) = (7u64, 4u64);

    let mut f_ref = f0.clone();
    let mut g_ref = g0.clone();
    run_decomposed(&geom, vs, &p, &mut f_ref, &mut g_ref, steps,
                   &CommsConfig { ranks: 1, ..CommsConfig::default() })
        .unwrap();

    let ck = snapshot(&geom, vs, &f0, &g0,
                      &CommsConfig { ranks: 4, ..CommsConfig::default() },
                      snap);
    assert_eq!(ck.step, snap);
    assert_eq!(ck.nvel, vs.nvel as u32);

    let shapes: [(usize, [usize; 3], usize); 4] = [
        (4, [0, 0, 0], 1), // the decomposition it was taken at
        (2, [1, 2, 1], 1), // different rank count AND grid shape
        (2, [0, 0, 0], 2), // depth-2 super-steps over the remainder
        (1, [0, 0, 0], 1), // single-rank world
    ];
    for (ranks, grid, depth) in shapes {
        let (mut f, mut g) = take_fg(&ck, want);
        let cfg =
            CommsConfig { ranks, grid, depth, ..CommsConfig::default() };
        run_decomposed(&geom, vs, &p, &mut f, &mut g, steps - snap, &cfg)
            .unwrap();
        assert_eq!(f, f_ref,
                   "restore into ranks={ranks} grid={grid:?} \
                    depth={depth} must finish bit-identical");
        assert_eq!(g, g_ref,
                   "restore into ranks={ranks} grid={grid:?} \
                    depth={depth} must finish bit-identical");
    }

    // the fused single-domain engine is also a valid restore target
    let (f, g) = take_fg(&ck, want);
    let mut target = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let mut engine =
        LbEngine::new(&mut target, geom, LatticeModel::D2Q9, p).unwrap();
    assert!(engine.fused_active());
    engine.load_state(&f, &g).unwrap();
    engine.run(steps - snap).unwrap();
    let mut f_en = vec![0.0; want];
    let mut g_en = vec![0.0; want];
    engine.fetch_state(&mut f_en, &mut g_en).unwrap();
    assert_eq!(f_en, f_ref, "fused-engine restore matches the reference");
    assert_eq!(g_en, g_ref, "fused-engine restore matches the reference");
}

/// D3Q19: the snapshot comes from a depth-2 super-stepping world and
/// restores into a real TCP socket world (and a 1-rank world) — a
/// transport *and* depth change across the checkpoint boundary.
#[test]
fn d3q19_snapshot_crosses_transports_and_depths() {
    let vs = d3q19();
    let geom = Geometry::new(8, 4, 4);
    let n = geom.nsites();
    let want = vs.nvel * n;
    let p = FeParams::default();
    let (f0, g0) = spinodal(vs, &geom, 23);
    let (steps, snap) = (6u64, 4u64);

    let mut f_ref = f0.clone();
    let mut g_ref = g0.clone();
    run_decomposed(&geom, vs, &p, &mut f_ref, &mut g_ref, steps,
                   &CommsConfig { ranks: 1, ..CommsConfig::default() })
        .unwrap();

    // snapshot out of a 2-rank depth-2 world (advance(4) = 2 super-steps)
    let ck = snapshot(&geom, vs, &f0, &g0,
                      &CommsConfig { ranks: 2, depth: 2,
                                     ..CommsConfig::default() },
                      snap);

    // restore into a 2-rank depth-1 socket world on loopback
    let cfg = CommsConfig { ranks: 2, ..CommsConfig::default() };
    let (mut f_sk, mut g_sk) = take_fg(&ck, want);
    let server = RankServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let joins: Vec<_> = (0..cfg.ranks)
        .map(|r| {
            let addr = addr.clone();
            thread::spawn(move || connect_rank(&addr, Some(r)).unwrap())
        })
        .collect();
    let ctl = server.rendezvous(cfg.ranks, b"").unwrap();
    let mut endpoints: Vec<Option<SocketTransport>> =
        (0..cfg.ranks).map(|_| None).collect();
    for j in joins {
        let (t, _payload) = j.join().unwrap();
        let r = t.rank();
        endpoints[r] = Some(t);
    }
    let world = CommsWorld::new(geom, cfg.clone()).unwrap();
    let mut servers = Vec::new();
    for t in endpoints.into_iter().map(Option::unwrap) {
        let d = world.dec.domains[t.rank()].clone();
        let (f, g) = (f_sk.clone(), g_sk.clone());
        let cfg = cfg.clone();
        servers.push(thread::spawn(move || {
            serve_rank(d, vs, &p, f, g, &cfg, 1, Box::new(t))
        }));
    }
    let mut session = world.remote_session(vs, Box::new(ctl)).unwrap();
    session.advance(steps - snap).unwrap();
    session.gather(&mut f_sk, &mut g_sk).unwrap();
    session.finish().unwrap();
    for s in servers {
        s.join().unwrap().unwrap();
    }
    assert_eq!(f_sk, f_ref,
               "socket restore of a super-step snapshot matches the \
                uninterrupted reference");
    assert_eq!(g_sk, g_ref);

    // and into a single rank, for completeness
    let (mut f1, mut g1) = take_fg(&ck, want);
    run_decomposed(&geom, vs, &p, &mut f1, &mut g1, steps - snap,
                   &CommsConfig { ranks: 1, ..CommsConfig::default() })
        .unwrap();
    assert_eq!(f1, f_ref);
    assert_eq!(g1, g_ref);
}

/// The driver-level plumbing: a decomposed `run_simulation` with
/// `checkpoint_every` leaves a TDPK file behind, and a second
/// `run_simulation` restoring from it — down a *different* path, the
/// single-engine pipeline — reports bit-identical final observables.
/// The checkpoint lands at step 6 of 10 (a remainder of two logging
/// blocks), exercising the `blocks % checkpoint_every` bookkeeping.
#[test]
fn run_simulation_checkpoints_and_restores_across_pipelines() {
    use targetdp::config::Config;
    use targetdp::coordinator::pipeline::checkpoint_path;
    use targetdp::coordinator::run_simulation;

    let dir = std::env::temp_dir()
        .join(format!("tdpk-restart-{}", std::process::id()));
    let ck = dir.join("ck.tdpk");
    let ck_str = ck.to_string_lossy().into_owned();
    let base = "[simulation]\nlattice = \"d2q9\"\nlx = 8\nly = 8\n\
                lz = 1\nsteps = 10\n\n[target]\nranks = 2\n\
                observables = \"gather\"\n\n[output]\nevery = 2\n\
                checkpoint_every = 3\n";

    let mut cfg = Config::from_toml_str(base).unwrap();
    cfg.output.checkpoint_out = ck_str.clone();
    assert_eq!(checkpoint_path(&cfg).as_deref(), Some(ck_str.as_str()));
    let full = run_simulation(&cfg).unwrap();
    assert!(ck.exists(), "the decomposed run left a checkpoint behind");

    // the snapshot records step 6 (blocks of 2, every 3rd block) and
    // carries a config echo naming this run
    let snap = Checkpoint::read_file(&ck).unwrap();
    assert_eq!(snap.step, 6);
    assert!(snap.config_toml.contains("checkpoint_every = 3"));

    // resume through the *single-engine* pipeline: ranks = 1 routes off
    // the comms path entirely, and the fused engine finishes the run
    let mut resumed = Config::from_toml_str(base).unwrap();
    resumed.target.ranks = 1;
    resumed.output.checkpoint_every = 0;
    resumed.output.restore = ck_str.clone();
    let half = run_simulation(&resumed).unwrap();
    assert_eq!(half.r#final.mass.to_bits(), full.r#final.mass.to_bits());
    assert_eq!(half.r#final.phi_total.to_bits(),
               full.r#final.phi_total.to_bits());
    assert_eq!(half.r#final.phi_variance.to_bits(),
               full.r#final.phi_variance.to_bits());

    // a dims mismatch is a named config-time error, not a bad run
    let mut wrong = Config::from_toml_str(base).unwrap();
    wrong.simulation.lx = 16;
    wrong.output.restore = ck_str;
    let err = run_simulation(&wrong).unwrap_err();
    assert!(err.to_string().contains("checkpoint"), "{err}");

    let _ = std::fs::remove_dir_all(&dir);
}
