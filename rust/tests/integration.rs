//! Cross-module integration: the full engine pipeline on every backend,
//! target equivalence, decomposition, and the coordinator.

use targetdp::config::Config;
use targetdp::coordinator::pipeline::quick_spinodal;
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::LbEngine;
use targetdp::lb::init;
use targetdp::lb::model::LatticeModel;
use targetdp::targetdp::tlp::{Schedule, TlpPool};
use targetdp::targetdp::{HostTarget, Target, XlaTarget};

fn spinodal_state(model: LatticeModel, geom: &Geometry, seed: u64)
                  -> (Vec<f64>, Vec<f64>) {
    let vs = model.velset();
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &FeParams::default(), geom, &mut f, &mut g,
                        0.05, seed);
    (f, g)
}

/// Run `steps` on a target and return the final (f, g).
fn run_on(target: &mut dyn Target, model: LatticeModel, geom: Geometry,
          steps: u64, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let vs = model.velset();
    let n = geom.nsites();
    let (f0, g0) = spinodal_state(model, &geom, seed);
    let mut engine =
        LbEngine::new(target, geom, model, FeParams::default()).unwrap();
    engine.load_state(&f0, &g0).unwrap();
    engine.run(steps).unwrap();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    engine.fetch_state(&mut f, &mut g).unwrap();
    (f, g)
}

fn max_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn all_host_targets_agree_bitwise_physics() {
    let model = LatticeModel::D3Q19;
    let geom = Geometry::new(8, 8, 8);
    let mut scalar = HostTarget::scalar(TlpPool::serial());
    let (f_ref, g_ref) = run_on(&mut scalar, model, geom, 5, 77);
    for vvl in [1, 2, 4, 8, 16, 32] {
        let mut simd = HostTarget::simd(vvl, TlpPool::serial()).unwrap();
        let (f, g) = run_on(&mut simd, model, geom, 5, 77);
        assert!(max_diff(&f, &f_ref) < 1e-12, "vvl={vvl}");
        assert!(max_diff(&g, &g_ref) < 1e-12, "vvl={vvl}");
    }
}

#[test]
fn threaded_and_dynamic_schedules_agree() {
    let model = LatticeModel::D2Q9;
    let geom = Geometry::new(16, 16, 1);
    let mut serial = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let (f_ref, g_ref) = run_on(&mut serial, model, geom, 4, 5);
    for (threads, sched) in [(2, Schedule::Static),
                             (4, Schedule::Dynamic { batch: 2 })] {
        let mut t =
            HostTarget::simd(8, TlpPool::new(threads, sched)).unwrap();
        let (f, g) = run_on(&mut t, model, geom, 4, 5);
        assert_eq!(max_diff(&f, &f_ref), 0.0, "threads={threads}");
        assert_eq!(max_diff(&g, &g_ref), 0.0);
    }
}

#[test]
fn xla_target_matches_host_over_multiple_steps() {
    let Ok(mut xla) = XlaTarget::from_default_artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let model = LatticeModel::D3Q19;
    let geom = Geometry::new(16, 16, 16);
    // use the parameters baked into the artifact for an exact comparison
    let p = xla
        .baked_params(model, geom.nsites())
        .unwrap_or_default();
    assert_eq!(p, FeParams::default(),
               "artifacts must be built with default params");

    let (f, g) = run_on(&mut xla, model, geom, 10, 2020);
    let mut host = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let (fh, gh) = run_on(&mut host, model, geom, 10, 2020);
    assert!(max_diff(&f, &fh) < 1e-11, "f: {:e}", max_diff(&f, &fh));
    assert!(max_diff(&g, &gh) < 1e-11, "g: {:e}", max_diff(&g, &gh));
}

#[test]
fn xla_d2q9_full_step_matches_host() {
    let Ok(mut xla) = XlaTarget::from_default_artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let model = LatticeModel::D2Q9;
    let geom = Geometry::new(64, 64, 1);
    let (f, g) = run_on(&mut xla, model, geom, 3, 808);
    let mut host = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let (fh, gh) = run_on(&mut host, model, geom, 3, 808);
    assert!(max_diff(&f, &fh) < 1e-11);
    assert!(max_diff(&g, &gh) < 1e-11);
}

#[test]
fn conservation_long_run() {
    let s = quick_spinodal("host-simd", LatticeModel::D3Q19, (12, 12, 12),
                           50, 8)
        .unwrap();
    assert!(s.mass_drift() < 1e-11, "mass drift {:e}", s.mass_drift());
    assert!(s.phi_drift() < 1e-11);
}

#[test]
fn spinodal_decomposition_coarsens() {
    // physics sanity: after the noise smooths out, phi variance must grow
    // toward the two-phase state (the headline behaviour of the model)
    let cfg = Config::from_toml_str(
        "[simulation]\nlattice = \"d2q9\"\nlx = 32\nly = 32\nlz = 1\n\
         steps = 400\nnoise = 0.1\nseed = 42\n\n[output]\nevery = 0\n",
    )
    .unwrap();
    let s = targetdp::coordinator::run_simulation(&cfg).unwrap();
    assert!(
        s.r#final.phi_variance > 4.0 * s.initial.phi_variance,
        "variance should grow: {:e} -> {:e}",
        s.initial.phi_variance,
        s.r#final.phi_variance
    );
}

#[test]
fn scale_example_on_xla_target() {
    // the paper's section III host-code sequence against the XLA target
    use targetdp::targetdp::constant::Constant;
    use targetdp::targetdp::memory::FieldDesc;
    use targetdp::targetdp::target::{KernelId, LaunchArgs};

    let Ok(mut t) = XlaTarget::from_default_artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let n = 4096;
    let host: Vec<f64> = (0..3 * n).map(|i| i as f64).collect();
    let id = t.malloc(&FieldDesc::new("field", 3, n)).unwrap();
    t.copy_to_target(id, &host).unwrap();
    t.copy_constant("scale_a", Constant::Double(1.5)).unwrap();
    let args = LaunchArgs::new(Geometry::new(16, 16, 16),
                               LatticeModel::D3Q19)
        .bind("field", id);
    t.launch(KernelId::Scale, &args).unwrap();
    t.sync().unwrap();
    let mut out = vec![0.0; 3 * n];
    t.copy_from_target(id, &mut out).unwrap();
    for (i, v) in out.iter().enumerate() {
        assert_eq!(*v, 1.5 * i as f64);
    }
}

#[test]
fn xla_constant_mismatch_is_detected() {
    use targetdp::targetdp::constant::Constant;
    use targetdp::targetdp::memory::FieldDesc;
    use targetdp::targetdp::target::{KernelId, LaunchArgs};

    let Ok(mut t) = XlaTarget::from_default_artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let n = 4096;
    let id = t.malloc(&FieldDesc::new("field", 3, n)).unwrap();
    t.copy_to_target(id, &vec![1.0; 3 * n]).unwrap();
    // wrong scale constant: the launch must refuse (constant coherence)
    t.copy_constant("scale_a", Constant::Double(2.0)).unwrap();
    let args = LaunchArgs::new(Geometry::new(16, 16, 16),
                               LatticeModel::D3Q19)
        .bind("field", id);
    let err = t.launch(KernelId::Scale, &args).unwrap_err();
    assert!(err.to_string().contains("disagrees"), "{err}");
}

#[test]
fn reduce_sum_kernel_all_targets() {
    // the paper's section-V reduction extension: same API on host + xla
    use targetdp::targetdp::memory::FieldDesc;
    use targetdp::targetdp::target::{KernelId, LaunchArgs};

    let n = 4096;
    let ncomp = 19;
    let host_data: Vec<f64> =
        (0..ncomp * n).map(|i| ((i % 101) as f64) * 0.5).collect();
    let want: Vec<f64> = (0..ncomp)
        .map(|c| host_data[c * n..(c + 1) * n].iter().sum())
        .collect();

    let mut targets: Vec<Box<dyn Target>> = vec![
        Box::new(HostTarget::scalar(TlpPool::serial())),
        Box::new(HostTarget::simd(8, TlpPool::new(
            3, Schedule::Dynamic { batch: 2 })).unwrap()),
    ];
    if let Ok(x) = XlaTarget::from_default_artifacts() {
        targets.push(Box::new(x));
    }
    for t in targets.iter_mut() {
        let field = t.malloc(&FieldDesc::new("field", ncomp, n)).unwrap();
        let result = t.malloc(&FieldDesc::new("result", ncomp, 1)).unwrap();
        t.copy_to_target(field, &host_data).unwrap();
        let args = LaunchArgs::new(Geometry::new(16, 16, 16),
                                   LatticeModel::D3Q19)
            .bind("field", field)
            .bind("result", result);
        t.launch(KernelId::ReduceSum, &args).unwrap();
        let mut out = vec![0.0; ncomp];
        t.copy_from_target(result, &mut out).unwrap();
        for (c, (a, b)) in out.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-8 * b.abs(),
                    "{}: comp {c}: {a} vs {b}", t.describe());
        }
    }
}

#[test]
fn missing_artifact_error_is_actionable() {
    let Ok(mut t) = XlaTarget::from_default_artifacts() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    // no collision/full_step artifact exists for this odd size
    let geom = Geometry::new(5, 5, 5);
    let model = LatticeModel::D3Q19;
    let (f0, g0) = spinodal_state(model, &geom, 1);
    let mut engine =
        LbEngine::new(&mut t, geom, model, FeParams::default()).unwrap();
    engine.load_state(&f0, &g0).unwrap();
    let err = engine.run(1).unwrap_err().to_string();
    assert!(err.contains("make artifacts"), "{err}");
}
