//! Host fusion-tier parity: the fused `FullStep` sweep must reproduce the
//! unfused 5-kernel pipeline **bit-for-bit** — same collision core, and
//! streaming is a pure permutation, so there is no tolerance to hide
//! behind. Covered axes: lattice model (D3Q19 / D2Q9), execution mode
//! (scalar + every supported VVL), and TLP pool shape (serial, static
//! threads, dynamic threads).

use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::LbEngine;
use targetdp::lb::init;
use targetdp::lb::model::LatticeModel;
use targetdp::targetdp::ilp::SUPPORTED_VVL;
use targetdp::targetdp::target::KernelId;
use targetdp::targetdp::tlp::{Schedule, TlpPool};
use targetdp::targetdp::{HostTarget, Target};

const STEPS: u64 = 10;
const POOLS: [&str; 3] = ["serial", "static4", "dyn3"];

fn pool_by_name(name: &str) -> TlpPool {
    match name {
        "serial" => TlpPool::serial(),
        "static4" => TlpPool::new(4, Schedule::Static),
        "dyn3" => TlpPool::new(3, Schedule::Dynamic { batch: 2 }),
        other => unreachable!("unknown pool {other}"),
    }
}

fn spinodal_state(model: LatticeModel, geom: &Geometry)
                  -> (Vec<f64>, Vec<f64>) {
    let vs = model.velset();
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &FeParams::default(), geom, &mut f, &mut g,
                        0.05, 4242);
    (f, g)
}

/// Run `STEPS` steps on `target` with the given fusion setting.
fn run_steps(target: &mut dyn Target, fusion: bool, model: LatticeModel,
             geom: Geometry) -> (Vec<f64>, Vec<f64>) {
    let vs = model.velset();
    let n = geom.nsites();
    let (f0, g0) = spinodal_state(model, &geom);
    let mut engine =
        LbEngine::new(target, geom, model, FeParams::default()).unwrap();
    engine.set_fusion(fusion);
    engine.load_state(&f0, &g0).unwrap();
    engine.run(STEPS).unwrap();
    assert_eq!(engine.steps_done(), STEPS);
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    engine.fetch_state(&mut f, &mut g).unwrap();
    (f, g)
}

#[test]
fn host_target_advertises_full_step() {
    assert!(HostTarget::default_simd().supports(KernelId::FullStep));
    assert!(HostTarget::scalar(TlpPool::serial())
        .supports(KernelId::FullStep));
}

#[test]
fn fused_matches_unfused_simd_all_vvl() {
    // geometries with nsites not a multiple of any VVL exercise the tail
    for (model, geom) in [(LatticeModel::D3Q19, Geometry::new(6, 5, 4)),
                          (LatticeModel::D2Q9, Geometry::new(12, 9, 1))] {
        for pname in POOLS {
            for &vvl in SUPPORTED_VVL {
                let mut t_ref =
                    HostTarget::simd(vvl, pool_by_name(pname)).unwrap();
                let (f_ref, g_ref) =
                    run_steps(&mut t_ref, false, model, geom);
                let mut t_fused =
                    HostTarget::simd(vvl, pool_by_name(pname)).unwrap();
                let (f, g) = run_steps(&mut t_fused, true, model, geom);
                assert_eq!(f, f_ref,
                           "{} vvl={vvl} pool={pname}: f diverged",
                           model.name());
                assert_eq!(g, g_ref,
                           "{} vvl={vvl} pool={pname}: g diverged",
                           model.name());
            }
        }
    }
}

#[test]
fn fused_matches_unfused_scalar_mode() {
    for (model, geom) in [(LatticeModel::D3Q19, Geometry::new(5, 4, 3)),
                          (LatticeModel::D2Q9, Geometry::new(9, 7, 1))] {
        for pname in POOLS {
            let mut t_ref = HostTarget::scalar(pool_by_name(pname));
            let (f_ref, g_ref) = run_steps(&mut t_ref, false, model, geom);
            let mut t_fused = HostTarget::scalar(pool_by_name(pname));
            let (f, g) = run_steps(&mut t_fused, true, model, geom);
            assert_eq!(f, f_ref, "{} scalar pool={pname}: f", model.name());
            assert_eq!(g, g_ref, "{} scalar pool={pname}: g", model.name());
        }
    }
}

#[test]
fn fused_scalar_matches_fused_simd_to_roundoff() {
    // cross-mode agreement (not bitwise: different summation order)
    let model = LatticeModel::D3Q19;
    let geom = Geometry::new(6, 6, 6);
    let mut scalar = HostTarget::scalar(TlpPool::serial());
    let (f_s, g_s) = run_steps(&mut scalar, true, model, geom);
    let mut simd = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let (f_v, g_v) = run_steps(&mut simd, true, model, geom);
    let max = |a: &[f64], b: &[f64]| {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    };
    assert!(max(&f_s, &f_v) < 1e-12);
    assert!(max(&g_s, &g_v) < 1e-12);
}
