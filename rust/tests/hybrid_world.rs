//! Hybrid worlds: multi-rank host processes with per-link transport
//! routing. Each simulated "host" is a `connect_host` block whose ranks
//! share one process — co-hosted neighbours exchange frames over
//! in-process channels while cross-host links ride one TCP stream per
//! host pair. The headline guarantees pinned here:
//!
//! * **bit-identical physics** — every hybrid world matches the channel
//!   world, the socket-style references and the single-domain fused
//!   engine, over slab and grid shapes, both schedules, depth 1 and
//!   depth 2, D2Q9 and D3Q19;
//! * **per-link traffic split** — `bytes_intra + bytes_inter ==
//!   bytes_sent` everywhere, co-hosted faces count as intra, and on a
//!   2x2x2 grid over 2 hosts the inner-axis (y, z) faces land on
//!   channel links while only the x faces cross the network;
//! * **failure semantics** — a host process dying mid-run surfaces as
//!   an error on the driver (and on surviving hosts), never a hang.

use std::thread;
use std::time::Duration;

use targetdp::comms::launcher::{connect_host, RankServer};
use targetdp::comms::{run_decomposed, serve_rank, CommsConfig, CommsWorld,
                      HybridTransport, Transport, WorldReport};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::LbEngine;
use targetdp::lb::init::init_spinodal;
use targetdp::lb::model::LatticeModel;
use targetdp::targetdp::tlp::TlpPool;
use targetdp::targetdp::HostTarget;

fn initial_state(model: LatticeModel, geom: &Geometry)
                 -> (Vec<f64>, Vec<f64>) {
    let vs = model.velset();
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init_spinodal(vs, &FeParams::default(), geom, &mut f, &mut g, 0.05,
                  2026);
    (f, g)
}

/// Single-domain reference through the engine's fused `FullStep` tier.
fn fullstep_reference(model: LatticeModel, geom: &Geometry, steps: u64)
                      -> (Vec<f64>, Vec<f64>) {
    let (f0, g0) = initial_state(model, geom);
    let mut target = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let mut engine =
        LbEngine::new(&mut target, *geom, model, FeParams::default())
            .unwrap();
    assert!(engine.fused_active(), "host target must take the fused tier");
    engine.load_state(&f0, &g0).unwrap();
    engine.run(steps).unwrap();
    let mut f = vec![0.0; f0.len()];
    let mut g = vec![0.0; g0.len()];
    engine.fetch_state(&mut f, &mut g).unwrap();
    (f, g)
}

/// Assemble a hybrid world on loopback through the production
/// rendezvous: one `connect_host` thread per `(first, count)` block
/// (each a simulated host process), the driver running
/// `rendezvous_hosts`. Returns the rank endpoints in rank order plus
/// the controller.
fn hybrid_loopback(nranks: usize, blocks: &[(usize, usize)])
                   -> (Vec<HybridTransport>, HybridTransport) {
    let server = RankServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let joins: Vec<_> = blocks
        .iter()
        .map(|&(first, count)| {
            let addr = addr.clone();
            thread::spawn(move || {
                connect_host(&addr, Some(first), count).unwrap()
            })
        })
        .collect();
    let ctl = server.rendezvous_hosts(nranks, b"").unwrap();
    let mut ranks: Vec<Option<HybridTransport>> =
        (0..nranks).map(|_| None).collect();
    for j in joins {
        let (eps, _payload) = j.join().unwrap();
        for t in eps {
            let r = t.rank();
            assert!(ranks[r].is_none());
            ranks[r] = Some(t);
        }
    }
    (ranks.into_iter().map(Option::unwrap).collect(), ctl)
}

/// Run one hybrid world to completion: serve every endpoint on its own
/// resident thread (exactly what a host process does), drive the
/// session from the controller, and return the gathered state plus the
/// world report.
fn run_hybrid(model: LatticeModel, geom: &Geometry, steps: u64,
              cfg: &CommsConfig, blocks: &[(usize, usize)])
              -> (Vec<f64>, Vec<f64>, WorldReport) {
    let vs = model.velset();
    let (f0, g0) = initial_state(model, geom);
    let (endpoints, ctl) = hybrid_loopback(cfg.ranks, blocks);
    let world = CommsWorld::new(*geom, cfg.clone()).unwrap();
    let p = FeParams::default();
    let mut servers = Vec::new();
    for t in endpoints {
        let d = world.dec.domains[t.rank()].clone();
        let (f0, g0) = (f0.clone(), g0.clone());
        let cfg = cfg.clone();
        servers.push(thread::spawn(move || {
            serve_rank(d, vs, &p, f0, g0, &cfg, 1, Box::new(t))
        }));
    }
    let mut session = world.remote_session(vs, Box::new(ctl)).unwrap();
    session.advance(steps).unwrap();
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    session.gather(&mut f, &mut g).unwrap();
    let report = session.finish().unwrap();
    for s in servers {
        s.join().unwrap().unwrap();
    }
    (f, g, report)
}

/// Every rank's intra/inter split must account for every halo frame.
fn assert_split_sums(report: &WorldReport) {
    for r in &report.ranks {
        assert_eq!(r.bytes_intra + r.bytes_inter, r.bytes_sent,
                   "rank {}: byte split must sum to the total", r.rank);
        assert_eq!(r.msgs_intra + r.msgs_inter, r.msgs_sent,
                   "rank {}: message split must sum to the total",
                   r.rank);
    }
}

/// Slab world, 2 hosts x 2 ranks, both schedules: bit-identical to the
/// channel world and the fused engine, with the periodic ring split
/// half-and-half between channel and socket links.
#[test]
fn slab_hybrid_world_matches_channel_and_engine() {
    let model = LatticeModel::D2Q9;
    let geom = Geometry::new(9, 6, 1); // 9 -> uneven slab split
    let steps = 6u64;
    let (f_en, g_en) = fullstep_reference(model, &geom, steps);
    for overlap in [false, true] {
        let cfg = CommsConfig { ranks: 4, overlap,
                                ..CommsConfig::default() };
        let (mut f_ch, mut g_ch) = initial_state(model, &geom);
        run_decomposed(&geom, model.velset(), &FeParams::default(),
                       &mut f_ch, &mut g_ch, steps, &cfg)
            .unwrap();
        assert_eq!(f_ch, f_en, "channel reference matches the engine");
        assert_eq!(g_ch, g_en);

        let (f, g, report) =
            run_hybrid(model, &geom, steps, &cfg, &[(0, 2), (2, 2)]);
        assert_eq!(f, f_ch, "overlap={overlap}: hybrid f diverged");
        assert_eq!(g, g_ch, "overlap={overlap}: hybrid g diverged");
        assert_split_sums(&report);
        for r in &report.ranks {
            // blocks [0,1] and [2,3] on the 4-ring: every rank has one
            // co-hosted neighbour and one cross-host neighbour, and a
            // slab rank sends 3 planes per side per step
            assert_eq!(r.msgs_sent, 6 * steps);
            assert_eq!(r.msgs_intra, 3 * steps,
                       "rank {}: one neighbour is co-hosted", r.rank);
            assert_eq!(r.msgs_inter, 3 * steps,
                       "rank {}: one neighbour is cross-host", r.rank);
            assert!(r.bytes_intra > 0 && r.bytes_inter > 0);
        }
    }
}

/// D3Q19 2x2x2 grid over 2 hosts: ranks are numbered z-fastest and the
/// blocks split on x, so **every y and z face stays on a channel link**
/// and only the x faces cross the socket — the perf story the per-link
/// counters must prove. Physics stays bit-identical to the channel
/// world and the fused engine, both schedules.
#[test]
fn grid_hybrid_world_keeps_inner_axis_faces_on_channels() {
    let model = LatticeModel::D3Q19;
    let geom = Geometry::new(8, 6, 4);
    let steps = 4u64;
    let grid = [2, 2, 2];
    let (f_en, g_en) = fullstep_reference(model, &geom, steps);
    for overlap in [false, true] {
        let cfg = CommsConfig { ranks: 8, overlap, grid,
                                ..CommsConfig::default() };
        let (mut f_ch, mut g_ch) = initial_state(model, &geom);
        run_decomposed(&geom, model.velset(), &FeParams::default(),
                       &mut f_ch, &mut g_ch, steps, &cfg)
            .unwrap();
        assert_eq!(f_ch, f_en);
        assert_eq!(g_ch, g_en);

        // rank = (cx*py + cy)*pz + cz: ranks 0..4 are the cx=0 cell
        // column, 4..8 the cx=1 one — one host per x layer
        let (f, g, report) =
            run_hybrid(model, &geom, steps, &cfg, &[(0, 4), (4, 4)]);
        assert_eq!(f, f_ch, "overlap={overlap}: hybrid f diverged");
        assert_eq!(g, g_ch, "overlap={overlap}: hybrid g diverged");
        assert_split_sums(&report);
        for r in &report.ranks {
            // staged exchange: 6 face messages per decomposed axis per
            // step; the x faces are the only inter-host traffic
            assert_eq!(r.msgs_sent, 18 * steps);
            assert_eq!(r.bytes_inter, r.bytes_axis[0],
                       "rank {}: x faces cross hosts", r.rank);
            assert_eq!(r.bytes_intra,
                       r.bytes_axis[1] + r.bytes_axis[2],
                       "rank {}: y/z faces stay on channels", r.rank);
            assert_eq!(r.msgs_inter, r.msgs_axis[0]);
            assert_eq!(r.msgs_intra, r.msgs_axis[1] + r.msgs_axis[2]);
            assert!(r.bytes_intra > r.bytes_inter,
                    "co-hosting the z-fastest blocks keeps most bytes \
                     off the network");
        }
    }
}

/// Depth-2 super-steps over a hybrid slab: ghost-block batches keep
/// socket-side coalescing while channel links skip framing — and the
/// communication-avoiding message count holds with an even
/// channel/socket split.
#[test]
fn depth2_hybrid_slab_matches_channel_with_batched_blocks() {
    let model = LatticeModel::D2Q9;
    let geom = Geometry::new(16, 4, 1);
    let steps = 6u64;
    let cfg = CommsConfig { ranks: 4, depth: 2,
                            ..CommsConfig::default() };
    let (mut f_ch, mut g_ch) = initial_state(model, &geom);
    run_decomposed(&geom, model.velset(), &FeParams::default(), &mut f_ch,
                   &mut g_ch, steps, &cfg)
        .unwrap();

    let (f, g, report) =
        run_hybrid(model, &geom, steps, &cfg, &[(0, 2), (2, 2)]);
    assert_eq!(f, f_ch, "depth-2 hybrid f diverged");
    assert_eq!(g, g_ch, "depth-2 hybrid g diverged");
    assert_split_sums(&report);
    let supers = steps.div_ceil(2);
    for r in &report.ranks {
        assert_eq!(r.super_steps, supers);
        // 4 ghost-block messages (2 fields x 2 sides) per super-step,
        // one neighbour co-hosted and one cross-host per rank
        assert_eq!(r.msgs_sent, 4 * supers);
        assert_eq!(r.msgs_intra, 2 * supers);
        assert_eq!(r.msgs_inter, 2 * supers);
        // symmetric slabs: both neighbours get identical block bytes
        assert_eq!(r.bytes_intra, r.bytes_inter);
    }
}

/// One host carrying every rank (the spawn-local hybrid shape): all
/// traffic is intra-process, zero socket bytes — and still
/// bit-identical to the channel world.
#[test]
fn single_host_hybrid_world_is_all_channel_traffic() {
    let model = LatticeModel::D2Q9;
    let geom = Geometry::new(10, 4, 1);
    let steps = 4u64;
    let cfg = CommsConfig { ranks: 3, ..CommsConfig::default() };
    let (mut f_ch, mut g_ch) = initial_state(model, &geom);
    run_decomposed(&geom, model.velset(), &FeParams::default(), &mut f_ch,
                   &mut g_ch, steps, &cfg)
        .unwrap();

    let (f, g, report) = run_hybrid(model, &geom, steps, &cfg, &[(0, 3)]);
    assert_eq!(f, f_ch);
    assert_eq!(g, g_ch);
    assert_split_sums(&report);
    for r in &report.ranks {
        assert!(r.bytes_intra > 0);
        assert_eq!(r.bytes_inter, 0,
                   "co-hosted ranks never touch a socket");
        assert_eq!(r.msgs_inter, 0);
    }
}

/// A host process dying mid-run (its link closing before its residents'
/// reports crossed) surfaces as a prompt error on the driver — and the
/// driver vanishing surfaces on the surviving hosts' ranks. No hangs.
#[test]
fn host_process_death_errors_instead_of_hanging() {
    let (mut ranks, mut ctl) = hybrid_loopback(4, &[(0, 2), (2, 2)]);
    // "host B dies": drop ranks 2 and 3 without sending any report;
    // the driver-side link reader sees EOF with 0 of 2 reports seen
    drop(ranks.pop().unwrap());
    drop(ranks.pop().unwrap());
    let err = loop {
        // frames from the healthy host may still be queued; the death
        // notice arrives through the same merged inbox
        match ctl.recv_bytes_timeout(Duration::from_secs(30)) {
            Ok(Some(_)) => continue,
            Ok(None) => panic!("death must surface, not time out"),
            Err(e) => break e,
        }
    };
    assert!(format!("{err}").contains("host process died"),
            "got: {err}");

    // the driver dropping its controller surfaces on surviving ranks
    drop(ctl);
    let mut r0 = ranks.remove(0);
    assert!(r0.recv_bytes().is_err(),
            "driver-gone must error on resident ranks");
}
