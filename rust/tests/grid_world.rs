//! 3D Cartesian grid worlds: the staged per-axis face exchange must be
//! **bit-identical** to the slab world and to the single-domain fused
//! `FullStep` engine — over in-process channels and over real TCP
//! sockets — while moving *less* halo data than the slab whenever the
//! grid's surface-to-volume ratio wins.
//!
//! The sweep covers y-only, x+y and x+y+z decompositions with uneven
//! per-axis splits, both exchange schedules, both lattice models, and a
//! 2x2x2 world served over loopback sockets. The traffic tests pin the
//! staged protocol's message count (6 face messages per decomposed axis
//! per rank per step) and the headline surface win: on a 32^3 cube at 8
//! ranks, 2x2x2 exchanges fewer halo bytes per step than the 8x1x1
//! slab.

use std::thread;

use targetdp::comms::launcher::{connect_rank, RankServer};
use targetdp::comms::{run_decomposed, serve_rank, CommsConfig, CommsWorld,
                      SocketTransport, Transport};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::LbEngine;
use targetdp::lb::init::init_spinodal;
use targetdp::lb::model::LatticeModel;
use targetdp::targetdp::tlp::TlpPool;
use targetdp::targetdp::HostTarget;

fn initial_state(model: LatticeModel, geom: &Geometry)
                 -> (Vec<f64>, Vec<f64>) {
    let vs = model.velset();
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init_spinodal(vs, &FeParams::default(), geom, &mut f, &mut g, 0.05,
                  4711);
    (f, g)
}

/// Single-domain reference through the engine's fused `FullStep` tier.
fn fullstep_reference(model: LatticeModel, geom: &Geometry, steps: u64)
                      -> (Vec<f64>, Vec<f64>) {
    let (f0, g0) = initial_state(model, geom);
    let mut target = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let mut engine =
        LbEngine::new(&mut target, *geom, model, FeParams::default())
            .unwrap();
    assert!(engine.fused_active(), "host target must take the fused tier");
    engine.load_state(&f0, &g0).unwrap();
    engine.run(steps).unwrap();
    let mut f = vec![0.0; f0.len()];
    let mut g = vec![0.0; g0.len()];
    engine.fetch_state(&mut f, &mut g).unwrap();
    (f, g)
}

/// Assemble an N-rank + controller socket world on loopback (the
/// production rendezvous, rank endpoints on threads of this process).
fn loopback_world(nranks: usize)
                  -> (Vec<SocketTransport>, SocketTransport) {
    let server = RankServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let joins: Vec<_> = (0..nranks)
        .map(|r| {
            let addr = addr.clone();
            thread::spawn(move || connect_rank(&addr, Some(r)).unwrap())
        })
        .collect();
    let ctl = server.rendezvous(nranks, b"").unwrap();
    let mut ranks: Vec<Option<SocketTransport>> =
        (0..nranks).map(|_| None).collect();
    for j in joins {
        let (t, _payload) = j.join().unwrap();
        let r = t.rank();
        assert!(ranks[r].is_none());
        ranks[r] = Some(t);
    }
    (ranks.into_iter().map(Option::unwrap).collect(), ctl)
}

/// Channel grid worlds across models, grids and schedules, all pinned
/// bitwise against the fused engine.
#[test]
fn grid_worlds_match_fused_engine_bitwise() {
    let steps = 6u64;
    let cases: [(LatticeModel, Geometry, &[[usize; 3]]); 2] = [
        // 7x6x5: every axis splits unevenly somewhere in the sweep
        (LatticeModel::D3Q19, Geometry::new(7, 6, 5),
         &[[1, 2, 1], [2, 2, 1], [2, 2, 2]]),
        // d2q9 keeps z whole; [1, 8, 1] leaves one interior y plane per
        // rank, the hardest case for the staged edge carry
        (LatticeModel::D2Q9, Geometry::new(9, 8, 1),
         &[[1, 2, 1], [2, 2, 1], [1, 8, 1]]),
    ];
    for (model, geom, grids) in cases {
        let (f_want, g_want) = fullstep_reference(model, &geom, steps);
        for &grid in grids {
            let ranks = grid.iter().product();
            for overlap in [false, true] {
                let cfg = CommsConfig { ranks, overlap, grid,
                                        ..CommsConfig::default() };
                let (mut f, mut g) = initial_state(model, &geom);
                let rep = run_decomposed(&geom, model.velset(),
                                         &FeParams::default(), &mut f,
                                         &mut g, steps, &cfg)
                    .unwrap();
                assert_eq!(rep.ranks.len(), ranks);
                assert_eq!(
                    f, f_want,
                    "{} grid={grid:?} overlap={overlap}: f diverged",
                    model.name()
                );
                assert_eq!(
                    g, g_want,
                    "{} grid={grid:?} overlap={overlap}: g diverged",
                    model.name()
                );
            }
        }
    }
}

/// The staged exchange sends exactly 6 face messages (2 moments + 4
/// stream) per decomposed axis per rank per step, and the same bytes on
/// both schedules.
#[test]
fn grid_traffic_is_six_messages_per_axis_and_schedule_independent() {
    let model = LatticeModel::D3Q19;
    let geom = Geometry::new(6, 6, 4);
    let steps = 3u64;
    for (grid, naxes) in [([2, 1, 1], 1usize), ([2, 2, 1], 2),
                          ([2, 2, 2], 3)] {
        let ranks = grid.iter().product();
        let mut traffic = vec![];
        for overlap in [false, true] {
            let cfg = CommsConfig { ranks, overlap, grid,
                                    ..CommsConfig::default() };
            let (mut f, mut g) = initial_state(model, &geom);
            let rep = run_decomposed(&geom, model.velset(),
                                     &FeParams::default(), &mut f, &mut g,
                                     steps, &cfg)
                .unwrap();
            for r in &rep.ranks {
                assert_eq!(r.msgs_sent, 6 * naxes as u64 * steps,
                           "grid={grid:?} overlap={overlap}");
                // the per-axis split is a partition of the totals
                assert_eq!(r.msgs_axis.iter().sum::<u64>(), r.msgs_sent,
                           "grid={grid:?}: per-axis messages sum to the \
                            total");
                assert_eq!(r.bytes_axis.iter().sum::<u64>(), r.bytes_sent,
                           "grid={grid:?}: per-axis bytes sum to the \
                            total");
                // every decomposed axis carries its 6 messages per step,
                // undecomposed axes carry none
                for (a, &parts) in grid.iter().enumerate() {
                    let want =
                        if parts > 1 { 6 * steps } else { 0 };
                    assert_eq!(r.msgs_axis[a], want,
                               "grid={grid:?} axis {a}");
                }
            }
            traffic.push(rep.ranks.iter()
                             .map(|r| r.bytes_sent)
                             .sum::<u64>());
        }
        assert_eq!(traffic[0], traffic[1],
                   "grid={grid:?}: schedules exchange the same faces");
    }
}

/// The acceptance benchmark in test form: on a 32^3 cube at 8 ranks the
/// 2x2x2 block decomposition moves fewer halo bytes per step than the
/// 8x1x1 slab (5832 vs 6144 site payloads per rank per step), while
/// staying bit-identical to it.
#[test]
fn block_grid_beats_slab_halo_bytes_on_a_cube_at_8_ranks() {
    let model = LatticeModel::D3Q19;
    let geom = Geometry::new(32, 32, 32);
    let steps = 1u64;
    let mut bytes = vec![];
    let mut states = vec![];
    for grid in [[8, 1, 1], [2, 2, 2]] {
        let cfg = CommsConfig { ranks: 8, grid, threads: 8,
                                ..CommsConfig::default() };
        let (mut f, mut g) = initial_state(model, &geom);
        let rep = run_decomposed(&geom, model.velset(),
                                 &FeParams::default(), &mut f, &mut g,
                                 steps, &cfg)
            .unwrap();
        bytes.push(rep.ranks.iter().map(|r| r.bytes_sent).sum::<u64>());
        states.push((f, g));
    }
    assert!(bytes[1] < bytes[0],
            "2x2x2 must exchange fewer halo bytes than 8x1x1 on a cube \
             (got grid {} vs slab {})",
            bytes[1], bytes[0]);
    assert_eq!(states[0], states[1],
               "slab and block worlds are bit-identical");
}

/// A 2x2x2 world served over real TCP sockets — 8 rank endpoints plus
/// the controller on loopback — matches the channel world and the fused
/// engine bitwise, through the full resident command protocol.
#[test]
fn grid_socket_world_matches_channel_world_and_engine() {
    let model = LatticeModel::D3Q19;
    let vs = model.velset();
    let geom = Geometry::new(6, 5, 4); // uneven y and z splits
    let n = geom.nsites();
    let steps = 4u64;
    let p = FeParams::default();
    let grid = [2, 2, 2];
    let cfg = CommsConfig { ranks: 8, grid, ..CommsConfig::default() };
    let (f0, g0) = initial_state(model, &geom);

    // reference 1: the channel grid world
    let mut f_ch = f0.clone();
    let mut g_ch = g0.clone();
    run_decomposed(&geom, vs, &p, &mut f_ch, &mut g_ch, steps, &cfg)
        .unwrap();

    // reference 2: the single-domain fused engine
    let (f_en, g_en) = fullstep_reference(model, &geom, steps);
    assert_eq!(f_ch, f_en, "channel grid world matches the fused engine");
    assert_eq!(g_ch, g_en);

    // the socket world: 8 rank endpoints over real TCP connections
    let (rank_transports, ctl) = loopback_world(8);
    let world = CommsWorld::new(geom, cfg.clone()).unwrap();
    let mut servers = Vec::new();
    for t in rank_transports {
        let d = world.dec.domains[t.rank()].clone();
        let (f0, g0) = (f0.clone(), g0.clone());
        let cfg = cfg.clone();
        servers.push(thread::spawn(move || {
            serve_rank(d, vs, &p, f0, g0, &cfg, 1, Box::new(t))
        }));
    }
    let mut session = world.remote_session(vs, Box::new(ctl)).unwrap();
    // multi-block schedule with a mid-run distributed reduction
    session.advance(1).unwrap();
    let obs = session.observables().unwrap();
    assert!((obs.mass - n as f64).abs() < 1e-9,
            "mass conserved over the grid-world socket reduction");
    session.advance(steps - 1).unwrap();
    let mut f_s = vec![0.0; vs.nvel * n];
    let mut g_s = vec![0.0; vs.nvel * n];
    session.gather(&mut f_s, &mut g_s).unwrap();
    let phi = session.gather_phi().unwrap();
    let report = session.finish().unwrap();
    for s in servers {
        s.join().unwrap().unwrap();
    }

    assert_eq!(f_s, f_ch, "socket grid world is bit-identical to channel");
    assert_eq!(g_s, g_ch);
    assert_eq!(phi.len(), n);
    assert_eq!(report.ranks.len(), 8);
    for r in &report.ranks {
        assert_eq!(r.steps, steps);
        // 3 decomposed axes: 18 face messages per rank per step
        assert_eq!(r.msgs_sent, 18 * steps);
    }
}

/// Validation errors are grid-aware and name the offending axis.
#[test]
fn grid_validation_names_the_axis() {
    let cfg = |grid: [usize; 3], ranks: usize, depth: usize| CommsConfig {
        ranks,
        grid,
        depth,
        ..CommsConfig::default()
    };

    // an axis too short to split is reported by name
    let err = CommsWorld::new(Geometry::new(8, 2, 8),
                              cfg([1, 4, 1], 4, 1))
        .unwrap_err()
        .to_string();
    assert!(err.contains("y axis"), "{err}");

    // grid product must match the rank count
    let err = CommsWorld::new(Geometry::new(8, 8, 8),
                              cfg([2, 2, 1], 8, 1))
        .unwrap_err()
        .to_string();
    assert!(err.contains("ranks"), "{err}");

    // super-steps are an x-blocked slab optimisation
    let err = CommsWorld::new(Geometry::new(16, 8, 8),
                              cfg([1, 2, 2], 4, 2))
        .unwrap_err()
        .to_string();
    assert!(err.contains("slab"), "{err}");

    // ... and still work on an explicit slab grid
    assert!(CommsWorld::new(Geometry::new(16, 8, 8),
                            cfg([4, 1, 1], 4, 2))
        .is_ok());
}
