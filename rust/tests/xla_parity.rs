//! Cross-layer parity: the AOT JAX/Pallas executables (L1/L2) must agree
//! with the Rust host kernels (L3) to f64 round-off. This is the test that
//! pins all three layers of the stack together.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use targetdp::lattice::geometry::Geometry;
use targetdp::lb::collision::collide_lattice;
use targetdp::lb::init;
use targetdp::lb::model::{d3q19, LatticeModel};
use targetdp::runtime::Runtime;
use targetdp::targetdp::tlp::TlpPool;

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::load(Runtime::default_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP xla parity: {e}");
            None
        }
    }
}

#[test]
fn scale_artifact_matches_host() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = 4096;
    let field: Vec<f64> = (0..3 * n).map(|i| (i as f64).sin()).collect();
    let out = rt
        .execute("scale_n4096_vvl256", &[&field])
        .expect("scale executes");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), 3 * n);
    for (i, (a, b)) in out[0].iter().zip(&field).enumerate() {
        assert!((a - 1.5 * b).abs() < 1e-15, "elem {i}: {a} vs {}", 1.5 * b);
    }
}

#[test]
fn gradient_artifact_matches_host() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let geom = Geometry::new(16, 16, 16);
    let n = geom.nsites();
    let phi: Vec<f64> = (0..n)
        .map(|s| {
            let (x, y, z) = geom.coords(s);
            (x as f64 * 0.39).sin() + (y as f64 * 0.17).cos()
                + (z as f64 * 0.58).sin()
        })
        .collect();
    let out = rt.execute("gradient_16x16x16", &[&phi]).expect("gradient");
    assert_eq!(out.len(), 2);

    let mut grad = vec![0.0; 3 * n];
    let mut lap = vec![0.0; n];
    targetdp::free_energy::gradient::gradient_fd(
        &geom, &phi, &mut grad, &mut lap, &TlpPool::serial(), 8);

    for (i, (a, b)) in out[0].iter().zip(&grad).enumerate() {
        assert!((a - b).abs() < 1e-12, "grad[{i}]: {a} vs {b}");
    }
    for (i, (a, b)) in out[1].iter().zip(&lap).enumerate() {
        assert!((a - b).abs() < 1e-12, "lap[{i}]: {a} vs {b}");
    }
}

#[test]
fn collision_artifact_matches_host_kernel() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let meta = rt
        .find(|m| m.matches_flat("collision", "d3q19", 4096))
        .expect("collision artifact")
        .clone();
    let p = meta.params.expect("baked params");
    let vs = d3q19();
    let n = 4096;

    // deterministic near-equilibrium state
    let geom = Geometry::new(16, 16, 16);
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f, &mut g, 0.05, 2024);
    let mut rng = init::Rng64::new(7);
    let grad: Vec<f64> = (0..3 * n).map(|_| 0.01 * rng.uniform()).collect();
    let lap: Vec<f64> = (0..n).map(|_| 0.01 * rng.uniform()).collect();

    let out = rt
        .execute(&meta.name, &[&f, &g, &grad, &lap])
        .expect("collision executes");
    assert_eq!(out.len(), 2);

    let mut f_host = f.clone();
    let mut g_host = g.clone();
    collide_lattice(vs, &p, &mut f_host, &mut g_host, &grad, &lap, n,
                    &TlpPool::serial(), 8, false);

    let mut max_f: f64 = 0.0;
    for (a, b) in out[0].iter().zip(&f_host) {
        max_f = max_f.max((a - b).abs());
    }
    let mut max_g: f64 = 0.0;
    for (a, b) in out[1].iter().zip(&g_host) {
        max_g = max_g.max((a - b).abs());
    }
    assert!(max_f < 1e-13, "f parity: max |diff| = {max_f:e}");
    assert!(max_g < 1e-13, "g parity: max |diff| = {max_g:e}");
}

#[test]
fn full_step_artifact_matches_host_pipeline() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let vs = d3q19();
    let geom = Geometry::new(16, 16, 16);
    let n = geom.nsites();
    let meta = rt
        .find(|m| m.matches_grid("full_step", "d3q19", &[16, 16, 16]))
        .expect("full_step artifact")
        .clone();
    let p = meta.params.expect("baked params");

    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f, &mut g, 0.05, 31337);

    // host pipeline: phi -> grad -> collide -> stream
    let pool = TlpPool::serial();
    let mut f_host = f.clone();
    let mut g_host = g.clone();
    let mut phi = vec![0.0; n];
    let mut grad = vec![0.0; 3 * n];
    let mut lap = vec![0.0; n];
    targetdp::lb::moments::phi_from_g(vs, &g_host, &mut phi, n, &pool, 8);
    targetdp::free_energy::gradient::gradient_fd(&geom, &phi, &mut grad,
                                                 &mut lap, &pool, 8);
    collide_lattice(vs, &p, &mut f_host, &mut g_host, &grad, &lap, n, &pool,
                    8, false);
    let mut fs = vec![0.0; vs.nvel * n];
    let mut gs = vec![0.0; vs.nvel * n];
    targetdp::lb::propagation::stream(vs, &geom, &f_host, &mut fs, &pool, 8);
    targetdp::lb::propagation::stream(vs, &geom, &g_host, &mut gs, &pool, 8);

    let out = rt.execute(&meta.name, &[&f, &g]).expect("full_step executes");
    let mut max_f: f64 = 0.0;
    for (a, b) in out[0].iter().zip(&fs) {
        max_f = max_f.max((a - b).abs());
    }
    let mut max_g: f64 = 0.0;
    for (a, b) in out[1].iter().zip(&gs) {
        max_g = max_g.max((a - b).abs());
    }
    assert!(max_f < 1e-12, "full step f parity: {max_f:e}");
    assert!(max_g < 1e-12, "full step g parity: {max_g:e}");
}

#[test]
fn multi_step_equals_repeated_full_step() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let Some(multi) = rt
        .find(|m| m.matches_grid("multi_step", "d3q19", &[16, 16, 16]))
        .cloned()
    else {
        eprintln!("SKIP: no multi_step artifact");
        return;
    };
    let steps = multi.steps.unwrap();
    let full = rt
        .find(|m| m.matches_grid("full_step", "d3q19", &[16, 16, 16]))
        .expect("full_step artifact")
        .clone();

    let vs = d3q19();
    let geom = Geometry::new(16, 16, 16);
    let n = geom.nsites();
    let p = multi.params.expect("params");
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &p, &geom, &mut f, &mut g, 0.05, 5150);

    let fused = rt.execute(&multi.name, &[&f, &g]).expect("multi_step");

    let mut fr = f.clone();
    let mut gr = g.clone();
    for _ in 0..steps {
        let out = rt.execute(&full.name, &[&fr, &gr]).expect("full_step");
        fr = out[0].clone();
        gr = out[1].clone();
    }

    let mut max_d: f64 = 0.0;
    for (a, b) in fused[0].iter().zip(&fr) {
        max_d = max_d.max((a - b).abs());
    }
    for (a, b) in fused[1].iter().zip(&gr) {
        max_d = max_d.max((a - b).abs());
    }
    assert!(max_d < 1e-11, "multi-step parity: {max_d:e}");
    let _ = LatticeModel::D3Q19;
}
