//! Residency parity: a resident comms session advanced in logging blocks
//! — rank threads spawned once, state staying slab-local, commands
//! pausing the ranks at a barrier between blocks — must be
//! **bit-identical** to the one-shot world (single `Advance`) and to the
//! single-domain fused `FullStep` engine, for every block pattern, rank
//! count and exchange schedule. Per-block distributed observables must
//! match the gathered-state reduction to summation-order rounding (the
//! documented contract of `Observables::from_sums`).

use targetdp::comms::{run_decomposed, CommsConfig, CommsWorld};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::{state_observables, LbEngine, Observables};
use targetdp::lb::init;
use targetdp::lb::model::LatticeModel;
use targetdp::targetdp::tlp::TlpPool;
use targetdp::targetdp::HostTarget;

const STEPS: u64 = 10;

/// Block patterns summing to [`STEPS`]: single-step blocks, coarse blocks
/// with an uneven remainder, and a two-block split.
const PATTERNS: [&[u64]; 3] =
    [&[1, 1, 1, 1, 1, 1, 1, 1, 1, 1], &[3, 3, 3, 1], &[4, 6]];

fn initial_state(model: LatticeModel, geom: &Geometry)
                 -> (Vec<f64>, Vec<f64>) {
    let vs = model.velset();
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init::init_spinodal(vs, &FeParams::default(), geom, &mut f, &mut g,
                        0.06, 2024);
    (f, g)
}

/// Single-domain reference through the engine's fused `FullStep` tier.
fn fullstep_reference(model: LatticeModel, geom: &Geometry)
                      -> (Vec<f64>, Vec<f64>) {
    let (f0, g0) = initial_state(model, geom);
    let mut target = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let mut engine =
        LbEngine::new(&mut target, *geom, model, FeParams::default())
            .unwrap();
    assert!(engine.fused_active(), "host target must take the fused tier");
    engine.load_state(&f0, &g0).unwrap();
    engine.run(STEPS).unwrap();
    let mut f = vec![0.0; f0.len()];
    let mut g = vec![0.0; g0.len()];
    engine.fetch_state(&mut f, &mut g).unwrap();
    (f, g)
}

fn check_model(model: LatticeModel, geom: Geometry) {
    let vs = model.velset();
    let n = geom.nsites();
    let (f_want, g_want) = fullstep_reference(model, &geom);
    for ranks in [1usize, 2, 4] {
        for overlap in [false, true] {
            let cfg = CommsConfig {
                ranks,
                overlap,
                threads: 4, // shared budget: ranks get 4/ranks workers
                ..CommsConfig::default()
            };

            // one-shot world: the wrapper (session + single Advance)
            let (mut f1, mut g1) = initial_state(model, &geom);
            let rep = run_decomposed(&geom, vs, &FeParams::default(),
                                     &mut f1, &mut g1, STEPS, &cfg)
                .unwrap();
            assert_eq!(rep.ranks.len(), ranks);
            assert!(rep.ranks.iter().all(|r| r.steps == STEPS));
            assert_eq!(
                f1, f_want,
                "{} ranks={ranks} overlap={overlap}: one-shot f diverged",
                model.name()
            );
            assert_eq!(
                g1, g_want,
                "{} ranks={ranks} overlap={overlap}: one-shot g diverged",
                model.name()
            );

            // resident sessions: same steps split into pause/resume
            // blocks, with a distributed reduction at every boundary
            for pattern in PATTERNS {
                assert_eq!(pattern.iter().sum::<u64>(), STEPS);
                let world = CommsWorld::new(geom, cfg.clone()).unwrap();
                let (f0, g0) = initial_state(model, &geom);
                let mut session = world
                    .session(vs, &FeParams::default(), f0, g0)
                    .unwrap();
                for &block in pattern {
                    session.advance(block).unwrap();
                    // the between-block reduction must not perturb state
                    session.observables().unwrap();
                }
                assert_eq!(session.steps_done(), STEPS);
                let mut f = vec![0.0; vs.nvel * n];
                let mut g = vec![0.0; vs.nvel * n];
                session.gather(&mut f, &mut g).unwrap();
                let rep = session.finish().unwrap();
                assert!(rep.ranks.iter().all(|r| r.steps == STEPS));
                assert_eq!(
                    f, f_want,
                    "{} ranks={ranks} overlap={overlap} blocks={pattern:?}: \
                     resident f diverged",
                    model.name()
                );
                assert_eq!(
                    g, g_want,
                    "{} ranks={ranks} overlap={overlap} blocks={pattern:?}: \
                     resident g diverged",
                    model.name()
                );
            }
        }
    }
}

#[test]
fn d3q19_resident_blocks_match_fullstep_bitwise() {
    // lx = 13 over 4 ranks -> slabs of 4,3,3,3: uneven split exercised
    check_model(LatticeModel::D3Q19, Geometry::new(13, 4, 4));
}

#[test]
fn d2q9_resident_blocks_match_fullstep_bitwise() {
    // lx = 10 over 4 ranks -> slabs of 3,3,2,2
    check_model(LatticeModel::D2Q9, Geometry::new(10, 12, 1));
}

/// Distributed per-block observables vs the gathered-state reduction at
/// every block boundary. The partial sums are exact per rank and combine
/// in rank order; only the summation *order* differs from the single
/// global sweep of `state_observables`, so the values agree to rounding
/// (documented on `Observables::from_sums`) — pinned here with an
/// absolute + relative tolerance.
#[test]
fn reduced_observables_track_gathered_state_at_every_boundary() {
    let model = LatticeModel::D3Q19;
    let geom = Geometry::new(12, 5, 4);
    let vs = model.velset();
    let n = geom.nsites();
    let close = |a: f64, b: f64, what: &str, step: u64| {
        assert!((a - b).abs() <= 1e-12 + 1e-9 * b.abs(),
                "step {step} {what}: reduced {a} vs gathered {b}");
    };
    for ranks in [1usize, 3] {
        let world = CommsWorld::new(geom, CommsConfig {
            ranks,
            ..CommsConfig::default()
        })
        .unwrap();
        let (f0, g0) = initial_state(model, &geom);
        let mut session =
            world.session(vs, &FeParams::default(), f0, g0).unwrap();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        for &block in &[3u64, 3, 4] {
            session.advance(block).unwrap();
            let got = session.observables().unwrap();
            session.gather(&mut f, &mut g).unwrap();
            let want = state_observables(vs, &f, &g, n);
            let step = session.steps_done();
            close(got.mass, want.mass, "mass", step);
            close(got.phi_total, want.phi_total, "phi_total", step);
            close(got.phi_variance, want.phi_variance, "phi_variance",
                  step);
            for a in 0..3 {
                close(got.momentum[a], want.momentum[a], "momentum", step);
            }
        }
        session.finish().unwrap();
    }
}

/// The distributed reduction is deterministic: two identical resident
/// runs produce bit-identical observables at every boundary.
#[test]
fn reduced_observables_are_deterministic() {
    let model = LatticeModel::D2Q9;
    let geom = Geometry::new(9, 7, 1);
    let vs = model.velset();
    let run = || -> Vec<Observables> {
        let world = CommsWorld::new(geom, CommsConfig {
            ranks: 3,
            threads: 4,
            ..CommsConfig::default()
        })
        .unwrap();
        let (f0, g0) = initial_state(model, &geom);
        let mut session =
            world.session(vs, &FeParams::default(), f0, g0).unwrap();
        let mut out = Vec::new();
        for _ in 0..4 {
            session.advance(2).unwrap();
            out.push(session.observables().unwrap());
        }
        session.finish().unwrap();
        out
    };
    let a = run();
    let b = run();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.mass.to_bits(), y.mass.to_bits());
        assert_eq!(x.phi_total.to_bits(), y.phi_total.to_bits());
        assert_eq!(x.phi_variance.to_bits(), y.phi_variance.to_bits());
        for (ma, mb) in x.momentum.iter().zip(&y.momentum) {
            assert_eq!(ma.to_bits(), mb.to_bits());
        }
    }
}

/// The halo-traffic totals accumulate across blocks exactly like a
/// one-shot run: the command plane adds no halo messages, and a resident
/// multi-block run moves the same planes as a single Advance.
#[test]
fn resident_traffic_matches_one_shot() {
    let model = LatticeModel::D2Q9;
    let geom = Geometry::new(12, 6, 1);
    let vs = model.velset();
    let cfg = CommsConfig { ranks: 3, ..CommsConfig::default() };

    let (mut f, mut g) = initial_state(model, &geom);
    let one_shot = run_decomposed(&geom, vs, &FeParams::default(), &mut f,
                                  &mut g, STEPS, &cfg)
        .unwrap();

    let world = CommsWorld::new(geom, cfg).unwrap();
    let (f0, g0) = initial_state(model, &geom);
    let mut session =
        world.session(vs, &FeParams::default(), f0, g0).unwrap();
    for &block in &[2u64, 5, 3] {
        session.advance(block).unwrap();
        session.observables().unwrap();
    }
    let resident = session.finish().unwrap();

    for (a, b) in one_shot.ranks.iter().zip(&resident.ranks) {
        assert_eq!(a.rank, b.rank);
        // 6 halo messages per rank per step in both worlds
        assert_eq!(a.msgs_sent, 6 * STEPS);
        assert_eq!(b.msgs_sent, 6 * STEPS);
        assert_eq!(a.bytes_sent, b.bytes_sent);
        assert!(b.idle_s >= 0.0 && b.compute_s >= 0.0 && b.wait_s >= 0.0);
    }
}
