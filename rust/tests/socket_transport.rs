//! SocketTransport over loopback: wire-frame byte round-trips, timeout
//! semantics (whole frames or nothing), and the headline guarantee — a
//! 2-rank world over TCP is **bit-identical** to the same world over
//! in-process channels and to the single-domain fused `FullStep` engine.
//!
//! These tests assemble real TCP socket worlds on 127.0.0.1 through the
//! production rendezvous (`comms::launcher`), with the rank endpoints
//! served from threads of this process — the byte stream is exactly the
//! multi-process one (the CI multidomain smoke additionally spans real
//! OS processes).

use std::thread;
use std::time::Duration;

use targetdp::comms::launcher::{connect_rank, RankServer};
use targetdp::comms::{run_decomposed, serve_rank, Axis, Command,
                      CommsConfig, CommsWorld, FieldId, Frame, PartialObs,
                      Phase, PlaneMsg, Side, SocketTransport, Tag,
                      Transport};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::engine::LbEngine;
use targetdp::lb::init::init_spinodal;
use targetdp::lb::model::{d2q9, LatticeModel};
use targetdp::targetdp::tlp::TlpPool;
use targetdp::targetdp::HostTarget;

/// Assemble an N-rank + controller socket world on loopback: N
/// `connect_rank` threads against one rendezvous server.
fn loopback_world(nranks: usize)
                  -> (Vec<SocketTransport>, SocketTransport) {
    let server = RankServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let joins: Vec<_> = (0..nranks)
        .map(|r| {
            let addr = addr.clone();
            thread::spawn(move || connect_rank(&addr, Some(r)).unwrap())
        })
        .collect();
    let ctl = server.rendezvous(nranks, b"").unwrap();
    let mut ranks: Vec<Option<SocketTransport>> =
        (0..nranks).map(|_| None).collect();
    for j in joins {
        let (t, _payload) = j.join().unwrap();
        let r = t.rank();
        assert!(ranks[r].is_none());
        ranks[r] = Some(t);
    }
    (ranks.into_iter().map(Option::unwrap).collect(), ctl)
}

fn awkward_doubles() -> Vec<f64> {
    vec![0.0, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE, f64::MAX, -1e-300,
         f64::EPSILON, -255.25]
}

#[test]
fn wire_frames_round_trip_bitwise_over_tcp() {
    let (mut ranks, mut ctl) = loopback_world(2);

    // rank 0 -> rank 1: a tagged halo plane with awkward payloads
    let msg = PlaneMsg {
        src: 0,
        tag: Tag {
            step: 41,
            phase: Phase::Stream,
            field: FieldId::G,
            side: Side::High,
            axis: Axis::Y,
        },
        data: awkward_doubles(),
    };
    ranks[0].send_frame(1, &Frame::Plane(msg.clone())).unwrap();
    match ranks[1].recv().unwrap() {
        Frame::Plane(back) => {
            assert_eq!(back.src, msg.src);
            assert_eq!(back.tag, msg.tag);
            assert_eq!(back.data.len(), msg.data.len());
            for (a, b) in back.data.iter().zip(&msg.data) {
                assert_eq!(a.to_bits(), b.to_bits(),
                           "bitwise f64 transport over TCP");
            }
        }
        other => panic!("expected a plane, got {other:?}"),
    }

    // controller -> rank: a command; rank -> controller: partial sums
    ctl.send_frame(0, &Frame::Command(Command::Advance { steps: 7 }))
        .unwrap();
    assert_eq!(ranks[0].recv().unwrap(),
               Frame::Command(Command::Advance { steps: 7 }));
    let p = PartialObs {
        src: 1,
        steps: 7,
        sites: 123,
        mass: 1.0 / 3.0,
        momentum: [-0.0, f64::MIN_POSITIVE, 7.25e11],
        phi_total: -41.5,
        phi_sq: 1e-300,
        wait_s: 0.125,
        busy_s: 2.5,
    };
    ranks[1].send_frame(2, &Frame::Partials(p)).unwrap();
    match ctl.recv().unwrap() {
        Frame::Partials(back) => {
            assert_eq!(back.mass.to_bits(), p.mass.to_bits());
            for (a, b) in back.momentum.iter().zip(&p.momentum) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(back.phi_sq.to_bits(), p.phi_sq.to_bits());
        }
        other => panic!("expected partials, got {other:?}"),
    }
}

#[test]
fn per_sender_order_is_preserved() {
    let (mut ranks, _ctl) = loopback_world(2);
    let tag = |step| Tag {
        step,
        phase: Phase::Moments,
        field: FieldId::F,
        side: Side::Low,
        axis: Axis::X,
    };
    for step in 0..50u64 {
        ranks[0]
            .send_plane(1, 0, tag(step), &[step as f64])
            .unwrap();
    }
    for step in 0..50u64 {
        match ranks[1].recv().unwrap() {
            Frame::Plane(m) => {
                assert_eq!(m.tag.step, step, "TCP preserves send order");
                assert_eq!(m.data, vec![step as f64]);
            }
            other => panic!("expected a plane, got {other:?}"),
        }
    }
}

#[test]
fn timeout_is_whole_frame_or_none() {
    let (mut ranks, mut ctl) = loopback_world(2);
    // nothing in flight: a timed receive returns None, consuming nothing
    assert!(ranks[0]
        .recv_bytes_timeout(Duration::from_millis(30))
        .unwrap()
        .is_none());
    // a large frame (hundreds of KiB, many TCP segments) still arrives
    // as exactly one complete frame
    let big = PlaneMsg {
        src: 1,
        tag: Tag {
            step: 1,
            phase: Phase::Stream,
            field: FieldId::F,
            side: Side::Low,
            axis: Axis::Z,
        },
        data: (0..100_000).map(|i| i as f64 * 0.5).collect(),
    };
    let encoded = big.encode();
    ctl.send_bytes(0, encoded.clone()).unwrap();
    let got = ranks[0]
        .recv_bytes_timeout(Duration::from_secs(30))
        .unwrap()
        .expect("frame arrives");
    assert_eq!(got, encoded, "byte-exact frame image");
    assert_eq!(PlaneMsg::decode(&got).unwrap(), big);
}

#[test]
fn dead_world_errors_instead_of_hanging() {
    let (mut ranks, ctl) = loopback_world(2);
    let r1 = ranks.pop().unwrap();
    let mut r0 = ranks.pop().unwrap();
    drop(r1);
    drop(ctl);
    // every connection is gone: receives error rather than block forever
    assert!(r0.recv_bytes().is_err(), "a dead world must surface");
    assert!(r0.recv_bytes_timeout(Duration::from_secs(30)).is_err());
}

/// The headline acceptance test: the same 2-rank run over
/// `SocketTransport` (real TCP worlds), over `ChannelTransport`, and on
/// the single-domain fused `FullStep` engine — all three bit-identical,
/// with a mid-run distributed reduction and a multi-block schedule
/// exercising the full resident command protocol over sockets.
#[test]
fn two_rank_socket_world_matches_channel_world_and_engine() {
    let vs = d2q9();
    let geom = Geometry::new(9, 6, 1); // 9 -> uneven 5+4 slab split
    let n = geom.nsites();
    let steps = 6u64;
    let p = FeParams::default();
    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 31);
    let cfg = CommsConfig { ranks: 2, ..CommsConfig::default() };

    // reference 1: the channel world
    let mut f_ch = f0.clone();
    let mut g_ch = g0.clone();
    run_decomposed(&geom, vs, &p, &mut f_ch, &mut g_ch, steps, &cfg)
        .unwrap();

    // reference 2: the single-domain fused FullStep engine
    let mut target = HostTarget::simd(8, TlpPool::serial()).unwrap();
    let mut engine =
        LbEngine::new(&mut target, geom, LatticeModel::D2Q9, p).unwrap();
    assert!(engine.fused_active());
    engine.load_state(&f0, &g0).unwrap();
    engine.run(steps).unwrap();
    let mut f_en = vec![0.0; vs.nvel * n];
    let mut g_en = vec![0.0; vs.nvel * n];
    engine.fetch_state(&mut f_en, &mut g_en).unwrap();
    assert_eq!(f_ch, f_en, "channel world matches the fused engine");
    assert_eq!(g_ch, g_en);

    // the socket world: rank endpoints served over real TCP connections
    let (rank_transports, ctl) = loopback_world(2);
    let world = CommsWorld::new(geom, cfg.clone()).unwrap();
    let mut servers = Vec::new();
    for t in rank_transports {
        let d = world.dec.domains[t.rank()].clone();
        let (f0, g0) = (f0.clone(), g0.clone());
        let cfg = cfg.clone();
        servers.push(thread::spawn(move || {
            serve_rank(d, vs, &p, f0, g0, &cfg, 1, Box::new(t))
        }));
    }
    let mut session = world.remote_session(vs, Box::new(ctl)).unwrap();
    // multi-block schedule with a mid-run reduction: 6 = 2 + 4
    session.advance(2).unwrap();
    let obs = session.observables().unwrap();
    assert!((obs.mass - n as f64).abs() < 1e-9,
            "mass conserved over the socket reduction");
    session.advance(steps - 2).unwrap();
    let mut f_s = vec![0.0; vs.nvel * n];
    let mut g_s = vec![0.0; vs.nvel * n];
    session.gather(&mut f_s, &mut g_s).unwrap();
    let phi = session.gather_phi().unwrap();
    let report = session.finish().unwrap();
    for s in servers {
        s.join().unwrap().unwrap();
    }

    assert_eq!(f_s, f_ch, "socket world is bit-identical to channel");
    assert_eq!(g_s, g_ch);
    assert_eq!(f_s, f_en, "socket world is bit-identical to the engine");
    assert_eq!(g_s, g_en);
    assert_eq!(phi.len(), n);
    assert_eq!(report.ranks.len(), 2);
    for r in &report.ranks {
        assert_eq!(r.steps, steps);
        // same wire frames -> same halo-traffic accounting as channel
        // worlds: 6 plane messages per step
        assert_eq!(r.msgs_sent, 6 * steps);
        assert!(r.bytes_sent > 0);
    }
}

/// Both exchange schedules and an uneven 3-rank split over sockets stay
/// bit-identical to the channel world.
#[test]
fn socket_world_parity_across_schedules_and_rank_counts() {
    let vs = d2q9();
    let geom = Geometry::new(10, 4, 1);
    let n = geom.nsites();
    let steps = 4u64;
    let p = FeParams::default();
    let mut f0 = vec![0.0; vs.nvel * n];
    let mut g0 = vec![0.0; vs.nvel * n];
    init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 77);

    for ranks in [2usize, 3] {
        for overlap in [false, true] {
            let cfg = CommsConfig { ranks, overlap,
                                    ..CommsConfig::default() };
            let mut f_ch = f0.clone();
            let mut g_ch = g0.clone();
            run_decomposed(&geom, vs, &p, &mut f_ch, &mut g_ch, steps,
                           &cfg)
                .unwrap();

            let (rank_transports, ctl) = loopback_world(ranks);
            let world = CommsWorld::new(geom, cfg.clone()).unwrap();
            let mut servers = Vec::new();
            for t in rank_transports {
                let d = world.dec.domains[t.rank()].clone();
                let (f0, g0) = (f0.clone(), g0.clone());
                let cfg = cfg.clone();
                servers.push(thread::spawn(move || {
                    serve_rank(d, vs, &p, f0, g0, &cfg, 1, Box::new(t))
                }));
            }
            let mut session =
                world.remote_session(vs, Box::new(ctl)).unwrap();
            session.advance(steps).unwrap();
            let mut f_s = vec![0.0; vs.nvel * n];
            let mut g_s = vec![0.0; vs.nvel * n];
            session.gather(&mut f_s, &mut g_s).unwrap();
            session.finish().unwrap();
            for s in servers {
                s.join().unwrap().unwrap();
            }
            assert_eq!(f_s, f_ch, "ranks={ranks} overlap={overlap}");
            assert_eq!(g_s, g_ch, "ranks={ranks} overlap={overlap}");
        }
    }
}

/// serve_rank validates the endpoint/subdomain pairing up front.
#[test]
fn serve_rank_rejects_mismatched_endpoints() {
    let vs = d2q9();
    let geom = Geometry::new(8, 4, 1);
    let cfg = CommsConfig { ranks: 2, ..CommsConfig::default() };
    let world = CommsWorld::new(geom, cfg.clone()).unwrap();
    let (mut rank_transports, _ctl) = loopback_world(2);
    let t1 = rank_transports.pop().unwrap(); // endpoint 1
    // endpoint 1 serving rank 0's subdomain is refused before any I/O
    let d0 = world.dec.domains[0].clone();
    let n = geom.nsites();
    let err = serve_rank(d0, vs, &FeParams::default(),
                         vec![0.0; vs.nvel * n], vec![0.0; vs.nvel * n],
                         &cfg, 1, Box::new(t1));
    assert!(err.is_err());
}
