//! Property-based tests (in-tree generator; proptest is unavailable in
//! this offline environment). Each property runs over many randomized
//! cases seeded deterministically, and failures print the seed.

use targetdp::comms::{run_decomposed, CommsConfig};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::collision::{collide_lattice, collide_sites_scalar};
use targetdp::lb::init::Rng64;
use targetdp::lb::model::{d2q9, d3q19, VelSet};
use targetdp::lb::propagation::stream;
use targetdp::targetdp::masked;
use targetdp::targetdp::tlp::{Schedule, TlpPool};

/// Random admissible free-energy parameters.
fn random_params(rng: &mut Rng64) -> FeParams {
    let a = -(0.01 + 0.15 * (rng.uniform() + 0.5));
    FeParams {
        a,
        b: -a * (0.5 + (rng.uniform() + 0.5)),
        kappa: 0.01 + 0.1 * (rng.uniform() + 0.5),
        gamma: 0.5 + (rng.uniform() + 0.5),
        tau_f: 0.6 + 1.5 * (rng.uniform() + 0.5),
        tau_g: 0.6 + 1.5 * (rng.uniform() + 0.5),
    }
}

fn random_state(vs: &VelSet, nsites: usize, rng: &mut Rng64)
                -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut f = vec![0.0; vs.nvel * nsites];
    let mut g = vec![0.0; vs.nvel * nsites];
    for i in 0..vs.nvel {
        for s in 0..nsites {
            f[i * nsites + s] = vs.wv[i] * (1.0 + 0.15 * rng.uniform());
            g[i * nsites + s] = vs.wv[i] * 0.2 * rng.uniform();
        }
    }
    let mut grad = vec![0.0; 3 * nsites];
    for d in 0..vs.ndim {
        for s in 0..nsites {
            grad[d * nsites + s] = 0.02 * rng.uniform();
        }
    }
    let lap: Vec<f64> = (0..nsites).map(|_| 0.02 * rng.uniform()).collect();
    (f, g, grad, lap)
}

fn invariants(vs: &VelSet, f: &[f64], g: &[f64], nsites: usize)
              -> (f64, [f64; 3], f64) {
    let mut mass = 0.0;
    let mut mom = [0.0f64; 3];
    for i in 0..vs.nvel {
        for s in 0..nsites {
            let fi = f[i * nsites + s];
            mass += fi;
            for a in 0..3 {
                mom[a] += vs.cv[i][a] * fi;
            }
        }
    }
    (mass, mom, g.iter().sum())
}

/// PROPERTY: collision conserves mass, momentum and phi for any admissible
/// parameters, lattice, VVL and state.
#[test]
fn prop_collision_conserves() {
    for case in 0..40u64 {
        let mut rng = Rng64::new(1000 + case);
        let vs = if case % 2 == 0 { d3q19() } else { d2q9() };
        let nsites = 32 + (rng.next_u64() % 200) as usize;
        let vvl = [1, 2, 4, 8, 16, 32][(rng.next_u64() % 6) as usize];
        let p = random_params(&mut rng);
        let (mut f, mut g, grad, lap) = random_state(vs, nsites, &mut rng);
        let (m0, mom0, phi0) = invariants(vs, &f, &g, nsites);
        collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, nsites,
                        &TlpPool::serial(), vvl, false);
        let (m1, mom1, phi1) = invariants(vs, &f, &g, nsites);
        assert!((m1 - m0).abs() < 1e-10, "case {case}: mass");
        assert!((phi1 - phi0).abs() < 1e-10, "case {case}: phi");
        for a in 0..3 {
            assert!((mom1[a] - mom0[a]).abs() < 1e-10,
                    "case {case}: mom[{a}]");
        }
    }
}

/// PROPERTY: the VVL partitioning never changes the physics (chunked ==
/// scalar for every VVL, nsites, alignment).
#[test]
fn prop_vvl_invariance() {
    for case in 0..30u64 {
        let mut rng = Rng64::new(9000 + case);
        let vs = if case % 2 == 0 { d3q19() } else { d2q9() };
        // deliberately misaligned sizes to exercise tail chunks
        let nsites = 17 + (rng.next_u64() % 150) as usize;
        let p = random_params(&mut rng);
        let (f0, g0, grad, lap) = random_state(vs, nsites, &mut rng);

        let mut f_ref = f0.clone();
        let mut g_ref = g0.clone();
        collide_sites_scalar(vs, &p, &mut f_ref, &mut g_ref, &grad, &lap,
                             nsites, 0, nsites);

        let vvl = [2, 4, 8, 16, 32][(rng.next_u64() % 5) as usize];
        let mut f = f0;
        let mut g = g0;
        collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, nsites,
                        &TlpPool::serial(), vvl, false);
        for (a, b) in f.iter().zip(&f_ref) {
            assert!((a - b).abs() < 1e-13, "case {case} vvl={vvl}");
        }
        for (a, b) in g.iter().zip(&g_ref) {
            assert!((a - b).abs() < 1e-13, "case {case} vvl={vvl}");
        }
    }
}

/// PROPERTY: TLP scheduling (threads, static/dynamic, batch) never changes
/// results — bitwise.
#[test]
fn prop_tlp_schedule_invariance() {
    for case in 0..15u64 {
        let mut rng = Rng64::new(4000 + case);
        let vs = d3q19();
        let nsites = 64 + (rng.next_u64() % 100) as usize;
        let p = random_params(&mut rng);
        let (f0, g0, grad, lap) = random_state(vs, nsites, &mut rng);
        let mut f_ref = f0.clone();
        let mut g_ref = g0.clone();
        collide_lattice(vs, &p, &mut f_ref, &mut g_ref, &grad, &lap, nsites,
                        &TlpPool::serial(), 8, false);
        let threads = 2 + (rng.next_u64() % 3) as usize;
        let batch = 1 + (rng.next_u64() % 4) as usize;
        let pool = TlpPool::new(threads, Schedule::Dynamic { batch });
        let mut f = f0;
        let mut g = g0;
        collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, nsites, &pool,
                        8, false);
        assert_eq!(f, f_ref, "case {case}");
        assert_eq!(g, g_ref, "case {case}");
    }
}

/// PROPERTY: streaming is a bijection — forward then backward is identity.
#[test]
fn prop_stream_bijective() {
    for case in 0..20u64 {
        let mut rng = Rng64::new(7000 + case);
        let vs = if case % 2 == 0 { d3q19() } else { d2q9() };
        let (lx, ly) = (2 + (rng.next_u64() % 6) as usize,
                        2 + (rng.next_u64() % 6) as usize);
        let lz = if vs.ndim == 3 { 2 + (rng.next_u64() % 4) as usize }
                 else { 1 };
        let geom = Geometry::new(lx, ly, lz);
        let n = geom.nsites();
        let src: Vec<f64> =
            (0..vs.nvel * n).map(|_| rng.uniform()).collect();
        let mut fwd = vec![0.0; vs.nvel * n];
        stream(vs, &geom, &src, &mut fwd, &TlpPool::serial(), 4);
        // pull with +c inverts the permutation
        let mut back = vec![0.0; vs.nvel * n];
        for s in 0..n {
            let (x, y, z) = geom.coords(s);
            for i in 0..vs.nvel {
                let c = vs.ci[i];
                let from = geom.neighbor(x, y, z, c[0], c[1], c[2]);
                back[i * n + s] = fwd[i * n + from];
            }
        }
        assert_eq!(back, src, "case {case}");
    }
}

/// PROPERTY: masked pack/unpack restores exactly the masked subset and
/// never touches the complement.
#[test]
fn prop_masked_copy_partition() {
    for case in 0..25u64 {
        let mut rng = Rng64::new(3000 + case);
        let nsites = 8 + (rng.next_u64() % 64) as usize;
        let ncomp = 1 + (rng.next_u64() % 19) as usize;
        let src: Vec<f64> =
            (0..ncomp * nsites).map(|_| rng.uniform()).collect();
        let mask: Vec<bool> =
            (0..nsites).map(|_| rng.next_u64() % 3 == 0).collect();
        let idx = masked::mask_indices(&mask);
        let packed = masked::pack(&src, nsites, ncomp, &idx);
        let sentinel = -42.0;
        let mut dst = vec![sentinel; ncomp * nsites];
        masked::unpack(&mut dst, nsites, ncomp, &idx, &packed);
        for c in 0..ncomp {
            for s in 0..nsites {
                let got = dst[c * nsites + s];
                if mask[s] {
                    assert_eq!(got, src[c * nsites + s], "case {case}");
                } else {
                    assert_eq!(got, sentinel, "case {case}");
                }
            }
        }
    }
}

/// PROPERTY: axis-face pack/unpack is a lossless round trip onto exactly
/// the face — for any axis (contiguous x planes, strided y runs,
/// z singletons), plane index, component count and geometry — and never
/// touches the complement.
#[test]
fn prop_face_pack_unpack_round_trip() {
    use targetdp::lattice::halo::{face_sites, pack_face, unpack_face};
    for case in 0..40u64 {
        let mut rng = Rng64::new(11_000 + case);
        let lx = 2 + (rng.next_u64() % 6) as usize;
        let ly = 2 + (rng.next_u64() % 6) as usize;
        let lz = 2 + (rng.next_u64() % 6) as usize;
        let geom = Geometry::new(lx, ly, lz);
        let n = geom.nsites();
        let ncomp = 1 + (rng.next_u64() % 19) as usize;
        let axis = (rng.next_u64() % 3) as usize;
        let ext = [lx, ly, lz][axis];
        let p = (rng.next_u64() % ext as u64) as usize;
        let src: Vec<f64> =
            (0..ncomp * n).map(|_| rng.uniform()).collect();

        let fsites = face_sites(&geom, axis);
        let mut payload = vec![0.0; ncomp * fsites];
        pack_face(&src, ncomp, &geom, axis, p, &mut payload);
        let sentinel = -77.5;
        let mut dst = vec![sentinel; ncomp * n];
        unpack_face(&mut dst, ncomp, &geom, axis, p, &payload);

        for c in 0..ncomp {
            for x in 0..lx {
                for y in 0..ly {
                    for z in 0..lz {
                        let s = geom.index(x, y, z);
                        let got = dst[c * n + s];
                        if [x, y, z][axis] == p {
                            assert_eq!(
                                got.to_bits(),
                                src[c * n + s].to_bits(),
                                "case {case} axis={axis} plane={p}"
                            );
                        } else {
                            assert_eq!(got, sentinel,
                                       "case {case} axis={axis} leaked");
                        }
                    }
                }
            }
        }
    }
}

/// PROPERTY: domain decomposition is exact for any domain count.
#[test]
fn prop_decomposition_exact() {
    for case in 0..6u64 {
        let mut rng = Rng64::new(5000 + case);
        let vs = d3q19();
        let p = FeParams::default();
        let lx = 6 + (rng.next_u64() % 7) as usize;
        let geom = Geometry::new(lx, 4, 3);
        let n = geom.nsites();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        targetdp::lb::init::init_spinodal(vs, &p, &geom, &mut f, &mut g,
                                          0.05, 60 + case);
        let pool = TlpPool::serial();

        // single-domain reference: 2 steps
        let mut f1 = f.clone();
        let mut g1 = g.clone();
        for _ in 0..2 {
            let mut phi = vec![0.0; n];
            let mut grad = vec![0.0; 3 * n];
            let mut lap = vec![0.0; n];
            targetdp::lb::moments::phi_from_g(vs, &g1, &mut phi, n, &pool,
                                              8);
            targetdp::free_energy::gradient::gradient_fd(
                &geom, &phi, &mut grad, &mut lap, &pool, 8);
            collide_lattice(vs, &p, &mut f1, &mut g1, &grad, &lap, n, &pool,
                            8, false);
            let mut fs = vec![0.0; vs.nvel * n];
            let mut gs = vec![0.0; vs.nvel * n];
            stream(vs, &geom, &f1, &mut fs, &pool, 8);
            stream(vs, &geom, &g1, &mut gs, &pool, 8);
            f1 = fs;
            g1 = gs;
        }

        // concurrent comms ranks, random count and schedule: must be
        // *bitwise* equal to the single-domain sweep
        let ndom = 2 + (rng.next_u64() % (lx as u64 - 2)) as usize;
        let overlap = rng.next_u64() % 2 == 0;
        let cfg = CommsConfig { ranks: ndom, overlap,
                                ..CommsConfig::default() };
        let mut f2 = f.clone();
        let mut g2 = g.clone();
        run_decomposed(&geom, vs, &p, &mut f2, &mut g2, 2, &cfg).unwrap();
        assert_eq!(f1, f2, "case {case} ndom={ndom} overlap={overlap}");
        assert_eq!(g1, g2, "case {case} ndom={ndom} overlap={overlap}");
    }
}

/// PROPERTY: Trace frames survive the wire bit-exactly for any span
/// batch — every phase/axis/side tag combination, arbitrary u64 steps,
/// arbitrary f64 timestamps (bit-compared), any record count including
/// zero.
#[test]
fn prop_trace_frame_round_trip() {
    use targetdp::comms::{Frame, TraceMsg};
    use targetdp::obs::trace::{Span, TracePhase, AXIS_NONE, SIDE_NONE};
    for case in 0..40u64 {
        let mut rng = Rng64::new(13_000 + case);
        let count = (rng.next_u64() % 50) as usize;
        let spans: Vec<Span> = (0..count)
            .map(|_| {
                let nphases = TracePhase::ALL.len() as u64;
                let t0 = rng.uniform() + 0.5;
                Span {
                    phase: TracePhase::ALL
                        [(rng.next_u64() % nphases) as usize],
                    step: rng.next_u64(),
                    axis: match rng.next_u64() % 4 {
                        3 => AXIS_NONE,
                        a => a as u8,
                    },
                    side: match rng.next_u64() % 3 {
                        2 => SIDE_NONE,
                        s => s as u8,
                    },
                    tid: (rng.next_u64() % 17) as u32,
                    t_start: t0,
                    t_end: t0 + rng.uniform() + 0.5,
                }
            })
            .collect();
        let msg = TraceMsg { src: (rng.next_u64() % 64) as u32,
                             spans: spans.clone() };
        let bytes = Frame::Trace(msg).encode();
        assert_eq!(bytes.len(), TraceMsg::frame_len(count), "case {case}");
        match Frame::decode(&bytes).unwrap() {
            Frame::Trace(back) => {
                assert_eq!(back.spans.len(), count, "case {case}");
                for (a, b) in back.spans.iter().zip(&spans) {
                    assert_eq!(a.phase, b.phase, "case {case}");
                    assert_eq!(a.step, b.step, "case {case}");
                    assert_eq!(a.axis, b.axis, "case {case}");
                    assert_eq!(a.side, b.side, "case {case}");
                    assert_eq!(a.tid, b.tid, "case {case}");
                    assert_eq!(a.t_start.to_bits(), b.t_start.to_bits(),
                               "case {case}");
                    assert_eq!(a.t_end.to_bits(), b.t_end.to_bits(),
                               "case {case}");
                }
            }
            other => panic!("case {case}: expected trace, got {other:?}"),
        }
    }
}

/// PROPERTY: the TDPK checkpoint image round-trips bit-exactly for any
/// step, dims, velocity-set width, config echo and field set — and the
/// strict decoder rejects every truncation, trailing garbage, bad
/// magic/version, a corrupted per-field count, and a dims edit that
/// breaks the `count == ncomp * nsites` cross-check.
#[test]
fn prop_checkpoint_image_round_trip_and_strict_decode() {
    use targetdp::comms::{Checkpoint, CheckpointField,
                          CHECKPOINT_HEADER_LEN};
    let palette = [0.0, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE, f64::MAX,
                   -1e-300, f64::EPSILON, -255.25];
    for case in 0..30u64 {
        let mut rng = Rng64::new(17_000 + case);
        let dims = [1 + rng.next_u64() % 5, 1 + rng.next_u64() % 4,
                    1 + rng.next_u64() % 3];
        let nsites = (dims[0] * dims[1] * dims[2]) as usize;
        let config_toml: String = (0..(rng.next_u64() % 60) as usize)
            .map(|_| (b' ' + (rng.next_u64() % 94) as u8) as char)
            .collect();
        let nfields = (rng.next_u64() % 4) as usize;
        let fields: Vec<CheckpointField> = (0..nfields)
            .map(|i| {
                let ncomp = 1 + (rng.next_u64() % 19) as u32;
                CheckpointField {
                    name: format!("field-{i}"),
                    ncomp,
                    data: (0..ncomp as usize * nsites)
                        .map(|_| match rng.next_u64() % 3 {
                            0 => palette
                                [(rng.next_u64() % 8) as usize],
                            _ => rng.uniform(),
                        })
                        .collect(),
                }
            })
            .collect();
        let ck = Checkpoint { step: rng.next_u64(), dims,
                              nvel: rng.next_u64() as u32,
                              config_toml, fields };
        let bytes = ck.encode();

        // bit-exact round trip (PartialEq on f64 misses -0.0 vs 0.0
        // and would accept it; compare payload bits explicitly)
        let back = Checkpoint::decode(&bytes).unwrap();
        assert_eq!(back, ck, "case {case}");
        for (a, b) in back.fields.iter().zip(&ck.fields) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "case {case}");
            }
        }

        // every strict prefix is rejected — whole image or nothing
        for len in 0..bytes.len() {
            assert!(Checkpoint::decode(&bytes[..len]).is_err(),
                    "case {case}: {len}-byte prefix decoded");
        }
        // oversize: trailing garbage after the last field
        let mut oversize = bytes.clone();
        oversize.push((rng.next_u64() % 256) as u8);
        assert!(Checkpoint::decode(&oversize).is_err(), "case {case}");
        // bad magic / version
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(Checkpoint::decode(&bad).is_err(), "case {case}");
        let mut bad = bytes.clone();
        bad[4] = bad[4].wrapping_add(1);
        assert!(Checkpoint::decode(&bad).is_err(), "case {case}");
        // a dims edit breaks every field's count cross-check (or, with
        // no fields, survives as a *different* valid header — never UB)
        let mut bad = bytes.clone();
        bad[13] = bad[13].wrapping_add(1);
        if nfields > 0 {
            assert!(Checkpoint::decode(&bad).is_err(), "case {case}");
        }
        // corrupting a field's count is caught by the cross-check
        if let Some(first) = ck.fields.first() {
            let count_at = CHECKPOINT_HEADER_LEN
                + ck.config_toml.len() // config echo
                + 1                    // nfields
                + 1 + first.name.len() // name_len + name
                + 4;                   // ncomp
            let mut bad = bytes.clone();
            bad[count_at] = bad[count_at].wrapping_add(1);
            assert!(Checkpoint::decode(&bad).is_err(), "case {case}");
        }
        // a non-UTF-8 config echo is rejected, not lossily accepted
        if !ck.config_toml.is_empty() {
            let mut bad = bytes;
            bad[CHECKPOINT_HEADER_LEN] = 0xff;
            assert!(Checkpoint::decode(&bad).is_err(), "case {case}");
        }
    }
}

/// PROPERTY: every `Command` wire frame — including the v6 `Checkpoint`
/// op — is 15 bytes, round-trips exactly, and survives no truncation,
/// trailing byte, or out-of-range op.
#[test]
fn prop_command_frame_strict() {
    use targetdp::comms::{Command, Frame};
    for case in 0..40u64 {
        let mut rng = Rng64::new(19_000 + case);
        let cmds = [Command::Advance { steps: rng.next_u64() },
                    Command::Observables, Command::Gather,
                    Command::GatherPhi, Command::Shutdown,
                    Command::Checkpoint];
        for cmd in cmds {
            let bytes = Frame::Command(cmd).encode();
            assert_eq!(bytes.len(), 15, "case {case} {cmd:?}");
            match Frame::decode(&bytes).unwrap() {
                Frame::Command(back) => {
                    assert_eq!(back, cmd, "case {case}")
                }
                other => panic!("case {case}: got {other:?}"),
            }
            for len in 0..bytes.len() {
                assert!(Frame::decode(&bytes[..len]).is_err(),
                        "case {case} {cmd:?}: {len}-byte prefix");
            }
            let mut bad = bytes.clone();
            bad.push(0);
            assert!(Frame::decode(&bad).is_err(), "case {case} {cmd:?}");
            // op byte (offset 6) out of range: 5 is the last command
            let mut bad = bytes;
            bad[6] = 6 + (rng.next_u64() % 250) as u8;
            assert!(Frame::decode(&bad).is_err(), "case {case} {cmd:?}");
        }
    }
}

/// PROPERTY: TLP chunk coverage is an exact partition for random (n, vvl,
/// threads, schedule).
#[test]
fn prop_tlp_partition() {
    use std::sync::atomic::{AtomicU32, Ordering};
    for case in 0..40u64 {
        let mut rng = Rng64::new(8000 + case);
        let n = (rng.next_u64() % 500) as usize;
        let vvl = 1 + (rng.next_u64() % 33) as usize;
        let threads = 1 + (rng.next_u64() % 4) as usize;
        let pool = if rng.next_u64() % 2 == 0 {
            TlpPool::new(threads, Schedule::Static)
        } else {
            TlpPool::new(threads, Schedule::Dynamic {
                batch: 1 + (rng.next_u64() % 5) as usize,
            })
        };
        let hits: Vec<AtomicU32> =
            (0..n).map(|_| AtomicU32::new(0)).collect();
        pool.for_chunks(n, vvl, |base, len| {
            for s in base..base + len {
                hits[s].fetch_add(1, Ordering::Relaxed);
            }
        });
        for (s, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1,
                       "case {case}: site {s} n={n} vvl={vvl}");
        }
    }
}
