//! Phase-trace telemetry: enabling span recording must be **free** —
//! bit-identical fields, identical data-plane traffic accounting — while
//! shipping a per-rank span timeline to the driver at `Shutdown`. Covers
//! the channel world (both exchange schedules, super-step depths, rank
//! grids) and a real 2-rank TCP socket world, where the `Trace` frames
//! cross an actual byte stream.

use std::thread;

use targetdp::comms::launcher::{connect_rank, RankServer};
use targetdp::comms::{run_decomposed, serve_rank, CommsConfig, CommsWorld,
                      SocketTransport, Transport, WorldReport};
use targetdp::free_energy::symmetric::FeParams;
use targetdp::lattice::geometry::Geometry;
use targetdp::lb::init::init_spinodal;
use targetdp::lb::model::{d2q9, VelSet};
use targetdp::obs::trace::TracePhase;

const STEPS: u64 = 6;

fn initial_state(vs: &VelSet, geom: &Geometry) -> (Vec<f64>, Vec<f64>) {
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    init_spinodal(vs, &FeParams::default(), geom, &mut f, &mut g, 0.05,
                  31);
    (f, g)
}

/// Run one channel world to completion and return (f, g, report).
fn run_world(geom: &Geometry, cfg: &CommsConfig)
             -> (Vec<f64>, Vec<f64>, WorldReport) {
    let vs = d2q9();
    let (mut f, mut g) = initial_state(vs, geom);
    let rep = run_decomposed(geom, vs, &FeParams::default(), &mut f,
                             &mut g, STEPS, cfg)
        .unwrap();
    (f, g, rep)
}

/// Every rank's timeline must cover the required phase classes: at least
/// one receive wait and at least one interior-compute span, all on a
/// sane clock (`t_end >= t_start`, against the shared run epoch).
fn check_timelines(rep: &WorldReport, label: &str) {
    assert_eq!(rep.traces.len(), rep.ranks.len(), "{label}");
    for (rank, spans) in rep.traces.iter().enumerate() {
        assert!(!spans.is_empty(), "{label}: rank {rank} shipped no spans");
        let count = |p: TracePhase| {
            spans.iter().filter(|s| s.phase == p).count()
        };
        assert!(count(TracePhase::WaitRecv) >= 1,
                "{label}: rank {rank} has no wait_recv span");
        assert!(count(TracePhase::Interior) >= 1,
                "{label}: rank {rank} has no interior span");
        assert!(count(TracePhase::Pack) >= 1,
                "{label}: rank {rank} has no pack span");
        assert!(spans.iter().any(|s| s.tid == 0),
                "{label}: rank {rank} has no rank-thread spans");
        for s in spans {
            assert!(s.t_end >= s.t_start,
                    "{label}: rank {rank} span runs backwards: {s:?}");
            assert!(s.t_start >= 0.0,
                    "{label}: rank {rank} span precedes the epoch: {s:?}");
        }
    }
}

/// The headline guarantee: tracing only reads the clock around existing
/// operations, so a traced world is **bit-identical** to an untraced one
/// and ships the same data-plane traffic — across both exchange
/// schedules, a communication-avoiding super-step depth, and a 2-D rank
/// grid.
#[test]
fn tracing_is_bit_identical_and_free() {
    let slab = Geometry::new(9, 6, 1); // 9 -> uneven 5+4 slab split
    let cases: [(&str, Geometry, CommsConfig); 4] = [
        ("bulk-sync slab", slab,
         CommsConfig { ranks: 2, overlap: false,
                       ..CommsConfig::default() }),
        ("overlap slab", slab,
         CommsConfig { ranks: 2, overlap: true,
                       ..CommsConfig::default() }),
        // wide slabs: depth 2 needs room for the ghost blocks
        ("depth-2 super-step", Geometry::new(32, 6, 1),
         CommsConfig { ranks: 2, depth: 2, ..CommsConfig::default() }),
        ("2x2 rank grid", Geometry::new(9, 8, 1),
         CommsConfig { ranks: 4, grid: [2, 2, 1],
                       ..CommsConfig::default() }),
    ];
    for (label, geom, cfg) in cases {
        let (f_off, g_off, rep_off) = run_world(&geom, &cfg);
        let traced = CommsConfig { trace: true, ..cfg };
        let (f_on, g_on, rep_on) = run_world(&geom, &traced);

        assert_eq!(f_on, f_off, "{label}: tracing perturbed f");
        assert_eq!(g_on, g_off, "{label}: tracing perturbed g");

        // trace frames are control-plane: the halo-traffic accounting
        // must not move by a single byte or message
        for (on, off) in rep_on.ranks.iter().zip(&rep_off.ranks) {
            assert_eq!(on.msgs_sent, off.msgs_sent,
                       "{label}: tracing changed the message count");
            assert_eq!(on.bytes_sent, off.bytes_sent,
                       "{label}: tracing changed the byte count");
            assert_eq!(on.msgs_axis, off.msgs_axis,
                       "{label}: tracing changed per-axis messages");
        }

        // off by default: no rank ships a single span
        assert!(rep_off.traces.iter().all(Vec::is_empty),
                "{label}: untraced world shipped spans");
        check_timelines(&rep_on, label);
    }
}

/// Assemble an N-rank + controller socket world on loopback (same
/// production rendezvous as the multi-process launcher).
fn loopback_world(nranks: usize)
                  -> (Vec<SocketTransport>, SocketTransport) {
    let server = RankServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let joins: Vec<_> = (0..nranks)
        .map(|r| {
            let addr = addr.clone();
            thread::spawn(move || connect_rank(&addr, Some(r)).unwrap())
        })
        .collect();
    let ctl = server.rendezvous(nranks, b"").unwrap();
    let mut ranks: Vec<Option<SocketTransport>> =
        (0..nranks).map(|_| None).collect();
    for j in joins {
        let (t, _payload) = j.join().unwrap();
        let r = t.rank();
        assert!(ranks[r].is_none());
        ranks[r] = Some(t);
    }
    (ranks.into_iter().map(Option::unwrap).collect(), ctl)
}

/// The socket acceptance test: a traced 2-rank TCP world is bit-identical
/// to the untraced channel world, its `Trace` frames survive the real
/// byte stream, and the wire-traffic pins still hold (trace frames ride
/// the control plane, not the halo counters).
#[test]
fn traced_socket_world_is_bit_identical_and_ships_timelines() {
    let vs = d2q9();
    let geom = Geometry::new(9, 6, 1);
    let n = geom.nsites();
    let p = FeParams::default();
    let (f0, g0) = initial_state(vs, &geom);

    // reference: untraced channel world
    let cfg_off = CommsConfig { ranks: 2, ..CommsConfig::default() };
    let (f_ch, g_ch, _) = run_world(&geom, &cfg_off);

    // traced socket world over real loopback TCP
    let cfg = CommsConfig { trace: true, ..cfg_off };
    let (rank_transports, ctl) = loopback_world(2);
    let world = CommsWorld::new(geom, cfg.clone()).unwrap();
    let mut servers = Vec::new();
    for t in rank_transports {
        let d = world.dec.domains[t.rank()].clone();
        let (f0, g0) = (f0.clone(), g0.clone());
        let cfg = cfg.clone();
        servers.push(thread::spawn(move || {
            serve_rank(d, vs, &p, f0, g0, &cfg, 1, Box::new(t))
        }));
    }
    let mut session = world.remote_session(vs, Box::new(ctl)).unwrap();
    session.advance(STEPS).unwrap();
    let mut f_s = vec![0.0; vs.nvel * n];
    let mut g_s = vec![0.0; vs.nvel * n];
    session.gather(&mut f_s, &mut g_s).unwrap();
    let report = session.finish().unwrap();
    for s in servers {
        s.join().unwrap().unwrap();
    }

    assert_eq!(f_s, f_ch, "traced socket world diverged from channel");
    assert_eq!(g_s, g_ch);
    for r in &report.ranks {
        assert_eq!(r.steps, STEPS);
        // trace frames must not leak into the halo-plane accounting
        assert_eq!(r.msgs_sent, 6 * STEPS,
                   "trace frames counted as data-plane messages");
    }
    check_timelines(&report, "socket");
}
