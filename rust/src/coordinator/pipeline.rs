//! The timestep pipeline: build the configured target, initialise the
//! state, advance in blocks while logging observables, emit CSV/VTK.

use std::path::Path;

use crate::comms::launcher::{connect_rank, LocalRanks, RankServer};
use crate::comms::{CommsSession, CommsWorld};
use crate::config::{Config, ObservablesMode, TransportMode};
use crate::error::{Error, Result};
use crate::lattice::io::{write_vtk_scalar, CsvWriter};
use crate::lb::engine::{state_observables, LbEngine, Observables};
use crate::lb::init;
use crate::lb::model::LatticeModel;
use crate::targetdp::target::KernelId;
use crate::targetdp::tlp::threads_per_rank;

use super::metrics::{Mlups, Timer};

/// Outcome of a pipeline run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub target: String,
    pub steps: u64,
    pub nsites: usize,
    pub seconds: f64,
    pub mlups: f64,
    /// Whether the run used a fused (`FullStep`/`MultiStep`) kernel tier.
    pub fused: bool,
    pub initial: Observables,
    pub r#final: Observables,
}

impl RunSummary {
    /// Relative drift of the conserved mass over the run. A zero-mass
    /// initial state has no meaningful relative scale — the absolute
    /// drift is returned instead of dividing through to NaN/inf.
    pub fn mass_drift(&self) -> f64 {
        let drift = (self.r#final.mass - self.initial.mass).abs();
        if self.initial.mass == 0.0 {
            drift
        } else {
            drift / self.initial.mass.abs()
        }
    }

    /// Per-site absolute drift of the order parameter total.
    pub fn phi_drift(&self) -> f64 {
        (self.r#final.phi_total - self.initial.phi_total).abs()
            / self.nsites as f64
    }
}

/// Build the configured initial condition — shared by the single-engine
/// pipeline, the decomposed driver, *and* every socket rank process
/// (which recomputes it locally from the shipped config), so no path can
/// drift: both initialisers are deterministic functions of the config.
pub fn initial_state(cfg: &Config, geom: &crate::lattice::geometry::Geometry)
                     -> (Vec<f64>, Vec<f64>) {
    let vs = cfg.model().expect("validated by caller").velset();
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    match cfg.simulation.init.as_str() {
        "droplet" => init::init_droplet(vs, &cfg.free_energy, geom, &mut f,
                                        &mut g, geom.lx as f64 / 2.0,
                                        geom.ly as f64 / 2.0,
                                        cfg.simulation.radius),
        _ => init::init_spinodal(vs, &cfg.free_energy, geom, &mut f,
                                 &mut g, cfg.simulation.noise,
                                 cfg.simulation.seed),
    }
    (f, g)
}

/// Open the observables CSV (when an output dir is configured) and write
/// the step-0 row — shared column schema for both pipelines.
fn open_observables_csv(cfg: &Config, initial: &Observables)
                        -> Result<Option<CsvWriter>> {
    if cfg.output.dir.is_empty() {
        return Ok(None);
    }
    std::fs::create_dir_all(&cfg.output.dir)?;
    let path = Path::new(&cfg.output.dir).join("observables.csv");
    let mut w = CsvWriter::create(
        &path,
        &["step", "mass", "phi_total", "phi_variance", "mlups"],
    )?;
    w.row(&[0.0, initial.mass, initial.phi_total, initial.phi_variance,
            0.0])?;
    Ok(Some(w))
}

/// Steps per logging block.
fn block_size(cfg: &Config) -> u64 {
    if cfg.output.every == 0 {
        cfg.simulation.steps
    } else {
        cfg.output.every
    }
}

/// Run a full simulation according to `cfg`, logging to stdout.
/// `ranks > 1` (or `transport = "socket"`) routes through the comms
/// subsystem — concurrent ranks on a Cartesian grid with overlapped
/// halo exchange, as threads or as OS processes — instead of a single
/// engine.
pub fn run_simulation(cfg: &Config) -> Result<RunSummary> {
    let transport = cfg.transport_mode()?;
    if cfg.target.ranks > 1 || transport == TransportMode::Socket {
        return run_decomposed_simulation(cfg, transport);
    }
    let geom = cfg.geometry();
    let model = cfg.model()?;
    let n = geom.nsites();

    let mut target = cfg.build_target()?;
    let target_desc = target.describe();
    println!("target   : {target_desc}");
    println!("lattice  : {} {}x{}x{} ({} sites)", model.name(), geom.lx,
             geom.ly, geom.lz, n);

    let mut engine =
        LbEngine::new(target.as_mut(), geom, model, cfg.free_energy)?;
    engine.set_fusion(cfg.target.fusion);
    let fused = engine.fused_active();
    println!("pipeline : {}", match engine.fused_tier() {
        Some((KernelId::MultiStep, k)) => {
            format!("fused multi-step (k={k} per launch)")
        }
        Some(_) => "fused full-step".into(),
        None => "unfused (5 kernels)".to_string(),
    });

    // initial condition
    let (f, g) = initial_state(cfg, &geom);
    engine.load_state(&f, &g)?;

    let initial = engine.observables()?;
    println!("initial  : mass={:.6} phi={:.6} var={:.3e}", initial.mass,
             initial.phi_total, initial.phi_variance);

    let mut csv = open_observables_csv(cfg, &initial)?;
    let block = block_size(cfg);
    let mut mlups = Mlups::new();
    let timer = Timer::start();
    let mut done = 0;
    while done < cfg.simulation.steps {
        let todo = block.min(cfg.simulation.steps - done);
        let t = Timer::start();
        engine.run(todo)?;
        mlups.record(n, todo, t.seconds());
        done += todo;
        let obs = engine.observables()?;
        println!(
            "step {done:>6}: mass={:.6} phi={:.6} var={:.4e} [{:.2} MLUPS]",
            obs.mass, obs.phi_total, obs.phi_variance, mlups.value()
        );
        if let Some(w) = csv.as_mut() {
            w.row(&[done as f64, obs.mass, obs.phi_total, obs.phi_variance,
                    mlups.value()])?;
        }
    }

    let final_obs = engine.observables()?;
    if cfg.output.vtk && !cfg.output.dir.is_empty() {
        let phi = engine.phi_field()?;
        let path = Path::new(&cfg.output.dir).join("phi_final.vtk");
        write_vtk_scalar(&path, &geom, "phi", &phi)?;
        println!("wrote {}", path.display());
    }
    if let Some(w) = csv.as_mut() {
        w.flush()?;
    }

    let summary = RunSummary {
        target: target_desc,
        steps: cfg.simulation.steps,
        nsites: n,
        seconds: timer.seconds(),
        mlups: mlups.value(),
        fused,
        initial,
        r#final: final_obs,
    };
    println!(
        "done     : {} steps in {:.3}s = {:.2} MLUPS, mass drift {:.2e}",
        summary.steps, summary.seconds, summary.mlups, summary.mass_drift()
    );
    Ok(summary)
}

/// The decomposed (`ranks > 1` or socket-transport) pipeline: bring up a
/// **resident** comms rank session — in-process threads spawned exactly
/// once, or rank OS processes assembled by the socket rendezvous — each
/// rank owning its slab-local state for the whole run; advance in
/// logging blocks over the session command protocol, and report per-rank
/// MLUPS and exchange-wait breakdowns from the session-accumulated
/// [`crate::comms::WorldReport`].
///
/// Per-block observables are **distributed reductions** by default
/// (`[target] observables = "reduced"`): every rank sums its own interior
/// and only O(ranks) partial sums travel — no global f/g scatter/gather
/// between blocks. `"gather"` restores the old pull-everything-back
/// behaviour (bit-exact with the single-engine reduction) at O(state)
/// cost per block. The full state is gathered only on demand: the VTK
/// snapshot asks the resident ranks for phi directly.
///
/// Socket mode (`transport = "socket"`): with no `rank_server` the
/// driver binds an ephemeral loopback port and spawns one
/// `targetdp rank` child per slab; with `rank_server = "host:port"` it
/// listens there for manually started ranks (one
/// `targetdp rank --connect host:port` per host). Either way the full
/// config travels in the rendezvous payload and each rank process
/// recomputes the deterministic initial state locally, so the physics is
/// bit-identical to the channel world and to the single-domain engine.
fn run_decomposed_simulation(cfg: &Config, transport: TransportMode)
                             -> Result<RunSummary> {
    let geom = cfg.geometry();
    let model = cfg.model()?;
    let vs = model.velset();
    let n = geom.nsites();
    let ccfg = cfg.comms_config()?;
    let mode = cfg.observables_mode()?;
    let world = CommsWorld::new(geom, ccfg.clone())?;
    let target_desc = format!(
        "comms(ranks={}{},{},{},{},vvl={},threads={},depth={}{})",
        ccfg.ranks,
        // the slab grid is the default shape — only a real 3D grid is
        // worth a tag in the target line
        if world.dec.is_slab() {
            String::new()
        } else {
            format!(",grid={}x{}x{}", world.dec.grid[0],
                    world.dec.grid[1], world.dec.grid[2])
        },
        match transport {
            TransportMode::Channel => "channel",
            TransportMode::Socket => "socket",
        },
        if ccfg.overlap { "overlap" } else { "bulk-sync" },
        if ccfg.scalar { "host-scalar" } else { "host-simd" },
        ccfg.vvl,
        ccfg.threads,
        ccfg.depth,
        if ccfg.pin { ",pinned" } else { "" },
    );
    println!("target   : {target_desc}");
    println!("lattice  : {} {}x{}x{} ({} sites)", model.name(), geom.lx,
             geom.ly, geom.lz, n);
    println!("pipeline : resident ranks, unfused (halo exchange {}, {} \
              observables)",
             if ccfg.overlap { "overlapped with interior compute" }
             else { "bulk-synchronous" },
             match mode {
                 ObservablesMode::Reduced => "distributed-reduction",
                 ObservablesMode::Gather => "gathered-state",
             });
    for d in &world.dec.domains {
        println!(
            "rank {:>4}: cell ({},{},{})  x = [{}, {})  y = [{}, {})  \
             z = [{}, {})  ({} sites)",
            d.rank, d.coords[0], d.coords[1], d.coords[2], d.origin[0],
            d.origin[0] + d.ext[0], d.origin[1], d.origin[1] + d.ext[1],
            d.origin[2], d.origin[2] + d.ext[2], d.interior_sites(),
        );
    }

    let (f0, g0) = initial_state(cfg, &geom);
    let initial = state_observables(vs, &f0, &g0, n);
    println!("initial  : mass={:.6} phi={:.6} var={:.3e}", initial.mass,
             initial.phi_total, initial.phi_variance);

    // channel mode: the initial state moves into the session — each rank
    // thread copies its own planes out of it (first touch on the rank's
    // pool). Socket mode: each rank *process* recomputes it from the
    // config shipped in the rendezvous payload instead, so no state
    // crosses the wire at startup. Either way the ranks stay resident
    // until `finish`.
    let (mut session, local_ranks): (CommsSession, Option<LocalRanks>) =
        match transport {
            TransportMode::Channel => {
                (world.session(vs, &cfg.free_energy, f0, g0)?, None)
            }
            TransportMode::Socket => {
                let listen = if cfg.target.rank_server.is_empty() {
                    "127.0.0.1:0"
                } else {
                    cfg.target.rank_server.as_str()
                };
                let server = RankServer::bind(listen)?;
                let addr = server.local_addr()?;
                let local = if cfg.target.rank_server.is_empty() {
                    println!("ranks    : spawning {} local rank \
                              processes -> {addr}",
                             ccfg.ranks);
                    Some(LocalRanks::spawn(ccfg.ranks, &addr.to_string(),
                                           &["rank".to_string()])?)
                } else {
                    // a wildcard bind (0.0.0.0 / ::) is not a dialable
                    // address — tell the operator to substitute a host
                    // the rank machines can actually route to
                    let shown = if addr.ip().is_unspecified() {
                        format!("<driver-host>:{}", addr.port())
                    } else {
                        addr.to_string()
                    };
                    println!("ranks    : waiting for {} ranks; start \
                              `targetdp rank --connect {shown}` on each \
                              host",
                             ccfg.ranks);
                    None
                };
                let controller = server
                    .rendezvous(ccfg.ranks,
                                cfg.to_toml_string().as_bytes())?;
                (world.remote_session(vs, Box::new(controller))?, local)
            }
        };

    let mut csv = open_observables_csv(cfg, &initial)?;
    let block = block_size(cfg);
    let mut mlups = Mlups::new();
    let timer = Timer::start();
    let mut done = 0;
    // gather-mode scratch, allocated only when the knob asks for it
    let mut gathered = match mode {
        ObservablesMode::Gather => {
            Some((vec![0.0; vs.nvel * n], vec![0.0; vs.nvel * n]))
        }
        ObservablesMode::Reduced => None,
    };
    let mut last_obs = initial;
    while done < cfg.simulation.steps {
        let todo = block.min(cfg.simulation.steps - done);
        let t = Timer::start();
        session.advance(todo)?;
        let obs = match gathered.as_mut() {
            None => session.observables()?,
            Some((f, g)) => {
                session.gather(f, g)?;
                state_observables(vs, f, g, n)
            }
        };
        mlups.record(n, todo, t.seconds());
        done += todo;
        last_obs = obs;
        println!(
            "step {done:>6}: mass={:.6} phi={:.6} var={:.4e} [{:.2} MLUPS]",
            obs.mass, obs.phi_total, obs.phi_variance, mlups.value()
        );
        if let Some(w) = csv.as_mut() {
            w.row(&[done as f64, obs.mass, obs.phi_total, obs.phi_variance,
                    mlups.value()])?;
        }
    }
    let final_obs = last_obs;

    if cfg.output.vtk && !cfg.output.dir.is_empty() {
        // phi computed by the resident ranks (their own pools and VVL) —
        // only nsites doubles travel, not the nvel-component state
        let phi = session.gather_phi()?;
        let path = Path::new(&cfg.output.dir).join("phi_final.vtk");
        write_vtk_scalar(&path, &geom, "phi", &phi)?;
        println!("wrote {}", path.display());
    }

    // retire the resident ranks; each reports its whole-run totals
    let report = session.finish()?;
    // a socket run then reaps its spawned rank processes: Shutdown has
    // been acknowledged by every rank, so this only collects exit codes
    if let Some(local) = local_ranks {
        local.wait()?;
    }
    println!("per-rank : (exchange wait share of working wall time)");
    for r in &report.ranks {
        println!(
            "rank {:>4}: {:>8.2} MLUPS  compute {:.3}s  wait {:.3}s \
             ({:.1}%)  idle {:.3}s",
            r.rank,
            r.mlups(),
            r.compute_s,
            r.wait_s,
            100.0 * r.wait_fraction(),
            r.idle_s,
        );
    }
    let bytes_sent: u64 = report.ranks.iter().map(|r| r.bytes_sent).sum();
    println!("exchange : {:.2} MiB total over {} steps",
             bytes_sent as f64 / (1024.0 * 1024.0), done);

    if let Some(w) = csv.as_mut() {
        w.flush()?;
    }

    let summary = RunSummary {
        target: target_desc,
        steps: cfg.simulation.steps,
        nsites: n,
        seconds: timer.seconds(),
        mlups: mlups.value(),
        fused: false,
        initial,
        r#final: final_obs,
    };
    println!(
        "done     : {} steps in {:.3}s = {:.2} MLUPS, mass drift {:.2e}",
        summary.steps, summary.seconds, summary.mlups, summary.mass_drift()
    );
    Ok(summary)
}

/// Entry point of a socket **rank process** (`targetdp rank --connect
/// HOST:PORT [--rank R]`): rendezvous with the driver's rank server,
/// rebuild the identical run from the config shipped in the `Welcome`
/// payload, recompute the deterministic initial state locally, and serve
/// this rank's subdomain until the driver's `Shutdown`.
///
/// The process is silent on success — all run logging belongs to the
/// driver; errors surface through the exit code, which the driver's
/// [`LocalRanks::wait`] (spawn-local) or the operator (multi-host)
/// observes.
pub fn run_rank_process(server: &str, want_rank: Option<usize>)
                        -> Result<()> {
    let (transport, payload) = connect_rank(server, want_rank)?;
    let text = String::from_utf8(payload).map_err(|_| {
        Error::Parse(
            "comms launcher: setup payload is not UTF-8 TOML".into(),
        )
    })?;
    let cfg = Config::from_toml_str(&text)?;
    let geom = cfg.geometry();
    let model = cfg.model()?;
    let vs = model.velset();
    let ccfg = cfg.comms_config()?;
    let rank = crate::comms::Transport::rank(&transport);
    let world = CommsWorld::new(geom, ccfg.clone())?;
    let d = world.dec.domains.get(rank).cloned().ok_or_else(|| {
        Error::Invalid(format!(
            "comms launcher: assigned rank {rank}, world has {} domains",
            world.dec.domains.len()
        ))
    })?;
    let (f0, g0) = initial_state(&cfg, &geom);
    let nthreads = threads_per_rank(ccfg.threads, ccfg.ranks);
    crate::comms::serve_rank(d, vs, &cfg.free_energy, f0, g0, &ccfg,
                             nthreads, Box::new(transport))
}

/// Convenience: run a short spinodal simulation on a given backend without
/// a config file (used by tests and the benches).
pub fn quick_spinodal(backend: &str, lattice: LatticeModel,
                      extent: (usize, usize, usize), steps: u64, vvl: usize)
                      -> Result<RunSummary> {
    let cfg = Config {
        simulation: crate::config::SimulationCfg {
            lattice: lattice.name().into(),
            lx: extent.0,
            ly: extent.1,
            lz: extent.2,
            steps,
            init: "spinodal".into(),
            noise: 0.05,
            seed: 1234,
            radius: 8.0,
        },
        target: crate::config::TargetCfg {
            backend: backend.into(),
            vvl,
            ..Default::default()
        },
        free_energy: Default::default(),
        output: Default::default(),
    };
    run_simulation(&cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_run_conserves_and_reports() {
        let s = quick_spinodal("host-simd", LatticeModel::D3Q19, (8, 8, 8),
                               10, 8)
            .unwrap();
        assert_eq!(s.steps, 10);
        assert!(s.fused, "host backend defaults to the fused tier");
        assert!(s.mass_drift() < 1e-12, "mass drift {}", s.mass_drift());
        assert!(s.phi_drift() < 1e-12);
        assert!(s.mlups > 0.0);
    }

    #[test]
    fn fusion_off_runs_unfused_with_same_physics() {
        let mk = |fusion: bool| {
            let mut cfg = Config {
                simulation: crate::config::SimulationCfg {
                    lattice: "d2q9".into(),
                    lx: 12,
                    ly: 12,
                    lz: 1,
                    steps: 6,
                    init: "spinodal".into(),
                    noise: 0.05,
                    seed: 99,
                    radius: 4.0,
                },
                target: Default::default(),
                free_energy: Default::default(),
                output: Default::default(),
            };
            cfg.target.fusion = fusion;
            run_simulation(&cfg).unwrap()
        };
        let fused = mk(true);
        let unfused = mk(false);
        assert!(fused.fused && !unfused.fused);
        assert_eq!(fused.r#final.phi_variance, unfused.r#final.phi_variance,
                   "fused and unfused pipelines are bit-identical");
    }

    #[test]
    fn decomposed_run_matches_single_engine_run() {
        let mk = |ranks: usize, overlap: bool, observables: &str| {
            let mut cfg = Config {
                simulation: crate::config::SimulationCfg {
                    lattice: "d2q9".into(),
                    lx: 9, // uneven over 2 ranks
                    ly: 8,
                    lz: 1,
                    steps: 6,
                    init: "spinodal".into(),
                    noise: 0.05,
                    seed: 42,
                    radius: 4.0,
                },
                target: Default::default(),
                free_energy: Default::default(),
                output: Default::default(),
            };
            cfg.target.ranks = ranks;
            cfg.target.overlap = overlap;
            cfg.target.observables = observables.into();
            run_simulation(&cfg).unwrap()
        };
        let single = mk(1, true, "reduced"); // engine path (fused)
        let multi = mk(2, true, "gather"); // comms path, overlapped
        let bulk = mk(2, false, "gather"); // comms path, bulk-sync
        assert!(single.fused && !multi.fused);
        assert!(multi.target.starts_with("comms(ranks=2"));
        // the distribution level must not change the physics at all:
        // gathered-state observables reduce the bit-identical global
        // state with the single sweep the engine path uses
        assert_eq!(single.r#final.phi_variance, multi.r#final.phi_variance);
        assert_eq!(single.r#final.mass, multi.r#final.mass);
        assert_eq!(multi.r#final.phi_variance, bulk.r#final.phi_variance);
        assert!(multi.mass_drift() < 1e-12);

        // the default distributed reduction sums the same interiors in
        // per-rank partial order: equal to rounding, and conservation
        // holds exactly as tightly
        let reduced = mk(2, true, "reduced");
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 + 1e-9 * b.abs();
        assert!(close(reduced.r#final.mass, multi.r#final.mass));
        assert!(close(reduced.r#final.phi_total, multi.r#final.phi_total));
        assert!(close(reduced.r#final.phi_variance,
                      multi.r#final.phi_variance));
        assert!(reduced.mass_drift() < 1e-9);
    }

    #[test]
    fn grid_run_matches_single_engine_run_and_tags_target() {
        let mk = |ranks: usize, grid: &str| {
            let mut cfg = Config {
                simulation: crate::config::SimulationCfg {
                    lattice: "d2q9".into(),
                    lx: 8,
                    ly: 7, // uneven over the 2-way y split
                    lz: 1,
                    steps: 5,
                    init: "spinodal".into(),
                    noise: 0.05,
                    seed: 7,
                    radius: 4.0,
                },
                target: Default::default(),
                free_energy: Default::default(),
                output: Default::default(),
            };
            cfg.target.ranks = ranks;
            cfg.target.grid = grid.into();
            cfg.target.observables = "gather".into();
            run_simulation(&cfg).unwrap()
        };
        let single = mk(1, "");
        let grid = mk(2, "1,2,1");
        // the grid world is tagged in the target line and changes no bits
        assert!(grid.target.contains("grid=1x2x1"), "{}", grid.target);
        assert_eq!(single.r#final.phi_variance, grid.r#final.phi_variance);
        assert_eq!(single.r#final.mass, grid.r#final.mass);
    }

    #[test]
    fn zero_mass_initial_state_has_finite_drift() {
        // regression: mass_drift divided by initial.mass, so a zero-mass
        // state (e.g. a pure order-parameter relaxation) reported NaN
        let zero = Observables {
            mass: 0.0,
            momentum: [0.0; 3],
            phi_total: 0.0,
            phi_variance: 0.0,
        };
        let mut s = RunSummary {
            target: "test".into(),
            steps: 1,
            nsites: 8,
            seconds: 1.0,
            mlups: 1.0,
            fused: false,
            initial: zero,
            r#final: zero,
        };
        assert_eq!(s.mass_drift(), 0.0);
        assert!(s.mass_drift().is_finite());
        // any drift away from zero mass is reported absolutely
        s.r#final.mass = 0.5;
        assert_eq!(s.mass_drift(), 0.5);
        assert!(s.phi_drift().is_finite());
        // negative initial mass must not flip the sign of the ratio
        s.initial.mass = -2.0;
        s.r#final.mass = -1.0;
        assert_eq!(s.mass_drift(), 0.5);
    }

    #[test]
    fn scalar_and_simd_agree() {
        let a = quick_spinodal("host-scalar", LatticeModel::D2Q9,
                               (16, 16, 1), 5, 1)
            .unwrap();
        let b = quick_spinodal("host-simd", LatticeModel::D2Q9, (16, 16, 1),
                               5, 8)
            .unwrap();
        assert!((a.r#final.phi_variance - b.r#final.phi_variance).abs()
                < 1e-13);
        assert!((a.r#final.mass - b.r#final.mass).abs() < 1e-9);
    }
}
