//! The timestep pipeline: build the configured target, initialise the
//! state, advance in blocks while logging observables, emit CSV/VTK.

use std::path::Path;

use crate::config::Config;
use crate::error::Result;
use crate::lattice::io::{write_vtk_scalar, CsvWriter};
use crate::lb::engine::{LbEngine, Observables};
use crate::lb::init;
use crate::lb::model::LatticeModel;
use crate::targetdp::target::KernelId;

use super::metrics::{Mlups, Timer};

/// Outcome of a pipeline run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub target: String,
    pub steps: u64,
    pub nsites: usize,
    pub seconds: f64,
    pub mlups: f64,
    /// Whether the run used a fused (`FullStep`/`MultiStep`) kernel tier.
    pub fused: bool,
    pub initial: Observables,
    pub r#final: Observables,
}

impl RunSummary {
    /// Relative drift of the conserved mass over the run. A zero-mass
    /// initial state has no meaningful relative scale — the absolute
    /// drift is returned instead of dividing through to NaN/inf.
    pub fn mass_drift(&self) -> f64 {
        let drift = (self.r#final.mass - self.initial.mass).abs();
        if self.initial.mass == 0.0 {
            drift
        } else {
            drift / self.initial.mass.abs()
        }
    }

    /// Per-site absolute drift of the order parameter total.
    pub fn phi_drift(&self) -> f64 {
        (self.r#final.phi_total - self.initial.phi_total).abs()
            / self.nsites as f64
    }
}

/// Run a full simulation according to `cfg`, logging to stdout.
pub fn run_simulation(cfg: &Config) -> Result<RunSummary> {
    let geom = cfg.geometry();
    let model = cfg.model()?;
    let vs = model.velset();
    let n = geom.nsites();

    let mut target = cfg.build_target()?;
    let target_desc = target.describe();
    println!("target   : {target_desc}");
    println!("lattice  : {} {}x{}x{} ({} sites)", model.name(), geom.lx,
             geom.ly, geom.lz, n);

    let mut engine =
        LbEngine::new(target.as_mut(), geom, model, cfg.free_energy)?;
    engine.set_fusion(cfg.target.fusion);
    let fused = engine.fused_active();
    println!("pipeline : {}", match engine.fused_tier() {
        Some((KernelId::MultiStep, k)) => {
            format!("fused multi-step (k={k} per launch)")
        }
        Some(_) => "fused full-step".into(),
        None => "unfused (5 kernels)".to_string(),
    });

    // initial condition
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    match cfg.simulation.init.as_str() {
        "droplet" => init::init_droplet(vs, &cfg.free_energy, &geom, &mut f,
                                        &mut g, geom.lx as f64 / 2.0,
                                        geom.ly as f64 / 2.0,
                                        cfg.simulation.radius),
        _ => init::init_spinodal(vs, &cfg.free_energy, &geom, &mut f,
                                 &mut g, cfg.simulation.noise,
                                 cfg.simulation.seed),
    }
    engine.load_state(&f, &g)?;

    let initial = engine.observables()?;
    println!("initial  : mass={:.6} phi={:.6} var={:.3e}", initial.mass,
             initial.phi_total, initial.phi_variance);

    let mut csv = if cfg.output.dir.is_empty() {
        None
    } else {
        std::fs::create_dir_all(&cfg.output.dir)?;
        let path = Path::new(&cfg.output.dir).join("observables.csv");
        let mut w = CsvWriter::create(
            &path,
            &["step", "mass", "phi_total", "phi_variance", "mlups"],
        )?;
        w.row(&[0.0, initial.mass, initial.phi_total,
                initial.phi_variance, 0.0])?;
        Some(w)
    };

    let block = if cfg.output.every == 0 {
        cfg.simulation.steps
    } else {
        cfg.output.every
    };
    let mut mlups = Mlups::new();
    let timer = Timer::start();
    let mut done = 0;
    while done < cfg.simulation.steps {
        let todo = block.min(cfg.simulation.steps - done);
        let t = Timer::start();
        engine.run(todo)?;
        mlups.record(n, todo, t.seconds());
        done += todo;
        let obs = engine.observables()?;
        println!(
            "step {done:>6}: mass={:.6} phi={:.6} var={:.4e} [{:.2} MLUPS]",
            obs.mass, obs.phi_total, obs.phi_variance, mlups.value()
        );
        if let Some(w) = csv.as_mut() {
            w.row(&[done as f64, obs.mass, obs.phi_total, obs.phi_variance,
                    mlups.value()])?;
        }
    }

    let final_obs = engine.observables()?;
    if cfg.output.vtk && !cfg.output.dir.is_empty() {
        let phi = engine.phi_field()?;
        let path = Path::new(&cfg.output.dir).join("phi_final.vtk");
        write_vtk_scalar(&path, &geom, "phi", &phi)?;
        println!("wrote {}", path.display());
    }
    if let Some(w) = csv.as_mut() {
        w.flush()?;
    }

    let summary = RunSummary {
        target: target_desc,
        steps: cfg.simulation.steps,
        nsites: n,
        seconds: timer.seconds(),
        mlups: mlups.value(),
        fused,
        initial,
        r#final: final_obs,
    };
    println!(
        "done     : {} steps in {:.3}s = {:.2} MLUPS, mass drift {:.2e}",
        summary.steps, summary.seconds, summary.mlups, summary.mass_drift()
    );
    Ok(summary)
}

/// Convenience: run a short spinodal simulation on a given backend without
/// a config file (used by tests and the benches).
pub fn quick_spinodal(backend: &str, lattice: LatticeModel,
                      extent: (usize, usize, usize), steps: u64, vvl: usize)
                      -> Result<RunSummary> {
    let cfg = Config {
        simulation: crate::config::SimulationCfg {
            lattice: lattice.name().into(),
            lx: extent.0,
            ly: extent.1,
            lz: extent.2,
            steps,
            init: "spinodal".into(),
            noise: 0.05,
            seed: 1234,
            radius: 8.0,
        },
        target: crate::config::TargetCfg {
            backend: backend.into(),
            vvl,
            ..Default::default()
        },
        free_energy: Default::default(),
        output: Default::default(),
    };
    run_simulation(&cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_run_conserves_and_reports() {
        let s = quick_spinodal("host-simd", LatticeModel::D3Q19, (8, 8, 8),
                               10, 8)
            .unwrap();
        assert_eq!(s.steps, 10);
        assert!(s.fused, "host backend defaults to the fused tier");
        assert!(s.mass_drift() < 1e-12, "mass drift {}", s.mass_drift());
        assert!(s.phi_drift() < 1e-12);
        assert!(s.mlups > 0.0);
    }

    #[test]
    fn fusion_off_runs_unfused_with_same_physics() {
        let mk = |fusion: bool| {
            let mut cfg = Config {
                simulation: crate::config::SimulationCfg {
                    lattice: "d2q9".into(),
                    lx: 12,
                    ly: 12,
                    lz: 1,
                    steps: 6,
                    init: "spinodal".into(),
                    noise: 0.05,
                    seed: 99,
                    radius: 4.0,
                },
                target: Default::default(),
                free_energy: Default::default(),
                output: Default::default(),
            };
            cfg.target.fusion = fusion;
            run_simulation(&cfg).unwrap()
        };
        let fused = mk(true);
        let unfused = mk(false);
        assert!(fused.fused && !unfused.fused);
        assert_eq!(fused.r#final.phi_variance, unfused.r#final.phi_variance,
                   "fused and unfused pipelines are bit-identical");
    }

    #[test]
    fn zero_mass_initial_state_has_finite_drift() {
        // regression: mass_drift divided by initial.mass, so a zero-mass
        // state (e.g. a pure order-parameter relaxation) reported NaN
        let zero = Observables {
            mass: 0.0,
            momentum: [0.0; 3],
            phi_total: 0.0,
            phi_variance: 0.0,
        };
        let mut s = RunSummary {
            target: "test".into(),
            steps: 1,
            nsites: 8,
            seconds: 1.0,
            mlups: 1.0,
            fused: false,
            initial: zero,
            r#final: zero,
        };
        assert_eq!(s.mass_drift(), 0.0);
        assert!(s.mass_drift().is_finite());
        // any drift away from zero mass is reported absolutely
        s.r#final.mass = 0.5;
        assert_eq!(s.mass_drift(), 0.5);
        assert!(s.phi_drift().is_finite());
        // negative initial mass must not flip the sign of the ratio
        s.initial.mass = -2.0;
        s.r#final.mass = -1.0;
        assert_eq!(s.mass_drift(), 0.5);
    }

    #[test]
    fn scalar_and_simd_agree() {
        let a = quick_spinodal("host-scalar", LatticeModel::D2Q9,
                               (16, 16, 1), 5, 1)
            .unwrap();
        let b = quick_spinodal("host-simd", LatticeModel::D2Q9, (16, 16, 1),
                               5, 8)
            .unwrap();
        assert!((a.r#final.phi_variance - b.r#final.phi_variance).abs()
                < 1e-13);
        assert!((a.r#final.mass - b.r#final.mass).abs() < 1e-9);
    }
}
