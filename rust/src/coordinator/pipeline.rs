//! The timestep pipeline: build the configured target, initialise the
//! state, advance in blocks while logging observables, emit CSV/VTK.

use std::path::Path;
use std::time::Instant;

use crate::comms::launcher::{connect_world, HostSpec, LocalRanks,
                             RankServer, WorldEndpoints};
use crate::comms::{Checkpoint, CheckpointField, CommsSession, CommsWorld,
                   WorldReport};
use crate::config::{Config, ObservablesMode, TransportMode};
use crate::error::{Error, Result};
use crate::lattice::io::{write_vtk_scalar, CsvWriter};
use crate::lb::engine::{state_observables, LbEngine, Observables};
use crate::lb::init;
use crate::lb::model::LatticeModel;
use crate::obs::trace::{Span, TracePhase, AXIS_NONE, SIDE_NONE};
use crate::targetdp::target::KernelId;
use crate::targetdp::tlp::threads_per_rank;
use crate::util::json::{obj, Json};

use super::metrics::{Mlups, Timer};

/// Outcome of a pipeline run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub target: String,
    pub steps: u64,
    pub nsites: usize,
    pub seconds: f64,
    pub mlups: f64,
    /// Whether the run used a fused (`FullStep`/`MultiStep`) kernel tier.
    pub fused: bool,
    pub initial: Observables,
    pub r#final: Observables,
}

impl RunSummary {
    /// Relative drift of the conserved mass over the run. A zero-mass
    /// initial state has no meaningful relative scale — the absolute
    /// drift is returned instead of dividing through to NaN/inf.
    pub fn mass_drift(&self) -> f64 {
        let drift = (self.r#final.mass - self.initial.mass).abs();
        if self.initial.mass == 0.0 {
            drift
        } else {
            drift / self.initial.mass.abs()
        }
    }

    /// Per-site absolute drift of the order parameter total.
    pub fn phi_drift(&self) -> f64 {
        (self.r#final.phi_total - self.initial.phi_total).abs()
            / self.nsites as f64
    }
}

/// Build the configured initial condition — shared by the single-engine
/// pipeline, the decomposed driver, *and* every socket rank process
/// (which recomputes it locally from the shipped config), so no path can
/// drift: both initialisers are deterministic functions of the config.
pub fn initial_state(cfg: &Config, geom: &crate::lattice::geometry::Geometry)
                     -> (Vec<f64>, Vec<f64>) {
    let vs = cfg.model().expect("validated by caller").velset();
    let n = geom.nsites();
    let mut f = vec![0.0; vs.nvel * n];
    let mut g = vec![0.0; vs.nvel * n];
    match cfg.simulation.init.as_str() {
        "droplet" => init::init_droplet(vs, &cfg.free_energy, geom, &mut f,
                                        &mut g, geom.lx as f64 / 2.0,
                                        geom.ly as f64 / 2.0,
                                        cfg.simulation.radius),
        _ => init::init_spinodal(vs, &cfg.free_energy, geom, &mut f,
                                 &mut g, cfg.simulation.noise,
                                 cfg.simulation.seed),
    }
    (f, g)
}

/// Build the state a run starts from: the deterministic initial
/// condition at step 0, or — when `[output] restore` names a checkpoint
/// file — the recorded global f/g at the recorded step. Shared by the
/// single-engine pipeline, the decomposed driver *and* every
/// socket/hybrid rank process (the restore path ships in the rendezvous
/// TOML, so remote ranks rebuild the identical state locally). The
/// checkpoint is decomposition-independent: it validates only the
/// lattice dims and velocity-set width against the config, never the
/// rank count or grid it was taken at.
pub fn starting_state(cfg: &Config,
                      geom: &crate::lattice::geometry::Geometry)
                      -> Result<(Vec<f64>, Vec<f64>, u64)> {
    if cfg.output.restore.is_empty() {
        let (f, g) = initial_state(cfg, geom);
        return Ok((f, g, 0));
    }
    let ck = Checkpoint::read_file(Path::new(&cfg.output.restore))?;
    let dims = [geom.lx as u64, geom.ly as u64, geom.lz as u64];
    if ck.dims != dims {
        return Err(Error::Invalid(format!(
            "checkpoint: {} holds a {}x{}x{} lattice, config wants \
             {}x{}x{}",
            cfg.output.restore, ck.dims[0], ck.dims[1], ck.dims[2],
            dims[0], dims[1], dims[2],
        )));
    }
    let nvel = cfg.model()?.velset().nvel as u32;
    if ck.nvel != nvel {
        return Err(Error::Invalid(format!(
            "checkpoint: {} holds nvel = {}, config wants {nvel}",
            cfg.output.restore, ck.nvel,
        )));
    }
    if ck.step > cfg.simulation.steps {
        return Err(Error::Invalid(format!(
            "checkpoint: {} was taken at step {}, past the configured \
             {} steps",
            cfg.output.restore, ck.step, cfg.simulation.steps,
        )));
    }
    let want = nvel as usize * geom.nsites();
    let mut ck = ck;
    let f = ck.take_field("f", want)?;
    let g = ck.take_field("g", want)?;
    Ok((f, g, ck.step))
}

/// Where a checkpointing run writes its snapshot: `checkpoint_out` when
/// set, else `<dir>/checkpoint.tdpk`, else `checkpoint.tdpk` in the
/// working directory. `None` while `checkpoint_every` is 0.
pub fn checkpoint_path(cfg: &Config) -> Option<String> {
    if cfg.output.checkpoint_every == 0 {
        return None;
    }
    if !cfg.output.checkpoint_out.is_empty() {
        return Some(cfg.output.checkpoint_out.clone());
    }
    if !cfg.output.dir.is_empty() {
        return Some(
            Path::new(&cfg.output.dir)
                .join("checkpoint.tdpk")
                .to_string_lossy()
                .into_owned(),
        );
    }
    Some("checkpoint.tdpk".into())
}

/// Assemble and atomically write a TDPK snapshot of the global state.
fn write_checkpoint(cfg: &Config, path: &str, step: u64, f: Vec<f64>,
                    g: Vec<f64>) -> Result<()> {
    let geom = cfg.geometry();
    let nvel = cfg.model()?.velset().nvel as u32;
    let ck = Checkpoint {
        step,
        dims: [geom.lx as u64, geom.ly as u64, geom.lz as u64],
        nvel,
        config_toml: cfg.to_toml_string(),
        fields: vec![
            CheckpointField { name: "f".into(), ncomp: nvel, data: f },
            CheckpointField { name: "g".into(), ncomp: nvel, data: g },
        ],
    };
    ck.write_file(Path::new(path))?;
    println!("ckpt     : step {step} -> {path}");
    Ok(())
}

/// Open the observables CSV (when an output dir is configured) and write
/// the step-0 row — shared column schema for both pipelines.
fn open_observables_csv(cfg: &Config, initial: &Observables)
                        -> Result<Option<CsvWriter>> {
    if cfg.output.dir.is_empty() {
        return Ok(None);
    }
    std::fs::create_dir_all(&cfg.output.dir)?;
    let path = Path::new(&cfg.output.dir).join("observables.csv");
    let mut w = CsvWriter::create(
        &path,
        &["step", "mass", "phi_total", "phi_variance", "mlups"],
    )?;
    w.row(&[0.0, initial.mass, initial.phi_total, initial.phi_variance,
            0.0])?;
    Ok(Some(w))
}

/// Steps per logging block.
fn block_size(cfg: &Config) -> u64 {
    if cfg.output.every == 0 {
        cfg.simulation.steps
    } else {
        cfg.output.every
    }
}

/// Run a full simulation according to `cfg`, logging to stdout.
/// `ranks > 1` (or `transport = "socket"` / `"hybrid"`) routes through
/// the comms subsystem — concurrent ranks on a Cartesian grid with
/// overlapped halo exchange, as threads, OS processes or per-host
/// processes — instead of a single engine.
pub fn run_simulation(cfg: &Config) -> Result<RunSummary> {
    let transport = cfg.transport_mode()?;
    if cfg.target.ranks > 1 || transport != TransportMode::Channel {
        return run_supervised(cfg, transport);
    }
    if !cfg.output.trace_out.is_empty() || !cfg.output.report_json.is_empty()
    {
        // the span recorders live in the comms ranks; the single-engine
        // path has none — surface the mismatch instead of silently
        // writing nothing
        println!("note     : --trace-out/--report-json trace the comms \
                  ranks; this single-engine run (ranks = 1) writes no \
                  telemetry");
    }
    let geom = cfg.geometry();
    let model = cfg.model()?;
    let n = geom.nsites();

    let mut target = cfg.build_target()?;
    let target_desc = target.describe();
    println!("target   : {target_desc}");
    println!("lattice  : {} {}x{}x{} ({} sites)", model.name(), geom.lx,
             geom.ly, geom.lz, n);

    let mut engine =
        LbEngine::new(target.as_mut(), geom, model, cfg.free_energy)?;
    engine.set_fusion(cfg.target.fusion);
    let fused = engine.fused_active();
    println!("pipeline : {}", match engine.fused_tier() {
        Some((KernelId::MultiStep, k)) => {
            format!("fused multi-step (k={k} per launch)")
        }
        Some(_) => "fused full-step".into(),
        None => "unfused (5 kernels)".to_string(),
    });

    // initial condition — or a restored checkpoint, in which case the
    // run continues from the recorded step, bitwise identical to an
    // uninterrupted run (the stepping is deterministic and
    // block-boundary-independent)
    let (f, g, step0) = starting_state(cfg, &geom)?;
    engine.load_state(&f, &g)?;
    if step0 > 0 {
        println!("restore  : {} at step {step0}", cfg.output.restore);
    }

    let initial = engine.observables()?;
    println!("initial  : mass={:.6} phi={:.6} var={:.3e}", initial.mass,
             initial.phi_total, initial.phi_variance);

    let mut csv = open_observables_csv(cfg, &initial)?;
    let block = block_size(cfg);
    let ck_path = checkpoint_path(cfg);
    let mut mlups = Mlups::new();
    let timer = Timer::start();
    let mut done = step0;
    let mut blocks_done = 0u64;
    while done < cfg.simulation.steps {
        let todo = block.min(cfg.simulation.steps - done);
        let t = Timer::start();
        engine.run(todo)?;
        mlups.record(n, todo, t.seconds());
        done += todo;
        blocks_done += 1;
        let obs = engine.observables()?;
        println!(
            "step {done:>6}: mass={:.6} phi={:.6} var={:.4e} [{:.2} MLUPS]",
            obs.mass, obs.phi_total, obs.phi_variance, mlups.value()
        );
        if let Some(w) = csv.as_mut() {
            w.row(&[done as f64, obs.mass, obs.phi_total, obs.phi_variance,
                    mlups.value()])?;
        }
        if let Some(path) = ck_path.as_ref() {
            if blocks_done % cfg.output.checkpoint_every == 0
                && done < cfg.simulation.steps
            {
                let mut ckf = vec![0.0; model.velset().nvel * n];
                let mut ckg = vec![0.0; model.velset().nvel * n];
                engine.fetch_state(&mut ckf, &mut ckg)?;
                write_checkpoint(cfg, path, done, ckf, ckg)?;
            }
        }
    }

    let final_obs = engine.observables()?;
    if cfg.output.vtk && !cfg.output.dir.is_empty() {
        let phi = engine.phi_field()?;
        let path = Path::new(&cfg.output.dir).join("phi_final.vtk");
        write_vtk_scalar(&path, &geom, "phi", &phi)?;
        println!("wrote {}", path.display());
    }
    if let Some(w) = csv.as_mut() {
        w.flush()?;
    }

    let summary = RunSummary {
        target: target_desc,
        steps: cfg.simulation.steps,
        nsites: n,
        seconds: timer.seconds(),
        mlups: mlups.value(),
        fused,
        initial,
        r#final: final_obs,
    };
    println!(
        "done     : {} steps in {:.3}s = {:.2} MLUPS, mass drift {:.2e}",
        summary.steps, summary.seconds, summary.mlups, summary.mass_drift()
    );
    Ok(summary)
}

/// Supervised driver loop for decomposed runs: run the world, and on a
/// world error — a dead rank or host surfacing through the transport
/// timeouts, the launcher's exit statuses, or the hybrid EOF policies —
/// tear the world down and relaunch it from the last checkpoint, up to
/// `[fault] max_restarts` times with `backoff_ms * attempt` sleeps in
/// between. Each relaunch:
///
/// - disarms the injected fault (unless `kill_repeat`, which is how the
///   retry-exhaustion tests drive every incarnation into the ground),
/// - points `[output] restore` at the checkpoint file when one exists
///   (otherwise the world restarts from the initial condition — still
///   correct, just more recompute), and
/// - optionally re-decomposes at `retry_ranks` ranks (the explicit
///   `grid` is cleared so the auto factorisation re-resolves), which is
///   sound because checkpoints are decomposition-independent.
///
/// `max_restarts = 0` (the default) is unsupervised: the first error
/// surfaces unchanged. Exhaustion returns a named error wrapping the
/// last failure — never a hang, because every receive in the world is
/// bounded by `CommsConfig::wait_timeout`.
fn run_supervised(cfg: &Config, transport: TransportMode)
                  -> Result<RunSummary> {
    let retries = cfg.fault.max_restarts;
    if retries == 0 {
        return run_decomposed_simulation(cfg, transport);
    }
    let ck = checkpoint_path(cfg);
    let mut attempt_cfg = cfg.clone();
    let mut last_err =
        match run_decomposed_simulation(&attempt_cfg, transport) {
            Ok(s) => return Ok(s),
            Err(e) => e,
        };
    for attempt in 1..=retries {
        println!("recover  : world error ({last_err}); restart \
                  {attempt}/{retries}");
        if !cfg.fault.kill_repeat {
            // the fault fired in the incarnation that just died; a
            // real failed node would not deterministically fail again
            attempt_cfg.fault.kill_step = 0;
        }
        if cfg.fault.retry_ranks > 0 {
            attempt_cfg.target.ranks = cfg.fault.retry_ranks as usize;
            // the explicit grid was sized for the old rank count; let
            // auto_grid re-factorise the new one
            attempt_cfg.target.grid = String::new();
        }
        if let Some(path) = ck.as_ref() {
            if Path::new(path).exists() {
                attempt_cfg.output.restore = path.clone();
                println!("recover  : resuming from {path}");
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(
            cfg.fault.backoff_ms.saturating_mul(attempt),
        ));
        match run_decomposed_simulation(&attempt_cfg, transport) {
            Ok(s) => return Ok(s),
            Err(e) => last_err = e,
        }
    }
    Err(Error::Invalid(format!(
        "comms: world failed after {retries} restart(s); last error: \
         {last_err}"
    )))
}

/// The decomposed (`ranks > 1` or socket-transport) pipeline: bring up a
/// **resident** comms rank session — in-process threads spawned exactly
/// once, or rank OS processes assembled by the socket rendezvous — each
/// rank owning its slab-local state for the whole run; advance in
/// logging blocks over the session command protocol, and report per-rank
/// MLUPS and exchange-wait breakdowns from the session-accumulated
/// [`crate::comms::WorldReport`].
///
/// Per-block observables are **distributed reductions** by default
/// (`[target] observables = "reduced"`): every rank sums its own interior
/// and only O(ranks) partial sums travel — no global f/g scatter/gather
/// between blocks. `"gather"` restores the old pull-everything-back
/// behaviour (bit-exact with the single-engine reduction) at O(state)
/// cost per block. The full state is gathered only on demand: the VTK
/// snapshot asks the resident ranks for phi directly.
///
/// Socket mode (`transport = "socket"`): with no `rank_server` the
/// driver binds an ephemeral loopback port and spawns one
/// `targetdp rank` child per slab; with `rank_server = "host:port"` it
/// listens there for manually started ranks (one
/// `targetdp rank --connect host:port` per host). Either way the full
/// config travels in the rendezvous payload and each rank process
/// recomputes the deterministic initial state locally, so the physics is
/// bit-identical to the channel world and to the single-domain engine.
fn run_decomposed_simulation(cfg: &Config, transport: TransportMode)
                             -> Result<RunSummary> {
    let geom = cfg.geometry();
    let model = cfg.model()?;
    let vs = model.velset();
    let n = geom.nsites();
    let ccfg = cfg.comms_config()?;
    let mode = cfg.observables_mode()?;
    let world = CommsWorld::new(geom, ccfg.clone())?;
    let target_desc = format!(
        "comms(ranks={}{},{},{},{},vvl={},threads={},depth={}{})",
        ccfg.ranks,
        // the slab grid is the default shape — only a real 3D grid is
        // worth a tag in the target line
        if world.dec.is_slab() {
            String::new()
        } else {
            format!(",grid={}x{}x{}", world.dec.grid[0],
                    world.dec.grid[1], world.dec.grid[2])
        },
        match transport {
            TransportMode::Channel => "channel",
            TransportMode::Socket => "socket",
            TransportMode::Hybrid => "hybrid",
        },
        if ccfg.overlap { "overlap" } else { "bulk-sync" },
        if ccfg.scalar { "host-scalar" } else { "host-simd" },
        ccfg.vvl,
        ccfg.threads,
        ccfg.depth,
        if ccfg.pin { ",pinned" } else { "" },
    );
    println!("target   : {target_desc}");
    println!("lattice  : {} {}x{}x{} ({} sites)", model.name(), geom.lx,
             geom.ly, geom.lz, n);
    println!("pipeline : resident ranks, unfused (halo exchange {}, {} \
              observables)",
             if ccfg.overlap { "overlapped with interior compute" }
             else { "bulk-synchronous" },
             match mode {
                 ObservablesMode::Reduced => "distributed-reduction",
                 ObservablesMode::Gather => "gathered-state",
             });
    for d in &world.dec.domains {
        println!(
            "rank {:>4}: cell ({},{},{})  x = [{}, {})  y = [{}, {})  \
             z = [{}, {})  ({} sites)",
            d.rank, d.coords[0], d.coords[1], d.coords[2], d.origin[0],
            d.origin[0] + d.ext[0], d.origin[1], d.origin[1] + d.ext[1],
            d.origin[2], d.origin[2] + d.ext[2], d.interior_sites(),
        );
    }

    let (f0, g0, step0) = starting_state(cfg, &geom)?;
    if step0 > 0 {
        println!("restore  : {} at step {step0}", cfg.output.restore);
    }
    let initial = state_observables(vs, &f0, &g0, n);
    println!("initial  : mass={:.6} phi={:.6} var={:.3e}", initial.mass,
             initial.phi_total, initial.phi_variance);

    // channel mode: the initial state moves into the session — each rank
    // thread copies its own planes out of it (first touch on the rank's
    // pool). Socket/hybrid mode: each rank (or host) *process*
    // recomputes it from the config shipped in the rendezvous payload
    // instead, so no state crosses the wire at startup. Either way the
    // ranks stay resident until `finish`.
    let (mut session, local_ranks): (CommsSession, Option<LocalRanks>) =
        match transport {
            TransportMode::Channel => {
                (world.session(vs, &cfg.free_energy, f0, g0)?, None)
            }
            TransportMode::Socket => {
                let listen = if cfg.target.rank_server.is_empty() {
                    "127.0.0.1:0"
                } else {
                    cfg.target.rank_server.as_str()
                };
                let server = RankServer::bind(listen)?;
                let addr = server.local_addr()?;
                let local = if cfg.target.rank_server.is_empty() {
                    println!("ranks    : spawning {} local rank \
                              processes -> {addr}",
                             ccfg.ranks);
                    Some(LocalRanks::spawn(ccfg.ranks, &addr.to_string(),
                                           &["rank".to_string()])?)
                } else {
                    // a wildcard bind (0.0.0.0 / ::) is not a dialable
                    // address — tell the operator to substitute a host
                    // the rank machines can actually route to
                    let shown = if addr.ip().is_unspecified() {
                        format!("<driver-host>:{}", addr.port())
                    } else {
                        addr.to_string()
                    };
                    println!("ranks    : waiting for {} ranks; start \
                              `targetdp rank --connect {shown}` on each \
                              host",
                             ccfg.ranks);
                    None
                };
                let controller = server
                    .rendezvous(ccfg.ranks,
                                cfg.to_toml_string().as_bytes())?;
                (world.remote_session(vs, Box::new(controller))?, local)
            }
            TransportMode::Hybrid => {
                let listen = if cfg.target.rank_server.is_empty() {
                    "127.0.0.1:0"
                } else {
                    cfg.target.rank_server.as_str()
                };
                let server = RankServer::bind(listen)?;
                let addr = server.local_addr()?;
                let local = if cfg.target.rank_server.is_empty() {
                    // one machine = one host process carrying every
                    // rank; every link is an in-process channel
                    println!("ranks    : spawning 1 local host process \
                              carrying {} ranks -> {addr}",
                             ccfg.ranks);
                    Some(LocalRanks::spawn_hosts(
                        &[HostSpec { first: 0, count: ccfg.ranks,
                                     env: vec![] }],
                        &addr.to_string(), &["rank".to_string()])?)
                } else {
                    let shown = if addr.ip().is_unspecified() {
                        format!("<driver-host>:{}", addr.port())
                    } else {
                        addr.to_string()
                    };
                    println!("ranks    : waiting for {} ranks; start \
                              `targetdp rank --connect {shown} \
                              --local-ranks <n>` on each host",
                             ccfg.ranks);
                    None
                };
                let controller = server
                    .rendezvous_hosts(ccfg.ranks,
                                      cfg.to_toml_string().as_bytes())?;
                (world.remote_session(vs, Box::new(controller))?, local)
            }
        };

    let mut csv = open_observables_csv(cfg, &initial)?;
    let block = block_size(cfg);
    let ck_path = checkpoint_path(cfg);
    let mut mlups = Mlups::new();
    let timer = Timer::start();
    let mut done = step0;
    let mut blocks_done = 0u64;
    // gather-mode scratch, allocated only when the knob asks for it
    let mut gathered = match mode {
        ObservablesMode::Gather => {
            Some((vec![0.0; vs.nvel * n], vec![0.0; vs.nvel * n]))
        }
        ObservablesMode::Reduced => None,
    };
    let mut last_obs = initial;
    let mut last_beat = Instant::now();
    while done < cfg.simulation.steps {
        let todo = block.min(cfg.simulation.steps - done);
        let t = Timer::start();
        session.advance(todo)?;
        let obs = match gathered.as_mut() {
            None => session.observables()?,
            Some((f, g)) => {
                session.gather(f, g)?;
                state_observables(vs, f, g, n)
            }
        };
        mlups.record(n, todo, t.seconds());
        done += todo;
        blocks_done += 1;
        last_obs = obs;
        println!(
            "step {done:>6}: mass={:.6} phi={:.6} var={:.4e} [{:.2} MLUPS]",
            obs.mass, obs.phi_total, obs.phi_variance, mlups.value()
        );
        if let Some(w) = csv.as_mut() {
            w.row(&[done as f64, obs.mass, obs.phi_total, obs.phi_variance,
                    mlups.value()])?;
        }
        // checkpoint between logging blocks: the resident ranks stream
        // their interiors up the bit-exact gather payload path and the
        // reassembled global state lands on disk atomically
        if let Some(path) = ck_path.as_ref() {
            if blocks_done % cfg.output.checkpoint_every == 0
                && done < cfg.simulation.steps
            {
                let mut ckf = vec![0.0; vs.nvel * n];
                let mut ckg = vec![0.0; vs.nvel * n];
                session.checkpoint(&mut ckf, &mut ckg)?;
                write_checkpoint(cfg, path, done, ckf, ckg)?;
            }
        }
        // progress heartbeat, rate-limited to at most one line per
        // `heartbeat` seconds (gather-mode observables carry no wait
        // partials, so the wait column shows n/a there)
        if cfg.output.heartbeat > 0
            && last_beat.elapsed().as_secs() >= cfg.output.heartbeat
        {
            let wait = match session.max_wait_fraction() {
                Some(w) => format!("{:.1}%", 100.0 * w),
                None => "n/a".into(),
            };
            println!("heartbeat: step {done}/{}, {:.2} MLUPS, max wait \
                      {wait}",
                     cfg.simulation.steps, mlups.value());
            last_beat = Instant::now();
        }
    }
    let final_obs = last_obs;

    if cfg.output.vtk && !cfg.output.dir.is_empty() {
        // phi computed by the resident ranks (their own pools and VVL) —
        // only nsites doubles travel, not the nvel-component state
        let phi = session.gather_phi()?;
        let path = Path::new(&cfg.output.dir).join("phi_final.vtk");
        write_vtk_scalar(&path, &geom, "phi", &phi)?;
        println!("wrote {}", path.display());
    }

    // retire the resident ranks; each reports its whole-run totals
    let report = session.finish()?;
    // a socket run then reaps its spawned rank processes: Shutdown has
    // been acknowledged by every rank, so this only collects exit codes
    if let Some(local) = local_ranks {
        local.wait()?;
    }
    println!("per-rank : (exchange wait share of working wall time)");
    for r in &report.ranks {
        println!(
            "rank {:>4}: {:>8.2} MLUPS  compute {:.3}s  wait {:.3}s \
             ({:.1}%)  idle {:.3}s",
            r.rank,
            r.mlups(),
            r.compute_s,
            r.wait_s,
            100.0 * r.wait_fraction(),
            r.idle_s,
        );
    }
    let bytes_sent: u64 = report.ranks.iter().map(|r| r.bytes_sent).sum();
    let bytes_intra: u64 =
        report.ranks.iter().map(|r| r.bytes_intra).sum();
    let bytes_inter: u64 =
        report.ranks.iter().map(|r| r.bytes_inter).sum();
    const MIB: f64 = 1024.0 * 1024.0;
    println!("exchange : {:.2} MiB total over {} steps \
              ({:.2} MiB intra-host, {:.2} MiB inter-host)",
             bytes_sent as f64 / MIB, done, bytes_intra as f64 / MIB,
             bytes_inter as f64 / MIB);

    if !cfg.output.trace_out.is_empty() {
        write_json_file(&cfg.output.trace_out,
                        &chrome_trace_json(&report.traces))?;
    }
    if !cfg.output.report_json.is_empty() {
        write_json_file(&cfg.output.report_json,
                        &run_report_json(cfg, &report, done, n,
                                         mlups.value()))?;
    }

    if let Some(w) = csv.as_mut() {
        w.flush()?;
    }

    let summary = RunSummary {
        target: target_desc,
        steps: cfg.simulation.steps,
        nsites: n,
        seconds: timer.seconds(),
        mlups: mlups.value(),
        fused: false,
        initial,
        r#final: final_obs,
    };
    println!(
        "done     : {} steps in {:.3}s = {:.2} MLUPS, mass drift {:.2e}",
        summary.steps, summary.seconds, summary.mlups, summary.mass_drift()
    );
    Ok(summary)
}

/// Serialize `value` to `path` (parent directories created on demand)
/// and log the destination like the CSV/VTK writers do.
fn write_json_file(path: &str, value: &Json) -> Result<()> {
    let p = Path::new(path);
    if let Some(parent) = p.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(p, value.to_string())?;
    println!("wrote {}", p.display());
    Ok(())
}

/// Span axis tag → Chrome-trace arg string.
fn axis_name(axis: u8) -> &'static str {
    match axis {
        0 => "x",
        1 => "y",
        2 => "z",
        _ => "?",
    }
}

/// Convert the wire-shipped span timelines into the Chrome
/// `trace_event` JSON object format: one complete (`"ph": "X"`) event
/// per span with microsecond timestamps against the rank's run epoch,
/// one process row per rank (`pid` = rank), one thread row per recorder
/// (`tid` 0 = the rank thread, `tid` t ≥ 1 = TLP worker t−1), with
/// metadata events naming them. Open the file in `chrome://tracing` or
/// <https://ui.perfetto.dev>.
fn chrome_trace_json(traces: &[Vec<Span>]) -> Json {
    let mut events = Vec::new();
    for (rank, spans) in traces.iter().enumerate() {
        if spans.is_empty() {
            continue;
        }
        events.push(obj(vec![
            ("name", Json::from("process_name")),
            ("ph", Json::from("M")),
            ("pid", Json::from(rank)),
            ("args", obj(vec![("name",
                               Json::from(format!("rank {rank}")))])),
        ]));
        let mut tids: Vec<u32> = spans.iter().map(|s| s.tid).collect();
        tids.sort_unstable();
        tids.dedup();
        for tid in tids {
            let label = if tid == 0 {
                "rank thread".to_string()
            } else {
                format!("tlp worker {}", tid - 1)
            };
            events.push(obj(vec![
                ("name", Json::from("thread_name")),
                ("ph", Json::from("M")),
                ("pid", Json::from(rank)),
                ("tid", Json::from(tid as u64)),
                ("args", obj(vec![("name", Json::from(label))])),
            ]));
        }
        for s in spans {
            let mut args = vec![("step", Json::from(s.step))];
            if s.axis != AXIS_NONE {
                args.push(("axis", Json::from(axis_name(s.axis))));
            }
            if s.side != SIDE_NONE {
                args.push(("side", Json::from(if s.side == 0 {
                    "low"
                } else {
                    "high"
                })));
            }
            events.push(obj(vec![
                ("name", Json::from(s.phase.name())),
                ("ph", Json::from("X")),
                ("pid", Json::from(rank)),
                ("tid", Json::from(s.tid as u64)),
                ("ts", Json::from(s.t_start * 1e6)),
                ("dur", Json::from((s.t_end - s.t_start) * 1e6)),
                ("args", obj(args)),
            ]));
        }
    }
    obj(vec![
        ("displayTimeUnit", Json::from("ms")),
        ("traceEvents", Json::Array(events)),
    ])
}

/// Build the `--report-json` document: a config echo, whole-world
/// summary, and per-rank counters (per-axis halo traffic, super-steps,
/// MLUPS, wait fraction, and the wall-time-per-phase histogram summed
/// from the rank thread's spans — nested phases like the `send` inside
/// a `pack` each count their own wall time).
fn run_report_json(cfg: &Config, report: &WorldReport, steps: u64,
                   nsites: usize, mlups: f64) -> Json {
    let s = &cfg.simulation;
    let t = &cfg.target;
    let config = obj(vec![
        ("lattice", Json::from(s.lattice.as_str())),
        ("lx", Json::from(s.lx)),
        ("ly", Json::from(s.ly)),
        ("lz", Json::from(s.lz)),
        ("steps", Json::from(s.steps)),
        ("init", Json::from(s.init.as_str())),
        ("seed", Json::from(s.seed)),
        ("backend", Json::from(t.backend.as_str())),
        ("vvl", Json::from(t.vvl)),
        ("threads", Json::from(t.threads)),
        ("schedule", Json::from(t.schedule.as_str())),
        ("ranks", Json::from(t.ranks)),
        ("grid", Json::from(t.grid.as_str())),
        ("overlap", Json::from(t.overlap)),
        ("comms_depth", Json::from(t.comms_depth)),
        ("observables", Json::from(t.observables.as_str())),
        ("transport", Json::from(t.transport.as_str())),
    ]);
    let empty: Vec<Span> = Vec::new();
    let ranks: Vec<Json> = report
        .ranks
        .iter()
        .map(|r| {
            let spans = report.traces.get(r.rank).unwrap_or(&empty);
            let mut hist = [0.0f64; TracePhase::ALL.len()];
            for s in spans.iter().filter(|s| s.tid == 0) {
                hist[s.phase as usize] += s.t_end - s.t_start;
            }
            let phases = obj(TracePhase::ALL
                .iter()
                .map(|p| (p.name(), Json::from(hist[*p as usize])))
                .collect());
            obj(vec![
                ("rank", Json::from(r.rank)),
                ("interior_sites", Json::from(r.interior_sites)),
                ("steps", Json::from(r.steps)),
                ("compute_s", Json::from(r.compute_s)),
                ("wait_s", Json::from(r.wait_s)),
                ("idle_s", Json::from(r.idle_s)),
                ("mlups", Json::from(r.mlups())),
                ("wait_fraction", Json::from(r.wait_fraction())),
                ("bytes_sent", Json::from(r.bytes_sent)),
                ("msgs_sent", Json::from(r.msgs_sent)),
                ("bytes_intra", Json::from(r.bytes_intra)),
                ("bytes_inter", Json::from(r.bytes_inter)),
                ("msgs_intra", Json::from(r.msgs_intra)),
                ("msgs_inter", Json::from(r.msgs_inter)),
                ("bytes_axis",
                 Json::Array(r.bytes_axis.iter().copied().map(Json::from)
                     .collect())),
                ("msgs_axis",
                 Json::Array(r.msgs_axis.iter().copied().map(Json::from)
                     .collect())),
                ("super_steps", Json::from(r.super_steps)),
                ("spans", Json::from(spans.len())),
                ("phase_seconds", phases),
            ])
        })
        .collect();
    obj(vec![
        ("config", config),
        ("world", obj(vec![
            ("ranks", Json::from(report.ranks.len())),
            ("steps", Json::from(steps)),
            ("nsites", Json::from(nsites)),
            ("seconds", Json::from(report.seconds)),
            ("overlap", Json::from(report.overlap)),
            ("mlups", Json::from(mlups)),
        ])),
        ("ranks", Json::Array(ranks)),
    ])
}

/// Entry point of a **rank process** (`targetdp rank --connect
/// HOST:PORT [--rank R] [--local-ranks N]`): rendezvous with the
/// driver's rank server, rebuild the identical run from the config
/// shipped in the `Welcome` payload, recompute the deterministic
/// initial state locally, and serve until the driver's `Shutdown`.
/// Against a socket driver this serves one rank; against a hybrid
/// driver it becomes a **host process** driving `local_ranks` resident
/// rank threads off the one rendezvous connection — co-hosted
/// neighbours exchange frames in-process, and the same rank body
/// ([`crate::comms::serve_rank`]) runs per thread either way.
///
/// The process is silent on success — all run logging belongs to the
/// driver; errors surface through the exit code, which the driver's
/// [`LocalRanks::wait`] (spawn-local) or the operator (multi-host)
/// observes.
pub fn run_rank_process(server: &str, want_rank: Option<usize>,
                        local_ranks: usize) -> Result<()> {
    let (endpoints, payload) =
        connect_world(server, want_rank, local_ranks)?;
    let text = String::from_utf8(payload).map_err(|_| {
        Error::Parse(
            "comms launcher: setup payload is not UTF-8 TOML".into(),
        )
    })?;
    let cfg = Config::from_toml_str(&text)?;
    let geom = cfg.geometry();
    let model = cfg.model()?;
    let vs = model.velset();
    let ccfg = cfg.comms_config()?;
    let world = CommsWorld::new(geom, ccfg.clone())?;
    let nthreads = threads_per_rank(ccfg.threads, ccfg.ranks);
    let domain_of = |rank: usize| {
        world.dec.domains.get(rank).cloned().ok_or_else(|| {
            Error::Invalid(format!(
                "comms launcher: assigned rank {rank}, world has {} \
                 domains",
                world.dec.domains.len()
            ))
        })
    };
    match endpoints {
        WorldEndpoints::Socket(transport) => {
            let rank = crate::comms::Transport::rank(&transport);
            let d = domain_of(rank)?;
            // restore ships as a path in the rendezvous TOML; the rank
            // process reads the checkpoint locally and keeps only its
            // own planes, exactly like the fresh initial condition
            let (f0, g0, _step0) = starting_state(&cfg, &geom)?;
            crate::comms::serve_rank(d, vs, &cfg.free_energy, f0, g0,
                                     &ccfg, nthreads, Box::new(transport))
        }
        WorldEndpoints::Hybrid(eps) => {
            // one resident thread per endpoint, all sharing this
            // process's links; each recomputes the deterministic
            // initial state and keeps only its own planes
            let fe = cfg.free_energy;
            let mut joins = Vec::with_capacity(eps.len());
            for t in eps {
                let rank = crate::comms::Transport::rank(&t);
                let d = domain_of(rank)?;
                let (f0, g0, _step0) = starting_state(&cfg, &geom)?;
                let ccfg = ccfg.clone();
                joins.push(std::thread::spawn(move || {
                    crate::comms::serve_rank(d, vs, &fe, f0, g0, &ccfg,
                                             nthreads, Box::new(t))
                }));
            }
            let mut first_err = None;
            for j in joins {
                match j.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => {
                        first_err.get_or_insert(e);
                    }
                    Err(_) => {
                        first_err.get_or_insert(Error::Invalid(
                            "comms hybrid: a resident rank thread \
                             panicked"
                                .into(),
                        ));
                    }
                }
            }
            match first_err {
                None => Ok(()),
                Some(e) => Err(e),
            }
        }
    }
}

/// Convenience: run a short spinodal simulation on a given backend without
/// a config file (used by tests and the benches).
pub fn quick_spinodal(backend: &str, lattice: LatticeModel,
                      extent: (usize, usize, usize), steps: u64, vvl: usize)
                      -> Result<RunSummary> {
    let cfg = Config {
        simulation: crate::config::SimulationCfg {
            lattice: lattice.name().into(),
            lx: extent.0,
            ly: extent.1,
            lz: extent.2,
            steps,
            init: "spinodal".into(),
            noise: 0.05,
            seed: 1234,
            radius: 8.0,
        },
        target: crate::config::TargetCfg {
            backend: backend.into(),
            vvl,
            ..Default::default()
        },
        free_energy: Default::default(),
        output: Default::default(),
        fault: Default::default(),
    };
    run_simulation(&cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_run_conserves_and_reports() {
        let s = quick_spinodal("host-simd", LatticeModel::D3Q19, (8, 8, 8),
                               10, 8)
            .unwrap();
        assert_eq!(s.steps, 10);
        assert!(s.fused, "host backend defaults to the fused tier");
        assert!(s.mass_drift() < 1e-12, "mass drift {}", s.mass_drift());
        assert!(s.phi_drift() < 1e-12);
        assert!(s.mlups > 0.0);
    }

    #[test]
    fn fusion_off_runs_unfused_with_same_physics() {
        let mk = |fusion: bool| {
            let mut cfg = Config {
                simulation: crate::config::SimulationCfg {
                    lattice: "d2q9".into(),
                    lx: 12,
                    ly: 12,
                    lz: 1,
                    steps: 6,
                    init: "spinodal".into(),
                    noise: 0.05,
                    seed: 99,
                    radius: 4.0,
                },
                target: Default::default(),
                free_energy: Default::default(),
                output: Default::default(),
                fault: Default::default(),
            };
            cfg.target.fusion = fusion;
            run_simulation(&cfg).unwrap()
        };
        let fused = mk(true);
        let unfused = mk(false);
        assert!(fused.fused && !unfused.fused);
        assert_eq!(fused.r#final.phi_variance, unfused.r#final.phi_variance,
                   "fused and unfused pipelines are bit-identical");
    }

    #[test]
    fn decomposed_run_matches_single_engine_run() {
        let mk = |ranks: usize, overlap: bool, observables: &str| {
            let mut cfg = Config {
                simulation: crate::config::SimulationCfg {
                    lattice: "d2q9".into(),
                    lx: 9, // uneven over 2 ranks
                    ly: 8,
                    lz: 1,
                    steps: 6,
                    init: "spinodal".into(),
                    noise: 0.05,
                    seed: 42,
                    radius: 4.0,
                },
                target: Default::default(),
                free_energy: Default::default(),
                output: Default::default(),
                fault: Default::default(),
            };
            cfg.target.ranks = ranks;
            cfg.target.overlap = overlap;
            cfg.target.observables = observables.into();
            run_simulation(&cfg).unwrap()
        };
        let single = mk(1, true, "reduced"); // engine path (fused)
        let multi = mk(2, true, "gather"); // comms path, overlapped
        let bulk = mk(2, false, "gather"); // comms path, bulk-sync
        assert!(single.fused && !multi.fused);
        assert!(multi.target.starts_with("comms(ranks=2"));
        // the distribution level must not change the physics at all:
        // gathered-state observables reduce the bit-identical global
        // state with the single sweep the engine path uses
        assert_eq!(single.r#final.phi_variance, multi.r#final.phi_variance);
        assert_eq!(single.r#final.mass, multi.r#final.mass);
        assert_eq!(multi.r#final.phi_variance, bulk.r#final.phi_variance);
        assert!(multi.mass_drift() < 1e-12);

        // the default distributed reduction sums the same interiors in
        // per-rank partial order: equal to rounding, and conservation
        // holds exactly as tightly
        let reduced = mk(2, true, "reduced");
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 + 1e-9 * b.abs();
        assert!(close(reduced.r#final.mass, multi.r#final.mass));
        assert!(close(reduced.r#final.phi_total, multi.r#final.phi_total));
        assert!(close(reduced.r#final.phi_variance,
                      multi.r#final.phi_variance));
        assert!(reduced.mass_drift() < 1e-9);
    }

    #[test]
    fn grid_run_matches_single_engine_run_and_tags_target() {
        let mk = |ranks: usize, grid: &str| {
            let mut cfg = Config {
                simulation: crate::config::SimulationCfg {
                    lattice: "d2q9".into(),
                    lx: 8,
                    ly: 7, // uneven over the 2-way y split
                    lz: 1,
                    steps: 5,
                    init: "spinodal".into(),
                    noise: 0.05,
                    seed: 7,
                    radius: 4.0,
                },
                target: Default::default(),
                free_energy: Default::default(),
                output: Default::default(),
                fault: Default::default(),
            };
            cfg.target.ranks = ranks;
            cfg.target.grid = grid.into();
            cfg.target.observables = "gather".into();
            run_simulation(&cfg).unwrap()
        };
        let single = mk(1, "");
        let grid = mk(2, "1,2,1");
        // the grid world is tagged in the target line and changes no bits
        assert!(grid.target.contains("grid=1x2x1"), "{}", grid.target);
        assert_eq!(single.r#final.phi_variance, grid.r#final.phi_variance);
        assert_eq!(single.r#final.mass, grid.r#final.mass);
    }

    #[test]
    fn zero_mass_initial_state_has_finite_drift() {
        // regression: mass_drift divided by initial.mass, so a zero-mass
        // state (e.g. a pure order-parameter relaxation) reported NaN
        let zero = Observables {
            mass: 0.0,
            momentum: [0.0; 3],
            phi_total: 0.0,
            phi_variance: 0.0,
        };
        let mut s = RunSummary {
            target: "test".into(),
            steps: 1,
            nsites: 8,
            seconds: 1.0,
            mlups: 1.0,
            fused: false,
            initial: zero,
            r#final: zero,
        };
        assert_eq!(s.mass_drift(), 0.0);
        assert!(s.mass_drift().is_finite());
        // any drift away from zero mass is reported absolutely
        s.r#final.mass = 0.5;
        assert_eq!(s.mass_drift(), 0.5);
        assert!(s.phi_drift().is_finite());
        // negative initial mass must not flip the sign of the ratio
        s.initial.mass = -2.0;
        s.r#final.mass = -1.0;
        assert_eq!(s.mass_drift(), 0.5);
    }

    #[test]
    fn telemetry_json_builders_emit_parseable_documents() {
        use crate::comms::RankReport;
        let span = |phase, tid, t0: f64, t1: f64| Span {
            phase,
            step: 3,
            axis: AXIS_NONE,
            side: SIDE_NONE,
            tid,
            t_start: t0,
            t_end: t1,
        };
        let report = WorldReport {
            ranks: vec![RankReport {
                rank: 0,
                interior_sites: 64,
                steps: 6,
                compute_s: 0.5,
                wait_s: 0.1,
                idle_s: 0.05,
                bytes_sent: 1024,
                msgs_sent: 12,
                bytes_axis: [1024, 0, 0],
                msgs_axis: [12, 0, 0],
                super_steps: 0,
                bytes_intra: 256,
                bytes_inter: 768,
                msgs_intra: 3,
                msgs_inter: 9,
            }],
            seconds: 0.7,
            overlap: true,
            traces: vec![vec![span(TracePhase::Interior, 0, 0.0, 0.2),
                              span(TracePhase::WaitRecv, 0, 0.2, 0.3),
                              span(TracePhase::Collide, 1, 0.0, 0.1)]],
        };

        let trace = chrome_trace_json(&report.traces);
        let parsed = Json::parse(&trace.to_string()).unwrap();
        let events = parsed.get("traceEvents").as_array().unwrap();
        // 1 process_name + 2 thread_name metadata + 3 span events
        assert_eq!(events.len(), 6);
        let interior = events
            .iter()
            .find(|e| e.get("name").as_str().unwrap() == "interior")
            .expect("interior span event");
        assert_eq!(interior.get("ph").as_str().unwrap(), "X");
        assert_eq!(interior.get("pid").as_usize().unwrap(), 0);
        assert_eq!(interior.get("dur").as_f64().unwrap(), 0.2 * 1e6);
        assert_eq!(interior.get("args").get("step").as_usize().unwrap(), 3);

        let cfg = Config::from_toml_str(
            "[simulation]\nlattice = \"d2q9\"\nlx = 8\nly = 8\nlz = 1\n\
             steps = 6\n\n[target]\nranks = 1\n",
        )
        .unwrap();
        let doc = run_report_json(&cfg, &report, 6, 64, 1.5);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("config").get("lattice").as_str().unwrap(),
                   "d2q9");
        assert_eq!(parsed.get("world").get("ranks").as_usize().unwrap(), 1);
        let ranks = parsed.get("ranks").as_array().unwrap();
        assert_eq!(ranks[0].get("super_steps").as_usize().unwrap(), 0);
        assert_eq!(ranks[0].get("bytes_intra").as_usize().unwrap(), 256);
        assert_eq!(ranks[0].get("bytes_inter").as_usize().unwrap(), 768);
        assert_eq!(ranks[0].get("msgs_intra").as_usize().unwrap(), 3);
        assert_eq!(ranks[0].get("msgs_inter").as_usize().unwrap(), 9);
        assert_eq!(ranks[0].get("bytes_axis").as_array().unwrap()[0]
                       .as_usize()
                       .unwrap(),
                   1024);
        let phases = ranks[0].get("phase_seconds");
        assert!((phases.get("interior").as_f64().unwrap() - 0.2).abs()
                    < 1e-12);
        assert_eq!(phases.get("collide").as_f64().unwrap(), 0.0,
                   "worker spans (tid > 0) stay out of the rank-thread \
                    histogram");
        assert_eq!(phases.get("idle").as_f64().unwrap(), 0.0,
                   "every phase key is present, zeros included");
    }

    #[test]
    fn scalar_and_simd_agree() {
        let a = quick_spinodal("host-scalar", LatticeModel::D2Q9,
                               (16, 16, 1), 5, 1)
            .unwrap();
        let b = quick_spinodal("host-simd", LatticeModel::D2Q9, (16, 16, 1),
                               5, 8)
            .unwrap();
        assert!((a.r#final.phi_variance - b.r#final.phi_variance).abs()
                < 1e-13);
        assert!((a.r#final.mass - b.r#final.mass).abs() < 1e-9);
    }
}
