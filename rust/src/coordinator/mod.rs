//! The L3 coordinator: configuration -> target -> timestep pipeline ->
//! metrics/IO. This is the launcher a user drives via the CLI
//! (`rust/src/main.rs`) or embeds via [`pipeline::run_simulation`].

pub mod metrics;
pub mod pipeline;

pub use metrics::{Mlups, Timer};
pub use pipeline::{run_rank_process, run_simulation, RunSummary};
