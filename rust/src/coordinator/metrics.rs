//! Timing and throughput metrics. The LB community's headline figure is
//! MLUPS — million lattice-site updates per second — which is what the
//! Figure-1 runtime bars translate to.

use std::time::{Duration, Instant};

/// Simple wall-clock timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn seconds(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Throughput accumulator.
#[derive(Debug, Default, Clone, Copy)]
pub struct Mlups {
    site_updates: u64,
    seconds: f64,
}

impl Mlups {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, nsites: usize, steps: u64, seconds: f64) {
        self.site_updates += nsites as u64 * steps;
        self.seconds += seconds;
    }

    /// Million lattice updates per second.
    pub fn value(&self) -> f64 {
        if self.seconds == 0.0 {
            return 0.0;
        }
        self.site_updates as f64 / self.seconds / 1e6
    }

    pub fn site_updates(&self) -> u64 {
        self.site_updates
    }

    pub fn seconds(&self) -> f64 {
        self.seconds
    }
}

/// Mean and standard deviation of repeated timings.
pub fn mean_std(samples: &[f64]) -> (f64, f64) {
    if samples.is_empty() {
        return (0.0, 0.0);
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    if samples.len() < 2 {
        return (mean, 0.0);
    }
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / (n - 1.0);
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlups_arithmetic() {
        let mut m = Mlups::new();
        m.record(1_000_000, 10, 2.0);
        assert!((m.value() - 5.0).abs() < 1e-12);
        m.record(1_000_000, 10, 2.0);
        assert!((m.value() - 5.0).abs() < 1e-12);
        assert_eq!(m.site_updates(), 20_000_000);
    }

    #[test]
    fn mlups_empty_is_zero() {
        assert_eq!(Mlups::new().value(), 0.0);
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 1.0, 1.0]);
        assert_eq!(m, 1.0);
        assert_eq!(s, 0.0);
        let (m, s) = mean_std(&[1.0, 3.0]);
        assert_eq!(m, 2.0);
        assert!((s - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.seconds() > 0.0);
    }
}
