//! Self-contained utilities (this build environment is offline, so the
//! usual ecosystem crates are replaced by minimal in-tree implementations).

pub mod cli;
pub mod json;
pub mod toml;
