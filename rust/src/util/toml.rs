//! Minimal TOML-subset reader for the run configuration files.
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / boolean values, `#` comments, blank lines. That covers every
//! config this project ships; anything fancier is a parse error rather
//! than a silent misread.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A TOML-subset scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    String(String),
    Integer(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::String(s) => Ok(s),
            other => Err(Error::Parse(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Integer(i) => Ok(*i as f64),
            other => Err(Error::Parse(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Integer(i) => Ok(*i),
            other => Err(Error::Parse(format!("expected integer, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        usize::try_from(i)
            .map_err(|_| Error::Parse(format!("expected unsigned, got {i}")))
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(Error::Parse(format!("expected bool, got {other:?}"))),
        }
    }
}

/// section -> key -> value.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| {
                Error::Parse(format!("line {}: unterminated [section]",
                                     lineno + 1))
            })?;
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line.split_once('=').ok_or_else(|| {
            Error::Parse(format!("line {}: expected key = value", lineno + 1))
        })?;
        let value = parse_value(value.trim()).map_err(|e| {
            Error::Parse(format!("line {}: {e}", lineno + 1))
        })?;
        doc.entry(section.clone())
            .or_default()
            .insert(key.trim().to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue> {
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| {
            Error::Parse("unterminated string".into())
        })?;
        return Ok(TomlValue::String(inner.to_string()));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !text.contains(['.', 'e', 'E']) {
        if let Ok(i) = text.parse::<i64>() {
            return Ok(TomlValue::Integer(i));
        }
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(Error::Parse(format!("cannot parse value {text:?}")))
}

/// Typed lookup helpers with defaults.
pub struct Section<'a>(pub Option<&'a BTreeMap<String, TomlValue>>);

impl<'a> Section<'a> {
    pub fn of(doc: &'a TomlDoc, name: &str) -> Self {
        Section(doc.get(name))
    }

    pub fn get(&self, key: &str) -> Option<&'a TomlValue> {
        self.0.and_then(|m| m.get(key))
    }

    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        match self.get(key) {
            Some(v) => Ok(v.as_str()?.to_string()),
            None => Ok(default.to_string()),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.as_usize(),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => Ok(v.as_i64()? as u64),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.as_f64(),
            None => Ok(default),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            Some(v) => v.as_bool(),
            None => Ok(default),
        }
    }

    pub fn require_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .ok_or_else(|| Error::Parse(format!("missing key {key:?}")))?
            .as_usize()
    }

    pub fn require_str(&self, key: &str) -> Result<String> {
        Ok(self
            .get(key)
            .ok_or_else(|| Error::Parse(format!("missing key {key:?}")))?
            .as_str()?
            .to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        # run config
        [simulation]
        lattice = "d3q19"   # model
        lx = 16
        steps = 100
        noise = 0.05
        vtk = true

        [target]
        backend = "host-simd"
    "#;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(SAMPLE).unwrap();
        let sim = Section::of(&doc, "simulation");
        assert_eq!(sim.require_str("lattice").unwrap(), "d3q19");
        assert_eq!(sim.require_usize("lx").unwrap(), 16);
        assert_eq!(sim.u64_or("steps", 0).unwrap(), 100);
        assert_eq!(sim.f64_or("noise", 0.0).unwrap(), 0.05);
        assert!(sim.bool_or("vtk", false).unwrap());
        let tgt = Section::of(&doc, "target");
        assert_eq!(tgt.str_or("backend", "x").unwrap(), "host-simd");
        assert_eq!(tgt.usize_or("vvl", 8).unwrap(), 8);
    }

    #[test]
    fn defaults_for_missing_section() {
        let doc = parse("").unwrap();
        let s = Section::of(&doc, "nope");
        assert_eq!(s.usize_or("x", 7).unwrap(), 7);
        assert!(s.require_usize("x").is_err());
    }

    #[test]
    fn integers_vs_floats() {
        let doc = parse("[a]\ni = 3\nf = 3.0\nn = -2\n").unwrap();
        let a = Section::of(&doc, "a");
        assert_eq!(a.get("i").unwrap(), &TomlValue::Integer(3));
        assert_eq!(a.get("f").unwrap(), &TomlValue::Float(3.0));
        assert!(a.get("n").unwrap().as_usize().is_err());
        assert_eq!(a.f64_or("i", 0.0).unwrap(), 3.0);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[open").is_err());
        assert!(parse("keyvalue").is_err());
        assert!(parse("k = \"unterminated").is_err());
        assert!(parse("k = what").is_err());
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = parse("[s]\nname = \"a#b\" # comment\n").unwrap();
        assert_eq!(Section::of(&doc, "s").require_str("name").unwrap(),
                   "a#b");
    }
}
