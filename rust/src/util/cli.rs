//! Tiny `--flag value` argument parser for the CLI binary (offline
//! replacement for clap).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line: subcommand + `--key value` flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    /// Flags present without a value (e.g. `--vtk`).
    switches: Vec<String>,
}

impl Args {
    /// Parse `args` (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        if let Some(first) = iter.peek() {
            if !first.starts_with("--") {
                out.command = iter.next().unwrap();
            }
        }
        while let Some(arg) = iter.next() {
            let key = arg.strip_prefix("--").ok_or_else(|| {
                Error::Parse(format!("unexpected argument {arg:?}"))
            })?;
            // --key=value or --key value or bare switch
            if let Some((k, v)) = key.split_once('=') {
                out.flags.insert(k.to_string(), v.to_string());
            } else if iter.peek().is_some_and(|next| !next.starts_with("--"))
            {
                out.flags.insert(key.to_string(), iter.next().unwrap());
            } else {
                out.switches.push(key.to_string());
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| {
                Error::Parse(format!("--{key} expects an integer, got {v:?}"))
            }),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().map_err(|_| {
                Error::Parse(format!("--{key} expects an integer, got {v:?}"))
            }),
            None => Ok(default),
        }
    }

    /// Boolean flag: `--key true|false|1|0` with a value, bare `--key`
    /// means true, absent means `default`.
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            Some("true") | Some("1") | Some("on") => Ok(true),
            Some("false") | Some("0") | Some("off") => Ok(false),
            Some(v) => Err(Error::Parse(format!(
                "--{key} expects true/false, got {v:?}"
            ))),
            None => Ok(self.switches.iter().any(|s| s == key) || default),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key) || self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["run", "--size", "16", "--backend=xla", "--vtk"]);
        assert_eq!(a.command, "run");
        assert_eq!(a.usize_or("size", 0).unwrap(), 16);
        assert_eq!(a.str_or("backend", ""), "xla");
        assert!(a.has("vtk"));
        assert!(!a.has("nope"));
        assert_eq!(a.u64_or("steps", 100).unwrap(), 100);
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--x", "1"]);
        assert_eq!(a.command, "");
        assert_eq!(a.usize_or("x", 0).unwrap(), 1);
    }

    #[test]
    fn bool_flags() {
        let a = parse(&["run", "--overlap", "false", "--vtk"]);
        assert!(!a.bool_or("overlap", true).unwrap());
        // bare switch means true; absent falls back to the default
        assert!(a.bool_or("vtk", false).unwrap());
        assert!(a.bool_or("missing", true).unwrap());
        assert!(!a.bool_or("missing", false).unwrap());
        let a = parse(&["run", "--overlap=1"]);
        assert!(a.bool_or("overlap", false).unwrap());
        let a = parse(&["run", "--overlap", "maybe"]);
        assert!(a.bool_or("overlap", true).is_err());
    }

    #[test]
    fn bad_int_rejected() {
        let a = parse(&["run", "--size", "big"]);
        assert!(a.usize_or("size", 0).is_err());
    }

    #[test]
    fn stray_positional_rejected() {
        assert!(Args::parse(["run".into(), "extra".into()]).is_err());
    }
}
