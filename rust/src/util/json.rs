//! Minimal JSON reader + writer — the reader covers
//! `artifacts/manifest.json`, the writer serializes the telemetry
//! outputs (`--trace-out` Chrome traces, `--report-json` run reports).
//!
//! Full JSON value model, recursive-descent parser, no external deps.
//! Numbers are f64 (the manifest only stores small integers and f64
//! physics constants, both exactly representable).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_array(&self) -> Result<&[Json]> {
        match self {
            Json::Array(a) => Ok(a),
            other => Err(Error::Parse(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_object(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Object(o) => Ok(o),
            other => Err(Error::Parse(format!("expected object, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::String(s) => Ok(s),
            other => Err(Error::Parse(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Number(n) => Ok(*n),
            other => Err(Error::Parse(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Parse(format!("expected non-negative int, got {n}")));
        }
        Ok(n as usize)
    }

    /// Object field access; `Ok(&Json::Null)` if absent.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Object(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Number(n) => {
                if n.is_finite() {
                    // `{:?}` prints the shortest string that round-trips
                    // the f64 bits (and always includes `.0` or an
                    // exponent, both fine for the parser)
                    out.push_str(&format!("{n:?}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::String(s) => write_escaped(s, out),
            Json::Array(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Serialize to compact JSON text (`value.to_string()`). The output
/// parses back with [`Json::parse`]; numbers use Rust's shortest
/// round-trip f64 formatting, and non-finite numbers (which JSON cannot
/// represent) serialize as `null`.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

/// Escape and quote a string for JSON output.
fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Shorthand: an object from key/value pairs (keys in given order are
/// fine — the `BTreeMap` sorts them, which keeps output deterministic).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Number(n)
    }
}

impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Number(n as f64)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Number(n as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::String(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::String(s)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Parse(format!("json: {msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| {
                                    self.err("bad hex in \\u escape")
                                })?;
                        }
                        out.push(char::from_u32(code)
                            .ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => out.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+'
                           || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("non-utf8 number"))?;
        text.parse::<f64>()
            .map(Json::Number)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(map)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Number(-250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(),
                   Json::String("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(
            r#"{"name": "x", "dims": [1, 2, 3], "meta": {"ok": true},
                "none": null}"#,
        )
        .unwrap();
        assert_eq!(v.get("name").as_str().unwrap(), "x");
        assert_eq!(v.get("dims").as_array().unwrap().len(), 3);
        assert_eq!(v.get("dims").as_array().unwrap()[2].as_usize().unwrap(),
                   3);
        assert_eq!(v.get("meta").get("ok"), &Json::Bool(true));
        assert!(v.get("none").is_null());
        assert!(v.get("absent").is_null());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(),
                   Json::String("A".into()));
    }

    #[test]
    fn writer_round_trips() {
        let v = Json::parse(
            r#"{"name": "x\n\"y\"", "dims": [1, 2.5, -3e2], "ok": true,
                "none": null, "empty": [], "nested": {"a": {}}}"#,
        )
        .unwrap();
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
        // compact and deterministic (BTreeMap key order)
        assert!(!text.contains(' '), "{text}");
    }

    #[test]
    fn writer_formats_scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::from(true).to_string(), "true");
        assert_eq!(Json::from(1.5).to_string(), "1.5");
        assert_eq!(Json::from(3u64).to_string(), "3.0");
        assert_eq!(Json::from("a\tb").to_string(), "\"a\\tb\"");
        assert_eq!(Json::from(f64::NAN).to_string(), "null",
                   "JSON has no NaN");
        assert_eq!(Json::from(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::from("\u{1}").to_string(), "\"\\u0001\"");
    }

    #[test]
    fn obj_helper_builds_objects() {
        let v = obj(vec![("b", Json::from(2u64)),
                         ("a", Json::Array(vec![Json::from("x")]))]);
        assert_eq!(v.to_string(), r#"{"a":["x"],"b":2.0}"#);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn accessor_type_errors() {
        let v = Json::parse("[1]").unwrap();
        assert!(v.as_object().is_err());
        assert!(v.as_str().is_err());
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
    }
}
