//! TOML configuration for the simulation driver (the launcher's input).
//!
//! Parsed with the in-tree TOML-subset reader ([`crate::util::toml`]);
//! every key has a documented default so minimal configs stay short.

use std::path::Path;

use crate::error::{Error, Result};
use crate::free_energy::symmetric::FeParams;
use crate::lattice::geometry::Geometry;
use crate::lb::model::LatticeModel;
use crate::targetdp::tlp::{Schedule, TlpPool};
use crate::targetdp::{HostTarget, Target, XlaTarget};
use crate::util::toml::{parse, Section};

/// Which transport carries a decomposed run (the `[target] transport`
/// knob / `--transport` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportMode {
    /// In-process: one rank thread per slab, frames through channels
    /// (`comms::ChannelTransport`). The default.
    Channel,
    /// Multi-process: one rank OS process per slab, frames over TCP
    /// (`comms::SocketTransport`). Without `rank_server` the driver
    /// spawns the rank processes locally on loopback; with it, the
    /// driver listens there and the operator starts
    /// `targetdp rank --connect host:port` on each host.
    Socket,
    /// Hybrid: one OS process **per host** carrying all of that host's
    /// ranks as resident threads (`comms::HybridTransport`) — co-hosted
    /// neighbours exchange frames over in-process channels, only
    /// cross-host links use sockets (one TCP stream per host pair).
    /// Without `rank_server` the driver spawns a single local host
    /// process carrying every rank; with it, the operator starts
    /// `targetdp rank --connect host:port --local-ranks N` per host.
    Hybrid,
}

/// How a decomposed run computes per-block observables (the `[target]
/// observables` knob / `--observables` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObservablesMode {
    /// Distributed reduction: every rank sums its own interior, only the
    /// O(ranks) partial sums travel (the `MPI_Allreduce` shape).
    Reduced,
    /// Gather the full state each block and reduce it in one sweep.
    Gather,
}

/// Complete run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub simulation: SimulationCfg,
    pub target: TargetCfg,
    pub free_energy: FeParams,
    pub output: OutputCfg,
    pub fault: FaultCfg,
}

#[derive(Debug, Clone)]
pub struct SimulationCfg {
    /// "d3q19" or "d2q9".
    pub lattice: String,
    pub lx: usize,
    pub ly: usize,
    pub lz: usize,
    pub steps: u64,
    /// Initial condition: "spinodal" or "droplet".
    pub init: String,
    pub noise: f64,
    pub seed: u64,
    /// Droplet radius (init = "droplet").
    pub radius: f64,
}

#[derive(Debug, Clone)]
pub struct TargetCfg {
    /// "host-simd", "host-scalar" or "xla".
    pub backend: String,
    pub vvl: usize,
    /// 0 = autodetect.
    pub threads: usize,
    /// "static" or "dynamic".
    pub schedule: String,
    /// dynamic-schedule batch size.
    pub batch: usize,
    /// Use the fused `FullStep`/`MultiStep` tiers when the target has them
    /// (`false` forces the unfused 5-kernel pipeline).
    pub fusion: bool,
    /// Host MultiStep blocked depth: 0 = auto (the target's cache
    /// heuristic decides, and may leave the tier off), k > 0 forces k
    /// fused timesteps per launch.
    pub multi_step: u64,
    /// Preferred Pallas block for the xla backend (0 = any).
    pub xla_vvl_block: usize,
    /// Concurrent slab ranks above the target level (the comms
    /// subsystem). 1 = single-domain through the engine; > 1 decomposes
    /// along x and runs one rank thread per slab (host backends only —
    /// `threads` then becomes the *total* TLP budget shared by the ranks).
    pub ranks: usize,
    /// Overlap halo exchange with interior compute when `ranks > 1`
    /// (`false` = bulk-synchronous reference schedule; same results).
    pub overlap: bool,
    /// Communication-avoiding super-step depth for a decomposed run:
    /// each rank exchanges a depth-`2k` ghost block once per `k` steps
    /// and advances the `k` steps locally (trapezoid-blocked, like the
    /// host `MultiStep` tier). 1 = classic one-exchange-per-step; 0 =
    /// auto (the same cache heuristic as `multi_step`, resolved
    /// deterministically so socket ranks agree with the driver).
    pub comms_depth: u64,
    /// Pin the TLP worker threads of each rank's pool to cores
    /// (round-robin `sched_setaffinity`, rank-major; Linux only, a no-op
    /// elsewhere). Off by default.
    pub pin_threads: bool,
    /// How a decomposed (`ranks > 1`) run computes per-block observables:
    /// `"reduced"` (default) combines distributed per-rank partial sums —
    /// no global state moves between logging blocks; `"gather"` pulls the
    /// full state back every block and reduces it in one sweep (the
    /// bit-exact match for the single-engine path, at O(state) cost per
    /// block).
    pub observables: String,
    /// Transport for a decomposed run: `"channel"` (default — one rank
    /// thread per slab, in-process), `"socket"` (one rank OS process
    /// per slab over TCP) or `"hybrid"` (one OS process per host;
    /// channel links inside, sockets between — bit-identical physics
    /// all three ways).
    pub transport: String,
    /// Socket/hybrid mode only: `host:port` the driver's rank server
    /// listens on for manually started ranks (`targetdp rank --connect
    /// host:port` on each host, plus `--local-ranks N` in hybrid mode).
    /// Empty (default) = spawn the rank (or host) processes locally on
    /// an ephemeral loopback port.
    pub rank_server: String,
    /// Rank grid for a decomposed run: `"px,py,pz"` with
    /// `px·py·pz = ranks` splits the lattice over a 3D Cartesian grid
    /// (each rank exchanges axis-tagged faces with its 6 neighbours).
    /// Empty (default) = auto: the factorisation of `ranks` that
    /// minimises halo surface — unless `comms_depth > 1`, whose
    /// x-blocked trapezoid recurrence needs the slab grid `(ranks,1,1)`.
    pub grid: String,
}

impl Default for TargetCfg {
    fn default() -> Self {
        TargetCfg {
            backend: "host-simd".into(),
            vvl: 8,
            threads: 1,
            schedule: "static".into(),
            batch: 4,
            fusion: true,
            multi_step: 0,
            xla_vvl_block: 0,
            ranks: 1,
            overlap: true,
            comms_depth: 1,
            pin_threads: false,
            observables: "reduced".into(),
            transport: "channel".into(),
            rank_server: String::new(),
            grid: String::new(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct OutputCfg {
    /// Log observables every N steps (0 = only at the end).
    pub every: u64,
    /// Output directory for CSV/VTK ("" = no files).
    pub dir: String,
    /// Dump a phi VTK snapshot at the end.
    pub vtk: bool,
    /// Write a Chrome `trace_event` JSON timeline here at the end of a
    /// decomposed run ("" = tracing off, the default). Setting it arms
    /// the per-rank span recorders (`comms::CommsConfig::trace`); open
    /// the file in `chrome://tracing` / Perfetto — one process row per
    /// rank, one thread row per TLP worker.
    pub trace_out: String,
    /// Write a machine-readable JSON run report here at the end of a
    /// decomposed run ("" = off): config echo + per-rank counters
    /// (per-axis halo traffic, super-steps, phase-time histogram, MLUPS,
    /// wait fraction). Also arms the span recorders — the phase
    /// histogram is computed from the shipped spans.
    pub report_json: String,
    /// Print a one-line progress heartbeat (`step/total, mlups, max
    /// wait%`) from the driver at most every N seconds between logging
    /// blocks of a decomposed run (0 = off, the default).
    pub heartbeat: u64,
    /// Write a checkpoint ([`crate::comms::checkpoint`], the `TDPK`
    /// encoding) every N **logging blocks** of a decomposed run (0 = off,
    /// the default). Snapshots are decomposition-independent: restore
    /// into any rank count, grid, transport or comms depth and finish
    /// bitwise identical to an uninterrupted run.
    pub checkpoint_every: u64,
    /// Checkpoint file path ("" = `<dir>/checkpoint.tdpk`, falling back
    /// to `checkpoint.tdpk` in the working directory when `dir` is empty
    /// too). Each write replaces the previous snapshot atomically
    /// (tmp-file + rename).
    pub checkpoint_out: String,
    /// Resume from this checkpoint file instead of the `[simulation]`
    /// initial condition ("" = fresh start). The lattice dims and model
    /// must match the config; the run continues from the recorded step.
    pub restore: String,
}

impl Default for OutputCfg {
    fn default() -> Self {
        OutputCfg {
            every: 50,
            dir: String::new(),
            vtk: false,
            trace_out: String::new(),
            report_json: String::new(),
            heartbeat: 0,
            checkpoint_every: 0,
            checkpoint_out: String::new(),
            restore: String::new(),
        }
    }
}

/// Fault injection + supervised-recovery knobs (the `[fault]` section).
///
/// The kill trio arms a **deterministic** fault: rank `kill_rank` dies
/// with a named error at step `kill_step` (counted from the start of the
/// current world incarnation), at the point chosen by `kill_point`. The
/// knobs ride the TOML round trip, so socket/hybrid rank processes arm
/// the same fault from the rendezvous payload. The recovery knobs drive
/// the supervised driver loop in [`crate::coordinator`]: a world error is
/// retried from the last checkpoint up to `max_restarts` times.
#[derive(Debug, Clone)]
pub struct FaultCfg {
    /// Rank index to kill (ignored while `kill_step` is 0).
    pub kill_rank: u64,
    /// Step at which the fault fires; 0 = fault injection off (the
    /// default). Counted within the current world incarnation, so after
    /// a restart a non-`kill_repeat` fault is disarmed by the driver.
    pub kill_step: u64,
    /// Where within the step the rank dies: `"step"` (at the start of
    /// the step or super-step), `"mid"` (mid-exchange, after the halo
    /// sends are posted) or `"barrier"` (at the command barrier between
    /// logging blocks).
    pub kill_point: String,
    /// Keep the fault armed across supervised restarts (every
    /// incarnation dies — for retry-exhaustion tests). Default false:
    /// the driver disarms the fault after the first death.
    pub kill_repeat: bool,
    /// Supervised restarts: on a world error the driver tears the world
    /// down and relaunches from the last checkpoint up to this many
    /// times (0 = unsupervised, the error surfaces immediately).
    pub max_restarts: u64,
    /// Sleep `backoff_ms * attempt` milliseconds before each relaunch.
    pub backoff_ms: u64,
    /// Elastic recovery: relaunch with this many ranks instead of
    /// `[target] ranks` (0 = same rank count). The rank grid is
    /// re-resolved (`CartDecomposition::auto_grid`), which is sound
    /// because checkpoints are decomposition-independent.
    pub retry_ranks: u64,
    /// Rank receive timeout in seconds (0 = the 120 s default). A dead
    /// neighbour is detected no later than this, so fault tests shrink
    /// it to keep recovery fast.
    pub wait_timeout_s: u64,
}

impl Default for FaultCfg {
    fn default() -> Self {
        FaultCfg {
            kill_rank: 0,
            kill_step: 0,
            kill_point: "step".into(),
            kill_repeat: false,
            max_restarts: 0,
            backoff_ms: 100,
            retry_ranks: 0,
            wait_timeout_s: 0,
        }
    }
}

impl Config {
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml_str(&text)
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = parse(text)?;

        let sim = Section::of(&doc, "simulation");
        if sim.0.is_none() {
            return Err(Error::Parse("missing [simulation] section".into()));
        }
        let simulation = SimulationCfg {
            lattice: sim.require_str("lattice")?,
            lx: sim.require_usize("lx")?,
            ly: sim.require_usize("ly")?,
            lz: sim.require_usize("lz")?,
            steps: sim.u64_or("steps", 100)?,
            init: sim.str_or("init", "spinodal")?,
            noise: sim.f64_or("noise", 0.05)?,
            seed: sim.u64_or("seed", 1234)?,
            radius: sim.f64_or("radius", 8.0)?,
        };

        let tgt = Section::of(&doc, "target");
        let dt = TargetCfg::default();
        let target = TargetCfg {
            backend: tgt.str_or("backend", &dt.backend)?,
            vvl: tgt.usize_or("vvl", dt.vvl)?,
            threads: tgt.usize_or("threads", dt.threads)?,
            schedule: tgt.str_or("schedule", &dt.schedule)?,
            batch: tgt.usize_or("batch", dt.batch)?,
            fusion: tgt.bool_or("fusion", dt.fusion)?,
            multi_step: tgt.u64_or("multi_step", dt.multi_step)?,
            xla_vvl_block: tgt.usize_or("xla_vvl_block", 0)?,
            ranks: tgt.usize_or("ranks", dt.ranks)?,
            overlap: tgt.bool_or("overlap", dt.overlap)?,
            comms_depth: tgt.u64_or("comms_depth", dt.comms_depth)?,
            pin_threads: tgt.bool_or("pin_threads", dt.pin_threads)?,
            observables: tgt.str_or("observables", &dt.observables)?,
            transport: tgt.str_or("transport", &dt.transport)?,
            rank_server: tgt.str_or("rank_server", &dt.rank_server)?,
            grid: tgt.str_or("grid", &dt.grid)?,
        };

        let fe = Section::of(&doc, "free_energy");
        let dp = FeParams::default();
        let free_energy = FeParams {
            a: fe.f64_or("a", dp.a)?,
            b: fe.f64_or("b", dp.b)?,
            kappa: fe.f64_or("kappa", dp.kappa)?,
            gamma: fe.f64_or("gamma", dp.gamma)?,
            tau_f: fe.f64_or("tau_f", dp.tau_f)?,
            tau_g: fe.f64_or("tau_g", dp.tau_g)?,
        };

        let out = Section::of(&doc, "output");
        let output = OutputCfg {
            every: out.u64_or("every", 50)?,
            dir: out.str_or("dir", "")?,
            vtk: out.bool_or("vtk", false)?,
            trace_out: out.str_or("trace_out", "")?,
            report_json: out.str_or("report_json", "")?,
            heartbeat: out.u64_or("heartbeat", 0)?,
            checkpoint_every: out.u64_or("checkpoint_every", 0)?,
            checkpoint_out: out.str_or("checkpoint_out", "")?,
            restore: out.str_or("restore", "")?,
        };

        let flt = Section::of(&doc, "fault");
        let df = FaultCfg::default();
        let fault = FaultCfg {
            kill_rank: flt.u64_or("kill_rank", df.kill_rank)?,
            kill_step: flt.u64_or("kill_step", df.kill_step)?,
            kill_point: flt.str_or("kill_point", &df.kill_point)?,
            kill_repeat: flt.bool_or("kill_repeat", df.kill_repeat)?,
            max_restarts: flt.u64_or("max_restarts", df.max_restarts)?,
            backoff_ms: flt.u64_or("backoff_ms", df.backoff_ms)?,
            retry_ranks: flt.u64_or("retry_ranks", df.retry_ranks)?,
            wait_timeout_s: flt.u64_or("wait_timeout_s",
                                       df.wait_timeout_s)?,
        };

        Ok(Config { simulation, target, free_energy, output, fault })
    }

    pub fn geometry(&self) -> Geometry {
        Geometry::new(self.simulation.lx, self.simulation.ly,
                      self.simulation.lz)
    }

    pub fn model(&self) -> Result<LatticeModel> {
        LatticeModel::from_name(&self.simulation.lattice).ok_or_else(|| {
            Error::Parse(format!(
                "unknown lattice {:?} (want d3q19 or d2q9)",
                self.simulation.lattice
            ))
        })
    }

    /// Transport for a decomposed run.
    pub fn transport_mode(&self) -> Result<TransportMode> {
        match self.target.transport.as_str() {
            "channel" => Ok(TransportMode::Channel),
            "socket" => Ok(TransportMode::Socket),
            "hybrid" => Ok(TransportMode::Hybrid),
            other => Err(Error::Parse(format!(
                "unknown transport {other:?} (want \"channel\", \
                 \"socket\" or \"hybrid\")"
            ))),
        }
    }

    /// Serialize back to the TOML subset [`Config::from_toml_str`] reads
    /// — byte-exact round-trip of every knob. This is how a socket run
    /// ships its configuration to the rank processes: the driver
    /// broadcasts this string in the rendezvous `Welcome`, and every
    /// rank rebuilds an identical (deterministic) simulation from it, so
    /// there is exactly one source of truth per run. Floats use the
    /// shortest representation that round-trips the f64 bits; strings
    /// must not contain `"` (the TOML subset has no escapes).
    pub fn to_toml_string(&self) -> String {
        let s = &self.simulation;
        let t = &self.target;
        let fe = &self.free_energy;
        let o = &self.output;
        let fl = &self.fault;
        format!(
            "[simulation]\n\
             lattice = \"{}\"\n\
             lx = {}\nly = {}\nlz = {}\n\
             steps = {}\n\
             init = \"{}\"\n\
             noise = {:?}\nseed = {}\nradius = {:?}\n\
             \n[target]\n\
             backend = \"{}\"\n\
             vvl = {}\nthreads = {}\n\
             schedule = \"{}\"\nbatch = {}\n\
             fusion = {}\nmulti_step = {}\nxla_vvl_block = {}\n\
             ranks = {}\noverlap = {}\n\
             comms_depth = {}\npin_threads = {}\n\
             observables = \"{}\"\n\
             transport = \"{}\"\nrank_server = \"{}\"\n\
             grid = \"{}\"\n\
             \n[free_energy]\n\
             a = {:?}\nb = {:?}\nkappa = {:?}\ngamma = {:?}\n\
             tau_f = {:?}\ntau_g = {:?}\n\
             \n[output]\n\
             every = {}\ndir = \"{}\"\nvtk = {}\n\
             trace_out = \"{}\"\nreport_json = \"{}\"\nheartbeat = {}\n\
             checkpoint_every = {}\ncheckpoint_out = \"{}\"\n\
             restore = \"{}\"\n\
             \n[fault]\n\
             kill_rank = {}\nkill_step = {}\nkill_point = \"{}\"\n\
             kill_repeat = {}\nmax_restarts = {}\nbackoff_ms = {}\n\
             retry_ranks = {}\nwait_timeout_s = {}\n",
            s.lattice, s.lx, s.ly, s.lz, s.steps, s.init, s.noise, s.seed,
            s.radius, t.backend, t.vvl, t.threads, t.schedule, t.batch,
            t.fusion, t.multi_step, t.xla_vvl_block, t.ranks, t.overlap,
            t.comms_depth, t.pin_threads,
            t.observables, t.transport, t.rank_server, t.grid, fe.a, fe.b,
            fe.kappa, fe.gamma, fe.tau_f, fe.tau_g, o.every, o.dir, o.vtk,
            o.trace_out, o.report_json, o.heartbeat, o.checkpoint_every,
            o.checkpoint_out, o.restore, fl.kill_rank, fl.kill_step,
            fl.kill_point, fl.kill_repeat, fl.max_restarts, fl.backoff_ms,
            fl.retry_ranks, fl.wait_timeout_s,
        )
    }

    /// Per-block observables strategy for a decomposed run.
    pub fn observables_mode(&self) -> Result<ObservablesMode> {
        match self.target.observables.as_str() {
            "reduced" => Ok(ObservablesMode::Reduced),
            "gather" => Ok(ObservablesMode::Gather),
            other => Err(Error::Parse(format!(
                "unknown observables mode {other:?} (want \"reduced\" or \
                 \"gather\")"
            ))),
        }
    }

    /// The rank grid for a decomposed run, resolved from the `grid`
    /// knob. Explicit `"px,py,pz"` is validated against `ranks`; empty
    /// (auto) picks the slab grid when the resolved super-step `depth`
    /// demands it and the minimal-halo-surface factorisation
    /// ([`crate::lattice::decomp::CartDecomposition::auto_grid`])
    /// otherwise. Deterministic: socket rank processes parse the same
    /// shipped TOML and resolve the same grid as the driver.
    pub fn comms_grid(&self, depth: usize) -> Result<[usize; 3]> {
        let ranks = self.target.ranks;
        let spec = self.target.grid.trim();
        if spec.is_empty() {
            if depth > 1 {
                // the trapezoid recurrence is x-blocked: slab only
                return Ok([ranks, 1, 1]);
            }
            return Ok(crate::lattice::decomp::CartDecomposition::auto_grid(
                &self.geometry(),
                ranks,
            ));
        }
        let parts: Vec<usize> = spec
            .split(',')
            .map(|p| p.trim().parse::<usize>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|_| {
                Error::Parse(format!(
                    "grid {spec:?} is not \"px,py,pz\" (three positive \
                     integers)"
                ))
            })?;
        if parts.len() != 3 || parts.contains(&0) {
            return Err(Error::Parse(format!(
                "grid {spec:?} is not \"px,py,pz\" (three positive \
                 integers)"
            )));
        }
        let grid = [parts[0], parts[1], parts[2]];
        if grid.iter().product::<usize>() != ranks {
            return Err(Error::Parse(format!(
                "grid {}x{}x{} needs {} ranks, but ranks = {ranks}",
                grid[0],
                grid[1],
                grid[2],
                grid.iter().product::<usize>(),
            )));
        }
        Ok(grid)
    }

    /// Comms-layer knobs for a decomposed (`ranks > 1`) run. The rank
    /// world drives the host kernels directly, so the backend must be a
    /// host one; `threads` is handed over as the total TLP budget the
    /// ranks share. `comms_depth = 0` (auto) is resolved **here**, by the
    /// deterministic [`crate::targetdp::host::comms_depth_plan`] cache
    /// heuristic — the driver and every socket rank process parse the
    /// same shipped TOML, so all of them resolve the same depth. The
    /// rank grid is resolved after it ([`Config::comms_grid`]): a
    /// super-step depth > 1 pins the auto grid to the slab.
    pub fn comms_config(&self) -> Result<crate::comms::CommsConfig> {
        use crate::targetdp::host::{comms_depth_plan,
                                    MULTI_STEP_CACHE_BYTES};
        match self.target.backend.as_str() {
            "host-simd" | "host-scalar" => {
                let depth = if self.target.comms_depth == 0 {
                    comms_depth_plan(&self.geometry(), self.model()?,
                                     self.target.ranks,
                                     MULTI_STEP_CACHE_BYTES)
                } else {
                    self.target.comms_depth as usize
                };
                let grid = self.comms_grid(depth)?;
                Ok(crate::comms::CommsConfig {
                    ranks: self.target.ranks,
                    overlap: self.target.overlap,
                    threads: self.target.threads,
                    vvl: self.target.vvl,
                    scalar: self.target.backend == "host-scalar",
                    schedule: match self.target.schedule.as_str() {
                        "dynamic" => Schedule::Dynamic {
                            batch: self.target.batch,
                        },
                        _ => Schedule::Static,
                    },
                    depth,
                    grid,
                    pin: self.target.pin_threads,
                    // either telemetry sink arms the span recorders: the
                    // trace file consumes the spans directly, the JSON
                    // report builds its phase histogram from them
                    trace: !self.output.trace_out.is_empty()
                        || !self.output.report_json.is_empty(),
                    fault: self.fault_spec()?,
                    wait_timeout: std::time::Duration::from_secs(
                        if self.fault.wait_timeout_s == 0 {
                            120
                        } else {
                            self.fault.wait_timeout_s
                        },
                    ),
                })
            }
            other => Err(Error::Parse(format!(
                "ranks > 1 needs a host backend (the comms ranks run the \
                 host kernels), got {other:?}"
            ))),
        }
    }

    /// The armed fault, if any (`kill_step` 0 = fault injection off).
    /// Validated here so every process — driver and rendezvoused rank
    /// processes alike — rejects a bad spec the same way.
    pub fn fault_spec(&self) -> Result<Option<crate::comms::FaultSpec>> {
        use crate::comms::{FaultPoint, FaultSpec};
        if self.fault.kill_step == 0 {
            return Ok(None);
        }
        if self.fault.kill_rank as usize >= self.target.ranks {
            return Err(Error::Parse(format!(
                "fault: kill_rank = {} but the world has {} rank(s)",
                self.fault.kill_rank, self.target.ranks,
            )));
        }
        let point = match self.fault.kill_point.as_str() {
            "step" => FaultPoint::Step,
            "mid" => FaultPoint::Mid,
            "barrier" => FaultPoint::Barrier,
            other => {
                return Err(Error::Parse(format!(
                    "fault: unknown kill_point {other:?} (want \"step\", \
                     \"mid\" or \"barrier\")"
                )))
            }
        };
        Ok(Some(FaultSpec {
            rank: self.fault.kill_rank as usize,
            step: self.fault.kill_step,
            point,
        }))
    }

    pub fn tlp_pool(&self) -> TlpPool {
        let threads = if self.target.threads == 0 {
            crate::targetdp::tlp::default_threads()
        } else {
            self.target.threads
        };
        let schedule = match self.target.schedule.as_str() {
            "dynamic" => Schedule::Dynamic { batch: self.target.batch },
            _ => Schedule::Static,
        };
        TlpPool::new(threads, schedule)
    }

    /// Instantiate the configured execution target.
    pub fn build_target(&self) -> Result<Box<dyn Target>> {
        use crate::targetdp::constant::Constant;
        match self.target.backend.as_str() {
            "host-simd" | "host-scalar" => {
                let mut t = if self.target.backend == "host-simd" {
                    HostTarget::simd(self.target.vvl, self.tlp_pool())?
                } else {
                    HostTarget::scalar(self.tlp_pool())
                };
                if self.target.multi_step > 0 {
                    t.copy_constant(
                        "multi_step",
                        Constant::Int(self.target.multi_step as i64),
                    )?;
                }
                Ok(Box::new(t))
            }
            "xla" => {
                if self.target.multi_step > 0 {
                    return Err(Error::Parse(
                        "multi_step is a host-backend knob; the xla \
                         MultiStep width is baked into the AOT artifact \
                         (re-run `make artifacts` to change it)"
                            .into(),
                    ));
                }
                let mut t = XlaTarget::from_default_artifacts()?;
                if self.target.xla_vvl_block > 0 {
                    use crate::targetdp::Target as _;
                    t.copy_constant(
                        "xla_vvl_block",
                        Constant::Int(self.target.xla_vvl_block as i64),
                    )?;
                }
                Ok(Box::new(t))
            }
            other => Err(Error::Parse(format!(
                "unknown backend {other:?} (want host-simd, host-scalar \
                 or xla)"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        [simulation]
        lattice = "d3q19"
        lx = 16
        ly = 16
        lz = 16
        steps = 100

        [target]
        backend = "host-simd"
        vvl = 8

        [free_energy]
        a = -0.0625
        b = 0.0625
        kappa = 0.04
        gamma = 1.0
        tau_f = 1.0
        tau_g = 0.8
    "#;

    #[test]
    fn parses_sample_config() {
        let cfg = Config::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.simulation.steps, 100);
        assert_eq!(cfg.simulation.init, "spinodal");
        assert_eq!(cfg.target.vvl, 8);
        assert_eq!(cfg.geometry().nsites(), 4096);
        assert!(cfg.model().is_ok());
        assert_eq!(cfg.free_energy, FeParams::default());
    }

    #[test]
    fn defaults_fill_missing_sections() {
        let cfg = Config::from_toml_str(
            "[simulation]\nlattice = \"d2q9\"\nlx = 8\nly = 8\nlz = 1\n\
             steps = 5\n",
        )
        .unwrap();
        assert_eq!(cfg.target.backend, "host-simd");
        assert_eq!(cfg.output.every, 50);
        assert_eq!(cfg.free_energy, FeParams::default());
    }

    #[test]
    fn missing_simulation_section_rejected() {
        assert!(Config::from_toml_str("[target]\nvvl = 8\n").is_err());
    }

    #[test]
    fn bad_lattice_and_backend_rejected() {
        let mut cfg = Config::from_toml_str(SAMPLE).unwrap();
        cfg.simulation.lattice = "d5q99".into();
        assert!(cfg.model().is_err());
        cfg.target.backend = "tpu".into();
        assert!(cfg.build_target().is_err());
    }

    #[test]
    fn builds_host_targets() {
        let cfg = Config::from_toml_str(SAMPLE).unwrap();
        let t = cfg.build_target().unwrap();
        assert_eq!(t.describe(), "host-simd(vvl=8,threads=1)");
    }

    #[test]
    fn fusion_defaults_on_and_parses_off() {
        let cfg = Config::from_toml_str(SAMPLE).unwrap();
        assert!(cfg.target.fusion);
        let cfg = Config::from_toml_str(
            "[simulation]\nlattice = \"d2q9\"\nlx = 8\nly = 8\nlz = 1\n\
             steps = 5\n\n[target]\nfusion = false\n",
        )
        .unwrap();
        assert!(!cfg.target.fusion);
    }

    #[test]
    fn multi_step_knob_defaults_auto_and_reaches_target() {
        let cfg = Config::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.target.multi_step, 0, "default is auto");

        let mut forced = cfg.clone();
        forced.target.multi_step = 3;
        let t = forced.build_target().unwrap();
        // the knob lands in the target's constant table and pins the
        // blocked depth for any geometry
        assert_eq!(t.multi_step_width(&forced.geometry(),
                                      forced.model().unwrap()),
                   Some(3));
        // auto on the 16^3 sample lattice: heuristic leaves the tier off
        let t = cfg.build_target().unwrap();
        assert_eq!(t.multi_step_width(&cfg.geometry(),
                                      cfg.model().unwrap()),
                   None);
        // host-only knob: forcing it with the xla backend is an error,
        // not a silent no-op (the artifact bakes the width)
        forced.target.backend = "xla".into();
        let err = forced.build_target().unwrap_err();
        assert!(err.to_string().contains("multi_step"), "{err}");
    }

    #[test]
    fn ranks_and_overlap_knobs() {
        let cfg = Config::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.target.ranks, 1, "default is single-domain");
        assert!(cfg.target.overlap, "overlap defaults on");

        let cfg = Config::from_toml_str(
            "[simulation]\nlattice = \"d2q9\"\nlx = 8\nly = 8\nlz = 1\n\
             steps = 5\n\n[target]\nranks = 4\noverlap = false\n\
             threads = 8\nschedule = \"dynamic\"\nbatch = 2\n",
        )
        .unwrap();
        assert_eq!(cfg.target.ranks, 4);
        assert!(!cfg.target.overlap);
        let cc = cfg.comms_config().unwrap();
        assert_eq!(cc.ranks, 4);
        assert!(!cc.overlap);
        assert_eq!(cc.threads, 8);
        assert!(!cc.scalar);
        // the schedule knob reaches the rank pools, same as tlp_pool()
        assert!(matches!(cc.schedule,
                         Schedule::Dynamic { batch } if batch == 2));

        // the comms ranks drive host kernels; xla cannot back them
        let mut xla = cfg.clone();
        xla.target.backend = "xla".into();
        assert!(xla.comms_config().is_err());
        let mut scalar = cfg;
        scalar.target.backend = "host-scalar".into();
        assert!(scalar.comms_config().unwrap().scalar);
    }

    #[test]
    fn comms_depth_knob_defaults_and_auto_resolves() {
        let cfg = Config::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.target.comms_depth, 1,
                   "classic one-exchange-per-step is the default");
        assert!(!cfg.target.pin_threads, "pinning is opt-in");
        assert_eq!(cfg.comms_config().unwrap().depth, 1);
        assert!(!cfg.comms_config().unwrap().pin);

        // 0 = auto: resolved here by the deterministic cache heuristic,
        // never handed to the world raw (the world rejects depth 0)
        let mut auto = cfg.clone();
        auto.target.ranks = 4;
        auto.target.comms_depth = 0;
        // 16^3 d3q19 over 4 ranks: 4-plane slabs fit a depth-2
        // super-step (ghost-extended slab within the cache budget)
        assert_eq!(auto.comms_config().unwrap().depth, 2);

        let mut forced = cfg.clone();
        forced.target.comms_depth = 4;
        forced.target.pin_threads = true;
        let cc = forced.comms_config().unwrap();
        assert_eq!(cc.depth, 4);
        assert!(cc.pin);
    }

    #[test]
    fn grid_knob_parses_autosizes_and_rejects() {
        let cfg = Config::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.target.grid, "", "auto grid is the default");

        // explicit grid reaches the comms config, product-checked
        let cfg = Config::from_toml_str(
            "[simulation]\nlattice = \"d3q19\"\nlx = 16\nly = 16\n\
             lz = 16\nsteps = 5\n\n[target]\nranks = 4\n\
             grid = \"2,2,1\"\n",
        )
        .unwrap();
        assert_eq!(cfg.comms_config().unwrap().grid, [2, 2, 1]);

        // auto follows the surface-minimizing factorisation
        let mut auto = cfg.clone();
        auto.target.grid = String::new();
        let want = crate::lattice::decomp::CartDecomposition::auto_grid(
            &auto.geometry(),
            auto.target.ranks,
        );
        assert_eq!(auto.comms_config().unwrap().grid, want);

        // auto + super-step depth > 1: pinned to the slab (the
        // trapezoid recurrence is x-blocked)
        let mut deep = auto.clone();
        deep.target.comms_depth = 2;
        assert_eq!(deep.comms_config().unwrap().grid, [4, 1, 1]);

        // product mismatch and malformed specs are config errors
        let mut bad = cfg.clone();
        bad.target.grid = "2,2,2".into();
        let err = bad.comms_config().unwrap_err();
        assert!(err.to_string().contains("8 ranks"), "{err}");
        bad.target.grid = "2,2".into();
        assert!(bad.comms_config().is_err());
        bad.target.grid = "2,0,2".into();
        assert!(bad.comms_config().is_err());
        bad.target.grid = "a,b,c".into();
        assert!(bad.comms_config().is_err());
    }

    #[test]
    fn observables_knob_parses_and_rejects() {
        let cfg = Config::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.target.observables, "reduced",
                   "distributed reductions are the default");
        assert_eq!(cfg.observables_mode().unwrap(),
                   ObservablesMode::Reduced);

        let cfg = Config::from_toml_str(
            "[simulation]\nlattice = \"d2q9\"\nlx = 8\nly = 8\nlz = 1\n\
             steps = 5\n\n[target]\nobservables = \"gather\"\n",
        )
        .unwrap();
        assert_eq!(cfg.observables_mode().unwrap(),
                   ObservablesMode::Gather);

        let mut bad = cfg;
        bad.target.observables = "telepathy".into();
        assert!(bad.observables_mode().is_err());
    }

    #[test]
    fn transport_knob_parses_and_rejects() {
        let cfg = Config::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.target.transport, "channel",
                   "in-process threads are the default");
        assert_eq!(cfg.transport_mode().unwrap(), TransportMode::Channel);
        assert_eq!(cfg.target.rank_server, "", "spawn-local by default");

        let cfg = Config::from_toml_str(
            "[simulation]\nlattice = \"d2q9\"\nlx = 8\nly = 8\nlz = 1\n\
             steps = 5\n\n[target]\nranks = 2\ntransport = \"socket\"\n\
             rank_server = \"0.0.0.0:7777\"\n",
        )
        .unwrap();
        assert_eq!(cfg.transport_mode().unwrap(), TransportMode::Socket);
        assert_eq!(cfg.target.rank_server, "0.0.0.0:7777");

        let mut cfg = cfg;
        cfg.target.transport = "hybrid".into();
        assert_eq!(cfg.transport_mode().unwrap(), TransportMode::Hybrid);

        let mut bad = cfg;
        bad.target.transport = "carrier-pigeon".into();
        assert!(bad.transport_mode().is_err());
    }

    #[test]
    fn toml_round_trip_is_lossless() {
        // the serialized form is what a socket driver ships to its rank
        // processes: every knob must survive, floats bit-exactly
        let mut cfg = Config::from_toml_str(SAMPLE).unwrap();
        cfg.simulation.noise = 0.07;
        cfg.simulation.init = "droplet".into();
        cfg.simulation.radius = 3.25;
        cfg.target.ranks = 3;
        cfg.target.overlap = false;
        cfg.target.transport = "hybrid".into();
        cfg.target.schedule = "dynamic".into();
        cfg.target.multi_step = 4;
        cfg.target.comms_depth = 2;
        cfg.target.pin_threads = true;
        cfg.target.grid = "3,1,1".into();
        cfg.free_energy.kappa = 1.0 / 3.0; // not exactly representable
        cfg.output.every = 7;
        cfg.output.dir = "out/run1".into();
        cfg.output.vtk = true;
        cfg.output.trace_out = "out/trace.json".into();
        cfg.output.report_json = "out/run.json".into();
        cfg.output.heartbeat = 5;
        cfg.output.checkpoint_every = 2;
        cfg.output.checkpoint_out = "out/ck.tdpk".into();
        cfg.output.restore = "out/prev.tdpk".into();
        cfg.fault.kill_rank = 1;
        cfg.fault.kill_step = 9;
        cfg.fault.kill_point = "mid".into();
        cfg.fault.kill_repeat = true;
        cfg.fault.max_restarts = 3;
        cfg.fault.backoff_ms = 50;
        cfg.fault.retry_ranks = 2;
        cfg.fault.wait_timeout_s = 4;

        let back = Config::from_toml_str(&cfg.to_toml_string()).unwrap();
        assert_eq!(back.simulation.lattice, cfg.simulation.lattice);
        assert_eq!(back.simulation.lx, cfg.simulation.lx);
        assert_eq!(back.simulation.steps, cfg.simulation.steps);
        assert_eq!(back.simulation.init, cfg.simulation.init);
        assert_eq!(back.simulation.noise.to_bits(),
                   cfg.simulation.noise.to_bits());
        assert_eq!(back.simulation.seed, cfg.simulation.seed);
        assert_eq!(back.simulation.radius.to_bits(),
                   cfg.simulation.radius.to_bits());
        assert_eq!(back.target.backend, cfg.target.backend);
        assert_eq!(back.target.vvl, cfg.target.vvl);
        assert_eq!(back.target.threads, cfg.target.threads);
        assert_eq!(back.target.schedule, cfg.target.schedule);
        assert_eq!(back.target.batch, cfg.target.batch);
        assert_eq!(back.target.fusion, cfg.target.fusion);
        assert_eq!(back.target.multi_step, cfg.target.multi_step);
        assert_eq!(back.target.ranks, cfg.target.ranks);
        assert_eq!(back.target.overlap, cfg.target.overlap);
        assert_eq!(back.target.comms_depth, cfg.target.comms_depth);
        assert_eq!(back.target.pin_threads, cfg.target.pin_threads);
        assert_eq!(back.target.observables, cfg.target.observables);
        assert_eq!(back.target.transport, cfg.target.transport);
        assert_eq!(back.target.rank_server, cfg.target.rank_server);
        assert_eq!(back.target.grid, cfg.target.grid);
        assert_eq!(back.free_energy.kappa.to_bits(),
                   cfg.free_energy.kappa.to_bits());
        assert_eq!(back.free_energy, cfg.free_energy);
        assert_eq!(back.output.every, cfg.output.every);
        assert_eq!(back.output.dir, cfg.output.dir);
        assert_eq!(back.output.vtk, cfg.output.vtk);
        assert_eq!(back.output.trace_out, cfg.output.trace_out);
        assert_eq!(back.output.report_json, cfg.output.report_json);
        assert_eq!(back.output.heartbeat, cfg.output.heartbeat);
        assert_eq!(back.output.checkpoint_every,
                   cfg.output.checkpoint_every);
        assert_eq!(back.output.checkpoint_out, cfg.output.checkpoint_out);
        assert_eq!(back.output.restore, cfg.output.restore);
        assert_eq!(back.fault.kill_rank, cfg.fault.kill_rank);
        assert_eq!(back.fault.kill_step, cfg.fault.kill_step);
        assert_eq!(back.fault.kill_point, cfg.fault.kill_point);
        assert_eq!(back.fault.kill_repeat, cfg.fault.kill_repeat);
        assert_eq!(back.fault.max_restarts, cfg.fault.max_restarts);
        assert_eq!(back.fault.backoff_ms, cfg.fault.backoff_ms);
        assert_eq!(back.fault.retry_ranks, cfg.fault.retry_ranks);
        assert_eq!(back.fault.wait_timeout_s, cfg.fault.wait_timeout_s);
    }

    #[test]
    fn fault_knobs_parse_validate_and_reach_comms_config() {
        use crate::comms::FaultPoint;
        let cfg = Config::from_toml_str(SAMPLE).unwrap();
        assert_eq!(cfg.fault.kill_step, 0, "fault injection is opt-in");
        assert!(cfg.fault_spec().unwrap().is_none());

        let cfg = Config::from_toml_str(
            "[simulation]\nlattice = \"d2q9\"\nlx = 8\nly = 8\nlz = 1\n\
             steps = 5\n\n[target]\nranks = 4\n\n[fault]\n\
             kill_rank = 2\nkill_step = 3\nkill_point = \"mid\"\n\
             wait_timeout_s = 2\n",
        )
        .unwrap();
        let spec = cfg.fault_spec().unwrap().unwrap();
        assert_eq!(spec.rank, 2);
        assert_eq!(spec.step, 3);
        assert_eq!(spec.point, FaultPoint::Mid);
        let cc = cfg.comms_config().unwrap();
        assert_eq!(cc.fault, Some(spec));
        assert_eq!(cc.wait_timeout,
                   std::time::Duration::from_secs(2));
        // wait_timeout_s = 0 keeps the 120 s default
        let mut dflt = cfg.clone();
        dflt.fault.wait_timeout_s = 0;
        assert_eq!(dflt.comms_config().unwrap().wait_timeout,
                   std::time::Duration::from_secs(120));

        // out-of-range rank and unknown point are config errors, caught
        // identically by the driver and the rendezvoused rank processes
        let mut bad = cfg.clone();
        bad.fault.kill_rank = 4;
        let err = bad.fault_spec().unwrap_err();
        assert!(err.to_string().contains("kill_rank"), "{err}");
        let mut bad = cfg;
        bad.fault.kill_point = "eventually".into();
        assert!(bad.fault_spec().is_err());
    }

    #[test]
    fn telemetry_knobs_arm_the_comms_trace() {
        let mut cfg = Config::from_toml_str(SAMPLE).unwrap();
        cfg.target.ranks = 2;
        assert!(!cfg.comms_config().unwrap().trace,
                "tracing is off by default");
        cfg.output.trace_out = "trace.json".into();
        assert!(cfg.comms_config().unwrap().trace);
        cfg.output.trace_out.clear();
        cfg.output.report_json = "run.json".into();
        assert!(cfg.comms_config().unwrap().trace,
                "the JSON report's phase histogram needs spans too");
    }

    #[test]
    fn dynamic_schedule_parsed() {
        let mut cfg = Config::from_toml_str(SAMPLE).unwrap();
        cfg.target.schedule = "dynamic".into();
        cfg.target.threads = 3;
        let pool = cfg.tlp_pool();
        assert_eq!(pool.nthreads, 3);
        assert!(matches!(pool.schedule,
                         Schedule::Dynamic { batch } if batch == 4));
    }
}
