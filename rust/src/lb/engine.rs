//! The LB engine: the paper's application written once against the
//! [`Target`] abstraction (section III-C's host-code shape: malloc +
//! copyToTarget + constants + kernel launches + sync + copyFromTarget).
//!
//! Per timestep the engine launches either
//!
//! * the **unfused pipeline** —
//!   1. `PhiMoment`  g -> phi
//!   2. `Gradient`   phi -> grad, lap     (finite differences)
//!   3. `BinaryCollision`                 (the Figure-1 hot spot)
//!   4. `Stream` f and g                  (pull propagation, double-buffered)
//!
//! * the fused `FullStep` — one launch per step. Both the XLA backend
//!   (whole step in one AOT executable) and the host backend (fused
//!   collide→push-stream sweep, see [`crate::targetdp::host`]) support
//!   this tier;
//!
//! * or the `MultiStep` tier — k fused timesteps per launch. On XLA this
//!   is an AOT executable with the step loop unrolled inside; on the host
//!   it is the temporal-blocking sweep of
//!   [`crate::lb::multistep::MultiStepPlan`] (cache-resident x-slabs with
//!   depth-2k halo recompute). A target advertises a usable depth through
//!   [`Target::multi_step_width`]; `run` drains whole k-blocks through it
//!   and lets the remainder fall through to `FullStep` (or the unfused
//!   pipeline) so any step count is served exactly.
//!
//! The engine always prefers the most fused tier available — the paper's
//! single-source promise: the application never changes, the target picks
//! its fastest path. All tiers are bit-identical
//! (`tests/fused_parity.rs`, `tests/multistep_parity.rs`). Use
//! [`LbEngine::set_fusion`] to force the unfused pipeline (parity tests,
//! fused-vs-unfused benches).
//!
//! Observables are reduced **on the target** when it provides `PhiMoment`
//! + `ReduceSum`: only the per-component sums and the 1-component phi
//! field cross the target→host boundary, not the full 19-component f/g
//! state (a 19x smaller transfer).
//!
//! The engine drives **one** target over one lattice. The level above —
//! several concurrent ranks each sweeping a slab, with halo exchange
//! overlapped against interior compute — is [`crate::comms`]; its ranks
//! run the same host kernels the engine's host target dispatches, and its
//! results are bit-identical to this engine's fused `FullStep` path
//! (`tests/comms_parity.rs`).

use crate::error::Result;
use crate::free_energy::symmetric::FeParams;
use crate::lattice::geometry::Geometry;
use crate::lb::model::LatticeModel;
use crate::lb::moments;
use crate::targetdp::constant::Constant;
use crate::targetdp::memory::{BufId, FieldDesc};
use crate::targetdp::target::{KernelId, LaunchArgs, Target};

/// Observable summary of the current state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observables {
    /// Total f mass (conserved by collision and streaming).
    pub mass: f64,
    /// Total velocity-weighted f momentum (conserved).
    pub momentum: [f64; 3],
    /// Total order parameter (conserved).
    pub phi_total: f64,
    /// Variance of phi over sites — grows during spinodal decomposition.
    pub phi_variance: f64,
}

/// Reduce a host-resident SoA state to global [`Observables`] — the
/// engine's fallback for targets without on-target reduction, and the
/// reduction the comms path applies to its gathered global state (the
/// place an `MPI_Allreduce` would sit).
pub fn state_observables(vs: &crate::lb::model::VelSet, f: &[f64],
                         g: &[f64], n: usize) -> Observables {
    let (mass, momentum, phi_total) = moments::totals(vs, f, g, n);
    let mean = phi_total / n as f64;
    let mut var = 0.0;
    for s in 0..n {
        let mut phi = 0.0;
        for i in 0..vs.nvel {
            phi += g[i * n + s];
        }
        var += (phi - mean) * (phi - mean);
    }
    Observables {
        mass,
        momentum,
        phi_total,
        phi_variance: var / n as f64,
    }
}

impl Observables {
    /// Build observables from exact global sums — the distributed
    /// (allreduce-style) path: each comms rank reduces its own interior
    /// and only the partial sums travel. The variance uses the one-pass
    /// identity `var = E[phi^2] - mean^2` (clamped at 0 against a
    /// rounding-negative result), and the mass/momentum/phi sums combine
    /// per-rank partials in rank order. Both choices make the values
    /// deterministic for a fixed decomposition but their summation
    /// *order* differs from the single global sweep of
    /// [`state_observables`] — the two agree to floating-point rounding,
    /// not bitwise (`tests/resident_world.rs` pins the tolerance).
    pub fn from_sums(mass: f64, momentum: [f64; 3], phi_total: f64,
                     phi_sq: f64, nsites: usize) -> Observables {
        let n = nsites as f64;
        let mean = phi_total / n;
        Observables {
            mass,
            momentum,
            phi_total,
            phi_variance: (phi_sq / n - mean * mean).max(0.0),
        }
    }
}

/// Binary-fluid LB simulation bound to one execution target.
pub struct LbEngine<'t> {
    target: &'t mut dyn Target,
    /// Lattice extents.
    pub geom: Geometry,
    /// Velocity-set model (D2Q9 or D3Q19).
    pub model: LatticeModel,
    /// Free-energy sector parameters.
    pub params: FeParams,
    f: BufId,
    g: BufId,
    f_tmp: BufId,
    g_tmp: BufId,
    phi: BufId,
    grad: BufId,
    lap: BufId,
    /// `nvel`-component scratch for on-target `ReduceSum` results.
    reduce: BufId,
    steps_done: u64,
    fusion: bool,
}

impl<'t> LbEngine<'t> {
    /// Bind a simulation to `target`: allocate the state and scratch
    /// buffers on it and upload the free-energy constants.
    pub fn new(target: &'t mut dyn Target, geom: Geometry,
               model: LatticeModel, params: FeParams) -> Result<Self> {
        let n = geom.nsites();
        let nvel = model.velset().nvel;
        let f = target.malloc(&FieldDesc::new("f", nvel, n))?;
        let g = target.malloc(&FieldDesc::new("g", nvel, n))?;
        let f_tmp = target.malloc(&FieldDesc::new("f_tmp", nvel, n))?;
        let g_tmp = target.malloc(&FieldDesc::new("g_tmp", nvel, n))?;
        let phi = target.malloc(&FieldDesc::new("phi", 1, n))?;
        let grad = target.malloc(&FieldDesc::new("grad_phi", 3, n))?;
        let lap = target.malloc(&FieldDesc::new("lap_phi", 1, n))?;
        let reduce = target.malloc(&FieldDesc::new("reduce_out", nvel, 1))?;

        // copyConstant*ToTarget: the free-energy sector parameters
        target.copy_constant("fe_a", Constant::Double(params.a))?;
        target.copy_constant("fe_b", Constant::Double(params.b))?;
        target.copy_constant("fe_kappa", Constant::Double(params.kappa))?;
        target.copy_constant("fe_gamma", Constant::Double(params.gamma))?;
        target.copy_constant("tau_f", Constant::Double(params.tau_f))?;
        target.copy_constant("tau_g", Constant::Double(params.tau_g))?;

        Ok(LbEngine {
            target,
            geom,
            model,
            params,
            f,
            g,
            f_tmp,
            g_tmp,
            phi,
            grad,
            lap,
            reduce,
            steps_done: 0,
            fusion: true,
        })
    }

    /// Enable/disable the fused `FullStep`/`MultiStep` tiers (on by
    /// default). With fusion off the engine always drives the unfused
    /// 5-kernel pipeline — the reference path for parity and benchmarks.
    pub fn set_fusion(&mut self, fusion: bool) {
        self.fusion = fusion;
    }

    /// The fused tier the next `run` will drive, most fused first:
    /// `(MultiStep, k)` when the target has a usable blocked depth for
    /// this geometry/model, else `(FullStep, 1)`, else `None` (unfused
    /// pipeline). This is the single dispatch decision shared by
    /// [`LbEngine::run`] and [`LbEngine::fused_active`].
    pub fn fused_tier(&self) -> Option<(KernelId, u64)> {
        if !self.fusion {
            return None;
        }
        if self.target.supports(KernelId::MultiStep) {
            let k = self
                .target
                .multi_step_width(&self.geom, self.model)
                .unwrap_or(0);
            if k > 0 {
                return Some((KernelId::MultiStep, k));
            }
        }
        if self.target.supports(KernelId::FullStep) {
            return Some((KernelId::FullStep, 1));
        }
        None
    }

    /// True when the next `run` will use a fused kernel (a target may
    /// advertise `MultiStep` yet have no usable width for this
    /// geometry/model — see [`LbEngine::fused_tier`]).
    pub fn fused_active(&self) -> bool {
        self.fused_tier().is_some()
    }

    /// Upload an initial state (SoA `nvel * nsites` each).
    pub fn load_state(&mut self, f: &[f64], g: &[f64]) -> Result<()> {
        self.target.copy_to_target(self.f, f)?;
        self.target.copy_to_target(self.g, g)
    }

    /// Download the current state.
    pub fn fetch_state(&mut self, f: &mut [f64], g: &mut [f64]) -> Result<()> {
        self.target.copy_from_target(self.f, f)?;
        self.target.copy_from_target(self.g, g)
    }

    fn args(&self) -> LaunchArgs {
        LaunchArgs::new(self.geom, self.model)
    }

    /// Bindings for the fused step: f/g plus the double-buffer and moment
    /// scratch the host tier streams through (accelerator targets that
    /// fuse internally simply ignore the extra bindings).
    fn full_step_args(&self) -> LaunchArgs {
        self.args()
            .bind("f", self.f)
            .bind("g", self.g)
            .bind("f_tmp", self.f_tmp)
            .bind("g_tmp", self.g_tmp)
            .bind("phi", self.phi)
            .bind("grad", self.grad)
            .bind("lap", self.lap)
    }

    /// Advance one timestep with the unfused kernel pipeline.
    fn step_unfused(&mut self) -> Result<()> {
        let phi_args = self.args().bind("g", self.g).bind("phi", self.phi);
        let grad_args = self
            .args()
            .bind("phi", self.phi)
            .bind("grad", self.grad)
            .bind("lap", self.lap);
        let coll_args = self
            .args()
            .bind("f", self.f)
            .bind("g", self.g)
            .bind("grad", self.grad)
            .bind("lap", self.lap);
        let stream_f = self.args().bind("src", self.f).bind("dst", self.f_tmp);
        let stream_g = self.args().bind("src", self.g).bind("dst", self.g_tmp);

        self.target.launch(KernelId::PhiMoment, &phi_args)?;
        self.target.launch(KernelId::Gradient, &grad_args)?;
        self.target.launch(KernelId::BinaryCollision, &coll_args)?;
        self.target.launch(KernelId::Stream, &stream_f)?;
        self.target.launch(KernelId::Stream, &stream_g)?;
        std::mem::swap(&mut self.f, &mut self.f_tmp);
        std::mem::swap(&mut self.g, &mut self.g_tmp);
        Ok(())
    }

    /// Advance `nsteps` timesteps, using the most fused kernel the target
    /// supports (unless fusion is disabled).
    pub fn run(&mut self, nsteps: u64) -> Result<()> {
        let mut remaining = nsteps;
        // drain whole k-blocks through the k-step fused kernel; like
        // FullStep it receives the double-buffer + moment scratch
        // bindings (targets that fuse internally ignore the extras)
        if let Some((KernelId::MultiStep, k)) = self.fused_tier() {
            let args = self.full_step_args();
            while remaining >= k {
                self.target.launch(KernelId::MultiStep, &args)?;
                remaining -= k;
                self.steps_done += k;
            }
        }
        // remainder (or everything, without a usable MultiStep): one
        // step at a time, fused when the target has FullStep
        while remaining > 0 {
            if self.fusion && self.target.supports(KernelId::FullStep) {
                self.target
                    .launch(KernelId::FullStep, &self.full_step_args())?;
            } else {
                self.step_unfused()?;
            }
            remaining -= 1;
            self.steps_done += 1;
        }
        self.target.sync()
    }

    /// Timesteps advanced since construction.
    pub fn steps_done(&self) -> u64 {
        self.steps_done
    }

    /// Reduce the state to global observables, on the target when it
    /// provides the kernels (downloads `nvel + nsites` doubles instead of
    /// the full `2 * nvel * nsites` state).
    pub fn observables(&mut self) -> Result<Observables> {
        let vs = self.model.velset();
        let n = self.geom.nsites();

        if self.target.supports(KernelId::PhiMoment)
            && self.target.supports(KernelId::ReduceSum)
        {
            let red_args =
                self.args().bind("field", self.f).bind("result", self.reduce);
            self.target.launch(KernelId::ReduceSum, &red_args)?;
            let mut comp = vec![0.0; vs.nvel];
            self.target.copy_from_target(self.reduce, &mut comp)?;
            let mass: f64 = comp.iter().sum();
            let mut momentum = [0.0f64; 3];
            for i in 0..vs.nvel {
                for (a, m) in momentum.iter_mut().enumerate() {
                    *m += vs.cv[i][a] * comp[i];
                }
            }

            let phi = self.phi_field()?;
            let phi_total: f64 = phi.iter().sum();
            let mean = phi_total / n as f64;
            let var = phi
                .iter()
                .map(|p| (p - mean) * (p - mean))
                .sum::<f64>()
                / n as f64;
            return Ok(Observables {
                mass,
                momentum,
                phi_total,
                phi_variance: var,
            });
        }

        // fallback: download the full state and reduce on the host
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        self.fetch_state(&mut f, &mut g)?;
        Ok(state_observables(vs, &f, &g, n))
    }

    /// Per-site phi field (for IO / analysis), computed on the target when
    /// it has the `PhiMoment` kernel so only `nsites` doubles transfer.
    pub fn phi_field(&mut self) -> Result<Vec<f64>> {
        let vs = self.model.velset();
        let n = self.geom.nsites();
        if self.target.supports(KernelId::PhiMoment) {
            let args = self.args().bind("g", self.g).bind("phi", self.phi);
            self.target.launch(KernelId::PhiMoment, &args)?;
            let mut phi = vec![0.0; n];
            self.target.copy_from_target(self.phi, &mut phi)?;
            return Ok(phi);
        }
        let mut g = vec![0.0; vs.nvel * n];
        self.target.copy_from_target(self.g, &mut g)?;
        let mut phi = vec![0.0; n];
        for s in 0..n {
            for i in 0..vs.nvel {
                phi[s] += g[i * n + s];
            }
        }
        Ok(phi)
    }
}

impl Drop for LbEngine<'_> {
    fn drop(&mut self) {
        for id in [self.f, self.g, self.f_tmp, self.g_tmp, self.phi,
                   self.grad, self.lap, self.reduce] {
            let _ = self.target.free(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lb::init;
    use crate::targetdp::tlp::TlpPool;
    use crate::targetdp::HostTarget;

    fn setup(geom: Geometry) -> (Vec<f64>, Vec<f64>) {
        let vs = LatticeModel::D3Q19.velset();
        let n = geom.nsites();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        init::init_spinodal(vs, &FeParams::default(), &geom, &mut f,
                            &mut g, 0.05, 17);
        (f, g)
    }

    #[test]
    fn state_roundtrip_and_step_count() {
        let geom = Geometry::new(4, 4, 4);
        let (f, g) = setup(geom);
        let mut t = HostTarget::simd(4, TlpPool::serial()).unwrap();
        let mut e = LbEngine::new(&mut t, geom, LatticeModel::D3Q19,
                                  FeParams::default())
            .unwrap();
        assert!(e.fused_active(), "host target now has the fused tier");
        e.load_state(&f, &g).unwrap();
        let mut f2 = vec![0.0; f.len()];
        let mut g2 = vec![0.0; g.len()];
        e.fetch_state(&mut f2, &mut g2).unwrap();
        assert_eq!(f, f2);
        assert_eq!(g, g2);
        e.run(3).unwrap();
        assert_eq!(e.steps_done(), 3);
    }

    #[test]
    fn observables_and_phi_field_consistent() {
        let geom = Geometry::new(4, 4, 4);
        let n = geom.nsites();
        let (f, g) = setup(geom);
        let mut t = HostTarget::simd(4, TlpPool::serial()).unwrap();
        let mut e = LbEngine::new(&mut t, geom, LatticeModel::D3Q19,
                                  FeParams::default())
            .unwrap();
        e.load_state(&f, &g).unwrap();
        let obs = e.observables().unwrap();
        let phi = e.phi_field().unwrap();
        let total: f64 = phi.iter().sum();
        assert!((obs.phi_total - total).abs() < 1e-10);
        let mean = total / n as f64;
        let var: f64 = phi.iter().map(|p| (p - mean) * (p - mean)).sum::<f64>()
            / n as f64;
        assert!((obs.phi_variance - var).abs() < 1e-12);
        assert!((obs.mass - n as f64).abs() < 1e-9);
    }

    #[test]
    fn on_target_observables_match_host_fallback() {
        // the ReduceSum path and the download-everything path must agree
        let geom = Geometry::new(5, 3, 4);
        let (f, g) = setup(geom);
        let mut t = HostTarget::simd(8, TlpPool::serial()).unwrap();
        let mut e = LbEngine::new(&mut t, geom, LatticeModel::D3Q19,
                                  FeParams::default())
            .unwrap();
        e.load_state(&f, &g).unwrap();
        e.run(2).unwrap();
        let on_target = e.observables().unwrap();

        // host-side reference from the downloaded state
        let vs = LatticeModel::D3Q19.velset();
        let n = geom.nsites();
        let mut fh = vec![0.0; vs.nvel * n];
        let mut gh = vec![0.0; vs.nvel * n];
        e.fetch_state(&mut fh, &mut gh).unwrap();
        let (mass, momentum, phi_total) = moments::totals(vs, &fh, &gh, n);
        assert!((on_target.mass - mass).abs() < 1e-10);
        assert!((on_target.phi_total - phi_total).abs() < 1e-10);
        for a in 0..3 {
            assert!((on_target.momentum[a] - momentum[a]).abs() < 1e-10);
        }
    }

    #[test]
    fn zero_steps_is_identity() {
        let geom = Geometry::new(4, 4, 4);
        let (f, g) = setup(geom);
        let mut t = HostTarget::simd(4, TlpPool::serial()).unwrap();
        let mut e = LbEngine::new(&mut t, geom, LatticeModel::D3Q19,
                                  FeParams::default())
            .unwrap();
        e.load_state(&f, &g).unwrap();
        e.run(0).unwrap();
        let mut f2 = vec![0.0; f.len()];
        let mut g2 = vec![0.0; g.len()];
        e.fetch_state(&mut f2, &mut g2).unwrap();
        assert_eq!(f, f2);
        assert_eq!(g, g2);
    }

    #[test]
    fn fusion_toggle_changes_nothing_physical() {
        let geom = Geometry::new(4, 5, 3);
        let (f, g) = setup(geom);
        let run = |fusion: bool| {
            let mut t = HostTarget::simd(8, TlpPool::serial()).unwrap();
            let mut e = LbEngine::new(&mut t, geom, LatticeModel::D3Q19,
                                      FeParams::default())
                .unwrap();
            e.set_fusion(fusion);
            assert_eq!(e.fused_active(), fusion);
            e.load_state(&f, &g).unwrap();
            e.run(4).unwrap();
            let mut fo = vec![0.0; f.len()];
            let mut go = vec![0.0; g.len()];
            e.fetch_state(&mut fo, &mut go).unwrap();
            (fo, go)
        };
        let (ff, gf) = run(true);
        let (fu, gu) = run(false);
        assert_eq!(ff, fu, "fused f must bit-match unfused");
        assert_eq!(gf, gu, "fused g must bit-match unfused");
    }
}
