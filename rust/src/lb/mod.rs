//! The motivating application (paper section II-A): a binary-fluid
//! lattice-Boltzmann engine in the style of Ludwig.
//!
//! The *binary collision* kernel ([`collision`]) is the computational
//! kernel the paper extracts for its Figure-1 benchmark; the rest of the
//! engine (moments, equilibria, propagation, boundaries, initialisation,
//! and the [`engine::LbEngine`] driver that runs everything through a
//! [`crate::targetdp::Target`]) is the substrate it lives in.

pub mod boundary;
pub mod collision;
pub mod engine;
pub mod equilibrium;
pub mod init;
pub mod model;
pub mod moments;
pub mod multistep;
pub mod propagation;

pub use engine::LbEngine;
pub use model::{LatticeModel, VelSet};
