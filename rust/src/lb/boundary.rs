//! Solid boundaries: full-way bounce-back walls.
//!
//! Sites flagged solid act as reflectors: after streaming, every
//! population resident on a solid site is reversed in place
//! (`h_i <-> h_opposite(i)`); the next streaming step carries it back into
//! the fluid. The effective no-slip plane sits half a lattice spacing
//! inside the solid row. Solid sites are excluded from collision
//! ([`restore_solid`] keeps their populations intact across a whole-lattice
//! collision launch, so the collision kernels stay mask-free and
//! data-parallel — the targetDP-friendly formulation).

use crate::lattice::geometry::Geometry;
use crate::lb::model::VelSet;

/// Site classification for boundary handling.
#[derive(Debug, Clone)]
pub struct SolidMask {
    pub solid: Vec<bool>,
}

impl SolidMask {
    pub fn fluid(nsites: usize) -> Self {
        SolidMask { solid: vec![false; nsites] }
    }

    /// Walls at y = 0 and y = ly-1 (the Poiseuille channel).
    pub fn channel_walls_y(geom: &Geometry) -> Self {
        let mut solid = vec![false; geom.nsites()];
        for (x, y, z, s) in geom.iter() {
            let _ = (x, z);
            if y == 0 || y == geom.ly - 1 {
                solid[s] = true;
            }
        }
        SolidMask { solid }
    }

    pub fn n_solid(&self) -> usize {
        self.solid.iter().filter(|&&b| b).count()
    }
}

/// Post-streaming full-way bounce-back: reverse all populations in place
/// at every solid site.
pub fn bounce_back(vs: &VelSet, geom: &Geometry, h: &mut [f64],
                   mask: &SolidMask) {
    let n = geom.nsites();
    debug_assert_eq!(h.len(), vs.nvel * n);
    debug_assert_eq!(mask.solid.len(), n);
    for s in 0..n {
        if !mask.solid[s] {
            continue;
        }
        for i in 1..vs.nvel {
            let j = vs.opposite(i);
            if j > i {
                h.swap(i * n + s, j * n + s);
            }
        }
    }
}

/// Snapshot the populations of the solid sites (call before a
/// whole-lattice collision launch).
pub fn save_solid(vs: &VelSet, h: &[f64], mask: &SolidMask,
                  nsites: usize) -> Vec<f64> {
    let mut saved = Vec::new();
    for s in 0..nsites {
        if mask.solid[s] {
            for i in 0..vs.nvel {
                saved.push(h[i * nsites + s]);
            }
        }
    }
    saved
}

/// Restore the snapshot taken by [`save_solid`] (call after collision), so
/// solid sites are effectively excluded from the collision.
pub fn restore_solid(vs: &VelSet, h: &mut [f64], mask: &SolidMask,
                     nsites: usize, saved: &[f64]) {
    let mut k = 0;
    for s in 0..nsites {
        if mask.solid[s] {
            for i in 0..vs.nvel {
                h[i * nsites + s] = saved[k];
                k += 1;
            }
        }
    }
    debug_assert_eq!(k, saved.len());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lb::model::d2q9;

    #[test]
    fn channel_mask_counts() {
        let geom = Geometry::new(4, 6, 1);
        let mask = SolidMask::channel_walls_y(&geom);
        assert_eq!(mask.n_solid(), 2 * 4);
    }

    #[test]
    fn bounce_back_conserves_mass_and_reverses() {
        let vs = d2q9();
        let geom = Geometry::new(4, 6, 1);
        let n = geom.nsites();
        let mask = SolidMask::channel_walls_y(&geom);
        let mut h: Vec<f64> =
            (0..vs.nvel * n).map(|i| (i % 13) as f64).collect();
        let before: f64 = h.iter().sum();
        let h0 = h.clone();
        bounce_back(vs, &geom, &mut h, &mask);
        let after: f64 = h.iter().sum();
        assert_eq!(before, after);
        // at a solid site every population moved to its opposite slot
        let s = geom.index(1, 0, 0);
        for i in 0..vs.nvel {
            assert_eq!(h[i * n + s], h0[vs.opposite(i) * n + s]);
        }
        // fluid sites untouched
        let sf = geom.index(1, 2, 0);
        for i in 0..vs.nvel {
            assert_eq!(h[i * n + sf], h0[i * n + sf]);
        }
    }

    #[test]
    fn double_bounce_back_is_identity() {
        let vs = d2q9();
        let geom = Geometry::new(3, 4, 1);
        let mask = SolidMask::channel_walls_y(&geom);
        let mut h: Vec<f64> =
            (0..vs.nvel * geom.nsites()).map(|i| i as f64).collect();
        let h0 = h.clone();
        bounce_back(vs, &geom, &mut h, &mask);
        bounce_back(vs, &geom, &mut h, &mask);
        assert_eq!(h, h0);
    }

    #[test]
    fn save_restore_roundtrip_excludes_collision() {
        let vs = d2q9();
        let geom = Geometry::new(3, 4, 1);
        let n = geom.nsites();
        let mask = SolidMask::channel_walls_y(&geom);
        let h0: Vec<f64> = (0..vs.nvel * n).map(|i| i as f64 * 0.1).collect();
        let mut h = h0.clone();
        let saved = save_solid(vs, &h, &mask, n);
        // simulate a whole-lattice collision trashing everything
        for v in h.iter_mut() {
            *v = -1.0;
        }
        restore_solid(vs, &mut h, &mask, n, &saved);
        for s in 0..n {
            for i in 0..vs.nvel {
                let want = if mask.solid[s] { h0[i * n + s] } else { -1.0 };
                assert_eq!(h[i * n + s], want);
            }
        }
    }
}
