//! Moment-projection equilibria (DESIGN.md section 5):
//! `h_i = w_i [a + 3 b.c_i + 9/2 S : (c_i c_i - I_d/3)]`.
//!
//! Used for initialisation and by tests; the collision kernels inline the
//! same algebra for speed.

use crate::free_energy::symmetric::FeParams;
use crate::lb::model::{VelSet, CS2, MAX_NVEL, SYM6};

/// Generic projection for one site: scalar moment `a`, vector moment `b`,
/// traceless-adjusted tensor `s` packed as (xx xy xz yy yz zz).
pub fn project(vs: &VelSet, a: f64, b: [f64; 3], s6: [f64; 6])
               -> [f64; MAX_NVEL] {
    let mut h = [0.0f64; MAX_NVEL];
    for i in 0..vs.nvel {
        let c = vs.cv[i];
        let cb = c[0] * b[0] + c[1] * b[1] + c[2] * b[2];
        let mut qs = 0.0;
        for k in 0..6 {
            qs += vs.q6[i][k] * s6[k];
        }
        h[i] = vs.wv[i] * (a + 3.0 * cb + 4.5 * qs);
    }
    h
}

/// Binary-fluid equilibrium pair (f_eq, g_eq) for one site.
///
/// `grad`/`lap` are the order-parameter gradients (zero for bulk init).
pub fn equilibrium_site(vs: &VelSet, p: &FeParams, rho: f64, phi: f64,
                        u: [f64; 3], grad: [f64; 3], lap: f64)
                        -> ([f64; MAX_NVEL], [f64; MAX_NVEL]) {
    let iso_f = p.pth_iso(rho, phi, grad, lap) - rho * CS2;
    let mu = p.chemical_potential(phi, lap);
    let iso_g = p.gamma * mu - phi * CS2;

    let mut s_f = [0.0f64; 6];
    let mut s_g = [0.0f64; 6];
    for (k, (a, b)) in SYM6.iter().enumerate() {
        let uu = u[*a] * u[*b];
        s_f[k] = rho * uu + p.kappa * grad[*a] * grad[*b];
        s_g[k] = phi * uu;
        if a == b {
            s_f[k] += iso_f;
            s_g[k] += iso_g;
        }
    }
    let f = project(vs, rho, [rho * u[0], rho * u[1], rho * u[2]], s_f);
    let g = project(vs, phi, [phi * u[0], phi * u[1], phi * u[2]], s_g);
    (f, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lb::model::{d2q9, d3q19};

    #[test]
    fn projection_reproduces_moments() {
        for vs in [d3q19(), d2q9()] {
            let a = 1.1;
            let mut b = [0.01, -0.02, 0.03];
            let mut s6 = [0.02, -0.01, 0.005, 0.015, -0.003, 0.01];
            if vs.ndim == 2 {
                b[2] = 0.0;
                s6[2] = 0.0; // xz
                s6[4] = 0.0; // yz
                s6[5] = 0.0; // zz
            }
            let h = project(vs, a, b, s6);

            let m0: f64 = h[..vs.nvel].iter().sum();
            assert!((m0 - a).abs() < 1e-14, "{}: zeroth", vs.name);

            for d in 0..3 {
                let m1: f64 = (0..vs.nvel).map(|i| vs.cv[i][d] * h[i]).sum();
                assert!((m1 - b[d]).abs() < 1e-14, "{}: first {d}", vs.name);
            }

            // second moment = a/3 I_d + S
            for (k, (x, y)) in SYM6.iter().enumerate() {
                let m2: f64 = (0..vs.nvel)
                    .map(|i| vs.cv[i][*x] * vs.cv[i][*y] * h[i])
                    .sum();
                let delta = if x == y && *x < vs.ndim { a / 3.0 } else { 0.0 };
                assert!((m2 - (delta + s6[k])).abs() < 1e-13,
                        "{}: second ({x},{y}): {m2}", vs.name);
            }
        }
    }

    #[test]
    fn equilibrium_site_moments() {
        let vs = d3q19();
        let p = FeParams::default();
        let (f, g) = equilibrium_site(vs, &p, 1.05, -0.3,
                                      [0.01, 0.0, -0.02], [0.0; 3], 0.0);
        let rho: f64 = f[..vs.nvel].iter().sum();
        let phi: f64 = g[..vs.nvel].iter().sum();
        assert!((rho - 1.05).abs() < 1e-14);
        assert!((phi + 0.3).abs() < 1e-14);
        for d in 0..3 {
            let m: f64 = (0..vs.nvel).map(|i| vs.cv[i][d] * f[i]).sum();
            let want = 1.05 * [0.01, 0.0, -0.02][d];
            assert!((m - want).abs() < 1e-14);
        }
    }
}
