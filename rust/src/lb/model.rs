//! Velocity sets (D3Q19, D2Q9) and the moment-projection tables.
//!
//! Ordering, weights and the packed `q6` projection tensor are **identical**
//! to `python/compile/kernels/ref.py` — the cross-layer agreement the whole
//! stack's correctness rests on (verified by `tests/xla_parity.rs`).

use std::sync::OnceLock;

/// Speed of sound squared, c_s^2 = 1/3 for both sets.
pub const CS2: f64 = 1.0 / 3.0;

/// Unique symmetric-tensor components in packed order: xx xy xz yy yz zz.
pub const SYM6: [(usize, usize); 6] =
    [(0, 0), (0, 1), (0, 2), (1, 1), (1, 2), (2, 2)];

/// Contraction multiplicity of each packed component (off-diagonals twice).
pub const SYM6_MULT: [f64; 6] = [1.0, 2.0, 2.0, 1.0, 2.0, 1.0];

/// Maximum nvel over the supported sets (stack-buffer capacity in kernels).
pub const MAX_NVEL: usize = 19;

/// Which velocity set a simulation uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LatticeModel {
    D3Q19,
    D2Q9,
}

impl LatticeModel {
    pub fn velset(&self) -> &'static VelSet {
        match self {
            LatticeModel::D3Q19 => d3q19(),
            LatticeModel::D2Q9 => d2q9(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LatticeModel::D3Q19 => "d3q19",
            LatticeModel::D2Q9 => "d2q9",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "d3q19" => Some(LatticeModel::D3Q19),
            "d2q9" => Some(LatticeModel::D2Q9),
            _ => None,
        }
    }
}

/// A discrete velocity set plus the precomputed projection tables.
#[derive(Debug)]
pub struct VelSet {
    pub name: &'static str,
    pub nvel: usize,
    /// Spatial dimensionality (2 for D2Q9; vectors still embedded in 3-D).
    pub ndim: usize,
    /// Lattice vectors as f64 (for moment arithmetic).
    pub cv: Vec<[f64; 3]>,
    /// Lattice vectors as integers (for streaming / neighbour offsets).
    pub ci: Vec<[i64; 3]>,
    /// Quadrature weights.
    pub wv: Vec<f64>,
    /// Packed projection tensor: `q6[i][k] = mult_k * (c_i c_i - I_d/3)_k`
    /// so `sum_ab Q_iab S_ab == q6[i] . s6` for symmetric S.
    pub q6: Vec<[f64; 6]>,
}

impl VelSet {
    fn build(name: &'static str, ndim: usize, ci: Vec<[i64; 3]>,
             wv: Vec<f64>) -> Self {
        let nvel = ci.len();
        let cv: Vec<[f64; 3]> = ci
            .iter()
            .map(|c| [c[0] as f64, c[1] as f64, c[2] as f64])
            .collect();
        // I_d embedded in 3x3 (ref.lattice_eye)
        let mut eye = [0.0f64; 3];
        for e in eye.iter_mut().take(ndim) {
            *e = 1.0;
        }
        let q6 = cv
            .iter()
            .map(|c| {
                let mut q = [0.0f64; 6];
                for (k, (a, b)) in SYM6.iter().enumerate() {
                    let delta = if a == b { eye[*a] } else { 0.0 };
                    q[k] = SYM6_MULT[k] * (c[*a] * c[*b] - delta / 3.0);
                }
                q
            })
            .collect();
        VelSet { name, nvel, ndim, cv, ci, wv, q6 }
    }

    /// Index of the velocity opposite to `i` (for bounce-back).
    pub fn opposite(&self, i: usize) -> usize {
        let c = self.ci[i];
        self.ci
            .iter()
            .position(|d| d[0] == -c[0] && d[1] == -c[1] && d[2] == -c[2])
            .expect("velocity set is parity symmetric")
    }
}

/// D3Q19, Ludwig ordering: rest, 6 faces, 12 edges (matches ref.py).
pub fn d3q19() -> &'static VelSet {
    static SET: OnceLock<VelSet> = OnceLock::new();
    SET.get_or_init(|| {
        let ci = vec![
            [0, 0, 0],
            [1, 0, 0], [-1, 0, 0], [0, 1, 0], [0, -1, 0], [0, 0, 1],
            [0, 0, -1],
            [1, 1, 0], [-1, -1, 0], [1, -1, 0], [-1, 1, 0],
            [1, 0, 1], [-1, 0, -1], [1, 0, -1], [-1, 0, 1],
            [0, 1, 1], [0, -1, -1], [0, 1, -1], [0, -1, 1],
        ];
        let mut wv = vec![1.0 / 36.0; 19];
        wv[0] = 1.0 / 3.0;
        for w in wv.iter_mut().take(7).skip(1) {
            *w = 1.0 / 18.0;
        }
        VelSet::build("d3q19", 3, ci, wv)
    })
}

/// D2Q9 embedded in 3-D, z component zero (matches ref.py).
pub fn d2q9() -> &'static VelSet {
    static SET: OnceLock<VelSet> = OnceLock::new();
    SET.get_or_init(|| {
        let ci = vec![
            [0, 0, 0],
            [1, 0, 0], [-1, 0, 0], [0, 1, 0], [0, -1, 0],
            [1, 1, 0], [-1, -1, 0], [1, -1, 0], [-1, 1, 0],
        ];
        let mut wv = vec![1.0 / 36.0; 9];
        wv[0] = 4.0 / 9.0;
        for w in wv.iter_mut().take(5).skip(1) {
            *w = 1.0 / 9.0;
        }
        VelSet::build("d2q9", 2, ci, wv)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_moment_identities(vs: &VelSet) {
        // sum w = 1
        let sw: f64 = vs.wv.iter().sum();
        assert!((sw - 1.0).abs() < 1e-14, "{}: sum w = {sw}", vs.name);
        // sum w c_a = 0
        for a in 0..3 {
            let s: f64 = (0..vs.nvel).map(|i| vs.wv[i] * vs.cv[i][a]).sum();
            assert!(s.abs() < 1e-14, "{}: first moment", vs.name);
        }
        // sum w c_a c_b = (1/3) I_d
        for a in 0..3 {
            for b in 0..3 {
                let s: f64 = (0..vs.nvel)
                    .map(|i| vs.wv[i] * vs.cv[i][a] * vs.cv[i][b])
                    .sum();
                let want = if a == b && a < vs.ndim { CS2 } else { 0.0 };
                assert!((s - want).abs() < 1e-14,
                        "{}: second moment ({a},{b}) = {s}", vs.name);
            }
        }
        // sum w q6 = 0 (conservation of the projection)
        for k in 0..6 {
            let s: f64 = (0..vs.nvel).map(|i| vs.wv[i] * vs.q6[i][k]).sum();
            assert!(s.abs() < 1e-14, "{}: q6[{k}]", vs.name);
        }
        // fourth-moment isotropy: sum w c_a c_b (c_a c_b - delta/3) = 2/9
        // for a != b within the active dimensions
        for (a, b) in [(0, 1), (0, 2), (1, 2)] {
            if b >= vs.ndim {
                continue;
            }
            let s: f64 = (0..vs.nvel)
                .map(|i| vs.wv[i] * vs.cv[i][a] * vs.cv[i][b]
                     * vs.cv[i][a] * vs.cv[i][b])
                .sum();
            assert!((s - 1.0 / 9.0).abs() < 1e-14,
                    "{}: fourth moment ({a},{b}) = {s}", vs.name);
        }
    }

    #[test]
    fn d3q19_identities() {
        let vs = d3q19();
        assert_eq!(vs.nvel, 19);
        check_moment_identities(vs);
    }

    #[test]
    fn d2q9_identities() {
        let vs = d2q9();
        assert_eq!(vs.nvel, 9);
        check_moment_identities(vs);
    }

    #[test]
    fn opposite_velocities() {
        for vs in [d3q19(), d2q9()] {
            assert_eq!(vs.opposite(0), 0);
            for i in 0..vs.nvel {
                let j = vs.opposite(i);
                assert_eq!(vs.opposite(j), i);
                for a in 0..3 {
                    assert_eq!(vs.ci[i][a], -vs.ci[j][a]);
                }
            }
        }
    }

    #[test]
    fn model_names_roundtrip() {
        for m in [LatticeModel::D3Q19, LatticeModel::D2Q9] {
            assert_eq!(LatticeModel::from_name(m.name()), Some(m));
        }
        assert_eq!(LatticeModel::from_name("d1q3"), None);
    }
}
