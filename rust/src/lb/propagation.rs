//! LB propagation (streaming): `h_i(x + c_i, t+1) = h_i(x, t)`.
//!
//! Implemented as a *pull* over the destination lattice with periodic
//! wrap — equivalent to the roll-based push in the reference/JAX layer
//! (`ref.stream`), as pinned by the parity tests.

use crate::lattice::geometry::Geometry;
use crate::lb::model::VelSet;
use crate::targetdp::tlp::TlpPool;

/// Stream `src` into `dst` (both `nvel * nsites`, SoA).
#[allow(clippy::too_many_arguments)]
pub fn stream(vs: &VelSet, geom: &Geometry, src: &[f64], dst: &mut [f64],
              pool: &TlpPool, vvl: usize) {
    let n = geom.nsites();
    debug_assert_eq!(src.len(), vs.nvel * n);
    debug_assert_eq!(dst.len(), vs.nvel * n);

    let dst_ptr = SendPtr(dst.as_mut_ptr());
    pool.for_chunks(n, vvl, |base, len| {
        let dst = dst_ptr;
        for s in base..base + len {
            let (x, y, z) = geom.coords(s);
            for i in 0..vs.nvel {
                let c = vs.ci[i];
                // pull: the value arriving at (x,y,z) left from x - c
                let from = geom.neighbor(x, y, z, -c[0], -c[1], -c[2]);
                unsafe {
                    *dst.0.add(i * n + s) = src[i * n + from];
                }
            }
        }
    });
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lb::model::{d2q9, d3q19};

    #[test]
    fn rest_population_is_unmoved() {
        let vs = d3q19();
        let geom = Geometry::new(4, 3, 2);
        let n = geom.nsites();
        let src: Vec<f64> = (0..vs.nvel * n).map(|i| i as f64).collect();
        let mut dst = vec![0.0; vs.nvel * n];
        stream(vs, &geom, &src, &mut dst, &TlpPool::serial(), 8);
        assert_eq!(&dst[..n], &src[..n], "i = 0 is the rest velocity");
    }

    #[test]
    fn single_pulse_moves_by_c() {
        let vs = d3q19();
        let geom = Geometry::new(4, 4, 4);
        let n = geom.nsites();
        for i in 1..vs.nvel {
            let mut src = vec![0.0; vs.nvel * n];
            let origin = geom.index(1, 2, 3);
            src[i * n + origin] = 1.0;
            let mut dst = vec![0.0; vs.nvel * n];
            stream(vs, &geom, &src, &mut dst, &TlpPool::serial(), 8);
            let c = vs.ci[i];
            let want = geom.neighbor(1, 2, 3, c[0], c[1], c[2]);
            for s in 0..n {
                let expect = if s == want { 1.0 } else { 0.0 };
                assert_eq!(dst[i * n + s], expect, "i={i} s={s}");
            }
        }
    }

    #[test]
    fn streaming_is_a_permutation() {
        let vs = d2q9();
        let geom = Geometry::new(5, 7, 1);
        let n = geom.nsites();
        let src: Vec<f64> = (0..vs.nvel * n).map(|i| (i * i) as f64).collect();
        let mut dst = vec![0.0; vs.nvel * n];
        stream(vs, &geom, &src, &mut dst, &TlpPool::serial(), 4);
        for i in 0..vs.nvel {
            let mut a: Vec<f64> = src[i * n..(i + 1) * n].to_vec();
            let mut b: Vec<f64> = dst[i * n..(i + 1) * n].to_vec();
            a.sort_by(f64::total_cmp);
            b.sort_by(f64::total_cmp);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn forward_backward_roundtrip() {
        let vs = d3q19();
        let geom = Geometry::new(3, 4, 5);
        let n = geom.nsites();
        let src: Vec<f64> = (0..vs.nvel * n).map(|i| i as f64 * 0.5).collect();
        let mut fwd = vec![0.0; vs.nvel * n];
        stream(vs, &geom, &src, &mut fwd, &TlpPool::serial(), 8);
        // streaming with the opposite set = inverse permutation
        let mut back = vec![0.0; vs.nvel * n];
        let pool = TlpPool::serial();
        pool.for_chunks(n, 8, |base, len| {
            let _ = (base, len);
        });
        // build the reverse by pulling with +c (push)
        for s in 0..n {
            let (x, y, z) = geom.coords(s);
            for i in 0..vs.nvel {
                let c = vs.ci[i];
                let from = geom.neighbor(x, y, z, c[0], c[1], c[2]);
                back[i * n + s] = fwd[i * n + from];
            }
        }
        assert_eq!(back, src);
    }
}
