//! LB propagation (streaming): `h_i(x + c_i, t+1) = h_i(x, t)`.
//!
//! Implemented as a *pull* over the destination lattice with periodic
//! wrap — equivalent to the roll-based push in the reference/JAX layer
//! (`ref.stream`), as pinned by the parity tests.
//!
//! The hot loop does no index arithmetic: a cached
//! [`StreamTable`] turns each velocity row into contiguous
//! interior `memcpy` runs at a constant offset plus a short list of
//! wrapped boundary sites (see `lattice/stream_table.rs`).

use crate::lattice::geometry::Geometry;
use crate::lattice::stream_table::StreamTable;
use crate::lb::model::VelSet;
use crate::targetdp::tlp::TlpPool;

/// Stream `src` into `dst` (both `nvel * nsites`, SoA), building/fetching
/// the streaming table from the process-wide cache.
#[allow(clippy::too_many_arguments)]
pub fn stream(vs: &VelSet, geom: &Geometry, src: &[f64], dst: &mut [f64],
              pool: &TlpPool, vvl: usize) {
    let table = StreamTable::cached(vs, geom);
    stream_with_table(vs, &table, src, dst, pool, vvl);
}

/// Stream `src` into `dst` using a prebuilt table (the form the host
/// target's `Stream`/`FullStep` kernels use).
pub fn stream_with_table(vs: &VelSet, table: &StreamTable, src: &[f64],
                         dst: &mut [f64], pool: &TlpPool, vvl: usize) {
    stream_range(vs, table, src, dst, 0..table.nsites, pool, vvl);
}

/// Ranged pull-stream: only destination sites in `sites` are written
/// (entries outside are untouched). The comms layer streams the interior
/// destination range while halo planes are still in flight, then
/// completes the boundary destinations on arrival — per-site values are
/// identical to the full sweep, the split only reorders independent
/// copies.
pub fn stream_range(vs: &VelSet, table: &StreamTable, src: &[f64],
                    dst: &mut [f64], sites: std::ops::Range<usize>,
                    pool: &TlpPool, vvl: usize) {
    let n = table.nsites;
    debug_assert_eq!(src.len(), vs.nvel * n);
    debug_assert_eq!(dst.len(), vs.nvel * n);
    debug_assert!(sites.end <= n);
    let start = sites.start;
    let count = sites.len();

    // SAFETY of the raw pointer: chunks partition `sites`, and each chunk
    // materialises a &mut slice over exactly its own destination range
    // dst[i*n + base .. i*n + base + len] per velocity — the parallel
    // borrows are disjoint.
    let dst_ptr = SendPtr(dst.as_mut_ptr());
    pool.for_chunks(count, vvl, |base, len| {
        let dst_ptr = dst_ptr;
        let base = start + base;
        for i in 0..vs.nvel {
            let dst_chunk = unsafe {
                std::slice::from_raw_parts_mut(
                    dst_ptr.0.add(i * n + base), len)
            };
            table.pull_chunk(i, &src[i * n..(i + 1) * n], dst_chunk, base);
        }
    });
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lb::model::{d2q9, d3q19};

    #[test]
    fn rest_population_is_unmoved() {
        let vs = d3q19();
        let geom = Geometry::new(4, 3, 2);
        let n = geom.nsites();
        let src: Vec<f64> = (0..vs.nvel * n).map(|i| i as f64).collect();
        let mut dst = vec![0.0; vs.nvel * n];
        stream(vs, &geom, &src, &mut dst, &TlpPool::serial(), 8);
        assert_eq!(&dst[..n], &src[..n], "i = 0 is the rest velocity");
    }

    #[test]
    fn single_pulse_moves_by_c() {
        let vs = d3q19();
        let geom = Geometry::new(4, 4, 4);
        let n = geom.nsites();
        for i in 1..vs.nvel {
            let mut src = vec![0.0; vs.nvel * n];
            let origin = geom.index(1, 2, 3);
            src[i * n + origin] = 1.0;
            let mut dst = vec![0.0; vs.nvel * n];
            stream(vs, &geom, &src, &mut dst, &TlpPool::serial(), 8);
            let c = vs.ci[i];
            let want = geom.neighbor(1, 2, 3, c[0], c[1], c[2]);
            for s in 0..n {
                let expect = if s == want { 1.0 } else { 0.0 };
                assert_eq!(dst[i * n + s], expect, "i={i} s={s}");
            }
        }
    }

    #[test]
    fn streaming_is_a_permutation() {
        let vs = d2q9();
        let geom = Geometry::new(5, 7, 1);
        let n = geom.nsites();
        let src: Vec<f64> = (0..vs.nvel * n).map(|i| (i * i) as f64).collect();
        let mut dst = vec![0.0; vs.nvel * n];
        stream(vs, &geom, &src, &mut dst, &TlpPool::serial(), 4);
        for i in 0..vs.nvel {
            let mut a: Vec<f64> = src[i * n..(i + 1) * n].to_vec();
            let mut b: Vec<f64> = dst[i * n..(i + 1) * n].to_vec();
            a.sort_by(f64::total_cmp);
            b.sort_by(f64::total_cmp);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn forward_backward_roundtrip() {
        let vs = d3q19();
        let geom = Geometry::new(3, 4, 5);
        let n = geom.nsites();
        let src: Vec<f64> = (0..vs.nvel * n).map(|i| i as f64 * 0.5).collect();
        let mut fwd = vec![0.0; vs.nvel * n];
        stream(vs, &geom, &src, &mut fwd, &TlpPool::serial(), 8);
        // streaming with the opposite set = inverse permutation:
        // build the reverse by pulling with +c (push)
        let mut back = vec![0.0; vs.nvel * n];
        for s in 0..n {
            let (x, y, z) = geom.coords(s);
            for i in 0..vs.nvel {
                let c = vs.ci[i];
                let from = geom.neighbor(x, y, z, c[0], c[1], c[2]);
                back[i * n + s] = fwd[i * n + from];
            }
        }
        assert_eq!(back, src);
    }

    #[test]
    fn ranged_stream_pieces_reassemble_full_sweep() {
        let vs = d3q19();
        let geom = Geometry::new(6, 3, 4);
        let n = geom.nsites();
        let table = crate::lattice::StreamTable::cached(vs, &geom);
        let src: Vec<f64> =
            (0..vs.nvel * n).map(|i| (i % 113) as f64 * 0.25).collect();
        let mut whole = vec![0.0; vs.nvel * n];
        stream(vs, &geom, &src, &mut whole, &TlpPool::serial(), 8);
        // interior planes first, then the two boundary planes — the comms
        // overlap split
        let plane = geom.ly * geom.lz;
        let mut split = vec![-7.0; vs.nvel * n];
        let pool = TlpPool::serial();
        stream_range(vs, &table, &src, &mut split, plane..5 * plane, &pool,
                     4);
        stream_range(vs, &table, &src, &mut split, 0..plane, &pool, 4);
        stream_range(vs, &table, &src, &mut split, 5 * plane..n, &pool, 4);
        assert_eq!(split, whole);
    }

    #[test]
    fn threaded_stream_matches_serial() {
        let vs = d3q19();
        let geom = Geometry::new(5, 4, 3);
        let n = geom.nsites();
        let src: Vec<f64> =
            (0..vs.nvel * n).map(|i| (i % 41) as f64 * 0.5).collect();
        let mut serial = vec![0.0; vs.nvel * n];
        stream(vs, &geom, &src, &mut serial, &TlpPool::serial(), 8);
        let pool = TlpPool::new(4, crate::targetdp::tlp::Schedule::Dynamic {
            batch: 2,
        });
        let mut par = vec![0.0; vs.nvel * n];
        stream(vs, &geom, &src, &mut par, &pool, 4);
        assert_eq!(serial, par);
    }
}
