//! The paper's Figure-1 hot spot: binary-fluid BGK collision.
//!
//! Two implementations of the identical physics (DESIGN.md section 5):
//!
//! * [`collide_sites_scalar`] — one site at a time over SoA data, inner
//!   loops over the `nvel` (19) velocities; the compiler is left to find
//!   ILP, exactly like the paper's *original* CPU code (which the AoS
//!   [`crate::baseline`] variant reproduces even more literally).
//! * [`collide_chunk`] — the targetDP version: a `const VVL` chunk of
//!   consecutive sites processed lane-wise (`[f64; VVL]` arrays, innermost
//!   loops of compile-time extent VVL over contiguous SoA lanes), which the
//!   auto-vectorizer maps onto SIMD — the `TARGET_ILP` mechanism.
//!
//! Both store through a shared per-site/per-lane core, which is also what
//! the **fused collide→push-stream** variants ([`collide_stream_lattice`])
//! reuse: the post-collision populations are scattered straight to their
//! streaming destinations (via a precomputed
//! [`StreamTable`]) instead of being written back and
//! re-read by a separate `Stream` sweep — halving the f/g memory traffic
//! of a timestep. Because fused and unfused paths run the *same* collision
//! core and streaming is a pure permutation, they agree bit-for-bit
//! (pinned by `tests/fused_parity.rs`).
//!
//! All paths must agree with `python/compile/kernels/ref.py` to f64
//! round-off; `rust/tests/xla_parity.rs` pins the layers together.

use std::ops::Range;

use crate::free_energy::symmetric::FeParams;
use crate::lattice::stream_table::StreamTable;
use crate::lb::model::{VelSet, CS2, MAX_NVEL};
use crate::targetdp::tlp::TlpPool;

/// Post-collision populations of one site (the scalar core shared by the
/// in-place and fused scalar paths).
///
/// Layout: `f[i * nsites + s]`, `grad[d * nsites + s]`, `lap[s]`.
#[allow(clippy::too_many_arguments)]
#[inline]
fn collide_site(vs: &VelSet, p: &FeParams, f: &[f64], g: &[f64],
                grad: &[f64], lap: &[f64], nsites: usize, s: usize,
                f_out: &mut [f64; MAX_NVEL], g_out: &mut [f64; MAX_NVEL]) {
    // moments
    let mut rho = 0.0;
    let mut ru = [0.0f64; 3];
    let mut phi = 0.0;
    for i in 0..vs.nvel {
        let fi = f[i * nsites + s];
        rho += fi;
        for a in 0..3 {
            ru[a] += vs.cv[i][a] * fi;
        }
        phi += g[i * nsites + s];
    }
    let u = [ru[0] / rho, ru[1] / rho, ru[2] / rho];
    let gd = [grad[s], grad[nsites + s], grad[2 * nsites + s]];
    let lp = lap[s];

    // free-energy sector
    let mu = p.chemical_potential(phi, lp);
    let iso_f = p.pth_iso(rho, phi, gd, lp) - rho * CS2;
    let iso_g = p.gamma * mu - phi * CS2;

    // packed symmetric tensors (xx xy xz yy yz zz)
    let mut s_f = [0.0f64; 6];
    let mut s_g = [0.0f64; 6];
    for (k, (a, b)) in crate::lb::model::SYM6.iter().enumerate() {
        let uu = u[*a] * u[*b];
        s_f[k] = rho * uu + p.kappa * gd[*a] * gd[*b];
        s_g[k] = phi * uu;
        if a == b {
            s_f[k] += iso_f;
            s_g[k] += iso_g;
        }
    }

    // relax toward the moment-projection equilibrium
    let pu = [phi * u[0], phi * u[1], phi * u[2]];
    for i in 0..vs.nvel {
        let mut cb_f = 0.0;
        let mut cb_g = 0.0;
        for a in 0..3 {
            cb_f += vs.cv[i][a] * ru[a];
            cb_g += vs.cv[i][a] * pu[a];
        }
        let mut qs_f = 0.0;
        let mut qs_g = 0.0;
        for k in 0..6 {
            qs_f += vs.q6[i][k] * s_f[k];
            qs_g += vs.q6[i][k] * s_g[k];
        }
        let feq = vs.wv[i] * (rho + 3.0 * cb_f + 4.5 * qs_f);
        let geq = vs.wv[i] * (phi + 3.0 * cb_g + 4.5 * qs_g);
        let fi = f[i * nsites + s];
        f_out[i] = fi - (fi - feq) / p.tau_f;
        let gi = g[i * nsites + s];
        g_out[i] = gi - (gi - geq) / p.tau_g;
    }
}

/// Scalar reference path: collide sites `[base, base+len)` of SoA fields
/// in place.
#[allow(clippy::too_many_arguments)]
pub fn collide_sites_scalar(vs: &VelSet, p: &FeParams, f: &mut [f64],
                            g: &mut [f64], grad: &[f64], lap: &[f64],
                            nsites: usize, base: usize, len: usize) {
    let mut f_out = [0.0f64; MAX_NVEL];
    let mut g_out = [0.0f64; MAX_NVEL];
    for s in base..base + len {
        collide_site(vs, p, f, g, grad, lap, nsites, s, &mut f_out,
                     &mut g_out);
        for i in 0..vs.nvel {
            f[i * nsites + s] = f_out[i];
            g[i * nsites + s] = g_out[i];
        }
    }
}

/// Fused scalar path: collide sites `[base, base+len)` of `f_src`/`g_src`
/// and push-stream the post-collision populations into `f_dst`/`g_dst`.
#[allow(clippy::too_many_arguments)]
pub fn collide_stream_sites_scalar(vs: &VelSet, p: &FeParams,
                                   f_src: &[f64], g_src: &[f64],
                                   f_dst: &mut [f64], g_dst: &mut [f64],
                                   grad: &[f64], lap: &[f64],
                                   table: &StreamTable, nsites: usize,
                                   base: usize, len: usize) {
    let mut f_out = [0.0f64; MAX_NVEL];
    let mut g_out = [0.0f64; MAX_NVEL];
    for s in base..base + len {
        collide_site(vs, p, f_src, g_src, grad, lap, nsites, s, &mut f_out,
                     &mut g_out);
        for i in 0..vs.nvel {
            let to = table.push_to(i, s);
            f_dst[i * nsites + to] = f_out[i];
            g_dst[i * nsites + to] = g_out[i];
        }
    }
}

/// Load the distribution slab of one chunk: `fl/gl[i]` holds lane values
/// for velocity i (stack resident, 19 * VVL * 8 B <= 4.75 KiB each).
/// For a short tail (`len < VVL`) dead lanes get neutral fill (rho = 1).
#[allow(clippy::too_many_arguments)]
#[inline]
fn load_lanes<const VVL: usize>(vs: &VelSet, f: &[f64], g: &[f64],
                                nsites: usize, base: usize, len: usize,
                                fl: &mut [[f64; VVL]; MAX_NVEL],
                                gl: &mut [[f64; VVL]; MAX_NVEL]) {
    let full = len == VVL;
    for i in 0..vs.nvel {
        let fr = &f[i * nsites + base..];
        let gr = &g[i * nsites + base..];
        if full {
            for v in 0..VVL {
                fl[i][v] = fr[v];
                gl[i][v] = gr[v];
            }
        } else {
            // tail: neutral fill keeps rho lanes at w_i sum == 1
            for v in 0..VVL {
                fl[i][v] = if v < len { fr[v] } else { vs.wv[i] };
                gl[i][v] = if v < len { gr[v] } else { 0.0 };
            }
        }
    }
}

/// The lane-wise collision core (`TARGET_ILP` loops of compile-time extent
/// VVL): relax the loaded slab in place, `fl/gl[i]` becoming the
/// post-collision populations. Shared by the in-place and fused chunks so
/// the two paths are arithmetically identical.
#[allow(clippy::too_many_arguments)]
#[inline]
fn collide_lanes<const VVL: usize>(vs: &VelSet, p: &FeParams,
                                   fl: &mut [[f64; VVL]; MAX_NVEL],
                                   gl: &mut [[f64; VVL]; MAX_NVEL],
                                   grad: &[f64], lap: &[f64],
                                   nsites: usize, base: usize, len: usize) {
    let nvel = vs.nvel;

    // moments, lane-wise
    let mut rho = [0.0f64; VVL];
    let mut rux = [0.0f64; VVL];
    let mut ruy = [0.0f64; VVL];
    let mut ruz = [0.0f64; VVL];
    let mut phi = [0.0f64; VVL];
    for i in 0..nvel {
        let c = vs.cv[i];
        for v in 0..VVL {
            // f64::mul_add: FMA keeps the lane loops on the FP throughput
            // roofline (see EXPERIMENTS.md §Perf P3)
            let fi = fl[i][v];
            rho[v] += fi;
            rux[v] = c[0].mul_add(fi, rux[v]);
            ruy[v] = c[1].mul_add(fi, ruy[v]);
            ruz[v] = c[2].mul_add(fi, ruz[v]);
            phi[v] += gl[i][v];
        }
    }

    let mut gx = [0.0f64; VVL];
    let mut gy = [0.0f64; VVL];
    let mut gz = [0.0f64; VVL];
    let mut lp = [0.0f64; VVL];
    for v in 0..VVL.min(len) {
        gx[v] = grad[base + v];
        gy[v] = grad[nsites + base + v];
        gz[v] = grad[2 * nsites + base + v];
        lp[v] = lap[base + v];
    }

    // per-lane free-energy quantities and packed tensors
    let mut s_f = [[0.0f64; VVL]; 6];
    let mut s_g = [[0.0f64; VVL]; 6];
    let mut pux = [0.0f64; VVL];
    let mut puy = [0.0f64; VVL];
    let mut puz = [0.0f64; VVL];
    for v in 0..VVL {
        let r = rho[v];
        let ph = phi[v];
        let inv = 1.0 / r;
        let ux = rux[v] * inv;
        let uy = ruy[v] * inv;
        let uz = ruz[v] * inv;
        pux[v] = ph * ux;
        puy[v] = ph * uy;
        puz[v] = ph * uz;

        let ph2 = ph * ph;
        let mu = p.a * ph + p.b * ph * ph2 - p.kappa * lp[v];
        let p0 = r * CS2 + 0.5 * p.a * ph2 + 0.75 * p.b * ph2 * ph2;
        let gsq = gx[v] * gx[v] + gy[v] * gy[v] + gz[v] * gz[v];
        let iso_f = p0 - p.kappa * ph * lp[v] - 0.5 * p.kappa * gsq - r * CS2;
        let iso_g = p.gamma * mu - ph * CS2;

        // order: xx xy xz yy yz zz
        s_f[0][v] = r * ux * ux + p.kappa * gx[v] * gx[v] + iso_f;
        s_f[1][v] = r * ux * uy + p.kappa * gx[v] * gy[v];
        s_f[2][v] = r * ux * uz + p.kappa * gx[v] * gz[v];
        s_f[3][v] = r * uy * uy + p.kappa * gy[v] * gy[v] + iso_f;
        s_f[4][v] = r * uy * uz + p.kappa * gy[v] * gz[v];
        s_f[5][v] = r * uz * uz + p.kappa * gz[v] * gz[v] + iso_f;

        s_g[0][v] = ph * ux * ux + iso_g;
        s_g[1][v] = ph * ux * uy;
        s_g[2][v] = ph * ux * uz;
        s_g[3][v] = ph * uy * uy + iso_g;
        s_g[4][v] = ph * uy * uz;
        s_g[5][v] = ph * uz * uz + iso_g;
    }

    // equilibrium + BGK relaxation, lanes updated in place
    let inv_tf = 1.0 / p.tau_f;
    let inv_tg = 1.0 / p.tau_g;
    for i in 0..nvel {
        let c = vs.cv[i];
        let q = vs.q6[i];
        let w = vs.wv[i];
        for v in 0..VVL {
            let cb_f = c[0].mul_add(rux[v],
                        c[1].mul_add(ruy[v], c[2] * ruz[v]));
            let cb_g = c[0].mul_add(pux[v],
                        c[1].mul_add(puy[v], c[2] * puz[v]));
            let qs_f = q[0].mul_add(s_f[0][v],
                        q[1].mul_add(s_f[1][v],
                         q[2].mul_add(s_f[2][v],
                          q[3].mul_add(s_f[3][v],
                           q[4].mul_add(s_f[4][v], q[5] * s_f[5][v])))));
            let qs_g = q[0].mul_add(s_g[0][v],
                        q[1].mul_add(s_g[1][v],
                         q[2].mul_add(s_g[2][v],
                          q[3].mul_add(s_g[3][v],
                           q[4].mul_add(s_g[4][v], q[5] * s_g[5][v])))));
            let feq = w * 3.0f64.mul_add(cb_f, 4.5f64.mul_add(qs_f, rho[v]));
            let geq = w * 3.0f64.mul_add(cb_g, 4.5f64.mul_add(qs_g, phi[v]));
            fl[i][v] = (fl[i][v] - feq).mul_add(-inv_tf, fl[i][v]);
            gl[i][v] = (gl[i][v] - geq).mul_add(-inv_tg, gl[i][v]);
        }
    }
}

/// targetDP path: collide one chunk of `VVL` consecutive sites lane-wise,
/// in place.
///
/// `len == VVL` except for the tail chunk; dead lanes are computed with
/// neutral fill values (rho = 1) and never stored.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn collide_chunk<const VVL: usize>(vs: &VelSet, p: &FeParams,
                                       f: &mut [f64], g: &mut [f64],
                                       grad: &[f64], lap: &[f64],
                                       nsites: usize, base: usize,
                                       len: usize) {
    let mut fl = [[0.0f64; VVL]; MAX_NVEL];
    let mut gl = [[0.0f64; VVL]; MAX_NVEL];
    load_lanes(vs, f, g, nsites, base, len, &mut fl, &mut gl);
    collide_lanes(vs, p, &mut fl, &mut gl, grad, lap, nsites, base, len);
    for i in 0..vs.nvel {
        let fr = &mut f[i * nsites + base..];
        for v in 0..len {
            fr[v] = fl[i][v];
        }
        let gr = &mut g[i * nsites + base..];
        for v in 0..len {
            gr[v] = gl[i][v];
        }
    }
}

/// Fused targetDP path: collide one chunk lane-wise and push-stream the
/// post-collision lanes straight into the destination buffers — the
/// store side becomes one [`StreamTable::push_row`] scatter per velocity
/// (contiguous interior runs + wrapped boundary patch-up) instead of a
/// write-back that a later `Stream` sweep would have to re-read.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn collide_stream_chunk<const VVL: usize>(
    vs: &VelSet, p: &FeParams, f_src: &[f64], g_src: &[f64],
    f_dst: &mut [f64], g_dst: &mut [f64], grad: &[f64], lap: &[f64],
    table: &StreamTable, nsites: usize, base: usize, len: usize,
) {
    let mut fl = [[0.0f64; VVL]; MAX_NVEL];
    let mut gl = [[0.0f64; VVL]; MAX_NVEL];
    load_lanes(vs, f_src, g_src, nsites, base, len, &mut fl, &mut gl);
    collide_lanes(vs, p, &mut fl, &mut gl, grad, lap, nsites, base, len);
    for i in 0..vs.nvel {
        table.push_row(i, &mut f_dst[i * nsites..(i + 1) * nsites], base,
                       len, &fl[i]);
        table.push_row(i, &mut g_dst[i * nsites..(i + 1) * nsites], base,
                       len, &gl[i]);
    }
}

/// Full-lattice collision with TLP + ILP partitioning (the targetDP
/// execution model): TLP distributes VVL-chunks over threads, each chunk
/// runs the const-generic lane kernel.
#[allow(clippy::too_many_arguments)]
pub fn collide_lattice(vs: &VelSet, p: &FeParams, f: &mut [f64],
                       g: &mut [f64], grad: &[f64], lap: &[f64],
                       nsites: usize, pool: &TlpPool, vvl: usize,
                       scalar: bool) {
    collide_lattice_range(vs, p, f, g, grad, lap, nsites, 0..nsites, pool,
                          vvl, scalar);
}

/// Ranged in-place collision: only the sites in `sites` are collided
/// (used by the multidomain step to skip the halo planes, whose gradients
/// are garbage). Per-site arithmetic is chunk-position independent, so a
/// restricted range produces bitwise the same values as the full sweep.
#[allow(clippy::too_many_arguments)]
pub fn collide_lattice_range(vs: &VelSet, p: &FeParams, f: &mut [f64],
                             g: &mut [f64], grad: &[f64], lap: &[f64],
                             nsites: usize, sites: Range<usize>,
                             pool: &TlpPool, vvl: usize, scalar: bool) {
    debug_assert_eq!(f.len(), vs.nvel * nsites);
    debug_assert_eq!(g.len(), vs.nvel * nsites);
    debug_assert_eq!(grad.len(), 3 * nsites);
    debug_assert_eq!(lap.len(), nsites);
    debug_assert!(sites.end <= nsites);
    let start = sites.start;
    let count = sites.len();

    // SAFETY: chunks partition `sites`; every lane write of a chunk
    // touches only sites in [base, base+len), so the parallel mutable
    // accesses are disjoint.
    let f_ptr = SendPtr(f.as_mut_ptr(), f.len());
    let g_ptr = SendPtr(g.as_mut_ptr(), g.len());

    pool.for_chunks(count, vvl, |base, len| {
        // rebind so the closure captures the Send+Sync wrappers whole
        let (f_ptr, g_ptr) = (f_ptr, g_ptr);
        let base = start + base;
        let f = unsafe { std::slice::from_raw_parts_mut(f_ptr.0, f_ptr.1) };
        let g = unsafe { std::slice::from_raw_parts_mut(g_ptr.0, g_ptr.1) };
        if scalar {
            collide_sites_scalar(vs, p, f, g, grad, lap, nsites, base, len);
        } else {
            crate::dispatch_vvl!(
                vvl,
                collide_chunk(vs, p, f, g, grad, lap, nsites, base, len)
            );
        }
    });
}

/// Fused full-lattice collide→push-stream (the host `FullStep` hot loop):
/// every chunk is collided in registers and scattered straight to its
/// streaming destinations in `f_dst`/`g_dst`. Reads `f_src`/`g_src` and
/// `grad`/`lap` exactly once; the separate `Stream` read-modify-write
/// sweeps of the unfused pipeline disappear.
#[allow(clippy::too_many_arguments)]
pub fn collide_stream_lattice(vs: &VelSet, p: &FeParams, f_src: &[f64],
                              g_src: &[f64], f_dst: &mut [f64],
                              g_dst: &mut [f64], grad: &[f64], lap: &[f64],
                              table: &StreamTable, nsites: usize,
                              pool: &TlpPool, vvl: usize, scalar: bool) {
    collide_stream_range(vs, p, f_src, g_src, f_dst, g_dst, grad, lap,
                         table, nsites, 0..nsites, pool, vvl, scalar);
}

/// Ranged fused collide→push-stream: only the sites in `sites` are
/// collided and scattered — the inner sweep of the temporal-blocked
/// `MultiStep` tier, which shrinks the collided slab region by one plane
/// per side per blocked step. Destination entries whose unique source site
/// lies outside `sites` are left untouched.
#[allow(clippy::too_many_arguments)]
pub fn collide_stream_range(vs: &VelSet, p: &FeParams, f_src: &[f64],
                            g_src: &[f64], f_dst: &mut [f64],
                            g_dst: &mut [f64], grad: &[f64], lap: &[f64],
                            table: &StreamTable, nsites: usize,
                            sites: Range<usize>, pool: &TlpPool,
                            vvl: usize, scalar: bool) {
    debug_assert_eq!(f_src.len(), vs.nvel * nsites);
    debug_assert_eq!(g_src.len(), vs.nvel * nsites);
    debug_assert_eq!(f_dst.len(), vs.nvel * nsites);
    debug_assert_eq!(g_dst.len(), vs.nvel * nsites);
    debug_assert_eq!(grad.len(), 3 * nsites);
    debug_assert_eq!(lap.len(), nsites);
    debug_assert_eq!(table.nsites, nsites);
    debug_assert!(sites.end <= nsites);
    let start = sites.start;
    let count = sites.len();

    // SAFETY: per velocity, push-streaming is a bijection on sites, so the
    // destination sets of disjoint chunks are disjoint; chunks partition
    // `sites`.
    let f_ptr = SendPtr(f_dst.as_mut_ptr(), f_dst.len());
    let g_ptr = SendPtr(g_dst.as_mut_ptr(), g_dst.len());

    pool.for_chunks(count, vvl, |base, len| {
        let (f_ptr, g_ptr) = (f_ptr, g_ptr);
        let base = start + base;
        let f_dst =
            unsafe { std::slice::from_raw_parts_mut(f_ptr.0, f_ptr.1) };
        let g_dst =
            unsafe { std::slice::from_raw_parts_mut(g_ptr.0, g_ptr.1) };
        if scalar {
            collide_stream_sites_scalar(vs, p, f_src, g_src, f_dst, g_dst,
                                        grad, lap, table, nsites, base, len);
        } else {
            crate::dispatch_vvl!(
                vvl,
                collide_stream_chunk(vs, p, f_src, g_src, f_dst, g_dst,
                                     grad, lap, table, nsites, base, len)
            );
        }
    });
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f64, usize);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::geometry::Geometry;
    use crate::lb::model::{d2q9, d3q19};
    use crate::lb::propagation::stream;

    /// Deterministic near-equilibrium state (mirrors tests/test_kernel.py).
    pub fn make_state(vs: &VelSet, nsites: usize, seed: u64)
                      -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = seed.max(1);
        let mut next = move || {
            // xorshift64*
            rng ^= rng >> 12;
            rng ^= rng << 25;
            rng ^= rng >> 27;
            (rng.wrapping_mul(0x2545F4914F6CDD1D) >> 11) as f64
                / (1u64 << 53) as f64
                - 0.5
        };
        let mut f = vec![0.0; vs.nvel * nsites];
        let mut g = vec![0.0; vs.nvel * nsites];
        for i in 0..vs.nvel {
            for s in 0..nsites {
                f[i * nsites + s] = vs.wv[i] * (1.0 + 0.1 * next());
                g[i * nsites + s] = vs.wv[i] * 0.1 * next();
            }
        }
        let mut grad = vec![0.0; 3 * nsites];
        for d in 0..vs.ndim {
            for s in 0..nsites {
                grad[d * nsites + s] = 0.02 * next();
            }
        }
        let lap: Vec<f64> = (0..nsites).map(|_| 0.02 * next()).collect();
        (f, g, grad, lap)
    }

    fn moments(vs: &VelSet, f: &[f64], nsites: usize) -> (f64, [f64; 3]) {
        let mut mass = 0.0;
        let mut mom = [0.0f64; 3];
        for i in 0..vs.nvel {
            for s in 0..nsites {
                let fi = f[i * nsites + s];
                mass += fi;
                for a in 0..3 {
                    mom[a] += vs.cv[i][a] * fi;
                }
            }
        }
        (mass, mom)
    }

    #[test]
    fn chunk_matches_scalar_all_vvl() {
        for vs in [d3q19(), d2q9()] {
            let nsites = 160;
            let p = FeParams::default();
            let (f0, g0, grad, lap) = make_state(vs, nsites, 42);

            let mut f_ref = f0.clone();
            let mut g_ref = g0.clone();
            collide_sites_scalar(vs, &p, &mut f_ref, &mut g_ref, &grad,
                                 &lap, nsites, 0, nsites);

            for &vvl in crate::targetdp::ilp::SUPPORTED_VVL {
                let mut f = f0.clone();
                let mut g = g0.clone();
                collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, nsites,
                                &TlpPool::serial(), vvl, false);
                for (a, b) in f.iter().zip(&f_ref) {
                    assert!((a - b).abs() < 1e-14,
                            "{} vvl={vvl}: f {a} vs {b}", vs.name);
                }
                for (a, b) in g.iter().zip(&g_ref) {
                    assert!((a - b).abs() < 1e-14,
                            "{} vvl={vvl}: g {a} vs {b}", vs.name);
                }
            }
        }
    }

    #[test]
    fn tail_chunks_handled() {
        // nsites not a multiple of VVL exercises the fill path
        let vs = d3q19();
        let nsites = 37;
        let p = FeParams::default();
        let (f0, g0, grad, lap) = make_state(vs, nsites, 7);
        let mut f_ref = f0.clone();
        let mut g_ref = g0.clone();
        collide_sites_scalar(vs, &p, &mut f_ref, &mut g_ref, &grad, &lap,
                             nsites, 0, nsites);
        let mut f = f0.clone();
        let mut g = g0.clone();
        collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, nsites,
                        &TlpPool::serial(), 16, false);
        for (a, b) in f.iter().zip(&f_ref) {
            assert!((a - b).abs() < 1e-14);
        }
        for (a, b) in g.iter().zip(&g_ref) {
            assert!((a - b).abs() < 1e-14);
        }
    }

    #[test]
    fn ranged_collide_is_bitwise_restriction_of_full_sweep() {
        // unaligned range start: chunk bases shift, values must not —
        // the property the MultiStep temporal blocking relies on
        let vs = d3q19();
        let nsites = 120;
        let p = FeParams::default();
        let (f0, g0, grad, lap) = make_state(vs, nsites, 21);
        let mut f_full = f0.clone();
        let mut g_full = g0.clone();
        collide_lattice(vs, &p, &mut f_full, &mut g_full, &grad, &lap,
                        nsites, &TlpPool::serial(), 8, false);
        let range = 17..93;
        let mut f = f0.clone();
        let mut g = g0.clone();
        collide_lattice_range(vs, &p, &mut f, &mut g, &grad, &lap, nsites,
                              range.clone(), &TlpPool::serial(), 8, false);
        for i in 0..vs.nvel {
            for s in 0..nsites {
                let (wf, wg) = if range.contains(&s) {
                    (f_full[i * nsites + s], g_full[i * nsites + s])
                } else {
                    (f0[i * nsites + s], g0[i * nsites + s])
                };
                assert_eq!(f[i * nsites + s], wf, "i={i} s={s}");
                assert_eq!(g[i * nsites + s], wg, "i={i} s={s}");
            }
        }
    }

    #[test]
    fn collision_conserves_invariants() {
        for vs in [d3q19(), d2q9()] {
            let nsites = 96;
            let p = FeParams::default();
            let (mut f, mut g, grad, lap) = make_state(vs, nsites, 3);
            let (mass0, mom0) = moments(vs, &f, nsites);
            let phi0: f64 = g.iter().sum();
            collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, nsites,
                            &TlpPool::serial(), 8, false);
            let (mass1, mom1) = moments(vs, &f, nsites);
            let phi1: f64 = g.iter().sum();
            assert!((mass1 - mass0).abs() < 1e-11, "{} mass", vs.name);
            assert!((phi1 - phi0).abs() < 1e-11, "{} phi", vs.name);
            for a in 0..3 {
                assert!((mom1[a] - mom0[a]).abs() < 1e-11,
                        "{} mom[{a}]", vs.name);
            }
        }
    }

    #[test]
    fn threads_match_serial() {
        let vs = d3q19();
        let nsites = 200;
        let p = FeParams::default();
        let (f0, g0, grad, lap) = make_state(vs, nsites, 9);
        let mut f1 = f0.clone();
        let mut g1 = g0.clone();
        collide_lattice(vs, &p, &mut f1, &mut g1, &grad, &lap, nsites,
                        &TlpPool::serial(), 8, false);
        let mut f2 = f0;
        let mut g2 = g0;
        let pool = TlpPool::new(4, crate::targetdp::tlp::Schedule::Dynamic {
            batch: 2,
        });
        collide_lattice(vs, &p, &mut f2, &mut g2, &grad, &lap, nsites,
                        &pool, 8, false);
        assert_eq!(f1, f2);
        assert_eq!(g1, g2);
    }

    #[test]
    fn equilibrium_is_fixed_point() {
        // a uniform zero-velocity equilibrium state must be invariant
        let vs = d3q19();
        let nsites = 64;
        let p = FeParams::default();
        let rho = 1.0;
        let phi = 0.4;
        let mut f = vec![0.0; vs.nvel * nsites];
        let mut g = vec![0.0; vs.nvel * nsites];
        let (feq, geq) = crate::lb::equilibrium::equilibrium_site(
            vs, &p, rho, phi, [0.0; 3], [0.0; 3], 0.0);
        for i in 0..vs.nvel {
            for s in 0..nsites {
                f[i * nsites + s] = feq[i];
                g[i * nsites + s] = geq[i];
            }
        }
        let f0 = f.clone();
        let g0 = g.clone();
        let grad = vec![0.0; 3 * nsites];
        let lap = vec![0.0; nsites];
        collide_lattice(vs, &p, &mut f, &mut g, &grad, &lap, nsites,
                        &TlpPool::serial(), 4, false);
        for (a, b) in f.iter().zip(&f0) {
            assert!((a - b).abs() < 1e-14);
        }
        for (a, b) in g.iter().zip(&g0) {
            assert!((a - b).abs() < 1e-14);
        }
    }

    #[test]
    fn fused_matches_collide_then_stream_bitwise() {
        // the fused sweep must be indistinguishable from the 2-kernel
        // sequence — exact equality, not a tolerance
        for (vs, geom) in [(d3q19(), Geometry::new(5, 4, 3)),
                           (d2q9(), Geometry::new(9, 7, 1))] {
            let n = geom.nsites();
            let p = FeParams::default();
            let (f0, g0, grad, lap) = make_state(vs, n, 1234);
            let table = StreamTable::new(vs, &geom);
            let pool = TlpPool::serial();

            for scalar in [false, true] {
                for &vvl in crate::targetdp::ilp::SUPPORTED_VVL {
                    // unfused reference: collide in place, then stream
                    let mut f_ref = f0.clone();
                    let mut g_ref = g0.clone();
                    collide_lattice(vs, &p, &mut f_ref, &mut g_ref, &grad,
                                    &lap, n, &pool, vvl, scalar);
                    let mut fs = vec![0.0; vs.nvel * n];
                    let mut gs = vec![0.0; vs.nvel * n];
                    stream(vs, &geom, &f_ref, &mut fs, &pool, vvl);
                    stream(vs, &geom, &g_ref, &mut gs, &pool, vvl);

                    // fused
                    let mut fd = vec![0.0; vs.nvel * n];
                    let mut gd = vec![0.0; vs.nvel * n];
                    collide_stream_lattice(vs, &p, &f0, &g0, &mut fd,
                                           &mut gd, &grad, &lap, &table, n,
                                           &pool, vvl, scalar);
                    assert_eq!(fd, fs,
                               "{} vvl={vvl} scalar={scalar}: f", vs.name);
                    assert_eq!(gd, gs,
                               "{} vvl={vvl} scalar={scalar}: g", vs.name);
                }
            }
        }
    }

    #[test]
    fn fused_threads_match_serial() {
        let vs = d3q19();
        let geom = Geometry::new(6, 5, 4);
        let n = geom.nsites();
        let p = FeParams::default();
        let (f0, g0, grad, lap) = make_state(vs, n, 77);
        let table = StreamTable::new(vs, &geom);

        let mut f1 = vec![0.0; vs.nvel * n];
        let mut g1 = vec![0.0; vs.nvel * n];
        collide_stream_lattice(vs, &p, &f0, &g0, &mut f1, &mut g1, &grad,
                               &lap, &table, n, &TlpPool::serial(), 8,
                               false);

        let pool = TlpPool::new(4, crate::targetdp::tlp::Schedule::Dynamic {
            batch: 1,
        });
        let mut f2 = vec![0.0; vs.nvel * n];
        let mut g2 = vec![0.0; vs.nvel * n];
        collide_stream_lattice(vs, &p, &f0, &g0, &mut f2, &mut g2, &grad,
                               &lap, &table, n, &pool, 8, false);
        assert_eq!(f1, f2);
        assert_eq!(g1, g2);
    }
}
