//! Initial conditions for the binary-fluid simulations.

use crate::free_energy::symmetric::FeParams;
use crate::lattice::geometry::Geometry;
use crate::lb::equilibrium::equilibrium_site;
use crate::lb::model::VelSet;

/// Deterministic xorshift64* RNG — reproducible initial noise without an
/// external crate.
#[derive(Debug, Clone)]
pub struct Rng64(u64);

impl Rng64 {
    pub fn new(seed: u64) -> Self {
        Rng64(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [-0.5, 0.5).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64 - 0.5
    }
}

/// Fill (f, g) with equilibria for given per-site (rho, phi, u) profiles.
pub fn init_equilibrium<FR, FP, FU>(vs: &VelSet, p: &FeParams,
                                    geom: &Geometry, f: &mut [f64],
                                    g: &mut [f64], rho_of: FR, phi_of: FP,
                                    u_of: FU)
where
    FR: Fn(usize, usize, usize) -> f64,
    FP: Fn(usize, usize, usize) -> f64,
    FU: Fn(usize, usize, usize) -> [f64; 3],
{
    let n = geom.nsites();
    for (x, y, z, s) in geom.iter() {
        let (fe, ge) = equilibrium_site(vs, p, rho_of(x, y, z),
                                        phi_of(x, y, z), u_of(x, y, z),
                                        [0.0; 3], 0.0);
        for i in 0..vs.nvel {
            f[i * n + s] = fe[i];
            g[i * n + s] = ge[i];
        }
    }
}

/// Spinodal quench: rho = 1, phi = small symmetric noise, u = 0.
pub fn init_spinodal(vs: &VelSet, p: &FeParams, geom: &Geometry,
                     f: &mut [f64], g: &mut [f64], amplitude: f64,
                     seed: u64) {
    let n = geom.nsites();
    let mut rng = Rng64::new(seed);
    let noise: Vec<f64> =
        (0..n).map(|_| 2.0 * amplitude * rng.uniform()).collect();
    init_equilibrium(vs, p, geom, f, g, |_, _, _| 1.0,
                     |x, y, z| noise[geom.index(x, y, z)],
                     |_, _, _| [0.0; 3]);
}

/// Circular droplet of phi = -phi* in a phi = +phi* background, with a
/// tanh profile of the equilibrium interface width.
#[allow(clippy::too_many_arguments)]
pub fn init_droplet(vs: &VelSet, p: &FeParams, geom: &Geometry,
                    f: &mut [f64], g: &mut [f64], cx: f64, cy: f64,
                    radius: f64) {
    let phi_star = p.phi_star();
    let xi = p.interface_width();
    init_equilibrium(vs, p, geom, f, g, |_, _, _| 1.0, |x, y, _| {
        let dx = x as f64 - cx;
        let dy = y as f64 - cy;
        let r = (dx * dx + dy * dy).sqrt();
        phi_star * ((r - radius) / xi).tanh()
    }, |_, _, _| [0.0; 3]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lb::model::d3q19;
    use crate::lb::moments::totals;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng64::new(42);
        let mut b = Rng64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let u = a.uniform();
        assert!((-0.5..0.5).contains(&u));
    }

    #[test]
    fn spinodal_has_unit_density_and_zero_momentum() {
        let vs = d3q19();
        let p = FeParams::default();
        let geom = Geometry::new(8, 8, 8);
        let n = geom.nsites();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        init_spinodal(vs, &p, &geom, &mut f, &mut g, 0.05, 1234);
        let (mass, mom, phi) = totals(vs, &f, &g, n);
        assert!((mass - n as f64).abs() < 1e-9);
        assert!(mom.iter().all(|&m| m.abs() < 1e-10));
        assert!(phi.abs() < 0.05 * n as f64, "noise is mean-ish-zero");
    }

    #[test]
    fn droplet_phi_signs() {
        let vs = d3q19();
        let p = FeParams::default();
        let geom = Geometry::new(32, 32, 1);
        let n = geom.nsites();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        init_droplet(vs, &p, &geom, &mut f, &mut g, 16.0, 16.0, 8.0);
        // phi at the centre is -phi*, far away +phi*
        let phi_at = |x: usize, y: usize| -> f64 {
            (0..vs.nvel).map(|i| g[i * n + geom.index(x, y, 0)]).sum()
        };
        assert!(phi_at(16, 16) < -0.9 * p.phi_star());
        assert!(phi_at(0, 0) > 0.9 * p.phi_star());
    }
}
