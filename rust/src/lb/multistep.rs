//! Host k-step temporal blocking: the `MultiStep` tier on the CPU backend.
//!
//! The fused `FullStep` still traverses the full f/g state (plus the
//! phi/gradient fields) once per timestep. [`MultiStepPlan`] amortises
//! those traversals over **k timesteps per launch** with the classic
//! structured-grid trapezoid scheme:
//!
//! 1. the lattice is partitioned into x-slabs of `slab_w` interior planes;
//! 2. each slab is gathered into a local scratch lattice extended by
//!    `2k` halo planes per side ([`HALO_PER_STEP`] planes per blocked
//!    step: one for the gradient stencil, one for streaming), filled with
//!    periodic neighbour planes — the depth-k generalisation of the
//!    [`crate::lattice::decomp::SlabDecomposition`] halo-plane copies;
//! 3. the slab advances k fused collide→push-stream timesteps while it is
//!    cache resident, the valid region shrinking by two planes per side
//!    per step (the overlap is *recomputed*, wavefront style — no
//!    inter-slab communication inside the block);
//! 4. after k steps exactly the interior planes remain valid and are
//!    scattered back to the global double buffer.
//!
//! Every per-site update (phi moment, gradient stencil, collision,
//! streaming scatter) is arithmetically independent of chunk placement,
//! so the blocked sweep is **bit-identical** to k successive `FullStep`
//! launches (`tests/multistep_parity.rs`) — including when the extended
//! slab wraps around a small lattice and some planes are redundantly
//! recomputed copies of each other.

use std::sync::Arc;

use crate::free_energy::gradient::gradient_fd_range;
use crate::free_energy::symmetric::FeParams;
use crate::lattice::geometry::Geometry;
use crate::lattice::stream_table::StreamTable;
use crate::lb::collision::collide_stream_range;
use crate::lb::model::VelSet;
use crate::lb::moments::phi_from_g_range;
use crate::obs::trace::{SpanRecorder, TracePhase, AXIS_NONE, SIDE_NONE};
use crate::targetdp::tlp::TlpPool;

/// Halo planes consumed per blocked timestep per side: one for the
/// gradient stencil plus one for streaming.
pub const HALO_PER_STEP: usize = 2;

/// Reusable blocked-sweep state for one `(geometry, model, k, slab_w)`
/// combination: the local slab geometry, its streaming table and the
/// per-slab scratch buffers (sized once, reused across launches — no
/// allocation on the step path).
pub struct MultiStepPlan {
    /// Timesteps advanced per launch.
    pub k: usize,
    /// Interior planes per slab (the last slab may be narrower).
    pub slab_w: usize,
    global: Geometry,
    nvel: usize,
    /// Extended slab geometry: `slab_w + 2 * HALO_PER_STEP * k` x-planes.
    local: Geometry,
    table: Arc<StreamTable>,
    // ping/pong distribution scratch plus the moment fields, all local
    f_a: Vec<f64>,
    g_a: Vec<f64>,
    f_b: Vec<f64>,
    g_b: Vec<f64>,
    phi: Vec<f64>,
    grad: Vec<f64>,
    lap: Vec<f64>,
}

impl MultiStepPlan {
    pub fn new(vs: &VelSet, global: Geometry, k: usize, slab_w: usize)
               -> Self {
        assert!(k >= 1, "MultiStep depth must be at least 1");
        let slab_w = slab_w.clamp(1, global.lx);
        let halo = HALO_PER_STEP * k;
        let local =
            Geometry::new(slab_w + 2 * halo, global.ly, global.lz);
        let table = StreamTable::cached(vs, &local);
        let ln = local.nsites();
        MultiStepPlan {
            k,
            slab_w,
            global,
            nvel: vs.nvel,
            local,
            table,
            f_a: vec![0.0; vs.nvel * ln],
            g_a: vec![0.0; vs.nvel * ln],
            f_b: vec![0.0; vs.nvel * ln],
            g_b: vec![0.0; vs.nvel * ln],
            phi: vec![0.0; ln],
            grad: vec![0.0; 3 * ln],
            lap: vec![0.0; ln],
        }
    }

    /// Whether this plan can serve a launch with these parameters.
    pub fn matches(&self, global: &Geometry, nvel: usize, k: usize,
                   slab_w: usize) -> bool {
        self.global == *global
            && self.nvel == nvel
            && self.k == k
            && self.slab_w == slab_w.clamp(1, global.lx)
    }

    /// Advance the whole lattice `k` timesteps: read `f`/`g` at time t,
    /// write `f_out`/`g_out` at time t+k (the engine's double buffer).
    #[allow(clippy::too_many_arguments)]
    pub fn run(&mut self, vs: &VelSet, p: &FeParams, f: &[f64], g: &[f64],
               f_out: &mut [f64], g_out: &mut [f64], pool: &TlpPool,
               vvl: usize, scalar: bool) {
        self.run_traced(vs, p, f, g, f_out, g_out, pool, vvl, scalar,
                        &mut SpanRecorder::disabled(), 0);
    }

    /// [`MultiStepPlan::run`] with phase spans: the slab gathers record
    /// as `Pack`, each blocked step's three sweeps as
    /// `Interior`/`Gradient`/`Collide` (tagged `step0 + j`), and the
    /// interior scatter as `Unpack`. With a disabled recorder this *is*
    /// `run` — tracing only reads the clock around the existing sweeps,
    /// so the output stays bit-identical either way.
    #[allow(clippy::too_many_arguments)]
    pub fn run_traced(&mut self, vs: &VelSet, p: &FeParams, f: &[f64],
                      g: &[f64], f_out: &mut [f64], g_out: &mut [f64],
                      pool: &TlpPool, vvl: usize, scalar: bool,
                      trace: &mut SpanRecorder, step0: u64) {
        let n = self.global.nsites();
        let ln = self.local.nsites();
        let plane = self.global.ly * self.global.lz;
        let lloc = self.local.lx;
        let halo = HALO_PER_STEP * self.k;
        debug_assert_eq!(vs.nvel, self.nvel);
        debug_assert_eq!(f.len(), self.nvel * n);
        debug_assert_eq!(g.len(), self.nvel * n);
        debug_assert_eq!(f_out.len(), self.nvel * n);
        debug_assert_eq!(g_out.len(), self.nvel * n);

        let nslab = self.global.lx.div_ceil(self.slab_w);
        for b in 0..nslab {
            let x0 = b * self.slab_w;
            let wb = self.slab_w.min(self.global.lx - x0);

            // gather the extended slab [x0 - halo, x0 + slab_w + halo)
            // with periodic x wrap; planes are contiguous per component
            let t0 = trace.now();
            for (q0, gx, len) in
                wrapped_runs(self.global.lx, x0 as i64 - halo as i64, lloc)
            {
                for c in 0..self.nvel {
                    let dst = c * ln + q0 * plane;
                    let src = c * n + gx * plane;
                    self.f_a[dst..dst + len * plane]
                        .copy_from_slice(&f[src..src + len * plane]);
                    self.g_a[dst..dst + len * plane]
                        .copy_from_slice(&g[src..src + len * plane]);
                }
            }
            trace.close(TracePhase::Pack, step0, AXIS_NONE, SIDE_NONE, t0);

            // k blocked timesteps, the valid window shrinking by
            // HALO_PER_STEP planes per side per step
            for j in 1..=self.k {
                let step = step0 + j as u64;
                let c0 = 2 * j - 1;
                let c1 = lloc - (2 * j - 1);
                let p0 = 2 * j - 2;
                let p1 = lloc - (2 * j - 2);
                pool.trace_context(TracePhase::Interior, step);
                let t0 = trace.now();
                phi_from_g_range(vs, &self.g_a, &mut self.phi, ln,
                                 p0 * plane..p1 * plane, pool, vvl);
                trace.close(TracePhase::Interior, step, AXIS_NONE,
                            SIDE_NONE, t0);
                pool.trace_context(TracePhase::Gradient, step);
                let t0 = trace.now();
                gradient_fd_range(&self.local, &self.phi, &mut self.grad,
                                  &mut self.lap, c0 * plane..c1 * plane,
                                  pool, vvl);
                trace.close(TracePhase::Gradient, step, AXIS_NONE,
                            SIDE_NONE, t0);
                pool.trace_context(TracePhase::Collide, step);
                let t0 = trace.now();
                collide_stream_range(vs, p, &self.f_a, &self.g_a,
                                     &mut self.f_b, &mut self.g_b,
                                     &self.grad, &self.lap, &self.table,
                                     ln, c0 * plane..c1 * plane, pool, vvl,
                                     scalar);
                trace.close(TracePhase::Collide, step, AXIS_NONE,
                            SIDE_NONE, t0);
                std::mem::swap(&mut self.f_a, &mut self.f_b);
                std::mem::swap(&mut self.g_a, &mut self.g_b);
            }

            // scatter the (now fully advanced) interior planes back
            let t0 = trace.now();
            for c in 0..self.nvel {
                let src = c * ln + halo * plane;
                let dst = c * n + x0 * plane;
                f_out[dst..dst + wb * plane]
                    .copy_from_slice(&self.f_a[src..src + wb * plane]);
                g_out[dst..dst + wb * plane]
                    .copy_from_slice(&self.g_a[src..src + wb * plane]);
            }
            trace.close(TracePhase::Unpack, step0 + self.k as u64,
                        AXIS_NONE, SIDE_NONE, t0);
        }
    }
}

/// Decompose `count` consecutive x-planes starting at (possibly negative
/// or wrapping) global plane `start` into `(local_offset, global_x, len)`
/// runs that are contiguous in both the local and the global lattice.
/// Lazy so the gather path stays allocation-free.
fn wrapped_runs(lx: usize, start: i64, count: usize)
                -> impl Iterator<Item = (usize, usize, usize)> {
    let mut q = 0usize;
    std::iter::from_fn(move || {
        if q >= count {
            return None;
        }
        let gx = (start + q as i64).rem_euclid(lx as i64) as usize;
        let len = (lx - gx).min(count - q);
        let run = (q, gx, len);
        q += len;
        Some(run)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::free_energy::gradient::gradient_fd;
    use crate::lb::collision::collide_stream_lattice;
    use crate::lb::init;
    use crate::lb::model::{d2q9, d3q19};
    use crate::lb::moments::phi_from_g;

    #[test]
    fn wrapped_runs_cover_and_wrap() {
        let runs = |lx, start, count| {
            wrapped_runs(lx, start, count).collect::<Vec<_>>()
        };
        // 12-plane lattice, extended slab [-4, 9): wraps low
        assert_eq!(runs(12, -4, 13), vec![(0, 8, 4), (4, 0, 9)]);
        // no wrap
        assert_eq!(runs(12, 3, 5), vec![(0, 3, 5)]);
        // extended extent larger than the lattice: multiple wraps
        assert_eq!(runs(4, -2, 11),
                   vec![(0, 2, 2), (2, 0, 4), (6, 0, 4), (10, 0, 1)]);
    }

    /// Reference: k global fused full steps (phi → grad → collide-stream).
    fn full_steps(vs: &VelSet, p: &FeParams, geom: &Geometry, k: usize,
                  f: &mut Vec<f64>, g: &mut Vec<f64>) {
        let n = geom.nsites();
        let pool = TlpPool::serial();
        let table = StreamTable::cached(vs, geom);
        let mut phi = vec![0.0; n];
        let mut grad = vec![0.0; 3 * n];
        let mut lap = vec![0.0; n];
        let mut f_dst = vec![0.0; vs.nvel * n];
        let mut g_dst = vec![0.0; vs.nvel * n];
        for _ in 0..k {
            phi_from_g(vs, g, &mut phi, n, &pool, 8);
            gradient_fd(geom, &phi, &mut grad, &mut lap, &pool, 8);
            collide_stream_lattice(vs, p, f, g, &mut f_dst, &mut g_dst,
                                   &grad, &lap, &table, n, &pool, 8,
                                   false);
            std::mem::swap(f, &mut f_dst);
            std::mem::swap(g, &mut g_dst);
        }
    }

    #[test]
    fn blocked_sweep_is_bitwise_equal_to_k_full_steps() {
        let p = FeParams::default();
        for (vs, geom) in [(d3q19(), Geometry::new(10, 4, 3)),
                           (d2q9(), Geometry::new(9, 6, 1))] {
            let n = geom.nsites();
            let mut f0 = vec![0.0; vs.nvel * n];
            let mut g0 = vec![0.0; vs.nvel * n];
            init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 5);

            for k in [1usize, 2, 3] {
                // slab widths: single slab, even split, uneven remainder,
                // and width 2 (heavy overlap recompute + self-wrap)
                for w in [geom.lx, 5, 4, 2] {
                    let mut f_ref = f0.clone();
                    let mut g_ref = g0.clone();
                    full_steps(vs, &p, &geom, k, &mut f_ref, &mut g_ref);

                    let mut plan = MultiStepPlan::new(vs, geom, k, w);
                    let mut f_out = vec![0.0; vs.nvel * n];
                    let mut g_out = vec![0.0; vs.nvel * n];
                    plan.run(vs, &p, &f0, &g0, &mut f_out, &mut g_out,
                             &TlpPool::serial(), 8, false);
                    assert_eq!(f_out, f_ref, "{} k={k} w={w}: f", vs.name);
                    assert_eq!(g_out, g_ref, "{} k={k} w={w}: g", vs.name);
                }
            }
        }
    }

    #[test]
    fn traced_run_is_bitwise_equal_and_labels_every_blocked_step() {
        use std::time::Instant;
        let vs = d2q9();
        let p = FeParams::default();
        let geom = Geometry::new(9, 6, 1);
        let n = geom.nsites();
        let mut f0 = vec![0.0; vs.nvel * n];
        let mut g0 = vec![0.0; vs.nvel * n];
        init::init_spinodal(vs, &p, &geom, &mut f0, &mut g0, 0.05, 5);

        let mut plan = MultiStepPlan::new(vs, geom, 2, 4);
        let mut f_ref = vec![0.0; vs.nvel * n];
        let mut g_ref = vec![0.0; vs.nvel * n];
        plan.run(vs, &p, &f0, &g0, &mut f_ref, &mut g_ref,
                 &TlpPool::serial(), 8, false);

        let mut rec = SpanRecorder::enabled(1024, Instant::now());
        let mut f_out = vec![0.0; vs.nvel * n];
        let mut g_out = vec![0.0; vs.nvel * n];
        plan.run_traced(vs, &p, &f0, &g0, &mut f_out, &mut g_out,
                        &TlpPool::serial(), 8, false, &mut rec, 10);
        assert_eq!(f_out, f_ref, "tracing must not change the state");
        assert_eq!(g_out, g_ref);

        let spans = rec.take_spans();
        assert!(!spans.is_empty());
        // every blocked step (absolute: step0 + 1..=k) shows all three
        // sweeps, and the gather/scatter bracket each slab
        for step in [11u64, 12] {
            for phase in [TracePhase::Interior, TracePhase::Gradient,
                          TracePhase::Collide] {
                assert!(spans.iter().any(|s| s.phase == phase
                                         && s.step == step),
                        "missing {phase:?} at step {step}");
            }
        }
        assert!(spans.iter().any(|s| s.phase == TracePhase::Pack));
        assert!(spans.iter().any(|s| s.phase == TracePhase::Unpack));
        assert!(spans.iter().all(|s| s.t_end >= s.t_start && s.tid == 0));
    }

    #[test]
    fn plan_matches_is_exact() {
        let vs = d3q19();
        let geom = Geometry::new(8, 4, 4);
        let plan = MultiStepPlan::new(vs, geom, 2, 4);
        assert!(plan.matches(&geom, vs.nvel, 2, 4));
        assert!(!plan.matches(&geom, vs.nvel, 3, 4));
        assert!(!plan.matches(&geom, vs.nvel, 2, 5));
        assert!(!plan.matches(&Geometry::new(8, 4, 5), vs.nvel, 2, 4));
        // widths clamp identically on both sides
        let wide = MultiStepPlan::new(vs, geom, 1, 99);
        assert!(wide.matches(&geom, vs.nvel, 1, 99));
        assert_eq!(wide.slab_w, geom.lx);
    }
}
