//! Hydrodynamic moments of the distributions (observables + the phi-moment
//! kernel feeding the gradient step).

use std::ops::Range;

use crate::lb::model::VelSet;
use crate::targetdp::tlp::TlpPool;

/// phi(s) = sum_i g_i(s), SoA layout.
pub fn phi_from_g(vs: &VelSet, g: &[f64], phi: &mut [f64], nsites: usize,
                  pool: &TlpPool, vvl: usize) {
    phi_from_g_range(vs, g, phi, nsites, 0..nsites, pool, vvl);
}

/// Ranged variant: compute phi only for the sites in `sites` (used by the
/// temporal-blocked `MultiStep` sweep, which shrinks the valid slab region
/// step by step). Per-site arithmetic is identical to the full sweep, so
/// restricting the range cannot change any computed value.
pub fn phi_from_g_range(vs: &VelSet, g: &[f64], phi: &mut [f64],
                        nsites: usize, sites: Range<usize>, pool: &TlpPool,
                        vvl: usize) {
    debug_assert_eq!(g.len(), vs.nvel * nsites);
    debug_assert_eq!(phi.len(), nsites);
    debug_assert!(sites.end <= nsites);
    let start = sites.start;
    let count = sites.len();
    let phi_ptr = SendPtr(phi.as_mut_ptr());
    pool.for_chunks(count, vvl, |base, len| {
        let phi = phi_ptr;
        for s in start + base..start + base + len {
            let mut acc = 0.0;
            for i in 0..vs.nvel {
                acc += g[i * nsites + s];
            }
            unsafe {
                *phi.0.add(s) = acc;
            }
        }
    });
}

/// Density and velocity for one site.
pub fn hydro_site(vs: &VelSet, f: &[f64], nsites: usize, s: usize)
                  -> (f64, [f64; 3]) {
    let mut rho = 0.0;
    let mut ru = [0.0f64; 3];
    for i in 0..vs.nvel {
        let fi = f[i * nsites + s];
        rho += fi;
        for a in 0..3 {
            ru[a] += vs.cv[i][a] * fi;
        }
    }
    (rho, [ru[0] / rho, ru[1] / rho, ru[2] / rho])
}

/// Global invariants: (total mass, total momentum, total phi).
pub fn totals(vs: &VelSet, f: &[f64], g: &[f64], nsites: usize)
              -> (f64, [f64; 3], f64) {
    let mut mass = 0.0;
    let mut mom = [0.0f64; 3];
    for i in 0..vs.nvel {
        for s in 0..nsites {
            let fi = f[i * nsites + s];
            mass += fi;
            for a in 0..3 {
                mom[a] += vs.cv[i][a] * fi;
            }
        }
    }
    let phi: f64 = g.iter().sum();
    (mass, mom, phi)
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lb::model::d3q19;

    #[test]
    fn phi_moment_sums_components() {
        let vs = d3q19();
        let nsites = 10;
        let mut g = vec![0.0; vs.nvel * nsites];
        for i in 0..vs.nvel {
            for s in 0..nsites {
                g[i * nsites + s] = (i + 1) as f64 * (s + 1) as f64;
            }
        }
        let mut phi = vec![0.0; nsites];
        phi_from_g(vs, &g, &mut phi, nsites, &TlpPool::serial(), 4);
        let csum: f64 = (1..=vs.nvel).map(|i| i as f64).sum();
        for s in 0..nsites {
            assert!((phi[s] - csum * (s + 1) as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn hydro_site_uniform_rest() {
        let vs = d3q19();
        let nsites = 4;
        let mut f = vec![0.0; vs.nvel * nsites];
        for i in 0..vs.nvel {
            for s in 0..nsites {
                f[i * nsites + s] = vs.wv[i];
            }
        }
        let (rho, u) = hydro_site(vs, &f, nsites, 2);
        assert!((rho - 1.0).abs() < 1e-14);
        assert!(u.iter().all(|&x| x.abs() < 1e-14));
    }
}
