//! Free-energy sector of the binary fluid: the symmetric (phi^4)
//! functional, its chemical potential and pressure tensor, and the
//! finite-difference gradient kernel that feeds the collision.

pub mod gradient;
pub mod symmetric;

pub use symmetric::FeParams;
