//! Symmetric binary free energy
//! `V(phi) = A/2 phi^2 + B/4 phi^4 + kappa/2 |grad phi|^2` (A < 0 < B),
//! the standard Ludwig/Kendon two-phase functional.
//!
//! Must agree exactly with `python/compile/kernels/ref.py` — both layers
//! compute `mu`, `p0` and `Pth` from the same formulas and the parameter
//! values baked into each AOT artifact are recorded in the manifest so the
//! host targets can be configured identically.

use crate::lb::model::CS2;

/// Free-energy + relaxation parameters (the kernel's constant memory).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FeParams {
    /// Bulk coefficient A (< 0 inside the two-phase region).
    pub a: f64,
    /// Bulk coefficient B (> 0).
    pub b: f64,
    /// Interfacial penalty kappa.
    pub kappa: f64,
    /// Order-parameter mobility prefactor Gamma.
    pub gamma: f64,
    /// Fluid relaxation time tau_f.
    pub tau_f: f64,
    /// Order-parameter relaxation time tau_g.
    pub tau_g: f64,
}

impl Default for FeParams {
    /// Identical to `ref.FreeEnergyParams()` defaults.
    fn default() -> Self {
        FeParams {
            a: -0.0625,
            b: 0.0625,
            kappa: 0.04,
            gamma: 1.0,
            tau_f: 1.0,
            tau_g: 0.8,
        }
    }
}

impl FeParams {
    /// Chemical potential `mu = A phi + B phi^3 - kappa lap(phi)`.
    #[inline(always)]
    pub fn chemical_potential(&self, phi: f64, lap_phi: f64) -> f64 {
        self.a * phi + self.b * phi * phi * phi - self.kappa * lap_phi
    }

    /// Bulk pressure `p0 = rho cs2 + A/2 phi^2 + 3B/4 phi^4`.
    #[inline(always)]
    pub fn bulk_pressure(&self, rho: f64, phi: f64) -> f64 {
        let phi2 = phi * phi;
        rho * CS2 + 0.5 * self.a * phi2 + 0.75 * self.b * phi2 * phi2
    }

    /// Isotropic part of the thermodynamic pressure tensor:
    /// `p0 - kappa phi lap - kappa/2 |grad|^2`.
    #[inline(always)]
    pub fn pth_iso(&self, rho: f64, phi: f64, grad: [f64; 3],
                   lap_phi: f64) -> f64 {
        let gsq = grad[0] * grad[0] + grad[1] * grad[1] + grad[2] * grad[2];
        self.bulk_pressure(rho, phi) - self.kappa * phi * lap_phi
            - 0.5 * self.kappa * gsq
    }

    /// Equilibrium interface width `xi = sqrt(-2 kappa / A)`.
    pub fn interface_width(&self) -> f64 {
        (-2.0 * self.kappa / self.a).sqrt()
    }

    /// Interfacial tension `sigma = sqrt(-8 kappa A^3 / 9 B^2)` for the
    /// symmetric functional (used by the droplet Laplace-law example).
    pub fn surface_tension(&self) -> f64 {
        (-8.0 * self.kappa * self.a.powi(3) / (9.0 * self.b * self.b)).sqrt()
    }

    /// Equilibrium bulk order parameter `phi* = sqrt(-A/B)`.
    pub fn phi_star(&self) -> f64 {
        (-self.a / self.b).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_python_oracle() {
        let p = FeParams::default();
        assert_eq!(p.a, -0.0625);
        assert_eq!(p.b, 0.0625);
        assert_eq!(p.kappa, 0.04);
        assert_eq!(p.gamma, 1.0);
        assert_eq!(p.tau_f, 1.0);
        assert_eq!(p.tau_g, 0.8);
    }

    #[test]
    fn chemical_potential_at_bulk_minimum_is_zero() {
        let p = FeParams::default();
        let phi_star = p.phi_star();
        assert!((p.chemical_potential(phi_star, 0.0)).abs() < 1e-14);
        assert!((p.chemical_potential(-phi_star, 0.0)).abs() < 1e-14);
        assert!(p.chemical_potential(0.5 * phi_star, 0.0) < 0.0);
    }

    #[test]
    fn bulk_pressure_ideal_gas_limit() {
        let p = FeParams::default();
        assert!((p.bulk_pressure(1.0, 0.0) - CS2).abs() < 1e-15);
    }

    #[test]
    fn pth_iso_reduces_to_p0_without_gradients() {
        let p = FeParams::default();
        let iso = p.pth_iso(1.0, 0.3, [0.0; 3], 0.0);
        assert!((iso - p.bulk_pressure(1.0, 0.3)).abs() < 1e-15);
    }

    #[test]
    fn derived_scales_positive() {
        let p = FeParams::default();
        assert!(p.interface_width() > 0.0);
        assert!(p.surface_tension() > 0.0);
        assert!((p.phi_star() - 1.0).abs() < 1e-14);
    }
}
