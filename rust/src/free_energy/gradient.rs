//! Finite-difference gradient kernel: central differences of the order
//! parameter on the periodic lattice (feeds grad(phi), lap(phi) into the
//! collision). Matches `ref.gradient_fd` (roll-based) exactly, including
//! the 2-D degenerate case `lz == 1` where the z terms cancel and the
//! laplacian reduces to the 5-point stencil.

use std::ops::Range;

use crate::lattice::geometry::Geometry;
use crate::targetdp::tlp::TlpPool;

/// grad layout: `grad[d * nsites + s]`, d in x,y,z; lap layout: `lap[s]`.
pub fn gradient_fd(geom: &Geometry, phi: &[f64], grad: &mut [f64],
                   lap: &mut [f64], pool: &TlpPool, vvl: usize) {
    gradient_fd_range(geom, phi, grad, lap, 0..geom.nsites(), pool, vvl);
}

/// Ranged variant: compute grad/lap only for the sites in `sites`. The
/// caller guarantees `phi` is valid at every periodic neighbour of those
/// sites (the MultiStep blocked sweep and the multidomain interior
/// restriction both arrange exactly that); entries outside the range are
/// left untouched.
pub fn gradient_fd_range(geom: &Geometry, phi: &[f64], grad: &mut [f64],
                         lap: &mut [f64], sites: Range<usize>,
                         pool: &TlpPool, vvl: usize) {
    let n = geom.nsites();
    debug_assert_eq!(phi.len(), n);
    debug_assert_eq!(grad.len(), 3 * n);
    debug_assert_eq!(lap.len(), n);
    debug_assert!(sites.end <= n);
    let start = sites.start;
    let count = sites.len();

    // SAFETY of the parallel writes: chunks partition the site range, and
    // each site writes only its own grad/lap entries.
    let grad_ptr = SendPtr(grad.as_mut_ptr());
    let lap_ptr = SendPtr(lap.as_mut_ptr());

    pool.for_chunks(count, vvl, |base, len| {
        let grad = grad_ptr;
        let lap = lap_ptr;
        for s in start + base..start + base + len {
            let (x, y, z) = geom.coords(s);
            let xp = phi[geom.neighbor(x, y, z, 1, 0, 0)];
            let xm = phi[geom.neighbor(x, y, z, -1, 0, 0)];
            let yp = phi[geom.neighbor(x, y, z, 0, 1, 0)];
            let ym = phi[geom.neighbor(x, y, z, 0, -1, 0)];
            let zp = phi[geom.neighbor(x, y, z, 0, 0, 1)];
            let zm = phi[geom.neighbor(x, y, z, 0, 0, -1)];
            unsafe {
                *grad.0.add(s) = 0.5 * (xp - xm);
                *grad.0.add(n + s) = 0.5 * (yp - ym);
                *grad.0.add(2 * n + s) = 0.5 * (zp - zm);
                *lap.0.add(s) = xp + xm + yp + ym + zp + zm - 6.0 * phi[s];
            }
        }
    });
}

/// Raw pointer wrapper to move disjoint-write pointers into TLP closures.
#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(geom: &Geometry, phi: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let n = geom.nsites();
        let mut grad = vec![0.0; 3 * n];
        let mut lap = vec![0.0; n];
        gradient_fd(geom, phi, &mut grad, &mut lap, &TlpPool::serial(), 8);
        (grad, lap)
    }

    #[test]
    fn constant_field_zero_gradient() {
        let geom = Geometry::new(4, 4, 4);
        let phi = vec![0.7; geom.nsites()];
        let (grad, lap) = run(&geom, &phi);
        assert!(grad.iter().all(|&v| v.abs() < 1e-15));
        assert!(lap.iter().all(|&v| v.abs() < 1e-13));
    }

    #[test]
    fn sinusoid_matches_discrete_derivative() {
        let l = 16usize;
        let geom = Geometry::new(l, 4, 4);
        let k = 2.0 * std::f64::consts::PI / l as f64;
        let phi: Vec<f64> = (0..geom.nsites())
            .map(|s| {
                let (x, _, _) = geom.coords(s);
                (k * x as f64).sin()
            })
            .collect();
        let (grad, lap) = run(&geom, &phi);
        let n = geom.nsites();
        for s in 0..n {
            let (x, _, _) = geom.coords(s);
            let gx = (k * x as f64).cos() * k.sin();
            assert!((grad[s] - gx).abs() < 1e-12, "site {s}");
            assert!(grad[n + s].abs() < 1e-13);
            assert!(grad[2 * n + s].abs() < 1e-13);
            let want_lap = (2.0 * k.cos() - 2.0) * (k * x as f64).sin();
            assert!((lap[s] - want_lap).abs() < 1e-12);
        }
    }

    #[test]
    fn degenerate_z_reduces_to_2d_stencil() {
        // lz == 1: zp == zm == self, so lap = 5-point 2-D stencil
        let geom = Geometry::new(4, 4, 1);
        let mut phi = vec![0.0; geom.nsites()];
        phi[geom.index(2, 2, 0)] = 1.0;
        let (_, lap) = run(&geom, &phi);
        assert!((lap[geom.index(2, 2, 0)] + 4.0).abs() < 1e-15);
        assert!((lap[geom.index(1, 2, 0)] - 1.0).abs() < 1e-15);
        assert!((lap[geom.index(2, 1, 0)] - 1.0).abs() < 1e-15);
        assert!(lap[geom.index(1, 1, 0)].abs() < 1e-15);
    }

    #[test]
    fn ranged_matches_full_inside_and_leaves_rest_alone() {
        let geom = Geometry::new(6, 5, 4);
        let n = geom.nsites();
        let phi: Vec<f64> = (0..n)
            .map(|s| ((s * 2654435761) % 113) as f64 / 113.0)
            .collect();
        let (g_full, l_full) = run(&geom, &phi);
        let range = 2 * 20..4 * 20; // planes 2..4 (plane = ly * lz = 20)
        let mut g = vec![-9.0; 3 * n];
        let mut l = vec![-9.0; n];
        gradient_fd_range(&geom, &phi, &mut g, &mut l, range.clone(),
                          &TlpPool::serial(), 8);
        for s in 0..n {
            if range.contains(&s) {
                for d in 0..3 {
                    assert_eq!(g[d * n + s], g_full[d * n + s], "s={s}");
                }
                assert_eq!(l[s], l_full[s], "s={s}");
            } else {
                assert_eq!(l[s], -9.0, "s={s} must be untouched");
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let geom = Geometry::new(8, 8, 8);
        let phi: Vec<f64> = (0..geom.nsites())
            .map(|s| ((s * 2654435761) % 997) as f64 / 997.0)
            .collect();
        let (g1, l1) = run(&geom, &phi);
        let n = geom.nsites();
        let mut g2 = vec![0.0; 3 * n];
        let mut l2 = vec![0.0; n];
        let pool = TlpPool::new(4, crate::targetdp::tlp::Schedule::Dynamic {
            batch: 3,
        });
        gradient_fd(&geom, &phi, &mut g2, &mut l2, &pool, 4);
        assert_eq!(g1, g2);
        assert_eq!(l1, l2);
    }
}
