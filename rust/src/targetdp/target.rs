//! The [`Target`] abstraction: one trait, three backends.
//!
//! The paper keeps a strict host/target distinction *even when the target
//! is the host CPU itself* (section III-A); lattice data has a master copy
//! in target memory and all lattice operations are launched on the target.
//! This trait is the Rust rendering of that contract: the memory-plane
//! methods map 1:1 onto the paper's C API, and the compute plane replaces
//! the `TARGET_ENTRY`/`TARGET_LAUNCH` single-source macros with a named
//! kernel registry ([`KernelId`]) — each backend provides its own compiled
//! implementation of every kernel it supports (DESIGN.md section 10).

use crate::error::{Error, Result};
use crate::lattice::geometry::Geometry;
use crate::lb::model::LatticeModel;

use super::constant::Constant;
use super::memory::{BufId, FieldDesc};

/// Which hardware story a target tells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TargetKind {
    /// Per-site loops, compiler left to find ILP (the "original" style).
    HostScalar,
    /// VVL strip-mined chunks for the auto-vectorizer (targetDP CPU).
    HostSimd,
    /// AOT-compiled JAX/Pallas executables on the PJRT client (the
    /// accelerator analog of the paper's CUDA implementation).
    Xla,
}

/// The lattice kernels known to the framework.
///
/// Host targets implement them in Rust ([`crate::lb`], [`crate::free_energy`]);
/// the XLA target maps them onto AOT artifacts from `artifacts/manifest.json`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelId {
    /// Scale a vector field by the constant `scale_a` (paper section III).
    Scale,
    /// phi(s) = sum_i g_i(s).
    PhiMoment,
    /// Central-difference gradient + laplacian of a periodic scalar field.
    Gradient,
    /// The paper's Figure-1 hot spot: binary-fluid BGK collision.
    BinaryCollision,
    /// LB propagation (pull streaming) for one distribution.
    Stream,
    /// One fused LB timestep (gradients + collision + streaming).
    FullStep,
    /// `steps` fused LB timesteps in one launch.
    MultiStep,
    /// Per-component lattice sum: `result[c] = sum_s field[c][s]` — the
    /// reduction extension the paper's §V names as future work.
    ReduceSum,
}

impl KernelId {
    /// Stable snake_case name (artifact manifests key on it).
    pub fn name(&self) -> &'static str {
        match self {
            KernelId::Scale => "scale",
            KernelId::PhiMoment => "phi_moment",
            KernelId::Gradient => "gradient",
            KernelId::BinaryCollision => "binary_collision",
            KernelId::Stream => "stream",
            KernelId::FullStep => "full_step",
            KernelId::MultiStep => "multi_step",
            KernelId::ReduceSum => "reduce_sum",
        }
    }
}

/// Named buffer bindings + lattice context for a kernel launch
/// (the argument list of the paper's `kernel TARGET_LAUNCH(N) (args)`).
#[derive(Debug, Clone)]
pub struct LaunchArgs {
    /// Lattice extents the kernel sweeps.
    pub geometry: Geometry,
    /// Velocity-set model the kernel is specialised for.
    pub model: LatticeModel,
    bufs: Vec<(&'static str, BufId)>,
}

impl LaunchArgs {
    /// Start an argument list with no buffer bindings.
    pub fn new(geometry: Geometry, model: LatticeModel) -> Self {
        LaunchArgs { geometry, model, bufs: Vec::new() }
    }

    /// Bind a target buffer to a kernel parameter name.
    pub fn bind(mut self, name: &'static str, id: BufId) -> Self {
        self.bufs.push((name, id));
        self
    }

    /// Look up the buffer bound to `name` (error when unbound).
    pub fn buf(&self, name: &str) -> Result<BufId> {
        self.bufs
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, id)| *id)
            .ok_or_else(|| {
                Error::Invalid(format!("launch missing buffer binding {name:?}"))
            })
    }

    /// All `(name, buffer)` bindings, in bind order.
    pub fn bindings(&self) -> &[(&'static str, BufId)] {
        &self.bufs
    }
}

/// A targetDP execution target (host CPU or accelerator).
pub trait Target {
    /// Which hardware story this target tells.
    fn kind(&self) -> TargetKind;

    /// Diagnostic name, e.g. `host-simd(vvl=8,threads=1)`.
    fn describe(&self) -> String;

    /// `targetMalloc`.
    fn malloc(&mut self, desc: &FieldDesc) -> Result<BufId>;

    /// `targetFree`.
    fn free(&mut self, id: BufId) -> Result<()>;

    /// `copyToTarget` (full lattice).
    fn copy_to_target(&mut self, id: BufId, host: &[f64]) -> Result<()>;

    /// `copyFromTarget` (full lattice).
    fn copy_from_target(&mut self, id: BufId, host: &mut [f64]) -> Result<()>;

    /// `copyToTargetMasked`: transfer only the sites flagged in `mask`
    /// (one flag per site; all components of a selected site move).
    fn copy_to_target_masked(&mut self, id: BufId, host: &[f64],
                             mask: &[bool]) -> Result<()>;

    /// `copyFromTargetMasked`.
    fn copy_from_target_masked(&mut self, id: BufId, host: &mut [f64],
                               mask: &[bool]) -> Result<()>;

    /// `copyConstant<X>ToTarget`.
    fn copy_constant(&mut self, name: &str, value: Constant) -> Result<()>;

    /// Whether this backend has an implementation of `kernel`.
    fn supports(&self, kernel: KernelId) -> bool;

    /// If the backend has a k-step fused `MultiStep` kernel for this
    /// geometry/model, the number of timesteps one launch advances.
    fn multi_step_width(&self, _geom: &Geometry,
                        _model: LatticeModel) -> Option<u64> {
        None
    }

    /// `kernel TARGET_LAUNCH(N) (args)`: run a lattice kernel on the target.
    fn launch(&mut self, kernel: KernelId, args: &LaunchArgs) -> Result<()>;

    /// `syncTarget`.
    fn sync(&mut self) -> Result<()>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_args_bindings() {
        let args = LaunchArgs::new(Geometry::new(4, 4, 4), LatticeModel::D3Q19)
            .bind("f", 0)
            .bind("g", 1);
        assert_eq!(args.buf("f").unwrap(), 0);
        assert_eq!(args.buf("g").unwrap(), 1);
        assert!(args.buf("phi").is_err());
        assert_eq!(args.bindings().len(), 2);
    }

    #[test]
    fn kernel_names_are_stable() {
        assert_eq!(KernelId::BinaryCollision.name(), "binary_collision");
        assert_eq!(KernelId::MultiStep.name(), "multi_step");
    }
}
