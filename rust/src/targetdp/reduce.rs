//! Lattice reductions — the extension the paper names in §V ("we also
//! plan to extend the library to provide more lattice-based operations
//! such as reductions, which at the moment ... must be implemented using
//! the lower level CUDA/OpenMP syntax directly").
//!
//! Provided as a first-class kernel: per-component sum over all lattice
//! sites of an SoA field (`result[c] = sum_s field[c][s]`), with the same
//! TLP x ILP execution model as every other kernel — the site loop is
//! strip-mined into VVL chunks, each chunk produces a partial sum, and
//! partials combine in chunk order so the result is *deterministic* for a
//! fixed (nsites, vvl), independent of thread count or schedule.

use std::ops::Range;

use crate::targetdp::tlp::TlpPool;

/// Per-component lattice sum. `field`: `ncomp * nsites` SoA; `out`: ncomp.
pub fn reduce_sum(field: &[f64], ncomp: usize, nsites: usize,
                  pool: &TlpPool, vvl: usize, out: &mut [f64]) {
    reduce_sum_range(field, ncomp, nsites, 0..nsites, pool, vvl, out);
}

/// Ranged variant: per-component sum over only the sites in `sites` (used
/// by the comms ranks, whose observable partials reduce the interior of a
/// halo-padded local lattice). Chunk order is fixed by
/// (`sites.len()`, `vvl`), so the result is deterministic for a given
/// range, independent of thread count or schedule.
pub fn reduce_sum_range(field: &[f64], ncomp: usize, nsites: usize,
                        sites: Range<usize>, pool: &TlpPool, vvl: usize,
                        out: &mut [f64]) {
    debug_assert_eq!(field.len(), ncomp * nsites);
    debug_assert_eq!(out.len(), ncomp);
    debug_assert!(sites.end <= nsites);
    let start = sites.start;
    let count = sites.len();
    if count == 0 {
        out.fill(0.0);
        return;
    }

    // one partial per (chunk, component), written disjointly by chunks
    let nchunks = count.div_ceil(vvl);
    let mut partials = vec![0.0f64; nchunks * ncomp];
    let ptr = SendPtr(partials.as_mut_ptr());
    pool.for_chunks(count, vvl, |base, len| {
        let ptr = ptr;
        let chunk = base / vvl;
        for c in 0..ncomp {
            let lo = c * nsites + start + base;
            let row = &field[lo..lo + len];
            // TARGET_ILP: fixed-extent lane loop the compiler vectorises
            let mut acc = 0.0;
            for v in row {
                acc += v;
            }
            unsafe {
                *ptr.0.add(chunk * ncomp + c) = acc;
            }
        }
    });

    // deterministic combine in chunk order
    out.fill(0.0);
    for chunk in 0..nchunks {
        for c in 0..ncomp {
            out[c] += partials[chunk * ncomp + c];
        }
    }
}

/// Deterministic sum of squares of a single-component field over the
/// sites in `sites` — the second moment the distributed phi-variance
/// reduction needs. Same TLP × ILP strip-mining and chunk-order combine
/// as [`reduce_sum_range`].
pub fn reduce_sum_sq_range(field: &[f64], nsites: usize,
                           sites: Range<usize>, pool: &TlpPool, vvl: usize)
                           -> f64 {
    debug_assert_eq!(field.len(), nsites);
    debug_assert!(sites.end <= nsites);
    let start = sites.start;
    let count = sites.len();
    if count == 0 {
        return 0.0;
    }
    let nchunks = count.div_ceil(vvl);
    let mut partials = vec![0.0f64; nchunks];
    let ptr = SendPtr(partials.as_mut_ptr());
    pool.for_chunks(count, vvl, |base, len| {
        let ptr = ptr;
        let chunk = base / vvl;
        let row = &field[start + base..start + base + len];
        // TARGET_ILP: fixed-extent lane loop the compiler vectorises
        let mut acc = 0.0;
        for v in row {
            acc += v * v;
        }
        unsafe {
            *ptr.0.add(chunk) = acc;
        }
    });
    partials.iter().sum()
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::targetdp::tlp::Schedule;

    fn field(ncomp: usize, nsites: usize) -> Vec<f64> {
        (0..ncomp * nsites).map(|i| (i % 97) as f64 * 0.25).collect()
    }

    fn expected(f: &[f64], ncomp: usize, nsites: usize) -> Vec<f64> {
        (0..ncomp)
            .map(|c| f[c * nsites..(c + 1) * nsites].iter().sum())
            .collect()
    }

    #[test]
    fn sums_per_component() {
        let (ncomp, nsites) = (3, 100);
        let f = field(ncomp, nsites);
        let mut out = vec![0.0; ncomp];
        reduce_sum(&f, ncomp, nsites, &TlpPool::serial(), 8, &mut out);
        let want = expected(&f, ncomp, nsites);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn deterministic_across_schedules() {
        let (ncomp, nsites) = (19, 333);
        let f = field(ncomp, nsites);
        let mut ref_out = vec![0.0; ncomp];
        reduce_sum(&f, ncomp, nsites, &TlpPool::serial(), 8, &mut ref_out);
        for pool in [TlpPool::new(3, Schedule::Static),
                     TlpPool::new(4, Schedule::Dynamic { batch: 2 })] {
            let mut out = vec![0.0; ncomp];
            reduce_sum(&f, ncomp, nsites, &pool, 8, &mut out);
            assert_eq!(out, ref_out, "bitwise deterministic");
        }
    }

    #[test]
    fn vvl_changes_grouping_not_value() {
        let (ncomp, nsites) = (2, 257);
        let f = field(ncomp, nsites);
        let want = expected(&f, ncomp, nsites);
        for vvl in [1, 4, 32] {
            let mut out = vec![0.0; ncomp];
            reduce_sum(&f, ncomp, nsites, &TlpPool::serial(), vvl, &mut out);
            for (a, b) in out.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9, "vvl={vvl}");
            }
        }
    }

    #[test]
    fn empty_lattice() {
        let mut out = vec![1.0; 2];
        reduce_sum(&[], 2, 0, &TlpPool::serial(), 8, &mut out);
        assert_eq!(out, vec![0.0, 0.0]);
    }

    #[test]
    fn ranged_sum_matches_manual_range() {
        let (ncomp, nsites) = (4, 61);
        let f = field(ncomp, nsites);
        let range = 9..47;
        let want: Vec<f64> = (0..ncomp)
            .map(|c| {
                f[c * nsites + range.start..c * nsites + range.end]
                    .iter()
                    .sum()
            })
            .collect();
        let mut out = vec![0.0; ncomp];
        reduce_sum_range(&f, ncomp, nsites, range.clone(),
                         &TlpPool::serial(), 8, &mut out);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-10);
        }
        // bitwise deterministic across pools, like the full reduction
        for pool in [TlpPool::new(3, Schedule::Static),
                     TlpPool::new(2, Schedule::Dynamic { batch: 3 })] {
            let mut got = vec![0.0; ncomp];
            reduce_sum_range(&f, ncomp, nsites, range.clone(), &pool, 8,
                             &mut got);
            assert_eq!(got, out);
        }
        // empty range is a zero sum
        let mut out = vec![1.0; ncomp];
        reduce_sum_range(&f, ncomp, nsites, 5..5, &TlpPool::serial(), 8,
                         &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn ranged_sum_of_squares() {
        let nsites = 37;
        let f: Vec<f64> =
            (0..nsites).map(|i| (i as f64 - 11.0) * 0.5).collect();
        let range = 4..30;
        let want: f64 = f[range.clone()].iter().map(|v| v * v).sum();
        let got = reduce_sum_sq_range(&f, nsites, range.clone(),
                                      &TlpPool::serial(), 8);
        assert!((got - want).abs() < 1e-10);
        // deterministic across pools
        for pool in [TlpPool::new(4, Schedule::Static),
                     TlpPool::new(3, Schedule::Dynamic { batch: 2 })] {
            let again =
                reduce_sum_sq_range(&f, nsites, range.clone(), &pool, 8);
            assert_eq!(again.to_bits(), got.to_bits());
        }
        assert_eq!(reduce_sum_sq_range(&f, nsites, 12..12,
                                       &TlpPool::serial(), 8),
                   0.0);
    }
}
