//! Host CPU backends: the paper's C/OpenMP implementation of targetDP.
//!
//! Two modes of the same backend:
//!
//! * [`HostMode::Scalar`] — kernels run site-at-a-time; the compiler is
//!   left to discover ILP (the paper's pre-targetDP structure, but on SoA
//!   data; the AoS "original" lives in [`crate::baseline`]).
//! * [`HostMode::Simd`] — the targetDP execution model: `TARGET_TLP`
//!   strip-mines the site loop into VVL chunks distributed over threads
//!   ([`TlpPool`]), and `TARGET_ILP` lane loops of compile-time extent VVL
//!   run inside each chunk ([`crate::dispatch_vvl!`]).
//!
//! Host and target memory are distinct allocations even though both live
//! in DRAM — the paper keeps the same distinction for the CPU target
//! (section III-A), which is what lets the identical application code also
//! drive the XLA backend.
//!
//! # The host fusion tier
//!
//! Besides the five per-step kernels, the host backend implements the
//! fused [`KernelId::FullStep`]: one launch advances a whole timestep,
//! with the collision chunk scattered straight to its streaming
//! destinations ([`crate::lb::collision::collide_stream_lattice`] over a
//! cached [`StreamTable`]). That removes the separate `Stream` sweeps —
//! per step, f and g are each read and written **once** instead of twice
//! (4 → 2 full 19-component traversals) — the same "keep the master copy
//! resident and fuse" optimisation the XLA backend gets from its AOT
//! executables, picked up by the engine's `supports(FullStep)` dispatch
//! with no application-code change. Fused and unfused pipelines agree
//! bit-for-bit (`tests/fused_parity.rs`).

use crate::error::{Error, Result};
use crate::free_energy::gradient::gradient_fd;
use crate::free_energy::symmetric::FeParams;
use crate::lattice::stream_table::StreamTable;
use crate::lb::collision::{collide_lattice, collide_stream_lattice};
use crate::lb::moments::phi_from_g;
use crate::lb::propagation::stream_with_table;

use super::constant::{Constant, ConstantTable};
use super::ilp;
use super::masked;
use super::memory::{BufId, FieldDesc, HostPool};
use super::target::{KernelId, LaunchArgs, Target, TargetKind};
use super::tlp::TlpPool;

/// Execution mode of the host backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostMode {
    /// Per-site loops, compiler-found ILP.
    Scalar,
    /// VVL strip-mined lane kernels (the targetDP model).
    Simd,
}

/// Host CPU target.
pub struct HostTarget {
    mode: HostMode,
    vvl: usize,
    pool: TlpPool,
    bufs: HostPool,
    constants: ConstantTable,
}

impl HostTarget {
    /// targetDP SIMD mode with the given VVL (must be in
    /// [`ilp::SUPPORTED_VVL`]) and TLP pool.
    pub fn simd(vvl: usize, pool: TlpPool) -> Result<Self> {
        if !ilp::is_supported(vvl) {
            return Err(Error::Invalid(format!(
                "VVL {vvl} unsupported; pick one of {:?}",
                ilp::SUPPORTED_VVL
            )));
        }
        Ok(HostTarget {
            mode: HostMode::Simd,
            vvl,
            pool,
            bufs: HostPool::new(),
            constants: ConstantTable::new(),
        })
    }

    /// Scalar mode (site loops; chunking still used for TLP decomposition).
    pub fn scalar(pool: TlpPool) -> Self {
        HostTarget {
            mode: HostMode::Scalar,
            vvl: 32, // TLP chunk granularity only; no lane kernels
            pool,
            bufs: HostPool::new(),
            constants: ConstantTable::new(),
        }
    }

    /// Serial SIMD target with the paper's optimal CPU VVL (8).
    pub fn default_simd() -> Self {
        Self::simd(8, TlpPool::serial()).expect("8 is a supported VVL")
    }

    pub fn vvl(&self) -> usize {
        self.vvl
    }

    pub fn mode(&self) -> HostMode {
        self.mode
    }

    /// Free-energy parameters from the constant table (set by the engine
    /// via `copyConstant*ToTarget`; defaults if unset).
    fn fe_params(&self) -> FeParams {
        let d = FeParams::default();
        FeParams {
            a: self.constants.get_double("fe_a").unwrap_or(d.a),
            b: self.constants.get_double("fe_b").unwrap_or(d.b),
            kappa: self.constants.get_double("fe_kappa").unwrap_or(d.kappa),
            gamma: self.constants.get_double("fe_gamma").unwrap_or(d.gamma),
            tau_f: self.constants.get_double("tau_f").unwrap_or(d.tau_f),
            tau_g: self.constants.get_double("tau_g").unwrap_or(d.tau_g),
        }
    }
}

impl Target for HostTarget {
    fn kind(&self) -> TargetKind {
        match self.mode {
            HostMode::Scalar => TargetKind::HostScalar,
            HostMode::Simd => TargetKind::HostSimd,
        }
    }

    fn describe(&self) -> String {
        match self.mode {
            HostMode::Scalar => {
                format!("host-scalar(threads={})", self.pool.nthreads)
            }
            HostMode::Simd => format!(
                "host-simd(vvl={},threads={})",
                self.vvl, self.pool.nthreads
            ),
        }
    }

    fn malloc(&mut self, desc: &FieldDesc) -> Result<BufId> {
        Ok(self.bufs.malloc(desc))
    }

    fn free(&mut self, id: BufId) -> Result<()> {
        self.bufs.free(id);
        Ok(())
    }

    fn copy_to_target(&mut self, id: BufId, host: &[f64]) -> Result<()> {
        self.bufs.copy_in(id, host)
    }

    fn copy_from_target(&mut self, id: BufId, host: &mut [f64]) -> Result<()> {
        self.bufs.copy_out(id, host)
    }

    fn copy_to_target_masked(&mut self, id: BufId, host: &[f64],
                             mask: &[bool]) -> Result<()> {
        let buf = self.bufs.get_mut(id)?;
        let (ncomp, nsites) = (buf.desc.ncomp, buf.desc.nsites);
        if host.len() != buf.data.len() || mask.len() != nsites {
            return Err(Error::Invalid(format!(
                "masked copyToTarget size mismatch for {}", buf.desc.name
            )));
        }
        masked::copy_masked_direct(&mut buf.data, host, nsites, ncomp, mask);
        Ok(())
    }

    fn copy_from_target_masked(&mut self, id: BufId, host: &mut [f64],
                               mask: &[bool]) -> Result<()> {
        let buf = self.bufs.get(id)?;
        let (ncomp, nsites) = (buf.desc.ncomp, buf.desc.nsites);
        if host.len() != buf.data.len() || mask.len() != nsites {
            return Err(Error::Invalid(format!(
                "masked copyFromTarget size mismatch for {}", buf.desc.name
            )));
        }
        masked::copy_masked_direct(host, &buf.data, nsites, ncomp, mask);
        Ok(())
    }

    fn copy_constant(&mut self, name: &str, value: Constant) -> Result<()> {
        self.constants.set(name, value);
        Ok(())
    }

    fn supports(&self, kernel: KernelId) -> bool {
        // FullStep is native (the fused collide→stream sweep); only the
        // k-step MultiStep remains an accelerator-only artifact kernel.
        !matches!(kernel, KernelId::MultiStep)
    }

    fn launch(&mut self, kernel: KernelId, args: &LaunchArgs) -> Result<()> {
        let vs = args.model.velset();
        let scalar = self.mode == HostMode::Scalar;
        match kernel {
            KernelId::Scale => {
                let a = self.constants.get_double("scale_a")?;
                let buf = self.bufs.get_mut(args.buf("field")?)?;
                let (ncomp, nsites) = (buf.desc.ncomp, buf.desc.nsites);
                let data = SendMut(buf.data.as_mut_ptr(), buf.data.len());
                self.pool.for_chunks(nsites, self.vvl, |base, len| {
                    let data = data; // capture the Send+Sync wrapper whole
                    let data =
                        unsafe { std::slice::from_raw_parts_mut(data.0, data.1) };
                    for c in 0..ncomp {
                        let row = &mut data[c * nsites..(c + 1) * nsites];
                        for v in row[base..base + len].iter_mut() {
                            *v *= a;
                        }
                    }
                });
                Ok(())
            }
            KernelId::PhiMoment => {
                let g = self.bufs.take(args.buf("g")?)?;
                let mut phi = self.bufs.take(args.buf("phi")?)?;
                let n = phi.desc.nsites;
                phi_from_g(vs, &g.data, &mut phi.data, n, &self.pool,
                           self.vvl);
                self.bufs.restore(args.buf("g")?, g);
                self.bufs.restore(args.buf("phi")?, phi);
                Ok(())
            }
            KernelId::Gradient => {
                let phi = self.bufs.take(args.buf("phi")?)?;
                let mut grad = self.bufs.take(args.buf("grad")?)?;
                let mut lap = self.bufs.take(args.buf("lap")?)?;
                gradient_fd(&args.geometry, &phi.data, &mut grad.data,
                            &mut lap.data, &self.pool, self.vvl);
                self.bufs.restore(args.buf("phi")?, phi);
                self.bufs.restore(args.buf("grad")?, grad);
                self.bufs.restore(args.buf("lap")?, lap);
                Ok(())
            }
            KernelId::BinaryCollision => {
                let p = self.fe_params();
                let mut f = self.bufs.take(args.buf("f")?)?;
                let mut g = self.bufs.take(args.buf("g")?)?;
                let grad = self.bufs.take(args.buf("grad")?)?;
                let lap = self.bufs.take(args.buf("lap")?)?;
                let n = lap.desc.nsites;
                collide_lattice(vs, &p, &mut f.data, &mut g.data, &grad.data,
                                &lap.data, n, &self.pool, self.vvl, scalar);
                self.bufs.restore(args.buf("f")?, f);
                self.bufs.restore(args.buf("g")?, g);
                self.bufs.restore(args.buf("grad")?, grad);
                self.bufs.restore(args.buf("lap")?, lap);
                Ok(())
            }
            KernelId::Stream => {
                let table = StreamTable::cached(vs, &args.geometry);
                let src = self.bufs.take(args.buf("src")?)?;
                let mut dst = self.bufs.take(args.buf("dst")?)?;
                stream_with_table(vs, &table, &src.data, &mut dst.data,
                                  &self.pool, self.vvl);
                self.bufs.restore(args.buf("src")?, src);
                self.bufs.restore(args.buf("dst")?, dst);
                Ok(())
            }
            KernelId::FullStep => {
                // the fused tier: phi moment + gradients feed one
                // collide→push-stream sweep into the *_tmp buffers, then
                // the data vectors swap — in-place step semantics for the
                // engine, 2 instead of 4 full f/g traversals
                let p = self.fe_params();
                let (f_id, g_id) = (args.buf("f")?, args.buf("g")?);
                let (ft_id, gt_id) = (args.buf("f_tmp")?, args.buf("g_tmp")?);
                let (phi_id, grad_id, lap_id) =
                    (args.buf("phi")?, args.buf("grad")?, args.buf("lap")?);
                let table = StreamTable::cached(vs, &args.geometry);

                let mut f = self.bufs.take(f_id)?;
                let mut g = self.bufs.take(g_id)?;
                let mut f_tmp = self.bufs.take(ft_id)?;
                let mut g_tmp = self.bufs.take(gt_id)?;
                let mut phi = self.bufs.take(phi_id)?;
                let mut grad = self.bufs.take(grad_id)?;
                let mut lap = self.bufs.take(lap_id)?;

                let n = phi.desc.nsites;
                phi_from_g(vs, &g.data, &mut phi.data, n, &self.pool,
                           self.vvl);
                gradient_fd(&args.geometry, &phi.data, &mut grad.data,
                            &mut lap.data, &self.pool, self.vvl);
                collide_stream_lattice(vs, &p, &f.data, &g.data,
                                       &mut f_tmp.data, &mut g_tmp.data,
                                       &grad.data, &lap.data, &table, n,
                                       &self.pool, self.vvl, scalar);
                std::mem::swap(&mut f.data, &mut f_tmp.data);
                std::mem::swap(&mut g.data, &mut g_tmp.data);

                self.bufs.restore(f_id, f);
                self.bufs.restore(g_id, g);
                self.bufs.restore(ft_id, f_tmp);
                self.bufs.restore(gt_id, g_tmp);
                self.bufs.restore(phi_id, phi);
                self.bufs.restore(grad_id, grad);
                self.bufs.restore(lap_id, lap);
                Ok(())
            }
            KernelId::ReduceSum => {
                let field = self.bufs.take(args.buf("field")?)?;
                let mut result = self.bufs.take(args.buf("result")?)?;
                let (ncomp, nsites) =
                    (field.desc.ncomp, field.desc.nsites);
                if result.desc.len() != ncomp {
                    let e = Error::Invalid(format!(
                        "reduce_sum result buffer has {} elements, field \
                         has {ncomp} components",
                        result.desc.len()
                    ));
                    self.bufs.restore(args.buf("field")?, field);
                    self.bufs.restore(args.buf("result")?, result);
                    return Err(e);
                }
                super::reduce::reduce_sum(&field.data, ncomp, nsites,
                                          &self.pool, self.vvl,
                                          &mut result.data);
                self.bufs.restore(args.buf("field")?, field);
                self.bufs.restore(args.buf("result")?, result);
                Ok(())
            }
            KernelId::MultiStep => Err(Error::UnsupportedKernel {
                target: self.describe(),
                kernel: kernel.name().into(),
            }),
        }
    }

    fn sync(&mut self) -> Result<()> {
        // host launches are synchronous (the paper's C syncTarget no-op)
        Ok(())
    }
}

#[derive(Clone, Copy)]
struct SendMut(*mut f64, usize);
unsafe impl Send for SendMut {}
unsafe impl Sync for SendMut {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::geometry::Geometry;
    use crate::lb::model::LatticeModel;

    fn scale_args(field: BufId) -> LaunchArgs {
        LaunchArgs::new(Geometry::new(4, 4, 4), LatticeModel::D3Q19)
            .bind("field", field)
    }

    #[test]
    fn scale_kernel_paper_example() {
        // the paper's section III running example end to end
        for target in [&mut HostTarget::scalar(TlpPool::serial()),
                       &mut HostTarget::default_simd()] {
            let n = 64;
            let desc = FieldDesc::new("field", 3, n);
            let host: Vec<f64> = (0..3 * n).map(|i| i as f64).collect();

            let t_field = target.malloc(&desc).unwrap();
            target.copy_to_target(t_field, &host).unwrap();
            target
                .copy_constant("scale_a", Constant::Double(1.5))
                .unwrap();
            target.launch(KernelId::Scale, &scale_args(t_field)).unwrap();
            target.sync().unwrap();

            let mut out = vec![0.0; 3 * n];
            target.copy_from_target(t_field, &mut out).unwrap();
            target.free(t_field).unwrap();
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, 1.5 * i as f64);
            }
        }
    }

    #[test]
    fn scale_requires_constant() {
        let mut t = HostTarget::default_simd();
        let id = t.malloc(&FieldDesc::new("field", 3, 8)).unwrap();
        assert!(t.launch(KernelId::Scale, &scale_args(id)).is_err());
    }

    #[test]
    fn masked_copies_only_touch_selected_sites() {
        let mut t = HostTarget::default_simd();
        let n = 8;
        let id = t.malloc(&FieldDesc::new("x", 2, n)).unwrap();
        let host: Vec<f64> = (0..2 * n).map(|i| i as f64).collect();
        let mask: Vec<bool> = (0..n).map(|s| s % 2 == 0).collect();
        t.copy_to_target_masked(id, &host, &mask).unwrap();
        let mut out = vec![0.0; 2 * n];
        t.copy_from_target(id, &mut out).unwrap();
        for c in 0..2 {
            for s in 0..n {
                let want = if mask[s] { host[c * n + s] } else { 0.0 };
                assert_eq!(out[c * n + s], want);
            }
        }
    }

    #[test]
    fn unsupported_vvl_rejected() {
        assert!(HostTarget::simd(3, TlpPool::serial()).is_err());
    }

    #[test]
    fn full_step_supported_multi_step_not() {
        let t = HostTarget::default_simd();
        assert!(t.supports(KernelId::FullStep));
        assert!(t.supports(KernelId::BinaryCollision));
        assert!(!t.supports(KernelId::MultiStep));
    }

    #[test]
    fn full_step_requires_scratch_bindings() {
        // the engine binds f/g plus the tmp and moment scratch buffers;
        // a bare f/g launch must fail with a missing-binding error, not
        // corrupt state
        let mut t = HostTarget::default_simd();
        let n = 8;
        let f = t.malloc(&FieldDesc::new("f", 19, n)).unwrap();
        let g = t.malloc(&FieldDesc::new("g", 19, n)).unwrap();
        let args = LaunchArgs::new(Geometry::new(2, 2, 2),
                                   LatticeModel::D3Q19)
            .bind("f", f)
            .bind("g", g);
        let err = t.launch(KernelId::FullStep, &args).unwrap_err();
        assert!(err.to_string().contains("f_tmp"), "{err}");
    }
}
