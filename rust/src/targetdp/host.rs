//! Host CPU backends: the paper's C/OpenMP implementation of targetDP.
//!
//! Two modes of the same backend:
//!
//! * [`HostMode::Scalar`] — kernels run site-at-a-time; the compiler is
//!   left to discover ILP (the paper's pre-targetDP structure, but on SoA
//!   data; the AoS "original" lives in [`crate::baseline`]).
//! * [`HostMode::Simd`] — the targetDP execution model: `TARGET_TLP`
//!   strip-mines the site loop into VVL chunks distributed over threads
//!   ([`TlpPool`]), and `TARGET_ILP` lane loops of compile-time extent VVL
//!   run inside each chunk ([`crate::dispatch_vvl!`]).
//!
//! Host and target memory are distinct allocations even though both live
//! in DRAM — the paper keeps the same distinction for the CPU target
//! (section III-A), which is what lets the identical application code also
//! drive the XLA backend.
//!
//! # The host fusion tiers
//!
//! Besides the five per-step kernels, the host backend implements two
//! fused tiers, giving the engine three execution levels to pick from:
//!
//! 1. **unfused** — the reference 5-kernel pipeline (phi → gradient →
//!    collision → 2× stream), 4 full f/g traversals per step;
//! 2. **[`KernelId::FullStep`]** — one launch per timestep: the collision
//!    chunk is scattered straight to its streaming destinations
//!    ([`crate::lb::collision::collide_stream_lattice`] over a cached
//!    [`StreamTable`]), so f and g are each read and written **once**
//!    per step (4 → 2 traversals);
//! 3. **[`KernelId::MultiStep`]** — k timesteps per launch via temporal
//!    blocking ([`crate::lb::multistep::MultiStepPlan`]): the lattice is
//!    swept in x-slabs extended by depth-2k periodic halo planes, each
//!    slab advancing k fused steps while cache resident, amortising the
//!    global f/g (and phi/gradient) traversals over k steps. The
//!    [`multi_step_plan`] heuristic sizes slabs from an assumed cache
//!    budget and only volunteers the tier when it plausibly wins; the
//!    `multi_step` / `multi_step_slab` / `multi_step_cache_kb` constants
//!    force or tune it.
//!
//! All three tiers agree bit-for-bit (`tests/fused_parity.rs`,
//! `tests/multistep_parity.rs`) — the paper's single-source promise: the
//! application never changes, the target picks its fastest path.

use crate::error::{Error, Result};
use crate::free_energy::gradient::gradient_fd;
use crate::free_energy::symmetric::FeParams;
use crate::lattice::geometry::Geometry;
use crate::lattice::stream_table::StreamTable;
use crate::lb::collision::{collide_lattice, collide_stream_lattice};
use crate::lb::model::LatticeModel;
use crate::lb::moments::phi_from_g;
use crate::lb::multistep::{MultiStepPlan, HALO_PER_STEP};
use crate::lb::propagation::stream_with_table;

use super::constant::{Constant, ConstantTable};
use super::ilp;
use super::masked;
use super::memory::{BufId, FieldDesc, HostPool};
use super::target::{KernelId, LaunchArgs, Target, TargetKind};
use super::tlp::TlpPool;

/// Assumed cache budget per slab for the MultiStep planner when the
/// `multi_step_cache_kb` constant is unset: 2 MiB, a typical per-core L2.
pub const MULTI_STEP_CACHE_BYTES: usize = 2 << 20;

/// Size the host temporal-blocking tier for a geometry/model: returns
/// `(k, slab_w)` — blocked depth and interior slab width in x-planes — or
/// `None` when the tier should stay off and the engine should fall back
/// to `FullStep`.
///
/// `force_k`/`force_w` (0 = auto) pin the knobs; with `force_k == 0` the
/// heuristic only volunteers a plan when it plausibly wins: the slab
/// scratch (f/g ping+pong plus phi/grad/lap) must fit `cache_bytes` with
/// at most 50% halo-overlap recompute, and the lattice must be wider than
/// one slab (otherwise `FullStep` is already cache resident and the
/// overlap is pure overhead).
pub fn multi_step_plan(geom: &Geometry, model: LatticeModel,
                       force_k: usize, force_w: usize,
                       cache_bytes: usize) -> Option<(usize, usize)> {
    let vs = model.velset();
    let plane = geom.ly * geom.lz;
    // slab scratch per x-plane: 4 distribution rows (f/g ping+pong) plus
    // phi, grad (3) and lap, all f64
    let bytes_per_plane = plane * (4 * vs.nvel + 5) * 8;
    let fit_w = |k: usize| {
        (cache_bytes / bytes_per_plane)
            .saturating_sub(2 * HALO_PER_STEP * k)
    };
    if force_k > 0 {
        let w = if force_w > 0 { force_w } else { fit_w(force_k).max(1) };
        return Some((force_k, w.clamp(1, geom.lx)));
    }
    // auto depth: deepest k whose slab width (pinned by force_w when set)
    // passes the overlap and multi-slab conditions
    for k in [4usize, 3, 2] {
        let w = if force_w > 0 { force_w } else { fit_w(k) };
        if w >= 2 * HALO_PER_STEP * k && w < geom.lx {
            return Some((k, w));
        }
    }
    None
}

/// Size the communication-avoiding super-step depth for a rank world:
/// how many timesteps each rank advances per halo exchange. Mirrors the
/// [`multi_step_plan`] cache arithmetic, but the "slab" is the rank's own
/// x-extent (`lx / ranks`, the narrowest one under the uneven split), so
/// a depth is accepted only when the deep ghost region still comes from a
/// single neighbour (`2k <= min lxl`) and the whole deep local lattice
/// stays within `cache_bytes`. Returns 1 (plain per-step exchange) when
/// no deeper super-step qualifies.
pub fn comms_depth_plan(geom: &Geometry, model: LatticeModel,
                        ranks: usize, cache_bytes: usize) -> usize {
    let vs = model.velset();
    let plane = geom.ly * geom.lz;
    let bytes_per_plane = plane * (4 * vs.nvel + 5) * 8;
    let min_lxl = geom.lx / ranks.max(1);
    for k in [4usize, 3, 2] {
        let halo = HALO_PER_STEP * k;
        if halo <= min_lxl
            && (min_lxl + 2 * halo) * bytes_per_plane <= cache_bytes
        {
            return k;
        }
    }
    1
}

/// Execution mode of the host backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostMode {
    /// Per-site loops, compiler-found ILP.
    Scalar,
    /// VVL strip-mined lane kernels (the targetDP model).
    Simd,
}

/// Host CPU target.
pub struct HostTarget {
    mode: HostMode,
    vvl: usize,
    pool: TlpPool,
    bufs: HostPool,
    constants: ConstantTable,
    /// Cached temporal-blocking plan (scratch + local stream table),
    /// rebuilt only when geometry/model/knobs change.
    multistep: Option<MultiStepPlan>,
}

impl HostTarget {
    /// targetDP SIMD mode with the given VVL (must be in
    /// [`ilp::SUPPORTED_VVL`]) and TLP pool.
    pub fn simd(vvl: usize, pool: TlpPool) -> Result<Self> {
        if !ilp::is_supported(vvl) {
            return Err(Error::Invalid(format!(
                "VVL {vvl} unsupported; pick one of {:?}",
                ilp::SUPPORTED_VVL
            )));
        }
        Ok(HostTarget {
            mode: HostMode::Simd,
            vvl,
            pool,
            bufs: HostPool::new(),
            constants: ConstantTable::new(),
            multistep: None,
        })
    }

    /// Scalar mode (site loops; chunking still used for TLP decomposition).
    pub fn scalar(pool: TlpPool) -> Self {
        HostTarget {
            mode: HostMode::Scalar,
            vvl: 32, // TLP chunk granularity only; no lane kernels
            pool,
            bufs: HostPool::new(),
            constants: ConstantTable::new(),
            multistep: None,
        }
    }

    /// Serial SIMD target with the paper's optimal CPU VVL (8).
    pub fn default_simd() -> Self {
        Self::simd(8, TlpPool::serial()).expect("8 is a supported VVL")
    }

    /// The strip-mining virtual vector length this target sweeps with.
    pub fn vvl(&self) -> usize {
        self.vvl
    }

    /// Scalar or SIMD kernel selection.
    pub fn mode(&self) -> HostMode {
        self.mode
    }

    /// Resolve the MultiStep knobs from the constant table and run the
    /// planner: `multi_step` (blocked depth k, 0 = auto), `multi_step_slab`
    /// (interior slab width, 0 = auto) and `multi_step_cache_kb` (planner
    /// cache budget, 0/unset = [`MULTI_STEP_CACHE_BYTES`]).
    fn multi_step_params(&self, geom: &Geometry, model: LatticeModel)
                         -> Option<(usize, usize)> {
        let knob = |name: &str| {
            self.constants
                .get_int(name)
                .ok()
                .filter(|&v| v > 0)
                .map_or(0, |v| v as usize)
        };
        let cache = self
            .constants
            .get_int("multi_step_cache_kb")
            .ok()
            .filter(|&v| v > 0)
            .map_or(MULTI_STEP_CACHE_BYTES, |v| (v as usize) << 10);
        multi_step_plan(geom, model, knob("multi_step"),
                        knob("multi_step_slab"), cache)
    }

    /// Free-energy parameters from the constant table (set by the engine
    /// via `copyConstant*ToTarget`; defaults if unset).
    fn fe_params(&self) -> FeParams {
        let d = FeParams::default();
        FeParams {
            a: self.constants.get_double("fe_a").unwrap_or(d.a),
            b: self.constants.get_double("fe_b").unwrap_or(d.b),
            kappa: self.constants.get_double("fe_kappa").unwrap_or(d.kappa),
            gamma: self.constants.get_double("fe_gamma").unwrap_or(d.gamma),
            tau_f: self.constants.get_double("tau_f").unwrap_or(d.tau_f),
            tau_g: self.constants.get_double("tau_g").unwrap_or(d.tau_g),
        }
    }
}

impl Target for HostTarget {
    fn kind(&self) -> TargetKind {
        match self.mode {
            HostMode::Scalar => TargetKind::HostScalar,
            HostMode::Simd => TargetKind::HostSimd,
        }
    }

    fn describe(&self) -> String {
        match self.mode {
            HostMode::Scalar => {
                format!("host-scalar(threads={})", self.pool.nthreads)
            }
            HostMode::Simd => format!(
                "host-simd(vvl={},threads={})",
                self.vvl, self.pool.nthreads
            ),
        }
    }

    fn malloc(&mut self, desc: &FieldDesc) -> Result<BufId> {
        // first-touch: zero the field from the TLP workers that will sweep
        // it, so its pages land on their NUMA nodes (ROADMAP item)
        Ok(self.bufs.malloc_first_touch(desc, &self.pool))
    }

    fn free(&mut self, id: BufId) -> Result<()> {
        self.bufs.free(id);
        Ok(())
    }

    fn copy_to_target(&mut self, id: BufId, host: &[f64]) -> Result<()> {
        self.bufs.copy_in(id, host)
    }

    fn copy_from_target(&mut self, id: BufId, host: &mut [f64]) -> Result<()> {
        self.bufs.copy_out(id, host)
    }

    fn copy_to_target_masked(&mut self, id: BufId, host: &[f64],
                             mask: &[bool]) -> Result<()> {
        let buf = self.bufs.get_mut(id)?;
        let (ncomp, nsites) = (buf.desc.ncomp, buf.desc.nsites);
        if host.len() != buf.data.len() || mask.len() != nsites {
            return Err(Error::Invalid(format!(
                "masked copyToTarget size mismatch for {}", buf.desc.name
            )));
        }
        masked::copy_masked_direct(&mut buf.data, host, nsites, ncomp, mask);
        Ok(())
    }

    fn copy_from_target_masked(&mut self, id: BufId, host: &mut [f64],
                               mask: &[bool]) -> Result<()> {
        let buf = self.bufs.get(id)?;
        let (ncomp, nsites) = (buf.desc.ncomp, buf.desc.nsites);
        if host.len() != buf.data.len() || mask.len() != nsites {
            return Err(Error::Invalid(format!(
                "masked copyFromTarget size mismatch for {}", buf.desc.name
            )));
        }
        masked::copy_masked_direct(host, &buf.data, nsites, ncomp, mask);
        Ok(())
    }

    fn copy_constant(&mut self, name: &str, value: Constant) -> Result<()> {
        self.constants.set(name, value);
        Ok(())
    }

    fn supports(&self, _kernel: KernelId) -> bool {
        // every kernel tier is native, including the temporal-blocked
        // MultiStep; whether MultiStep is *worth using* for a given
        // geometry is a separate question answered by `multi_step_width`
        true
    }

    fn multi_step_width(&self, geom: &Geometry,
                        model: LatticeModel) -> Option<u64> {
        self.multi_step_params(geom, model).map(|(k, _)| k as u64)
    }

    fn launch(&mut self, kernel: KernelId, args: &LaunchArgs) -> Result<()> {
        let vs = args.model.velset();
        let scalar = self.mode == HostMode::Scalar;
        match kernel {
            KernelId::Scale => {
                let a = self.constants.get_double("scale_a")?;
                let buf = self.bufs.get_mut(args.buf("field")?)?;
                let (ncomp, nsites) = (buf.desc.ncomp, buf.desc.nsites);
                let data = SendMut(buf.data.as_mut_ptr(), buf.data.len());
                self.pool.for_chunks(nsites, self.vvl, |base, len| {
                    let data = data; // capture the Send+Sync wrapper whole
                    let data =
                        unsafe { std::slice::from_raw_parts_mut(data.0, data.1) };
                    for c in 0..ncomp {
                        let row = &mut data[c * nsites..(c + 1) * nsites];
                        for v in row[base..base + len].iter_mut() {
                            *v *= a;
                        }
                    }
                });
                Ok(())
            }
            KernelId::PhiMoment => {
                let g = self.bufs.take(args.buf("g")?)?;
                let mut phi = self.bufs.take(args.buf("phi")?)?;
                let n = phi.desc.nsites;
                phi_from_g(vs, &g.data, &mut phi.data, n, &self.pool,
                           self.vvl);
                self.bufs.restore(args.buf("g")?, g);
                self.bufs.restore(args.buf("phi")?, phi);
                Ok(())
            }
            KernelId::Gradient => {
                let phi = self.bufs.take(args.buf("phi")?)?;
                let mut grad = self.bufs.take(args.buf("grad")?)?;
                let mut lap = self.bufs.take(args.buf("lap")?)?;
                gradient_fd(&args.geometry, &phi.data, &mut grad.data,
                            &mut lap.data, &self.pool, self.vvl);
                self.bufs.restore(args.buf("phi")?, phi);
                self.bufs.restore(args.buf("grad")?, grad);
                self.bufs.restore(args.buf("lap")?, lap);
                Ok(())
            }
            KernelId::BinaryCollision => {
                let p = self.fe_params();
                let mut f = self.bufs.take(args.buf("f")?)?;
                let mut g = self.bufs.take(args.buf("g")?)?;
                let grad = self.bufs.take(args.buf("grad")?)?;
                let lap = self.bufs.take(args.buf("lap")?)?;
                let n = lap.desc.nsites;
                collide_lattice(vs, &p, &mut f.data, &mut g.data, &grad.data,
                                &lap.data, n, &self.pool, self.vvl, scalar);
                self.bufs.restore(args.buf("f")?, f);
                self.bufs.restore(args.buf("g")?, g);
                self.bufs.restore(args.buf("grad")?, grad);
                self.bufs.restore(args.buf("lap")?, lap);
                Ok(())
            }
            KernelId::Stream => {
                let table = StreamTable::cached(vs, &args.geometry);
                let src = self.bufs.take(args.buf("src")?)?;
                let mut dst = self.bufs.take(args.buf("dst")?)?;
                stream_with_table(vs, &table, &src.data, &mut dst.data,
                                  &self.pool, self.vvl);
                self.bufs.restore(args.buf("src")?, src);
                self.bufs.restore(args.buf("dst")?, dst);
                Ok(())
            }
            KernelId::FullStep => {
                // the fused tier: phi moment + gradients feed one
                // collide→push-stream sweep into the *_tmp buffers, then
                // the data vectors swap — in-place step semantics for the
                // engine, 2 instead of 4 full f/g traversals
                let p = self.fe_params();
                let (f_id, g_id) = (args.buf("f")?, args.buf("g")?);
                let (ft_id, gt_id) = (args.buf("f_tmp")?, args.buf("g_tmp")?);
                let (phi_id, grad_id, lap_id) =
                    (args.buf("phi")?, args.buf("grad")?, args.buf("lap")?);
                let table = StreamTable::cached(vs, &args.geometry);

                let mut f = self.bufs.take(f_id)?;
                let mut g = self.bufs.take(g_id)?;
                let mut f_tmp = self.bufs.take(ft_id)?;
                let mut g_tmp = self.bufs.take(gt_id)?;
                let mut phi = self.bufs.take(phi_id)?;
                let mut grad = self.bufs.take(grad_id)?;
                let mut lap = self.bufs.take(lap_id)?;

                let n = phi.desc.nsites;
                phi_from_g(vs, &g.data, &mut phi.data, n, &self.pool,
                           self.vvl);
                gradient_fd(&args.geometry, &phi.data, &mut grad.data,
                            &mut lap.data, &self.pool, self.vvl);
                collide_stream_lattice(vs, &p, &f.data, &g.data,
                                       &mut f_tmp.data, &mut g_tmp.data,
                                       &grad.data, &lap.data, &table, n,
                                       &self.pool, self.vvl, scalar);
                std::mem::swap(&mut f.data, &mut f_tmp.data);
                std::mem::swap(&mut g.data, &mut g_tmp.data);

                self.bufs.restore(f_id, f);
                self.bufs.restore(g_id, g);
                self.bufs.restore(ft_id, f_tmp);
                self.bufs.restore(gt_id, g_tmp);
                self.bufs.restore(phi_id, phi);
                self.bufs.restore(grad_id, grad);
                self.bufs.restore(lap_id, lap);
                Ok(())
            }
            KernelId::ReduceSum => {
                let field = self.bufs.take(args.buf("field")?)?;
                let mut result = self.bufs.take(args.buf("result")?)?;
                let (ncomp, nsites) =
                    (field.desc.ncomp, field.desc.nsites);
                if result.desc.len() != ncomp {
                    let e = Error::Invalid(format!(
                        "reduce_sum result buffer has {} elements, field \
                         has {ncomp} components",
                        result.desc.len()
                    ));
                    self.bufs.restore(args.buf("field")?, field);
                    self.bufs.restore(args.buf("result")?, result);
                    return Err(e);
                }
                super::reduce::reduce_sum(&field.data, ncomp, nsites,
                                          &self.pool, self.vvl,
                                          &mut result.data);
                self.bufs.restore(args.buf("field")?, field);
                self.bufs.restore(args.buf("result")?, result);
                Ok(())
            }
            KernelId::MultiStep => {
                // the temporal-blocking tier: k fused timesteps per
                // launch over cache-resident x-slabs (lb/multistep.rs);
                // like FullStep, the result lands in the *_tmp double
                // buffer and the data vectors swap
                let p = self.fe_params();
                // validate bindings before building the (multi-MB) plan
                let (f_id, g_id) = (args.buf("f")?, args.buf("g")?);
                let (ft_id, gt_id) =
                    (args.buf("f_tmp")?, args.buf("g_tmp")?);
                let (k, w) = self
                    .multi_step_params(&args.geometry, args.model)
                    .ok_or_else(|| {
                        Error::Invalid(format!(
                            "no MultiStep plan for {}x{}x{} {} on {} — \
                             set the multi_step constant or launch \
                             FullStep",
                            args.geometry.lx, args.geometry.ly,
                            args.geometry.lz, args.model.name(),
                            self.describe()
                        ))
                    })?;
                let stale = self.multistep.as_ref().map_or(true, |pl| {
                    !pl.matches(&args.geometry, vs.nvel, k, w)
                });
                if stale {
                    self.multistep =
                        Some(MultiStepPlan::new(vs, args.geometry, k, w));
                }
                let mut f = self.bufs.take(f_id)?;
                let mut g = self.bufs.take(g_id)?;
                let mut f_tmp = self.bufs.take(ft_id)?;
                let mut g_tmp = self.bufs.take(gt_id)?;

                let plan =
                    self.multistep.as_mut().expect("plan built above");
                plan.run(vs, &p, &f.data, &g.data, &mut f_tmp.data,
                         &mut g_tmp.data, &self.pool, self.vvl, scalar);
                std::mem::swap(&mut f.data, &mut f_tmp.data);
                std::mem::swap(&mut g.data, &mut g_tmp.data);

                self.bufs.restore(f_id, f);
                self.bufs.restore(g_id, g);
                self.bufs.restore(ft_id, f_tmp);
                self.bufs.restore(gt_id, g_tmp);
                Ok(())
            }
        }
    }

    fn sync(&mut self) -> Result<()> {
        // host launches are synchronous (the paper's C syncTarget no-op)
        Ok(())
    }
}

#[derive(Clone, Copy)]
struct SendMut(*mut f64, usize);
unsafe impl Send for SendMut {}
unsafe impl Sync for SendMut {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::geometry::Geometry;
    use crate::lb::model::LatticeModel;

    fn scale_args(field: BufId) -> LaunchArgs {
        LaunchArgs::new(Geometry::new(4, 4, 4), LatticeModel::D3Q19)
            .bind("field", field)
    }

    #[test]
    fn scale_kernel_paper_example() {
        // the paper's section III running example end to end
        for target in [&mut HostTarget::scalar(TlpPool::serial()),
                       &mut HostTarget::default_simd()] {
            let n = 64;
            let desc = FieldDesc::new("field", 3, n);
            let host: Vec<f64> = (0..3 * n).map(|i| i as f64).collect();

            let t_field = target.malloc(&desc).unwrap();
            target.copy_to_target(t_field, &host).unwrap();
            target
                .copy_constant("scale_a", Constant::Double(1.5))
                .unwrap();
            target.launch(KernelId::Scale, &scale_args(t_field)).unwrap();
            target.sync().unwrap();

            let mut out = vec![0.0; 3 * n];
            target.copy_from_target(t_field, &mut out).unwrap();
            target.free(t_field).unwrap();
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, 1.5 * i as f64);
            }
        }
    }

    #[test]
    fn scale_requires_constant() {
        let mut t = HostTarget::default_simd();
        let id = t.malloc(&FieldDesc::new("field", 3, 8)).unwrap();
        assert!(t.launch(KernelId::Scale, &scale_args(id)).is_err());
    }

    #[test]
    fn masked_copies_only_touch_selected_sites() {
        let mut t = HostTarget::default_simd();
        let n = 8;
        let id = t.malloc(&FieldDesc::new("x", 2, n)).unwrap();
        let host: Vec<f64> = (0..2 * n).map(|i| i as f64).collect();
        let mask: Vec<bool> = (0..n).map(|s| s % 2 == 0).collect();
        t.copy_to_target_masked(id, &host, &mask).unwrap();
        let mut out = vec![0.0; 2 * n];
        t.copy_from_target(id, &mut out).unwrap();
        for c in 0..2 {
            for s in 0..n {
                let want = if mask[s] { host[c * n + s] } else { 0.0 };
                assert_eq!(out[c * n + s], want);
            }
        }
    }

    #[test]
    fn unsupported_vvl_rejected() {
        assert!(HostTarget::simd(3, TlpPool::serial()).is_err());
    }

    #[test]
    fn all_kernels_supported_multi_step_width_gated() {
        let mut t = HostTarget::default_simd();
        assert!(t.supports(KernelId::FullStep));
        assert!(t.supports(KernelId::BinaryCollision));
        assert!(t.supports(KernelId::MultiStep));
        // tiny lattice: the auto heuristic keeps temporal blocking off
        // (FullStep is already cache resident)
        let geom = Geometry::new(4, 4, 4);
        assert_eq!(t.multi_step_width(&geom, LatticeModel::D3Q19), None);
        // forcing the knob turns the tier on at exactly that depth
        t.copy_constant("multi_step", Constant::Int(3)).unwrap();
        assert_eq!(t.multi_step_width(&geom, LatticeModel::D3Q19),
                   Some(3));
    }

    #[test]
    fn auto_heuristic_enables_on_slab_friendly_lattices() {
        // long-thin 2-D lattice: slabs fit the cache budget with modest
        // overlap, so auto picks the deepest k it tries
        let geom = Geometry::new(4096, 8, 1);
        let plan = multi_step_plan(&geom, LatticeModel::D2Q9, 0, 0,
                                   MULTI_STEP_CACHE_BYTES);
        let (k, w) = plan.expect("auto plan for long-thin lattice");
        assert_eq!(k, 4);
        assert!(w >= 2 * HALO_PER_STEP * k && w < geom.lx, "w={w}");
        // fat cross-section: a single plane blows the budget, stay off
        let fat = Geometry::new(128, 64, 64);
        assert_eq!(multi_step_plan(&fat, LatticeModel::D3Q19, 0, 0,
                                   MULTI_STEP_CACHE_BYTES),
                   None);
        // forced knobs are honoured and clamped to the lattice
        assert_eq!(multi_step_plan(&fat, LatticeModel::D3Q19, 2, 500,
                                   MULTI_STEP_CACHE_BYTES),
                   Some((2, 128)));
    }

    #[test]
    fn comms_depth_auto_tracks_slab_width_and_cache() {
        // long-thin lattice, cache-resident slabs: deepest super-step
        // qualifies
        let geom = Geometry::new(256, 8, 1);
        assert_eq!(comms_depth_plan(&geom, LatticeModel::D2Q9, 4,
                                    MULTI_STEP_CACHE_BYTES),
                   4);
        // narrow slabs: the 2k-deep ghost region must come from a single
        // neighbour, so depth is capped by lx / ranks
        let narrow = Geometry::new(24, 4, 1);
        assert_eq!(comms_depth_plan(&narrow, LatticeModel::D2Q9, 4,
                                    MULTI_STEP_CACHE_BYTES),
                   3); // min lxl = 6: 2k <= 6 first holds at k = 3
        // fat cross-section blows the cache budget: stay at 1
        let fat = Geometry::new(128, 64, 64);
        assert_eq!(comms_depth_plan(&fat, LatticeModel::D3Q19, 2,
                                    MULTI_STEP_CACHE_BYTES),
                   1);
    }

    #[test]
    fn multi_step_launch_requires_double_buffer_bindings() {
        let mut t = HostTarget::default_simd();
        t.copy_constant("multi_step", Constant::Int(2)).unwrap();
        let n = 2 * 2 * 2;
        let f = t.malloc(&FieldDesc::new("f", 19, n)).unwrap();
        let g = t.malloc(&FieldDesc::new("g", 19, n)).unwrap();
        let args = LaunchArgs::new(Geometry::new(2, 2, 2),
                                   LatticeModel::D3Q19)
            .bind("f", f)
            .bind("g", g);
        let err = t.launch(KernelId::MultiStep, &args).unwrap_err();
        assert!(err.to_string().contains("f_tmp"), "{err}");
    }

    #[test]
    fn full_step_requires_scratch_bindings() {
        // the engine binds f/g plus the tmp and moment scratch buffers;
        // a bare f/g launch must fail with a missing-binding error, not
        // corrupt state
        let mut t = HostTarget::default_simd();
        let n = 8;
        let f = t.malloc(&FieldDesc::new("f", 19, n)).unwrap();
        let g = t.malloc(&FieldDesc::new("g", 19, n)).unwrap();
        let args = LaunchArgs::new(Geometry::new(2, 2, 2),
                                   LatticeModel::D3Q19)
            .bind("f", f)
            .bind("g", g);
        let err = t.launch(KernelId::FullStep, &args).unwrap_err();
        assert!(err.to_string().contains("f_tmp"), "{err}");
    }
}
