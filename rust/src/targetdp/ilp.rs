//! Instruction-level parallelism: the `TARGET_ILP` analog.
//!
//! The paper's `TARGET_ILP(vecIndex)` expands to a fixed-extent loop
//!
//! ```c
//! for (vecIndex = 0; vecIndex < VVL; vecIndex++)
//! ```
//!
//! over the chunk of VVL consecutive lattice sites owned by the current
//! thread; because the extent is a compile-time constant and SoA data makes
//! the accesses contiguous, the compiler maps the loop onto SIMD lanes.
//!
//! In Rust the compile-time VVL is a **const generic**: kernels are written
//! as `fn chunk<const VVL: usize>(...)` with `for v in 0..VVL` innermost
//! loops over `[f64; VVL]` lane arrays, and [`dispatch_vvl!`] selects the
//! monomorphised instance from the runtime `vvl` value — the same
//! "edit VVL in the header" tunability, without rebuilding.

/// VVL values for which kernels are monomorphised. Mirrors the paper's
/// sweep: 1 (no ILP) up to 32 (m*AVX-width for m = 1..8 at f64).
pub const SUPPORTED_VVL: &[usize] = &[1, 2, 4, 8, 16, 32];

/// True if [`dispatch_vvl!`] can dispatch this VVL.
pub fn is_supported(vvl: usize) -> bool {
    SUPPORTED_VVL.contains(&vvl)
}

/// Dispatch `$body::<VVL>($($args),*)` for a runtime `vvl` value.
///
/// Panics on unsupported VVL — callers validate with [`is_supported`]
/// (the paper equivalent is a compile error when VVL is edited wrongly).
#[macro_export]
macro_rules! dispatch_vvl {
    ($vvl:expr, $body:ident ( $($args:expr),* $(,)? )) => {
        match $vvl {
            1 => $body::<1>($($args),*),
            2 => $body::<2>($($args),*),
            4 => $body::<4>($($args),*),
            8 => $body::<8>($($args),*),
            16 => $body::<16>($($args),*),
            32 => $body::<32>($($args),*),
            other => panic!(
                "unsupported VVL {other}; supported: {:?}",
                $crate::targetdp::ilp::SUPPORTED_VVL
            ),
        }
    };
}

/// Lane-wise helpers for chunk kernels. A "lane array" is `[f64; VVL]`
/// holding one scalar quantity for each site of the chunk.
pub mod lanes {
    /// Load VVL contiguous values from an SoA row starting at `base`.
    /// For a short tail (`len < VVL`) missing lanes are filled with `fill`.
    #[inline(always)]
    pub fn load<const VVL: usize>(row: &[f64], base: usize, len: usize,
                                  fill: f64) -> [f64; VVL] {
        let mut out = [fill; VVL];
        out[..len].copy_from_slice(&row[base..base + len]);
        out
    }

    /// Store the first `len` lanes back to an SoA row at `base`.
    #[inline(always)]
    pub fn store<const VVL: usize>(row: &mut [f64], base: usize, len: usize,
                                   vals: &[f64; VVL]) {
        row[base..base + len].copy_from_slice(&vals[..len]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sum_chunk<const VVL: usize>(x: &[f64]) -> f64 {
        let mut acc = [0.0; VVL];
        for (i, v) in x.iter().enumerate() {
            acc[i % VVL] += v;
        }
        acc.iter().sum()
    }

    #[test]
    fn dispatch_selects_width() {
        let x: Vec<f64> = (0..64).map(|i| i as f64).collect();
        for &vvl in SUPPORTED_VVL {
            let s = dispatch_vvl!(vvl, sum_chunk(&x));
            assert_eq!(s, 2016.0);
        }
    }

    #[test]
    #[should_panic(expected = "unsupported VVL 3")]
    fn dispatch_rejects_unsupported() {
        let x = [0.0; 4];
        let _ = dispatch_vvl!(3, sum_chunk(&x));
    }

    #[test]
    fn lane_load_store_with_tail() {
        let row: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let v = lanes::load::<4>(&row, 8, 2, 99.0);
        assert_eq!(v, [8.0, 9.0, 99.0, 99.0]);
        let mut out = vec![0.0; 10];
        lanes::store::<4>(&mut out, 8, 2, &v);
        assert_eq!(&out[8..], &[8.0, 9.0]);
        assert!(out[..8].iter().all(|&x| x == 0.0));
    }
}
