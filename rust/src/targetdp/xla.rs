//! The accelerator backend: targetDP's CUDA implementation analog.
//!
//! Kernels are the AOT-compiled JAX/Pallas executables produced by
//! `python/compile/aot.py` (Layer 2/1) and run through the PJRT client
//! ([`crate::runtime::Runtime`]). The paper's mapping holds piecewise:
//!
//! * `targetMalloc`/`copyToTarget` — the target keeps a device mirror of
//!   every buffer; launches feed it to the executable and write results
//!   back (the 0.5.1 PJRT wrapper returns tuple results as one tuple
//!   buffer, so state cannot stay device-resident *between* launches —
//!   the fused `FullStep`/`MultiStep` kernels restore the "master copy
//!   lives on the target" performance model; DESIGN.md section 2).
//! * `TARGET_CONST` — constants are baked into the HLO at AOT time; the
//!   launch *validates* the runtime constant table against the manifest's
//!   baked values, turning host/target constant drift into a hard error.
//! * `TPB` / VVL — the Pallas `vvl_block` recorded per artifact; the
//!   `xla_vvl_block` constant selects among compiled variants (E2).

use crate::error::{Error, Result};
use crate::free_energy::symmetric::FeParams;
use crate::lattice::geometry::Geometry;
use crate::lb::model::LatticeModel;
use crate::runtime::{ArtifactMeta, Runtime};

use super::constant::{Constant, ConstantTable};
use super::memory::{BufId, FieldDesc, HostPool};
use super::masked;
use super::target::{KernelId, LaunchArgs, Target, TargetKind};

/// Accelerator target backed by AOT XLA executables.
pub struct XlaTarget {
    runtime: Runtime,
    bufs: HostPool,
    constants: ConstantTable,
}

impl XlaTarget {
    /// Wrap an already-loaded PJRT runtime as a target.
    pub fn new(runtime: Runtime) -> Self {
        XlaTarget {
            runtime,
            bufs: HostPool::new(),
            constants: ConstantTable::new(),
        }
    }

    /// Connect using the default artifact directory.
    pub fn from_default_artifacts() -> Result<Self> {
        Ok(Self::new(Runtime::load(Runtime::default_dir())?))
    }

    /// The PJRT runtime (platform + loaded artifacts).
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    fn grid_of(geom: &Geometry) -> Vec<usize> {
        vec![geom.lx, geom.ly, geom.lz]
    }

    /// Preferred Pallas block (the GPU-side VVL knob), if set.
    fn preferred_block(&self) -> Option<usize> {
        self.constants
            .get_int("xla_vvl_block")
            .ok()
            .map(|v| v as usize)
    }

    /// Validate that the constant table agrees with the artifact's baked
    /// free-energy parameters (constant-memory coherence check).
    fn validate_params(&self, meta: &ArtifactMeta) -> Result<()> {
        let Some(baked) = meta.params else { return Ok(()) };
        let pairs = [
            ("fe_a", baked.a),
            ("fe_b", baked.b),
            ("fe_kappa", baked.kappa),
            ("fe_gamma", baked.gamma),
            ("tau_f", baked.tau_f),
            ("tau_g", baked.tau_g),
        ];
        for (name, want) in pairs {
            if let Ok(have) = self.constants.get_double(name) {
                if have != want {
                    return Err(Error::Invalid(format!(
                        "constant {name}={have} disagrees with value {want} \
                         baked into artifact {}; re-run `make artifacts` \
                         with matching parameters",
                        meta.name
                    )));
                }
            }
        }
        Ok(())
    }

    /// Baked free-energy parameters of the collision artifact this target
    /// would use for (`model`, `n`) — the engine can mirror them exactly.
    pub fn baked_params(&self, model: LatticeModel, n: usize)
                        -> Option<FeParams> {
        self.runtime
            .find(|m| m.matches_flat("collision", model.name(), n))
            .and_then(|m| m.params)
    }

    fn pick_artifact(&self, kind: &str, lattice: Option<&str>,
                     flat_n: Option<usize>, grid: Option<&[usize]>)
                     -> Result<String> {
        let pref = self.preferred_block();
        let matches = |m: &&ArtifactMeta| -> bool {
            m.kind == kind
                && (lattice.is_none() || m.lattice.as_deref() == lattice)
                && (flat_n.is_none() || m.n_sites == flat_n)
                && (grid.is_none() || m.grid.as_deref() == grid)
        };
        let metas: Vec<&ArtifactMeta> =
            self.runtime.artifacts().iter().filter(matches).collect();
        if metas.is_empty() {
            return Err(Error::Invalid(format!(
                "no {kind} artifact for lattice={lattice:?} n={flat_n:?} \
                 grid={grid:?}; add it to python/compile/aot.py and re-run \
                 `make artifacts`"
            )));
        }
        let chosen = pref
            .and_then(|b| metas.iter().find(|m| m.vvl_block == b).copied())
            .unwrap_or(metas[0]);
        Ok(chosen.name.clone())
    }

    /// Run one artifact with pool-resident inputs, writing pool outputs.
    fn run(&mut self, name: &str, input_ids: &[BufId],
           output_ids: &[BufId]) -> Result<()> {
        // borrow all inputs out of the pool
        let mut inputs = Vec::with_capacity(input_ids.len());
        for &id in input_ids {
            inputs.push(self.bufs.take(id)?);
        }
        let input_slices: Vec<&[f64]> =
            inputs.iter().map(|b| b.data.as_slice()).collect();
        let result = self.runtime.execute(name, &input_slices);
        for (&id, buf) in input_ids.iter().zip(inputs) {
            self.bufs.restore(id, buf);
        }
        let outputs = result?;
        if outputs.len() != output_ids.len() {
            return Err(Error::Xla(format!(
                "{name}: got {} outputs, caller expected {}",
                outputs.len(),
                output_ids.len()
            )));
        }
        for (&id, data) in output_ids.iter().zip(outputs) {
            self.bufs.copy_in(id, &data)?;
        }
        Ok(())
    }
}

impl Target for XlaTarget {
    fn kind(&self) -> TargetKind {
        TargetKind::Xla
    }

    fn describe(&self) -> String {
        format!(
            "xla({}, {} artifacts{})",
            self.runtime.platform(),
            self.runtime.artifacts().len(),
            self.preferred_block()
                .map(|b| format!(", vvl_block={b}"))
                .unwrap_or_default()
        )
    }

    fn malloc(&mut self, desc: &FieldDesc) -> Result<BufId> {
        Ok(self.bufs.malloc(desc))
    }

    fn free(&mut self, id: BufId) -> Result<()> {
        self.bufs.free(id);
        Ok(())
    }

    fn copy_to_target(&mut self, id: BufId, host: &[f64]) -> Result<()> {
        self.bufs.copy_in(id, host)
    }

    fn copy_from_target(&mut self, id: BufId, host: &mut [f64]) -> Result<()> {
        self.bufs.copy_out(id, host)
    }

    fn copy_to_target_masked(&mut self, id: BufId, host: &[f64],
                             mask: &[bool]) -> Result<()> {
        // the CUDA route: pack on the source, move packed, unpack on target
        let buf = self.bufs.get_mut(id)?;
        let (ncomp, nsites) = (buf.desc.ncomp, buf.desc.nsites);
        if host.len() != buf.data.len() || mask.len() != nsites {
            return Err(Error::Invalid(format!(
                "masked copyToTarget size mismatch for {}", buf.desc.name
            )));
        }
        let idx = masked::mask_indices(mask);
        let packed = masked::pack(host, nsites, ncomp, &idx);
        masked::unpack(&mut buf.data, nsites, ncomp, &idx, &packed);
        Ok(())
    }

    fn copy_from_target_masked(&mut self, id: BufId, host: &mut [f64],
                               mask: &[bool]) -> Result<()> {
        let buf = self.bufs.get(id)?;
        let (ncomp, nsites) = (buf.desc.ncomp, buf.desc.nsites);
        if host.len() != buf.data.len() || mask.len() != nsites {
            return Err(Error::Invalid(format!(
                "masked copyFromTarget size mismatch for {}", buf.desc.name
            )));
        }
        let idx = masked::mask_indices(mask);
        let packed = masked::pack(&buf.data, nsites, ncomp, &idx);
        masked::unpack(host, nsites, ncomp, &idx, &packed);
        Ok(())
    }

    fn copy_constant(&mut self, name: &str, value: Constant) -> Result<()> {
        self.constants.set(name, value);
        Ok(())
    }

    fn supports(&self, kernel: KernelId) -> bool {
        let kind = match kernel {
            KernelId::Scale => "scale",
            KernelId::BinaryCollision => "collision",
            KernelId::Gradient => "gradient",
            KernelId::FullStep => "full_step",
            KernelId::MultiStep => "multi_step",
            KernelId::ReduceSum => "reduce",
            KernelId::PhiMoment | KernelId::Stream => return false,
        };
        self.runtime.artifacts().iter().any(|m| m.kind == kind)
    }

    fn multi_step_width(&self, geom: &Geometry,
                        model: LatticeModel) -> Option<u64> {
        let grid = Self::grid_of(geom);
        self.runtime
            .find(|m| m.matches_grid("multi_step", model.name(), &grid))
            .and_then(|m| m.steps)
    }

    fn launch(&mut self, kernel: KernelId, args: &LaunchArgs) -> Result<()> {
        let lattice = args.model.name();
        let n = args.geometry.nsites();
        let grid = Self::grid_of(&args.geometry);
        match kernel {
            KernelId::Scale => {
                let field = args.buf("field")?;
                let nsites = self.bufs.get(field)?.desc.nsites;
                let name = self.pick_artifact("scale", None, Some(nsites),
                                              None)?;
                // constant coherence: baked a must equal the table's value
                let baked = self
                    .runtime
                    .find(|m| m.name == name)
                    .and_then(|m| m.a);
                if let (Some(baked), Ok(have)) =
                    (baked, self.constants.get_double("scale_a"))
                {
                    if have != baked {
                        return Err(Error::Invalid(format!(
                            "scale_a={have} disagrees with baked a={baked} \
                             in artifact {name}"
                        )));
                    }
                }
                self.run(&name, &[field], &[field])
            }
            KernelId::BinaryCollision => {
                let name = self.pick_artifact("collision", Some(lattice),
                                              Some(n), None)?;
                let meta = self.runtime.find(|m| m.name == name).unwrap()
                    .clone();
                self.validate_params(&meta)?;
                let f = args.buf("f")?;
                let g = args.buf("g")?;
                let grad = args.buf("grad")?;
                let lap = args.buf("lap")?;
                self.run(&name, &[f, g, grad, lap], &[f, g])
            }
            KernelId::Gradient => {
                let name = self.pick_artifact("gradient", None, None,
                                              Some(&grid))?;
                let phi = args.buf("phi")?;
                let grad = args.buf("grad")?;
                let lap = args.buf("lap")?;
                self.run(&name, &[phi], &[grad, lap])
            }
            KernelId::FullStep => {
                let name = self.pick_artifact("full_step", Some(lattice),
                                              None, Some(&grid))?;
                let meta = self.runtime.find(|m| m.name == name).unwrap()
                    .clone();
                self.validate_params(&meta)?;
                let f = args.buf("f")?;
                let g = args.buf("g")?;
                self.run(&name, &[f, g], &[f, g])
            }
            KernelId::MultiStep => {
                let name = self.pick_artifact("multi_step", Some(lattice),
                                              None, Some(&grid))?;
                let meta = self.runtime.find(|m| m.name == name).unwrap()
                    .clone();
                self.validate_params(&meta)?;
                let f = args.buf("f")?;
                let g = args.buf("g")?;
                self.run(&name, &[f, g], &[f, g])
            }
            KernelId::ReduceSum => {
                let field = args.buf("field")?;
                let result = args.buf("result")?;
                let (ncomp, nsites) = {
                    let b = self.bufs.get(field)?;
                    (b.desc.ncomp, b.desc.nsites)
                };
                let name = self
                    .runtime
                    .find(|m| m.kind == "reduce"
                          && m.n_sites == Some(nsites)
                          && m.inputs.first()
                              .map(|s| s.shape.first() == Some(&ncomp))
                              .unwrap_or(false))
                    .map(|m| m.name.clone())
                    .ok_or_else(|| Error::Invalid(format!(
                        "no reduce artifact for ncomp={ncomp} n={nsites}; \
                         add it to python/compile/aot.py and re-run \
                         `make artifacts`"
                    )))?;
                self.run(&name, &[field], &[result])
            }
            KernelId::PhiMoment | KernelId::Stream => {
                Err(Error::UnsupportedKernel {
                    target: self.describe(),
                    kernel: kernel.name().into(),
                })
            }
        }
    }

    fn sync(&mut self) -> Result<()> {
        // execute() is synchronous through this wrapper
        Ok(())
    }
}
