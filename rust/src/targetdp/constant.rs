//! Target constant memory: the `TARGET_CONST` / `copyConstant<X>ToTarget`
//! analog (paper section III-B).
//!
//! Lattice operations use small read-only parameters (relaxation times,
//! free-energy coefficients, scale factors). The paper keeps host and
//! target copies and provides a family of typed copy functions
//! (`copyConstantDoubleToTarget`, `copyConstantInt...`, `...1DArray...`);
//! the CUDA implementation maps them to `__constant__` memory, the C one
//! to plain `memcpy`. Here each target owns a [`ConstantTable`] that
//! kernels read at launch time; for the XLA target the constants are baked
//! into the HLO at AOT time and the table is used for *validation* (the
//! launch refuses to run if the table disagrees with the artifact's baked
//! values — catching exactly the host/target desynchronisation bug class
//! the paper's API prevents).

use std::collections::HashMap;

use crate::error::{Error, Result};

/// A typed constant, mirroring the paper's `copyConstant<X>ToTarget` family.
#[derive(Debug, Clone, PartialEq)]
pub enum Constant {
    Double(f64),
    Int(i64),
    Double1DArray(Vec<f64>),
}

impl Constant {
    /// The `Double` payload, or a typed error.
    pub fn as_double(&self) -> Result<f64> {
        match self {
            Constant::Double(v) => Ok(*v),
            other => Err(Error::Invalid(format!(
                "constant is {other:?}, expected Double"
            ))),
        }
    }

    /// The `Int` payload, or a typed error.
    pub fn as_int(&self) -> Result<i64> {
        match self {
            Constant::Int(v) => Ok(*v),
            other => Err(Error::Invalid(format!(
                "constant is {other:?}, expected Int"
            ))),
        }
    }

    /// The `Double1DArray` payload, or a typed error.
    pub fn as_array(&self) -> Result<&[f64]> {
        match self {
            Constant::Double1DArray(v) => Ok(v),
            other => Err(Error::Invalid(format!(
                "constant is {other:?}, expected Double1DArray"
            ))),
        }
    }
}

/// Per-target table of named constants.
#[derive(Debug, Default, Clone)]
pub struct ConstantTable {
    values: HashMap<String, Constant>,
}

impl ConstantTable {
    /// An empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// `copyConstant<X>ToTarget`.
    pub fn set(&mut self, name: impl Into<String>, value: Constant) {
        self.values.insert(name.into(), value);
    }

    /// Look a constant up by name (error when unset).
    pub fn get(&self, name: &str) -> Result<&Constant> {
        self.values
            .get(name)
            .ok_or_else(|| Error::Invalid(format!("constant {name:?} not set")))
    }

    /// Typed lookup of a `Double` constant.
    pub fn get_double(&self, name: &str) -> Result<f64> {
        self.get(name)?.as_double()
    }

    /// Typed lookup of an `Int` constant.
    pub fn get_int(&self, name: &str) -> Result<i64> {
        self.get(name)?.as_int()
    }

    /// Typed lookup of a `Double1DArray` constant.
    pub fn get_array(&self, name: &str) -> Result<&[f64]> {
        self.get(name)?.as_array()
    }

    /// Whether `name` has been set.
    pub fn contains(&self, name: &str) -> bool {
        self.values.contains_key(name)
    }

    /// Number of constants set.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// Whether the table is empty.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_roundtrip() {
        let mut t = ConstantTable::new();
        t.set("a", Constant::Double(1.5));
        t.set("n", Constant::Int(7));
        t.set("w", Constant::Double1DArray(vec![0.5, 0.25]));
        assert_eq!(t.get_double("a").unwrap(), 1.5);
        assert_eq!(t.get_int("n").unwrap(), 7);
        assert_eq!(t.get_array("w").unwrap(), &[0.5, 0.25]);
    }

    #[test]
    fn missing_and_wrong_type_errors() {
        let mut t = ConstantTable::new();
        t.set("a", Constant::Double(1.0));
        assert!(t.get_double("b").is_err());
        assert!(t.get_int("a").is_err());
        assert!(t.get_array("a").is_err());
    }

    #[test]
    fn overwrite_updates() {
        let mut t = ConstantTable::new();
        t.set("a", Constant::Double(1.0));
        t.set("a", Constant::Double(2.0));
        assert_eq!(t.get_double("a").unwrap(), 2.0);
        assert_eq!(t.len(), 1);
    }
}
