//! The targetDP programming layer (the paper's contribution).
//!
//! targetDP exposes the data parallelism of lattice-based applications to
//! the hardware hierarchy:
//!
//! * **TLP** — the lattice-site loop is decomposed over threads in strides
//!   of a *virtual vector length* (VVL): the paper's `TARGET_TLP` macro is
//!   [`tlp::TlpPool::for_chunks`].
//! * **ILP** — each thread owns a chunk of VVL consecutive sites; the
//!   innermost loop over the chunk (`TARGET_ILP`) has a fixed, tunable
//!   extent the compiler can map onto SIMD lanes: [`ilp`].
//! * **Memory model** — host and target copies of each lattice field; the
//!   target copy is the master during lattice operations. `targetMalloc`,
//!   `copyToTarget`, `copyFromTarget` and the *masked* variants are methods
//!   on [`Target`]; `TARGET_CONST` + `copyConstant*ToTarget` is
//!   [`constant::ConstantTable`].
//!
//! Three backends implement [`Target`]:
//!
//! | paper            | here                                             |
//! |------------------|--------------------------------------------------|
//! | C + OpenMP       | [`host::HostTarget`] (scalar or SIMD/VVL mode)   |
//! | CUDA on a GPU    | [`xla::XlaTarget`]: AOT JAX/Pallas HLO via PJRT  |
//!
//! A kernel is written once against the [`Target`] trait and dispatched by
//! [`KernelId`]; the deviation from the paper's literal single-source C
//! macro trick (impossible across Rust/XLA) is documented in DESIGN.md §10.

pub mod constant;
pub mod host;
pub mod ilp;
pub mod masked;
pub mod memory;
pub mod reduce;
pub mod target;
pub mod tlp;
pub mod xla;

pub use constant::{Constant, ConstantTable};
pub use host::{HostMode, HostTarget};
pub use memory::{BufId, FieldDesc};
pub use target::{KernelId, LaunchArgs, Target, TargetKind};
pub use tlp::{Schedule, TlpPool};
pub use xla::XlaTarget;
