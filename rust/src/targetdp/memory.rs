//! Host-side buffer pool: the `targetMalloc`/`targetFree` substrate.
//!
//! Lattice fields are stored **SoA** (structure of arrays): component `c`
//! of site `s` lives at `data[c * nsites + s]`, so a VVL-chunk of
//! consecutive sites is a contiguous vector lane (paper section III-B).

use crate::error::{Error, Result};

/// Opaque handle to a target-resident buffer (the `t_field` pointer analog).
pub type BufId = usize;

/// Shape of a lattice field buffer: `ncomp` SoA components over `nsites`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FieldDesc {
    /// Human-readable name (diagnostics only).
    pub name: String,
    /// Number of per-site values (e.g. 3 for a velocity field, 19 for f).
    pub ncomp: usize,
    /// Number of lattice sites covered by the buffer.
    pub nsites: usize,
}

impl FieldDesc {
    /// Describe an `ncomp`-component SoA field over `nsites` sites.
    pub fn new(name: impl Into<String>, ncomp: usize, nsites: usize) -> Self {
        FieldDesc { name: name.into(), ncomp, nsites }
    }

    /// Total number of f64 elements.
    pub fn len(&self) -> usize {
        self.ncomp * self.nsites
    }

    /// Whether the buffer holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One allocated target buffer.
#[derive(Debug)]
pub struct HostBuf {
    /// Shape and name of the field.
    pub desc: FieldDesc,
    /// The target-resident f64 elements (`desc.len()` of them).
    pub data: Vec<f64>,
}

/// Slab of host-side buffers used by the host targets (and as the staging
/// descriptor table for the XLA target).
#[derive(Debug, Default)]
pub struct HostPool {
    bufs: Vec<Option<HostBuf>>,
}

impl HostPool {
    /// An empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// `targetMalloc`: allocate a zero-initialised buffer.
    pub fn malloc(&mut self, desc: &FieldDesc) -> BufId {
        self.insert(HostBuf { desc: desc.clone(), data: vec![0.0; desc.len()] })
    }

    /// `targetMalloc` with NUMA-friendly first-touch initialisation: the
    /// buffer's pages are zeroed by `pool`'s workers under the same static
    /// chunk→thread assignment the kernels sweep with, so each page lands
    /// on the socket that will process it (see
    /// [`crate::targetdp::tlp::TlpPool::zeros`]).
    pub fn malloc_first_touch(&mut self, desc: &FieldDesc,
                              pool: &crate::targetdp::tlp::TlpPool) -> BufId {
        let data = pool.zeros(desc.len());
        self.insert(HostBuf { desc: desc.clone(), data })
    }

    fn insert(&mut self, buf: HostBuf) -> BufId {
        // reuse the first free slot to keep handles dense
        if let Some(slot) = self.bufs.iter().position(Option::is_none) {
            self.bufs[slot] = Some(buf);
            slot
        } else {
            self.bufs.push(Some(buf));
            self.bufs.len() - 1
        }
    }

    /// `targetFree`.
    pub fn free(&mut self, id: BufId) {
        if id < self.bufs.len() {
            self.bufs[id] = None;
        }
    }

    /// Borrow a live buffer by handle.
    pub fn get(&self, id: BufId) -> Result<&HostBuf> {
        self.bufs
            .get(id)
            .and_then(Option::as_ref)
            .ok_or(Error::BadBuffer(id))
    }

    /// Mutably borrow a live buffer by handle.
    pub fn get_mut(&mut self, id: BufId) -> Result<&mut HostBuf> {
        self.bufs
            .get_mut(id)
            .and_then(Option::as_mut)
            .ok_or(Error::BadBuffer(id))
    }

    /// Temporarily remove a buffer (split-borrow helper for kernels that
    /// read some buffers while writing others). Pair with [`Self::restore`].
    pub fn take(&mut self, id: BufId) -> Result<HostBuf> {
        self.bufs
            .get_mut(id)
            .and_then(Option::take)
            .ok_or(Error::BadBuffer(id))
    }

    /// Put back a buffer removed with [`Self::take`].
    pub fn restore(&mut self, id: BufId, buf: HostBuf) {
        debug_assert!(id < self.bufs.len() && self.bufs[id].is_none());
        self.bufs[id] = Some(buf);
    }

    /// `copyToTarget`: full-lattice host -> target transfer.
    pub fn copy_in(&mut self, id: BufId, host: &[f64]) -> Result<()> {
        let buf = self.get_mut(id)?;
        if host.len() != buf.data.len() {
            return Err(Error::Invalid(format!(
                "copyToTarget size mismatch for {}: host {} vs target {}",
                buf.desc.name,
                host.len(),
                buf.data.len()
            )));
        }
        buf.data.copy_from_slice(host);
        Ok(())
    }

    /// `copyFromTarget`.
    pub fn copy_out(&self, id: BufId, host: &mut [f64]) -> Result<()> {
        let buf = self.get(id)?;
        if host.len() != buf.data.len() {
            return Err(Error::Invalid(format!(
                "copyFromTarget size mismatch for {}: host {} vs target {}",
                buf.desc.name,
                host.len(),
                buf.data.len()
            )));
        }
        host.copy_from_slice(&buf.data);
        Ok(())
    }

    /// Number of live buffers.
    pub fn live(&self) -> usize {
        self.bufs.iter().filter(|b| b.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn malloc_free_reuses_slots() {
        let mut pool = HostPool::new();
        let a = pool.malloc(&FieldDesc::new("a", 3, 8));
        let b = pool.malloc(&FieldDesc::new("b", 1, 8));
        assert_ne!(a, b);
        pool.free(a);
        let c = pool.malloc(&FieldDesc::new("c", 2, 4));
        assert_eq!(c, a, "freed slot is reused");
        assert_eq!(pool.live(), 2);
    }

    #[test]
    fn copy_roundtrip() {
        let mut pool = HostPool::new();
        let id = pool.malloc(&FieldDesc::new("x", 2, 4));
        let host: Vec<f64> = (0..8).map(|i| i as f64).collect();
        pool.copy_in(id, &host).unwrap();
        let mut out = vec![0.0; 8];
        pool.copy_out(id, &mut out).unwrap();
        assert_eq!(out, host);
    }

    #[test]
    fn copy_size_mismatch_is_rejected() {
        let mut pool = HostPool::new();
        let id = pool.malloc(&FieldDesc::new("x", 2, 4));
        assert!(pool.copy_in(id, &[0.0; 7]).is_err());
        let mut small = vec![0.0; 3];
        assert!(pool.copy_out(id, &mut small).is_err());
    }

    #[test]
    fn bad_handle_is_rejected() {
        let pool = HostPool::new();
        assert!(matches!(pool.get(3), Err(Error::BadBuffer(3))));
    }

    #[test]
    fn take_restore() {
        let mut pool = HostPool::new();
        let id = pool.malloc(&FieldDesc::new("x", 1, 4));
        let buf = pool.take(id).unwrap();
        assert!(pool.get(id).is_err());
        pool.restore(id, buf);
        assert!(pool.get(id).is_ok());
    }
}
