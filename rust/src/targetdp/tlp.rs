//! Thread-level parallelism: the `TARGET_TLP` analog.
//!
//! The paper's C implementation expands `TARGET_TLP(baseIndex, N)` to
//!
//! ```c
//! _Pragma("omp parallel for")
//! for (baseIndex = 0; baseIndex < N; baseIndex += VVL)
//! ```
//!
//! i.e. the site loop is strip-mined in strides of VVL and the chunks are
//! decomposed between OpenMP threads. [`TlpPool::for_chunks`] reproduces
//! exactly that: the closure receives `(base, len)` for each chunk of at
//! most `vvl` sites and chunks are distributed over `nthreads` workers with
//! either static (OpenMP `schedule(static)`) or dynamic
//! (`schedule(dynamic, k)`) assignment — the launch-geometry tuning knob
//! benchmarked in `benches/tlp_sched.rs` (E5).
//!
//! Like an OpenMP runtime, the worker threads are **persistent**: they are
//! spawned once when the pool is created and parked on a condvar between
//! launches, so a kernel launch costs one wake broadcast instead of
//! `nthreads` OS thread spawns. A generation counter tells parked workers
//! that a new launch has been published; the launching thread blocks until
//! every participating worker has checked back in, which is what makes it
//! sound for kernel bodies to borrow stack data.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::obs::trace::{PoolTrace, TracePhase};

/// Chunk-to-thread assignment policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Contiguous block of chunks per thread (OpenMP `schedule(static)`).
    Static,
    /// Threads grab batches of `chunk` chunks from a shared cursor
    /// (OpenMP `schedule(dynamic, chunk)`).
    Dynamic { batch: usize },
}

impl Default for Schedule {
    fn default() -> Self {
        Schedule::Static
    }
}

/// The TLP worker pool.
///
/// `nthreads > 1` spawns persistent parked workers at construction; with
/// `nthreads == 1` launches run inline with zero overhead — the hot path
/// on a single-core testbed. Dropping the pool shuts the workers down.
///
/// # Examples
///
/// The paper's `TARGET_TLP(baseIndex, N)` loop: 100 sites strip-mined
/// into VVL-8 chunks, decomposed over 2 persistent workers — every site
/// visited exactly once:
///
/// ```
/// use std::sync::atomic::{AtomicUsize, Ordering};
/// use targetdp::targetdp::{Schedule, TlpPool};
///
/// let pool = TlpPool::new(2, Schedule::Static);
/// let visited = AtomicUsize::new(0);
/// pool.for_chunks(100, 8, |base, len| {
///     assert!(len == 8 || base + len == 100, "short chunk only at tail");
///     visited.fetch_add(len, Ordering::Relaxed);
/// });
/// assert_eq!(visited.load(Ordering::Relaxed), 100);
/// ```
pub struct TlpPool {
    /// Worker count (1 = inline execution, no worker threads).
    pub nthreads: usize,
    /// Chunk-to-thread assignment policy.
    pub schedule: Schedule,
    /// First logical CPU of this pool's round-robin core pin (`None` =
    /// unpinned, the default).
    pin: Option<usize>,
    workers: Option<WorkerPool>,
    /// Per-worker span sink armed by [`TlpPool::set_trace`] (`None` = no
    /// tracing, the default — launches pay a single branch).
    trace: Option<Arc<PoolTrace>>,
}

impl Default for TlpPool {
    fn default() -> Self {
        TlpPool::new(default_threads(), Schedule::Static)
    }
}

impl Clone for TlpPool {
    /// Clones the *configuration*; the clone gets its own fresh workers
    /// (pinned to the same CPUs if the original was pinned) and shares
    /// the original's trace sink, if any.
    fn clone(&self) -> Self {
        let mut pool = match self.pin {
            Some(first) => {
                TlpPool::new_pinned(self.nthreads, self.schedule, first)
            }
            None => TlpPool::new(self.nthreads, self.schedule),
        };
        pool.trace = self.trace.clone();
        pool
    }
}

impl std::fmt::Debug for TlpPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TlpPool")
            .field("nthreads", &self.nthreads)
            .field("schedule", &self.schedule)
            .field("persistent", &self.workers.is_some())
            .field("traced", &self.trace.is_some())
            .finish()
    }
}

/// `TARGETDP_NUM_THREADS` env var, else available parallelism.
pub fn default_threads() -> usize {
    env_or_available()
}

/// TLP threads *per rank* when a thread budget of `total` is shared by
/// `nranks` concurrently running ranks (the comms layer's pool sizing):
/// an even split, never below 1, with `total == 0` meaning "divide the
/// machine". Ranks are themselves OS threads, so a rank whose share is 1
/// runs its kernels inline with zero pool overhead.
pub fn threads_per_rank(total: usize, nranks: usize) -> usize {
    let total = if total == 0 { env_or_available() } else { total };
    (total / nranks.max(1)).max(1)
}

fn env_or_available() -> usize {
    std::env::var("TARGETDP_NUM_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

impl TlpPool {
    /// Spawn a pool of `nthreads` persistent workers (clamped to >= 1;
    /// 1 runs launches inline).
    pub fn new(nthreads: usize, schedule: Schedule) -> Self {
        let nthreads = nthreads.max(1);
        let workers =
            (nthreads > 1).then(|| WorkerPool::spawn(nthreads, None));
        TlpPool { nthreads, schedule, pin: None, workers, trace: None }
    }

    /// [`TlpPool::new`] with each worker pinned to one logical CPU:
    /// worker `i` lands on CPU `(first_cpu + i) % nproc` (Linux
    /// `sched_setaffinity`; a no-op elsewhere). With `nthreads == 1`
    /// launches run inline, so the *calling* thread is pinned instead —
    /// in the comms layer that is the rank thread itself. Pinning is a
    /// locality hint: failures are ignored, results never change.
    pub fn new_pinned(nthreads: usize, schedule: Schedule,
                      first_cpu: usize) -> Self {
        let nthreads = nthreads.max(1);
        if nthreads == 1 {
            let nproc = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            let _ = pin_to_cpu(first_cpu % nproc);
            return TlpPool {
                nthreads,
                schedule,
                pin: Some(first_cpu),
                workers: None,
                trace: None,
            };
        }
        let workers = WorkerPool::spawn(nthreads, Some(first_cpu));
        TlpPool {
            nthreads,
            schedule,
            pin: Some(first_cpu),
            workers: Some(workers),
            trace: None,
        }
    }

    /// Serial pool (inline execution, no worker threads).
    pub fn serial() -> Self {
        TlpPool {
            nthreads: 1,
            schedule: Schedule::Static,
            pin: None,
            workers: None,
            trace: None,
        }
    }

    /// Arm per-worker span recording: every subsequent threaded launch
    /// times each participating worker's share of the kernel and records
    /// one span per worker per launch into `trace`, labelled with the
    /// phase/step context last published via [`TlpPool::trace_context`].
    /// Inline launches (`nthreads == 1` or a single chunk) are not
    /// recorded — the calling rank's own recorder covers them.
    ///
    /// Tracing never reorders or re-times the kernel body itself; it only
    /// reads the clock around the existing per-worker chunk loop, so
    /// results are bit-identical with tracing on or off.
    pub fn set_trace(&mut self, trace: Arc<PoolTrace>) {
        self.trace = Some(trace);
    }

    /// Publish the phase/step context that the next launches' worker
    /// spans will carry. A no-op (one branch) when tracing is off.
    #[inline]
    pub fn trace_context(&self, phase: TracePhase, step: u64) {
        if let Some(tr) = &self.trace {
            tr.set_context(phase, step);
        }
    }

    /// Strip-mine `nsites` into chunks of at most `vvl` sites and run
    /// `body(base, len)` for every chunk (`len < vvl` only for the tail).
    pub fn for_chunks<F>(&self, nsites: usize, vvl: usize, body: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        assert!(vvl > 0, "VVL must be positive");
        if nsites == 0 {
            return;
        }
        let nchunks = nsites.div_ceil(vvl);
        let run_chunk = |c: usize| {
            let base = c * vvl;
            let len = vvl.min(nsites - base);
            body(base, len);
        };

        if self.nthreads <= 1 || nchunks == 1 {
            for c in 0..nchunks {
                run_chunk(c);
            }
            return;
        }

        let workers =
            self.workers.as_ref().expect("nthreads > 1 spawns workers");
        let nworkers = self.nthreads.min(nchunks);
        let trace = self.trace.as_deref();
        match self.schedule {
            Schedule::Static => {
                // contiguous ranges of chunks, remainder spread over the
                // first threads (OpenMP static semantics)
                let per = nchunks / nworkers;
                let rem = nchunks % nworkers;
                workers.run(nworkers, &|t: usize| {
                    let t0 = trace.map(|tr| tr.now());
                    let start = t * per + t.min(rem);
                    let count = per + usize::from(t < rem);
                    for c in start..start + count {
                        run_chunk(c);
                    }
                    if let (Some(tr), Some(t0)) = (trace, t0) {
                        tr.record(t, t0);
                    }
                });
            }
            Schedule::Dynamic { batch } => {
                let batch = batch.max(1);
                let cursor = AtomicUsize::new(0);
                workers.run(nworkers, &|t: usize| {
                    let t0 = trace.map(|tr| tr.now());
                    loop {
                        let begin =
                            cursor.fetch_add(batch, Ordering::Relaxed);
                        if begin >= nchunks {
                            break;
                        }
                        for c in begin..(begin + batch).min(nchunks) {
                            run_chunk(c);
                        }
                    }
                    if let (Some(tr), Some(t0)) = (trace, t0) {
                        tr.record(t, t0);
                    }
                });
            }
        }
    }

    /// First-touch allocation: a `len`-element zeroed buffer whose pages
    /// are written for the first time by this pool's own workers, under
    /// the pool's normal chunk→thread assignment. On a NUMA machine
    /// first-touch placement puts each page on the socket of the thread
    /// that touched it, so a field zeroed here lands next to the workers
    /// that will sweep it — `vec![0.0; len]` from the main thread pins
    /// everything to the main thread's node instead.
    ///
    /// Zeroing runs at a coarse grain (`FIRST_TOUCH_GRAIN` sites) rather
    /// than per-VVL-chunk: static scheduling still hands each worker one
    /// contiguous block, and page (4 KiB = 512 f64) placement only cares
    /// about which worker's block a page falls in, not the exact chunk
    /// boundaries inside it.
    pub fn zeros(&self, len: usize) -> Vec<f64> {
        let mut v: Vec<f64> = Vec::with_capacity(len);
        if len == 0 {
            return v;
        }
        let ptr = ZeroPtr(v.as_mut_ptr());
        self.for_chunks(len, FIRST_TOUCH_GRAIN, |base, n| {
            // SAFETY: chunks partition [0, len) within the reserved
            // capacity; disjoint ranges, each written exactly once
            unsafe { std::ptr::write_bytes(ptr.0.add(base), 0, n) };
        });
        // SAFETY: every element in [0, len) was initialised above
        unsafe { v.set_len(len) };
        v
    }
}

/// Zeroing grain (in f64 elements) for [`TlpPool::zeros`]: 8 pages.
const FIRST_TOUCH_GRAIN: usize = 4096;

/// Pin the calling thread to logical CPU `cpu` via `sched_setaffinity`.
/// Declared directly (the crate is pure std, no libc dependency); the
/// 1024-bit mask matches glibc's `cpu_set_t`. Returns whether the kernel
/// accepted the mask — callers treat failure as "no pinning", never as
/// an error, because affinity is purely a locality hint.
#[cfg(target_os = "linux")]
fn pin_to_cpu(cpu: usize) -> bool {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize,
                             mask: *const u64) -> i32;
    }
    let mut set = [0u64; 16];
    set[(cpu / 64) % set.len()] |= 1u64 << (cpu % 64);
    // SAFETY: pid 0 = calling thread; the mask is a valid, live buffer of
    // the size we pass.
    unsafe {
        sched_setaffinity(0, std::mem::size_of_val(&set), set.as_ptr())
            == 0
    }
}

/// Thread pinning is Linux-only; everywhere else the knob is a no-op.
#[cfg(not(target_os = "linux"))]
fn pin_to_cpu(_cpu: usize) -> bool {
    false
}

#[derive(Clone, Copy)]
struct ZeroPtr(*mut f64);
unsafe impl Send for ZeroPtr {}
unsafe impl Sync for ZeroPtr {}

/// Type-erased pointer to the per-worker job body (`fn(worker_index)`).
///
/// The lifetime is erased so the job can be published through the shared
/// slot; [`WorkerPool::run`] does not return until every participating
/// worker has finished calling it, so the borrow never escapes.
#[derive(Clone, Copy)]
struct TaskRef(*const (dyn Fn(usize) + Sync));
unsafe impl Send for TaskRef {}

/// The job slot workers poll: one launch at a time, identified by a
/// monotonically increasing generation.
struct JobSlot {
    generation: u64,
    task: Option<TaskRef>,
    nworkers: usize,
    /// Participating workers that have not yet finished the current job.
    active: usize,
    /// A worker's job body panicked (re-raised on the launcher).
    panicked: bool,
    /// A launch is in flight (serialises concurrent submitters).
    busy: bool,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<JobSlot>,
    /// Workers park here between launches.
    go: Condvar,
    /// The launcher parks here until `active` drains to zero.
    done: Condvar,
}

/// Persistent parked worker threads (spawned once per [`TlpPool`]).
struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn the persistent workers; with `pin_first = Some(first_cpu)`
    /// worker `idx` pins itself to CPU `(first_cpu + idx) % nproc` before
    /// parking (the round-robin layout the comms ranks use so rank r's
    /// workers occupy CPUs `r * nthreads ..`).
    fn spawn(nthreads: usize, pin_first: Option<usize>) -> Self {
        let shared = Arc::new(Shared {
            slot: Mutex::new(JobSlot {
                generation: 0,
                task: None,
                nworkers: 0,
                active: 0,
                panicked: false,
                busy: false,
                shutdown: false,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..nthreads)
            .map(|idx| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || {
                    if let Some(first) = pin_first {
                        let nproc = std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1);
                        let _ = pin_to_cpu((first + idx) % nproc);
                    }
                    worker_loop(&shared, idx)
                })
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Publish `task` to the workers and block until workers
    /// `0..nworkers` have each run `task(worker_index)` to completion.
    fn run(&self, nworkers: usize, task: &(dyn Fn(usize) + Sync)) {
        // SAFETY: the erased borrow is only dereferenced by workers between
        // the publish below and the `active == 0` handshake; this function
        // does not return before that handshake completes.
        let task: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(task) };

        let mut slot = self.shared.slot.lock().unwrap();
        while slot.busy {
            slot = self.shared.done.wait(slot).unwrap();
        }
        slot.busy = true;
        slot.task = Some(TaskRef(task as *const _));
        slot.nworkers = nworkers;
        slot.active = nworkers;
        slot.generation += 1;
        drop(slot);
        self.shared.go.notify_all();

        let mut slot = self.shared.slot.lock().unwrap();
        while slot.active > 0 {
            slot = self.shared.done.wait(slot).unwrap();
        }
        let panicked = slot.panicked;
        slot.panicked = false;
        slot.task = None;
        slot.busy = false;
        drop(slot);
        // wake any launcher queued behind `busy`
        self.shared.done.notify_all();
        if panicked {
            // the scoped-thread implementation re-raised worker panics on
            // join; preserve that instead of silently losing chunks
            panic!("TLP kernel body panicked in a worker thread");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.slot.lock().unwrap().shutdown = true;
        self.shared.go.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, idx: usize) {
    let mut seen = 0u64;
    loop {
        let (generation, task, nworkers) = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.generation != seen {
                    break;
                }
                slot = shared.go.wait(slot).unwrap();
            }
            (slot.generation, slot.task, slot.nworkers)
        };
        seen = generation;
        // a worker beyond the launch width (or one that raced a cleared
        // slot) just acknowledges the generation and parks again
        let Some(task) = task else { continue };
        if idx >= nworkers {
            continue;
        }
        // a panicking body must still check in, or the launcher would wait
        // on `active` forever; the panic is re-raised by `run`
        let result = catch_unwind(AssertUnwindSafe(|| {
            unsafe { (&*task.0)(idx) };
        }));
        let mut slot = shared.slot.lock().unwrap();
        slot.active -= 1;
        if result.is_err() {
            slot.panicked = true;
        }
        let finished = slot.active == 0;
        drop(slot);
        if finished {
            shared.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    fn cover(nsites: usize, vvl: usize, pool: TlpPool) -> Vec<u32> {
        let hits = Mutex::new(vec![0u32; nsites]);
        pool.for_chunks(nsites, vvl, |base, len| {
            let mut h = hits.lock().unwrap();
            for s in base..base + len {
                h[s] += 1;
            }
        });
        hits.into_inner().unwrap()
    }

    #[test]
    fn every_site_exactly_once_serial() {
        for (n, vvl) in [(0, 4), (1, 4), (7, 4), (8, 4), (100, 8), (5, 16)] {
            let hits = cover(n, vvl, TlpPool::serial());
            assert!(hits.iter().all(|&h| h == 1), "n={n} vvl={vvl}");
        }
    }

    #[test]
    fn every_site_exactly_once_static_threads() {
        for threads in [2, 3, 5] {
            let pool = TlpPool::new(threads, Schedule::Static);
            let hits = cover(103, 8, pool);
            assert!(hits.iter().all(|&h| h == 1), "threads={threads}");
        }
    }

    #[test]
    fn every_site_exactly_once_dynamic() {
        for batch in [1, 2, 7] {
            let pool = TlpPool::new(4, Schedule::Dynamic { batch });
            let hits = cover(97, 4, pool);
            assert!(hits.iter().all(|&h| h == 1), "batch={batch}");
        }
    }

    #[test]
    fn tail_chunk_is_short() {
        let pool = TlpPool::serial();
        let lens = Mutex::new(vec![]);
        pool.for_chunks(10, 4, |base, len| {
            lens.lock().unwrap().push((base, len));
        });
        assert_eq!(lens.into_inner().unwrap(), vec![(0, 4), (4, 4), (8, 2)]);
    }

    #[test]
    #[should_panic(expected = "VVL must be positive")]
    fn zero_vvl_panics() {
        TlpPool::serial().for_chunks(8, 0, |_, _| {});
    }

    #[test]
    fn workers_are_persistent_across_launches() {
        // the whole point of the rewrite: repeated launches reuse the same
        // parked workers instead of spawning fresh OS threads (the old
        // scoped implementation would show ~3 new ids per launch here)
        use std::collections::HashSet;
        let pool = TlpPool::new(3, Schedule::Static);
        let ids = Mutex::new(HashSet::new());
        for _ in 0..50 {
            pool.for_chunks(64, 4, |_, _| {
                ids.lock().unwrap().insert(std::thread::current().id());
            });
        }
        let ids = ids.into_inner().unwrap();
        assert!(!ids.is_empty());
        assert!(ids.len() <= 3, "saw {} distinct worker threads", ids.len());
    }

    #[test]
    fn launch_width_can_vary_between_launches() {
        // nworkers = min(nthreads, nchunks) changes per launch; parked
        // non-participants must not wedge the generation handshake
        let pool = TlpPool::new(4, Schedule::Static);
        for nsites in [4, 40, 8, 400, 4] {
            let hits = Mutex::new(vec![0u32; nsites]);
            pool.for_chunks(nsites, 4, |base, len| {
                let mut h = hits.lock().unwrap();
                for s in base..base + len {
                    h[s] += 1;
                }
            });
            let h = hits.into_inner().unwrap();
            assert!(h.iter().all(|&x| x == 1), "nsites={nsites}");
        }
    }

    #[test]
    #[should_panic(expected = "kernel body panicked")]
    fn worker_panic_propagates_to_launcher() {
        let pool = TlpPool::new(2, Schedule::Static);
        pool.for_chunks(8, 2, |base, _len| {
            assert!(base != 4, "boom");
        });
    }

    #[test]
    fn pool_survives_a_panicked_launch() {
        let pool = TlpPool::new(2, Schedule::Static);
        let poisoned = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.for_chunks(8, 2, |base, _len| {
                    assert!(base != 4, "boom");
                });
            }),
        );
        assert!(poisoned.is_err());
        // the workers parked cleanly and the next launch works
        let hits = cover(40, 4, pool);
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn zeros_is_fully_initialised() {
        for pool in [TlpPool::serial(), TlpPool::new(3, Schedule::Static),
                     TlpPool::new(2, Schedule::Dynamic { batch: 2 })] {
            for len in [0usize, 1, 511, 4096, 3 * 4096 + 17] {
                let v = pool.zeros(len);
                assert_eq!(v.len(), len);
                assert!(v.iter().all(|&x| x == 0.0),
                        "len={len} pool={pool:?}");
            }
        }
    }

    #[test]
    fn threads_per_rank_splits_evenly() {
        assert_eq!(threads_per_rank(8, 2), 4);
        assert_eq!(threads_per_rank(8, 3), 2);
        // never below one thread per rank
        assert_eq!(threads_per_rank(2, 8), 1);
        assert_eq!(threads_per_rank(1, 1), 1);
        // 0 = divide the detected machine width: at least 1 each
        assert!(threads_per_rank(0, 4) >= 1);
    }

    #[test]
    fn pinned_pools_cover_every_site() {
        // pinning is a locality hint: chunk coverage (and hence results)
        // must be identical with and without it, on every platform
        let pool = TlpPool::new_pinned(3, Schedule::Static, 0);
        let hits = cover(103, 8, pool);
        assert!(hits.iter().all(|&h| h == 1));
        // nthreads == 1 pins the calling thread and runs inline; the
        // clone re-pins its own fresh workers from the same first CPU
        let one = TlpPool::new_pinned(1, Schedule::Static, 1);
        let hits = cover(9, 4, one.clone());
        assert!(hits.iter().all(|&h| h == 1));
        let hits = cover(9, 4, one);
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn traced_pool_records_one_span_per_worker_per_launch() {
        use crate::obs::trace::{PoolTrace, TracePhase};
        use std::time::Instant;
        let mut pool = TlpPool::new(3, Schedule::Static);
        let trace = PoolTrace::new(3, Instant::now(), 64);
        pool.set_trace(Arc::clone(&trace));
        pool.trace_context(TracePhase::Collide, 9);
        // clone shares the sink, and coverage is unchanged by tracing
        let hits = cover(103, 8, pool.clone());
        assert!(hits.iter().all(|&h| h == 1));
        let spans = trace.drain();
        assert_eq!(spans.len(), 3, "one span per participating worker");
        for s in &spans {
            assert_eq!(s.phase, TracePhase::Collide);
            assert_eq!(s.step, 9);
            assert!((1..=3).contains(&s.tid), "worker tids are 1-based");
            assert!(s.t_end >= s.t_start);
        }
        // an untraced pool records nothing
        let quiet = TlpPool::new(2, Schedule::Static);
        let hits = cover(40, 4, quiet);
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn clone_gets_independent_workers() {
        let pool = TlpPool::new(2, Schedule::Dynamic { batch: 1 });
        let copy = pool.clone();
        assert_eq!(copy.nthreads, 2);
        assert_eq!(copy.schedule, pool.schedule);
        let hits = cover(33, 4, copy);
        assert!(hits.iter().all(|&h| h == 1));
        // original still works after the clone is dropped
        let hits = cover(33, 4, pool);
        assert!(hits.iter().all(|&h| h == 1));
    }
}
