//! Thread-level parallelism: the `TARGET_TLP` analog.
//!
//! The paper's C implementation expands `TARGET_TLP(baseIndex, N)` to
//!
//! ```c
//! _Pragma("omp parallel for")
//! for (baseIndex = 0; baseIndex < N; baseIndex += VVL)
//! ```
//!
//! i.e. the site loop is strip-mined in strides of VVL and the chunks are
//! decomposed between OpenMP threads. [`TlpPool::for_chunks`] reproduces
//! exactly that: the closure receives `(base, len)` for each chunk of at
//! most `vvl` sites and chunks are distributed over `nthreads` workers with
//! either static (OpenMP `schedule(static)`) or dynamic
//! (`schedule(dynamic, k)`) assignment — the launch-geometry tuning knob
//! benchmarked in `benches/tlp_sched.rs` (E5).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Chunk-to-thread assignment policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// Contiguous block of chunks per thread (OpenMP `schedule(static)`).
    Static,
    /// Threads grab batches of `chunk` chunks from a shared cursor
    /// (OpenMP `schedule(dynamic, chunk)`).
    Dynamic { batch: usize },
}

impl Default for Schedule {
    fn default() -> Self {
        Schedule::Static
    }
}

/// The TLP worker pool configuration.
///
/// Threads are scoped per launch (no persistent worker state), which keeps
/// kernels free to borrow stack data; with `nthreads == 1` the launch runs
/// inline with zero overhead — the hot path on this single-core testbed.
#[derive(Debug, Clone, Copy)]
pub struct TlpPool {
    pub nthreads: usize,
    pub schedule: Schedule,
}

impl Default for TlpPool {
    fn default() -> Self {
        TlpPool { nthreads: default_threads(), schedule: Schedule::Static }
    }
}

/// `TARGETDP_NUM_THREADS` env var, else available parallelism.
pub fn default_threads() -> usize {
    std::env::var("TARGETDP_NUM_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

impl TlpPool {
    pub fn new(nthreads: usize, schedule: Schedule) -> Self {
        TlpPool { nthreads: nthreads.max(1), schedule }
    }

    /// Serial pool (inline execution).
    pub fn serial() -> Self {
        TlpPool { nthreads: 1, schedule: Schedule::Static }
    }

    /// Strip-mine `nsites` into chunks of at most `vvl` sites and run
    /// `body(base, len)` for every chunk (`len < vvl` only for the tail).
    pub fn for_chunks<F>(&self, nsites: usize, vvl: usize, body: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        assert!(vvl > 0, "VVL must be positive");
        if nsites == 0 {
            return;
        }
        let nchunks = nsites.div_ceil(vvl);
        let run_chunk = |c: usize| {
            let base = c * vvl;
            let len = vvl.min(nsites - base);
            body(base, len);
        };

        if self.nthreads <= 1 || nchunks == 1 {
            for c in 0..nchunks {
                run_chunk(c);
            }
            return;
        }

        let nthreads = self.nthreads.min(nchunks);
        match self.schedule {
            Schedule::Static => {
                // contiguous ranges of chunks, remainder spread over the
                // first threads (OpenMP static semantics)
                let per = nchunks / nthreads;
                let rem = nchunks % nthreads;
                std::thread::scope(|s| {
                    let mut start = 0;
                    for t in 0..nthreads {
                        let count = per + usize::from(t < rem);
                        let range = start..start + count;
                        start += count;
                        let run_chunk = &run_chunk;
                        s.spawn(move || {
                            for c in range {
                                run_chunk(c);
                            }
                        });
                    }
                });
            }
            Schedule::Dynamic { batch } => {
                let batch = batch.max(1);
                let cursor = AtomicUsize::new(0);
                std::thread::scope(|s| {
                    for _ in 0..nthreads {
                        let cursor = &cursor;
                        let run_chunk = &run_chunk;
                        s.spawn(move || loop {
                            let begin =
                                cursor.fetch_add(batch, Ordering::Relaxed);
                            if begin >= nchunks {
                                break;
                            }
                            for c in begin..(begin + batch).min(nchunks) {
                                run_chunk(c);
                            }
                        });
                    }
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    fn cover(nsites: usize, vvl: usize, pool: TlpPool) -> Vec<u32> {
        let hits = Mutex::new(vec![0u32; nsites]);
        pool.for_chunks(nsites, vvl, |base, len| {
            let mut h = hits.lock().unwrap();
            for s in base..base + len {
                h[s] += 1;
            }
        });
        hits.into_inner().unwrap()
    }

    #[test]
    fn every_site_exactly_once_serial() {
        for (n, vvl) in [(0, 4), (1, 4), (7, 4), (8, 4), (100, 8), (5, 16)] {
            let hits = cover(n, vvl, TlpPool::serial());
            assert!(hits.iter().all(|&h| h == 1), "n={n} vvl={vvl}");
        }
    }

    #[test]
    fn every_site_exactly_once_static_threads() {
        for threads in [2, 3, 5] {
            let pool = TlpPool::new(threads, Schedule::Static);
            let hits = cover(103, 8, pool);
            assert!(hits.iter().all(|&h| h == 1), "threads={threads}");
        }
    }

    #[test]
    fn every_site_exactly_once_dynamic() {
        for batch in [1, 2, 7] {
            let pool = TlpPool::new(4, Schedule::Dynamic { batch });
            let hits = cover(97, 4, pool);
            assert!(hits.iter().all(|&h| h == 1), "batch={batch}");
        }
    }

    #[test]
    fn tail_chunk_is_short() {
        let pool = TlpPool::serial();
        let lens = Mutex::new(vec![]);
        pool.for_chunks(10, 4, |base, len| {
            lens.lock().unwrap().push((base, len));
        });
        assert_eq!(lens.into_inner().unwrap(), vec![(0, 4), (4, 4), (8, 2)]);
    }

    #[test]
    #[should_panic(expected = "VVL must be positive")]
    fn zero_vvl_panics() {
        TlpPool::serial().for_chunks(8, 0, |_, _| {});
    }
}
