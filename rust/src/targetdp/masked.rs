//! Masked host<->target copies (paper section III-B).
//!
//! `copyToTargetMasked` / `copyFromTargetMasked` take a boolean mask over
//! the `nsites` lattice sites and transfer only the selected sites. The
//! CUDA implementation packs the selected sites into a scratch structure on
//! the device, moves the packed data, and unpacks on the other side; the C
//! implementation does the same with loops. Both shapes are reproduced
//! here: [`pack`] / [`unpack`] are the scratch-structure route (used by the
//! XLA target, where the transfer itself is the expensive step) and
//! [`copy_masked_direct`] is the loop route (used by the host targets).
//!
//! Masks follow the paper's convention: one flag per *site*; all `ncomp`
//! SoA components of a selected site are transferred.

/// Indices of the selected sites (the packed layout order).
pub fn mask_indices(mask: &[bool]) -> Vec<usize> {
    mask.iter()
        .enumerate()
        .filter_map(|(i, &m)| m.then_some(i))
        .collect()
}

/// Pack the masked sites of an SoA field into a dense scratch buffer.
///
/// `src` has `ncomp * nsites` elements; the result has
/// `ncomp * indices.len()` elements, still SoA (component-major).
pub fn pack(src: &[f64], nsites: usize, ncomp: usize,
            indices: &[usize]) -> Vec<f64> {
    debug_assert_eq!(src.len(), ncomp * nsites);
    let nsel = indices.len();
    let mut out = vec![0.0; ncomp * nsel];
    for c in 0..ncomp {
        let row = &src[c * nsites..(c + 1) * nsites];
        let orow = &mut out[c * nsel..(c + 1) * nsel];
        for (k, &s) in indices.iter().enumerate() {
            orow[k] = row[s];
        }
    }
    out
}

/// Unpack a dense scratch buffer back into the masked sites of `dst`.
pub fn unpack(dst: &mut [f64], nsites: usize, ncomp: usize,
              indices: &[usize], packed: &[f64]) {
    debug_assert_eq!(dst.len(), ncomp * nsites);
    debug_assert_eq!(packed.len(), ncomp * indices.len());
    let nsel = indices.len();
    for c in 0..ncomp {
        let row = &mut dst[c * nsites..(c + 1) * nsites];
        let prow = &packed[c * nsel..(c + 1) * nsel];
        for (k, &s) in indices.iter().enumerate() {
            row[s] = prow[k];
        }
    }
}

/// Loop-based masked copy (the paper's C implementation): copy the selected
/// sites of `src` into `dst` in place, both full SoA fields.
pub fn copy_masked_direct(dst: &mut [f64], src: &[f64], nsites: usize,
                          ncomp: usize, mask: &[bool]) {
    debug_assert_eq!(src.len(), ncomp * nsites);
    debug_assert_eq!(dst.len(), ncomp * nsites);
    debug_assert_eq!(mask.len(), nsites);
    for c in 0..ncomp {
        let off = c * nsites;
        for s in 0..nsites {
            if mask[s] {
                dst[off + s] = src[off + s];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn field(ncomp: usize, nsites: usize) -> Vec<f64> {
        (0..ncomp * nsites).map(|i| i as f64).collect()
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let nsites = 10;
        let ncomp = 3;
        let src = field(ncomp, nsites);
        let mask: Vec<bool> = (0..nsites).map(|i| i % 3 == 0).collect();
        let idx = mask_indices(&mask);
        let packed = pack(&src, nsites, ncomp, &idx);
        assert_eq!(packed.len(), ncomp * idx.len());

        let mut dst = vec![-1.0; ncomp * nsites];
        unpack(&mut dst, nsites, ncomp, &idx, &packed);
        for c in 0..ncomp {
            for s in 0..nsites {
                let want = if mask[s] { src[c * nsites + s] } else { -1.0 };
                assert_eq!(dst[c * nsites + s], want);
            }
        }
    }

    #[test]
    fn direct_equals_pack_route() {
        let nsites = 17;
        let ncomp = 19;
        let src = field(ncomp, nsites);
        let mask: Vec<bool> = (0..nsites).map(|i| i % 2 == 1).collect();

        let mut via_direct = vec![0.0; ncomp * nsites];
        copy_masked_direct(&mut via_direct, &src, nsites, ncomp, &mask);

        let idx = mask_indices(&mask);
        let packed = pack(&src, nsites, ncomp, &idx);
        let mut via_pack = vec![0.0; ncomp * nsites];
        unpack(&mut via_pack, nsites, ncomp, &idx, &packed);

        assert_eq!(via_direct, via_pack);
    }

    #[test]
    fn empty_and_full_masks() {
        let src = field(2, 5);
        let idx_none = mask_indices(&[false; 5]);
        assert!(pack(&src, 5, 2, &idx_none).is_empty());
        let idx_all = mask_indices(&[true; 5]);
        assert_eq!(pack(&src, 5, 2, &idx_all), src);
    }
}
