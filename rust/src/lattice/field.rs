//! Host-side lattice fields and the SoA/AoS layout conversions.
//!
//! targetDP mandates SoA ("Structure of Arrays") so that consecutive site
//! indices are consecutive in memory and VVL chunks load as vectors
//! (paper section III-B). The AoS layout (`data[site * ncomp + c]`) is kept
//! for the [`crate::baseline`] comparator and the E3 layout ablation.

use crate::lattice::geometry::Geometry;

/// A host lattice field in SoA layout: `data[c * nsites + s]`.
#[derive(Debug, Clone, PartialEq)]
pub struct HostField {
    pub name: String,
    pub ncomp: usize,
    pub nsites: usize,
    pub data: Vec<f64>,
}

impl HostField {
    pub fn zeros(name: impl Into<String>, ncomp: usize, nsites: usize) -> Self {
        HostField {
            name: name.into(),
            ncomp,
            nsites,
            data: vec![0.0; ncomp * nsites],
        }
    }

    pub fn from_fn(name: impl Into<String>, ncomp: usize, geom: &Geometry,
                   f: impl Fn(usize, usize, usize, usize) -> f64) -> Self {
        let nsites = geom.nsites();
        let mut field = Self::zeros(name, ncomp, nsites);
        for c in 0..ncomp {
            for (x, y, z, s) in geom.iter() {
                field.data[c * nsites + s] = f(c, x, y, z);
            }
        }
        field
    }

    #[inline(always)]
    pub fn get(&self, c: usize, s: usize) -> f64 {
        self.data[c * self.nsites + s]
    }

    #[inline(always)]
    pub fn set(&mut self, c: usize, s: usize, v: f64) {
        self.data[c * self.nsites + s] = v;
    }

    /// SoA component row.
    pub fn row(&self, c: usize) -> &[f64] {
        &self.data[c * self.nsites..(c + 1) * self.nsites]
    }

    /// Convert to AoS: `out[s * ncomp + c]`.
    pub fn to_aos(&self) -> Vec<f64> {
        soa_to_aos(&self.data, self.ncomp, self.nsites)
    }

    /// Build from an AoS buffer.
    pub fn from_aos(name: impl Into<String>, aos: &[f64], ncomp: usize,
                    nsites: usize) -> Self {
        HostField {
            name: name.into(),
            ncomp,
            nsites,
            data: aos_to_soa(aos, ncomp, nsites),
        }
    }
}

/// `soa[c * nsites + s]` -> `aos[s * ncomp + c]`.
pub fn soa_to_aos(soa: &[f64], ncomp: usize, nsites: usize) -> Vec<f64> {
    debug_assert_eq!(soa.len(), ncomp * nsites);
    let mut aos = vec![0.0; soa.len()];
    for c in 0..ncomp {
        for s in 0..nsites {
            aos[s * ncomp + c] = soa[c * nsites + s];
        }
    }
    aos
}

/// `aos[s * ncomp + c]` -> `soa[c * nsites + s]`.
pub fn aos_to_soa(aos: &[f64], ncomp: usize, nsites: usize) -> Vec<f64> {
    debug_assert_eq!(aos.len(), ncomp * nsites);
    let mut soa = vec![0.0; aos.len()];
    for c in 0..ncomp {
        for s in 0..nsites {
            soa[c * nsites + s] = aos[s * ncomp + c];
        }
    }
    soa
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_conversions_roundtrip() {
        let ncomp = 19;
        let nsites = 37;
        let soa: Vec<f64> = (0..ncomp * nsites).map(|i| i as f64).collect();
        let aos = soa_to_aos(&soa, ncomp, nsites);
        assert_eq!(aos_to_soa(&aos, ncomp, nsites), soa);
        // spot-check addressing
        assert_eq!(aos[5 * ncomp + 3], soa[3 * nsites + 5]);
    }

    #[test]
    fn from_fn_and_accessors() {
        let geom = Geometry::new(2, 3, 4);
        let f = HostField::from_fn("v", 3, &geom,
                                   |c, x, y, z| (c * 100 + x * 16 + y * 4 + z)
                                       as f64);
        assert_eq!(f.get(2, geom.index(1, 2, 3)), 227.0);
        assert_eq!(f.row(1).len(), geom.nsites());
    }

    #[test]
    fn field_aos_roundtrip() {
        let geom = Geometry::new(3, 3, 3);
        let f = HostField::from_fn("x", 2, &geom,
                                   |c, x, _, _| c as f64 + x as f64);
        let aos = f.to_aos();
        let back = HostField::from_aos("x", &aos, 2, geom.nsites());
        assert_eq!(back.data, f.data);
    }
}
