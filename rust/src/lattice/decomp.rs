//! Domain decomposition: the partitioning layer under the rank-parallel
//! [`crate::comms`] subsystem.
//!
//! The paper's framework is explicitly designed to combine with node-level
//! parallelism ("targetDP may be used in conjunction with ... MPI"). This
//! module owns the *geometry* of that level, in two tiers:
//!
//! * [`SlabDecomposition`] / [`SubDomain`] — the x-slab split Ludwig
//!   historically used: each subdomain holds `lxl` interior planes plus
//!   one (or, for super-steps, `k`) halo plane per side. The slab layout
//!   keeps every exchanged plane a contiguous slice copy.
//! * [`CartDecomposition`] / [`CartSubDomain`] — the general 3D Cartesian
//!   `(px, py, pz)` rank grid (Ludwig's production MPI decomposition):
//!   halo *surface* scales with the local surface-to-volume ratio instead
//!   of growing linearly with rank count. A slab grid `(p, 1, 1)` is the
//!   exact special case ([`CartSubDomain::to_slab`]), so every slab code
//!   path keeps its meaning. [`CartDecomposition::auto_grid`] picks the
//!   surface-minimizing factorization when only a rank count is given.
//!
//! Everything that *moves* data between subdomains (halo exchange,
//! overlap with compute, transports) lives in [`crate::comms`], which
//! runs one concurrent rank per subdomain; this module only answers
//! "which global sites does rank r own, and where do they sit in its
//! local lattice".
//!
//! With z fastest in memory, an x plane is a contiguous `ly * lz` block
//! per SoA component, so slab scatters/gathers and x-halo packing are
//! pure slice copies (see `halo::pack_x_plane`); y/z faces are strided
//! (see `halo::pack_face`) and grid-interior traversal happens over the
//! run list of [`box_runs`].

use crate::error::{Error, Result};
use crate::lattice::geometry::Geometry;

/// One slab subdomain: interior `lxl` planes + 2 halo planes.
#[derive(Debug, Clone)]
pub struct SubDomain {
    pub rank: usize,
    /// Global x of the first interior plane.
    pub x0: usize,
    /// Interior extent along x.
    pub lxl: usize,
    /// Local geometry *including* the two halo planes.
    pub local: Geometry,
}

impl SubDomain {
    /// Sites per x plane.
    pub fn plane(&self) -> usize {
        self.local.ly * self.local.lz
    }

    /// Local site range covering the interior (contiguous by layout).
    pub fn interior(&self) -> std::ops::Range<usize> {
        self.interior_with_halo(1)
    }

    /// Local geometry for a deep ghost region of `halo` planes per side —
    /// the lattice a communication-avoiding super-step runs on (`halo` =
    /// `HALO_PER_STEP * depth`). `halo = 1` is [`SubDomain::local`].
    pub fn local_with_halo(&self, halo: usize) -> Geometry {
        Geometry::new(self.lxl + 2 * halo, self.local.ly, self.local.lz)
    }

    /// Interior site range inside a `halo`-deep local lattice.
    pub fn interior_with_halo(&self, halo: usize) -> std::ops::Range<usize> {
        halo * self.plane()..(halo + self.lxl) * self.plane()
    }

    /// Copy this subdomain's interior planes out of a global SoA field
    /// into `local` (`ncomp * local.nsites()`; halo planes untouched).
    /// This is the per-rank half of [`SlabDecomposition::scatter`] — the
    /// comms ranks call it from their *own* threads so a freshly
    /// first-touch-allocated local field is filled where it will be swept.
    pub fn scatter_into(&self, global: &[f64], ncomp: usize,
                        local: &mut [f64]) {
        self.scatter_into_with_halo(global, ncomp, local, 1)
    }

    /// [`SubDomain::scatter_into`] for a `halo`-deep local lattice (the
    /// [`SubDomain::local_with_halo`] shape).
    pub fn scatter_into_with_halo(&self, global: &[f64], ncomp: usize,
                                  local: &mut [f64], halo: usize) {
        let ln = self.local_with_halo(halo).nsites();
        let gn = global.len() / ncomp;
        let plane = self.plane();
        debug_assert_eq!(global.len(), ncomp * gn);
        debug_assert_eq!(local.len(), ncomp * ln);
        debug_assert!((self.x0 + self.lxl) * plane <= gn);
        for c in 0..ncomp {
            let src = &global[c * gn + self.x0 * plane
                ..c * gn + (self.x0 + self.lxl) * plane];
            local[c * ln + halo * plane
                ..c * ln + (halo + self.lxl) * plane]
                .copy_from_slice(src);
        }
    }

    /// Copy out the interior planes of a local SoA field as one packed
    /// payload (halo planes dropped) — the body of a comms `Gather`
    /// response wire frame (`ncomp * lxl * plane` doubles,
    /// component-major).
    pub fn interior_of(&self, local: &[f64], ncomp: usize) -> Vec<f64> {
        self.interior_of_with_halo(local, ncomp, 1)
    }

    /// [`SubDomain::interior_of`] for a `halo`-deep local lattice.
    pub fn interior_of_with_halo(&self, local: &[f64], ncomp: usize,
                                 halo: usize) -> Vec<f64> {
        let ln = self.local_with_halo(halo).nsites();
        let plane = self.plane();
        debug_assert_eq!(local.len(), ncomp * ln);
        let mut out = Vec::with_capacity(ncomp * self.lxl * plane);
        for c in 0..ncomp {
            out.extend_from_slice(
                &local[c * ln + halo * plane
                    ..c * ln + (halo + self.lxl) * plane],
            );
        }
        out
    }

    /// Place a packed interior payload (the [`SubDomain::interior_of`]
    /// layout) into a global SoA field at this subdomain's x offset — the
    /// receiving half of a comms `Gather`.
    pub fn place_interior(&self, interior: &[f64], ncomp: usize,
                          global: &mut [f64]) {
        let plane = self.plane();
        let il = self.lxl * plane;
        let gn = global.len() / ncomp;
        debug_assert_eq!(interior.len(), ncomp * il);
        debug_assert_eq!(global.len(), ncomp * gn);
        debug_assert!((self.x0 + self.lxl) * plane <= gn);
        for c in 0..ncomp {
            let lo = c * gn + self.x0 * plane;
            global[lo..lo + il]
                .copy_from_slice(&interior[c * il..(c + 1) * il]);
        }
    }

    /// Copy this subdomain's interior planes back into a global SoA field
    /// — the inverse of [`SubDomain::scatter_into`].
    pub fn gather_from(&self, local: &[f64], ncomp: usize,
                       global: &mut [f64]) {
        let ln = self.local.nsites();
        let gn = global.len() / ncomp;
        let plane = self.plane();
        debug_assert_eq!(global.len(), ncomp * gn);
        debug_assert_eq!(local.len(), ncomp * ln);
        for c in 0..ncomp {
            let dst = &mut global[c * gn + self.x0 * plane
                ..c * gn + (self.x0 + self.lxl) * plane];
            dst.copy_from_slice(
                &local[c * ln + plane..c * ln + (self.lxl + 1) * plane],
            );
        }
    }
}

/// Slab decomposition of a global periodic lattice along x.
#[derive(Debug, Clone)]
pub struct SlabDecomposition {
    pub global: Geometry,
    pub domains: Vec<SubDomain>,
}

impl SlabDecomposition {
    pub fn new(global: Geometry, ndom: usize) -> Result<Self> {
        if ndom == 0 || global.lx < ndom {
            return Err(Error::Invalid(format!(
                "cannot split lx={} into {ndom} slabs", global.lx
            )));
        }
        let mut domains = Vec::with_capacity(ndom);
        let mut x0 = 0;
        for rank in 0..ndom {
            let lxl = global.lx / ndom + usize::from(rank < global.lx % ndom);
            domains.push(SubDomain {
                rank,
                x0,
                lxl,
                local: Geometry::new(lxl + 2, global.ly, global.lz),
            });
            x0 += lxl;
        }
        Ok(SlabDecomposition { global, domains })
    }

    /// Scatter a global SoA field into per-domain local fields (halos
    /// left zero; the first comms exchange fills them).
    pub fn scatter(&self, global: &[f64], ncomp: usize) -> Vec<Vec<f64>> {
        debug_assert_eq!(global.len(), ncomp * self.global.nsites());
        self.domains
            .iter()
            .map(|d| {
                let mut local = vec![0.0; ncomp * d.local.nsites()];
                d.scatter_into(global, ncomp, &mut local);
                local
            })
            .collect()
    }

    /// Gather per-domain interiors back into a global SoA field.
    pub fn gather(&self, locals: &[Vec<f64>], ncomp: usize) -> Vec<f64> {
        let mut global = vec![0.0; ncomp * self.global.nsites()];
        self.gather_into(locals, ncomp, &mut global);
        global
    }

    /// Gather into a caller-owned global buffer (no allocation).
    pub fn gather_into(&self, locals: &[Vec<f64>], ncomp: usize,
                       global: &mut [f64]) {
        for (d, local) in self.domains.iter().zip(locals) {
            d.gather_from(local, ncomp, global);
        }
    }
}

/// Axis names for decomposition error messages ("x", "y", "z").
pub const AXIS_NAMES: [&str; 3] = ["x", "y", "z"];

/// Linear site ranges covering the axis-aligned box `lo..hi` (half-open
/// per axis) of `geom`, in x-major / y / z-ascending order — the
/// traversal order every packed payload in this module uses. Collapses
/// to the fewest contiguous runs the layout allows: one run when the box
/// spans full y and z (a slab of x planes), per-x runs when it spans
/// full z, per-(x, y) z-rows otherwise. Empty when the box is.
pub fn box_runs(geom: &Geometry, lo: [usize; 3], hi: [usize; 3])
                -> Vec<std::ops::Range<usize>> {
    debug_assert!(hi[0] <= geom.lx && hi[1] <= geom.ly && hi[2] <= geom.lz);
    if (0..3).any(|a| lo[a] >= hi[a]) {
        return Vec::new();
    }
    let full_y = lo[1] == 0 && hi[1] == geom.ly;
    let full_z = lo[2] == 0 && hi[2] == geom.lz;
    if full_y && full_z {
        let plane = geom.ly * geom.lz;
        return vec![lo[0] * plane..hi[0] * plane];
    }
    let mut runs = Vec::new();
    if full_z {
        for x in lo[0]..hi[0] {
            let s = geom.index(x, lo[1], 0);
            runs.push(s..s + (hi[1] - lo[1]) * geom.lz);
        }
    } else {
        for x in lo[0]..hi[0] {
            for y in lo[1]..hi[1] {
                let s = geom.index(x, y, lo[2]);
                runs.push(s..s + hi[2] - lo[2]);
            }
        }
    }
    runs
}

/// One subdomain of a 3D Cartesian rank grid: an `ext[0] x ext[1] x
/// ext[2]` interior box plus `halo[a]` ghost planes per side on every
/// *decomposed* axis (`grid[a] > 1`); non-decomposed axes keep the full
/// global extent so local periodic wraps along them stay physical.
///
/// Carries its own `grid` and `global` so neighbour ranks and global
/// placement are computable without the parent [`CartDecomposition`] —
/// this is what ships to a rank process.
#[derive(Debug, Clone)]
pub struct CartSubDomain {
    pub rank: usize,
    /// Position in the rank grid: `coords[a] in 0..grid[a]`.
    pub coords: [usize; 3],
    /// Global coordinate of the first interior site, per axis.
    pub origin: [usize; 3],
    /// Interior extent per axis.
    pub ext: [usize; 3],
    /// Ghost planes per side per axis (0 on non-decomposed axes; the
    /// slab special case reports `[1, 0, 0]` and the slab code path
    /// substitutes its own super-step depth).
    pub halo: [usize; 3],
    /// Rank-grid shape `(px, py, pz)`.
    pub grid: [usize; 3],
    /// The global lattice being decomposed.
    pub global: Geometry,
    /// Local geometry *including* halos.
    pub local: Geometry,
}

impl CartSubDomain {
    /// Rank id of grid coordinates under the canonical x-slowest map
    /// `r = (cx * py + cy) * pz + cz` — on a slab grid `(p, 1, 1)` this
    /// is `r = cx`, so slab rank ids keep their meaning, and consecutive
    /// ids are z-grid neighbours (what the topology-aware launcher packs
    /// onto one host).
    pub fn rank_of(grid: [usize; 3], coords: [usize; 3]) -> usize {
        (coords[0] * grid[1] + coords[1]) * grid[2] + coords[2]
    }

    /// Number of interior (owned) sites.
    pub fn interior_sites(&self) -> usize {
        self.ext.iter().product()
    }

    /// True when the grid decomposes x only — the `(p, 1, 1)` shape the
    /// slab code path (including depth-k super-steps) handles.
    pub fn is_slab(&self) -> bool {
        self.grid[1] == 1 && self.grid[2] == 1
    }

    /// The equivalent [`SubDomain`] of a slab-shaped grid.
    pub fn to_slab(&self) -> SubDomain {
        debug_assert!(self.is_slab());
        SubDomain {
            rank: self.rank,
            x0: self.origin[0],
            lxl: self.ext[0],
            local: Geometry::new(self.ext[0] + 2, self.global.ly,
                                 self.global.lz),
        }
    }

    /// Rank id of the face neighbour along `axis` (`up`: toward larger
    /// coordinates), periodic in the rank grid.
    pub fn neighbor(&self, axis: usize, up: bool) -> usize {
        let p = self.grid[axis];
        let mut c = self.coords;
        c[axis] = if up { (c[axis] + 1) % p } else { (c[axis] + p - 1) % p };
        Self::rank_of(self.grid, c)
    }

    /// Sites in one face plane of `axis`, spanning the *full* local
    /// extent (halos included) of the other two axes — the payload site
    /// count of one face frame (see `halo::pack_face`).
    pub fn face_sites(&self, axis: usize) -> usize {
        let le = [self.local.lx, self.local.ly, self.local.lz];
        (0..3).filter(|&b| b != axis).map(|b| le[b]).product()
    }

    /// Interior box bounds in local coordinates: `halo .. halo + ext`.
    pub fn interior_box(&self) -> ([usize; 3], [usize; 3]) {
        let lo = self.halo;
        let hi = [lo[0] + self.ext[0], lo[1] + self.ext[1],
                  lo[2] + self.ext[2]];
        (lo, hi)
    }

    /// Contiguous local site runs covering the interior box (one run per
    /// z-row in the worst case, one run total for a slab).
    pub fn interior_runs(&self) -> Vec<std::ops::Range<usize>> {
        let (lo, hi) = self.interior_box();
        box_runs(&self.local, lo, hi)
    }

    /// Copy this subdomain's interior box out of a global SoA field into
    /// `local` (halo sites untouched) — the grid analog of
    /// [`SubDomain::scatter_into`], called by each rank on its own
    /// thread so first-touch allocation lands where the sweeps run.
    pub fn scatter_into(&self, global: &[f64], ncomp: usize,
                        local: &mut [f64]) {
        let gn = self.global.nsites();
        let ln = self.local.nsites();
        debug_assert_eq!(global.len(), ncomp * gn);
        debug_assert_eq!(local.len(), ncomp * ln);
        for c in 0..ncomp {
            let gb = c * gn;
            let lb = c * ln;
            for x in 0..self.ext[0] {
                for y in 0..self.ext[1] {
                    let g0 = self.global.index(self.origin[0] + x,
                                               self.origin[1] + y,
                                               self.origin[2]);
                    let l0 = self.local.index(self.halo[0] + x,
                                              self.halo[1] + y,
                                              self.halo[2]);
                    local[lb + l0..lb + l0 + self.ext[2]].copy_from_slice(
                        &global[gb + g0..gb + g0 + self.ext[2]],
                    );
                }
            }
        }
    }

    /// Pack the interior box of a local SoA field as one payload (halos
    /// dropped): `ncomp * interior_sites()` doubles, component-major
    /// then x / y / z order — bytewise identical to
    /// [`SubDomain::interior_of`] on a slab grid, so `Gather` frames
    /// are transport- and grid-agnostic.
    pub fn interior_of(&self, local: &[f64], ncomp: usize) -> Vec<f64> {
        let ln = self.local.nsites();
        debug_assert_eq!(local.len(), ncomp * ln);
        let mut out = Vec::with_capacity(ncomp * self.interior_sites());
        for c in 0..ncomp {
            let lb = c * ln;
            for x in 0..self.ext[0] {
                for y in 0..self.ext[1] {
                    let l0 = self.local.index(self.halo[0] + x,
                                              self.halo[1] + y,
                                              self.halo[2]);
                    out.extend_from_slice(
                        &local[lb + l0..lb + l0 + self.ext[2]],
                    );
                }
            }
        }
        out
    }

    /// Place a packed interior payload (the [`CartSubDomain::interior_of`]
    /// layout) into a global SoA field at this subdomain's box — the
    /// receiving half of a comms `Gather`.
    pub fn place_interior(&self, interior: &[f64], ncomp: usize,
                          global: &mut [f64]) {
        let gn = global.len() / ncomp;
        let il = self.interior_sites();
        debug_assert_eq!(interior.len(), ncomp * il);
        debug_assert_eq!(global.len(), ncomp * gn);
        for c in 0..ncomp {
            let gb = c * gn;
            let mut src = c * il;
            for x in 0..self.ext[0] {
                for y in 0..self.ext[1] {
                    let g0 = self.global.index(self.origin[0] + x,
                                               self.origin[1] + y,
                                               self.origin[2]);
                    global[gb + g0..gb + g0 + self.ext[2]]
                        .copy_from_slice(&interior[src..src + self.ext[2]]);
                    src += self.ext[2];
                }
            }
        }
    }

    /// Copy the interior box of a local field back into a global SoA
    /// field — the inverse of [`CartSubDomain::scatter_into`].
    pub fn gather_from(&self, local: &[f64], ncomp: usize,
                       global: &mut [f64]) {
        self.place_interior(&self.interior_of(local, ncomp), ncomp, global);
    }
}

/// 3D Cartesian decomposition of a global periodic lattice over a
/// `(px, py, pz)` rank grid.
#[derive(Debug, Clone)]
pub struct CartDecomposition {
    pub global: Geometry,
    pub grid: [usize; 3],
    pub domains: Vec<CartSubDomain>,
}

impl CartDecomposition {
    /// Split `global` over the rank grid. Every axis is validated
    /// independently — the error names the axis that cannot be split.
    /// Uneven extents follow the slab rule per axis: the first
    /// `l mod p` domains get one extra plane.
    pub fn new(global: Geometry, grid: [usize; 3]) -> Result<Self> {
        let ext = [global.lx, global.ly, global.lz];
        for a in 0..3 {
            if grid[a] == 0 || ext[a] < grid[a] {
                return Err(Error::Invalid(format!(
                    "cannot split {axis}={l} into {p} domains along the \
                     {axis} axis",
                    axis = AXIS_NAMES[a],
                    l = ext[a],
                    p = grid[a]
                )));
            }
        }
        let slab = grid[1] == 1 && grid[2] == 1;
        let halo = if slab {
            [1, 0, 0]
        } else {
            [usize::from(grid[0] > 1), usize::from(grid[1] > 1),
             usize::from(grid[2] > 1)]
        };
        let split = |a: usize, c: usize| -> (usize, usize) {
            let (l, p) = (ext[a], grid[a]);
            let e = l / p + usize::from(c < l % p);
            let o = c * (l / p) + c.min(l % p);
            (o, e)
        };
        let mut domains = Vec::with_capacity(grid.iter().product());
        for cx in 0..grid[0] {
            for cy in 0..grid[1] {
                for cz in 0..grid[2] {
                    let coords = [cx, cy, cz];
                    let mut origin = [0; 3];
                    let mut dext = [0; 3];
                    for a in 0..3 {
                        let (o, e) = split(a, coords[a]);
                        origin[a] = o;
                        dext[a] = e;
                    }
                    let local = Geometry::new(dext[0] + 2 * halo[0],
                                              dext[1] + 2 * halo[1],
                                              dext[2] + 2 * halo[2]);
                    domains.push(CartSubDomain {
                        rank: CartSubDomain::rank_of(grid, coords),
                        coords,
                        origin,
                        ext: dext,
                        halo,
                        grid,
                        global,
                        local,
                    });
                }
            }
        }
        domains.sort_by_key(|d| d.rank);
        Ok(CartDecomposition { global, grid, domains })
    }

    /// True when this is the `(p, 1, 1)` slab special case.
    pub fn is_slab(&self) -> bool {
        self.grid[1] == 1 && self.grid[2] == 1
    }

    /// Surface-minimizing factorization of `ranks` into a `(px, py, pz)`
    /// grid with `p_a <= l_a` per axis: minimizes the estimated halo
    /// bytes per rank per step — for each decomposed axis, two faces
    /// whose area is the product of the *other* axes' local extents
    /// including their halo rows (face frames span the full halo-padded
    /// cross-section, see `comms::world`). Ties break toward fewer
    /// decomposed axes, then smaller `pz`, then smaller `py`, so a slab
    /// wins whenever it is no worse — keeping thin lattices on the
    /// contiguous (and super-step-capable) slab path.
    pub fn auto_grid(global: &Geometry, ranks: usize) -> [usize; 3] {
        let ext = [global.lx as f64, global.ly as f64, global.lz as f64];
        let lim = [global.lx, global.ly, global.lz];
        let mut best: Option<([usize; 3], (f64, usize, usize, usize))> =
            None;
        for px in 1..=ranks {
            if ranks % px != 0 || px > lim[0] {
                continue;
            }
            let rem = ranks / px;
            for py in 1..=rem {
                if rem % py != 0 || py > lim[1] {
                    continue;
                }
                let pz = rem / py;
                if pz > lim[2] {
                    continue;
                }
                let grid = [px, py, pz];
                let side = |a: usize| {
                    ext[a] / grid[a] as f64
                        + if grid[a] > 1 { 2.0 } else { 0.0 }
                };
                let mut cost = 0.0;
                for a in 0..3 {
                    if grid[a] > 1 {
                        let mut face = 2.0;
                        for b in 0..3 {
                            if b != a {
                                face *= side(b);
                            }
                        }
                        cost += face;
                    }
                }
                let naxes = grid.iter().filter(|&&p| p > 1).count();
                let key = (cost, naxes, pz, py);
                let better = match &best {
                    None => true,
                    Some((_, k)) => {
                        key.partial_cmp(k) == Some(std::cmp::Ordering::Less)
                    }
                };
                if better {
                    best = Some((grid, key));
                }
            }
        }
        best.map_or([ranks, 1, 1], |(g, _)| g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uneven_split_covers_lattice() {
        let geom = Geometry::new(10, 4, 4);
        let dec = SlabDecomposition::new(geom, 3).unwrap();
        let total: usize = dec.domains.iter().map(|d| d.lxl).sum();
        assert_eq!(total, 10);
        assert_eq!(dec.domains[0].lxl, 4); // 10 = 4 + 3 + 3
        assert_eq!(dec.domains[1].x0, 4);
        assert_eq!(dec.domains[2].x0, 7);
    }

    #[test]
    fn invalid_splits_rejected() {
        let geom = Geometry::new(4, 4, 4);
        assert!(SlabDecomposition::new(geom, 0).is_err());
        assert!(SlabDecomposition::new(geom, 5).is_err());
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let geom = Geometry::new(8, 3, 5);
        let dec = SlabDecomposition::new(geom, 3).unwrap();
        let field: Vec<f64> =
            (0..2 * geom.nsites()).map(|i| i as f64).collect();
        let locals = dec.scatter(&field, 2);
        assert_eq!(dec.gather(&locals, 2), field);
    }

    #[test]
    fn interior_roundtrip_matches_gather() {
        let geom = Geometry::new(9, 2, 3);
        let dec = SlabDecomposition::new(geom, 4).unwrap();
        let field: Vec<f64> =
            (0..2 * geom.nsites()).map(|i| i as f64 * 0.25).collect();
        let locals = dec.scatter(&field, 2);
        // interior_of drops the halo planes; place_interior lands each
        // payload exactly where gather_from would
        let mut global = vec![0.0; 2 * geom.nsites()];
        for (d, local) in dec.domains.iter().zip(&locals) {
            let interior = d.interior_of(local, 2);
            assert_eq!(interior.len(), 2 * d.lxl * d.plane());
            d.place_interior(&interior, 2, &mut global);
        }
        assert_eq!(global, field);
    }

    #[test]
    fn deep_halo_variants_agree_with_depth_one() {
        let geom = Geometry::new(12, 2, 3);
        let dec = SlabDecomposition::new(geom, 3).unwrap();
        let field: Vec<f64> =
            (0..2 * geom.nsites()).map(|i| i as f64 * 0.5).collect();
        for d in &dec.domains {
            for halo in [1usize, 2, 4] {
                let deep = d.local_with_halo(halo);
                assert_eq!(deep.lx, d.lxl + 2 * halo);
                let plane = d.plane();
                assert_eq!(d.interior_with_halo(halo),
                           halo * plane..(halo + d.lxl) * plane);
                let mut local = vec![0.0; 2 * deep.nsites()];
                d.scatter_into_with_halo(&field, 2, &mut local, halo);
                // same interior payload whatever the ghost depth
                let shallow = {
                    let mut l = vec![0.0; 2 * d.local.nsites()];
                    d.scatter_into(&field, 2, &mut l);
                    d.interior_of(&l, 2)
                };
                assert_eq!(d.interior_of_with_halo(&local, 2, halo),
                           shallow);
            }
        }
    }

    #[test]
    fn per_rank_scatter_matches_bulk_scatter() {
        let geom = Geometry::new(7, 2, 3);
        let dec = SlabDecomposition::new(geom, 2).unwrap();
        let field: Vec<f64> =
            (0..3 * geom.nsites()).map(|i| i as f64 * 0.5).collect();
        let bulk = dec.scatter(&field, 3);
        for (d, want) in dec.domains.iter().zip(&bulk) {
            let mut local = vec![0.0; 3 * d.local.nsites()];
            d.scatter_into(&field, 3, &mut local);
            assert_eq!(&local, want, "rank {}", d.rank);
            // and the interior range really is the middle planes
            let plane = d.plane();
            assert_eq!(d.interior(), plane..(d.lxl + 1) * plane);
        }
    }

    #[test]
    fn box_runs_collapse_by_layout() {
        let g = Geometry::new(4, 3, 5);
        // full y and z: one contiguous slab of x planes
        assert_eq!(box_runs(&g, [1, 0, 0], [3, 3, 5]), vec![15..45]);
        // full z only: one run per x plane
        let runs = box_runs(&g, [0, 1, 0], [2, 3, 5]);
        assert_eq!(runs, vec![5..15, 20..30]);
        // partial z: one run per (x, y) row
        let runs = box_runs(&g, [1, 1, 2], [3, 2, 4]);
        assert_eq!(runs,
                   vec![g.index(1, 1, 2)..g.index(1, 1, 4),
                        g.index(2, 1, 2)..g.index(2, 1, 4)]);
        // total coverage: runs of a box tile exactly its volume
        let total: usize = box_runs(&g, [0, 1, 1], [4, 3, 4])
            .iter()
            .map(|r| r.len())
            .sum();
        assert_eq!(total, 4 * 2 * 3);
        // empty boxes yield no runs
        assert!(box_runs(&g, [2, 0, 0], [2, 3, 5]).is_empty());
    }

    #[test]
    fn cart_slab_matches_slab_decomposition() {
        let geom = Geometry::new(10, 4, 3);
        let slab = SlabDecomposition::new(geom, 3).unwrap();
        let cart = CartDecomposition::new(geom, [3, 1, 1]).unwrap();
        assert!(cart.is_slab());
        let field: Vec<f64> =
            (0..2 * geom.nsites()).map(|i| i as f64 * 0.25).collect();
        for (s, c) in slab.domains.iter().zip(&cart.domains) {
            assert!(c.is_slab());
            let back = c.to_slab();
            assert_eq!((back.rank, back.x0, back.lxl), (s.rank, s.x0, s.lxl));
            assert_eq!(back.local, s.local);
            assert_eq!(c.halo, [1, 0, 0]);
            assert_eq!(c.interior_sites(), s.lxl * s.plane());
            // identical local images and identical packed payloads
            let mut sl = vec![0.0; 2 * s.local.nsites()];
            let mut cl = vec![0.0; 2 * c.local.nsites()];
            s.scatter_into(&field, 2, &mut sl);
            c.scatter_into(&field, 2, &mut cl);
            assert_eq!(sl, cl);
            assert_eq!(c.interior_of(&cl, 2), s.interior_of(&sl, 2));
            // slab interior is one contiguous run
            assert_eq!(c.interior_runs(), vec![s.interior()]);
        }
    }

    #[test]
    fn cart_grid_round_trips_uneven_boxes() {
        let geom = Geometry::new(7, 6, 5);
        let dec = CartDecomposition::new(geom, [2, 2, 2]).unwrap();
        assert_eq!(dec.domains.len(), 8);
        let covered: usize =
            dec.domains.iter().map(CartSubDomain::interior_sites).sum();
        assert_eq!(covered, geom.nsites());
        let field: Vec<f64> =
            (0..2 * geom.nsites()).map(|i| i as f64 * 0.5).collect();
        let mut rebuilt = vec![0.0; field.len()];
        for d in &dec.domains {
            // ranks are ordered by the canonical x-slowest map
            assert_eq!(d.rank, CartSubDomain::rank_of(d.grid, d.coords));
            assert_eq!(d.halo, [1, 1, 1]);
            let mut local = vec![0.0; 2 * d.local.nsites()];
            d.scatter_into(&field, 2, &mut local);
            let interior = d.interior_of(&local, 2);
            assert_eq!(interior.len(), 2 * d.interior_sites());
            d.place_interior(&interior, 2, &mut rebuilt);
        }
        assert_eq!(rebuilt, field);
    }

    #[test]
    fn cart_neighbors_wrap_periodically() {
        let dec =
            CartDecomposition::new(Geometry::new(4, 4, 4), [2, 2, 1])
                .unwrap();
        // r = (cx*2 + cy)*1 + cz: rank 0 = (0,0,0), rank 3 = (1,1,0)
        let d0 = &dec.domains[0];
        assert_eq!(d0.neighbor(0, true), 2);
        assert_eq!(d0.neighbor(0, false), 2); // px == 2 wraps to the same
        assert_eq!(d0.neighbor(1, true), 1);
        // y and z not decomposed for rank extents: z has pz == 1
        assert_eq!(d0.halo, [1, 1, 0]);
        assert_eq!(d0.local, Geometry::new(4, 4, 4));
        // face sites span the full halo-padded cross-section
        assert_eq!(d0.face_sites(0), 4 * 4);
        assert_eq!(d0.face_sites(1), 4 * 4);
    }

    #[test]
    fn cart_invalid_splits_name_the_axis() {
        let geom = Geometry::new(8, 2, 4);
        let err = CartDecomposition::new(geom, [1, 4, 1]).unwrap_err();
        assert!(err.to_string().contains("y axis"), "{err}");
        let err = CartDecomposition::new(geom, [1, 1, 0]).unwrap_err();
        assert!(err.to_string().contains("z axis"), "{err}");
        assert!(CartDecomposition::new(geom, [8, 2, 4]).is_ok());
    }

    #[test]
    fn auto_grid_minimizes_halo_surface() {
        // thin lattice: slab is strictly best
        assert_eq!(CartDecomposition::auto_grid(&Geometry::new(64, 8, 8), 4),
                   [4, 1, 1]);
        // cube at 8 ranks: a pencil beats both slab and block once the
        // +2 halo rows per transverse axis are charged
        assert_eq!(CartDecomposition::auto_grid(&Geometry::new(32, 32, 32),
                                                8),
                   [4, 2, 1]);
        // 2 ranks: always a slab (ties break toward fewer axes / low pz)
        assert_eq!(CartDecomposition::auto_grid(&Geometry::new(16, 16, 16),
                                                2),
                   [2, 1, 1]);
        // axis caps respected: lx = 2 is too thin to slab over 8 ranks,
        // and too thin to be worth decomposing at all — the cheapest
        // faces keep x whole and split the two big axes
        assert_eq!(CartDecomposition::auto_grid(&Geometry::new(2, 32, 32),
                                                8),
                   [1, 4, 2]);
    }
}
