//! Domain decomposition: the coarse-grained (MPI) level above targetDP.
//!
//! The paper's framework is explicitly designed to combine with node-level
//! parallelism ("targetDP may be used in conjunction with ... MPI"). This
//! module provides the slab decomposition Ludwig uses along the x axis:
//! each subdomain owns `lxl` interior planes plus one halo plane on each
//! side, and halo exchange moves interior boundary planes into the
//! neighbours' halos — in a real MPI run those are the messages; here the
//! "ranks" are in-process and the exchange is a bulk-synchronous copy,
//! which keeps the data flow identical and testable.
//!
//! With z fastest in memory, an x plane is a contiguous `ly * lz` block
//! per SoA component, so exchanges are pure slice copies (and the masked-
//! copy API of [`crate::targetdp::masked`] generalises them to arbitrary
//! subsets; see `halo::x_planes`).

use crate::error::{Error, Result};
use crate::free_energy::gradient::gradient_fd_range;
use crate::free_energy::symmetric::FeParams;
use crate::lattice::geometry::Geometry;
use crate::lb::collision::collide_lattice_range;
use crate::lb::model::VelSet;
use crate::lb::moments::phi_from_g;
use crate::lb::propagation::stream;
use crate::targetdp::tlp::TlpPool;

/// One slab subdomain: interior `lxl` planes + 2 halo planes.
#[derive(Debug, Clone)]
pub struct SubDomain {
    pub rank: usize,
    /// Global x of the first interior plane.
    pub x0: usize,
    /// Interior extent along x.
    pub lxl: usize,
    /// Local geometry *including* the two halo planes.
    pub local: Geometry,
}

impl SubDomain {
    /// Sites per x plane.
    pub fn plane(&self) -> usize {
        self.local.ly * self.local.lz
    }

    /// Local site range covering the interior (contiguous by layout).
    pub fn interior(&self) -> std::ops::Range<usize> {
        self.plane()..(self.lxl + 1) * self.plane()
    }
}

/// Slab decomposition of a global periodic lattice along x.
#[derive(Debug, Clone)]
pub struct SlabDecomposition {
    pub global: Geometry,
    pub domains: Vec<SubDomain>,
}

impl SlabDecomposition {
    pub fn new(global: Geometry, ndom: usize) -> Result<Self> {
        if ndom == 0 || global.lx < ndom {
            return Err(Error::Invalid(format!(
                "cannot split lx={} into {ndom} slabs", global.lx
            )));
        }
        let mut domains = Vec::with_capacity(ndom);
        let mut x0 = 0;
        for rank in 0..ndom {
            let lxl = global.lx / ndom + usize::from(rank < global.lx % ndom);
            domains.push(SubDomain {
                rank,
                x0,
                lxl,
                local: Geometry::new(lxl + 2, global.ly, global.lz),
            });
            x0 += lxl;
        }
        Ok(SlabDecomposition { global, domains })
    }

    /// Scatter a global SoA field into per-domain local fields (halos
    /// filled by a subsequent [`Self::exchange`]).
    pub fn scatter(&self, global: &[f64], ncomp: usize) -> Vec<Vec<f64>> {
        let gn = self.global.nsites();
        debug_assert_eq!(global.len(), ncomp * gn);
        self.domains
            .iter()
            .map(|d| {
                let ln = d.local.nsites();
                let plane = d.plane();
                let mut local = vec![0.0; ncomp * ln];
                for c in 0..ncomp {
                    let src = &global[c * gn + d.x0 * plane
                        ..c * gn + (d.x0 + d.lxl) * plane];
                    local[c * ln + plane..c * ln + (d.lxl + 1) * plane]
                        .copy_from_slice(src);
                }
                local
            })
            .collect()
    }

    /// Gather per-domain interiors back into a global SoA field.
    pub fn gather(&self, locals: &[Vec<f64>], ncomp: usize) -> Vec<f64> {
        let gn = self.global.nsites();
        let mut global = vec![0.0; ncomp * gn];
        for (d, local) in self.domains.iter().zip(locals) {
            let ln = d.local.nsites();
            let plane = d.plane();
            for c in 0..ncomp {
                let dst = &mut global[c * gn + d.x0 * plane
                    ..c * gn + (d.x0 + d.lxl) * plane];
                dst.copy_from_slice(
                    &local[c * ln + plane..c * ln + (d.lxl + 1) * plane],
                );
            }
        }
        global
    }

    /// Bulk-synchronous halo exchange of one field across all domains
    /// (periodic at the global x boundaries) — the MPI sendrecv analog.
    /// Convenience form that allocates staging per call; steady-state
    /// callers should hold an [`ExchangeStaging`] and use
    /// [`Self::exchange_with`] (4 exchanges per timestep otherwise churn
    /// two fresh `ndom * ncomp * plane` vectors each).
    pub fn exchange(&self, locals: &mut [Vec<f64>], ncomp: usize) {
        self.exchange_with(locals, ncomp,
                           &mut ExchangeStaging::new(self, ncomp));
    }

    /// Halo exchange through caller-owned staging buffers (no allocation).
    pub fn exchange_with(&self, locals: &mut [Vec<f64>], ncomp: usize,
                         staging: &mut ExchangeStaging) {
        let ndom = self.domains.len();
        let plane = self.global.ly * self.global.lz;
        let seg = ncomp * plane;
        assert_eq!(staging.lows.len(), ndom * seg,
                   "staging sized for another decomposition/field shape");
        // collect boundary planes first (so the copy is order-independent)
        for (i, (d, local)) in
            self.domains.iter().zip(locals.iter()).enumerate()
        {
            let ln = d.local.nsites();
            let low = &mut staging.lows[i * seg..(i + 1) * seg];
            let high = &mut staging.highs[i * seg..(i + 1) * seg];
            for c in 0..ncomp {
                low[c * plane..(c + 1) * plane].copy_from_slice(
                    &local[c * ln + plane..c * ln + 2 * plane],
                );
                high[c * plane..(c + 1) * plane].copy_from_slice(
                    &local[c * ln + d.lxl * plane
                        ..c * ln + (d.lxl + 1) * plane],
                );
            }
        }
        // deliver: my low halo <- left neighbour's high interior plane
        for (i, d) in self.domains.iter().enumerate() {
            let ln = d.local.nsites();
            let left = (i + ndom - 1) % ndom;
            let right = (i + 1) % ndom;
            let local = &mut locals[i];
            for c in 0..ncomp {
                local[c * ln..c * ln + plane].copy_from_slice(
                    &staging.highs
                        [left * seg + c * plane..left * seg + (c + 1) * plane],
                );
                local[c * ln + (d.lxl + 1) * plane
                    ..c * ln + (d.lxl + 2) * plane]
                    .copy_from_slice(
                        &staging.lows[right * seg + c * plane
                            ..right * seg + (c + 1) * plane],
                    );
            }
        }
    }
}

/// Reusable boundary-plane staging for [`SlabDecomposition::exchange_with`]
/// — one `ndom * ncomp * plane` buffer per direction, allocated once.
#[derive(Debug, Clone)]
pub struct ExchangeStaging {
    lows: Vec<f64>,
    highs: Vec<f64>,
}

impl ExchangeStaging {
    pub fn new(dec: &SlabDecomposition, ncomp: usize) -> Self {
        let plane = dec.global.ly * dec.global.lz;
        let len = dec.domains.len() * ncomp * plane;
        ExchangeStaging { lows: vec![0.0; len], highs: vec![0.0; len] }
    }
}

/// Persistent per-domain scratch for [`step_multidomain`]: moment fields,
/// streaming double buffers and exchange staging, allocated once per
/// decomposition instead of per step.
#[derive(Debug, Clone)]
pub struct MultiDomainScratch {
    phi: Vec<Vec<f64>>,
    grad: Vec<Vec<f64>>,
    lap: Vec<Vec<f64>>,
    streamed_f: Vec<Vec<f64>>,
    streamed_g: Vec<Vec<f64>>,
    staging: ExchangeStaging,
}

impl MultiDomainScratch {
    pub fn new(dec: &SlabDecomposition, nvel: usize) -> Self {
        let sized = |per: usize| -> Vec<Vec<f64>> {
            dec.domains
                .iter()
                .map(|d| vec![0.0; per * d.local.nsites()])
                .collect()
        };
        MultiDomainScratch {
            phi: sized(1),
            grad: sized(3),
            lap: sized(1),
            streamed_f: sized(nvel),
            streamed_g: sized(nvel),
            staging: ExchangeStaging::new(dec, nvel),
        }
    }
}

/// One full binary-fluid LB timestep over the decomposed lattice
/// (exchange -> moments/gradients -> collide -> exchange -> stream).
/// Matches the single-domain step exactly (see tests).
///
/// Gradients and collision run over the **interior** site range only: the
/// halo planes have garbage gradients (their x-stencil wraps inside the
/// local lattice) and their post-collision values were overwritten by the
/// next exchange anyway — colliding them was pure waste. phi still covers
/// the halo planes because the interior-boundary gradient stencil reads
/// them.
#[allow(clippy::too_many_arguments)]
pub fn step_multidomain(dec: &SlabDecomposition, vs: &VelSet, p: &FeParams,
                        f: &mut [Vec<f64>], g: &mut [Vec<f64>],
                        scratch: &mut MultiDomainScratch, pool: &TlpPool,
                        vvl: usize) {
    let nvel = vs.nvel;
    dec.exchange_with(f, nvel, &mut scratch.staging);
    dec.exchange_with(g, nvel, &mut scratch.staging);

    for (i, d) in dec.domains.iter().enumerate() {
        let ln = d.local.nsites();
        let interior = d.interior();
        phi_from_g(vs, &g[i], &mut scratch.phi[i], ln, pool, vvl);
        gradient_fd_range(&d.local, &scratch.phi[i], &mut scratch.grad[i],
                          &mut scratch.lap[i], interior.clone(), pool, vvl);
        collide_lattice_range(vs, p, &mut f[i], &mut g[i], &scratch.grad[i],
                              &scratch.lap[i], ln, interior, pool, vvl,
                              false);
    }

    dec.exchange_with(f, nvel, &mut scratch.staging);
    dec.exchange_with(g, nvel, &mut scratch.staging);

    for (i, d) in dec.domains.iter().enumerate() {
        stream(vs, &d.local, &f[i], &mut scratch.streamed_f[i], pool, vvl);
        stream(vs, &d.local, &g[i], &mut scratch.streamed_g[i], pool, vvl);
        f[i].copy_from_slice(&scratch.streamed_f[i]);
        g[i].copy_from_slice(&scratch.streamed_g[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::free_energy::gradient::gradient_fd;
    use crate::lb::collision::collide_lattice;
    use crate::lb::model::d3q19;

    fn global_state(geom: &Geometry, vs: &VelSet)
                    -> (Vec<f64>, Vec<f64>) {
        let n = geom.nsites();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        crate::lb::init::init_spinodal(vs, &FeParams::default(), geom,
                                       &mut f, &mut g, 0.05, 99);
        (f, g)
    }

    #[test]
    fn uneven_split_covers_lattice() {
        let geom = Geometry::new(10, 4, 4);
        let dec = SlabDecomposition::new(geom, 3).unwrap();
        let total: usize = dec.domains.iter().map(|d| d.lxl).sum();
        assert_eq!(total, 10);
        assert_eq!(dec.domains[0].lxl, 4); // 10 = 4 + 3 + 3
        assert_eq!(dec.domains[1].x0, 4);
        assert_eq!(dec.domains[2].x0, 7);
    }

    #[test]
    fn invalid_splits_rejected() {
        let geom = Geometry::new(4, 4, 4);
        assert!(SlabDecomposition::new(geom, 0).is_err());
        assert!(SlabDecomposition::new(geom, 5).is_err());
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let geom = Geometry::new(8, 3, 5);
        let dec = SlabDecomposition::new(geom, 3).unwrap();
        let field: Vec<f64> =
            (0..2 * geom.nsites()).map(|i| i as f64).collect();
        let locals = dec.scatter(&field, 2);
        assert_eq!(dec.gather(&locals, 2), field);
    }

    #[test]
    fn exchange_fills_halos_periodically() {
        let geom = Geometry::new(6, 2, 2);
        let dec = SlabDecomposition::new(geom, 2).unwrap();
        let n = geom.nsites();
        let field: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let mut locals = dec.scatter(&field, 1);
        dec.exchange(&mut locals, 1);
        // domain 0 low halo should hold global plane x = 5 (periodic)
        let d0 = &dec.domains[0];
        let plane = d0.plane();
        let want: Vec<f64> = (0..plane)
            .map(|k| field[5 * plane + k])
            .collect();
        assert_eq!(&locals[0][..plane], &want[..]);
        // domain 1 high halo holds global plane x = 0
        let d1 = &dec.domains[1];
        let ln = d1.local.nsites();
        let got = &locals[1][(d1.lxl + 1) * plane..ln];
        let want: Vec<f64> = (0..plane).map(|k| field[k]).collect();
        assert_eq!(got, &want[..]);
    }

    #[test]
    fn multidomain_step_matches_single_domain() {
        let vs = d3q19();
        let p = FeParams::default();
        let geom = Geometry::new(12, 4, 4);
        let (f_ref, g_ref) = global_state(&geom, vs);
        let pool = TlpPool::serial();

        // reference: single-domain step (phi -> grad -> collide -> stream)
        let n = geom.nsites();
        let mut f1 = f_ref.clone();
        let mut g1 = g_ref.clone();
        for _ in 0..3 {
            let mut phi = vec![0.0; n];
            let mut grad = vec![0.0; 3 * n];
            let mut lap = vec![0.0; n];
            phi_from_g(vs, &g1, &mut phi, n, &pool, 8);
            gradient_fd(&geom, &phi, &mut grad, &mut lap, &pool, 8);
            collide_lattice(vs, &p, &mut f1, &mut g1, &grad, &lap, n, &pool,
                            8, false);
            let mut fs = vec![0.0; vs.nvel * n];
            let mut gs = vec![0.0; vs.nvel * n];
            stream(vs, &geom, &f1, &mut fs, &pool, 8);
            stream(vs, &geom, &g1, &mut gs, &pool, 8);
            f1 = fs;
            g1 = gs;
        }

        // decomposed: 3 uneven slabs
        for ndom in [2, 3] {
            let dec = SlabDecomposition::new(geom, ndom).unwrap();
            let mut fl = dec.scatter(&f_ref, vs.nvel);
            let mut gl = dec.scatter(&g_ref, vs.nvel);
            let mut scratch = MultiDomainScratch::new(&dec, vs.nvel);
            for _ in 0..3 {
                step_multidomain(&dec, vs, &p, &mut fl, &mut gl,
                                 &mut scratch, &pool, 8);
            }
            let f2 = dec.gather(&fl, vs.nvel);
            let g2 = dec.gather(&gl, vs.nvel);
            for (a, b) in f1.iter().zip(&f2) {
                assert!((a - b).abs() < 1e-13, "ndom={ndom}");
            }
            for (a, b) in g1.iter().zip(&g2) {
                assert!((a - b).abs() < 1e-13, "ndom={ndom}");
            }
        }
    }

    #[test]
    fn exchange_with_reuses_staging_across_calls() {
        let geom = Geometry::new(6, 3, 2);
        let dec = SlabDecomposition::new(geom, 3).unwrap();
        let field: Vec<f64> =
            (0..2 * geom.nsites()).map(|i| i as f64 * 0.5).collect();
        // reference: allocating exchange
        let mut want = dec.scatter(&field, 2);
        dec.exchange(&mut want, 2);
        // staged exchange, run twice through the same buffers
        let mut got = dec.scatter(&field, 2);
        let mut staging = ExchangeStaging::new(&dec, 2);
        dec.exchange_with(&mut got, 2, &mut staging);
        dec.exchange_with(&mut got, 2, &mut staging);
        assert_eq!(got, want, "exchange is idempotent on filled halos");
    }
}
