//! Slab domain decomposition: the partitioning layer under the
//! rank-parallel [`crate::comms`] subsystem.
//!
//! The paper's framework is explicitly designed to combine with node-level
//! parallelism ("targetDP may be used in conjunction with ... MPI"). This
//! module owns the *geometry* of that level — the slab decomposition
//! Ludwig uses along the x axis: each subdomain holds `lxl` interior
//! planes plus one halo plane on each side. Everything that *moves* data
//! between subdomains (halo exchange, overlap with compute, transports)
//! lives in [`crate::comms`], which runs one concurrent rank per
//! subdomain; this module only answers "which global sites does rank r
//! own, and where do they sit in its local lattice".
//!
//! With z fastest in memory, an x plane is a contiguous `ly * lz` block
//! per SoA component, so scatters/gathers and halo-plane packing are pure
//! slice copies (see `halo::pack_x_plane`).

use crate::error::{Error, Result};
use crate::lattice::geometry::Geometry;

/// One slab subdomain: interior `lxl` planes + 2 halo planes.
#[derive(Debug, Clone)]
pub struct SubDomain {
    pub rank: usize,
    /// Global x of the first interior plane.
    pub x0: usize,
    /// Interior extent along x.
    pub lxl: usize,
    /// Local geometry *including* the two halo planes.
    pub local: Geometry,
}

impl SubDomain {
    /// Sites per x plane.
    pub fn plane(&self) -> usize {
        self.local.ly * self.local.lz
    }

    /// Local site range covering the interior (contiguous by layout).
    pub fn interior(&self) -> std::ops::Range<usize> {
        self.interior_with_halo(1)
    }

    /// Local geometry for a deep ghost region of `halo` planes per side —
    /// the lattice a communication-avoiding super-step runs on (`halo` =
    /// `HALO_PER_STEP * depth`). `halo = 1` is [`SubDomain::local`].
    pub fn local_with_halo(&self, halo: usize) -> Geometry {
        Geometry::new(self.lxl + 2 * halo, self.local.ly, self.local.lz)
    }

    /// Interior site range inside a `halo`-deep local lattice.
    pub fn interior_with_halo(&self, halo: usize) -> std::ops::Range<usize> {
        halo * self.plane()..(halo + self.lxl) * self.plane()
    }

    /// Copy this subdomain's interior planes out of a global SoA field
    /// into `local` (`ncomp * local.nsites()`; halo planes untouched).
    /// This is the per-rank half of [`SlabDecomposition::scatter`] — the
    /// comms ranks call it from their *own* threads so a freshly
    /// first-touch-allocated local field is filled where it will be swept.
    pub fn scatter_into(&self, global: &[f64], ncomp: usize,
                        local: &mut [f64]) {
        self.scatter_into_with_halo(global, ncomp, local, 1)
    }

    /// [`SubDomain::scatter_into`] for a `halo`-deep local lattice (the
    /// [`SubDomain::local_with_halo`] shape).
    pub fn scatter_into_with_halo(&self, global: &[f64], ncomp: usize,
                                  local: &mut [f64], halo: usize) {
        let ln = self.local_with_halo(halo).nsites();
        let gn = global.len() / ncomp;
        let plane = self.plane();
        debug_assert_eq!(global.len(), ncomp * gn);
        debug_assert_eq!(local.len(), ncomp * ln);
        debug_assert!((self.x0 + self.lxl) * plane <= gn);
        for c in 0..ncomp {
            let src = &global[c * gn + self.x0 * plane
                ..c * gn + (self.x0 + self.lxl) * plane];
            local[c * ln + halo * plane
                ..c * ln + (halo + self.lxl) * plane]
                .copy_from_slice(src);
        }
    }

    /// Copy out the interior planes of a local SoA field as one packed
    /// payload (halo planes dropped) — the body of a comms `Gather`
    /// response wire frame (`ncomp * lxl * plane` doubles,
    /// component-major).
    pub fn interior_of(&self, local: &[f64], ncomp: usize) -> Vec<f64> {
        self.interior_of_with_halo(local, ncomp, 1)
    }

    /// [`SubDomain::interior_of`] for a `halo`-deep local lattice.
    pub fn interior_of_with_halo(&self, local: &[f64], ncomp: usize,
                                 halo: usize) -> Vec<f64> {
        let ln = self.local_with_halo(halo).nsites();
        let plane = self.plane();
        debug_assert_eq!(local.len(), ncomp * ln);
        let mut out = Vec::with_capacity(ncomp * self.lxl * plane);
        for c in 0..ncomp {
            out.extend_from_slice(
                &local[c * ln + halo * plane
                    ..c * ln + (halo + self.lxl) * plane],
            );
        }
        out
    }

    /// Place a packed interior payload (the [`SubDomain::interior_of`]
    /// layout) into a global SoA field at this subdomain's x offset — the
    /// receiving half of a comms `Gather`.
    pub fn place_interior(&self, interior: &[f64], ncomp: usize,
                          global: &mut [f64]) {
        let plane = self.plane();
        let il = self.lxl * plane;
        let gn = global.len() / ncomp;
        debug_assert_eq!(interior.len(), ncomp * il);
        debug_assert_eq!(global.len(), ncomp * gn);
        debug_assert!((self.x0 + self.lxl) * plane <= gn);
        for c in 0..ncomp {
            let lo = c * gn + self.x0 * plane;
            global[lo..lo + il]
                .copy_from_slice(&interior[c * il..(c + 1) * il]);
        }
    }

    /// Copy this subdomain's interior planes back into a global SoA field
    /// — the inverse of [`SubDomain::scatter_into`].
    pub fn gather_from(&self, local: &[f64], ncomp: usize,
                       global: &mut [f64]) {
        let ln = self.local.nsites();
        let gn = global.len() / ncomp;
        let plane = self.plane();
        debug_assert_eq!(global.len(), ncomp * gn);
        debug_assert_eq!(local.len(), ncomp * ln);
        for c in 0..ncomp {
            let dst = &mut global[c * gn + self.x0 * plane
                ..c * gn + (self.x0 + self.lxl) * plane];
            dst.copy_from_slice(
                &local[c * ln + plane..c * ln + (self.lxl + 1) * plane],
            );
        }
    }
}

/// Slab decomposition of a global periodic lattice along x.
#[derive(Debug, Clone)]
pub struct SlabDecomposition {
    pub global: Geometry,
    pub domains: Vec<SubDomain>,
}

impl SlabDecomposition {
    pub fn new(global: Geometry, ndom: usize) -> Result<Self> {
        if ndom == 0 || global.lx < ndom {
            return Err(Error::Invalid(format!(
                "cannot split lx={} into {ndom} slabs", global.lx
            )));
        }
        let mut domains = Vec::with_capacity(ndom);
        let mut x0 = 0;
        for rank in 0..ndom {
            let lxl = global.lx / ndom + usize::from(rank < global.lx % ndom);
            domains.push(SubDomain {
                rank,
                x0,
                lxl,
                local: Geometry::new(lxl + 2, global.ly, global.lz),
            });
            x0 += lxl;
        }
        Ok(SlabDecomposition { global, domains })
    }

    /// Scatter a global SoA field into per-domain local fields (halos
    /// left zero; the first comms exchange fills them).
    pub fn scatter(&self, global: &[f64], ncomp: usize) -> Vec<Vec<f64>> {
        debug_assert_eq!(global.len(), ncomp * self.global.nsites());
        self.domains
            .iter()
            .map(|d| {
                let mut local = vec![0.0; ncomp * d.local.nsites()];
                d.scatter_into(global, ncomp, &mut local);
                local
            })
            .collect()
    }

    /// Gather per-domain interiors back into a global SoA field.
    pub fn gather(&self, locals: &[Vec<f64>], ncomp: usize) -> Vec<f64> {
        let mut global = vec![0.0; ncomp * self.global.nsites()];
        self.gather_into(locals, ncomp, &mut global);
        global
    }

    /// Gather into a caller-owned global buffer (no allocation).
    pub fn gather_into(&self, locals: &[Vec<f64>], ncomp: usize,
                       global: &mut [f64]) {
        for (d, local) in self.domains.iter().zip(locals) {
            d.gather_from(local, ncomp, global);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uneven_split_covers_lattice() {
        let geom = Geometry::new(10, 4, 4);
        let dec = SlabDecomposition::new(geom, 3).unwrap();
        let total: usize = dec.domains.iter().map(|d| d.lxl).sum();
        assert_eq!(total, 10);
        assert_eq!(dec.domains[0].lxl, 4); // 10 = 4 + 3 + 3
        assert_eq!(dec.domains[1].x0, 4);
        assert_eq!(dec.domains[2].x0, 7);
    }

    #[test]
    fn invalid_splits_rejected() {
        let geom = Geometry::new(4, 4, 4);
        assert!(SlabDecomposition::new(geom, 0).is_err());
        assert!(SlabDecomposition::new(geom, 5).is_err());
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let geom = Geometry::new(8, 3, 5);
        let dec = SlabDecomposition::new(geom, 3).unwrap();
        let field: Vec<f64> =
            (0..2 * geom.nsites()).map(|i| i as f64).collect();
        let locals = dec.scatter(&field, 2);
        assert_eq!(dec.gather(&locals, 2), field);
    }

    #[test]
    fn interior_roundtrip_matches_gather() {
        let geom = Geometry::new(9, 2, 3);
        let dec = SlabDecomposition::new(geom, 4).unwrap();
        let field: Vec<f64> =
            (0..2 * geom.nsites()).map(|i| i as f64 * 0.25).collect();
        let locals = dec.scatter(&field, 2);
        // interior_of drops the halo planes; place_interior lands each
        // payload exactly where gather_from would
        let mut global = vec![0.0; 2 * geom.nsites()];
        for (d, local) in dec.domains.iter().zip(&locals) {
            let interior = d.interior_of(local, 2);
            assert_eq!(interior.len(), 2 * d.lxl * d.plane());
            d.place_interior(&interior, 2, &mut global);
        }
        assert_eq!(global, field);
    }

    #[test]
    fn deep_halo_variants_agree_with_depth_one() {
        let geom = Geometry::new(12, 2, 3);
        let dec = SlabDecomposition::new(geom, 3).unwrap();
        let field: Vec<f64> =
            (0..2 * geom.nsites()).map(|i| i as f64 * 0.5).collect();
        for d in &dec.domains {
            for halo in [1usize, 2, 4] {
                let deep = d.local_with_halo(halo);
                assert_eq!(deep.lx, d.lxl + 2 * halo);
                let plane = d.plane();
                assert_eq!(d.interior_with_halo(halo),
                           halo * plane..(halo + d.lxl) * plane);
                let mut local = vec![0.0; 2 * deep.nsites()];
                d.scatter_into_with_halo(&field, 2, &mut local, halo);
                // same interior payload whatever the ghost depth
                let shallow = {
                    let mut l = vec![0.0; 2 * d.local.nsites()];
                    d.scatter_into(&field, 2, &mut l);
                    d.interior_of(&l, 2)
                };
                assert_eq!(d.interior_of_with_halo(&local, 2, halo),
                           shallow);
            }
        }
    }

    #[test]
    fn per_rank_scatter_matches_bulk_scatter() {
        let geom = Geometry::new(7, 2, 3);
        let dec = SlabDecomposition::new(geom, 2).unwrap();
        let field: Vec<f64> =
            (0..3 * geom.nsites()).map(|i| i as f64 * 0.5).collect();
        let bulk = dec.scatter(&field, 3);
        for (d, want) in dec.domains.iter().zip(&bulk) {
            let mut local = vec![0.0; 3 * d.local.nsites()];
            d.scatter_into(&field, 3, &mut local);
            assert_eq!(&local, want, "rank {}", d.rank);
            // and the interior range really is the middle planes
            let plane = d.plane();
            assert_eq!(d.interior(), plane..(d.lxl + 1) * plane);
        }
    }
}
