//! Structured-grid substrate: geometry, SoA lattice fields, halo masks,
//! domain decomposition and output.

pub mod decomp;
pub mod field;
pub mod geometry;
pub mod halo;
pub mod io;

pub use field::HostField;
pub use geometry::Geometry;
