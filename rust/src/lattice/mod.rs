//! Structured-grid substrate: geometry, SoA lattice fields, halo masks,
//! precomputed streaming tables, domain decomposition and output.

pub mod decomp;
pub mod field;
pub mod geometry;
pub mod halo;
pub mod io;
pub mod stream_table;

pub use field::HostField;
pub use geometry::Geometry;
pub use stream_table::StreamTable;
