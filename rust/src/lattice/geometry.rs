//! Cartesian lattice geometry and periodic index arithmetic.
//!
//! Site order matches the AOT artifacts: a `(Lx, Ly, Lz)` grid flattened in
//! C order — `site = (x * Ly + y) * Lz + z` (z fastest). Consecutive `z`
//! (and wrapped `y`, `x`) sites are therefore memory-consecutive, which is
//! what the SoA layout vectorises over.

/// A periodic Cartesian lattice. 2-D models use `lz == 1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Geometry {
    pub lx: usize,
    pub ly: usize,
    pub lz: usize,
}

impl Geometry {
    pub fn new(lx: usize, ly: usize, lz: usize) -> Self {
        assert!(lx > 0 && ly > 0 && lz > 0, "lattice extents must be positive");
        Geometry { lx, ly, lz }
    }

    /// Total number of sites.
    pub fn nsites(&self) -> usize {
        self.lx * self.ly * self.lz
    }

    /// Flattened index of `(x, y, z)`; caller guarantees in-range coords.
    #[inline(always)]
    pub fn index(&self, x: usize, y: usize, z: usize) -> usize {
        (x * self.ly + y) * self.lz + z
    }

    /// Inverse of [`Self::index`].
    #[inline(always)]
    pub fn coords(&self, site: usize) -> (usize, usize, usize) {
        let z = site % self.lz;
        let y = (site / self.lz) % self.ly;
        let x = site / (self.ly * self.lz);
        (x, y, z)
    }

    /// Periodic wrap of a possibly out-of-range signed coordinate.
    #[inline(always)]
    pub fn wrap(coord: i64, extent: usize) -> usize {
        let e = extent as i64;
        (((coord % e) + e) % e) as usize
    }

    /// Flattened-index delta of a lattice vector, ignoring periodic wrap:
    /// `index(x+c) - index(x)` whenever no coordinate wraps. This is what
    /// makes interior streaming a contiguous copy at constant offset
    /// ([`crate::lattice::stream_table::StreamTable`]).
    #[inline(always)]
    pub fn linear_offset(&self, c: [i64; 3]) -> i64 {
        (c[0] * self.ly as i64 + c[1]) * self.lz as i64 + c[2]
    }

    /// Site index of the periodic neighbour at offset `(dx, dy, dz)`.
    #[inline(always)]
    pub fn neighbor(&self, x: usize, y: usize, z: usize,
                    dx: i64, dy: i64, dz: i64) -> usize {
        let nx = Self::wrap(x as i64 + dx, self.lx);
        let ny = Self::wrap(y as i64 + dy, self.ly);
        let nz = Self::wrap(z as i64 + dz, self.lz);
        self.index(nx, ny, nz)
    }

    /// Iterate all `(x, y, z, site)` in flattened order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, usize, usize)> + '_ {
        (0..self.nsites()).map(move |s| {
            let (x, y, z) = self.coords(s);
            (x, y, z, s)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_coords_roundtrip() {
        let g = Geometry::new(3, 4, 5);
        for s in 0..g.nsites() {
            let (x, y, z) = g.coords(s);
            assert_eq!(g.index(x, y, z), s);
        }
    }

    #[test]
    fn z_is_fastest() {
        let g = Geometry::new(2, 2, 4);
        assert_eq!(g.index(0, 0, 1), 1);
        assert_eq!(g.index(0, 1, 0), 4);
        assert_eq!(g.index(1, 0, 0), 8);
    }

    #[test]
    fn wrap_is_periodic() {
        assert_eq!(Geometry::wrap(-1, 8), 7);
        assert_eq!(Geometry::wrap(8, 8), 0);
        assert_eq!(Geometry::wrap(-9, 8), 7);
        assert_eq!(Geometry::wrap(3, 8), 3);
    }

    #[test]
    fn neighbor_wraps_all_axes() {
        let g = Geometry::new(4, 4, 4);
        assert_eq!(g.neighbor(0, 0, 0, -1, 0, 0), g.index(3, 0, 0));
        assert_eq!(g.neighbor(3, 3, 3, 1, 1, 1), g.index(0, 0, 0));
        assert_eq!(g.neighbor(1, 2, 3, 0, 0, 1), g.index(1, 2, 0));
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_extent_rejected() {
        Geometry::new(0, 4, 4);
    }
}
