//! Precomputed streaming tables: no index arithmetic in the hot loop.
//!
//! LB propagation moves each population along its lattice vector. On a
//! periodic grid flattened in C order the destination (push) or source
//! (pull) of almost every site is at a *constant* linear offset
//! ([`Geometry::linear_offset`]); only sites on the faces the vector
//! crosses wrap around. The naive loop therefore spends its time in
//! `coords`/`wrap` div-mod arithmetic to handle a minority of sites.
//!
//! [`StreamTable`] precomputes, per velocity,
//!
//! * the constant interior offset, and
//! * a sorted **exception list** of the boundary sites whose periodic
//!   image breaks the linear rule (`O(surface)` entries, built once per
//!   `(velocity set, geometry)` and cached process-wide),
//!
//! so the hot loop degenerates into `memcpy`-able interior runs plus a
//! short patch-up pass — used by both the standalone `Stream` kernel
//! (pull) and the fused host `FullStep` collide→push-stream path.

use std::collections::HashMap;
use std::ops::Range;
use std::sync::{Arc, Mutex, OnceLock};

use crate::lattice::geometry::Geometry;
use crate::lb::model::VelSet;

/// Upper bound on the number of tables the process-wide cache retains.
/// Sweeps over many geometries (benchmarks, uneven slab widths, the
/// MultiStep slab planner) would otherwise pin one table per geometry
/// forever.
const CACHE_CAP: usize = 16;

type CacheKey = (&'static str, usize, Geometry);

struct Cache {
    /// Monotone access counter for LRU ordering.
    tick: u64,
    map: HashMap<CacheKey, (Arc<StreamTable>, u64)>,
}

static CACHE: OnceLock<Mutex<Cache>> = OnceLock::new();

/// Number of tables currently retained by the process-wide cache
/// (diagnostics; bounded by `CACHE_CAP`).
pub fn cached_table_count() -> usize {
    CACHE.get().map_or(0, |m| m.lock().unwrap().map.len())
}

/// One boundary-site exception: at `site` the linear-offset rule fails and
/// the periodic partner is `other` (the pull *source* or push
/// *destination*).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hop {
    pub site: u32,
    pub other: u32,
}

/// Streaming map for one velocity.
#[derive(Debug)]
pub struct VelStream {
    /// Linear index delta of the lattice vector: interior push goes to
    /// `s + offset`, interior pull comes from `s - offset`.
    pub offset: i64,
    /// Sites (sorted) whose pull source wraps.
    pub pull: Vec<Hop>,
    /// Sites (sorted) whose push destination wraps.
    pub push: Vec<Hop>,
}

/// Per-velocity streaming maps for one `(velocity set, geometry)` pair.
#[derive(Debug)]
pub struct StreamTable {
    pub nsites: usize,
    pub vels: Vec<VelStream>,
}

impl StreamTable {
    /// Build the table by checking every site's periodic neighbour against
    /// the linear rule — definitionally correct, O(nsites * nvel), done
    /// once (prefer [`StreamTable::cached`]).
    pub fn new(vs: &VelSet, geom: &Geometry) -> Self {
        let n = geom.nsites();
        assert!(n <= u32::MAX as usize, "lattice too large for u32 sites");
        let mut vels = Vec::with_capacity(vs.nvel);
        for i in 0..vs.nvel {
            let c = vs.ci[i];
            let offset = geom.linear_offset(c);
            let mut pull = Vec::new();
            let mut push = Vec::new();
            for (x, y, z, s) in geom.iter() {
                let from = geom.neighbor(x, y, z, -c[0], -c[1], -c[2]);
                if from as i64 != s as i64 - offset {
                    pull.push(Hop { site: s as u32, other: from as u32 });
                }
                let to = geom.neighbor(x, y, z, c[0], c[1], c[2]);
                if to as i64 != s as i64 + offset {
                    push.push(Hop { site: s as u32, other: to as u32 });
                }
            }
            vels.push(VelStream { offset, pull, push });
        }
        StreamTable { nsites: n, vels }
    }

    /// Process-wide table cache keyed by `(velocity set, geometry)` — the
    /// paper's "build launch geometry once, reuse every step" amortisation.
    ///
    /// The cache is **bounded** at `CACHE_CAP` entries: on overflow the
    /// least-recently-used table no longer referenced outside the cache
    /// (`Arc` strong count 1) is dropped first, falling back to the LRU
    /// entry outright — callers holding an `Arc` keep their table alive
    /// either way, but a sweep over distinct geometries can no longer grow
    /// the map without bound.
    ///
    /// Velocity sets are identified by `(name, nvel)`: the in-tree sets
    /// are singletons, so this is exact; a hand-built [`VelSet`] aliasing
    /// a stock name is caught by the debug offset check below.
    pub fn cached(vs: &VelSet, geom: &Geometry) -> Arc<StreamTable> {
        let cache = CACHE.get_or_init(|| {
            Mutex::new(Cache { tick: 0, map: HashMap::new() })
        });
        let key = (vs.name, vs.nvel, *geom);
        let mut c = cache.lock().unwrap();
        c.tick += 1;
        let now = c.tick;
        if let Some((table, used)) = c.map.get_mut(&key) {
            *used = now;
            let table = table.clone();
            debug_assert!(
                (0..vs.nvel).all(|i| {
                    table.vels[i].offset == geom.linear_offset(vs.ci[i])
                }),
                "cached StreamTable does not match this velocity set \
                 (two distinct VelSets share the name {:?})",
                vs.name
            );
            return table;
        }
        if c.map.len() >= CACHE_CAP {
            let victim = c
                .map
                .iter()
                .filter(|(_, (t, _))| Arc::strong_count(t) == 1)
                .min_by_key(|(_, (_, used))| *used)
                .map(|(k, _)| *k)
                .or_else(|| {
                    c.map
                        .iter()
                        .min_by_key(|(_, (_, used))| *used)
                        .map(|(k, _)| *k)
                });
            if let Some(v) = victim {
                c.map.remove(&v);
            }
        }
        let table = Arc::new(StreamTable::new(vs, geom));
        c.map.insert(key, (table.clone(), now));
        table
    }

    /// Pull source of `site` for velocity `i` (boundary-aware).
    #[inline]
    pub fn pull_from(&self, i: usize, site: usize) -> usize {
        let v = &self.vels[i];
        match v.pull.binary_search_by_key(&(site as u32), |h| h.site) {
            Ok(k) => v.pull[k].other as usize,
            Err(_) => (site as i64 - v.offset) as usize,
        }
    }

    /// Push destination of `site` for velocity `i` (boundary-aware).
    #[inline]
    pub fn push_to(&self, i: usize, site: usize) -> usize {
        let v = &self.vels[i];
        match v.push.binary_search_by_key(&(site as u32), |h| h.site) {
            Ok(k) => v.push[k].other as usize,
            Err(_) => (site as i64 + v.offset) as usize,
        }
    }

    /// Sorted slice of hops in `hops` whose `site` lies in `sites`.
    fn hops_in(hops: &[Hop], sites: &Range<usize>) -> &[Hop] {
        let lo =
            hops.partition_point(|h| (h.site as usize) < sites.start);
        let hi = lo
            + hops[lo..].partition_point(|h| (h.site as usize) < sites.end);
        &hops[lo..hi]
    }

    /// Pull exceptions of velocity `i` whose site lies in `sites` — the
    /// slab-ranged boundary query (empty slice ⇔ the range pulls purely at
    /// the constant interior offset).
    pub fn pull_hops(&self, i: usize, sites: Range<usize>) -> &[Hop] {
        Self::hops_in(&self.vels[i].pull, &sites)
    }

    /// Push exceptions of velocity `i` whose site lies in `sites`.
    pub fn push_hops(&self, i: usize, sites: Range<usize>) -> &[Hop] {
        Self::hops_in(&self.vels[i].push, &sites)
    }

    /// True when every pull *source* of the destination range `sites` for
    /// velocity `i` lies inside `bounds` — the safety predicate for
    /// streaming a destination sub-range before the halo planes outside
    /// `bounds` have arrived (the comms overlap asserts exactly this for
    /// its interior split). O(|sites| log) — intended for debug checks.
    pub fn pull_sources_within(&self, i: usize, sites: Range<usize>,
                               bounds: &Range<usize>) -> bool {
        sites.into_iter().all(|s| bounds.contains(&self.pull_from(i, s)))
    }

    /// Pull-stream the chunk of sites `[base, base + dst_chunk.len())` of
    /// one SoA velocity row: `dst_chunk[k] = src_row[pull_from(i, base+k)]`.
    /// Interior runs between exceptions are contiguous `copy_from_slice`s.
    /// The destination is exactly the chunk's own slice, so parallel
    /// chunks hold genuinely disjoint `&mut` borrows.
    pub fn pull_chunk(&self, i: usize, src_row: &[f64],
                      dst_chunk: &mut [f64], base: usize) {
        let v = &self.vels[i];
        let end = base + dst_chunk.len();
        let mut cur = base;
        for h in self.pull_hops(i, base..end) {
            let s = h.site as usize;
            if s > cur {
                let src0 = (cur as i64 - v.offset) as usize;
                dst_chunk[cur - base..s - base]
                    .copy_from_slice(&src_row[src0..src0 + (s - cur)]);
            }
            dst_chunk[s - base] = src_row[h.other as usize];
            cur = s + 1;
        }
        if end > cur {
            let src0 = (cur as i64 - v.offset) as usize;
            dst_chunk[cur - base..]
                .copy_from_slice(&src_row[src0..src0 + (end - cur)]);
        }
    }

    /// Push-stream the post-collision values of sites `[base, base + len)`
    /// (`vals[k]` belongs to site `base + k`) into one SoA velocity row:
    /// `dst_row[push_to(i, s)] = vals[s - base]`.
    pub fn push_row(&self, i: usize, dst_row: &mut [f64], base: usize,
                    len: usize, vals: &[f64]) {
        debug_assert!(vals.len() >= len);
        let v = &self.vels[i];
        let end = base + len;
        let mut cur = base;
        for h in self.push_hops(i, base..end) {
            let s = h.site as usize;
            if s > cur {
                let d0 = (cur as i64 + v.offset) as usize;
                dst_row[d0..d0 + (s - cur)]
                    .copy_from_slice(&vals[cur - base..s - base]);
            }
            dst_row[h.other as usize] = vals[s - base];
            cur = s + 1;
        }
        if end > cur {
            let d0 = (cur as i64 + v.offset) as usize;
            dst_row[d0..d0 + (end - cur)]
                .copy_from_slice(&vals[cur - base..end - base]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lb::model::{d2q9, d3q19};

    #[test]
    fn maps_match_geometry_neighbor() {
        for (vs, geom) in [(d3q19(), Geometry::new(4, 3, 2)),
                           (d2q9(), Geometry::new(5, 4, 1))] {
            let table = StreamTable::new(vs, &geom);
            for i in 0..vs.nvel {
                let c = vs.ci[i];
                for (x, y, z, s) in geom.iter() {
                    let from = geom.neighbor(x, y, z, -c[0], -c[1], -c[2]);
                    let to = geom.neighbor(x, y, z, c[0], c[1], c[2]);
                    assert_eq!(table.pull_from(i, s), from,
                               "{} i={i} s={s} pull", vs.name);
                    assert_eq!(table.push_to(i, s), to,
                               "{} i={i} s={s} push", vs.name);
                }
            }
        }
    }

    #[test]
    fn rest_velocity_has_no_exceptions() {
        let geom = Geometry::new(4, 4, 4);
        let table = StreamTable::new(d3q19(), &geom);
        assert_eq!(table.vels[0].offset, 0);
        assert!(table.vels[0].pull.is_empty());
        assert!(table.vels[0].push.is_empty());
        // face velocities wrap exactly one face worth of sites
        let face = geom.nsites() / 4;
        assert_eq!(table.vels[1].pull.len(), face);
        assert_eq!(table.vels[1].push.len(), face);
    }

    #[test]
    fn exceptions_are_sorted_by_site() {
        let table = StreamTable::new(d3q19(), &Geometry::new(3, 4, 5));
        for v in &table.vels {
            assert!(v.pull.windows(2).all(|w| w[0].site < w[1].site));
            assert!(v.push.windows(2).all(|w| w[0].site < w[1].site));
        }
    }

    #[test]
    fn pull_chunk_matches_per_site_pull() {
        let vs = d3q19();
        let geom = Geometry::new(4, 3, 5);
        let n = geom.nsites();
        let table = StreamTable::new(vs, &geom);
        let src: Vec<f64> = (0..n).map(|k| k as f64 * 0.25 + 1.0).collect();
        for i in 0..vs.nvel {
            // whole row and an interior sub-range with odd alignment
            for (base, len) in [(0, n), (3, n - 7)] {
                let mut dst = vec![-1.0; len];
                table.pull_chunk(i, &src, &mut dst, base);
                for (k, d) in dst.iter().enumerate() {
                    let s = base + k;
                    assert_eq!(*d, src[table.pull_from(i, s)],
                               "i={i} s={s}");
                }
            }
        }
    }

    #[test]
    fn push_row_is_inverse_of_pull_chunk() {
        let vs = d2q9();
        let geom = Geometry::new(6, 5, 1);
        let n = geom.nsites();
        let table = StreamTable::new(vs, &geom);
        let src: Vec<f64> = (0..n).map(|k| (k * k) as f64).collect();
        for i in 0..vs.nvel {
            // push the whole row in two unaligned chunks
            let mut pushed = vec![0.0; n];
            let split = 13;
            table.push_row(i, &mut pushed, 0, split, &src[..split]);
            table.push_row(i, &mut pushed, split, n - split, &src[split..]);
            // pulling the pushed row recovers the original
            let mut back = vec![0.0; n];
            table.pull_chunk(i, &pushed, &mut back, 0);
            assert_eq!(back, src, "i={i}");
        }
    }

    #[test]
    fn cached_tables_are_shared() {
        let geom = Geometry::new(7, 2, 3);
        let a = StreamTable::cached(d3q19(), &geom);
        let b = StreamTable::cached(d3q19(), &geom);
        assert!(Arc::ptr_eq(&a, &b));
        let c = StreamTable::cached(d2q9(), &Geometry::new(7, 2, 1));
        assert_eq!(c.vels.len(), 9);
    }

    #[test]
    fn cache_is_bounded() {
        // sweeping many distinct geometries must not pin a table each —
        // the regression the LRU bound exists for
        for lx in 2..40 {
            let _ = StreamTable::cached(d2q9(), &Geometry::new(lx, 3, 1));
        }
        assert!(cached_table_count() <= CACHE_CAP,
                "cache grew to {} tables", cached_table_count());
        // a held Arc survives eviction of its cache entry
        let keep = StreamTable::cached(d2q9(), &Geometry::new(41, 3, 1));
        for lx in 2..40 {
            let _ = StreamTable::cached(d2q9(), &Geometry::new(lx, 5, 1));
        }
        assert_eq!(keep.nsites, 41 * 3);
        assert!(cached_table_count() <= CACHE_CAP);
    }

    #[test]
    fn ranged_hop_queries_match_bruteforce() {
        let vs = d3q19();
        let geom = Geometry::new(5, 4, 3);
        let n = geom.nsites();
        let table = StreamTable::new(vs, &geom);
        for i in 0..vs.nvel {
            for range in [0..n, 7..n - 5, 13..13, n / 2..n] {
                let want_pull: Vec<Hop> = table.vels[i]
                    .pull
                    .iter()
                    .copied()
                    .filter(|h| range.contains(&(h.site as usize)))
                    .collect();
                assert_eq!(table.pull_hops(i, range.clone()), &want_pull[..],
                           "i={i} pull {range:?}");
                let want_push: Vec<Hop> = table.vels[i]
                    .push
                    .iter()
                    .copied()
                    .filter(|h| range.contains(&(h.site as usize)))
                    .collect();
                assert_eq!(table.push_hops(i, range.clone()), &want_push[..],
                           "i={i} push {range:?}");
            }
        }
    }

    #[test]
    fn pull_sources_within_splits_interior_from_boundary() {
        // the comms overlap invariant: destinations excluding one plane on
        // each side of a slab pull only from inside the slab, while the
        // edge planes need the (halo) planes beyond it
        let vs = d3q19();
        let geom = Geometry::new(6, 3, 4); // a 4-plane "slab" + 2 halos
        let plane = geom.ly * geom.lz;
        let n = geom.nsites();
        let table = StreamTable::new(vs, &geom);
        let interior = plane..(geom.lx - 1) * plane;
        let deep = 2 * plane..(geom.lx - 2) * plane;
        for i in 0..vs.nvel {
            assert!(table.pull_sources_within(i, deep.clone(), &interior),
                    "i={i}: deep destinations must not read the halos");
            let c = vs.ci[i];
            if c[0] != 0 {
                // x-moving velocities at the edge planes reach outside
                assert!(!table.pull_sources_within(i, interior.clone(),
                                                   &interior),
                        "i={i}");
            }
            assert!(table.pull_sources_within(i, 0..n, &(0..n)));
        }
    }

    #[test]
    fn interior_slab_ranges_have_no_x_face_hops() {
        // the MultiStep blocked sweep collides ranges that exclude the
        // first and last x planes, so x-moving velocities see no wrap there
        let vs = d3q19();
        let geom = Geometry::new(8, 3, 4);
        let plane = geom.ly * geom.lz;
        let table = StreamTable::new(vs, &geom);
        let interior = plane..(geom.lx - 1) * plane;
        for i in 0..vs.nvel {
            let c = vs.ci[i];
            if c[1] == 0 && c[2] == 0 {
                // pure-x velocities wrap only at the faces
                assert!(table.push_hops(i, interior.clone()).is_empty(),
                        "i={i}");
                assert!(table.pull_hops(i, interior.clone()).is_empty(),
                        "i={i}");
            }
        }
    }
}
