//! Halo masks: boolean site-subsets for the paper's masked copies.
//!
//! The masked transfer API (section III-B) exists because halo exchange
//! between MPI subdomains only needs the boundary shell of the lattice —
//! these helpers build the standard masks, and `benches/masked_copy.rs`
//! (E4) measures full vs masked transfer exactly as the paper motivates.

use crate::lattice::geometry::Geometry;

/// Mask selecting all sites within `depth` of any domain face.
pub fn boundary_shell(geom: &Geometry, depth: usize) -> Vec<bool> {
    let mut mask = vec![false; geom.nsites()];
    for (x, y, z, s) in geom.iter() {
        let near = |c: usize, l: usize| c < depth || c + depth >= l;
        // axes with extent 1 (2-D lattices) have no halo in that direction
        let hit = (geom.lx > 1 && near(x, geom.lx))
            || (geom.ly > 1 && near(y, geom.ly))
            || (geom.lz > 1 && near(z, geom.lz));
        if hit {
            mask[s] = true;
        }
    }
    mask
}

/// Mask selecting the `depth` planes at the low (`low = true`) or high end
/// of the x axis — the slab-decomposition exchange mask.
pub fn x_planes(geom: &Geometry, depth: usize, low: bool) -> Vec<bool> {
    let mut mask = vec![false; geom.nsites()];
    for (x, _, _, s) in geom.iter() {
        let hit = if low { x < depth } else { x + depth >= geom.lx };
        if hit {
            mask[s] = true;
        }
    }
    mask
}

/// Fraction of sites selected by a mask.
pub fn fill_fraction(mask: &[bool]) -> f64 {
    mask.iter().filter(|&&m| m).count() as f64 / mask.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shell_depth1_counts() {
        let geom = Geometry::new(4, 4, 4);
        let mask = boundary_shell(&geom, 1);
        // interior is 2^3 = 8, so shell = 64 - 8
        assert_eq!(mask.iter().filter(|&&m| m).count(), 56);
    }

    #[test]
    fn shell_2d_ignores_z() {
        let geom = Geometry::new(4, 4, 1);
        let mask = boundary_shell(&geom, 1);
        assert_eq!(mask.iter().filter(|&&m| m).count(), 12);
    }

    #[test]
    fn x_planes_select_slabs() {
        let geom = Geometry::new(4, 2, 2);
        let low = x_planes(&geom, 1, true);
        let high = x_planes(&geom, 1, false);
        for (x, _, _, s) in geom.iter() {
            assert_eq!(low[s], x == 0);
            assert_eq!(high[s], x == 3);
        }
    }

    #[test]
    fn fill_fraction_sane() {
        let geom = Geometry::new(8, 8, 8);
        let f = fill_fraction(&boundary_shell(&geom, 1));
        assert!((f - (512.0 - 216.0) / 512.0).abs() < 1e-12);
    }
}
