//! Halo masks and halo-exchange pack/unpack helpers.
//!
//! The masked transfer API (section III-B) exists because halo exchange
//! between MPI subdomains only needs the boundary shell of the lattice —
//! these helpers build the standard masks, and `benches/masked_copy.rs`
//! (E4) measures full vs masked transfer exactly as the paper motivates.
//! The pack/unpack half serializes boundary planes into contiguous
//! message payloads for [`crate::comms`]: contiguous x planes
//! ([`pack_x_plane`], depth-k blocks via [`pack_x_planes`]) and the
//! strided y/z faces of the 3D Cartesian grid ([`pack_face`]).

use crate::lattice::geometry::Geometry;

/// Mask selecting all sites within `depth` of any domain face.
pub fn boundary_shell(geom: &Geometry, depth: usize) -> Vec<bool> {
    let mut mask = vec![false; geom.nsites()];
    for (x, y, z, s) in geom.iter() {
        let near = |c: usize, l: usize| c < depth || c + depth >= l;
        // axes with extent 1 (2-D lattices) have no halo in that direction
        let hit = (geom.lx > 1 && near(x, geom.lx))
            || (geom.ly > 1 && near(y, geom.ly))
            || (geom.lz > 1 && near(z, geom.lz));
        if hit {
            mask[s] = true;
        }
    }
    mask
}

/// Mask selecting the `depth` planes at the low (`low = true`) or high end
/// of the x axis — the slab-decomposition exchange mask.
pub fn x_planes(geom: &Geometry, depth: usize, low: bool) -> Vec<bool> {
    let mut mask = vec![false; geom.nsites()];
    for (x, _, _, s) in geom.iter() {
        let hit = if low { x < depth } else { x + depth >= geom.lx };
        if hit {
            mask[s] = true;
        }
    }
    mask
}

/// Pack x-plane `p` of an SoA field (`ncomp * nsites`, z fastest so a
/// plane is `plane_sites` contiguous values per component) into a
/// contiguous `ncomp * plane_sites` buffer — the halo-exchange message
/// payload (the send-buffer packing an MPI code does before `MPI_Isend`).
pub fn pack_x_plane(field: &[f64], ncomp: usize, nsites: usize,
                    plane_sites: usize, p: usize, out: &mut [f64]) {
    debug_assert_eq!(field.len(), ncomp * nsites);
    debug_assert_eq!(out.len(), ncomp * plane_sites);
    debug_assert!((p + 1) * plane_sites <= nsites);
    for c in 0..ncomp {
        let src = c * nsites + p * plane_sites;
        out[c * plane_sites..(c + 1) * plane_sites]
            .copy_from_slice(&field[src..src + plane_sites]);
    }
}

/// Inverse of [`pack_x_plane`]: scatter a received plane payload into
/// x-plane `p` of the SoA field (the recv-buffer unpacking after
/// `MPI_Wait`).
pub fn unpack_x_plane(field: &mut [f64], ncomp: usize, nsites: usize,
                      plane_sites: usize, p: usize, payload: &[f64]) {
    debug_assert_eq!(field.len(), ncomp * nsites);
    debug_assert_eq!(payload.len(), ncomp * plane_sites);
    debug_assert!((p + 1) * plane_sites <= nsites);
    for c in 0..ncomp {
        let dst = c * nsites + p * plane_sites;
        field[dst..dst + plane_sites]
            .copy_from_slice(&payload[c * plane_sites..(c + 1) * plane_sites]);
    }
}

/// Pack `np` consecutive x-planes `p0..p0 + np` into a contiguous
/// `ncomp * np * plane_sites` buffer, component-major with the planes
/// contiguous per component — the depth-tagged ghost-block payload of a
/// communication-avoiding super-step (one message instead of `np`).
/// Because the planes are consecutive and z is fastest, each component is
/// a single `np * plane_sites` slice copy.
pub fn pack_x_planes(field: &[f64], ncomp: usize, nsites: usize,
                     plane_sites: usize, p0: usize, np: usize,
                     out: &mut [f64]) {
    debug_assert_eq!(field.len(), ncomp * nsites);
    debug_assert_eq!(out.len(), ncomp * np * plane_sites);
    debug_assert!((p0 + np) * plane_sites <= nsites);
    let block = np * plane_sites;
    for c in 0..ncomp {
        let src = c * nsites + p0 * plane_sites;
        out[c * block..(c + 1) * block]
            .copy_from_slice(&field[src..src + block]);
    }
}

/// Inverse of [`pack_x_planes`]: scatter a received ghost-block payload
/// into x-planes `p0..p0 + np` of the SoA field.
pub fn unpack_x_planes(field: &mut [f64], ncomp: usize, nsites: usize,
                       plane_sites: usize, p0: usize, np: usize,
                       payload: &[f64]) {
    debug_assert_eq!(field.len(), ncomp * nsites);
    debug_assert_eq!(payload.len(), ncomp * np * plane_sites);
    debug_assert!((p0 + np) * plane_sites <= nsites);
    let block = np * plane_sites;
    for c in 0..ncomp {
        let dst = c * nsites + p0 * plane_sites;
        field[dst..dst + block]
            .copy_from_slice(&payload[c * block..(c + 1) * block]);
    }
}

/// Sites in one face plane of `axis`: the product of the other two
/// extents — the payload site count of [`pack_face`] / [`unpack_face`].
pub fn face_sites(geom: &Geometry, axis: usize) -> usize {
    match axis {
        0 => geom.ly * geom.lz,
        1 => geom.lx * geom.lz,
        _ => geom.lx * geom.ly,
    }
}

/// Pack face plane `p` of `axis` (coordinate along that axis) of an SoA
/// field into a contiguous `ncomp * face_sites` buffer — the 3D-grid
/// generalization of [`pack_x_plane`]. Layout: component-major, then the
/// remaining axes in x / y / z order (so axis 0 is bytewise identical to
/// [`pack_x_plane`]). With z fastest, an x face is one contiguous slice
/// per component, a y face is `lx` runs of `lz`, and a z face gathers
/// `lx * ly` strided singletons.
pub fn pack_face(field: &[f64], ncomp: usize, geom: &Geometry,
                 axis: usize, p: usize, out: &mut [f64]) {
    let n = geom.nsites();
    let fsites = face_sites(geom, axis);
    debug_assert_eq!(field.len(), ncomp * n);
    debug_assert_eq!(out.len(), ncomp * fsites);
    match axis {
        0 => pack_x_plane(field, ncomp, n, fsites, p, out),
        1 => {
            debug_assert!(p < geom.ly);
            for c in 0..ncomp {
                for x in 0..geom.lx {
                    let src = c * n + geom.index(x, p, 0);
                    let dst = c * fsites + x * geom.lz;
                    out[dst..dst + geom.lz]
                        .copy_from_slice(&field[src..src + geom.lz]);
                }
            }
        }
        _ => {
            debug_assert!(p < geom.lz);
            for c in 0..ncomp {
                for x in 0..geom.lx {
                    for y in 0..geom.ly {
                        out[c * fsites + x * geom.ly + y] =
                            field[c * n + geom.index(x, y, p)];
                    }
                }
            }
        }
    }
}

/// Inverse of [`pack_face`]: scatter a received face payload into face
/// plane `p` of `axis`.
pub fn unpack_face(field: &mut [f64], ncomp: usize, geom: &Geometry,
                   axis: usize, p: usize, payload: &[f64]) {
    let n = geom.nsites();
    let fsites = face_sites(geom, axis);
    debug_assert_eq!(field.len(), ncomp * n);
    debug_assert_eq!(payload.len(), ncomp * fsites);
    match axis {
        0 => unpack_x_plane(field, ncomp, n, fsites, p, payload),
        1 => {
            debug_assert!(p < geom.ly);
            for c in 0..ncomp {
                for x in 0..geom.lx {
                    let dst = c * n + geom.index(x, p, 0);
                    let src = c * fsites + x * geom.lz;
                    field[dst..dst + geom.lz]
                        .copy_from_slice(&payload[src..src + geom.lz]);
                }
            }
        }
        _ => {
            debug_assert!(p < geom.lz);
            for c in 0..ncomp {
                for x in 0..geom.lx {
                    for y in 0..geom.ly {
                        field[c * n + geom.index(x, y, p)] =
                            payload[c * fsites + x * geom.ly + y];
                    }
                }
            }
        }
    }
}

/// Fraction of sites selected by a mask.
pub fn fill_fraction(mask: &[bool]) -> f64 {
    mask.iter().filter(|&&m| m).count() as f64 / mask.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shell_depth1_counts() {
        let geom = Geometry::new(4, 4, 4);
        let mask = boundary_shell(&geom, 1);
        // interior is 2^3 = 8, so shell = 64 - 8
        assert_eq!(mask.iter().filter(|&&m| m).count(), 56);
    }

    #[test]
    fn shell_2d_ignores_z() {
        let geom = Geometry::new(4, 4, 1);
        let mask = boundary_shell(&geom, 1);
        assert_eq!(mask.iter().filter(|&&m| m).count(), 12);
    }

    #[test]
    fn x_planes_select_slabs() {
        let geom = Geometry::new(4, 2, 2);
        let low = x_planes(&geom, 1, true);
        let high = x_planes(&geom, 1, false);
        for (x, _, _, s) in geom.iter() {
            assert_eq!(low[s], x == 0);
            assert_eq!(high[s], x == 3);
        }
    }

    #[test]
    fn plane_pack_unpack_roundtrip() {
        let geom = Geometry::new(5, 3, 2);
        let (ncomp, n, plane) = (2usize, geom.nsites(), geom.ly * geom.lz);
        let field: Vec<f64> = (0..ncomp * n).map(|i| i as f64).collect();
        for p in [0, 2, 4] {
            let mut buf = vec![0.0; ncomp * plane];
            pack_x_plane(&field, ncomp, n, plane, p, &mut buf);
            // component c of site s in plane p came from the right spot
            for c in 0..ncomp {
                for k in 0..plane {
                    assert_eq!(buf[c * plane + k],
                               field[c * n + p * plane + k]);
                }
            }
            let mut back = vec![-1.0; ncomp * n];
            unpack_x_plane(&mut back, ncomp, n, plane, p, &buf);
            for c in 0..ncomp {
                for k in 0..plane {
                    assert_eq!(back[c * n + p * plane + k],
                               field[c * n + p * plane + k]);
                }
            }
        }
    }

    #[test]
    fn plane_block_pack_unpack_roundtrip() {
        let geom = Geometry::new(7, 3, 2);
        let (ncomp, n, plane) = (2usize, geom.nsites(), geom.ly * geom.lz);
        let field: Vec<f64> = (0..ncomp * n).map(|i| i as f64).collect();
        for (p0, np) in [(0, 2), (2, 3), (3, 4), (5, 1)] {
            let mut buf = vec![0.0; ncomp * np * plane];
            pack_x_planes(&field, ncomp, n, plane, p0, np, &mut buf);
            // the block agrees plane-by-plane with pack_x_plane
            for j in 0..np {
                let mut one = vec![0.0; ncomp * plane];
                pack_x_plane(&field, ncomp, n, plane, p0 + j, &mut one);
                for c in 0..ncomp {
                    assert_eq!(
                        &buf[c * np * plane + j * plane
                            ..c * np * plane + (j + 1) * plane],
                        &one[c * plane..(c + 1) * plane]
                    );
                }
            }
            let mut back = vec![-1.0; ncomp * n];
            unpack_x_planes(&mut back, ncomp, n, plane, p0, np, &buf);
            for c in 0..ncomp {
                let lo = c * n + p0 * plane;
                assert_eq!(&back[lo..lo + np * plane],
                           &field[lo..lo + np * plane]);
            }
        }
    }

    #[test]
    fn face_pack_matches_hand_gather_and_round_trips() {
        let geom = Geometry::new(4, 3, 5);
        let (ncomp, n) = (2usize, geom.nsites());
        let field: Vec<f64> =
            (0..ncomp * n).map(|i| i as f64 * 0.5).collect();
        for axis in 0..3 {
            let ext = [geom.lx, geom.ly, geom.lz][axis];
            let fsites = face_sites(&geom, axis);
            for p in [0, 1, ext - 1] {
                let mut buf = vec![0.0; ncomp * fsites];
                pack_face(&field, ncomp, &geom, axis, p, &mut buf);
                // every face value came from a site with coordinate p on
                // `axis`, in x/y/z traversal order of the other axes
                let mut k = vec![0usize; ncomp];
                for (x, y, z, s) in geom.iter() {
                    if [x, y, z][axis] != p {
                        continue;
                    }
                    for (c, kc) in k.iter_mut().enumerate() {
                        assert_eq!(buf[c * fsites + *kc],
                                   field[c * n + s],
                                   "axis {axis} p {p} c {c}");
                        *kc += 1;
                    }
                }
                // scatter back into a clean field: exactly the face
                // plane lands, everything else untouched
                let mut back = vec![-1.0; ncomp * n];
                unpack_face(&mut back, ncomp, &geom, axis, p, &buf);
                for (x, y, z, s) in geom.iter() {
                    for c in 0..ncomp {
                        let want = if [x, y, z][axis] == p {
                            field[c * n + s]
                        } else {
                            -1.0
                        };
                        assert_eq!(back[c * n + s], want);
                    }
                }
            }
        }
    }

    #[test]
    fn face_axis0_is_bytewise_pack_x_plane() {
        let geom = Geometry::new(5, 3, 2);
        let (ncomp, n, plane) = (3usize, geom.nsites(), geom.ly * geom.lz);
        let field: Vec<f64> = (0..ncomp * n).map(|i| i as f64).collect();
        for p in 0..geom.lx {
            let mut a = vec![0.0; ncomp * plane];
            let mut b = vec![0.0; ncomp * plane];
            pack_face(&field, ncomp, &geom, 0, p, &mut a);
            pack_x_plane(&field, ncomp, n, plane, p, &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn fill_fraction_sane() {
        let geom = Geometry::new(8, 8, 8);
        let f = fill_fraction(&boundary_shell(&geom, 1));
        assert!((f - (512.0 - 216.0) / 512.0).abs() < 1e-12);
    }
}
