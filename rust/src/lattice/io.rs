//! Output: legacy-VTK structured points (for visualisation) and CSV time
//! series (for the benchmark/experiment harnesses).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::error::Result;
use crate::lattice::geometry::Geometry;

/// Write a scalar field as a legacy VTK STRUCTURED_POINTS file.
pub fn write_vtk_scalar(path: &Path, geom: &Geometry, name: &str,
                        field: &[f64]) -> Result<()> {
    assert_eq!(field.len(), geom.nsites());
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "# vtk DataFile Version 3.0")?;
    writeln!(w, "targetdp field {name}")?;
    writeln!(w, "ASCII")?;
    writeln!(w, "DATASET STRUCTURED_POINTS")?;
    writeln!(w, "DIMENSIONS {} {} {}", geom.lx, geom.ly, geom.lz)?;
    writeln!(w, "ORIGIN 0 0 0")?;
    writeln!(w, "SPACING 1 1 1")?;
    writeln!(w, "POINT_DATA {}", geom.nsites())?;
    writeln!(w, "SCALARS {name} double 1")?;
    writeln!(w, "LOOKUP_TABLE default")?;
    // VTK expects x fastest; our layout has z fastest, so emit transposed
    for z in 0..geom.lz {
        for y in 0..geom.ly {
            for x in 0..geom.lx {
                writeln!(w, "{}", field[geom.index(x, y, z)])?;
            }
        }
    }
    Ok(())
}

/// Incremental CSV writer for time series.
pub struct CsvWriter {
    w: BufWriter<File>,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w })
    }

    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        let line: Vec<String> = values.iter().map(|v| v.to_string()).collect();
        writeln!(self.w, "{}", line.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vtk_roundtrip_header() {
        let dir = std::env::temp_dir().join("targetdp_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("phi.vtk");
        let geom = Geometry::new(2, 2, 2);
        let field: Vec<f64> = (0..8).map(|i| i as f64).collect();
        write_vtk_scalar(&path, &geom, "phi", &field).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("DIMENSIONS 2 2 2"));
        assert!(text.contains("SCALARS phi double 1"));
        // first emitted value is site (0,0,0), then x fastest: (1,0,0)
        let tail: Vec<&str> = text.lines().rev().take(8).collect();
        assert_eq!(tail.len(), 8);
    }

    #[test]
    fn csv_writes_rows() {
        let dir = std::env::temp_dir().join("targetdp_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("series.csv");
        let mut csv = CsvWriter::create(&path, &["t", "mass"]).unwrap();
        csv.row(&[0.0, 1.0]).unwrap();
        csv.row(&[1.0, 1.0]).unwrap();
        csv.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("t,mass"));
    }
}
