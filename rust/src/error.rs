//! Library-wide error type.

use std::fmt;

// keep the From impl below building against whatever stands in for the
// PJRT bindings (see runtime/pjrt_stub.rs)
use crate::runtime::pjrt_stub as xla;

/// Errors produced by the targetDP library.
#[derive(Debug)]
pub enum Error {
    /// Invalid argument / state (shape mismatch, unknown kernel, ...).
    Invalid(String),
    /// A kernel was launched on a target that does not implement it.
    UnsupportedKernel { target: String, kernel: String },
    /// Buffer handle not found in the target's pool.
    BadBuffer(usize),
    /// I/O failure (artifact files, VTK output, ...).
    Io(std::io::Error),
    /// Failure inside the XLA/PJRT runtime.
    Xla(String),
    /// Manifest / config parse failure.
    Parse(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Invalid(m) => write!(f, "invalid: {m}"),
            Error::UnsupportedKernel { target, kernel } => {
                write!(f, "target {target} does not implement kernel {kernel}")
            }
            Error::BadBuffer(id) => write!(f, "unknown buffer handle {id}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Xla(m) => write!(f, "xla: {m}"),
            Error::Parse(m) => write!(f, "parse: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
