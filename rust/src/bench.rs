//! Minimal benchmarking harness (offline replacement for criterion).
//!
//! Each `cargo bench` target is a plain `main()` (harness = false) that
//! builds a [`Bench`] and reports mean / std / throughput per case,
//! printing both a human table and machine-readable `BENCH-CSV` lines the
//! experiment scripts grep for.

use std::time::Instant;

use crate::coordinator::metrics::mean_std;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub name: String,
    /// Mean seconds per iteration.
    pub mean: f64,
    pub std: f64,
    /// Lattice-site updates per iteration (for MLUPS), if applicable.
    pub sites_per_iter: Option<f64>,
}

impl CaseResult {
    pub fn mlups(&self) -> Option<f64> {
        self.sites_per_iter.map(|s| s / self.mean / 1e6)
    }
}

/// Fixed-iteration benchmark runner.
pub struct Bench {
    pub title: String,
    pub warmup_iters: u32,
    pub iters: u32,
    results: Vec<CaseResult>,
}

impl Bench {
    pub fn new(title: &str) -> Self {
        // honour a quick mode for CI-ish runs
        let quick = std::env::var("TARGETDP_BENCH_QUICK").is_ok();
        Bench {
            title: title.to_string(),
            warmup_iters: if quick { 1 } else { 3 },
            iters: if quick { 3 } else { 10 },
            results: Vec::new(),
        }
    }

    pub fn with_iters(mut self, warmup: u32, iters: u32) -> Self {
        self.warmup_iters = warmup;
        self.iters = iters.max(1);
        self
    }

    /// Time `f` (which performs one full iteration of work).
    pub fn case(&mut self, name: &str, sites_per_iter: Option<f64>,
                mut f: impl FnMut()) -> &CaseResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let (mean, std) = mean_std(&samples);
        self.results.push(CaseResult {
            name: name.to_string(),
            mean,
            std,
            sites_per_iter,
        });
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }

    /// Mean seconds of a named case (for ratio reporting).
    pub fn mean_of(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|r| r.name == name).map(|r| r.mean)
    }

    /// Print the human table + BENCH-CSV lines.
    pub fn report(&self) {
        println!("\n== {} ==", self.title);
        println!("{:<44} {:>12} {:>10} {:>10}", "case", "mean", "std",
                 "MLUPS");
        for r in &self.results {
            println!(
                "{:<44} {:>12} {:>10} {:>10}",
                r.name,
                format_secs(r.mean),
                format_secs(r.std),
                r.mlups().map(|m| format!("{m:.2}")).unwrap_or_default()
            );
        }
        for r in &self.results {
            println!(
                "BENCH-CSV,{},{},{:.9},{:.9},{}",
                self.title,
                r.name,
                r.mean,
                r.std,
                r.mlups().map(|m| format!("{m:.3}")).unwrap_or_default()
            );
        }
    }
}

fn format_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_records_and_reports() {
        let mut b = Bench::new("t").with_iters(1, 3);
        let mut count = 0;
        b.case("noop", Some(1e6), || count += 1);
        assert_eq!(count, 4); // 1 warmup + 3 iters
        let r = &b.results()[0];
        assert!(r.mean >= 0.0);
        assert!(r.mlups().unwrap() > 0.0);
        assert_eq!(b.mean_of("noop"), Some(r.mean));
        assert!(b.mean_of("absent").is_none());
    }

    #[test]
    fn format_is_scaled() {
        assert!(format_secs(2.0).ends_with(" s"));
        assert!(format_secs(2e-3).ends_with(" ms"));
        assert!(format_secs(2e-6).ends_with(" us"));
    }
}
