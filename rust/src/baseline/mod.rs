//! The "original" comparator: the pre-targetDP Ludwig code structure.
//!
//! The paper's Figure-1 baseline is the existing CPU code, augmented with
//! OpenMP for fairness: **AoS** storage (`f[site][vel]`), innermost loops
//! over the discrete momenta (extent 19) or spatial dimensions (extent 3),
//! and the compiler left to find ILP — extents that "do not map perfectly
//! onto the AVX vector length of 4", leaving vector units under-utilised.
//!
//! This module reproduces that structure faithfully so the E1/E3 benches
//! can measure exactly the contrast the paper reports. Physics is
//! identical to [`crate::lb::collision`] (pinned by tests).

use crate::free_energy::symmetric::FeParams;
use crate::lb::model::{VelSet, CS2, SYM6};
use crate::targetdp::tlp::TlpPool;

/// AoS binary collision over sites `[0, nsites)`:
/// `f[s * nvel + i]`, `grad[s * 3 + d]`, `lap[s]`.
///
/// The TLP decomposition (OpenMP analog) strides in single sites; all
/// innermost loops have model extents (nvel, 3, 6), exactly the structure
/// the paper's original code had.
#[allow(clippy::too_many_arguments)]
pub fn collide_aos(vs: &VelSet, p: &FeParams, f: &mut [f64], g: &mut [f64],
                   grad: &[f64], lap: &[f64], nsites: usize,
                   pool: &TlpPool) {
    let nvel = vs.nvel;
    debug_assert_eq!(f.len(), nvel * nsites);
    debug_assert_eq!(grad.len(), 3 * nsites);

    let f_ptr = SendMut(f.as_mut_ptr(), f.len());
    let g_ptr = SendMut(g.as_mut_ptr(), g.len());

    pool.for_chunks(nsites, 1, |s, _len| {
        // rebind the wrappers so the closure captures the Send+Sync structs
        // (edition-2021 disjoint capture would otherwise grab the raw field)
        let (f_ptr, g_ptr) = (f_ptr, g_ptr);
        let f = unsafe { std::slice::from_raw_parts_mut(f_ptr.0, f_ptr.1) };
        let g = unsafe { std::slice::from_raw_parts_mut(g_ptr.0, g_ptr.1) };
        let fs = &mut f[s * nvel..(s + 1) * nvel];
        let gs = &mut g[s * nvel..(s + 1) * nvel];
        let gd = [grad[s * 3], grad[s * 3 + 1], grad[s * 3 + 2]];
        let lp = lap[s];

        // moments: innermost loop over the 19 momenta
        let mut rho = 0.0;
        let mut phi = 0.0;
        let mut ru = [0.0f64; 3];
        for i in 0..nvel {
            rho += fs[i];
            phi += gs[i];
            // inner loop of extent 3 over spatial dimensions
            for a in 0..3 {
                ru[a] += vs.cv[i][a] * fs[i];
            }
        }
        let mut u = [0.0f64; 3];
        for a in 0..3 {
            u[a] = ru[a] / rho;
        }

        let mu = p.chemical_potential(phi, lp);
        let iso_f = p.pth_iso(rho, phi, gd, lp) - rho * CS2;
        let iso_g = p.gamma * mu - phi * CS2;

        let mut s_f = [0.0f64; 6];
        let mut s_g = [0.0f64; 6];
        for (k, (a, b)) in SYM6.iter().enumerate() {
            s_f[k] = rho * u[*a] * u[*b] + p.kappa * gd[*a] * gd[*b];
            s_g[k] = phi * u[*a] * u[*b];
            if a == b {
                s_f[k] += iso_f;
                s_g[k] += iso_g;
            }
        }

        for i in 0..nvel {
            let mut cb_f = 0.0;
            let mut cb_g = 0.0;
            for a in 0..3 {
                cb_f += vs.cv[i][a] * ru[a];
                cb_g += vs.cv[i][a] * phi * u[a];
            }
            let mut qs_f = 0.0;
            let mut qs_g = 0.0;
            for k in 0..6 {
                qs_f += vs.q6[i][k] * s_f[k];
                qs_g += vs.q6[i][k] * s_g[k];
            }
            let feq = vs.wv[i] * (rho + 3.0 * cb_f + 4.5 * qs_f);
            let geq = vs.wv[i] * (phi + 3.0 * cb_g + 4.5 * qs_g);
            fs[i] -= (fs[i] - feq) / p.tau_f;
            gs[i] -= (gs[i] - geq) / p.tau_g;
        }
    });
}

#[derive(Clone, Copy)]
struct SendMut(*mut f64, usize);
unsafe impl Send for SendMut {}
unsafe impl Sync for SendMut {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::field::{aos_to_soa, soa_to_aos};
    use crate::lb::collision::collide_lattice;
    use crate::lb::model::{d2q9, d3q19};

    #[test]
    fn aos_matches_targetdp_physics() {
        for vs in [d3q19(), d2q9()] {
            let nsites = 120;
            let p = FeParams::default();

            // build an SoA state, run the targetDP kernel
            let mut f_soa = vec![0.0; vs.nvel * nsites];
            let mut g_soa = vec![0.0; vs.nvel * nsites];
            let mut grad_soa = vec![0.0; 3 * nsites];
            let mut lap = vec![0.0; nsites];
            let mut seed = 12345u64;
            let mut next = move || {
                seed ^= seed >> 12;
                seed ^= seed << 25;
                seed ^= seed >> 27;
                (seed.wrapping_mul(0x2545F4914F6CDD1D) >> 11) as f64
                    / (1u64 << 53) as f64
                    - 0.5
            };
            for i in 0..vs.nvel {
                for s in 0..nsites {
                    f_soa[i * nsites + s] = vs.wv[i] * (1.0 + 0.1 * next());
                    g_soa[i * nsites + s] = vs.wv[i] * 0.1 * next();
                }
            }
            for d in 0..vs.ndim {
                for s in 0..nsites {
                    grad_soa[d * nsites + s] = 0.02 * next();
                }
            }
            for l in lap.iter_mut() {
                *l = 0.02 * next();
            }

            // AoS copies
            let mut f_aos = soa_to_aos(&f_soa, vs.nvel, nsites);
            let mut g_aos = soa_to_aos(&g_soa, vs.nvel, nsites);
            let grad_aos = soa_to_aos(&grad_soa, 3, nsites);

            collide_lattice(vs, &p, &mut f_soa, &mut g_soa, &grad_soa, &lap,
                            nsites, &TlpPool::serial(), 8, false);
            collide_aos(vs, &p, &mut f_aos, &mut g_aos, &grad_aos, &lap,
                        nsites, &TlpPool::serial());

            let f_back = aos_to_soa(&f_aos, vs.nvel, nsites);
            let g_back = aos_to_soa(&g_aos, vs.nvel, nsites);
            for (a, b) in f_back.iter().zip(&f_soa) {
                assert!((a - b).abs() < 1e-14, "{}: f", vs.name);
            }
            for (a, b) in g_back.iter().zip(&g_soa) {
                assert!((a - b).abs() < 1e-14, "{}: g", vs.name);
            }
        }
    }
}
