//! Pluggable rank-to-rank transports.
//!
//! A [`Transport`] is one rank's endpoint into the communication fabric —
//! the role MPI's BTL/PML stack plays under `MPI_Isend`/`MPI_Recv`. The
//! contract is deliberately minimal and byte-oriented: addressed,
//! non-blocking sends of encoded [`PlaneMsg`] frames, and a blocking
//! receive of the next frame addressed to this rank. Ordering is only
//! guaranteed *per sender pair* (like MPI's non-overtaking rule); message
//! matching by [`crate::comms::wire::Tag`] happens one layer up in
//! [`crate::comms::world::Rank`].
//!
//! [`ChannelTransport`] is the in-process implementation: every rank runs
//! on its own OS thread and frames travel through `std::sync::mpsc`
//! channels (the shared-memory BTL analog). It still moves *encoded
//! bytes*, not structs, so every run exercises the exact frames a socket
//! transport would put on a TCP stream — dropping in a remote transport
//! is implementing this trait over a socket pair (ROADMAP follow-up).

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use crate::comms::wire::{PlaneMsg, Tag};
use crate::error::{Error, Result};

/// One rank's endpoint into the communication fabric.
pub trait Transport: Send {
    /// This endpoint's rank id.
    fn rank(&self) -> usize;
    /// Number of ranks in the world (`MPI_Comm_size`).
    fn nranks(&self) -> usize;
    /// Non-blocking addressed send (`MPI_Isend`): encode one tagged plane
    /// for `dst` and return immediately — the frame is built straight
    /// from the borrowed payload, no owned message needs to exist on the
    /// sender side. Self-sends (`dst == rank()`) are legal — a 1-rank
    /// world talks to itself across the periodic seam.
    fn send_plane(&mut self, dst: usize, src: u32, tag: Tag, data: &[f64])
                  -> Result<()>;
    /// Send an owned [`PlaneMsg`] (convenience over
    /// [`Transport::send_plane`]).
    fn send(&mut self, dst: usize, msg: &PlaneMsg) -> Result<()> {
        self.send_plane(dst, msg.src, msg.tag, &msg.data)
    }
    /// Blocking receive of the next frame addressed to this rank, in
    /// per-sender arrival order.
    fn recv(&mut self) -> Result<PlaneMsg>;
    /// Like [`Transport::recv`] but gives up after `timeout`, returning
    /// `Ok(None)` — the hook [`crate::comms::world::Rank::wait`] uses to
    /// turn a lost neighbour into an error instead of a hung world.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<PlaneMsg>>;
}

/// In-process transport: one mpsc inbox per rank, frames as encoded bytes.
pub struct ChannelTransport {
    rank: usize,
    nranks: usize,
    /// Senders to every rank. For `nranks > 1` the slot for *this* rank
    /// is `None`: the slab ring never self-sends then, and holding our
    /// own `Sender` would keep our inbox "connected" even after every
    /// real peer died — dropping it makes a dead 2-rank world surface as
    /// `Disconnected` immediately instead of waiting out a full recv
    /// timeout.
    peers: Vec<Option<Sender<Vec<u8>>>>,
    inbox: Receiver<Vec<u8>>,
}

impl ChannelTransport {
    /// Build a fully connected world of `nranks` endpoints.
    pub fn mesh(nranks: usize) -> Vec<ChannelTransport> {
        let (senders, inboxes): (Vec<_>, Vec<_>) =
            (0..nranks).map(|_| channel::<Vec<u8>>()).unzip();
        inboxes
            .into_iter()
            .enumerate()
            .map(|(rank, inbox)| ChannelTransport {
                rank,
                nranks,
                peers: senders
                    .iter()
                    .enumerate()
                    .map(|(dst, s)| {
                        (nranks == 1 || dst != rank).then(|| s.clone())
                    })
                    .collect(),
                inbox,
            })
            .collect()
    }
}

impl Transport for ChannelTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn nranks(&self) -> usize {
        self.nranks
    }

    fn send_plane(&mut self, dst: usize, src: u32, tag: Tag, data: &[f64])
                  -> Result<()> {
        let peer = self
            .peers
            .get(dst)
            .and_then(Option::as_ref)
            .ok_or_else(|| {
                Error::Invalid(format!(
                    "comms: send to rank {dst} of {} (self-sends only \
                     exist in a 1-rank world)",
                    self.nranks
                ))
            })?;
        peer.send(PlaneMsg::encode_from(src, tag, data)).map_err(|_| {
            Error::Invalid(format!("comms: rank {dst} hung up"))
        })
    }

    fn recv(&mut self) -> Result<PlaneMsg> {
        let bytes = self.inbox.recv().map_err(|_| {
            Error::Invalid(
                "comms: all peers hung up while receiving".to_string(),
            )
        })?;
        PlaneMsg::decode(&bytes)
    }

    fn recv_timeout(&mut self, timeout: Duration)
                    -> Result<Option<PlaneMsg>> {
        match self.inbox.recv_timeout(timeout) {
            Ok(bytes) => PlaneMsg::decode(&bytes).map(Some),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(Error::Invalid(
                "comms: all peers hung up while receiving".to_string(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::wire::{FieldId, Phase, Side, Tag};

    fn msg(src: u32, step: u64, data: Vec<f64>) -> PlaneMsg {
        PlaneMsg {
            src,
            tag: Tag {
                step,
                phase: Phase::Moments,
                field: FieldId::G,
                side: Side::Low,
            },
            data,
        }
    }

    #[test]
    fn mesh_delivers_across_threads() {
        let mut world = ChannelTransport::mesh(3);
        assert_eq!(world[1].rank(), 1);
        assert_eq!(world[1].nranks(), 3);
        let mut r2 = world.pop().unwrap();
        let mut r1 = world.pop().unwrap();
        let mut r0 = world.pop().unwrap();
        let t = std::thread::spawn(move || {
            r1.send(2, &msg(1, 7, vec![1.0, 2.0])).unwrap();
            r1.recv().unwrap()
        });
        r0.send(1, &msg(0, 9, vec![-4.0])).unwrap();
        let got2 = r2.recv().unwrap();
        assert_eq!(got2.src, 1);
        assert_eq!(got2.data, vec![1.0, 2.0]);
        let got1 = t.join().unwrap();
        assert_eq!(got1.src, 0);
        assert_eq!(got1.tag.step, 9);
    }

    #[test]
    fn self_send_loops_back() {
        let mut world = ChannelTransport::mesh(1);
        let mut r0 = world.pop().unwrap();
        r0.send(0, &msg(0, 3, vec![0.5])).unwrap();
        let got = r0.recv().unwrap();
        assert_eq!(got.tag.step, 3);
        assert_eq!(got.data, vec![0.5]);
    }

    #[test]
    fn out_of_range_destination_rejected() {
        let mut world = ChannelTransport::mesh(2);
        let mut r0 = world.remove(0);
        assert!(r0.send(5, &msg(0, 0, vec![])).is_err());
        // multi-rank worlds never self-send (the slab ring has distinct
        // neighbours), and the dropped self-Sender makes it an error
        assert!(r0.send(0, &msg(0, 0, vec![])).is_err());
    }

    #[test]
    fn dead_world_disconnects_instead_of_hanging() {
        let mut world = ChannelTransport::mesh(2);
        let mut r1 = world.pop().unwrap();
        drop(world); // rank 0 (and its Sender clones) are gone
        // without the dropped self-Sender this would block forever
        assert!(r1.recv().is_err());
        assert!(r1
            .recv_timeout(Duration::from_secs(30))
            .is_err());
    }
}
