//! Pluggable endpoint-to-endpoint transports.
//!
//! A [`Transport`] is one endpoint's port into the communication fabric —
//! the role MPI's BTL/PML stack plays under `MPI_Isend`/`MPI_Recv`. The
//! contract is deliberately minimal and **byte-oriented**: addressed,
//! non-blocking sends of encoded [`Frame`] bytes, and a blocking receive
//! of the next frame addressed to this endpoint. Ordering is only
//! guaranteed *per sender pair* (like MPI's non-overtaking rule); message
//! matching by [`crate::comms::wire::Tag`] — and command sequencing for
//! resident sessions — happens one layer up in
//! [`crate::comms::world::Rank`].
//!
//! Endpoints are the `nranks` compute ranks plus, for resident sessions,
//! one **controller** (the driver thread) addressed as endpoint id
//! `nranks`. Halo planes flow rank↔rank; command/partials/interior/report
//! frames flow controller↔rank. All of them are encoded wire bytes, so a
//! socket transport carries the whole session protocol by implementing
//! the three byte-level methods — the control plane needs nothing extra.
//!
//! Three implementations exist:
//!
//! * [`ChannelTransport`] — in-process: every endpoint runs on its own
//!   OS thread and frames travel through `std::sync::mpsc` channels (the
//!   shared-memory BTL analog). It still moves *encoded bytes*, not
//!   structs, so every run exercises the exact frames the socket
//!   transport puts on a TCP stream.
//! * [`crate::comms::socket::SocketTransport`] — inter-process: the same
//!   frames, length-prefixed, over per-peer TCP connections assembled by
//!   the [`crate::comms::launcher`] rendezvous. A run spans real
//!   processes and hosts with no change above this trait.
//! * [`crate::comms::hybrid::HybridTransport`] — per-link routing: one
//!   OS process per *host* runs that host's ranks as threads; co-hosted
//!   peers exchange frames through in-process channels, only cross-host
//!   links touch a socket. [`Transport::peer_is_intra`] reports which
//!   kind a given peer link is, feeding the per-link traffic split in
//!   [`crate::comms::wire::ReportMsg`].

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use crate::comms::wire::{Frame, PlaneMsg, Tag};
use crate::error::{Error, Result};

/// One endpoint's port into the communication fabric.
///
/// # Contract every implementation must satisfy
///
/// * **Whole frames only.** A successful receive returns the complete
///   byte image of exactly one sent frame — **never a partial frame, a
///   concatenation, or a resend**. A timeout ([`recv_bytes_timeout`]
///   returning `Ok(None)`) consumes nothing: a frame still in flight is
///   delivered intact by a later receive. A connection that dies
///   mid-frame must surface as an `Err`, not as truncated bytes.
/// * **Per-sender-pair ordering.** Frames from one sender to one
///   receiver arrive in send order (MPI's non-overtaking rule); no
///   ordering is promised across different senders. The layers above
///   depend on exactly this — commands are sequenced per sender, halo
///   planes are disambiguated by [`Tag`].
/// * **Local send completion.** [`send_bytes`] may buffer; it completes
///   locally (`MPI_Isend`) and returning `Ok` does not imply delivery.
/// * **Dead worlds surface.** When every peer is gone, a blocking
///   receive must return an error rather than hang forever.
///
/// [`recv_bytes_timeout`]: Transport::recv_bytes_timeout
/// [`send_bytes`]: Transport::send_bytes
/// [`Tag`]: crate::comms::wire::Tag
///
/// # Examples
///
/// Drive a 2-rank world plus controller over the in-process transport —
/// the exact frames a [`crate::comms::socket::SocketTransport`] puts on
/// a TCP stream:
///
/// ```
/// use std::time::Duration;
/// use targetdp::comms::{ChannelTransport, Command, Frame, Transport};
///
/// let (mut ranks, mut ctl) = ChannelTransport::mesh_with_controller(2);
/// ctl.send_frame(0, &Frame::Command(Command::Advance { steps: 3 }))?;
/// assert_eq!(ranks[0].recv()?,
///            Frame::Command(Command::Advance { steps: 3 }));
/// // nothing is in flight for rank 1: a timed receive returns None
/// assert!(ranks[1].recv_timeout(Duration::from_millis(5))?.is_none());
/// # Ok::<(), targetdp::Error>(())
/// ```
pub trait Transport: Send {
    /// This endpoint's id (compute ranks are `0..nranks()`; a session
    /// controller is `nranks()`).
    fn rank(&self) -> usize;
    /// Number of compute ranks in the world (`MPI_Comm_size`; the
    /// controller endpoint is *not* counted).
    fn nranks(&self) -> usize;
    /// Non-blocking addressed send of one encoded frame (`MPI_Isend`):
    /// the transport owns the bytes as soon as this returns. Self-sends
    /// (`dst == rank()`) are legal only in a 1-rank world, which talks to
    /// itself across the periodic seam.
    fn send_bytes(&mut self, dst: usize, frame: Vec<u8>) -> Result<()>;
    /// Blocking receive of the next frame's bytes addressed to this
    /// endpoint, in per-sender arrival order. Always one whole frame —
    /// see the trait-level contract.
    fn recv_bytes(&mut self) -> Result<Vec<u8>>;
    /// Like [`Transport::recv_bytes`] but gives up after `timeout`,
    /// returning `Ok(None)` — the hook [`crate::comms::world::Rank`] uses
    /// to turn a lost peer into an error instead of a hung world. A
    /// timeout never returns (or discards) part of a frame: either one
    /// complete frame arrived in time, or `None`.
    fn recv_bytes_timeout(&mut self, timeout: Duration)
                          -> Result<Option<Vec<u8>>>;

    /// Whether the link to `peer` stays inside this OS process (an
    /// in-process channel or the 1-rank periodic self-seam) rather than
    /// crossing a socket. Purely informational — it feeds the
    /// intra/inter-host traffic split in
    /// [`crate::comms::wire::ReportMsg`] and never changes routing. The
    /// conservative default says no link is intra-process; a pure-socket
    /// world deliberately keeps that answer even for co-hosted loopback
    /// peers, because those links still pay the full frame/syscall cost
    /// the hybrid transport removes.
    fn peer_is_intra(&self, _peer: usize) -> bool {
        false
    }

    /// Send several already-encoded frames to one destination. The
    /// frames stay **distinct messages** (each is received by its own
    /// `recv_bytes`, in order), but an implementation may coalesce the
    /// whole batch into a single carrier operation — the socket
    /// transport turns it into one TCP write, the lever behind the
    /// communication-avoiding super-step exchange. The default just
    /// loops [`Transport::send_bytes`].
    fn send_bytes_batch(&mut self, dst: usize, frames: Vec<Vec<u8>>)
                        -> Result<()> {
        for frame in frames {
            self.send_bytes(dst, frame)?;
        }
        Ok(())
    }

    /// Encode and send one tagged halo plane straight from a borrowed
    /// payload — the only copy on the send hot path.
    fn send_plane(&mut self, dst: usize, src: u32, tag: Tag, data: &[f64])
                  -> Result<()> {
        self.send_bytes(dst, PlaneMsg::encode_from(src, tag, data))
    }

    /// Encode and send any [`Frame`] (commands, partials, interiors,
    /// reports).
    fn send_frame(&mut self, dst: usize, frame: &Frame) -> Result<()> {
        self.send_bytes(dst, frame.encode())
    }

    /// Blocking receive of the next decoded [`Frame`].
    fn recv(&mut self) -> Result<Frame> {
        Frame::decode(&self.recv_bytes()?)
    }

    /// Timed receive of the next decoded [`Frame`].
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Frame>> {
        match self.recv_bytes_timeout(timeout)? {
            Some(bytes) => Frame::decode(&bytes).map(Some),
            None => Ok(None),
        }
    }
}

/// In-process transport: one mpsc inbox per endpoint, frames as encoded
/// bytes.
pub struct ChannelTransport {
    rank: usize,
    nranks: usize,
    /// Senders to every endpoint. The slot for *this* endpoint is `None`
    /// unless it is the single rank of a 1-rank world (which self-sends
    /// across the periodic seam): holding our own `Sender` would keep our
    /// inbox "connected" even after every real peer died — dropping it
    /// makes a dead world surface as `Disconnected` as soon as the last
    /// real sender goes away instead of waiting out a full recv timeout.
    peers: Vec<Option<Sender<Vec<u8>>>>,
    inbox: Receiver<Vec<u8>>,
}

impl ChannelTransport {
    /// Build a fully connected world of `nranks` rank endpoints (no
    /// controller).
    pub fn mesh(nranks: usize) -> Vec<ChannelTransport> {
        Self::build(nranks, nranks)
    }

    /// Build a world of `nranks` rank endpoints plus one controller
    /// endpoint (id `nranks`) for a resident session's driver thread.
    pub fn mesh_with_controller(nranks: usize)
                                -> (Vec<ChannelTransport>, ChannelTransport)
    {
        let mut eps = Self::build(nranks + 1, nranks);
        let controller = eps.pop().expect("controller endpoint exists");
        (eps, controller)
    }

    fn build(endpoints: usize, nranks: usize) -> Vec<ChannelTransport> {
        let (senders, inboxes): (Vec<_>, Vec<_>) =
            (0..endpoints).map(|_| channel::<Vec<u8>>()).unzip();
        inboxes
            .into_iter()
            .enumerate()
            .map(|(rank, inbox)| ChannelTransport {
                rank,
                nranks,
                peers: senders
                    .iter()
                    .enumerate()
                    .map(|(dst, s)| {
                        let keep = dst != rank
                            || (nranks == 1 && rank < nranks);
                        keep.then(|| s.clone())
                    })
                    .collect(),
                inbox,
            })
            .collect()
    }
}

impl Transport for ChannelTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn nranks(&self) -> usize {
        self.nranks
    }

    /// Every channel link lives inside this process.
    fn peer_is_intra(&self, _peer: usize) -> bool {
        true
    }

    fn send_bytes(&mut self, dst: usize, frame: Vec<u8>) -> Result<()> {
        let peer = self
            .peers
            .get(dst)
            .and_then(Option::as_ref)
            .ok_or_else(|| {
                Error::Invalid(format!(
                    "comms: send to endpoint {dst} of a {}-rank world \
                     (self-sends only exist in a 1-rank world)",
                    self.nranks
                ))
            })?;
        peer.send(frame).map_err(|_| {
            Error::Invalid(format!("comms: endpoint {dst} hung up"))
        })
    }

    fn recv_bytes(&mut self) -> Result<Vec<u8>> {
        self.inbox.recv().map_err(|_| {
            Error::Invalid(
                "comms: all peers hung up while receiving".to_string(),
            )
        })
    }

    fn recv_bytes_timeout(&mut self, timeout: Duration)
                          -> Result<Option<Vec<u8>>> {
        match self.inbox.recv_timeout(timeout) {
            Ok(bytes) => Ok(Some(bytes)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(Error::Invalid(
                "comms: all peers hung up while receiving".to_string(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::wire::{Axis, Command, FieldId, Phase, Side, Tag};

    fn msg(src: u32, step: u64, data: Vec<f64>) -> PlaneMsg {
        PlaneMsg {
            src,
            tag: Tag {
                step,
                phase: Phase::Moments,
                field: FieldId::G,
                side: Side::Low,
                axis: Axis::X,
            },
            data,
        }
    }

    fn recv_plane(t: &mut ChannelTransport) -> PlaneMsg {
        match t.recv().unwrap() {
            Frame::Plane(m) => m,
            other => panic!("expected a plane, got {other:?}"),
        }
    }

    #[test]
    fn mesh_delivers_across_threads() {
        let mut world = ChannelTransport::mesh(3);
        assert_eq!(world[1].rank(), 1);
        assert_eq!(world[1].nranks(), 3);
        let mut r2 = world.pop().unwrap();
        let mut r1 = world.pop().unwrap();
        let mut r0 = world.pop().unwrap();
        let t = std::thread::spawn(move || {
            r1.send_frame(2, &Frame::Plane(msg(1, 7, vec![1.0, 2.0])))
                .unwrap();
            recv_plane(&mut r1)
        });
        r0.send_frame(1, &Frame::Plane(msg(0, 9, vec![-4.0]))).unwrap();
        let got2 = recv_plane(&mut r2);
        assert_eq!(got2.src, 1);
        assert_eq!(got2.data, vec![1.0, 2.0]);
        let got1 = t.join().unwrap();
        assert_eq!(got1.src, 0);
        assert_eq!(got1.tag.step, 9);
    }

    #[test]
    fn self_send_loops_back() {
        let mut world = ChannelTransport::mesh(1);
        let mut r0 = world.pop().unwrap();
        r0.send_frame(0, &Frame::Plane(msg(0, 3, vec![0.5]))).unwrap();
        let got = recv_plane(&mut r0);
        assert_eq!(got.tag.step, 3);
        assert_eq!(got.data, vec![0.5]);
    }

    #[test]
    fn out_of_range_destination_rejected() {
        let mut world = ChannelTransport::mesh(2);
        let mut r0 = world.remove(0);
        let m = Frame::Plane(msg(0, 0, vec![]));
        assert!(r0.send_frame(5, &m).is_err());
        // multi-rank worlds never self-send (the slab ring has distinct
        // neighbours), and the dropped self-Sender makes it an error
        assert!(r0.send_frame(0, &m).is_err());
    }

    #[test]
    fn dead_world_disconnects_instead_of_hanging() {
        let mut world = ChannelTransport::mesh(2);
        let mut r1 = world.pop().unwrap();
        drop(world); // rank 0 (and its Sender clones) are gone
        // without the dropped self-Sender this would block forever
        assert!(r1.recv().is_err());
        assert!(r1
            .recv_timeout(Duration::from_secs(30))
            .is_err());
    }

    #[test]
    fn batched_sends_stay_distinct_messages() {
        let mut world = ChannelTransport::mesh(2);
        let mut r1 = world.pop().unwrap();
        let mut r0 = world.pop().unwrap();
        let frames: Vec<Vec<u8>> = (0..3)
            .map(|i| Frame::Plane(msg(0, i, vec![i as f64])).encode())
            .collect();
        r0.send_bytes_batch(1, frames).unwrap();
        for i in 0..3 {
            let got = recv_plane(&mut r1);
            assert_eq!(got.tag.step, i, "batch preserves send order");
            assert_eq!(got.data, vec![i as f64]);
        }
    }

    #[test]
    fn controller_mesh_routes_commands_and_responses() {
        let (mut ranks, mut ctl) = ChannelTransport::mesh_with_controller(2);
        assert_eq!(ctl.rank(), 2, "controller id is nranks");
        assert_eq!(ctl.nranks(), 2);
        assert_eq!(ranks.len(), 2);
        assert_eq!(ranks[0].nranks(), 2);

        // controller → rank 1: a command
        ctl.send_frame(1, &Frame::Command(Command::Advance { steps: 4 }))
            .unwrap();
        match ranks[1].recv().unwrap() {
            Frame::Command(Command::Advance { steps }) => {
                assert_eq!(steps, 4)
            }
            other => panic!("expected a command, got {other:?}"),
        }
        // rank 0 → controller (endpoint id nranks): a halo-style frame
        ranks[0]
            .send_frame(2, &Frame::Plane(msg(0, 1, vec![9.0])))
            .unwrap();
        let got = match ctl.recv().unwrap() {
            Frame::Plane(m) => m,
            other => panic!("expected a plane, got {other:?}"),
        };
        assert_eq!(got.src, 0);
        // ranks still talk to each other directly
        ranks[0]
            .send_frame(1, &Frame::Plane(msg(0, 2, vec![1.0])))
            .unwrap();
        match ranks[1].recv().unwrap() {
            Frame::Plane(m) => assert_eq!(m.tag.step, 2),
            other => panic!("expected a plane, got {other:?}"),
        }
        // the controller never self-sends
        assert!(ctl
            .send_frame(2, &Frame::Command(Command::Shutdown))
            .is_err());
    }

    #[test]
    fn one_rank_world_with_controller_keeps_self_seam() {
        let (mut ranks, _ctl) = ChannelTransport::mesh_with_controller(1);
        let mut r0 = ranks.pop().unwrap();
        // the single rank still self-sends across the periodic seam
        r0.send_frame(0, &Frame::Plane(msg(0, 0, vec![2.0]))).unwrap();
        assert_eq!(recv_plane(&mut r0).data, vec![2.0]);
    }
}
