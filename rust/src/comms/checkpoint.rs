//! Versioned checkpoint encoding: a decomposition-independent snapshot
//! of the global simulation state, written by the driver between
//! logging blocks and restored into **any** world shape.
//!
//! The snapshot is taken with the [`Command::Checkpoint`] session
//! command ([`crate::comms::wire`]): every resident rank streams its
//! interior `f` and `g` to the driver exactly like a `Gather`, the
//! driver places the sub-domains into global arrays, and this module
//! serializes those global arrays. Because the *global* state is what
//! lands on disk, a checkpoint taken at 4 ranks on a slab restores into
//! any rank count, grid shape, transport, or comms depth — including
//! the single-domain fused engine. `f` and `g` are sufficient for exact
//! resume at a step boundary: phi, the gradients and the Laplacian are
//! recomputed from `g` at the start of every step, and the stepping
//! itself is deterministic, so a run resumed from the step-`c` snapshot
//! finishes **bitwise identical** to the uninterrupted run.
//!
//! File layout (all integers little-endian, doubles as
//! `f64::to_le_bytes` images — the same bit-exact encoding as the wire
//! frames):
//!
//! ```text
//! offset  size  field
//!      0     4  magic   "TDPK"
//!      4     1  version (1)
//!      5     8  step    (timesteps completed when the snapshot was cut)
//!     13    24  lx, ly, lz (u64 each — global lattice extents)
//!     37     4  nvel    (velocity-set size, e.g. 9 or 19)
//!     41     4  config_len
//!     45     …  config  (config_len bytes of UTF-8 TOML — the driver
//!                        config echo, for provenance / `--restore`
//!                        sanity checks)
//!            1  nfields
//!  per field:
//!            1  name_len
//!            …  name    (name_len bytes of UTF-8, e.g. "f", "g")
//!            4  ncomp   (doubles per lattice site)
//!            8  count   (must equal ncomp * lx * ly * lz)
//!            …  payload (count doubles, LE f64)
//! ```
//!
//! Decoding is strict — magic, version, UTF-8, the `count` cross-check
//! against `ncomp * dims`, and the exact total length are all
//! validated, because `--restore` feeds this arbitrary bytes.

use std::path::Path;

use crate::error::{Error, Result};

/// Checkpoint file magic: "targetDP checkpoint".
pub const CHECKPOINT_MAGIC: [u8; 4] = *b"TDPK";
/// Checkpoint encoding version.
pub const CHECKPOINT_VERSION: u8 = 1;
/// Fixed header size in bytes (up to and excluding the config echo).
pub const CHECKPOINT_HEADER_LEN: usize = 45;

fn bad(m: String) -> Error {
    Error::Invalid(format!("checkpoint: {m}"))
}

/// One named global field inside a checkpoint (`"f"`, `"g"`, …).
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointField {
    /// Field name (`"f"` and `"g"` today).
    pub name: String,
    /// Doubles per lattice site (the velocity-set size for f/g).
    pub ncomp: u32,
    /// `ncomp * lx * ly * lz` doubles in the engine's SoA site order.
    pub data: Vec<f64>,
}

/// A decomposition-independent snapshot of the global simulation state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Timesteps completed when the snapshot was cut; a restored run
    /// resumes at this step and runs `steps - step` more.
    pub step: u64,
    /// Global lattice extents `[lx, ly, lz]`.
    pub dims: [u64; 3],
    /// Velocity-set size the state was produced with (9 or 19).
    pub nvel: u32,
    /// Driver config echo (TOML) for provenance; restore validates the
    /// *lattice*, not this echo, so a restored run may change ranks,
    /// grid, transport or depth freely.
    pub config_toml: String,
    /// The global fields, in write order.
    pub fields: Vec<CheckpointField>,
}

/// Strict little-endian cursor over the checkpoint image.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Reader { bytes, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            bad("length overflow".into())
        })?;
        if end > self.bytes.len() {
            return Err(bad(format!(
                "truncated: need {end} bytes, have {}",
                self.bytes.len()
            )));
        }
        let out = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.bytes.len() {
            return Err(bad(format!(
                "{} trailing bytes after the last field",
                self.bytes.len() - self.pos
            )));
        }
        Ok(())
    }
}

impl Checkpoint {
    /// Global site count `lx * ly * lz` (overflow-checked).
    pub fn nsites(&self) -> Result<u64> {
        self.dims[0]
            .checked_mul(self.dims[1])
            .and_then(|v| v.checked_mul(self.dims[2]))
            .ok_or_else(|| bad(format!("dims {:?} overflow", self.dims)))
    }

    /// Serialize to the on-disk image.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&CHECKPOINT_MAGIC);
        out.push(CHECKPOINT_VERSION);
        out.extend_from_slice(&self.step.to_le_bytes());
        for d in self.dims {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out.extend_from_slice(&self.nvel.to_le_bytes());
        let config = self.config_toml.as_bytes();
        assert!(config.len() <= u32::MAX as usize, "config echo too large");
        out.extend_from_slice(&(config.len() as u32).to_le_bytes());
        out.extend_from_slice(config);
        assert!(self.fields.len() <= u8::MAX as usize, "too many fields");
        out.push(self.fields.len() as u8);
        for field in &self.fields {
            let name = field.name.as_bytes();
            assert!(name.len() <= u8::MAX as usize, "field name too long");
            out.push(name.len() as u8);
            out.extend_from_slice(name);
            out.extend_from_slice(&field.ncomp.to_le_bytes());
            out.extend_from_slice(&(field.data.len() as u64).to_le_bytes());
            for v in &field.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Parse an on-disk image (strict: magic, version, UTF-8, the
    /// per-field `count == ncomp * lx*ly*lz` cross-check and the exact
    /// total length are all validated).
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        let mut r = Reader::new(bytes);
        let magic = r.take(4)?;
        if magic != CHECKPOINT_MAGIC {
            return Err(bad(format!("bad magic {magic:02x?}")));
        }
        let version = r.u8()?;
        if version != CHECKPOINT_VERSION {
            return Err(bad(format!(
                "version {version} (want {CHECKPOINT_VERSION})"
            )));
        }
        let step = r.u64()?;
        let dims = [r.u64()?, r.u64()?, r.u64()?];
        let nvel = r.u32()?;
        let nsites = dims[0]
            .checked_mul(dims[1])
            .and_then(|v| v.checked_mul(dims[2]))
            .ok_or_else(|| bad(format!("dims {dims:?} overflow")))?;
        if nsites == 0 {
            return Err(bad(format!("degenerate dims {dims:?}")));
        }
        let config_len = r.u32()? as usize;
        let config_toml = std::str::from_utf8(r.take(config_len)?)
            .map_err(|e| bad(format!("config echo is not UTF-8: {e}")))?
            .to_string();
        let nfields = r.u8()?;
        let mut fields = Vec::with_capacity(nfields as usize);
        for _ in 0..nfields {
            let name_len = r.u8()? as usize;
            let name = std::str::from_utf8(r.take(name_len)?)
                .map_err(|e| bad(format!("field name is not UTF-8: {e}")))?
                .to_string();
            let ncomp = r.u32()?;
            let count = r.u64()?;
            let want = (ncomp as u64).checked_mul(nsites).ok_or_else(|| {
                bad(format!("field {name:?}: ncomp {ncomp} overflows"))
            })?;
            if count != want {
                return Err(bad(format!(
                    "field {name:?}: count {count} != ncomp {ncomp} x \
                     {nsites} sites (dims {dims:?})"
                )));
            }
            let nbytes = count.checked_mul(8).ok_or_else(|| {
                bad(format!("field {name:?}: payload overflows"))
            })?;
            if nbytes > (bytes.len() - r.pos) as u64 {
                return Err(bad(format!(
                    "field {name:?}: truncated payload ({} bytes left, \
                     need {nbytes})",
                    bytes.len() - r.pos
                )));
            }
            let raw = r.take(nbytes as usize)?;
            let data = raw
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            fields.push(CheckpointField { name, ncomp, data });
        }
        r.done()?;
        Ok(Checkpoint { step, dims, nvel, config_toml, fields })
    }

    /// Look up a field by name.
    pub fn field(&self, name: &str) -> Option<&CheckpointField> {
        self.fields.iter().find(|f| f.name == name)
    }

    /// Remove and return a field's payload, validating its length.
    pub fn take_field(&mut self, name: &str, want: usize)
                      -> Result<Vec<f64>> {
        let idx = self
            .fields
            .iter()
            .position(|f| f.name == name)
            .ok_or_else(|| {
                bad(format!("snapshot has no field {name:?}"))
            })?;
        let field = self.fields.remove(idx);
        if field.data.len() != want {
            return Err(bad(format!(
                "field {name:?} holds {} doubles, this run needs {want}",
                field.data.len()
            )));
        }
        Ok(field.data)
    }

    /// Write the image atomically: a sibling `.tmp` file is renamed into
    /// place, so a crash mid-write never corrupts the previous
    /// checkpoint a supervised restart would restore from.
    pub fn write_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp_name);
        std::fs::write(&tmp, self.encode())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Read and parse a checkpoint file.
    pub fn read_file<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let path = path.as_ref();
        let bytes = std::fs::read(path).map_err(|e| {
            bad(format!("cannot read {}: {e}", path.display()))
        })?;
        Self::decode(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            step: 41,
            dims: [3, 2, 1],
            nvel: 9,
            config_toml: "[simulation]\nlattice = \"d2q9\"\n".into(),
            fields: vec![
                CheckpointField {
                    name: "f".into(),
                    ncomp: 9,
                    data: (0..54)
                        .map(|i| (i as f64) * 0.5 - 1e-300)
                        .collect(),
                },
                CheckpointField {
                    name: "g".into(),
                    ncomp: 9,
                    data: vec![1.0 / 3.0; 54],
                },
            ],
        }
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let ck = sample();
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        assert_eq!(back.step, ck.step);
        assert_eq!(back.dims, ck.dims);
        assert_eq!(back.nvel, ck.nvel);
        assert_eq!(back.config_toml, ck.config_toml);
        assert_eq!(back.fields.len(), 2);
        for (a, b) in back.fields.iter().zip(&ck.fields) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.ncomp, b.ncomp);
            assert_eq!(a.data.len(), b.data.len());
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "bitwise f64 image");
            }
        }
    }

    #[test]
    fn every_truncation_is_rejected() {
        let bytes = sample().encode();
        for len in 0..bytes.len() {
            assert!(
                Checkpoint::decode(&bytes[..len]).is_err(),
                "prefix of {len} bytes must not decode"
            );
        }
    }

    #[test]
    fn corrupt_images_rejected() {
        let good = sample().encode();
        // oversize: trailing garbage after the last field
        let mut bad = good.clone();
        bad.push(0);
        assert!(Checkpoint::decode(&bad).is_err());
        // bad magic
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(Checkpoint::decode(&bad).is_err());
        // bad version
        let mut bad = good.clone();
        bad[4] = 9;
        assert!(Checkpoint::decode(&bad).is_err());
        // dim mismatch: shrink lz so the field counts no longer match
        let mut bad = good.clone();
        bad[13] = 7; // lx: 3 -> 7
        assert!(Checkpoint::decode(&bad).is_err());
        // degenerate dims
        let mut bad = good.clone();
        bad[13] = 0;
        assert!(Checkpoint::decode(&bad).is_err());
    }

    #[test]
    fn take_field_validates_name_and_length() {
        let mut ck = sample();
        assert!(ck.take_field("phi", 54).is_err(), "unknown field");
        assert!(ck.clone().take_field("f", 53).is_err(), "length check");
        let f = ck.take_field("f", 54).unwrap();
        assert_eq!(f.len(), 54);
        assert!(ck.field("f").is_none(), "taken fields are removed");
        assert!(ck.field("g").is_some());
    }

    #[test]
    fn file_round_trip_through_tmp_rename() {
        let dir = std::env::temp_dir().join(format!(
            "tdpk-unit-{}",
            std::process::id()
        ));
        let path = dir.join("nested/ck.tdpk");
        let ck = sample();
        ck.write_file(&path).unwrap();
        let back = Checkpoint::read_file(&path).unwrap();
        assert_eq!(back, ck);
        assert!(!path.with_extension("tdpk.tmp").exists(),
                "the .tmp staging file is renamed away");
        // overwrite in place: the rename replaces the old image
        ck.write_file(&path).unwrap();
        assert_eq!(Checkpoint::read_file(&path).unwrap(), ck);
        std::fs::remove_dir_all(&dir).ok();
        assert!(Checkpoint::read_file(&path).is_err(), "missing file");
    }
}
