//! `comms` — the rank-parallel distribution level above targetDP.
//!
//! The paper positions targetDP as the *intra-node* layer, "used in
//! conjunction with higher-level paradigms such as MPI" for the
//! *inter-node* level; the follow-up paper (arXiv:1609.01479) scales that
//! stack to thousands of GPUs with slab/pencil halo exchange as the
//! dominant communication pattern. This module is that level: every
//! subdomain of the x-slab decomposition becomes a **rank** running
//! concurrently on its own thread with its own TLP pool and its own
//! first-touch-allocated fields, exchanging serialized halo planes
//! through a pluggable [`transport::Transport`] — in-process channels
//! today, sockets tomorrow, the rank-side code unchanged either way.
//!
//! Concept map for readers coming from MPI:
//!
//! | here                                  | MPI                                    |
//! |---------------------------------------|----------------------------------------|
//! | [`world::CommsWorld`]                 | `MPI_COMM_WORLD` + `mpirun -np N`      |
//! | [`world::Rank`], `rank`/`nranks`      | rank, `MPI_Comm_rank`/`MPI_Comm_size`  |
//! | [`world::Rank::isend`]                | `MPI_Isend` (returns once buffered)    |
//! | [`world::Rank::wait`]                 | posted `MPI_Irecv` + `MPI_Wait`        |
//! | the per-exchange pair of `wait` calls | `MPI_Waitall` on the recv requests     |
//! | [`wire::Tag`] matching                | `(source, tag, comm)` envelope match   |
//! | `Rank`'s pending-frame map            | the unexpected-message queue           |
//! | [`transport::ChannelTransport`]       | a shared-memory BTL                    |
//! | [`wire::PlaneMsg`] byte frames        | the network wire format                |
//! | halo `pack_x_plane`/`unpack_x_plane`  | derived-datatype pack/unpack           |
//!
//! The point of the subsystem is **communication/computation overlap**
//! (`CommsConfig::overlap`, on by default): a rank posts its boundary
//! planes, computes every site whose stencil does not reach a halo while
//! the messages are in flight, and finishes the edge planes on arrival —
//! the classic `isend/irecv → interior → waitall → boundary` pattern,
//! driven by the `StreamTable` boundary/interior exception lists. The
//! bulk-synchronous schedule is kept as a config toggle and is
//! bit-identical (as is the single-domain path; `tests/comms_parity.rs`
//! pins both, and `benches/halo_overlap.rs` measures the difference).

pub mod transport;
pub mod wire;
pub mod world;

pub use transport::{ChannelTransport, Transport};
pub use wire::{FieldId, Phase, PlaneMsg, Side, Tag};
pub use world::{run_decomposed, CommsConfig, CommsWorld, Rank, RankReport,
                WorldReport};
