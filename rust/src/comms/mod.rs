//! `comms` — the rank-parallel distribution level above targetDP.
//!
//! The paper positions targetDP as the *intra-node* layer, "used in
//! conjunction with higher-level paradigms such as MPI" for the
//! *inter-node* level; the follow-up paper (arXiv:1609.01479) scales that
//! stack to thousands of GPUs with slab/pencil halo exchange as the
//! dominant communication pattern — and keeps the ranks **resident** for
//! the whole run. This module is that level: every subdomain of a 3D
//! Cartesian `(px, py, pz)` decomposition ([`crate::lattice::decomp`])
//! becomes a **rank** running concurrently on its own thread with its
//! own TLP pool and its own first-touch-allocated fields, exchanging
//! serialized, axis-tagged halo faces through a pluggable
//! [`transport::Transport`] — in-process channels
//! ([`transport::ChannelTransport`]) or real TCP sockets spanning OS
//! processes and hosts ([`socket::SocketTransport`] +
//! [`launcher`]), the rank-side code unchanged either way.
//!
//! # Session lifecycle
//!
//! A [`world::CommsSession`] spawns the rank threads **once per run**
//! ([`world::CommsWorld::session`]); each rank scatters its own planes
//! out of the initial state, then serves a command loop until `Shutdown`,
//! pausing at the command barrier between logging blocks:
//!
//! ```text
//! driver (controller endpoint)          resident ranks (one thread each)
//! ─────────────────────────────         ─────────────────────────────────
//! session()                             allocate + scatter (first touch),
//!                                       park at the command barrier
//! advance(steps)     ── Advance ──►     step `steps` times (halo
//!                                       exchange rank↔rank, overlapped)
//! observables()      ── Observables ─►  reduce own interior
//!        ◄── Partials (O(1) sums) ──    (targetdp::reduce), stay put
//! gather(f, g)       ── Gather ──►      ship interior f, g
//!        ◄── Interior x2 ──
//! gather_phi()       ── GatherPhi ──►   fresh phi from g, own pool/VVL
//!        ◄── Interior(phi) ──
//! finish()           ── Shutdown ──►    send lifetime Report, exit
//!        ◄── Report ──                  (threads joined)
//! ```
//!
//! Between blocks **no global f/g state moves**: per-block observables
//! are distributed reductions (each rank's exact interior sums, combined
//! in rank order — the `MPI_Allreduce` shape), and the full state is
//! gathered only at the end or for an explicit VTK snapshot. The one-shot
//! [`world::CommsWorld::run`] / [`world::run_decomposed`] entry points
//! are thin wrappers: session + one `Advance` + `Gather` + `finish`.
//!
//! # Wire frames
//!
//! Everything — halo planes *and* the control plane — travels as
//! self-describing byte frames ([`wire::Frame`]), so the protocol is
//! transport-agnostic and a socket transport drops in by moving bytes:
//!
//! | frame                   | direction        | carries                            |
//! |-------------------------|------------------|------------------------------------|
//! | [`wire::PlaneMsg`]      | rank ↔ rank      | one axis-tagged halo face          |
//! | [`wire::PlaneBlockMsg`] | rank ↔ rank      | a depth-tagged ghost block of `2k` x-planes (super-steps) |
//! | [`wire::Command`]       | driver → rank    | `Advance{steps}` / `Observables` / `Gather` / `GatherPhi` / `Shutdown` / `Checkpoint` |
//! | [`wire::PartialObs`]    | rank → driver    | interior mass/momentum/phi/phi² sums |
//! | [`wire::InteriorMsg`]   | rank → driver    | packed interior of f, g or phi     |
//! | [`wire::ReportMsg`]     | rank → driver    | lifetime timing/traffic totals     |
//! | [`wire::TraceMsg`]      | rank → driver    | phase span timeline (tracing runs only, just before the `Report`) |
//!
//! Concept map for readers coming from MPI:
//!
//! | here                                  | MPI                                    |
//! |---------------------------------------|----------------------------------------|
//! | [`world::CommsWorld`]                 | `MPI_COMM_WORLD` + `mpirun -np N`      |
//! | [`world::CommsSession`]               | resident ranks + the driver rank       |
//! | [`world::Rank`], `rank`/`nranks`      | rank, `MPI_Comm_rank`/`MPI_Comm_size`  |
//! | [`world::Rank::isend`]                | `MPI_Isend` (returns once buffered)    |
//! | [`world::Rank::wait`]                 | posted `MPI_Irecv` + `MPI_Wait`        |
//! | the per-exchange pair of `wait` calls | `MPI_Waitall` on the recv requests     |
//! | [`world::CommsSession::observables`]  | `MPI_Reduce` of per-rank partials      |
//! | [`world::CommsSession::gather`]       | `MPI_Gather` of the distributed state  |
//! | [`wire::Tag`] matching                | `(source, tag, comm)` envelope match   |
//! | `Rank`'s pending-frame map            | the unexpected-message queue           |
//! | [`transport::ChannelTransport`]       | a shared-memory BTL                    |
//! | [`wire::Frame`] byte frames           | the network wire format                |
//! | halo `pack_x_plane`/`unpack_x_plane`  | derived-datatype pack/unpack           |
//!
//! The point of the subsystem is **communication/computation overlap**
//! (`CommsConfig::overlap`, on by default): a rank posts its boundary
//! planes, computes every site whose stencil does not reach a halo while
//! the messages are in flight, and finishes the edge planes on arrival —
//! the classic `isend/irecv → interior → waitall → boundary` pattern,
//! driven by the `StreamTable` boundary/interior exception lists. The
//! bulk-synchronous schedule is kept as a config toggle and is
//! bit-identical (as is the single-domain path; `tests/comms_parity.rs`
//! and `tests/resident_world.rs` pin both, `benches/halo_overlap.rs` and
//! `benches/resident_world.rs` measure the difference).
//!
//! On top of overlap sits **communication avoidance**
//! (`CommsConfig::depth`, the `[target] comms_depth` knob): with depth
//! `k > 1` each rank exchanges one depth-tagged ghost *block* of `2k`
//! planes per field per neighbour, then advances `k` trapezoid-blocked
//! timesteps locally, recomputing the shrinking overlap exactly like the
//! host `MultiStep` tier — 4 messages per `k` steps instead of `6k`,
//! bit-identical to every other schedule (`tests/multistep_world.rs`,
//! depth sweep in `benches/halo_overlap.rs`).
//!
//! Non-slab grids (`CommsConfig::grid`, the `[target] grid` knob) split
//! more than one axis: each rank talks only to its **6 face neighbours**
//! and the halo exchange runs as staged per-axis sweeps (x → y → z), so
//! edge and corner halo data ride through the faces in 2–3 hops instead
//! of 26-neighbour messages — still bit-identical to the slab world and
//! the fused engine (`tests/grid_world.rs`; the grid sweep in
//! `benches/halo_overlap.rs` measures the surface-to-volume win).
//!
//! # Multi-process worlds
//!
//! The session control frames travel as wire bytes through the same
//! transport as the halo planes, so promoting a run from threads to OS
//! processes is purely a transport swap: [`socket::SocketTransport`]
//! implements the three byte-level methods over per-peer TCP connections
//! (length-prefixed [`wire::Frame`] bytes, reused verbatim), and
//! [`launcher`] provides the rendezvous that assembles N processes into
//! a world — the driver holds the controller endpoint
//! ([`world::CommsWorld::remote_session`]) and each rank process runs
//! [`world::serve_rank`]. `targetdp run --transport socket` spawns local
//! rank processes automatically; `--rank-server host:port` +
//! `targetdp rank --connect host:port` spans hosts. Socket runs are
//! bit-identical to channel runs and to the single-domain fused engine
//! (`tests/socket_transport.rs`; `docs/architecture.md` is the operator
//! guide).
//!
//! # Hybrid worlds
//!
//! `--transport hybrid` keeps the multi-process reach but collapses each
//! host to **one OS process carrying all of that host's ranks as
//! resident threads**: [`hybrid::HybridTransport`] routes every peer
//! link by locality — co-hosted neighbours exchange encoded frames over
//! in-process channels (no length-prefix framing, no syscalls) while
//! cross-host links share one TCP stream per host pair, multiplexed by
//! destination envelopes. The rendezvous ([`launcher::connect_host`] /
//! `RankServer::rendezvous_hosts`) ships the host→ranks map in the
//! `Welcome`, so each host builds its channel mesh locally and dials
//! only inter-host sockets. Because grid ranks are numbered z-fastest
//! and placement is host-grouped, the highest-traffic inner-axis faces
//! land on channel links — [`wire::ReportMsg`]'s intra/inter traffic
//! split is the receipt (`tests/hybrid_world.rs` pins bitwise parity
//! against the channel, socket and fused-engine references).
//!
//! # Checkpoint/restart and fault tolerance
//!
//! [`world::CommsSession::checkpoint`] broadcasts
//! [`wire::Command::Checkpoint`] between logging blocks: every rank
//! streams its interior f/g to the driver (the `Gather` payload path,
//! bit-exact LE doubles) and [`checkpoint`] serializes the reassembled
//! **global** state — so a snapshot taken at 4 slab ranks restores into
//! any rank count, grid shape, transport, comms depth, or the fused
//! single-domain engine, and a resumed run finishes bitwise identical
//! to an uninterrupted one (`tests/checkpoint_restart.rs`). The
//! supervised driver loop in [`crate::coordinator`] turns a world error
//! (rank/host death via the launcher's exit status and the hybrid
//! [`wire::ReportMsg`]-counting EOF policies) into a bounded-retry
//! relaunch from the last checkpoint, optionally at reduced rank count.
//! `CommsConfig::fault` arms a deterministic fault-injection hook — a
//! chosen rank dies at a chosen step, mid-exchange or at the command
//! barrier — which is how `tests/fault_recovery.rs` and CI prove the
//! recovery path end to end.

pub mod checkpoint;
pub mod hybrid;
pub mod launcher;
pub mod socket;
pub mod transport;
pub mod wire;
pub mod world;

pub use checkpoint::{Checkpoint, CheckpointField, CHECKPOINT_HEADER_LEN,
                     CHECKPOINT_MAGIC, CHECKPOINT_VERSION};
pub use hybrid::HybridTransport;
pub use launcher::{connect_host, connect_rank, connect_world, HostBlock,
                   HostSpec, LocalRanks, RankServer, WorldEndpoints};
pub use socket::SocketTransport;
pub use transport::{ChannelTransport, Transport};
pub use wire::{Axis, Command, FieldId, Frame, InteriorField, InteriorMsg,
               PartialObs, Phase, PlaneBlockMsg, PlaneMsg, ReportMsg,
               Side, Tag, TraceMsg};
pub use world::{run_decomposed, serve_rank, CommsConfig, CommsSession,
                CommsWorld, FaultPoint, FaultSpec, Rank, RankReport,
                WorldReport};
