//! The rank world: resident ranks on a 3D Cartesian grid with
//! overlapped halo exchange.
//!
//! [`CommsWorld`] plays the role of `MPI_COMM_WORLD`: it owns the
//! Cartesian decomposition (a `(px, py, pz)` rank grid; the classic
//! x-slab world is the `(p, 1, 1)` special case) and, per
//! [`CommsWorld::session`], spawns **one OS thread per rank** — exactly
//! once per run. Each rank owns its local lattice (allocated and
//! first-touched by its own TLP pool) for the entire simulation, steps
//! independently, and talks to its face neighbours only through
//! [`Rank::isend`]/[`Rank::wait`] — there is no shared mutable state and
//! no sequential domain loop anywhere.
//!
//! The driver holds a [`CommsSession`] and steers the resident ranks over
//! the same [`Transport`] the halo planes use, with a small command
//! protocol ([`Command`]): `Advance{steps}` runs a block of timesteps,
//! `Observables` returns distributed partial reductions (no global
//! gather), `Gather`/`GatherPhi` ship the interiors on demand (final
//! state, VTK output), and `Shutdown` retires the rank with a final
//! [`ReportMsg`]. Between commands a rank pauses at the command barrier
//! ([`Rank::wait_command`]); neighbours that already started the next
//! block may race ahead — their planes are parked in the pending queue,
//! and the per-step [`Tag`] keeps every exchange unambiguous.
//!
//! Per timestep a rank performs two exchanges (three plane messages per
//! side, down from the four the old bulk-synchronous loop copied):
//!
//! 1. **Moments exchange** — post-stream `g` boundary planes, feeding the
//!    phi moment and the gradient stencil of the edge planes;
//! 2. **Stream exchange** — post-collision `f` and `g` boundary planes,
//!    feeding the pull-streaming of the edge destination planes.
//!
//! In overlapped mode (the default) the rank posts its sends, then
//! collides/streams the sites that do not depend on incoming halos while
//! the messages are in flight — the `StreamTable` exception lists prove
//! the interior split is safe (`pull_sources_within`) — and completes the
//! boundary planes on arrival. Bulk-sync mode waits for all halos before
//! computing (the `MPI_Sendrecv`-everything reference schedule). Both
//! orders run the identical per-site arithmetic, so they are bit-identical
//! to each other *and* to the single-domain fused `FullStep` path
//! (`tests/comms_parity.rs`, `tests/resident_world.rs`).
//!
//! # Communication-avoiding super-steps
//!
//! With [`CommsConfig::depth`] `k > 1` the per-step exchanges above are
//! replaced by one exchange per **k-step super-step**: each rank extends
//! its slab by `HALO_PER_STEP * k` ghost planes per side, receives a
//! single depth-tagged ghost *block* of `2k` x-planes per field per
//! neighbour ([`crate::comms::wire::PlaneBlockMsg`], batched so a socket
//! transport issues one TCP write per neighbour), and then advances `k`
//! fused collide→stream timesteps entirely locally, the valid window
//! shrinking by two planes per side per step — exactly the trapezoid
//! recurrence of the host [`crate::lb::multistep::MultiStepPlan`] tier,
//! shifted into the rank's deep-halo slab. Per `k` steps a rank sends 4
//! block messages instead of `6k` plane messages. The overlapped
//! schedule still applies: the first blocked step's interior needs no
//! ghost data and is computed while the blocks are in flight. Every
//! per-site update is placement-independent, so depth-k runs are
//! bit-identical to the depth-1 resident world and the fused engine
//! (`tests/multistep_world.rs`).
//!
//! # Grid worlds: staged per-axis face exchange
//!
//! On a non-slab grid every rank has up to six face neighbours. Instead
//! of 26-neighbour messages, each exchange is staged per decomposed axis
//! in x → y → z order: a face frame spans the *full* halo-padded local
//! cross-section of the other two axes, so the y faces a rank packs
//! after its x-wait already carry the freshly received x halos — edge
//! (and corner) data flow to where the diagonal stencils need them
//! through the staged sequence, later stages overwriting the staler
//! edge values earlier stages deposited. Per step a grid rank sends 6
//! face messages per decomposed axis (2 moments + 4 stream), each
//! axis-tagged ([`Axis`]) so a 2-wide axis — where both neighbours are
//! the same peer — stays unambiguous. The overlapped schedule computes
//! the deep interior while the *first* axis's faces are in flight and
//! finishes the face shell after the last stage; bulk-sync completes
//! the whole staged exchange up front. Super-steps (`depth > 1`) remain
//! slab-only. Every grid world is bit-identical to the slab world and
//! the fused single-domain engine (`tests/grid_world.rs`).

use std::collections::{HashMap, VecDeque};
use std::ops::Range;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::comms::transport::{ChannelTransport, Transport};
use crate::comms::wire::{Axis, Command, FieldId, Frame, InteriorField,
                         InteriorMsg, PartialObs, Phase, PlaneBlockMsg,
                         PlaneMsg, ReportMsg, Side, Tag, TraceMsg};
use crate::error::{Error, Result};
use crate::free_energy::gradient::gradient_fd_range;
use crate::free_energy::symmetric::FeParams;
use crate::lattice::decomp::{box_runs, CartDecomposition, CartSubDomain,
                             SubDomain, AXIS_NAMES};
use crate::lattice::geometry::Geometry;
use crate::lattice::halo::{face_sites, pack_face, pack_x_plane,
                           pack_x_planes, unpack_face, unpack_x_plane,
                           unpack_x_planes};
use crate::lattice::stream_table::StreamTable;
use crate::lb::collision::{collide_lattice_range, collide_stream_range};
use crate::lb::engine::Observables;
use crate::lb::model::VelSet;
use crate::lb::moments::phi_from_g_range;
use crate::lb::multistep::HALO_PER_STEP;
use crate::lb::propagation::stream_range;
use crate::obs::trace::{PoolTrace, Span, SpanRecorder, TracePhase,
                        AXIS_NONE, SIDE_NONE};
use crate::targetdp::ilp;
use crate::targetdp::reduce::{reduce_sum_range, reduce_sum_sq_range};
use crate::targetdp::tlp::{threads_per_rank, Schedule, TlpPool};

/// A blocked [`Rank::wait`] / controller collect gives up after this long
/// — it converts the MPI-style deadlock of a lost neighbour into a
/// diagnosable error instead of a hung world.
const WAIT_TIMEOUT: Duration = Duration::from_secs(120);

/// Span-ring capacity of a tracing rank thread. A slab step records
/// ~20 rank-thread spans, so this holds a few thousand steps before the
/// ring starts overwriting the oldest (counted, never reallocated).
const RANK_SPAN_CAP: usize = 65_536;

/// Span-ring capacity per TLP worker (one span per worker per traced
/// kernel launch).
const WORKER_SPAN_CAP: usize = 16_384;

/// Arm tracing on a rank's pool + thread recorder when the config asks
/// for it: one [`PoolTrace`] ring per worker and a rank-thread
/// [`SpanRecorder`], all timestamped against the rank's epoch `t0`.
/// Returns the pool trace so the Shutdown path can drain the worker
/// rings. With `trace` off both stay disabled and every instrumentation
/// site costs one branch.
fn arm_trace(pool: &mut TlpPool, rank: &mut Rank, trace: bool,
             nthreads: usize, t0: Instant) -> Option<Arc<PoolTrace>> {
    if !trace {
        return None;
    }
    rank.trace = SpanRecorder::enabled(RANK_SPAN_CAP, t0);
    // worker spans only exist on threaded launches; a 1-thread pool runs
    // inline under the rank thread's own recorder
    if nthreads > 1 {
        let pt = PoolTrace::new(nthreads, t0, WORKER_SPAN_CAP);
        pool.set_trace(Arc::clone(&pt));
        Some(pt)
    } else {
        None
    }
}

/// Knobs for a decomposed run.
#[derive(Debug, Clone)]
pub struct CommsConfig {
    /// Number of slab ranks (1 = a single rank talking to itself across
    /// the periodic seam).
    pub ranks: usize,
    /// Overlap halo exchange with interior compute (`false` = the
    /// bulk-synchronous reference schedule; identical results).
    pub overlap: bool,
    /// Total TLP thread budget shared by all ranks (0 = machine width);
    /// each rank's pool gets `threads / ranks`, at least 1.
    pub threads: usize,
    /// Virtual vector length for the per-rank kernels (must be a
    /// supported VVL unless `scalar`).
    pub vvl: usize,
    /// Use the scalar collision kernel (host-scalar analog).
    pub scalar: bool,
    /// Chunk→thread assignment inside each rank's pool (the `[target]
    /// schedule` knob, honoured here exactly like the engine path).
    pub schedule: Schedule,
    /// Timesteps advanced per halo exchange (the communication-avoiding
    /// super-step depth). 1 = the classic per-step exchange; `k > 1`
    /// trades `HALO_PER_STEP * k` ghost planes per side and trapezoid
    /// overlap recompute for one ghost-block message per field per
    /// neighbour per `k` steps. 0 ("auto") must be resolved before the
    /// world is built — `Config::comms_config` does, via
    /// `comms_depth_plan`.
    pub depth: usize,
    /// Pin each rank's TLP workers to cores, rank-major round-robin
    /// (`sched_setaffinity` on Linux, a no-op elsewhere) — the `[target]
    /// pin_threads` knob.
    pub pin: bool,
    /// Rank grid `(px, py, pz)`. `[0, 0, 0]` ("unset") resolves to the
    /// x-slab `[ranks, 1, 1]` here; `Config::comms_config` may instead
    /// resolve it to a surface-minimizing factorization
    /// ([`CartDecomposition::auto_grid`]). The product must equal
    /// `ranks`. Non-slab grids take the staged per-axis face-exchange
    /// path and support `depth == 1` only.
    pub grid: [usize; 3],
    /// Record phase span timelines on every rank (and its TLP workers)
    /// and ship them to the driver as `Trace` frames at `Shutdown` —
    /// the `--trace-out`/`--report-json` machinery. Off by default;
    /// tracing only reads the clock around existing operations, so
    /// results are bit-identical either way.
    pub trace: bool,
    /// Deterministic fault injection: the armed rank returns a named
    /// error at the configured step and point, killing its thread (or,
    /// over sockets/hybrid, its OS process) exactly like a real crash —
    /// the `[fault] kill_rank`/`kill_step` knobs. `None` (the default)
    /// injects nothing and costs one branch per check site.
    pub fault: Option<FaultSpec>,
    /// How long a blocked rank wait / controller collect may stall
    /// before surfacing a lost-neighbour error (the `[fault]
    /// wait_timeout_s` knob; fault tests shrink it so a killed
    /// neighbour is diagnosed in seconds, not minutes).
    pub wait_timeout: Duration,
}

impl Default for CommsConfig {
    fn default() -> Self {
        CommsConfig {
            ranks: 1,
            overlap: true,
            threads: 1,
            vvl: 8,
            scalar: false,
            schedule: Schedule::Static,
            depth: 1,
            pin: false,
            grid: [0, 0, 0],
            trace: false,
            fault: None,
            wait_timeout: WAIT_TIMEOUT,
        }
    }
}

/// Where an injected fault fires within the armed rank's step loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    /// At the top of the step (super-step) covering `kill_step`, before
    /// any halo traffic for it moves.
    Step,
    /// Mid-exchange: after the rank has posted its first batch of halo
    /// sends for `kill_step` but before it waits on its neighbours —
    /// peers are left holding half a handshake.
    Mid,
    /// At the command barrier, once `kill_step` steps have completed —
    /// the rank dies parked between logging blocks, exactly where the
    /// driver's next broadcast will find the corpse.
    Barrier,
}

/// A deterministic injected fault: `rank` dies at `step` (counted from
/// the start of this world incarnation) at `point`. Carried in
/// [`CommsConfig::fault`] and TOML-round-tripped through the `[fault]`
/// section, so socket/hybrid rank processes arm it from the rendezvous
/// payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    /// The rank that dies.
    pub rank: usize,
    /// The step it dies at (0-based; [`FaultPoint::Barrier`] fires at
    /// the first barrier with at least this many steps completed).
    pub step: u64,
    /// Where within the step loop it dies.
    pub point: FaultPoint,
}

/// The named error an injected fault surfaces as. The text deliberately
/// avoids the transport-blame phrases (`timed out`, `hung up`) so the
/// session's root-cause filter reports the injected death, not the
/// secondary wreckage on the surviving ranks.
fn fault_error(rank: usize, step: u64, point: &str) -> Error {
    Error::Invalid(format!(
        "fault: injected kill of rank {rank} at step {step} ({point})"
    ))
}

/// Fire the injected fault if `rank` is armed for `point` within the
/// step range `[step, upto)` — the range is one step wide except for
/// super-steps, which cover `depth` steps per exchange.
fn fault_check(fault: &Option<FaultSpec>, rank: usize, point: FaultPoint,
               step: u64, upto: u64, label: &str) -> Result<()> {
    if let Some(f) = fault {
        if f.rank == rank && f.point == point && f.step >= step
            && f.step < upto
        {
            return Err(fault_error(rank, f.step, label));
        }
    }
    Ok(())
}

/// Per-rank timing/traffic summary, accumulated by the resident rank over
/// its whole life and reported at `Shutdown`.
#[derive(Debug, Clone)]
pub struct RankReport {
    /// Which rank this report describes.
    pub rank: usize,
    /// Owned (interior) sites — halo planes excluded.
    pub interior_sites: usize,
    /// Timesteps this rank completed over its lifetime.
    pub steps: u64,
    /// Wall time spent computing (total minus blocked-in-wait and idle).
    pub compute_s: f64,
    /// Wall time blocked waiting for halo planes.
    pub wait_s: f64,
    /// Wall time parked at the command barrier waiting for the driver
    /// (between logging blocks; excluded from [`RankReport::mlups`]).
    pub idle_s: f64,
    /// Halo-exchange traffic only — control/response frames (commands,
    /// partials, interiors, reports, traces) are not counted.
    pub bytes_sent: u64,
    /// Halo plane messages sent over this rank's lifetime.
    pub msgs_sent: u64,
    /// [`RankReport::bytes_sent`] split by lattice axis (0 = x, 1 = y,
    /// 2 = z). Sums to the total; undecomposed axes stay zero, and slab
    /// super-step blocks count on x.
    pub bytes_axis: [u64; 3],
    /// [`RankReport::msgs_sent`] split by lattice axis; sums to the
    /// total.
    pub msgs_axis: [u64; 3],
    /// Communication-avoiding super-steps executed (0 for depth-1
    /// worlds, which take the per-step exchange path).
    pub super_steps: u64,
    /// Halo bytes that stayed inside this rank's OS process
    /// ([`Transport::peer_is_intra`] links: in-process channels, a
    /// hybrid world's co-hosted neighbours, the 1-rank self-seam).
    /// `bytes_intra + bytes_inter == bytes_sent`.
    pub bytes_intra: u64,
    /// Halo bytes that crossed a socket to another process or host.
    pub bytes_inter: u64,
    /// Halo messages on intra-process links;
    /// `msgs_intra + msgs_inter == msgs_sent`.
    pub msgs_intra: u64,
    /// Halo messages that crossed a socket.
    pub msgs_inter: u64,
}

impl RankReport {
    /// Million (interior) lattice-site updates per second of rank wall
    /// time spent on the simulation proper.
    ///
    /// The wall clock here is **working time only**: `compute_s +
    /// wait_s`. Driver-side pauses ([`RankReport::idle_s`], the time
    /// parked at the command barrier between logging blocks) are
    /// excluded — so a rank's MLUPS describes how fast it steps when it
    /// is actually being stepped, not how busy the driver kept it. The
    /// pipeline's per-rank table prints idle as its own column for the
    /// same reason.
    pub fn mlups(&self) -> f64 {
        let wall = self.compute_s + self.wait_s;
        if wall <= 0.0 {
            return 0.0;
        }
        self.interior_sites as f64 * self.steps as f64 / wall / 1e6
    }

    /// Fraction of this rank's working wall time spent blocked on halo
    /// arrival: `wait_s / (compute_s + wait_s)`. Uses the same
    /// idle-excluded wall clock as [`RankReport::mlups`] — a rank left
    /// parked by a slow driver does not look communication-bound.
    pub fn wait_fraction(&self) -> f64 {
        let wall = self.compute_s + self.wait_s;
        if wall <= 0.0 { 0.0 } else { self.wait_s / wall }
    }
}

/// Whole-world summary of one decomposed run.
#[derive(Debug, Clone)]
pub struct WorldReport {
    /// One lifetime report per rank, rank order.
    pub ranks: Vec<RankReport>,
    /// Wall time of the whole run (session start to finish).
    pub seconds: f64,
    /// Whether the run overlapped halo exchange with interior compute.
    pub overlap: bool,
    /// Per-rank phase span timelines (rank order), shipped as `Trace`
    /// frames just before each rank's report. Empty vectors unless the
    /// run had [`CommsConfig::trace`] set.
    pub traces: Vec<Vec<Span>>,
}

impl WorldReport {
    /// Aggregate MLUPS: all interior site-updates over the run wall time.
    pub fn mlups(&self) -> f64 {
        if self.seconds <= 0.0 {
            return 0.0;
        }
        let updates: f64 = self
            .ranks
            .iter()
            .map(|r| r.interior_sites as f64 * r.steps as f64)
            .sum();
        updates / self.seconds / 1e6
    }

    /// Worst per-rank exchange wait.
    pub fn max_wait_s(&self) -> f64 {
        self.ranks.iter().map(|r| r.wait_s).fold(0.0, f64::max)
    }
}

/// One rank's communication endpoint: tag-matched, non-blocking sends and
/// blocking waits over a pluggable [`Transport`].
///
/// MPI mapping: [`Rank::isend`] is `MPI_Isend` (completes locally — the
/// transport owns the bytes as soon as it returns), [`Rank::wait`] is a
/// posted `MPI_Irecv` + `MPI_Wait` pair, and the internal `pending` map is
/// the unexpected-message queue an MPI progress engine keeps for frames
/// that arrive before their receive is posted. Commands from the session
/// controller share the same inbox: [`Rank::wait`] parks them for
/// [`Rank::wait_command`], and vice versa, so halo planes from a
/// neighbour that raced into the next block never block the command
/// barrier.
pub struct Rank {
    /// This rank's id (`MPI_Comm_rank`).
    pub rank: usize,
    /// Compute ranks in the world (`MPI_Comm_size`).
    pub nranks: usize,
    transport: Box<dyn Transport>,
    /// Halo frames that arrived while waiting for a different tag.
    pending: HashMap<Tag, Vec<f64>>,
    /// Ghost-block frames that arrived before their wait was posted,
    /// keyed by (super-step start, field, side); the value keeps the
    /// sender's plane depth for validation at the matching wait.
    pending_blocks: HashMap<(u64, FieldId, Side), (u32, Vec<f64>)>,
    /// Commands that arrived while waiting for a halo plane.
    cmds: VecDeque<Command>,
    /// Seconds spent blocked in [`Rank::wait`].
    pub wait_s: f64,
    /// Seconds spent parked in [`Rank::wait_command`].
    pub idle_s: f64,
    /// Halo bytes sent (wire frames, length prefix excluded) — the same
    /// count whichever transport carries them.
    pub bytes_sent: u64,
    /// Halo plane messages sent.
    pub msgs_sent: u64,
    /// [`Rank::bytes_sent`] split by the lattice axis the frame crossed.
    pub bytes_axis: [u64; 3],
    /// [`Rank::msgs_sent`] split by lattice axis.
    pub msgs_axis: [u64; 3],
    /// Communication-avoiding super-steps executed.
    pub super_steps: u64,
    /// Halo bytes on links that stay inside this OS process
    /// ([`Transport::peer_is_intra`]); the rest are
    /// [`Rank::bytes_inter`]. Together they sum to
    /// [`Rank::bytes_sent`].
    pub bytes_intra: u64,
    /// Halo bytes that crossed a socket to another process or host.
    pub bytes_inter: u64,
    /// Halo messages on intra-process links.
    pub msgs_intra: u64,
    /// Halo messages that crossed a socket.
    pub msgs_inter: u64,
    /// The rank thread's span recorder — disabled (free) unless the
    /// world was built with [`CommsConfig::trace`].
    pub trace: SpanRecorder,
    /// How long a blocked [`Rank::wait`]/[`Rank::wait_block`] may stall
    /// before surfacing a lost-neighbour error. Defaults to the
    /// conservative production value; the serve loops override it from
    /// [`CommsConfig::wait_timeout`] so fault-injection tests diagnose a
    /// killed neighbour in seconds.
    pub timeout: Duration,
}

impl Rank {
    /// Wrap a transport endpoint (any [`Transport`] — in-process channel
    /// or TCP socket) as a tag-matching rank endpoint.
    pub fn new(transport: Box<dyn Transport>) -> Rank {
        Rank {
            rank: transport.rank(),
            nranks: transport.nranks(),
            transport,
            pending: HashMap::new(),
            pending_blocks: HashMap::new(),
            cmds: VecDeque::new(),
            wait_s: 0.0,
            idle_s: 0.0,
            bytes_sent: 0,
            msgs_sent: 0,
            bytes_axis: [0; 3],
            msgs_axis: [0; 3],
            super_steps: 0,
            bytes_intra: 0,
            bytes_inter: 0,
            msgs_intra: 0,
            msgs_inter: 0,
            trace: SpanRecorder::disabled(),
            timeout: WAIT_TIMEOUT,
        }
    }

    /// Left (lower-x) neighbour, periodic.
    pub fn left(&self) -> usize {
        (self.rank + self.nranks - 1) % self.nranks
    }

    /// Right (higher-x) neighbour, periodic.
    pub fn right(&self) -> usize {
        (self.rank + 1) % self.nranks
    }

    /// The session controller's endpoint id.
    pub fn controller(&self) -> usize {
        self.nranks
    }

    /// Non-blocking tagged send of one packed plane (`MPI_Isend`). The
    /// wire frame is encoded straight from `data` — the only copy on the
    /// send path. Counted in the halo-traffic totals.
    pub fn isend(&mut self, dst: usize, tag: Tag, data: &[f64])
                 -> Result<()> {
        let nbytes = PlaneMsg::frame_len(data.len()) as u64;
        self.bytes_sent += nbytes;
        self.msgs_sent += 1;
        self.bytes_axis[tag.axis.index()] += nbytes;
        self.msgs_axis[tag.axis.index()] += 1;
        if self.transport.peer_is_intra(dst) {
            self.bytes_intra += nbytes;
            self.msgs_intra += 1;
        } else {
            self.bytes_inter += nbytes;
            self.msgs_inter += 1;
        }
        let t0 = self.trace.now();
        let r = self.transport.send_plane(dst, self.rank as u32, tag, data);
        self.trace.close(TracePhase::Send, tag.step,
                         tag.axis.index() as u8, tag.side as u8, t0);
        r
    }

    /// Non-blocking send of a batch of depth-tagged ghost blocks to one
    /// neighbour — the super-step analog of [`Rank::isend`]. `depth` is
    /// the number of x-planes each block carries; every block is its own
    /// wire frame, but the whole batch is handed to the transport at once
    /// ([`Transport::send_bytes_batch`]) so a socket can coalesce one
    /// super-step's traffic to `dst` into a single TCP write. Counted in
    /// the halo-traffic totals, one message per block.
    pub fn isend_blocks(&mut self, dst: usize, step: u64, depth: u32,
                        blocks: &[(FieldId, Side, &[f64])]) -> Result<()> {
        let mut frames = Vec::with_capacity(blocks.len());
        let intra = self.transport.peer_is_intra(dst);
        for (field, side, data) in blocks {
            let nbytes = PlaneBlockMsg::frame_len(data.len()) as u64;
            self.bytes_sent += nbytes;
            self.msgs_sent += 1;
            // ghost blocks are x-blocked (super-steps are slab-only)
            self.bytes_axis[0] += nbytes;
            self.msgs_axis[0] += 1;
            if intra {
                self.bytes_intra += nbytes;
                self.msgs_intra += 1;
            } else {
                self.bytes_inter += nbytes;
                self.msgs_inter += 1;
            }
            frames.push(PlaneBlockMsg::encode_from(
                self.rank as u32, step, *field, *side, Axis::X, depth,
                data));
        }
        let t0 = self.trace.now();
        let r = self.transport.send_bytes_batch(dst, frames);
        self.trace.close(TracePhase::Send, step, 0, SIDE_NONE, t0);
        r
    }

    /// Send a control-plane response to the session controller (not
    /// counted as halo traffic).
    pub fn send_response(&mut self, frame: &Frame) -> Result<()> {
        let dst = self.controller();
        self.transport.send_frame(dst, frame)
    }

    /// Park an out-of-order halo plane for its own wait.
    fn park(&mut self, msg: PlaneMsg) -> Result<()> {
        // a duplicate tag means the transport broke the
        // one-frame-per-tag protocol (e.g. a retransmitting socket);
        // overwriting silently would corrupt physics
        if self.pending.insert(msg.tag, msg.data).is_some() {
            return Err(Error::Invalid(format!(
                "comms: rank {} received a duplicate frame for {:?}",
                self.rank, msg.tag
            )));
        }
        Ok(())
    }

    /// Park an out-of-order ghost block for its own wait.
    fn park_block(&mut self, msg: PlaneBlockMsg) -> Result<()> {
        let PlaneBlockMsg { step, field, side, depth, data, .. } = msg;
        if self
            .pending_blocks
            .insert((step, field, side), (depth, data))
            .is_some()
        {
            return Err(Error::Invalid(format!(
                "comms: rank {} received a duplicate ghost block for \
                 step {step} {field:?} {side:?}",
                self.rank
            )));
        }
        Ok(())
    }

    /// Block until the plane tagged `tag` has arrived and return its
    /// payload (`MPI_Wait` on the matching receive). Frames for other
    /// tags encountered on the way are parked for their own waits;
    /// commands are queued for [`Rank::wait_command`].
    pub fn wait(&mut self, tag: Tag) -> Result<Vec<f64>> {
        let tr0 = self.trace.now();
        if let Some(data) = self.pending.remove(&tag) {
            self.trace.close(TracePhase::WaitRecv, tag.step,
                             tag.axis.index() as u8, tag.side as u8, tr0);
            return Ok(data);
        }
        let t0 = Instant::now();
        let data = loop {
            // error strings are built only in the failure arms — this
            // receive loop runs 6+ times per timestep on the halo path
            match self.transport.recv_timeout(self.timeout)? {
                Some(Frame::Plane(msg)) if msg.tag == tag => break msg.data,
                Some(Frame::Plane(msg)) => self.park(msg)?,
                Some(Frame::PlaneBlock(msg)) => self.park_block(msg)?,
                Some(Frame::Command(cmd)) => self.cmds.push_back(cmd),
                Some(other) => {
                    return Err(Error::Invalid(format!(
                        "comms: rank {} received a controller-bound frame \
                         {other:?}",
                        self.rank
                    )))
                }
                None => {
                    return Err(Error::Invalid(format!(
                        "comms: rank {} timed out after {:?} \
                         waiting for {tag:?} — neighbour or driver lost?",
                        self.rank, self.timeout
                    )))
                }
            }
        };
        self.wait_s += t0.elapsed().as_secs_f64();
        self.trace.close(TracePhase::WaitRecv, tag.step,
                         tag.axis.index() as u8, tag.side as u8, tr0);
        Ok(data)
    }

    /// Block until the ghost block keyed `(step, field, side)` has
    /// arrived and return its payload — [`Rank::wait`] for the
    /// super-step exchange. The sender's depth tag must match `depth`
    /// (in planes): a mismatch means the two ends disagree on the
    /// super-step schedule, which would silently corrupt physics.
    pub fn wait_block(&mut self, step: u64, field: FieldId, side: Side,
                      depth: u32) -> Result<Vec<f64>> {
        let check = |got: u32| -> Result<()> {
            if got != depth {
                return Err(Error::Invalid(format!(
                    "comms: ghost block for step {step} {field:?} \
                     {side:?} carries {got} planes, want {depth}"
                )));
            }
            Ok(())
        };
        let tr0 = self.trace.now();
        if let Some((d, data)) =
            self.pending_blocks.remove(&(step, field, side))
        {
            check(d)?;
            self.trace.close(TracePhase::WaitRecv, step, 0, side as u8,
                             tr0);
            return Ok(data);
        }
        let t0 = Instant::now();
        let data = loop {
            match self.transport.recv_timeout(self.timeout)? {
                Some(Frame::PlaneBlock(msg))
                    if msg.step == step
                        && msg.field == field
                        && msg.side == side =>
                {
                    check(msg.depth)?;
                    break msg.data;
                }
                Some(Frame::PlaneBlock(msg)) => self.park_block(msg)?,
                Some(Frame::Plane(msg)) => self.park(msg)?,
                Some(Frame::Command(cmd)) => self.cmds.push_back(cmd),
                Some(other) => {
                    return Err(Error::Invalid(format!(
                        "comms: rank {} received a controller-bound frame \
                         {other:?}",
                        self.rank
                    )))
                }
                None => {
                    return Err(Error::Invalid(format!(
                        "comms: rank {} timed out after {:?} \
                         waiting for the step-{step} {field:?} {side:?} \
                         ghost block — neighbour or driver lost?",
                        self.rank, self.timeout
                    )))
                }
            }
        };
        self.wait_s += t0.elapsed().as_secs_f64();
        self.trace.close(TracePhase::WaitRecv, step, 0, side as u8, tr0);
        Ok(data)
    }

    /// Block at the command barrier until the controller's next
    /// [`Command`] arrives. Halo planes from neighbours that already
    /// started the next block are parked for their own waits. Unlike
    /// [`Rank::wait`] this never times out — an idle driver (a long pause
    /// between logging blocks) is legitimate; a *vanished* driver always
    /// broadcasts `Shutdown` first (session `finish`/`Drop`), and a fully
    /// dead world surfaces as a transport disconnect.
    pub fn wait_command(&mut self) -> Result<Command> {
        if let Some(cmd) = self.cmds.pop_front() {
            return Ok(cmd);
        }
        // Idle spans carry step 0 — a driver pause sits between blocks
        // and belongs to no timestep
        let tr0 = self.trace.now();
        let t0 = Instant::now();
        let cmd = loop {
            match self.transport.recv_timeout(self.timeout)? {
                None => continue, // idle at the barrier, keep waiting
                Some(Frame::Command(cmd)) => break cmd,
                Some(Frame::Plane(msg)) => self.park(msg)?,
                Some(Frame::PlaneBlock(msg)) => self.park_block(msg)?,
                Some(other) => {
                    return Err(Error::Invalid(format!(
                        "comms: rank {} received a controller-bound frame \
                         {other:?}",
                        self.rank
                    )))
                }
            }
        };
        self.idle_s += t0.elapsed().as_secs_f64();
        self.trace.close(TracePhase::Idle, 0, AXIS_NONE, SIDE_NONE, tr0);
        Ok(cmd)
    }
}

/// The rank world (`MPI_COMM_WORLD`): a Cartesian decomposition plus the
/// run configuration, ready to spawn a resident session of concurrent
/// ranks.
#[derive(Debug, Clone)]
pub struct CommsWorld {
    /// The Cartesian decomposition the ranks own (one subdomain per
    /// rank; an x-slab world is the `(p, 1, 1)` grid).
    pub dec: CartDecomposition,
    /// Run knobs (rank count, grid, overlap, thread budget, VVL,
    /// schedule).
    pub cfg: CommsConfig,
}

impl CommsWorld {
    /// Build the world: validate the knobs and split `geom` over the
    /// rank grid (`cfg.grid`, defaulting to `cfg.ranks` x-slabs). Every
    /// decomposed axis is validated independently — errors name the
    /// axis that cannot carry the requested split or halo depth. No
    /// threads spawn until [`CommsWorld::session`].
    pub fn new(geom: Geometry, cfg: CommsConfig) -> Result<Self> {
        if !cfg.scalar && !ilp::is_supported(cfg.vvl) {
            return Err(Error::Invalid(format!(
                "comms: VVL {} unsupported (pick one of {:?}, or scalar)",
                cfg.vvl,
                ilp::SUPPORTED_VVL
            )));
        }
        if cfg.depth == 0 {
            return Err(Error::Invalid(
                "comms: super-step depth 0 (auto) must be resolved \
                 before the world is built — Config::comms_config does \
                 this via comms_depth_plan"
                    .into(),
            ));
        }
        let grid = if cfg.grid == [0, 0, 0] {
            [cfg.ranks, 1, 1]
        } else {
            cfg.grid
        };
        let nr: usize = grid.iter().product();
        if nr != cfg.ranks {
            return Err(Error::Invalid(format!(
                "comms: grid {}x{}x{} needs {nr} ranks, config says {}",
                grid[0], grid[1], grid[2], cfg.ranks
            )));
        }
        let dec = CartDecomposition::new(geom, grid)?;
        if cfg.depth > 1 {
            if !dec.is_slab() {
                return Err(Error::Invalid(format!(
                    "comms: super-step depth {} needs a slab grid \
                     (px,1,1) — the trapezoid recurrence is x-blocked — \
                     but the grid is {}x{}x{}",
                    cfg.depth, grid[0], grid[1], grid[2]
                )));
            }
            // every rank needs a full trapezoid foot: HALO_PER_STEP *
            // depth ghost planes per side, no wider than its own slab
            // (a deeper foot would reach past the nearest neighbour)
            let halo = HALO_PER_STEP * cfg.depth;
            let min_lxl =
                dec.domains.iter().map(|d| d.ext[0]).min().unwrap_or(0);
            if halo > min_lxl {
                return Err(Error::Invalid(format!(
                    "comms: super-step depth {} needs {halo} ghost \
                     planes per side but the narrowest slab has only \
                     {min_lxl} interior planes on the x axis",
                    cfg.depth
                )));
            }
        }
        Ok(CommsWorld { dec, cfg })
    }

    /// Spawn the resident rank session: one thread per subdomain, each
    /// copying its own box out of the initial `f0`/`g0` (first touch on
    /// the sweeping pool via [`TlpPool::zeros`]) and then parking at the
    /// command barrier. The state lives rank-local until an explicit
    /// [`CommsSession::gather`].
    pub fn session(&self, vs: &'static VelSet, p: &FeParams, f0: Vec<f64>,
                   g0: Vec<f64>) -> Result<CommsSession> {
        let n = self.dec.global.nsites();
        if f0.len() != vs.nvel * n || g0.len() != vs.nvel * n {
            return Err(Error::Invalid(format!(
                "comms: state is {}+{} doubles, want {} each",
                f0.len(),
                g0.len(),
                vs.nvel * n
            )));
        }
        let (transports, controller) =
            ChannelTransport::mesh_with_controller(self.cfg.ranks);
        let nthreads = threads_per_rank(self.cfg.threads, self.cfg.ranks);
        let f0 = Arc::new(f0);
        let g0 = Arc::new(g0);
        let p = *p;
        let started = Instant::now();
        let mut session = CommsSession {
            dec: self.dec.clone(),
            cfg: self.cfg.clone(),
            vs,
            controller: Box::new(controller),
            handles: Vec::with_capacity(self.cfg.ranks),
            retired: false,
            steps_done: 0,
            started,
            last_max_wait: None,
        };
        for (tr, d) in transports.into_iter().zip(&self.dec.domains) {
            let d = d.clone();
            let cfg = self.cfg.clone();
            let (f0, g0) = (Arc::clone(&f0), Arc::clone(&g0));
            let handle = std::thread::Builder::new()
                .name(format!("targetdp-rank{}", d.rank))
                .spawn(move || {
                    rank_main(d, vs, p, f0, g0, cfg, nthreads,
                              Box::new(tr))
                });
            match handle {
                Ok(h) => session.handles.push(h),
                Err(e) => {
                    // session Drop shuts down the already-spawned ranks
                    return Err(Error::Invalid(format!(
                        "comms: failed to spawn rank thread: {e}"
                    )));
                }
            }
        }
        Ok(session)
    }

    /// Adopt a session whose ranks live in **other processes**: the
    /// driver of a socket run holds only the controller endpoint (the
    /// analog of the one [`ChannelTransport::mesh_with_controller`]
    /// returns — a [`crate::comms::launcher::RankServer::rendezvous`]
    /// result), and each rank process runs [`serve_rank`] on its own
    /// endpoint. The command protocol is identical to an in-process
    /// session; the only difference is that [`CommsSession::finish`] has
    /// no rank threads to join — process lifetimes belong to the
    /// launcher (e.g. [`crate::comms::launcher::LocalRanks::wait`]).
    pub fn remote_session(&self, vs: &'static VelSet,
                          controller: Box<dyn Transport>)
                          -> Result<CommsSession> {
        let nranks = self.cfg.ranks;
        if controller.nranks() != nranks || controller.rank() != nranks {
            return Err(Error::Invalid(format!(
                "comms: controller endpoint {}/{} does not match a \
                 {nranks}-rank world",
                controller.rank(),
                controller.nranks(),
            )));
        }
        Ok(CommsSession {
            dec: self.dec.clone(),
            cfg: self.cfg.clone(),
            vs,
            controller,
            handles: Vec::new(),
            retired: false,
            steps_done: 0,
            started: Instant::now(),
            last_max_wait: None,
        })
    }

    /// One-shot convenience: session + single `Advance` + `Gather` +
    /// `Shutdown`. Advance the global state `nsteps` timesteps with one
    /// concurrent rank per slab and gather back into `f`/`g`. Blocks
    /// until every rank has finished.
    pub fn run(&self, vs: &'static VelSet, p: &FeParams, f: &mut [f64],
               g: &mut [f64], nsteps: u64) -> Result<WorldReport> {
        let mut session = self.session(vs, p, f.to_vec(), g.to_vec())?;
        session.advance(nsteps)?;
        session.gather(f, g)?;
        session.finish()
    }
}

/// Convenience: build a [`CommsWorld`] and run it once.
pub fn run_decomposed(geom: &Geometry, vs: &'static VelSet, p: &FeParams,
                      f: &mut [f64], g: &mut [f64], nsteps: u64,
                      cfg: &CommsConfig) -> Result<WorldReport> {
    CommsWorld::new(*geom, cfg.clone())?.run(vs, p, f, g, nsteps)
}

/// A resident rank world: the rank threads were spawned once and keep
/// their slab-local state across an arbitrary sequence of commands. The
/// driver thread holds the controller transport endpoint and steers the
/// ranks with [`CommsSession::advance`] / [`CommsSession::observables`] /
/// [`CommsSession::gather`]; [`CommsSession::finish`] retires the world
/// and returns the accumulated per-rank reports. Dropping an unfinished
/// session broadcasts `Shutdown` and joins the ranks best-effort.
///
/// Ranks may live in this process ([`CommsWorld::session`]) or in other
/// processes over TCP ([`CommsWorld::remote_session`]); the driver-side
/// API is identical.
///
/// # Examples
///
/// A two-rank in-process session driven through a full block lifecycle:
///
/// ```
/// use targetdp::comms::{CommsConfig, CommsWorld};
/// use targetdp::free_energy::symmetric::FeParams;
/// use targetdp::lattice::geometry::Geometry;
/// use targetdp::lb::init::init_spinodal;
/// use targetdp::lb::model::d2q9;
///
/// let vs = d2q9();
/// let geom = Geometry::new(6, 4, 1);
/// let n = geom.nsites();
/// let p = FeParams::default();
/// let mut f = vec![0.0; vs.nvel * n];
/// let mut g = vec![0.0; vs.nvel * n];
/// init_spinodal(vs, &p, &geom, &mut f, &mut g, 0.05, 7);
///
/// let world = CommsWorld::new(geom, CommsConfig {
///     ranks: 2,
///     ..CommsConfig::default()
/// })?;
/// let mut session = world.session(vs, &p, f.clone(), g.clone())?;
/// session.advance(2)?;                    // one logging block
/// let obs = session.observables()?;       // distributed reduction
/// assert!((obs.mass - n as f64).abs() < 1e-9, "mass is conserved");
/// session.gather(&mut f, &mut g)?;        // explicit state gather
/// let report = session.finish()?;         // retire + per-rank totals
/// assert!(report.ranks.iter().all(|r| r.steps == 2));
/// # Ok::<(), targetdp::Error>(())
/// ```
pub struct CommsSession {
    dec: CartDecomposition,
    cfg: CommsConfig,
    vs: &'static VelSet,
    /// The driver's endpoint — in-process channels for
    /// [`CommsWorld::session`], a TCP socket endpoint for
    /// [`CommsWorld::remote_session`]; the command protocol cannot tell
    /// the difference.
    controller: Box<dyn Transport>,
    /// Rank threads of an in-process session (empty for a remote one,
    /// whose rank processes are owned by the launcher).
    handles: Vec<JoinHandle<Result<()>>>,
    /// `Shutdown` has been delivered and the ranks accounted for —
    /// nothing left for `Drop` to clean up.
    retired: bool,
    steps_done: u64,
    started: Instant,
    /// Worst per-rank wait fraction seen by the most recent
    /// [`CommsSession::observables`] call — the driver's heartbeat signal
    /// (`None` until the first observables block completes).
    last_max_wait: Option<f64>,
}

/// Is this error a knock-on symptom (a neighbour of the real failure
/// timing out / finding a closed channel) rather than a root cause?
fn knock_on(e: &Error) -> bool {
    let msg = e.to_string();
    msg.contains("timed out") || msg.contains("hung up")
}

/// Prefer the first root-cause error; fall back to the first knock-on.
fn pick_root(errs: Vec<Error>) -> Option<Error> {
    let mut first_any = None;
    for e in errs {
        if !knock_on(&e) {
            return Some(e);
        }
        first_any.get_or_insert(e);
    }
    first_any
}

impl CommsSession {
    /// Compute ranks in the session's world.
    pub fn nranks(&self) -> usize {
        self.dec.domains.len()
    }

    /// Timesteps advanced so far (commands already issued).
    pub fn steps_done(&self) -> u64 {
        self.steps_done
    }

    fn broadcast(&mut self, cmd: Command) -> Result<()> {
        for r in 0..self.dec.domains.len() {
            self.controller.send_frame(r, &Frame::Command(cmd))?;
        }
        Ok(())
    }

    fn recv_from_ranks(&mut self, what: &str) -> Result<Frame> {
        let timeout = self.cfg.wait_timeout;
        match self.controller.recv_timeout(timeout)? {
            Some(frame) => Ok(frame),
            None => Err(Error::Invalid(format!(
                "comms: driver timed out after {timeout:?} waiting \
                 for {what} — rank lost?"
            ))),
        }
    }

    /// Best-effort `Shutdown` to every rank individually — unlike
    /// [`CommsSession::broadcast`] this must not short-circuit on the
    /// first dead rank, or its still-healthy peers would never be
    /// released from the command barrier and the join would hang.
    fn shutdown_all(&mut self) {
        for r in 0..self.dec.domains.len() {
            let _ = self
                .controller
                .send_frame(r, &Frame::Command(Command::Shutdown));
        }
    }

    /// A controller-side failure usually means a rank died: release any
    /// ranks parked at the command barrier, join the threads, and surface
    /// the root cause instead of the knock-on symptom.
    fn fail(&mut self, err: Error) -> Error {
        self.shutdown_all();
        self.retired = true;
        let mut errs = Vec::new();
        for h in std::mem::take(&mut self.handles) {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => errs.push(e),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        pick_root(errs).unwrap_or(err)
    }

    /// Advance every rank `steps` timesteps (one logging block). Returns
    /// as soon as the command is buffered — the next collecting call
    /// (observables / gather / finish) is the block barrier.
    pub fn advance(&mut self, steps: u64) -> Result<()> {
        if let Err(e) = self.broadcast(Command::Advance { steps }) {
            return Err(self.fail(e));
        }
        self.steps_done += steps;
        Ok(())
    }

    /// Distributed observable reduction: every rank reduces its own
    /// interior ([`crate::targetdp::reduce`]) and only the
    /// O(ranks)-sized partial sums travel — no global state gather.
    /// Partials are combined in rank order, so the result is
    /// deterministic; the summation order differs from a single global
    /// sweep (see [`Observables::from_sums`]).
    pub fn observables(&mut self) -> Result<Observables> {
        if let Err(e) = self.broadcast(Command::Observables) {
            return Err(self.fail(e));
        }
        let nranks = self.dec.domains.len();
        let mut partials: Vec<Option<PartialObs>> = vec![None; nranks];
        let mut got = 0;
        while got < nranks {
            let frame = match self.recv_from_ranks("observable partials") {
                Ok(f) => f,
                Err(e) => return Err(self.fail(e)),
            };
            let p = match frame {
                Frame::Partials(p) => p,
                other => {
                    return Err(self.fail(Error::Invalid(format!(
                        "comms: driver expected partials, got {other:?}"
                    ))))
                }
            };
            let r = p.src as usize;
            if r >= nranks || partials[r].is_some() {
                return Err(self.fail(Error::Invalid(format!(
                    "comms: duplicate or out-of-range partials from rank \
                     {r}"
                ))));
            }
            if p.steps != self.steps_done {
                return Err(self.fail(Error::Invalid(format!(
                    "comms: rank {r} reduced at step {} but the session \
                     is at {}",
                    p.steps, self.steps_done
                ))));
            }
            partials[r] = Some(p);
            got += 1;
        }
        self.last_max_wait = partials
            .iter()
            .flatten()
            .filter(|p| p.busy_s > 0.0)
            .map(|p| p.wait_s / p.busy_s)
            .fold(None, |acc: Option<f64>, w| {
                Some(acc.map_or(w, |a| a.max(w)))
            });
        let mut mass = 0.0;
        let mut momentum = [0.0f64; 3];
        let mut phi_total = 0.0;
        let mut phi_sq = 0.0;
        let mut sites = 0u64;
        for p in partials.iter().flatten() {
            mass += p.mass;
            for (m, pm) in momentum.iter_mut().zip(&p.momentum) {
                *m += pm;
            }
            phi_total += p.phi_total;
            phi_sq += p.phi_sq;
            sites += p.sites;
        }
        let n = self.dec.global.nsites();
        if sites != n as u64 {
            return Err(self.fail(Error::Invalid(format!(
                "comms: partials cover {sites} sites, lattice has {n}"
            ))));
        }
        Ok(Observables::from_sums(mass, momentum, phi_total, phi_sq, n))
    }

    /// Worst per-rank halo-wait fraction (`wait / (compute + wait)`,
    /// session lifetime so far) reported with the most recent
    /// [`CommsSession::observables`] block — the load-imbalance signal
    /// behind the driver's `--heartbeat` line. `None` before the first
    /// observables call.
    pub fn max_wait_fraction(&self) -> Option<f64> {
        self.last_max_wait
    }

    /// Collect one interior payload per (rank, expected field) and place
    /// each into its global buffer. Frames from different ranks arrive in
    /// any interleaving (ordering is only per sender), so every frame is
    /// routed by its (field, src) envelope rather than expected in
    /// sequence.
    fn collect_interiors(&mut self,
                         wanted: &mut [(InteriorField, usize, &mut [f64])])
                         -> Result<()> {
        let nranks = self.dec.domains.len();
        let mut seen = vec![false; wanted.len() * nranks];
        let mut got = 0;
        while got < wanted.len() * nranks {
            let frame = match self.recv_from_ranks("interior payloads") {
                Ok(f) => f,
                Err(e) => return Err(self.fail(e)),
            };
            let msg = match frame {
                Frame::Interior(m) => m,
                other => {
                    return Err(self.fail(Error::Invalid(format!(
                        "comms: driver expected interiors, got {other:?}"
                    ))))
                }
            };
            let slot = wanted
                .iter()
                .position(|(field, _, _)| *field == msg.field);
            let r = msg.src as usize;
            let (w, dup) = match slot {
                Some(w) if r < nranks => (w, seen[w * nranks + r]),
                _ => {
                    return Err(self.fail(Error::Invalid(format!(
                        "comms: unexpected {:?} interior from rank {r}",
                        msg.field
                    ))))
                }
            };
            if dup {
                return Err(self.fail(Error::Invalid(format!(
                    "comms: duplicate {:?} interior from rank {r}",
                    msg.field
                ))));
            }
            let d = &self.dec.domains[r];
            let want_len = wanted[w].1 * d.interior_sites();
            if msg.data.len() != want_len {
                return Err(self.fail(Error::Invalid(format!(
                    "comms: rank {r} interior is {} doubles, want \
                     {want_len}",
                    msg.data.len()
                ))));
            }
            let d = d.clone();
            d.place_interior(&msg.data, wanted[w].1, wanted[w].2);
            seen[w * nranks + r] = true;
            got += 1;
        }
        Ok(())
    }

    /// Gather the full distributed state into `f`/`g` (the explicit
    /// `MPI_Gather` of the final state or a VTK snapshot). The ranks keep
    /// running — gathering does not disturb their local state.
    pub fn gather(&mut self, f: &mut [f64], g: &mut [f64]) -> Result<()> {
        let n = self.dec.global.nsites();
        let nvel = self.vs.nvel;
        if f.len() != nvel * n || g.len() != nvel * n {
            return Err(Error::Invalid(format!(
                "comms: gather buffers are {}+{} doubles, want {} each",
                f.len(),
                g.len(),
                nvel * n
            )));
        }
        if let Err(e) = self.broadcast(Command::Gather) {
            return Err(self.fail(e));
        }
        self.collect_interiors(&mut [(InteriorField::F, nvel, f),
                                     (InteriorField::G, nvel, g)])
    }

    /// Cut a checkpoint snapshot: broadcast [`Command::Checkpoint`] and
    /// reassemble every rank's interior f/g into the global buffers —
    /// the same bit-exact payload path as [`CommsSession::gather`], under
    /// the dedicated checkpoint command. The ranks keep running; the
    /// driver serializes the result via
    /// [`crate::comms::checkpoint::Checkpoint`], decomposition-free, so
    /// the snapshot restores into any world shape.
    pub fn checkpoint(&mut self, f: &mut [f64], g: &mut [f64])
                      -> Result<()> {
        let n = self.dec.global.nsites();
        let nvel = self.vs.nvel;
        if f.len() != nvel * n || g.len() != nvel * n {
            return Err(Error::Invalid(format!(
                "comms: checkpoint buffers are {}+{} doubles, want {} \
                 each",
                f.len(),
                g.len(),
                nvel * n
            )));
        }
        if let Err(e) = self.broadcast(Command::Checkpoint) {
            return Err(self.fail(e));
        }
        self.collect_interiors(&mut [(InteriorField::F, nvel, f),
                                     (InteriorField::G, nvel, g)])
    }

    /// Gather the per-site phi field, computed by the resident ranks from
    /// their current `g` with their own pools and VVL (the decomposed
    /// analog of `LbEngine::phi_field` — only `nsites` doubles travel,
    /// not the `nvel`-component state).
    pub fn gather_phi(&mut self) -> Result<Vec<f64>> {
        if let Err(e) = self.broadcast(Command::GatherPhi) {
            return Err(self.fail(e));
        }
        let mut phi = vec![0.0; self.dec.global.nsites()];
        self.collect_interiors(&mut [(InteriorField::Phi, 1, &mut phi)])?;
        Ok(phi)
    }

    /// Retire the session: every rank reports its accumulated
    /// timing/traffic totals and exits; the threads are joined. Returns
    /// the whole-run [`WorldReport`].
    pub fn finish(mut self) -> Result<WorldReport> {
        if let Err(e) = self.broadcast(Command::Shutdown) {
            return Err(self.fail(e));
        }
        let nranks = self.dec.domains.len();
        let mut reports: Vec<Option<RankReport>> = vec![None; nranks];
        let mut traces: Vec<Vec<Span>> = vec![Vec::new(); nranks];
        let mut got = 0;
        while got < nranks {
            let frame = match self.recv_from_ranks("rank reports") {
                Ok(f) => f,
                Err(e) => return Err(self.fail(e)),
            };
            let r = match frame {
                Frame::Report(r) => r,
                // a tracing rank ships its span timeline immediately
                // before its report (per-sender frame order), so every
                // timeline is in hand by the time the last report lands
                Frame::Trace(t) => {
                    let idx = t.src as usize;
                    if idx >= nranks {
                        return Err(self.fail(Error::Invalid(format!(
                            "comms: trace from out-of-range rank {idx}"
                        ))));
                    }
                    traces[idx].extend(t.spans);
                    continue;
                }
                other => {
                    return Err(self.fail(Error::Invalid(format!(
                        "comms: driver expected reports, got {other:?}"
                    ))))
                }
            };
            let idx = r.src as usize;
            if idx >= nranks || reports[idx].is_some() {
                return Err(self.fail(Error::Invalid(format!(
                    "comms: duplicate or out-of-range report from rank \
                     {idx}"
                ))));
            }
            reports[idx] = Some(RankReport {
                rank: idx,
                interior_sites: r.interior_sites as usize,
                steps: r.steps,
                compute_s: r.compute_s,
                wait_s: r.wait_s,
                idle_s: r.idle_s,
                bytes_sent: r.bytes_sent,
                msgs_sent: r.msgs_sent,
                bytes_axis: r.bytes_axis,
                msgs_axis: r.msgs_axis,
                super_steps: r.super_steps,
                bytes_intra: r.bytes_intra,
                bytes_inter: r.bytes_inter,
                msgs_intra: r.msgs_intra,
                msgs_inter: r.msgs_inter,
            });
            got += 1;
        }
        // every rank has acknowledged the Shutdown with its report —
        // whatever happens below, Drop has nothing left to release
        self.retired = true;
        let mut errs = Vec::new();
        for h in std::mem::take(&mut self.handles) {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => errs.push(e),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        if let Some(e) = pick_root(errs) {
            return Err(e);
        }
        Ok(WorldReport {
            ranks: reports
                .into_iter()
                .map(|r| r.expect("all ranks reported"))
                .collect(),
            seconds: self.started.elapsed().as_secs_f64(),
            overlap: self.cfg.overlap,
            traces,
        })
    }
}

impl Drop for CommsSession {
    fn drop(&mut self) {
        if self.retired {
            return;
        }
        // release ranks parked at the command barrier — including remote
        // rank *processes*, which would otherwise idle there until their
        // transport noticed the dead driver; ignore errors — a dead
        // world is exactly what this path cleans up after
        self.shutdown_all();
        if std::thread::panicking() {
            // don't risk a join hang during unwind; detach instead
            self.handles.clear();
            return;
        }
        for h in std::mem::take(&mut self.handles) {
            let _ = h.join();
        }
    }
}

/// Per-rank working state: local SoA fields + streaming double buffers +
/// moment scratch + the plane pack buffer. Everything is allocated by the
/// rank's own pool ([`TlpPool::zeros`]) so first touch happens on the
/// thread(s) that sweep it, and it all stays resident for the whole
/// session.
struct RankState {
    f: Vec<f64>,
    g: Vec<f64>,
    f_tmp: Vec<f64>,
    g_tmp: Vec<f64>,
    phi: Vec<f64>,
    grad: Vec<f64>,
    lap: Vec<f64>,
    send_buf: Vec<f64>,
}

/// Serve one rank of a **remote** world: the rank-process entry point of
/// a socket run (`targetdp rank`, or an example re-entering itself as a
/// child). `transport` is this rank's endpoint from
/// [`crate::comms::launcher::connect_rank`]; `f0`/`g0` are the *global*
/// initial state, recomputed locally by the rank process (the
/// initialisers are deterministic, so every process derives bit-identical
/// state from the shipped config) — only this rank's slab is kept after
/// the scatter. Blocks until the driver's `Shutdown`, exactly like an
/// in-process rank thread: the same rank body is shared verbatim.
#[allow(clippy::too_many_arguments)]
pub fn serve_rank(d: CartSubDomain, vs: &'static VelSet, p: &FeParams,
                  f0: Vec<f64>, g0: Vec<f64>, cfg: &CommsConfig,
                  nthreads: usize, transport: Box<dyn Transport>)
                  -> Result<()> {
    if transport.rank() != d.rank {
        return Err(Error::Invalid(format!(
            "comms: transport endpoint {} serving subdomain of rank {}",
            transport.rank(),
            d.rank
        )));
    }
    if transport.nranks() != cfg.ranks {
        return Err(Error::Invalid(format!(
            "comms: transport world of {} ranks, config says {}",
            transport.nranks(),
            cfg.ranks
        )));
    }
    if f0.len() != g0.len() || f0.len() % vs.nvel != 0 {
        return Err(Error::Invalid(format!(
            "comms: initial state is {}+{} doubles, want equal multiples \
             of nvel {}",
            f0.len(),
            g0.len(),
            vs.nvel
        )));
    }
    rank_main(d, vs, *p, Arc::new(f0), Arc::new(g0), cfg.clone(),
              nthreads, transport)
}

/// Body of one resident rank thread (and of a remote rank process via
/// [`serve_rank`]): dispatch on the grid shape. A slab-shaped grid
/// `(px, 1, 1)` — including every depth-k super-step world — runs the
/// contiguous x-plane path; anything else runs the staged per-axis
/// face-exchange path.
#[allow(clippy::too_many_arguments)]
fn rank_main(d: CartSubDomain, vs: &'static VelSet, p: FeParams,
             f0: Arc<Vec<f64>>, g0: Arc<Vec<f64>>, cfg: CommsConfig,
             nthreads: usize, transport: Box<dyn Transport>) -> Result<()> {
    if d.is_slab() {
        slab_main(d.to_slab(), vs, p, f0, g0, cfg, nthreads, transport)
    } else {
        grid_main(d, vs, p, f0, g0, cfg, nthreads, transport)
    }
}

/// Serve loop of one slab rank: allocate + scatter once, then serve the
/// controller's command loop until `Shutdown`.
#[allow(clippy::too_many_arguments)]
fn slab_main(d: SubDomain, vs: &'static VelSet, p: FeParams,
             f0: Arc<Vec<f64>>, g0: Arc<Vec<f64>>, cfg: CommsConfig,
             nthreads: usize, transport: Box<dyn Transport>) -> Result<()> {
    let mut pool = if cfg.pin {
        // rank-major round-robin: rank r's workers land on CPUs
        // r*nthreads, r*nthreads+1, ... (mod machine width)
        TlpPool::new_pinned(nthreads, cfg.schedule, d.rank * nthreads)
    } else {
        TlpPool::new(nthreads, cfg.schedule)
    };
    let depth = cfg.depth.max(1);
    // depth 1 keeps the classic one-plane halo layout and the per-step
    // exchange path; a super-stepping rank extends its slab by
    // HALO_PER_STEP ghost planes per blocked step, like MultiStepPlan
    let halo = if depth > 1 { HALO_PER_STEP * depth } else { 1 };
    let local = d.local_with_halo(halo);
    let ln = local.nsites();
    let nvel = vs.nvel;
    let send_len = if depth > 1 {
        // the f and g ghost blocks bound for one neighbour live side by
        // side (split_at_mut) so both frames of a batched send exist at
        // the same time
        2 * nvel * halo * d.plane()
    } else {
        nvel * d.plane()
    };
    let mut st = RankState {
        f: pool.zeros(nvel * ln),
        g: pool.zeros(nvel * ln),
        f_tmp: pool.zeros(nvel * ln),
        g_tmp: pool.zeros(nvel * ln),
        phi: pool.zeros(ln),
        grad: pool.zeros(3 * ln),
        lap: pool.zeros(ln),
        send_buf: vec![0.0; send_len],
    };
    d.scatter_into_with_halo(&f0, nvel, &mut st.f, halo);
    d.scatter_into_with_halo(&g0, nvel, &mut st.g, halo);
    // the global initial state is only needed for the scatter — free our
    // share of it before the long residency
    drop(f0);
    drop(g0);
    let table = StreamTable::cached(vs, &local);
    let mut rank = Rank::new(transport);
    rank.timeout = cfg.wait_timeout;

    let t0 = Instant::now();
    // armed only after allocation + scatter: zeros/first-touch launches
    // never leave stray spans, and the epoch starts at the serve loop
    let pool_trace =
        arm_trace(&mut pool, &mut rank, cfg.trace, nthreads, t0);
    let pool = pool;
    let mut step: u64 = 0;
    loop {
        fault_check(&cfg.fault, d.rank, FaultPoint::Barrier, 0,
                    step.saturating_add(1), "command barrier")?;
        match rank.wait_command()? {
            Command::Advance { steps } => {
                if depth > 1 {
                    // super-steps: one ghost-block exchange per up-to-k
                    // timesteps; a short remainder shrinks the trapezoid
                    // (base offset), never the exchange count
                    let mut left = steps;
                    while left > 0 {
                        let sdepth = depth.min(left as usize);
                        fault_check(&cfg.fault, d.rank, FaultPoint::Step,
                                    step, step + sdepth as u64,
                                    "super-step start")?;
                        super_step(&d, vs, &p, &table, &mut st, &mut rank,
                                   step, sdepth, halo, &cfg, &pool)?;
                        step += sdepth as u64;
                        left -= sdepth as u64;
                    }
                } else {
                    for _ in 0..steps {
                        fault_check(&cfg.fault, d.rank, FaultPoint::Step,
                                    step, step + 1, "step start")?;
                        step_rank(&d, vs, &p, &table, &mut st, &mut rank,
                                  step, &cfg, &pool)?;
                        step += 1;
                    }
                }
            }
            Command::Observables => {
                pool.trace_context(TracePhase::Reduce, step);
                let tr0 = rank.trace.now();
                let mut partials = rank_partials(&d, vs, &mut st, &pool,
                                                 &cfg, step, halo);
                rank.trace.close(TracePhase::Reduce, step, AXIS_NONE,
                                 SIDE_NONE, tr0);
                // running wait-fraction snapshot for the driver's
                // heartbeat: busy = working wall (idle excluded)
                partials.wait_s = rank.wait_s;
                partials.busy_s =
                    (t0.elapsed().as_secs_f64() - rank.idle_s).max(0.0);
                rank.send_response(&Frame::Partials(partials))?;
            }
            // a checkpoint snapshot is the gather payload path under its
            // own command: ship the interior f then g, bit-exact
            Command::Gather | Command::Checkpoint => {
                let fi = d.interior_of_with_halo(&st.f, nvel, halo);
                rank.send_response(&Frame::Interior(InteriorMsg {
                    src: d.rank as u32,
                    field: InteriorField::F,
                    data: fi,
                }))?;
                let gi = d.interior_of_with_halo(&st.g, nvel, halo);
                rank.send_response(&Frame::Interior(InteriorMsg {
                    src: d.rank as u32,
                    field: InteriorField::G,
                    data: gi,
                }))?;
            }
            Command::GatherPhi => {
                // fresh phi from the current g, interior only, with this
                // rank's own pool/VVL (st.phi is a per-step scratch, so
                // overwriting it cannot perturb the next Advance)
                phi_from_g_range(vs, &st.g, &mut st.phi, ln,
                                 d.interior_with_halo(halo), &pool,
                                 cfg.vvl);
                let pi = d.interior_of_with_halo(&st.phi, 1, halo);
                rank.send_response(&Frame::Interior(InteriorMsg {
                    src: d.rank as u32,
                    field: InteriorField::Phi,
                    data: pi,
                }))?;
            }
            Command::Shutdown => {
                let wall = t0.elapsed().as_secs_f64();
                ship_trace(&mut rank, &pool_trace, d.rank as u32)?;
                let report = ReportMsg {
                    src: d.rank as u32,
                    interior_sites: (d.lxl * d.plane()) as u64,
                    steps: step,
                    compute_s: (wall - rank.wait_s - rank.idle_s).max(0.0),
                    wait_s: rank.wait_s,
                    idle_s: rank.idle_s,
                    bytes_sent: rank.bytes_sent,
                    msgs_sent: rank.msgs_sent,
                    bytes_axis: rank.bytes_axis,
                    msgs_axis: rank.msgs_axis,
                    super_steps: rank.super_steps,
                    bytes_intra: rank.bytes_intra,
                    bytes_inter: rank.bytes_inter,
                    msgs_intra: rank.msgs_intra,
                    msgs_inter: rank.msgs_inter,
                };
                rank.send_response(&Frame::Report(report))?;
                return Ok(());
            }
        }
    }
}

/// Ship a tracing rank's merged span timeline (rank thread first, then
/// the TLP worker rings) to the driver as a `Trace` frame — sent
/// immediately *before* the `Report`, so the per-sender ordering
/// guarantee means the driver's report collection sees it first. A
/// tracing-off rank sends nothing.
fn ship_trace(rank: &mut Rank, pool_trace: &Option<Arc<PoolTrace>>,
              src: u32) -> Result<()> {
    if !rank.trace.is_enabled() {
        return Ok(());
    }
    let mut spans = rank.trace.take_spans();
    if let Some(pt) = pool_trace {
        spans.extend(pt.drain());
    }
    rank.send_response(&Frame::Trace(TraceMsg { src, spans }))
}

/// Exact partial observable sums over this rank's interior, via the
/// deterministic [`crate::targetdp::reduce`] kernels (TLP × ILP, chunk
/// order fixed by (sites, vvl), independent of thread count).
fn rank_partials(d: &SubDomain, vs: &VelSet, st: &mut RankState,
                 pool: &TlpPool, cfg: &CommsConfig, step: u64,
                 halo: usize) -> PartialObs {
    let ln = d.local_with_halo(halo).nsites();
    let interior = d.interior_with_halo(halo);
    let vvl = cfg.vvl;
    let mut fsum = vec![0.0; vs.nvel];
    reduce_sum_range(&st.f, vs.nvel, ln, interior.clone(), pool, vvl,
                     &mut fsum);
    let mut gsum = vec![0.0; vs.nvel];
    reduce_sum_range(&st.g, vs.nvel, ln, interior.clone(), pool, vvl,
                     &mut gsum);
    let mass: f64 = fsum.iter().sum();
    let mut momentum = [0.0f64; 3];
    for (i, fi) in fsum.iter().enumerate() {
        for (m, c) in momentum.iter_mut().zip(&vs.cv[i]) {
            *m += c * fi;
        }
    }
    let phi_total: f64 = gsum.iter().sum();
    // phi is a per-step scratch — safe to recompute here from post-step g
    phi_from_g_range(vs, &st.g, &mut st.phi, ln, interior.clone(), pool,
                     vvl);
    let phi_sq = reduce_sum_sq_range(&st.phi, ln, interior, pool, vvl);
    PartialObs {
        src: d.rank as u32,
        steps: step,
        sites: (d.lxl * d.plane()) as u64,
        mass,
        momentum,
        phi_total,
        phi_sq,
        // timing snapshots are stamped by the serve loop, which owns
        // the rank endpoint and its epoch
        wait_s: 0.0,
        busy_s: 0.0,
    }
}

/// Precomputed exchange + sweep plan for one *decomposed* axis of a grid
/// rank: the face neighbours, the local face coordinates the staged
/// exchange packs and unpacks, and the range partitions the overlapped
/// schedule sweeps once the staged exchange has delivered this axis's
/// halos.
struct AxisPlan {
    /// Lattice axis (0 = x, 1 = y, 2 = z).
    axis: usize,
    /// The same axis as a wire tag.
    wire: Axis,
    /// Face neighbours, periodic in the rank grid (with a 2-wide axis
    /// both are the same peer — the `(side, axis)` tag disambiguates).
    lo_nbr: usize,
    hi_nbr: usize,
    /// Interior boundary planes sent (local coordinates along `axis`).
    send_lo: usize,
    send_hi: usize,
    /// Halo planes the receives land in.
    recv_lo: usize,
    recv_hi: usize,
    /// Sites per component in one face payload (spans the full
    /// halo-padded local extent of the other two axes).
    face: usize,
    /// Runs of the two halo-face boxes — where phi is recomputed after
    /// the staged moments exchange. Boxes of different axes overlap on
    /// edge sites; phi is a pure per-site moment, so the recompute is
    /// idempotent.
    halo_runs: Vec<Range<usize>>,
    /// Runs of this axis's slice of the interior shell: the two face
    /// slabs, clipped to deep on earlier decomposed axes and interior on
    /// later ones — across axes an exact disjoint partition of
    /// interior-minus-deep, so in-place collide touches every site
    /// exactly once.
    shell_runs: Vec<Range<usize>>,
}

/// Build the per-axis plans of a grid rank, in staged x → y → z order.
fn grid_plans(d: &CartSubDomain) -> Vec<AxisPlan> {
    let local = &d.local;
    let le = [local.lx, local.ly, local.lz];
    let axes: Vec<usize> = (0..3).filter(|&a| d.grid[a] > 1).collect();
    let mut plans = Vec::with_capacity(axes.len());
    for &a in &axes {
        let la = d.ext[a];
        let mut halo_runs = Vec::new();
        for p in [0, la + 1] {
            let mut lo = [0; 3];
            let mut hi = le;
            lo[a] = p;
            hi[a] = p + 1;
            halo_runs.extend(box_runs(local, lo, hi));
        }
        let mut shell_runs = Vec::new();
        // a one-plane extent has coinciding low and high faces
        let mut face_planes = vec![1];
        if la > 1 {
            face_planes.push(la);
        }
        for &p in &face_planes {
            let mut lo = [0; 3];
            let mut hi = le;
            for &b in &axes {
                if b < a {
                    lo[b] = 2;
                    hi[b] = d.ext[b];
                } else if b > a {
                    lo[b] = 1;
                    hi[b] = d.ext[b] + 1;
                }
            }
            lo[a] = p;
            hi[a] = p + 1;
            shell_runs.extend(box_runs(local, lo, hi));
        }
        plans.push(AxisPlan {
            axis: a,
            wire: Axis::from_index(a),
            lo_nbr: d.neighbor(a, false),
            hi_nbr: d.neighbor(a, true),
            send_lo: 1,
            send_hi: la,
            recv_lo: 0,
            recv_hi: la + 1,
            face: d.face_sites(a),
            halo_runs,
            shell_runs,
        });
    }
    plans
}

/// Runs of the deep box: the interior shrunk by one plane per side on
/// every decomposed axis — the sites whose whole (diagonal-including)
/// stencil stays interior, computable while faces are in flight. Empty
/// when an extent is too thin.
fn deep_runs(d: &CartSubDomain) -> Vec<Range<usize>> {
    let mut lo = [0; 3];
    let mut hi = [d.local.lx, d.local.ly, d.local.lz];
    for a in 0..3 {
        if d.grid[a] > 1 {
            lo[a] = 2;
            hi[a] = d.ext[a];
        }
    }
    box_runs(&d.local, lo, hi)
}

/// Validate a received face payload and scatter it into face plane `p`
/// of `axis` — the error names the axis.
fn unpack_face_checked(field: &mut [f64], nvel: usize, geom: &Geometry,
                       axis: usize, p: usize, data: &[f64]) -> Result<()> {
    let want = nvel * face_sites(geom, axis);
    if data.len() != want {
        return Err(Error::Invalid(format!(
            "comms: {} face payload is {} doubles, want {want}",
            AXIS_NAMES[axis],
            data.len()
        )));
    }
    unpack_face(field, nvel, geom, axis, p, data);
    Ok(())
}

/// Post one axis's two face sends of `field` (`MPI_Isend` x2): the low
/// interior face fills the low neighbour's HIGH halo and vice versa.
#[allow(clippy::too_many_arguments)]
fn isend_faces(rank: &mut Rank, data: &[f64], field: FieldId, phase: Phase,
               step: u64, nvel: usize, local: &Geometry, plan: &AxisPlan,
               buf: &mut [f64]) -> Result<()> {
    let tr0 = rank.trace.now();
    let nb = nvel * plan.face;
    pack_face(data, nvel, local, plan.axis, plan.send_lo, &mut buf[..nb]);
    let tag = |side| Tag { step, phase, field, side, axis: plan.wire };
    rank.isend(plan.lo_nbr, tag(Side::High), &buf[..nb])?;
    pack_face(data, nvel, local, plan.axis, plan.send_hi, &mut buf[..nb]);
    rank.isend(plan.hi_nbr, tag(Side::Low), &buf[..nb])?;
    rank.trace.close(TracePhase::Pack, step, plan.axis as u8, SIDE_NONE,
                     tr0);
    Ok(())
}

/// Complete one axis's two face receives of `field` (`MPI_Waitall`),
/// scattering the payloads into this rank's halo planes.
fn wait_faces(rank: &mut Rank, data: &mut [f64], field: FieldId,
              phase: Phase, step: u64, nvel: usize, local: &Geometry,
              plan: &AxisPlan) -> Result<()> {
    let tag = |side| Tag { step, phase, field, side, axis: plan.wire };
    // wait both, then unpack both: the two payloads land in disjoint
    // halo planes, so deferring the first unpack past the second wait
    // is bit-identical — and gives one clean Unpack span
    let lo = rank.wait(tag(Side::Low))?;
    let hi = rank.wait(tag(Side::High))?;
    let tr0 = rank.trace.now();
    unpack_face_checked(data, nvel, local, plan.axis, plan.recv_lo, &lo)?;
    unpack_face_checked(data, nvel, local, plan.axis, plan.recv_hi, &hi)?;
    rank.trace.close(TracePhase::Unpack, step, plan.axis as u8, SIDE_NONE,
                     tr0);
    Ok(())
}

/// Serve loop of one non-slab grid rank: allocate + scatter the local
/// box once, precompute the staged exchange plans and sweep partitions,
/// then serve the controller's command loop until `Shutdown` — the grid
/// analog of [`slab_main`].
#[allow(clippy::too_many_arguments)]
fn grid_main(d: CartSubDomain, vs: &'static VelSet, p: FeParams,
             f0: Arc<Vec<f64>>, g0: Arc<Vec<f64>>, cfg: CommsConfig,
             nthreads: usize, transport: Box<dyn Transport>) -> Result<()> {
    let mut pool = if cfg.pin {
        TlpPool::new_pinned(nthreads, cfg.schedule, d.rank * nthreads)
    } else {
        TlpPool::new(nthreads, cfg.schedule)
    };
    let local = d.local;
    let ln = local.nsites();
    let nvel = vs.nvel;
    // one face frame is packed at a time: size the buffer for the widest
    let send_len = (0..3)
        .filter(|&a| d.grid[a] > 1)
        .map(|a| nvel * d.face_sites(a))
        .max()
        .unwrap_or(0);
    let mut st = RankState {
        f: pool.zeros(nvel * ln),
        g: pool.zeros(nvel * ln),
        f_tmp: pool.zeros(nvel * ln),
        g_tmp: pool.zeros(nvel * ln),
        phi: pool.zeros(ln),
        grad: pool.zeros(3 * ln),
        lap: pool.zeros(ln),
        send_buf: vec![0.0; send_len],
    };
    d.scatter_into(&f0, nvel, &mut st.f);
    d.scatter_into(&g0, nvel, &mut st.g);
    drop(f0);
    drop(g0);
    let table = StreamTable::cached(vs, &local);
    let plans = grid_plans(&d);
    let interior = d.interior_runs();
    let deep = deep_runs(&d);
    let mut rank = Rank::new(transport);
    rank.timeout = cfg.wait_timeout;

    let t0 = Instant::now();
    let pool_trace =
        arm_trace(&mut pool, &mut rank, cfg.trace, nthreads, t0);
    let pool = pool;
    let mut step: u64 = 0;
    loop {
        fault_check(&cfg.fault, d.rank, FaultPoint::Barrier, 0,
                    step.saturating_add(1), "command barrier")?;
        match rank.wait_command()? {
            Command::Advance { steps } => {
                for _ in 0..steps {
                    fault_check(&cfg.fault, d.rank, FaultPoint::Step,
                                step, step + 1, "step start")?;
                    step_rank_grid(&d, vs, &p, &table, &plans, &interior,
                                   &deep, &mut st, &mut rank, step, &cfg,
                                   &pool)?;
                    step += 1;
                }
            }
            Command::Observables => {
                pool.trace_context(TracePhase::Reduce, step);
                let tr0 = rank.trace.now();
                let mut partials = grid_partials(&d, vs, &mut st,
                                                 &interior, &pool, &cfg,
                                                 step);
                rank.trace.close(TracePhase::Reduce, step, AXIS_NONE,
                                 SIDE_NONE, tr0);
                partials.wait_s = rank.wait_s;
                partials.busy_s =
                    (t0.elapsed().as_secs_f64() - rank.idle_s).max(0.0);
                rank.send_response(&Frame::Partials(partials))?;
            }
            // a checkpoint snapshot is the gather payload path under its
            // own command: ship the interior f then g, bit-exact
            Command::Gather | Command::Checkpoint => {
                rank.send_response(&Frame::Interior(InteriorMsg {
                    src: d.rank as u32,
                    field: InteriorField::F,
                    data: d.interior_of(&st.f, nvel),
                }))?;
                rank.send_response(&Frame::Interior(InteriorMsg {
                    src: d.rank as u32,
                    field: InteriorField::G,
                    data: d.interior_of(&st.g, nvel),
                }))?;
            }
            Command::GatherPhi => {
                // fresh phi from the current g, interior only (st.phi is
                // a per-step scratch, so overwriting it cannot perturb
                // the next Advance)
                for r in &interior {
                    phi_from_g_range(vs, &st.g, &mut st.phi, ln, r.clone(),
                                     &pool, cfg.vvl);
                }
                rank.send_response(&Frame::Interior(InteriorMsg {
                    src: d.rank as u32,
                    field: InteriorField::Phi,
                    data: d.interior_of(&st.phi, 1),
                }))?;
            }
            Command::Shutdown => {
                let wall = t0.elapsed().as_secs_f64();
                ship_trace(&mut rank, &pool_trace, d.rank as u32)?;
                let report = ReportMsg {
                    src: d.rank as u32,
                    interior_sites: d.interior_sites() as u64,
                    steps: step,
                    compute_s: (wall - rank.wait_s - rank.idle_s).max(0.0),
                    wait_s: rank.wait_s,
                    idle_s: rank.idle_s,
                    bytes_sent: rank.bytes_sent,
                    msgs_sent: rank.msgs_sent,
                    bytes_axis: rank.bytes_axis,
                    msgs_axis: rank.msgs_axis,
                    super_steps: rank.super_steps,
                    bytes_intra: rank.bytes_intra,
                    bytes_inter: rank.bytes_inter,
                    msgs_intra: rank.msgs_intra,
                    msgs_inter: rank.msgs_inter,
                };
                rank.send_response(&Frame::Report(report))?;
                return Ok(());
            }
        }
    }
}

/// One binary-fluid LB timestep on this rank's grid box.
///
/// Schedule (overlapped mode; bulk-sync completes the whole staged
/// exchange before each compute block instead):
///
/// ```text
/// isend g faces, first axis        — moments stage 1    (MPI_Isend x2)
/// phi   interior; grad + collide deep box               ┐ overlapped
///                                                       ┘ with flight
/// wait  stage 1; then per later axis: isend + wait      (staged x→y→z)
/// phi   halo faces; grad + collide the interior shell
/// isend f,g faces, first axis      — stream stage 1     (MPI_Isend x4)
/// stream deep box destinations                          ─ overlapped
/// wait  stage 1; then per later axis: isend + wait
/// stream shell destinations; swap double buffers
/// ```
///
/// Stages are strictly serialized (wait axis a before packing axis
/// a + 1): a later face spans the earlier axes' freshly filled halos,
/// which is what carries edge/corner data without diagonal messages.
/// Every per-site update is position-independent, so the partitions
/// produce bitwise the values of the bulk schedule, the slab world, and
/// a single-domain sweep.
#[allow(clippy::too_many_arguments)]
fn step_rank_grid(d: &CartSubDomain, vs: &VelSet, p: &FeParams,
                  table: &StreamTable, plans: &[AxisPlan],
                  interior: &[Range<usize>], deep: &[Range<usize>],
                  st: &mut RankState, rank: &mut Rank, step: u64,
                  cfg: &CommsConfig, pool: &TlpPool) -> Result<()> {
    let (vvl, scalar) = (cfg.vvl, cfg.scalar);
    let local = &d.local;
    let ln = local.nsites();
    let nvel = vs.nvel;
    let (first, rest) =
        plans.split_first().expect("grid rank has a decomposed axis");

    // ---- exchange 1: post-stream g faces (moments halo), staged ----
    isend_faces(rank, &st.g, FieldId::G, Phase::Moments, step, nvel,
                local, first, &mut st.send_buf)?;
    // mid-exchange fault point: the first stage's faces are posted, the
    // neighbours are owed the rest of the handshake
    fault_check(&cfg.fault, d.rank, FaultPoint::Mid, step, step + 1,
                "mid-step, after the first face sends")?;
    if cfg.overlap {
        // the interior needs no halo for phi, the deep box none for the
        // gradient — compute both while stage 1 is in flight; collide
        // mutates only deep sites, which no face plane intersects, so
        // the later stages still pack pre-collision g
        pool.trace_context(TracePhase::Interior, step);
        let tr0 = rank.trace.now();
        for r in interior {
            phi_from_g_range(vs, &st.g, &mut st.phi, ln, r.clone(), pool,
                             vvl);
        }
        rank.trace.close(TracePhase::Interior, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        pool.trace_context(TracePhase::Gradient, step);
        let tr0 = rank.trace.now();
        for r in deep {
            gradient_fd_range(local, &st.phi, &mut st.grad, &mut st.lap,
                              r.clone(), pool, vvl);
        }
        rank.trace.close(TracePhase::Gradient, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        pool.trace_context(TracePhase::Collide, step);
        let tr0 = rank.trace.now();
        for r in deep {
            collide_lattice_range(vs, p, &mut st.f, &mut st.g, &st.grad,
                                  &st.lap, ln, r.clone(), pool, vvl,
                                  scalar);
        }
        rank.trace.close(TracePhase::Collide, step, AXIS_NONE, SIDE_NONE,
                         tr0);
    }
    wait_faces(rank, &mut st.g, FieldId::G, Phase::Moments, step, nvel,
               local, first)?;
    for plan in rest {
        isend_faces(rank, &st.g, FieldId::G, Phase::Moments, step, nvel,
                    local, plan, &mut st.send_buf)?;
        wait_faces(rank, &mut st.g, FieldId::G, Phase::Moments, step,
                   nvel, local, plan)?;
    }
    if cfg.overlap {
        // complete the moments on the freshly filled halos: phi on the
        // halo faces, then the gradient + collision over the shell — the
        // shell slices union with the deep box to exactly the interior,
        // each site collided once
        pool.trace_context(TracePhase::EdgeRim, step);
        let tr0 = rank.trace.now();
        for plan in plans {
            for r in &plan.halo_runs {
                phi_from_g_range(vs, &st.g, &mut st.phi, ln, r.clone(),
                                 pool, vvl);
            }
        }
        for plan in plans {
            for r in &plan.shell_runs {
                gradient_fd_range(local, &st.phi, &mut st.grad,
                                  &mut st.lap, r.clone(), pool, vvl);
            }
            for r in &plan.shell_runs {
                collide_lattice_range(vs, p, &mut st.f, &mut st.g,
                                      &st.grad, &st.lap, ln, r.clone(),
                                      pool, vvl, scalar);
            }
        }
        rank.trace.close(TracePhase::EdgeRim, step, AXIS_NONE, SIDE_NONE,
                         tr0);
    } else {
        // bulk-sync: halos are all fresh — one full-array phi sweep,
        // then the whole interior in one pass
        pool.trace_context(TracePhase::Interior, step);
        let tr0 = rank.trace.now();
        phi_from_g_range(vs, &st.g, &mut st.phi, ln, 0..ln, pool, vvl);
        rank.trace.close(TracePhase::Interior, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        pool.trace_context(TracePhase::Gradient, step);
        let tr0 = rank.trace.now();
        for r in interior {
            gradient_fd_range(local, &st.phi, &mut st.grad, &mut st.lap,
                              r.clone(), pool, vvl);
        }
        rank.trace.close(TracePhase::Gradient, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        pool.trace_context(TracePhase::Collide, step);
        let tr0 = rank.trace.now();
        for r in interior {
            collide_lattice_range(vs, p, &mut st.f, &mut st.g, &st.grad,
                                  &st.lap, ln, r.clone(), pool, vvl,
                                  scalar);
        }
        rank.trace.close(TracePhase::Collide, step, AXIS_NONE, SIDE_NONE,
                         tr0);
    }

    // ---- exchange 2: post-collision f,g faces (stream halo), staged ----
    isend_faces(rank, &st.f, FieldId::F, Phase::Stream, step, nvel, local,
                first, &mut st.send_buf)?;
    isend_faces(rank, &st.g, FieldId::G, Phase::Stream, step, nvel, local,
                first, &mut st.send_buf)?;
    if cfg.overlap {
        // deep destinations pull only interior sources (streaming writes
        // the _tmp buffers, so the in-flight packs stay untouched)
        pool.trace_context(TracePhase::Stream, step);
        let tr0 = rank.trace.now();
        for r in deep {
            stream_range(vs, table, &st.f, &mut st.f_tmp, r.clone(), pool,
                         vvl);
        }
        for r in deep {
            stream_range(vs, table, &st.g, &mut st.g_tmp, r.clone(), pool,
                         vvl);
        }
        rank.trace.close(TracePhase::Stream, step, AXIS_NONE, SIDE_NONE,
                         tr0);
    }
    wait_faces(rank, &mut st.f, FieldId::F, Phase::Stream, step, nvel,
               local, first)?;
    wait_faces(rank, &mut st.g, FieldId::G, Phase::Stream, step, nvel,
               local, first)?;
    for plan in rest {
        isend_faces(rank, &st.f, FieldId::F, Phase::Stream, step, nvel,
                    local, plan, &mut st.send_buf)?;
        isend_faces(rank, &st.g, FieldId::G, Phase::Stream, step, nvel,
                    local, plan, &mut st.send_buf)?;
        wait_faces(rank, &mut st.f, FieldId::F, Phase::Stream, step, nvel,
                   local, plan)?;
        wait_faces(rank, &mut st.g, FieldId::G, Phase::Stream, step, nvel,
                   local, plan)?;
    }
    if cfg.overlap {
        pool.trace_context(TracePhase::EdgeRim, step);
        let tr0 = rank.trace.now();
        for plan in plans {
            for r in &plan.shell_runs {
                stream_range(vs, table, &st.f, &mut st.f_tmp, r.clone(),
                             pool, vvl);
            }
            for r in &plan.shell_runs {
                stream_range(vs, table, &st.g, &mut st.g_tmp, r.clone(),
                             pool, vvl);
            }
        }
        rank.trace.close(TracePhase::EdgeRim, step, AXIS_NONE, SIDE_NONE,
                         tr0);
    } else {
        pool.trace_context(TracePhase::Stream, step);
        let tr0 = rank.trace.now();
        for r in interior {
            stream_range(vs, table, &st.f, &mut st.f_tmp, r.clone(), pool,
                         vvl);
        }
        for r in interior {
            stream_range(vs, table, &st.g, &mut st.g_tmp, r.clone(), pool,
                         vvl);
        }
        rank.trace.close(TracePhase::Stream, step, AXIS_NONE, SIDE_NONE,
                         tr0);
    }
    std::mem::swap(&mut st.f, &mut st.f_tmp);
    std::mem::swap(&mut st.g, &mut st.g_tmp);
    Ok(())
}

/// Exact partial observable sums over a grid rank's interior box — the
/// grid analog of [`rank_partials`]: the deterministic reduce kernels
/// run per interior run (runs visited in a fixed order, so the combined
/// sums are reproducible at any thread count).
fn grid_partials(d: &CartSubDomain, vs: &VelSet, st: &mut RankState,
                 interior: &[Range<usize>], pool: &TlpPool,
                 cfg: &CommsConfig, step: u64) -> PartialObs {
    let ln = d.local.nsites();
    let vvl = cfg.vvl;
    let mut fsum = vec![0.0; vs.nvel];
    let mut gsum = vec![0.0; vs.nvel];
    let mut scratch = vec![0.0; vs.nvel];
    let mut phi_sq = 0.0;
    for r in interior {
        reduce_sum_range(&st.f, vs.nvel, ln, r.clone(), pool, vvl,
                         &mut scratch);
        for (acc, s) in fsum.iter_mut().zip(&scratch) {
            *acc += s;
        }
        reduce_sum_range(&st.g, vs.nvel, ln, r.clone(), pool, vvl,
                         &mut scratch);
        for (acc, s) in gsum.iter_mut().zip(&scratch) {
            *acc += s;
        }
        // phi is a per-step scratch — safe to recompute from post-step g
        phi_from_g_range(vs, &st.g, &mut st.phi, ln, r.clone(), pool, vvl);
        phi_sq += reduce_sum_sq_range(&st.phi, ln, r.clone(), pool, vvl);
    }
    let mass: f64 = fsum.iter().sum();
    let mut momentum = [0.0f64; 3];
    for (i, fi) in fsum.iter().enumerate() {
        for (m, c) in momentum.iter_mut().zip(&vs.cv[i]) {
            *m += c * fi;
        }
    }
    let phi_total: f64 = gsum.iter().sum();
    PartialObs {
        src: d.rank as u32,
        steps: step,
        sites: d.interior_sites() as u64,
        mass,
        momentum,
        phi_total,
        phi_sq,
        // stamped by the serve loop (see the slab Observables arm)
        wait_s: 0.0,
        busy_s: 0.0,
    }
}

/// Validate a received plane payload and scatter it into halo plane `p`.
fn unpack_checked(field: &mut [f64], nvel: usize, ln: usize, plane: usize,
                  p: usize, data: &[f64]) -> Result<()> {
    if data.len() != nvel * plane {
        return Err(Error::Invalid(format!(
            "comms: halo payload is {} doubles, want {}",
            data.len(),
            nvel * plane
        )));
    }
    unpack_x_plane(field, nvel, ln, plane, p, data);
    Ok(())
}

/// Validate a received depth-tagged ghost block and scatter it into the
/// `np` ghost planes starting at local plane `p0`.
fn unpack_block_checked(field: &mut [f64], nvel: usize, ln: usize,
                        plane: usize, p0: usize, np: usize, data: &[f64])
                        -> Result<()> {
    if data.len() != nvel * np * plane {
        return Err(Error::Invalid(format!(
            "comms: ghost block is {} doubles, want {}",
            data.len(),
            nvel * np * plane
        )));
    }
    unpack_x_planes(field, nvel, ln, plane, p0, np, data);
    Ok(())
}

/// One trapezoid-blocked timestep inside a super-step: the
/// [`crate::lb::multistep::MultiStepPlan`] j-recurrence shifted inward
/// by `base` ghost planes (`base > 0` when a remainder super-step runs
/// shallower than the allocated halo). Reads the window left fully valid
/// by step `j - 1` and leaves `[base + 2j, lloc - base - 2j)` advanced;
/// after the last step exactly the interior planes remain.
#[allow(clippy::too_many_arguments)]
fn blocked_step(local: &Geometry, vs: &VelSet, p: &FeParams,
                table: &StreamTable, st: &mut RankState, base: usize,
                j: usize, cfg: &CommsConfig, pool: &TlpPool,
                trace: &mut SpanRecorder, step: u64) {
    let (vvl, scalar) = (cfg.vvl, cfg.scalar);
    let plane = local.ly * local.lz;
    let lloc = local.lx;
    let ln = local.nsites();
    let c0 = base + 2 * j - 1;
    let c1 = (lloc - base) - (2 * j - 1);
    let p0 = base + 2 * j - 2;
    let p1 = (lloc - base) - (2 * j - 2);
    pool.trace_context(TracePhase::Interior, step);
    let tr0 = trace.now();
    phi_from_g_range(vs, &st.g, &mut st.phi, ln, p0 * plane..p1 * plane,
                     pool, vvl);
    trace.close(TracePhase::Interior, step, AXIS_NONE, SIDE_NONE, tr0);
    pool.trace_context(TracePhase::Gradient, step);
    let tr0 = trace.now();
    gradient_fd_range(local, &st.phi, &mut st.grad, &mut st.lap,
                      c0 * plane..c1 * plane, pool, vvl);
    trace.close(TracePhase::Gradient, step, AXIS_NONE, SIDE_NONE, tr0);
    pool.trace_context(TracePhase::Collide, step);
    let tr0 = trace.now();
    collide_stream_range(vs, p, &st.f, &st.g, &mut st.f_tmp,
                         &mut st.g_tmp, &st.grad, &st.lap, table, ln,
                         c0 * plane..c1 * plane, pool, vvl, scalar);
    trace.close(TracePhase::Collide, step, AXIS_NONE, SIDE_NONE, tr0);
    std::mem::swap(&mut st.f, &mut st.f_tmp);
    std::mem::swap(&mut st.g, &mut st.g_tmp);
}

/// One communication-avoiding super-step: advance this rank's slab
/// `sdepth` fused timesteps behind a single depth-tagged ghost-block
/// exchange.
///
/// Schedule (overlapped mode; bulk-sync waits up front instead):
///
/// ```text
/// isend f,g ghost blocks (2k planes each) to both neighbours
///                                   — 2 batched sends, 4 block messages
/// step 1: phi + grad + collide-stream over the interior   ┐ overlapped
///         (needs no ghost data)                           ┘ with flight
/// wait   4 ghost blocks; finish step 1's rim on the fresh ghosts; swap
/// steps 2..=k: full trapezoid sweeps, window shrinking two planes per
///              side per step — purely local, no communication
/// ```
///
/// The sends pack interior planes of the *pre-step* `f`/`g` (step 1
/// writes only the `_tmp` buffers until its swap), so the blocks always
/// carry time-t state. A remainder super-step (`sdepth < depth`, when
/// `k` does not divide the block's steps) starts the trapezoid `base`
/// planes inward and exchanges proportionally thinner blocks — the
/// outer ghost planes hold stale garbage but are never read. Every
/// per-site update is placement-independent, so the result is
/// bit-identical to `sdepth` per-step exchanges.
#[allow(clippy::too_many_arguments)]
fn super_step(d: &SubDomain, vs: &VelSet, p: &FeParams,
              table: &StreamTable, st: &mut RankState, rank: &mut Rank,
              step: u64, sdepth: usize, halo: usize, cfg: &CommsConfig,
              pool: &TlpPool) -> Result<()> {
    let (vvl, scalar) = (cfg.vvl, cfg.scalar);
    let plane = d.plane();
    let lxl = d.lxl;
    let local = d.local_with_halo(halo);
    let lloc = local.lx;
    let ln = local.nsites();
    let nvel = vs.nvel;
    // ghost planes actually consumed this super-step, and where the
    // trapezoid foot starts (base = 0 at full depth)
    let s2 = HALO_PER_STEP * sdepth;
    let base = halo - s2;
    let nb = nvel * s2 * plane;

    rank.super_steps += 1;

    // ---- post the ghost-block sends: my lowest interior planes fill
    // the left neighbour's HIGH ghost region and vice versa, for both
    // fields, one batched send per neighbour ----
    {
        let tr0 = rank.trace.now();
        let (f_half, g_half) =
            st.send_buf.split_at_mut(nvel * halo * plane);
        pack_x_planes(&st.f, nvel, ln, plane, halo, s2,
                      &mut f_half[..nb]);
        pack_x_planes(&st.g, nvel, ln, plane, halo, s2,
                      &mut g_half[..nb]);
        rank.isend_blocks(rank.left(), step, s2 as u32,
                          &[(FieldId::F, Side::High, &f_half[..nb]),
                            (FieldId::G, Side::High, &g_half[..nb])])?;
        pack_x_planes(&st.f, nvel, ln, plane, halo + lxl - s2, s2,
                      &mut f_half[..nb]);
        pack_x_planes(&st.g, nvel, ln, plane, halo + lxl - s2, s2,
                      &mut g_half[..nb]);
        rank.isend_blocks(rank.right(), step, s2 as u32,
                          &[(FieldId::F, Side::Low, &f_half[..nb]),
                            (FieldId::G, Side::Low, &g_half[..nb])])?;
        rank.trace.close(TracePhase::Pack, step, 0, SIDE_NONE, tr0);
    }

    // mid-super-step fault point: both ghost-block batches are posted,
    // the neighbours are owed nothing more but this rank never collects
    fault_check(&cfg.fault, d.rank, FaultPoint::Mid, step,
                step + sdepth as u64,
                "mid-super-step, after the ghost-block sends")?;

    let wait_ghost_blocks =
        |rank: &mut Rank, st: &mut RankState| -> Result<()> {
            let f_lo =
                rank.wait_block(step, FieldId::F, Side::Low, s2 as u32)?;
            let f_hi =
                rank.wait_block(step, FieldId::F, Side::High, s2 as u32)?;
            let g_lo =
                rank.wait_block(step, FieldId::G, Side::Low, s2 as u32)?;
            let g_hi =
                rank.wait_block(step, FieldId::G, Side::High, s2 as u32)?;
            let tr0 = rank.trace.now();
            unpack_block_checked(&mut st.f, nvel, ln, plane, base, s2,
                                 &f_lo)?;
            unpack_block_checked(&mut st.f, nvel, ln, plane, halo + lxl,
                                 s2, &f_hi)?;
            unpack_block_checked(&mut st.g, nvel, ln, plane, base, s2,
                                 &g_lo)?;
            unpack_block_checked(&mut st.g, nvel, ln, plane, halo + lxl,
                                 s2, &g_hi)?;
            rank.trace.close(TracePhase::Unpack, step, 0, SIDE_NONE, tr0);
            Ok(())
        };

    if !cfg.overlap {
        // bulk-sync: ghosts first, then the whole trapezoid
        wait_ghost_blocks(rank, st)?;
        for j in 1..=sdepth {
            blocked_step(&local, vs, p, table, st, base, j, cfg, pool,
                         &mut rank.trace, step + j as u64 - 1);
        }
    } else {
        // step 1 split: its interior planes need no ghost data — the
        // k-step-wide overlap window is this sweep, computed while the
        // ghost blocks are in flight
        pool.trace_context(TracePhase::Interior, step);
        let tr0 = rank.trace.now();
        phi_from_g_range(vs, &st.g, &mut st.phi, ln,
                         halo * plane..(halo + lxl) * plane, pool, vvl);
        rank.trace.close(TracePhase::Interior, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        let deep = (halo + 1) * plane..(halo + lxl - 1) * plane;
        pool.trace_context(TracePhase::Gradient, step);
        let tr0 = rank.trace.now();
        gradient_fd_range(&local, &st.phi, &mut st.grad, &mut st.lap,
                          deep.clone(), pool, vvl);
        rank.trace.close(TracePhase::Gradient, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        pool.trace_context(TracePhase::Collide, step);
        let tr0 = rank.trace.now();
        collide_stream_range(vs, p, &st.f, &st.g, &mut st.f_tmp,
                             &mut st.g_tmp, &st.grad, &st.lap, table, ln,
                             deep, pool, vvl, scalar);
        rank.trace.close(TracePhase::Collide, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        // complete step 1's rim on the freshly filled ghost planes; the
        // split ranges union to exactly the bulk step-1 ranges, each
        // site computed once → bit-identical
        wait_ghost_blocks(rank, st)?;
        pool.trace_context(TracePhase::EdgeRim, step);
        let tr0 = rank.trace.now();
        phi_from_g_range(vs, &st.g, &mut st.phi, ln,
                         base * plane..halo * plane, pool, vvl);
        phi_from_g_range(vs, &st.g, &mut st.phi, ln,
                         (halo + lxl) * plane..(lloc - base) * plane,
                         pool, vvl);
        for rim in [(base + 1) * plane..(halo + 1) * plane,
                    (halo + lxl - 1) * plane
                        ..(lloc - base - 1) * plane] {
            gradient_fd_range(&local, &st.phi, &mut st.grad, &mut st.lap,
                              rim.clone(), pool, vvl);
            collide_stream_range(vs, p, &st.f, &st.g, &mut st.f_tmp,
                                 &mut st.g_tmp, &st.grad, &st.lap, table,
                                 ln, rim, pool, vvl, scalar);
        }
        rank.trace.close(TracePhase::EdgeRim, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        std::mem::swap(&mut st.f, &mut st.f_tmp);
        std::mem::swap(&mut st.g, &mut st.g_tmp);
        for j in 2..=sdepth {
            blocked_step(&local, vs, p, table, st, base, j, cfg, pool,
                         &mut rank.trace, step + j as u64 - 1);
        }
    }
    Ok(())
}

/// One binary-fluid LB timestep on this rank's slab.
///
/// Schedule (overlapped mode; bulk-sync waits where marked instead):
///
/// ```text
/// isend g[1], g[lxl]            — moments exchange        (MPI_Isend x2)
/// phi   interior                                          ┐ overlapped
/// grad + collide  deep interior (planes 2..lxl-1)         ┘ with flight
/// wait  g halos; phi halos; grad + collide edge planes    (MPI_Waitall)
/// isend f[1], f[lxl], g[1], g[lxl] — stream exchange      (MPI_Isend x4)
/// stream deep interior destinations                       ─ overlapped
/// wait  f,g halos; stream edge destinations               (MPI_Waitall)
/// swap double buffers
/// ```
///
/// Every site's arithmetic is position-independent, so the split ranges
/// produce bitwise the values of the bulk schedule and of a single-domain
/// sweep.
#[allow(clippy::too_many_arguments)]
fn step_rank(d: &SubDomain, vs: &VelSet, p: &FeParams, table: &StreamTable,
             st: &mut RankState, rank: &mut Rank, step: u64,
             cfg: &CommsConfig, pool: &TlpPool) -> Result<()> {
    let (vvl, scalar) = (cfg.vvl, cfg.scalar);
    let plane = d.plane();
    let lxl = d.lxl;
    let ln = d.local.nsites();
    let nvel = vs.nvel;
    let interior = d.interior();
    let halo_lo = 0..plane;
    let halo_hi = (lxl + 1) * plane..ln;
    let edge_lo = plane..2 * plane;
    let edge_hi = lxl * plane..(lxl + 1) * plane;
    // planes 2..=lxl-1: the sites whose whole stencil stays interior
    let deep = if lxl >= 2 { 2 * plane..lxl * plane } else { 0..0 };
    // with a single interior plane the low and high edges coincide
    let single = lxl == 1;
    let tag = |phase: Phase, field: FieldId, side: Side| Tag {
        step,
        phase,
        field,
        side,
        axis: Axis::X,
    };

    // ---- exchange 1: post-stream g edge planes (moments halo) ----
    // my low edge fills the left neighbour's HIGH halo and vice versa
    let tr0 = rank.trace.now();
    pack_x_plane(&st.g, nvel, ln, plane, 1, &mut st.send_buf);
    rank.isend(rank.left(), tag(Phase::Moments, FieldId::G, Side::High),
               &st.send_buf)?;
    pack_x_plane(&st.g, nvel, ln, plane, lxl, &mut st.send_buf);
    rank.isend(rank.right(), tag(Phase::Moments, FieldId::G, Side::Low),
               &st.send_buf)?;
    rank.trace.close(TracePhase::Pack, step, 0, SIDE_NONE, tr0);

    // mid-exchange fault point: both moments planes are posted, the
    // neighbours are left waiting for the stream exchange that never
    // comes
    fault_check(&cfg.fault, d.rank, FaultPoint::Mid, step, step + 1,
                "mid-step, after the moments sends")?;

    if !cfg.overlap {
        // bulk-sync: halos first, then everything in one sweep
        let lo = rank.wait(tag(Phase::Moments, FieldId::G, Side::Low))?;
        let hi = rank.wait(tag(Phase::Moments, FieldId::G, Side::High))?;
        let tr0 = rank.trace.now();
        unpack_checked(&mut st.g, nvel, ln, plane, 0, &lo)?;
        unpack_checked(&mut st.g, nvel, ln, plane, lxl + 1, &hi)?;
        rank.trace.close(TracePhase::Unpack, step, 0, SIDE_NONE, tr0);
        pool.trace_context(TracePhase::Interior, step);
        let tr0 = rank.trace.now();
        phi_from_g_range(vs, &st.g, &mut st.phi, ln, 0..ln, pool, vvl);
        rank.trace.close(TracePhase::Interior, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        pool.trace_context(TracePhase::Gradient, step);
        let tr0 = rank.trace.now();
        gradient_fd_range(&d.local, &st.phi, &mut st.grad, &mut st.lap,
                          interior.clone(), pool, vvl);
        rank.trace.close(TracePhase::Gradient, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        pool.trace_context(TracePhase::Collide, step);
        let tr0 = rank.trace.now();
        collide_lattice_range(vs, p, &mut st.f, &mut st.g, &st.grad,
                              &st.lap, ln, interior.clone(), pool, vvl,
                              scalar);
        rank.trace.close(TracePhase::Collide, step, AXIS_NONE, SIDE_NONE,
                         tr0);
    } else {
        // overlap: the interior needs no halo — compute it while the
        // edge planes are in flight
        pool.trace_context(TracePhase::Interior, step);
        let tr0 = rank.trace.now();
        phi_from_g_range(vs, &st.g, &mut st.phi, ln, interior.clone(),
                         pool, vvl);
        rank.trace.close(TracePhase::Interior, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        pool.trace_context(TracePhase::Gradient, step);
        let tr0 = rank.trace.now();
        gradient_fd_range(&d.local, &st.phi, &mut st.grad, &mut st.lap,
                          deep.clone(), pool, vvl);
        rank.trace.close(TracePhase::Gradient, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        pool.trace_context(TracePhase::Collide, step);
        let tr0 = rank.trace.now();
        collide_lattice_range(vs, p, &mut st.f, &mut st.g, &st.grad,
                              &st.lap, ln, deep.clone(), pool, vvl, scalar);
        rank.trace.close(TracePhase::Collide, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        // complete the edges on arrival
        let lo = rank.wait(tag(Phase::Moments, FieldId::G, Side::Low))?;
        let hi = rank.wait(tag(Phase::Moments, FieldId::G, Side::High))?;
        let tr0 = rank.trace.now();
        unpack_checked(&mut st.g, nvel, ln, plane, 0, &lo)?;
        unpack_checked(&mut st.g, nvel, ln, plane, lxl + 1, &hi)?;
        rank.trace.close(TracePhase::Unpack, step, 0, SIDE_NONE, tr0);
        pool.trace_context(TracePhase::EdgeRim, step);
        let tr0 = rank.trace.now();
        phi_from_g_range(vs, &st.g, &mut st.phi, ln, halo_lo, pool, vvl);
        phi_from_g_range(vs, &st.g, &mut st.phi, ln, halo_hi, pool, vvl);
        gradient_fd_range(&d.local, &st.phi, &mut st.grad, &mut st.lap,
                          edge_lo.clone(), pool, vvl);
        collide_lattice_range(vs, p, &mut st.f, &mut st.g, &st.grad,
                              &st.lap, ln, edge_lo.clone(), pool, vvl,
                              scalar);
        if !single {
            gradient_fd_range(&d.local, &st.phi, &mut st.grad, &mut st.lap,
                              edge_hi.clone(), pool, vvl);
            collide_lattice_range(vs, p, &mut st.f, &mut st.g, &st.grad,
                                  &st.lap, ln, edge_hi.clone(), pool, vvl,
                                  scalar);
        }
        rank.trace.close(TracePhase::EdgeRim, step, AXIS_NONE, SIDE_NONE,
                         tr0);
    }

    // ---- exchange 2: post-collision f,g edge planes (stream halo) ----
    let tr0 = rank.trace.now();
    pack_x_plane(&st.f, nvel, ln, plane, 1, &mut st.send_buf);
    rank.isend(rank.left(), tag(Phase::Stream, FieldId::F, Side::High),
               &st.send_buf)?;
    pack_x_plane(&st.f, nvel, ln, plane, lxl, &mut st.send_buf);
    rank.isend(rank.right(), tag(Phase::Stream, FieldId::F, Side::Low),
               &st.send_buf)?;
    pack_x_plane(&st.g, nvel, ln, plane, 1, &mut st.send_buf);
    rank.isend(rank.left(), tag(Phase::Stream, FieldId::G, Side::High),
               &st.send_buf)?;
    pack_x_plane(&st.g, nvel, ln, plane, lxl, &mut st.send_buf);
    rank.isend(rank.right(), tag(Phase::Stream, FieldId::G, Side::Low),
               &st.send_buf)?;
    rank.trace.close(TracePhase::Pack, step, 0, SIDE_NONE, tr0);

    let wait_stream_halos =
        |rank: &mut Rank, st: &mut RankState| -> Result<()> {
            let f_lo = rank.wait(tag(Phase::Stream, FieldId::F, Side::Low))?;
            let f_hi =
                rank.wait(tag(Phase::Stream, FieldId::F, Side::High))?;
            let g_lo = rank.wait(tag(Phase::Stream, FieldId::G, Side::Low))?;
            let g_hi =
                rank.wait(tag(Phase::Stream, FieldId::G, Side::High))?;
            let tr0 = rank.trace.now();
            unpack_checked(&mut st.f, nvel, ln, plane, 0, &f_lo)?;
            unpack_checked(&mut st.f, nvel, ln, plane, lxl + 1, &f_hi)?;
            unpack_checked(&mut st.g, nvel, ln, plane, 0, &g_lo)?;
            unpack_checked(&mut st.g, nvel, ln, plane, lxl + 1, &g_hi)?;
            rank.trace.close(TracePhase::Unpack, step, 0, SIDE_NONE, tr0);
            Ok(())
        };

    if !cfg.overlap {
        wait_stream_halos(rank, st)?;
        pool.trace_context(TracePhase::Stream, step);
        let tr0 = rank.trace.now();
        stream_range(vs, table, &st.f, &mut st.f_tmp, interior.clone(),
                     pool, vvl);
        stream_range(vs, table, &st.g, &mut st.g_tmp, interior, pool, vvl);
        rank.trace.close(TracePhase::Stream, step, AXIS_NONE, SIDE_NONE,
                         tr0);
    } else {
        // deep destinations pull only post-collision interior sources —
        // exactly what the StreamTable exception lists certify
        debug_assert!((0..nvel).all(|i| {
            table.pull_sources_within(i, deep.clone(), &d.interior())
        }));
        pool.trace_context(TracePhase::Stream, step);
        let tr0 = rank.trace.now();
        stream_range(vs, table, &st.f, &mut st.f_tmp, deep.clone(), pool,
                     vvl);
        stream_range(vs, table, &st.g, &mut st.g_tmp, deep, pool, vvl);
        rank.trace.close(TracePhase::Stream, step, AXIS_NONE, SIDE_NONE,
                         tr0);
        wait_stream_halos(rank, st)?;
        pool.trace_context(TracePhase::EdgeRim, step);
        let tr0 = rank.trace.now();
        stream_range(vs, table, &st.f, &mut st.f_tmp, edge_lo.clone(),
                     pool, vvl);
        stream_range(vs, table, &st.g, &mut st.g_tmp, edge_lo, pool, vvl);
        if !single {
            stream_range(vs, table, &st.f, &mut st.f_tmp, edge_hi.clone(),
                         pool, vvl);
            stream_range(vs, table, &st.g, &mut st.g_tmp, edge_hi, pool,
                         vvl);
        }
        rank.trace.close(TracePhase::EdgeRim, step, AXIS_NONE, SIDE_NONE,
                         tr0);
    }
    std::mem::swap(&mut st.f, &mut st.f_tmp);
    std::mem::swap(&mut st.g, &mut st.g_tmp);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lb::engine::state_observables;
    use crate::lb::init::init_spinodal;
    use crate::lb::model::{d2q9, d3q19};
    use crate::lb::propagation::stream;

    fn spinodal(vs: &VelSet, geom: &Geometry) -> (Vec<f64>, Vec<f64>) {
        let n = geom.nsites();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        init_spinodal(vs, &FeParams::default(), geom, &mut f, &mut g, 0.05,
                      77);
        (f, g)
    }

    /// Single-domain reference: the unfused per-kernel pipeline.
    fn reference(vs: &VelSet, geom: &Geometry, steps: u64)
                 -> (Vec<f64>, Vec<f64>) {
        let p = FeParams::default();
        let n = geom.nsites();
        let (mut f, mut g) = spinodal(vs, geom);
        let pool = TlpPool::serial();
        for _ in 0..steps {
            let mut phi = vec![0.0; n];
            let mut grad = vec![0.0; 3 * n];
            let mut lap = vec![0.0; n];
            crate::lb::moments::phi_from_g(vs, &g, &mut phi, n, &pool, 8);
            crate::free_energy::gradient::gradient_fd(geom, &phi, &mut grad,
                                                      &mut lap, &pool, 8);
            crate::lb::collision::collide_lattice(vs, &p, &mut f, &mut g,
                                                  &grad, &lap, n, &pool, 8,
                                                  false);
            let mut fs = vec![0.0; vs.nvel * n];
            let mut gs = vec![0.0; vs.nvel * n];
            stream(vs, geom, &f, &mut fs, &pool, 8);
            stream(vs, geom, &g, &mut gs, &pool, 8);
            f = fs;
            g = gs;
        }
        (f, g)
    }

    #[test]
    fn concurrent_ranks_match_single_domain_bitwise() {
        let vs = d3q19();
        let geom = Geometry::new(11, 4, 3); // 11 -> uneven splits
        let steps = 4;
        let (f_want, g_want) = reference(vs, &geom, steps);
        for ranks in [1usize, 2, 3] {
            for overlap in [false, true] {
                let (mut f, mut g) = spinodal(vs, &geom);
                let cfg = CommsConfig { ranks, overlap,
                                        ..CommsConfig::default() };
                let rep = run_decomposed(&geom, vs, &FeParams::default(),
                                         &mut f, &mut g, steps, &cfg)
                    .unwrap();
                assert_eq!(rep.ranks.len(), ranks);
                assert_eq!(f, f_want, "ranks={ranks} overlap={overlap}");
                assert_eq!(g, g_want, "ranks={ranks} overlap={overlap}");
            }
        }
    }

    #[test]
    fn single_plane_slabs_work() {
        // lxl == 1 everywhere: edge planes coincide, deep interior empty
        let vs = d2q9();
        let geom = Geometry::new(4, 6, 1);
        let steps = 3;
        let (f_want, g_want) = reference(vs, &geom, steps);
        for overlap in [false, true] {
            let (mut f, mut g) = spinodal(vs, &geom);
            let cfg = CommsConfig { ranks: 4, overlap,
                                    ..CommsConfig::default() };
            run_decomposed(&geom, vs, &FeParams::default(), &mut f, &mut g,
                           steps, &cfg)
                .unwrap();
            assert_eq!(f, f_want, "overlap={overlap}");
            assert_eq!(g, g_want, "overlap={overlap}");
        }
    }

    #[test]
    fn multi_block_session_is_bit_identical_to_one_shot() {
        // residency: 4 = 1 + 2 + 1 advances over a paused session must
        // produce exactly the bits of a single 4-step world
        let vs = d3q19();
        let geom = Geometry::new(9, 3, 4);
        let (f_want, g_want) = reference(vs, &geom, 4);
        let world = CommsWorld::new(geom, CommsConfig {
            ranks: 3,
            ..CommsConfig::default()
        })
        .unwrap();
        let (f0, g0) = spinodal(vs, &geom);
        let mut session = world
            .session(vs, &FeParams::default(), f0, g0)
            .unwrap();
        for block in [1u64, 2, 1] {
            session.advance(block).unwrap();
            // a reduction between blocks must not perturb the state
            session.observables().unwrap();
        }
        assert_eq!(session.steps_done(), 4);
        let n = geom.nsites();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        session.gather(&mut f, &mut g).unwrap();
        let rep = session.finish().unwrap();
        assert_eq!(f, f_want);
        assert_eq!(g, g_want);
        assert!(rep.ranks.iter().all(|r| r.steps == 4));
    }

    #[test]
    fn reduced_observables_match_gathered_state() {
        // distributed partial sums vs the single-sweep reduction of the
        // gathered state: same values up to summation order (documented
        // in Observables::from_sums), at every block boundary
        let vs = d3q19();
        let geom = Geometry::new(10, 4, 3);
        let n = geom.nsites();
        let world = CommsWorld::new(geom, CommsConfig {
            ranks: 3,
            ..CommsConfig::default()
        })
        .unwrap();
        let (f0, g0) = spinodal(vs, &geom);
        let mut session = world
            .session(vs, &FeParams::default(), f0, g0)
            .unwrap();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        let close = |a: f64, b: f64, what: &str| {
            assert!((a - b).abs() <= 1e-12 + 1e-9 * b.abs(),
                    "{what}: {a} vs {b}");
        };
        for _ in 0..3 {
            session.advance(2).unwrap();
            let got = session.observables().unwrap();
            session.gather(&mut f, &mut g).unwrap();
            let want = state_observables(vs, &f, &g, n);
            close(got.mass, want.mass, "mass");
            close(got.phi_total, want.phi_total, "phi_total");
            close(got.phi_variance, want.phi_variance, "phi_variance");
            for a in 0..3 {
                close(got.momentum[a], want.momentum[a], "momentum");
            }
        }
        session.finish().unwrap();
    }

    #[test]
    fn gather_phi_matches_host_phi_moment() {
        let vs = d2q9();
        let geom = Geometry::new(8, 5, 1);
        let n = geom.nsites();
        let world =
            CommsWorld::new(geom, CommsConfig { ranks: 2,
                                                ..CommsConfig::default() })
                .unwrap();
        let (f0, g0) = spinodal(vs, &geom);
        let mut session = world
            .session(vs, &FeParams::default(), f0, g0)
            .unwrap();
        session.advance(3).unwrap();
        let phi = session.gather_phi().unwrap();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        session.gather(&mut f, &mut g).unwrap();
        session.finish().unwrap();
        let mut want = vec![0.0; n];
        crate::lb::moments::phi_from_g(vs, &g, &mut want, n,
                                       &TlpPool::serial(), 8);
        // identical per-site arithmetic → identical bits
        assert_eq!(phi, want);
    }

    #[test]
    fn super_step_ranks_match_reference_bitwise() {
        // depth-k worlds (with a k ∤ nsteps remainder) vs the unfused
        // single-domain reference; one variant exercises pinned pools
        let vs = d2q9();
        let geom = Geometry::new(16, 4, 1);
        let steps = 5;
        let (f_want, g_want) = reference(vs, &geom, steps);
        for (depth, pin) in [(2usize, false), (2, true), (4, false)] {
            for overlap in [false, true] {
                let (mut f, mut g) = spinodal(vs, &geom);
                let cfg = CommsConfig { ranks: 2, depth, pin, overlap,
                                        ..CommsConfig::default() };
                run_decomposed(&geom, vs, &FeParams::default(), &mut f,
                               &mut g, steps, &cfg)
                    .unwrap();
                assert_eq!(f, f_want,
                           "depth={depth} overlap={overlap} pin={pin}");
                assert_eq!(g, g_want,
                           "depth={depth} overlap={overlap} pin={pin}");
            }
        }
    }

    #[test]
    fn super_steps_cut_halo_message_count() {
        // depth 1: 6 plane messages per step per rank; depth k: 4 block
        // messages per super-step (f,g × two sides), remainder included
        let vs = d2q9();
        let geom = Geometry::new(16, 4, 1);
        let steps = 5u64;
        for (depth, want_msgs) in [(1usize, 6 * steps),
                                   (2, 4 * steps.div_ceil(2)),
                                   (4, 4 * steps.div_ceil(4))] {
            let (mut f, mut g) = spinodal(vs, &geom);
            let cfg = CommsConfig { ranks: 2, depth,
                                    ..CommsConfig::default() };
            let rep = run_decomposed(&geom, vs, &FeParams::default(),
                                     &mut f, &mut g, steps, &cfg)
                .unwrap();
            for r in &rep.ranks {
                assert_eq!(r.msgs_sent, want_msgs, "depth={depth}");
                assert!(r.bytes_sent > 0);
            }
        }
    }

    #[test]
    fn world_rejects_bad_depths() {
        let geom = Geometry::new(8, 4, 1);
        // auto depth must be resolved by the config layer first
        assert!(CommsWorld::new(geom, CommsConfig {
            depth: 0,
            ..CommsConfig::default()
        })
        .is_err());
        // ranks=2 → lxl=4; depth 2 needs 4 ghost planes per side: ok
        assert!(CommsWorld::new(geom, CommsConfig {
            ranks: 2,
            depth: 2,
            ..CommsConfig::default()
        })
        .is_ok());
        // depth 3 needs 6 > 4: the trapezoid foot would span a
        // neighbour's neighbour
        assert!(CommsWorld::new(geom, CommsConfig {
            ranks: 2,
            depth: 3,
            ..CommsConfig::default()
        })
        .is_err());
    }

    #[test]
    fn report_accounts_for_all_ranks() {
        let vs = d2q9();
        let geom = Geometry::new(10, 4, 1);
        let (mut f, mut g) = spinodal(vs, &geom);
        let cfg = CommsConfig { ranks: 3, ..CommsConfig::default() };
        let rep = run_decomposed(&geom, vs, &FeParams::default(), &mut f,
                                 &mut g, 5, &cfg)
            .unwrap();
        let owned: usize = rep.ranks.iter().map(|r| r.interior_sites).sum();
        assert_eq!(owned, geom.nsites());
        for r in &rep.ranks {
            assert_eq!(r.steps, 5);
            // 2 + 4 halo messages per step; control-plane frames
            // (commands, gathers, reports) are not halo traffic
            assert_eq!(r.msgs_sent, 30);
            assert!(r.bytes_sent > 0);
            // the intra/inter split always accounts for every frame,
            // and a channel world is all-intra by definition
            assert_eq!(r.bytes_intra + r.bytes_inter, r.bytes_sent);
            assert_eq!(r.msgs_intra + r.msgs_inter, r.msgs_sent);
            assert_eq!(r.bytes_inter, 0);
            assert_eq!(r.msgs_inter, 0);
            assert!(r.compute_s >= 0.0 && r.wait_s >= 0.0);
            assert!(r.idle_s >= 0.0);
        }
        assert!(rep.mlups() >= 0.0);
        assert!(rep.max_wait_s() >= 0.0);
    }

    #[test]
    fn world_rejects_bad_shapes_and_vvl() {
        let vs = d2q9();
        let geom = Geometry::new(8, 4, 1);
        assert!(CommsWorld::new(geom, CommsConfig {
            vvl: 3,
            ..CommsConfig::default()
        })
        .is_err(), "unsupported VVL must be rejected up front");
        // scalar mode takes any vvl (it only sets the chunk grain)
        assert!(CommsWorld::new(geom, CommsConfig {
            vvl: 3,
            scalar: true,
            ..CommsConfig::default()
        })
        .is_ok());
        let world =
            CommsWorld::new(geom, CommsConfig::default()).unwrap();
        let mut short = vec![0.0; 7];
        let mut g = vec![0.0; vs.nvel * geom.nsites()];
        assert!(world
            .run(vs, &FeParams::default(), &mut short, &mut g, 1)
            .is_err());
        // gather-buffer validation happens before any command goes out
        let (f0, g0) = spinodal(vs, &geom);
        let mut session = world
            .session(vs, &FeParams::default(), f0, g0)
            .unwrap();
        let mut small = vec![0.0; 3];
        assert!(session.gather(&mut small, &mut g.clone()).is_err());
        session.finish().unwrap();
    }

    #[test]
    fn dropping_an_unfinished_session_shuts_down_cleanly() {
        let vs = d2q9();
        let geom = Geometry::new(6, 4, 1);
        let world =
            CommsWorld::new(geom, CommsConfig { ranks: 2,
                                                ..CommsConfig::default() })
                .unwrap();
        let (f0, g0) = spinodal(vs, &geom);
        let mut session = world
            .session(vs, &FeParams::default(), f0, g0)
            .unwrap();
        session.advance(2).unwrap();
        drop(session); // must broadcast Shutdown and join, not hang
    }

    #[test]
    fn grid_worlds_match_single_domain_bitwise() {
        // uneven extents on every axis; pencil + block grids, both
        // schedules — all must reproduce the reference bits
        let vs = d3q19();
        let geom = Geometry::new(7, 6, 5);
        let steps = 3;
        let (f_want, g_want) = reference(vs, &geom, steps);
        for grid in [[1, 2, 1], [1, 2, 2], [2, 2, 1], [2, 2, 2]] {
            let ranks = grid.iter().product();
            for overlap in [false, true] {
                let (mut f, mut g) = spinodal(vs, &geom);
                let cfg = CommsConfig { ranks, grid, overlap,
                                        ..CommsConfig::default() };
                let rep = run_decomposed(&geom, vs, &FeParams::default(),
                                         &mut f, &mut g, steps, &cfg)
                    .unwrap();
                assert_eq!(rep.ranks.len(), ranks);
                assert_eq!(f, f_want, "grid={grid:?} overlap={overlap}");
                assert_eq!(g, g_want, "grid={grid:?} overlap={overlap}");
            }
        }
    }

    #[test]
    fn d2q9_grid_worlds_match_single_domain_bitwise() {
        // lz == 1: z stays undecomposed, y faces exercise the strided
        // pack; one-plane y boxes (ly=6 over py=3 is fine, py=6 makes
        // single-plane extents)
        let vs = d2q9();
        let geom = Geometry::new(5, 6, 1);
        let steps = 3;
        let (f_want, g_want) = reference(vs, &geom, steps);
        for grid in [[1, 2, 1], [2, 2, 1], [1, 6, 1]] {
            let ranks = grid.iter().product();
            for overlap in [false, true] {
                let (mut f, mut g) = spinodal(vs, &geom);
                let cfg = CommsConfig { ranks, grid, overlap,
                                        ..CommsConfig::default() };
                run_decomposed(&geom, vs, &FeParams::default(), &mut f,
                               &mut g, steps, &cfg)
                    .unwrap();
                assert_eq!(f, f_want, "grid={grid:?} overlap={overlap}");
                assert_eq!(g, g_want, "grid={grid:?} overlap={overlap}");
            }
        }
    }

    #[test]
    fn grid_observables_and_phi_match_gathered_state() {
        let vs = d3q19();
        let geom = Geometry::new(6, 6, 4);
        let n = geom.nsites();
        let world = CommsWorld::new(geom, CommsConfig {
            ranks: 4,
            grid: [1, 2, 2],
            ..CommsConfig::default()
        })
        .unwrap();
        let (f0, g0) = spinodal(vs, &geom);
        let mut session = world
            .session(vs, &FeParams::default(), f0, g0)
            .unwrap();
        session.advance(2).unwrap();
        let got = session.observables().unwrap();
        let phi = session.gather_phi().unwrap();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        session.gather(&mut f, &mut g).unwrap();
        session.finish().unwrap();
        let want = state_observables(vs, &f, &g, n);
        let close = |a: f64, b: f64, what: &str| {
            assert!((a - b).abs() <= 1e-12 + 1e-9 * b.abs(),
                    "{what}: {a} vs {b}");
        };
        close(got.mass, want.mass, "mass");
        close(got.phi_total, want.phi_total, "phi_total");
        close(got.phi_variance, want.phi_variance, "phi_variance");
        let mut phi_want = vec![0.0; n];
        crate::lb::moments::phi_from_g(vs, &g, &mut phi_want, n,
                                       &TlpPool::serial(), 8);
        assert_eq!(phi, phi_want, "gathered phi is bit-exact");
    }

    #[test]
    fn grid_sends_six_messages_per_decomposed_axis_per_step() {
        let vs = d3q19();
        let geom = Geometry::new(6, 6, 4);
        let steps = 4u64;
        for (grid, naxes) in
            [([1, 2, 1], 1u64), ([2, 2, 1], 2), ([2, 2, 2], 3)]
        {
            let ranks = grid.iter().product();
            let (mut f, mut g) = spinodal(vs, &geom);
            let cfg = CommsConfig { ranks, grid,
                                    ..CommsConfig::default() };
            let rep = run_decomposed(&geom, vs, &FeParams::default(),
                                     &mut f, &mut g, steps, &cfg)
                .unwrap();
            for r in &rep.ranks {
                // 2 moments + 4 stream faces per decomposed axis
                assert_eq!(r.msgs_sent, 6 * naxes * steps,
                           "grid={grid:?}");
                assert!(r.bytes_sent > 0);
            }
        }
    }

    #[test]
    fn grid_world_rejects_bad_configs() {
        let geom = Geometry::new(8, 8, 8);
        // grid product must match the rank count
        assert!(CommsWorld::new(geom, CommsConfig {
            ranks: 4,
            grid: [2, 2, 2],
            ..CommsConfig::default()
        })
        .is_err());
        // an unsplittable axis is named in the error
        let err = CommsWorld::new(Geometry::new(8, 1, 1), CommsConfig {
            ranks: 2,
            grid: [1, 2, 1],
            ..CommsConfig::default()
        })
        .unwrap_err();
        assert!(err.to_string().contains("y axis"), "{err}");
        // super-steps are slab-only
        assert!(CommsWorld::new(geom, CommsConfig {
            ranks: 4,
            grid: [1, 2, 2],
            depth: 2,
            ..CommsConfig::default()
        })
        .is_err());
        // the slab special case still accepts super-steps
        assert!(CommsWorld::new(geom, CommsConfig {
            ranks: 2,
            grid: [2, 1, 1],
            depth: 2,
            ..CommsConfig::default()
        })
        .is_ok());
    }
}
