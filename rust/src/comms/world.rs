//! The rank world: concurrent slab ranks with overlapped halo exchange.
//!
//! [`CommsWorld`] plays the role of `MPI_COMM_WORLD`: it owns the slab
//! decomposition and, per [`CommsWorld::run`], spawns **one OS thread per
//! rank**. Each rank owns its local lattice (allocated and first-touched
//! by its own TLP pool), steps independently, and talks to its two x
//! neighbours only through [`Rank::isend`]/[`Rank::wait`] — there is no
//! shared mutable state and no sequential domain loop anywhere.
//!
//! Per timestep a rank performs two exchanges (three plane messages per
//! side, down from the four the old bulk-synchronous loop copied):
//!
//! 1. **Moments exchange** — post-stream `g` boundary planes, feeding the
//!    phi moment and the gradient stencil of the edge planes;
//! 2. **Stream exchange** — post-collision `f` and `g` boundary planes,
//!    feeding the pull-streaming of the edge destination planes.
//!
//! In overlapped mode (the default) the rank posts its sends, then
//! collides/streams the sites that do not depend on incoming halos while
//! the messages are in flight — the `StreamTable` exception lists prove
//! the interior split is safe (`pull_sources_within`) — and completes the
//! boundary planes on arrival. Bulk-sync mode waits for all halos before
//! computing (the `MPI_Sendrecv`-everything reference schedule). Both
//! orders run the identical per-site arithmetic, so they are bit-identical
//! to each other *and* to the single-domain fused `FullStep` path
//! (`tests/comms_parity.rs`).

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::comms::transport::{ChannelTransport, Transport};
use crate::comms::wire::{FieldId, Phase, PlaneMsg, Side, Tag};
use crate::error::{Error, Result};
use crate::free_energy::gradient::gradient_fd_range;
use crate::free_energy::symmetric::FeParams;
use crate::lattice::decomp::{SlabDecomposition, SubDomain};
use crate::lattice::geometry::Geometry;
use crate::lattice::halo::{pack_x_plane, unpack_x_plane};
use crate::lattice::stream_table::StreamTable;
use crate::lb::collision::collide_lattice_range;
use crate::lb::model::VelSet;
use crate::lb::moments::phi_from_g_range;
use crate::lb::propagation::stream_range;
use crate::targetdp::ilp;
use crate::targetdp::tlp::{threads_per_rank, Schedule, TlpPool};

/// A blocked [`Rank::wait`] gives up after this long — it converts the
/// MPI-style deadlock of a lost neighbour into a diagnosable error
/// instead of a hung world.
const WAIT_TIMEOUT: Duration = Duration::from_secs(120);

/// Knobs for a decomposed run.
#[derive(Debug, Clone)]
pub struct CommsConfig {
    /// Number of slab ranks (1 = a single rank talking to itself across
    /// the periodic seam).
    pub ranks: usize,
    /// Overlap halo exchange with interior compute (`false` = the
    /// bulk-synchronous reference schedule; identical results).
    pub overlap: bool,
    /// Total TLP thread budget shared by all ranks (0 = machine width);
    /// each rank's pool gets `threads / ranks`, at least 1.
    pub threads: usize,
    /// Virtual vector length for the per-rank kernels (must be a
    /// supported VVL unless `scalar`).
    pub vvl: usize,
    /// Use the scalar collision kernel (host-scalar analog).
    pub scalar: bool,
    /// Chunk→thread assignment inside each rank's pool (the `[target]
    /// schedule` knob, honoured here exactly like the engine path).
    pub schedule: Schedule,
}

impl Default for CommsConfig {
    fn default() -> Self {
        CommsConfig {
            ranks: 1,
            overlap: true,
            threads: 1,
            vvl: 8,
            scalar: false,
            schedule: Schedule::Static,
        }
    }
}

/// Per-rank timing/traffic summary (the output of one rank's run).
#[derive(Debug, Clone)]
pub struct RankReport {
    pub rank: usize,
    /// Owned (interior) sites — halo planes excluded.
    pub interior_sites: usize,
    pub steps: u64,
    /// Wall time spent computing (total minus blocked-in-wait).
    pub compute_s: f64,
    /// Wall time blocked waiting for halo planes.
    pub wait_s: f64,
    pub bytes_sent: u64,
    pub msgs_sent: u64,
}

impl RankReport {
    /// Million (interior) lattice-site updates per second of rank wall
    /// time (compute + wait).
    pub fn mlups(&self) -> f64 {
        let wall = self.compute_s + self.wait_s;
        if wall <= 0.0 {
            return 0.0;
        }
        self.interior_sites as f64 * self.steps as f64 / wall / 1e6
    }

    /// Fraction of this rank's wall time spent blocked on halo arrival.
    pub fn wait_fraction(&self) -> f64 {
        let wall = self.compute_s + self.wait_s;
        if wall <= 0.0 { 0.0 } else { self.wait_s / wall }
    }
}

/// Whole-world summary of one decomposed run.
#[derive(Debug, Clone)]
pub struct WorldReport {
    pub ranks: Vec<RankReport>,
    /// Wall time of the whole run (spawn to join).
    pub seconds: f64,
    pub overlap: bool,
}

impl WorldReport {
    /// Aggregate MLUPS: all interior site-updates over the run wall time.
    pub fn mlups(&self) -> f64 {
        if self.seconds <= 0.0 {
            return 0.0;
        }
        let updates: f64 = self
            .ranks
            .iter()
            .map(|r| r.interior_sites as f64 * r.steps as f64)
            .sum();
        updates / self.seconds / 1e6
    }

    /// Worst per-rank exchange wait.
    pub fn max_wait_s(&self) -> f64 {
        self.ranks.iter().map(|r| r.wait_s).fold(0.0, f64::max)
    }
}

/// One rank's communication endpoint: tag-matched, non-blocking sends and
/// blocking waits over a pluggable [`Transport`].
///
/// MPI mapping: [`Rank::isend`] is `MPI_Isend` (completes locally — the
/// transport owns the bytes as soon as it returns), [`Rank::wait`] is a
/// posted `MPI_Irecv` + `MPI_Wait` pair, and the internal `pending` map is
/// the unexpected-message queue an MPI progress engine keeps for frames
/// that arrive before their receive is posted.
pub struct Rank {
    pub rank: usize,
    pub nranks: usize,
    transport: Box<dyn Transport>,
    /// Frames that arrived while waiting for a different tag.
    pending: HashMap<Tag, Vec<f64>>,
    /// Seconds spent blocked in [`Rank::wait`].
    pub wait_s: f64,
    pub bytes_sent: u64,
    pub msgs_sent: u64,
}

impl Rank {
    pub fn new(transport: Box<dyn Transport>) -> Rank {
        Rank {
            rank: transport.rank(),
            nranks: transport.nranks(),
            transport,
            pending: HashMap::new(),
            wait_s: 0.0,
            bytes_sent: 0,
            msgs_sent: 0,
        }
    }

    /// Left (lower-x) neighbour, periodic.
    pub fn left(&self) -> usize {
        (self.rank + self.nranks - 1) % self.nranks
    }

    /// Right (higher-x) neighbour, periodic.
    pub fn right(&self) -> usize {
        (self.rank + 1) % self.nranks
    }

    /// Non-blocking tagged send of one packed plane (`MPI_Isend`). The
    /// wire frame is encoded straight from `data` — the only copy on the
    /// send path.
    pub fn isend(&mut self, dst: usize, tag: Tag, data: &[f64])
                 -> Result<()> {
        self.bytes_sent += PlaneMsg::frame_len(data.len()) as u64;
        self.msgs_sent += 1;
        self.transport.send_plane(dst, self.rank as u32, tag, data)
    }

    /// Block until the plane tagged `tag` has arrived and return its
    /// payload (`MPI_Wait` on the matching receive). Frames for other
    /// tags encountered on the way are parked for their own waits.
    pub fn wait(&mut self, tag: Tag) -> Result<Vec<f64>> {
        if let Some(data) = self.pending.remove(&tag) {
            return Ok(data);
        }
        let t0 = Instant::now();
        let data = loop {
            match self.transport.recv_timeout(WAIT_TIMEOUT)? {
                Some(msg) if msg.tag == tag => break msg.data,
                Some(msg) => {
                    // a duplicate tag means the transport broke the
                    // one-frame-per-tag protocol (e.g. a retransmitting
                    // socket); overwriting silently would corrupt physics
                    if self.pending.insert(msg.tag, msg.data).is_some() {
                        return Err(Error::Invalid(format!(
                            "comms: rank {} received a duplicate frame \
                             for {:?}",
                            self.rank, msg.tag
                        )));
                    }
                }
                None => {
                    return Err(Error::Invalid(format!(
                        "comms: rank {} timed out after {WAIT_TIMEOUT:?} \
                         waiting for {tag:?} — neighbour lost?",
                        self.rank
                    )))
                }
            }
        };
        self.wait_s += t0.elapsed().as_secs_f64();
        Ok(data)
    }
}

/// The rank world (`MPI_COMM_WORLD`): a slab decomposition plus the run
/// configuration, ready to spawn concurrent ranks.
#[derive(Debug, Clone)]
pub struct CommsWorld {
    pub dec: SlabDecomposition,
    pub cfg: CommsConfig,
}

impl CommsWorld {
    pub fn new(geom: Geometry, cfg: CommsConfig) -> Result<Self> {
        if !cfg.scalar && !ilp::is_supported(cfg.vvl) {
            return Err(Error::Invalid(format!(
                "comms: VVL {} unsupported (pick one of {:?}, or scalar)",
                cfg.vvl,
                ilp::SUPPORTED_VVL
            )));
        }
        let dec = SlabDecomposition::new(geom, cfg.ranks)?;
        Ok(CommsWorld { dec, cfg })
    }

    /// Advance the global state `nsteps` timesteps with one concurrent
    /// rank per slab: scatter (each rank copies its own planes), run,
    /// gather back into `f`/`g`. Blocks until every rank has finished.
    pub fn run(&self, vs: &VelSet, p: &FeParams, f: &mut [f64],
               g: &mut [f64], nsteps: u64) -> Result<WorldReport> {
        let n = self.dec.global.nsites();
        if f.len() != vs.nvel * n || g.len() != vs.nvel * n {
            return Err(Error::Invalid(format!(
                "comms: state is {}+{} doubles, want {} each",
                f.len(),
                g.len(),
                vs.nvel * n
            )));
        }
        let transports = ChannelTransport::mesh(self.cfg.ranks);
        let nthreads = threads_per_rank(self.cfg.threads, self.cfg.ranks);
        let cfg = &self.cfg;
        let f_in: &[f64] = f;
        let g_in: &[f64] = g;
        let t0 = Instant::now();
        let results: Vec<Result<(Vec<f64>, Vec<f64>, RankReport)>> =
            std::thread::scope(|s| {
                let handles: Vec<_> = transports
                    .into_iter()
                    .zip(&self.dec.domains)
                    .map(|(tr, d)| {
                        s.spawn(move || {
                            rank_main(d, vs, p, f_in, g_in, nsteps, cfg,
                                      nthreads, tr)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| match h.join() {
                        Ok(r) => r,
                        Err(e) => std::panic::resume_unwind(e),
                    })
                    .collect()
            });
        let seconds = t0.elapsed().as_secs_f64();

        // a failing rank makes its neighbours fail too (timeout /
        // hung-up errors); surface the root cause, not the knock-on —
        // prefer the first error that is neither a wait timeout nor a
        // dropped-peer symptom
        if results.iter().any(|r| r.is_err()) {
            let knock_on =
                |e: &Error| {
                    let msg = e.to_string();
                    msg.contains("timed out") || msg.contains("hung up")
                };
            let mut first_any = None;
            for r in results {
                if let Err(e) = r {
                    if !knock_on(&e) {
                        return Err(e);
                    }
                    first_any.get_or_insert(e);
                }
            }
            return Err(first_any.expect("an error exists"));
        }
        let mut reports = Vec::with_capacity(self.cfg.ranks);
        let mut f_locals = Vec::with_capacity(self.cfg.ranks);
        let mut g_locals = Vec::with_capacity(self.cfg.ranks);
        for r in results {
            let (lf, lg, rep) = r?;
            f_locals.push(lf);
            g_locals.push(lg);
            reports.push(rep);
        }
        self.dec.gather_into(&f_locals, vs.nvel, f);
        self.dec.gather_into(&g_locals, vs.nvel, g);
        Ok(WorldReport {
            ranks: reports,
            seconds,
            overlap: self.cfg.overlap,
        })
    }
}

/// Convenience: build a [`CommsWorld`] and run it once.
pub fn run_decomposed(geom: &Geometry, vs: &VelSet, p: &FeParams,
                      f: &mut [f64], g: &mut [f64], nsteps: u64,
                      cfg: &CommsConfig) -> Result<WorldReport> {
    CommsWorld::new(*geom, cfg.clone())?.run(vs, p, f, g, nsteps)
}

/// Per-rank working state: local SoA fields + streaming double buffers +
/// moment scratch + the plane pack buffer. Everything is allocated by the
/// rank's own pool ([`TlpPool::zeros`]) so first touch happens on the
/// thread(s) that sweep it.
struct RankState {
    f: Vec<f64>,
    g: Vec<f64>,
    f_tmp: Vec<f64>,
    g_tmp: Vec<f64>,
    phi: Vec<f64>,
    grad: Vec<f64>,
    lap: Vec<f64>,
    send_buf: Vec<f64>,
}

/// Body of one rank thread: allocate + scatter, step `nsteps` times,
/// return the local state and a timing report.
#[allow(clippy::too_many_arguments)]
fn rank_main(d: &SubDomain, vs: &VelSet, p: &FeParams, f_global: &[f64],
             g_global: &[f64], nsteps: u64, cfg: &CommsConfig,
             nthreads: usize, transport: ChannelTransport)
             -> Result<(Vec<f64>, Vec<f64>, RankReport)> {
    let pool = TlpPool::new(nthreads, cfg.schedule);
    let ln = d.local.nsites();
    let nvel = vs.nvel;
    let mut st = RankState {
        f: pool.zeros(nvel * ln),
        g: pool.zeros(nvel * ln),
        f_tmp: pool.zeros(nvel * ln),
        g_tmp: pool.zeros(nvel * ln),
        phi: pool.zeros(ln),
        grad: pool.zeros(3 * ln),
        lap: pool.zeros(ln),
        send_buf: vec![0.0; nvel * d.plane()],
    };
    d.scatter_into(f_global, nvel, &mut st.f);
    d.scatter_into(g_global, nvel, &mut st.g);
    let table = StreamTable::cached(vs, &d.local);
    let mut rank = Rank::new(Box::new(transport));

    let t0 = Instant::now();
    for step in 0..nsteps {
        step_rank(d, vs, p, &table, &mut st, &mut rank, step, cfg, &pool)?;
    }
    let wall = t0.elapsed().as_secs_f64();

    let report = RankReport {
        rank: d.rank,
        interior_sites: d.lxl * d.plane(),
        steps: nsteps,
        compute_s: (wall - rank.wait_s).max(0.0),
        wait_s: rank.wait_s,
        bytes_sent: rank.bytes_sent,
        msgs_sent: rank.msgs_sent,
    };
    Ok((st.f, st.g, report))
}

/// Validate a received plane payload and scatter it into halo plane `p`.
fn unpack_checked(field: &mut [f64], nvel: usize, ln: usize, plane: usize,
                  p: usize, data: &[f64]) -> Result<()> {
    if data.len() != nvel * plane {
        return Err(Error::Invalid(format!(
            "comms: halo payload is {} doubles, want {}",
            data.len(),
            nvel * plane
        )));
    }
    unpack_x_plane(field, nvel, ln, plane, p, data);
    Ok(())
}

/// One binary-fluid LB timestep on this rank's slab.
///
/// Schedule (overlapped mode; bulk-sync waits where marked instead):
///
/// ```text
/// isend g[1], g[lxl]            — moments exchange        (MPI_Isend x2)
/// phi   interior                                          ┐ overlapped
/// grad + collide  deep interior (planes 2..lxl-1)         ┘ with flight
/// wait  g halos; phi halos; grad + collide edge planes    (MPI_Waitall)
/// isend f[1], f[lxl], g[1], g[lxl] — stream exchange      (MPI_Isend x4)
/// stream deep interior destinations                       ─ overlapped
/// wait  f,g halos; stream edge destinations               (MPI_Waitall)
/// swap double buffers
/// ```
///
/// Every site's arithmetic is position-independent, so the split ranges
/// produce bitwise the values of the bulk schedule and of a single-domain
/// sweep.
#[allow(clippy::too_many_arguments)]
fn step_rank(d: &SubDomain, vs: &VelSet, p: &FeParams, table: &StreamTable,
             st: &mut RankState, rank: &mut Rank, step: u64,
             cfg: &CommsConfig, pool: &TlpPool) -> Result<()> {
    let (vvl, scalar) = (cfg.vvl, cfg.scalar);
    let plane = d.plane();
    let lxl = d.lxl;
    let ln = d.local.nsites();
    let nvel = vs.nvel;
    let interior = d.interior();
    let halo_lo = 0..plane;
    let halo_hi = (lxl + 1) * plane..ln;
    let edge_lo = plane..2 * plane;
    let edge_hi = lxl * plane..(lxl + 1) * plane;
    // planes 2..=lxl-1: the sites whose whole stencil stays interior
    let deep = if lxl >= 2 { 2 * plane..lxl * plane } else { 0..0 };
    // with a single interior plane the low and high edges coincide
    let single = lxl == 1;
    let tag = |phase: Phase, field: FieldId, side: Side| Tag {
        step,
        phase,
        field,
        side,
    };

    // ---- exchange 1: post-stream g edge planes (moments halo) ----
    // my low edge fills the left neighbour's HIGH halo and vice versa
    pack_x_plane(&st.g, nvel, ln, plane, 1, &mut st.send_buf);
    rank.isend(rank.left(), tag(Phase::Moments, FieldId::G, Side::High),
               &st.send_buf)?;
    pack_x_plane(&st.g, nvel, ln, plane, lxl, &mut st.send_buf);
    rank.isend(rank.right(), tag(Phase::Moments, FieldId::G, Side::Low),
               &st.send_buf)?;

    if !cfg.overlap {
        // bulk-sync: halos first, then everything in one sweep
        let lo = rank.wait(tag(Phase::Moments, FieldId::G, Side::Low))?;
        unpack_checked(&mut st.g, nvel, ln, plane, 0, &lo)?;
        let hi = rank.wait(tag(Phase::Moments, FieldId::G, Side::High))?;
        unpack_checked(&mut st.g, nvel, ln, plane, lxl + 1, &hi)?;
        phi_from_g_range(vs, &st.g, &mut st.phi, ln, 0..ln, pool, vvl);
        gradient_fd_range(&d.local, &st.phi, &mut st.grad, &mut st.lap,
                          interior.clone(), pool, vvl);
        collide_lattice_range(vs, p, &mut st.f, &mut st.g, &st.grad,
                              &st.lap, ln, interior.clone(), pool, vvl,
                              scalar);
    } else {
        // overlap: the interior needs no halo — compute it while the
        // edge planes are in flight
        phi_from_g_range(vs, &st.g, &mut st.phi, ln, interior.clone(),
                         pool, vvl);
        gradient_fd_range(&d.local, &st.phi, &mut st.grad, &mut st.lap,
                          deep.clone(), pool, vvl);
        collide_lattice_range(vs, p, &mut st.f, &mut st.g, &st.grad,
                              &st.lap, ln, deep.clone(), pool, vvl, scalar);
        // complete the edges on arrival
        let lo = rank.wait(tag(Phase::Moments, FieldId::G, Side::Low))?;
        unpack_checked(&mut st.g, nvel, ln, plane, 0, &lo)?;
        let hi = rank.wait(tag(Phase::Moments, FieldId::G, Side::High))?;
        unpack_checked(&mut st.g, nvel, ln, plane, lxl + 1, &hi)?;
        phi_from_g_range(vs, &st.g, &mut st.phi, ln, halo_lo, pool, vvl);
        phi_from_g_range(vs, &st.g, &mut st.phi, ln, halo_hi, pool, vvl);
        gradient_fd_range(&d.local, &st.phi, &mut st.grad, &mut st.lap,
                          edge_lo.clone(), pool, vvl);
        collide_lattice_range(vs, p, &mut st.f, &mut st.g, &st.grad,
                              &st.lap, ln, edge_lo.clone(), pool, vvl,
                              scalar);
        if !single {
            gradient_fd_range(&d.local, &st.phi, &mut st.grad, &mut st.lap,
                              edge_hi.clone(), pool, vvl);
            collide_lattice_range(vs, p, &mut st.f, &mut st.g, &st.grad,
                                  &st.lap, ln, edge_hi.clone(), pool, vvl,
                                  scalar);
        }
    }

    // ---- exchange 2: post-collision f,g edge planes (stream halo) ----
    pack_x_plane(&st.f, nvel, ln, plane, 1, &mut st.send_buf);
    rank.isend(rank.left(), tag(Phase::Stream, FieldId::F, Side::High),
               &st.send_buf)?;
    pack_x_plane(&st.f, nvel, ln, plane, lxl, &mut st.send_buf);
    rank.isend(rank.right(), tag(Phase::Stream, FieldId::F, Side::Low),
               &st.send_buf)?;
    pack_x_plane(&st.g, nvel, ln, plane, 1, &mut st.send_buf);
    rank.isend(rank.left(), tag(Phase::Stream, FieldId::G, Side::High),
               &st.send_buf)?;
    pack_x_plane(&st.g, nvel, ln, plane, lxl, &mut st.send_buf);
    rank.isend(rank.right(), tag(Phase::Stream, FieldId::G, Side::Low),
               &st.send_buf)?;

    let wait_stream_halos =
        |rank: &mut Rank, st: &mut RankState| -> Result<()> {
            let f_lo = rank.wait(tag(Phase::Stream, FieldId::F, Side::Low))?;
            unpack_checked(&mut st.f, nvel, ln, plane, 0, &f_lo)?;
            let f_hi =
                rank.wait(tag(Phase::Stream, FieldId::F, Side::High))?;
            unpack_checked(&mut st.f, nvel, ln, plane, lxl + 1, &f_hi)?;
            let g_lo = rank.wait(tag(Phase::Stream, FieldId::G, Side::Low))?;
            unpack_checked(&mut st.g, nvel, ln, plane, 0, &g_lo)?;
            let g_hi =
                rank.wait(tag(Phase::Stream, FieldId::G, Side::High))?;
            unpack_checked(&mut st.g, nvel, ln, plane, lxl + 1, &g_hi)?;
            Ok(())
        };

    if !cfg.overlap {
        wait_stream_halos(rank, st)?;
        stream_range(vs, table, &st.f, &mut st.f_tmp, interior.clone(),
                     pool, vvl);
        stream_range(vs, table, &st.g, &mut st.g_tmp, interior, pool, vvl);
    } else {
        // deep destinations pull only post-collision interior sources —
        // exactly what the StreamTable exception lists certify
        debug_assert!((0..nvel).all(|i| {
            table.pull_sources_within(i, deep.clone(), &d.interior())
        }));
        stream_range(vs, table, &st.f, &mut st.f_tmp, deep.clone(), pool,
                     vvl);
        stream_range(vs, table, &st.g, &mut st.g_tmp, deep, pool, vvl);
        wait_stream_halos(rank, st)?;
        stream_range(vs, table, &st.f, &mut st.f_tmp, edge_lo.clone(),
                     pool, vvl);
        stream_range(vs, table, &st.g, &mut st.g_tmp, edge_lo, pool, vvl);
        if !single {
            stream_range(vs, table, &st.f, &mut st.f_tmp, edge_hi.clone(),
                         pool, vvl);
            stream_range(vs, table, &st.g, &mut st.g_tmp, edge_hi, pool,
                         vvl);
        }
    }
    std::mem::swap(&mut st.f, &mut st.f_tmp);
    std::mem::swap(&mut st.g, &mut st.g_tmp);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lb::init::init_spinodal;
    use crate::lb::model::{d2q9, d3q19};
    use crate::lb::propagation::stream;

    fn spinodal(vs: &VelSet, geom: &Geometry) -> (Vec<f64>, Vec<f64>) {
        let n = geom.nsites();
        let mut f = vec![0.0; vs.nvel * n];
        let mut g = vec![0.0; vs.nvel * n];
        init_spinodal(vs, &FeParams::default(), geom, &mut f, &mut g, 0.05,
                      77);
        (f, g)
    }

    /// Single-domain reference: the unfused per-kernel pipeline.
    fn reference(vs: &VelSet, geom: &Geometry, steps: u64)
                 -> (Vec<f64>, Vec<f64>) {
        let p = FeParams::default();
        let n = geom.nsites();
        let (mut f, mut g) = spinodal(vs, geom);
        let pool = TlpPool::serial();
        for _ in 0..steps {
            let mut phi = vec![0.0; n];
            let mut grad = vec![0.0; 3 * n];
            let mut lap = vec![0.0; n];
            crate::lb::moments::phi_from_g(vs, &g, &mut phi, n, &pool, 8);
            crate::free_energy::gradient::gradient_fd(geom, &phi, &mut grad,
                                                      &mut lap, &pool, 8);
            crate::lb::collision::collide_lattice(vs, &p, &mut f, &mut g,
                                                  &grad, &lap, n, &pool, 8,
                                                  false);
            let mut fs = vec![0.0; vs.nvel * n];
            let mut gs = vec![0.0; vs.nvel * n];
            stream(vs, geom, &f, &mut fs, &pool, 8);
            stream(vs, geom, &g, &mut gs, &pool, 8);
            f = fs;
            g = gs;
        }
        (f, g)
    }

    #[test]
    fn concurrent_ranks_match_single_domain_bitwise() {
        let vs = d3q19();
        let geom = Geometry::new(11, 4, 3); // 11 -> uneven splits
        let steps = 4;
        let (f_want, g_want) = reference(vs, &geom, steps);
        for ranks in [1usize, 2, 3] {
            for overlap in [false, true] {
                let (mut f, mut g) = spinodal(vs, &geom);
                let cfg = CommsConfig { ranks, overlap,
                                        ..CommsConfig::default() };
                let rep = run_decomposed(&geom, vs, &FeParams::default(),
                                         &mut f, &mut g, steps, &cfg)
                    .unwrap();
                assert_eq!(rep.ranks.len(), ranks);
                assert_eq!(f, f_want, "ranks={ranks} overlap={overlap}");
                assert_eq!(g, g_want, "ranks={ranks} overlap={overlap}");
            }
        }
    }

    #[test]
    fn single_plane_slabs_work() {
        // lxl == 1 everywhere: edge planes coincide, deep interior empty
        let vs = d2q9();
        let geom = Geometry::new(4, 6, 1);
        let steps = 3;
        let (f_want, g_want) = reference(vs, &geom, steps);
        for overlap in [false, true] {
            let (mut f, mut g) = spinodal(vs, &geom);
            let cfg = CommsConfig { ranks: 4, overlap,
                                    ..CommsConfig::default() };
            run_decomposed(&geom, vs, &FeParams::default(), &mut f, &mut g,
                           steps, &cfg)
                .unwrap();
            assert_eq!(f, f_want, "overlap={overlap}");
            assert_eq!(g, g_want, "overlap={overlap}");
        }
    }

    #[test]
    fn report_accounts_for_all_ranks() {
        let vs = d2q9();
        let geom = Geometry::new(10, 4, 1);
        let (mut f, mut g) = spinodal(vs, &geom);
        let cfg = CommsConfig { ranks: 3, ..CommsConfig::default() };
        let rep = run_decomposed(&geom, vs, &FeParams::default(), &mut f,
                                 &mut g, 5, &cfg)
            .unwrap();
        let owned: usize = rep.ranks.iter().map(|r| r.interior_sites).sum();
        assert_eq!(owned, geom.nsites());
        for r in &rep.ranks {
            assert_eq!(r.steps, 5);
            // 2 + 4 messages per step
            assert_eq!(r.msgs_sent, 30);
            assert!(r.bytes_sent > 0);
            assert!(r.compute_s >= 0.0 && r.wait_s >= 0.0);
        }
        assert!(rep.mlups() >= 0.0);
        assert!(rep.max_wait_s() >= 0.0);
    }

    #[test]
    fn world_rejects_bad_shapes_and_vvl() {
        let vs = d2q9();
        let geom = Geometry::new(8, 4, 1);
        assert!(CommsWorld::new(geom, CommsConfig {
            vvl: 3,
            ..CommsConfig::default()
        })
        .is_err(), "unsupported VVL must be rejected up front");
        // scalar mode takes any vvl (it only sets the chunk grain)
        assert!(CommsWorld::new(geom, CommsConfig {
            vvl: 3,
            scalar: true,
            ..CommsConfig::default()
        })
        .is_ok());
        let world =
            CommsWorld::new(geom, CommsConfig::default()).unwrap();
        let mut short = vec![0.0; 7];
        let mut g = vec![0.0; vs.nvel * geom.nsites()];
        assert!(world
            .run(vs, &FeParams::default(), &mut short, &mut g, 1)
            .is_err());
    }
}
