//! Rank launcher: rendezvous that turns N OS processes into a socket
//! or hybrid world, plus a local process spawner.
//!
//! A socket run has one **driver** process (holding the session
//! controller endpoint — the analog of
//! [`crate::comms::transport::ChannelTransport::mesh_with_controller`]'s
//! controller) and N **rank** processes. Only the driver's address must
//! be known up front; everything else is negotiated:
//!
//! ```text
//! driver                                rank process (x N)
//! ──────────────────────────            ─────────────────────────────
//! RankServer::bind(addr)
//!                                       connect_rank(addr, want_rank):
//!                                         connect to the driver,
//!                                         bind an ephemeral listener,
//!                              ◄─ Hello   {want_rank, listen_port, host}
//! rendezvous(n, payload):
//!   accept n Hellos,
//!   assign rank ids
//!   (host-grouped),
//!   Welcome ─►                            {rank, nranks, payload,
//!                                          roster of rank addresses}
//!                                         peer mesh: connect to every
//!                                         lower rank (PeerHello{rank}),
//!                                         accept every higher rank
//!   returns the controller              returns (SocketTransport,
//!   SocketTransport                              payload)
//! ```
//!
//! The `payload` is an opaque setup blob the driver broadcasts in the
//! `Welcome` — the CLI ships the full run configuration (TOML) through
//! it so every rank process rebuilds an identical simulation from one
//! source of truth, and an example can ship nothing and parameterise its
//! children by argv instead.
//!
//! Rank ids: a rank may request a specific id (`want_rank`, what
//! [`spawn_local`] children do) or leave it to the driver (what manually
//! started multi-host ranks do). Requesting a taken or out-of-range id
//! fails the whole rendezvous.
//!
//! Anonymous id assignment is **topology-aware**: every `Hello` carries
//! the sender's host tag ([`rank_host`]: `TARGETDP_HOST`, else the
//! kernel hostname, else `"localhost"`), and the driver hands each
//! host's ranks *consecutive* free ids, hosts in first-arrival order
//! ([`host_grouped_order`]). Grid worlds number ranks z-fastest
//! (`rank = (cx·py + cy)·pz + cz`), so consecutive ids are grid
//! neighbours — host-grouped blocks keep as many of a rank's six face
//! exchanges as possible on intra-host sockets instead of the network.
//!
//! The peer mesh cannot deadlock: a rank's listener is bound *before*
//! its `Hello` is sent, so every address in the roster is already
//! accepting by the time any peer sees it; lower ranks accept while
//! higher ranks connect, and the driver writes all `Welcome`s without
//! waiting on any rank.
//!
//! # Hybrid worlds
//!
//! A **hybrid** run replaces the one-process-per-rank shape with one
//! process per *host*: each connecting process declares in its `Hello`
//! how many ranks it will carry (`nlocal`), the driver assigns it a
//! *contiguous block* of rank ids (explicit `want_rank` = the block's
//! first id; anonymous blocks are host-grouped exactly like socket
//! ranks), and the `Welcome` carries the whole **host→ranks map** —
//! every block's `(first, count, address)` — instead of a per-rank
//! roster. Each host process then builds its in-process channel mesh
//! locally and dials **one** socket per lower-block host
//! (lower-`first` blocks accept, higher connect: the socket world's
//! lower-connect/higher-accept rule lifted to host pairs), so a
//! 2-host world has exactly three streams: host↔host, and one
//! driver↔host each. [`RankServer::rendezvous_hosts`] /
//! [`connect_host`] drive this; [`connect_world`] lets one entry point
//! serve whichever mode the driver runs. Both modes speak the same
//! version-3 handshake — a socket world is the degenerate case where
//! every block has `count == 1`.
//!
//! Deployment shapes (see `docs/architecture.md` for the walkthrough):
//!
//! * **spawn-local** — the driver binds `127.0.0.1:0` and spawns
//!   children of its own executable ([`spawn_local`] /
//!   [`LocalRanks::spawn`]): `targetdp run --transport socket` (one
//!   child per rank) or `--transport hybrid`
//!   ([`LocalRanks::spawn_hosts`]: one child per host, which on a
//!   single machine means one child carrying every rank).
//! * **multi-host** — the driver binds a routable address
//!   (`--rank-server host:port`) and the operator starts
//!   `targetdp rank --connect host:port` on each host — adding
//!   `--local-ranks N` to carry that host's N ranks in one process
//!   when the driver runs hybrid.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::process::Child;
use std::time::{Duration, Instant};

use crate::comms::hybrid::{self, EofPolicy, HostLink, HybridTransport};
use crate::comms::socket::SocketTransport;
use crate::error::{Error, Result};

/// How long the whole rendezvous (and each handshake read inside it) may
/// take before a missing rank process is reported instead of waited on.
pub const RENDEZVOUS_TIMEOUT: Duration = Duration::from_secs(60);

/// Cap on the `Welcome` setup payload (a run config is a few hundred
/// bytes; anything larger than this is corruption).
const MAX_PAYLOAD_LEN: usize = 16 << 20;
/// Cap on one roster address string.
const MAX_ADDR_LEN: usize = 256;
/// Cap on the world size a `Welcome` may announce.
const MAX_NRANKS: usize = 1 << 16;

const HELLO_MAGIC: [u8; 4] = *b"TDPH";
const WELCOME_MAGIC: [u8; 4] = *b"TDPR";
const PEER_MAGIC: [u8; 4] = *b"TDPP";
/// Version 3: `Hello` declares how many ranks the connecting process
/// carries (`nlocal`), and `Welcome` grew a mode byte plus a
/// host-block roster (`(first, count, addr)` per host) in hybrid mode.
const HANDSHAKE_VERSION: u8 = 3;
/// `Welcome` mode byte: one process per rank, per-rank roster.
const MODE_SOCKET: u8 = 0;
/// `Welcome` mode byte: one process per host, host-block roster.
const MODE_HYBRID: u8 = 1;
/// Cap on the `Hello` host tag string.
const MAX_HOST_LEN: usize = 256;

fn resolve(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()
        .map_err(|e| {
            Error::Invalid(format!(
                "comms launcher: cannot resolve {addr:?}: {e}"
            ))
        })?
        .next()
        .ok_or_else(|| {
            Error::Invalid(format!(
                "comms launcher: {addr:?} resolves to no address"
            ))
        })
}

fn read_exact_checked(stream: &mut TcpStream, buf: &mut [u8], what: &str)
                      -> Result<()> {
    stream.read_exact(buf).map_err(|e| {
        Error::Invalid(format!(
            "comms launcher: short read in {what} handshake: {e}"
        ))
    })
}

fn check_magic(got: &[u8; 4], want: &[u8; 4], version: u8, what: &str)
               -> Result<()> {
    if got != want {
        return Err(Error::Invalid(format!(
            "comms launcher: bad {what} magic {got:02x?}"
        )));
    }
    if version != HANDSHAKE_VERSION {
        return Err(Error::Invalid(format!(
            "comms launcher: {what} handshake version {version} (want \
             {HANDSHAKE_VERSION})"
        )));
    }
    Ok(())
}

/// The host tag this process advertises in its `Hello`: the
/// `TARGETDP_HOST` env var if set (the operator's override for
/// placement experiments), else the kernel hostname, else
/// `"localhost"`.
pub fn rank_host() -> String {
    if let Ok(h) = std::env::var("TARGETDP_HOST") {
        let h = h.trim().to_string();
        if !h.is_empty() {
            return h;
        }
    }
    if let Ok(h) = std::fs::read_to_string("/proc/sys/kernel/hostname") {
        let h = h.trim().to_string();
        if !h.is_empty() {
            return h;
        }
    }
    "localhost".to_string()
}

/// Topology-aware placement order for anonymous ranks: given the host
/// tags in arrival order, return the arrival indices reordered so each
/// host's ranks are consecutive (hosts kept in first-arrival order).
/// Filling free rank slots in this order co-locates grid-neighbour
/// ranks: ids are z-fastest on the Cartesian grid, so a host's
/// consecutive block shares the most faces.
pub fn host_grouped_order(hosts: &[String]) -> Vec<usize> {
    let mut groups: Vec<(&str, Vec<usize>)> = Vec::new();
    for (i, h) in hosts.iter().enumerate() {
        match groups.iter_mut().find(|(name, _)| *name == h.as_str()) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((h.as_str(), vec![i])),
        }
    }
    groups.into_iter().flat_map(|(_, idxs)| idxs).collect()
}

/// `Hello`: magic(4) version(1) want_rank(i64, -1 = any) listen_port(u16)
/// nlocal(u16) host_len(u16) host (UTF-8). `nlocal` is how many ranks
/// this process will carry (1 for a socket-world rank process); with
/// `nlocal > 1`, `want_rank` names the *first* rank of the requested
/// contiguous block.
fn write_hello(stream: &mut TcpStream, want_rank: Option<usize>,
               listen_port: u16, nlocal: usize, host: &str)
               -> Result<()> {
    let mut cut = host.len().min(MAX_HOST_LEN);
    while !host.is_char_boundary(cut) {
        cut -= 1;
    }
    let host = &host.as_bytes()[..cut];
    let nlocal = u16::try_from(nlocal)
        .ok()
        .filter(|&n| n > 0)
        .ok_or_else(|| {
            Error::Invalid(format!(
                "comms launcher: a process cannot carry {nlocal} ranks"
            ))
        })?;
    let mut buf = Vec::with_capacity(19 + host.len());
    buf.extend_from_slice(&HELLO_MAGIC);
    buf.push(HANDSHAKE_VERSION);
    let want: i64 = match want_rank {
        Some(r) => i64::try_from(r).map_err(|_| {
            Error::Invalid(format!("comms launcher: rank {r} out of range"))
        })?,
        None => -1,
    };
    buf.extend_from_slice(&want.to_le_bytes());
    buf.extend_from_slice(&listen_port.to_le_bytes());
    buf.extend_from_slice(&nlocal.to_le_bytes());
    buf.extend_from_slice(&(host.len() as u16).to_le_bytes());
    buf.extend_from_slice(host);
    stream.write_all(&buf).map_err(Error::from)
}

fn read_hello(stream: &mut TcpStream)
              -> Result<(Option<usize>, u16, usize, String)> {
    let mut buf = [0u8; 19];
    read_exact_checked(stream, &mut buf, "Hello")?;
    check_magic(&buf[..4].try_into().unwrap(), &HELLO_MAGIC, buf[4],
                "Hello")?;
    let want = i64::from_le_bytes(buf[5..13].try_into().unwrap());
    let port = u16::from_le_bytes(buf[13..15].try_into().unwrap());
    let nlocal =
        u16::from_le_bytes(buf[15..17].try_into().unwrap()) as usize;
    let hlen = u16::from_le_bytes(buf[17..19].try_into().unwrap()) as usize;
    if nlocal == 0 {
        return Err(Error::Invalid(
            "comms launcher: Hello from a process carrying 0 ranks".into(),
        ));
    }
    if hlen > MAX_HOST_LEN {
        return Err(Error::Invalid(format!(
            "comms launcher: Hello host tag of {hlen} bytes"
        )));
    }
    let mut host = vec![0u8; hlen];
    read_exact_checked(stream, &mut host, "Hello host")?;
    let host = String::from_utf8(host).map_err(|_| {
        Error::Invalid("comms launcher: Hello host is not UTF-8".into())
    })?;
    let want = if want < 0 { None } else { Some(want as usize) };
    Ok((want, port, nlocal, host))
}

/// One host's slice of a hybrid world, as announced in the `Welcome`
/// host-block roster: the contiguous rank block `[first, first+count)`
/// served by one host process at `addr`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostBlock {
    /// First rank id of the block.
    pub first: usize,
    /// Number of ranks the host process carries.
    pub count: usize,
    /// The host process's peer listener, `ip:port`.
    pub addr: String,
}

impl HostBlock {
    /// The rank ids of this block.
    fn ranks(&self) -> std::ops::Range<usize> {
        self.first..self.first + self.count
    }
}

/// A decoded `Welcome`, by mode.
enum WelcomeMsg {
    /// One process per rank: this process is `rank`, the roster is one
    /// `ip:port` per rank.
    Socket { rank: usize, nranks: usize, payload: Vec<u8>,
             roster: Vec<String> },
    /// One process per host: this process carries the block starting
    /// at `first`; the roster is one [`HostBlock`] per host, sorted by
    /// `first` and covering `0..nranks` exactly.
    Hybrid { first: usize, nranks: usize, payload: Vec<u8>,
             blocks: Vec<HostBlock> },
}

/// `Welcome`: magic(4) version(1) mode(1) rank(u32) nranks(u32)
/// payload_len(u32) payload, then the mode's roster. Mode 0 (socket):
/// `nranks` length-prefixed (u16) UTF-8 `ip:port` entries, rank order.
/// Mode 1 (hybrid): nblocks(u16), then per block first(u32) count(u32)
/// addr_len(u16) addr — blocks sorted by `first`, covering `0..nranks`
/// contiguously; `rank` is the recipient's block `first`.
fn write_welcome_head(buf: &mut Vec<u8>, mode: u8, rank: usize,
                      nranks: usize, payload: &[u8]) {
    buf.extend_from_slice(&WELCOME_MAGIC);
    buf.push(HANDSHAKE_VERSION);
    buf.push(mode);
    buf.extend_from_slice(&(rank as u32).to_le_bytes());
    buf.extend_from_slice(&(nranks as u32).to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
}

fn write_welcome(stream: &mut TcpStream, rank: usize, nranks: usize,
                 payload: &[u8], roster: &[SocketAddr]) -> Result<()> {
    let mut buf = Vec::with_capacity(18 + payload.len() + 24 * nranks);
    write_welcome_head(&mut buf, MODE_SOCKET, rank, nranks, payload);
    for addr in roster {
        let s = addr.to_string();
        buf.extend_from_slice(&(s.len() as u16).to_le_bytes());
        buf.extend_from_slice(s.as_bytes());
    }
    stream.write_all(&buf).map_err(Error::from)
}

fn write_welcome_hybrid(stream: &mut TcpStream, first: usize,
                        nranks: usize, payload: &[u8],
                        blocks: &[HostBlock]) -> Result<()> {
    let mut buf =
        Vec::with_capacity(20 + payload.len() + 32 * blocks.len());
    write_welcome_head(&mut buf, MODE_HYBRID, first, nranks, payload);
    buf.extend_from_slice(&(blocks.len() as u16).to_le_bytes());
    for b in blocks {
        buf.extend_from_slice(&(b.first as u32).to_le_bytes());
        buf.extend_from_slice(&(b.count as u32).to_le_bytes());
        buf.extend_from_slice(&(b.addr.len() as u16).to_le_bytes());
        buf.extend_from_slice(b.addr.as_bytes());
    }
    stream.write_all(&buf).map_err(Error::from)
}

fn read_addr_entry(stream: &mut TcpStream) -> Result<String> {
    let mut len = [0u8; 2];
    read_exact_checked(stream, &mut len, "Welcome roster")?;
    let len = u16::from_le_bytes(len) as usize;
    if len > MAX_ADDR_LEN {
        return Err(Error::Invalid(format!(
            "comms launcher: roster address of {len} bytes"
        )));
    }
    let mut addr = vec![0u8; len];
    read_exact_checked(stream, &mut addr, "Welcome roster")?;
    String::from_utf8(addr).map_err(|_| {
        Error::Invalid("comms launcher: roster address is not UTF-8".into())
    })
}

fn read_welcome(stream: &mut TcpStream) -> Result<WelcomeMsg> {
    let mut head = [0u8; 18];
    read_exact_checked(stream, &mut head, "Welcome")?;
    check_magic(&head[..4].try_into().unwrap(), &WELCOME_MAGIC, head[4],
                "Welcome")?;
    let mode = head[5];
    let rank = u32::from_le_bytes(head[6..10].try_into().unwrap()) as usize;
    let nranks =
        u32::from_le_bytes(head[10..14].try_into().unwrap()) as usize;
    let plen =
        u32::from_le_bytes(head[14..18].try_into().unwrap()) as usize;
    if nranks == 0 || nranks > MAX_NRANKS || rank >= nranks {
        return Err(Error::Invalid(format!(
            "comms launcher: Welcome assigns rank {rank} of {nranks}"
        )));
    }
    if plen > MAX_PAYLOAD_LEN {
        return Err(Error::Invalid(format!(
            "comms launcher: Welcome payload of {plen} bytes exceeds cap"
        )));
    }
    let mut payload = vec![0u8; plen];
    read_exact_checked(stream, &mut payload, "Welcome")?;
    match mode {
        MODE_SOCKET => {
            let mut roster = Vec::with_capacity(nranks);
            for _ in 0..nranks {
                roster.push(read_addr_entry(stream)?);
            }
            Ok(WelcomeMsg::Socket { rank, nranks, payload, roster })
        }
        MODE_HYBRID => {
            let mut nb = [0u8; 2];
            read_exact_checked(stream, &mut nb, "Welcome blocks")?;
            let nblocks = u16::from_le_bytes(nb) as usize;
            if nblocks == 0 || nblocks > nranks {
                return Err(Error::Invalid(format!(
                    "comms launcher: Welcome with {nblocks} host blocks \
                     for {nranks} ranks"
                )));
            }
            let mut blocks = Vec::with_capacity(nblocks);
            let mut next = 0usize;
            for _ in 0..nblocks {
                let mut fc = [0u8; 8];
                read_exact_checked(stream, &mut fc, "Welcome blocks")?;
                let first =
                    u32::from_le_bytes(fc[..4].try_into().unwrap())
                        as usize;
                let count =
                    u32::from_le_bytes(fc[4..].try_into().unwrap())
                        as usize;
                let addr = read_addr_entry(stream)?;
                // blocks must tile 0..nranks in order — gaps, overlaps
                // or empty blocks are corruption
                if first != next || count == 0 {
                    return Err(Error::Invalid(format!(
                        "comms launcher: Welcome host block \
                         ({first},{count}) breaks the contiguous tiling \
                         at rank {next}"
                    )));
                }
                next += count;
                blocks.push(HostBlock { first, count, addr });
            }
            if next != nranks {
                return Err(Error::Invalid(format!(
                    "comms launcher: Welcome host blocks cover {next} of \
                     {nranks} ranks"
                )));
            }
            Ok(WelcomeMsg::Hybrid { first: rank, nranks, payload, blocks })
        }
        v => Err(Error::Invalid(format!(
            "comms launcher: unknown Welcome mode {v}"
        ))),
    }
}

/// `PeerHello`: magic(4) version(1) rank(u32) — sent by the connecting
/// (higher-id peers are connected *to*) side of a rank↔rank link.
fn write_peer_hello(stream: &mut TcpStream, rank: usize) -> Result<()> {
    let mut buf = Vec::with_capacity(9);
    buf.extend_from_slice(&PEER_MAGIC);
    buf.push(HANDSHAKE_VERSION);
    buf.extend_from_slice(&(rank as u32).to_le_bytes());
    stream.write_all(&buf).map_err(Error::from)
}

fn read_peer_hello(stream: &mut TcpStream) -> Result<usize> {
    let mut buf = [0u8; 9];
    read_exact_checked(stream, &mut buf, "PeerHello")?;
    check_magic(&buf[..4].try_into().unwrap(), &PEER_MAGIC, buf[4],
                "PeerHello")?;
    Ok(u32::from_le_bytes(buf[5..9].try_into().unwrap()) as usize)
}

/// Accept one connection with a deadline (the listener is switched to
/// non-blocking and polled so a missing peer cannot hang the rendezvous
/// forever).
fn accept_deadline(listener: &TcpListener, deadline: Instant, what: &str)
                   -> Result<(TcpStream, SocketAddr)> {
    listener.set_nonblocking(true)?;
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                stream.set_nonblocking(false)?;
                stream.set_read_timeout(Some(RENDEZVOUS_TIMEOUT))?;
                return Ok((stream, peer));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(Error::Invalid(format!(
                        "comms launcher: timed out waiting for {what}"
                    )));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// The driver's side of the rendezvous: a bound listener waiting for N
/// rank processes.
pub struct RankServer {
    listener: TcpListener,
}

impl RankServer {
    /// Bind the rank server. `"127.0.0.1:0"` picks a free loopback port
    /// for a spawn-local run; a routable `host:port` serves a multi-host
    /// one.
    pub fn bind(addr: &str) -> Result<RankServer> {
        let listener = TcpListener::bind(addr).map_err(|e| {
            Error::Invalid(format!(
                "comms launcher: cannot bind rank server on {addr:?}: {e}"
            ))
        })?;
        Ok(RankServer { listener })
    }

    /// The bound address — what rank processes pass to `--connect` (and
    /// what [`spawn_local`] forwards for you).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().map_err(Error::from)
    }

    /// Run the rendezvous: accept `nranks` Hellos, assign rank ids
    /// (explicit requests first; anonymous ranks host-grouped into the
    /// free slots, [`host_grouped_order`]), broadcast the `Welcome`
    /// (with `payload` and the full roster), and return the
    /// **controller** transport (endpoint id `nranks`) the driver
    /// hands to [`crate::comms::CommsWorld::remote_session`].
    pub fn rendezvous(self, nranks: usize, payload: &[u8])
                      -> Result<SocketTransport> {
        if nranks == 0 || nranks > MAX_NRANKS {
            return Err(Error::Invalid(format!(
                "comms launcher: cannot rendezvous {nranks} ranks"
            )));
        }
        let deadline = Instant::now() + RENDEZVOUS_TIMEOUT;
        let mut pending: Vec<(TcpStream, Option<usize>, SocketAddr,
                              String)> = Vec::with_capacity(nranks);
        while pending.len() < nranks {
            let what = format!(
                "rank processes ({}/{nranks} connected)",
                pending.len()
            );
            let (mut stream, peer) =
                accept_deadline(&self.listener, deadline, &what)?;
            let (want, port, nlocal, host) = read_hello(&mut stream)?;
            if nlocal != 1 {
                return Err(Error::Invalid(format!(
                    "comms launcher: a host process carrying {nlocal} \
                     ranks connected to a socket-world rendezvous (run \
                     the driver with --transport hybrid)"
                )));
            }
            // the roster advertises the rank's listener on the address
            // this connection actually came from — the interface peers
            // can route to
            pending.push((stream, want, SocketAddr::new(peer.ip(), port),
                          host));
        }
        // explicit requests claim their slots first ...
        let mut by_rank: Vec<Option<(TcpStream, SocketAddr)>> =
            (0..nranks).map(|_| None).collect();
        let mut anonymous = Vec::new();
        let mut hosts = Vec::new();
        for (stream, want, addr, host) in pending {
            match want {
                Some(r) => {
                    if r >= nranks {
                        return Err(Error::Invalid(format!(
                            "comms launcher: a process asked for rank {r} \
                             of a {nranks}-rank world"
                        )));
                    }
                    if by_rank[r].is_some() {
                        return Err(Error::Invalid(format!(
                            "comms launcher: two processes asked for rank \
                             {r}"
                        )));
                    }
                    by_rank[r] = Some((stream, addr));
                }
                None => {
                    anonymous.push(Some((stream, addr)));
                    hosts.push(host);
                }
            }
        }
        // ... then host-grouped blocks fill the gaps: each host's ranks
        // land on consecutive ids, which are z-neighbours on the grid
        let order = host_grouped_order(&hosts);
        let mut order = order.into_iter();
        for slot in by_rank.iter_mut() {
            if slot.is_none() {
                *slot = anonymous[order.next().expect("counts match")]
                    .take();
            }
        }
        debug_assert!(order.next().is_none(), "counts match");
        let roster: Vec<SocketAddr> = by_rank
            .iter()
            .map(|s| s.as_ref().expect("every slot filled").1)
            .collect();
        let mut conns = Vec::with_capacity(nranks);
        for (r, slot) in by_rank.into_iter().enumerate() {
            let (mut stream, _) = slot.expect("every slot filled");
            write_welcome(&mut stream, r, nranks, payload, &roster)?;
            conns.push((r, stream));
        }
        SocketTransport::assemble(nranks, nranks, conns)
    }

    /// The hybrid-world rendezvous: accept host processes until their
    /// declared rank counts sum to `nranks`, assign each a contiguous
    /// rank block (explicit `want_rank` requests claim `[want,
    /// want+nlocal)` first; anonymous hosts are placed in host-grouped
    /// arrival order into the lowest free runs), broadcast the
    /// mode-1 `Welcome` with the full host-block roster, and return
    /// the **controller** transport (endpoint id `nranks`) for
    /// [`crate::comms::CommsWorld::remote_session`]. The controller
    /// holds one link per host; a link that closes before every
    /// resident rank's report crossed it surfaces a mid-run host death
    /// as an error.
    pub fn rendezvous_hosts(self, nranks: usize, payload: &[u8])
                            -> Result<HybridTransport> {
        if nranks == 0 || nranks > MAX_NRANKS {
            return Err(Error::Invalid(format!(
                "comms launcher: cannot rendezvous {nranks} ranks"
            )));
        }
        let deadline = Instant::now() + RENDEZVOUS_TIMEOUT;
        let mut pending: Vec<(TcpStream, Option<usize>, SocketAddr,
                              usize, String)> = Vec::new();
        let mut total = 0usize;
        while total < nranks {
            let what = format!(
                "host processes ({total}/{nranks} ranks connected)"
            );
            let (mut stream, peer) =
                accept_deadline(&self.listener, deadline, &what)?;
            let (want, port, nlocal, host) = read_hello(&mut stream)?;
            total += nlocal;
            if total > nranks {
                return Err(Error::Invalid(format!(
                    "comms launcher: host processes declare {total} \
                     ranks for a {nranks}-rank world"
                )));
            }
            pending.push((stream, want, SocketAddr::new(peer.ip(), port),
                          nlocal, host));
        }
        // explicit requests claim their contiguous blocks first ...
        let mut claimed = vec![false; nranks];
        let mut placed: Vec<(TcpStream, HostBlock)> = Vec::new();
        let mut anonymous = Vec::new();
        let mut hosts = Vec::new();
        for (stream, want, addr, nlocal, host) in pending {
            match want {
                Some(first) => {
                    if first + nlocal > nranks
                        || claimed[first..first + nlocal]
                            .iter()
                            .any(|&c| c)
                    {
                        return Err(Error::Invalid(format!(
                            "comms launcher: a host process asked for \
                             ranks {first}..{} of a {nranks}-rank world \
                             (out of range or already claimed)",
                            first + nlocal
                        )));
                    }
                    claimed[first..first + nlocal].fill(true);
                    placed.push((stream, HostBlock {
                        first,
                        count: nlocal,
                        addr: addr.to_string(),
                    }));
                }
                None => {
                    anonymous.push(Some((stream, addr, nlocal)));
                    hosts.push(host);
                }
            }
        }
        // ... then anonymous hosts fill the lowest free runs in
        // host-grouped arrival order: two processes tagged with the
        // same host land on adjacent blocks, keeping their shared grid
        // faces off the network
        for i in host_grouped_order(&hosts) {
            let (stream, addr, nlocal) =
                anonymous[i].take().expect("each host placed once");
            let first = find_free_run(&claimed, nlocal).ok_or_else(|| {
                Error::Invalid(format!(
                    "comms launcher: no contiguous run of {nlocal} free \
                     rank ids left for a host process (explicit \
                     requests fragmented the id space)"
                ))
            })?;
            claimed[first..first + nlocal].fill(true);
            placed.push((stream, HostBlock {
                first,
                count: nlocal,
                addr: addr.to_string(),
            }));
        }
        placed.sort_by_key(|(_, b)| b.first);
        let blocks: Vec<HostBlock> =
            placed.iter().map(|(_, b)| b.clone()).collect();
        let mut links = Vec::with_capacity(placed.len());
        for (mut stream, block) in placed {
            write_welcome_hybrid(&mut stream, block.first, nranks,
                                 payload, &blocks)?;
            let last = block.first + block.count - 1;
            links.push(HostLink {
                stream,
                peers: block.ranks().collect(),
                eof: EofPolicy::UnlessReports {
                    expect: block.count,
                    msg: format!(
                        "comms hybrid: the host process carrying ranks \
                         {}..={last} closed its link before delivering \
                         every report — host process died mid-run",
                        block.first
                    ),
                },
            });
        }
        let mut eps = hybrid::assemble(nranks, &[nranks], links)?;
        Ok(eps.pop().expect("one controller endpoint"))
    }
}

/// Lowest index of a contiguous run of `len` unclaimed rank ids, if
/// one exists.
fn find_free_run(claimed: &[bool], len: usize) -> Option<usize> {
    let mut run = 0usize;
    for (i, &c) in claimed.iter().enumerate() {
        if c {
            run = 0;
        } else {
            run += 1;
            if run == len {
                return Some(i + 1 - len);
            }
        }
    }
    None
}

/// What [`connect_world`] built, depending on the mode the driver's
/// `Welcome` announced.
pub enum WorldEndpoints {
    /// A socket-world rank endpoint (one process per rank).
    Socket(SocketTransport),
    /// A hybrid host process's endpoints: one per resident rank, in
    /// block order. Each is served by its own thread
    /// ([`crate::comms::serve_rank`]); they share the host's links.
    Hybrid(Vec<HybridTransport>),
}

/// The connecting process's side of the rendezvous: dial the driver at
/// `server` (`host:port`), declare how many ranks this process carries
/// (`nlocal`; 1 for a plain rank process) and optionally which block
/// it wants (`want_first` = the first rank id), then build whichever
/// world the driver's `Welcome` announces — a per-rank socket mesh or
/// a hybrid host process. Returns the endpoints plus the driver's
/// opaque setup payload.
pub fn connect_world(server: &str, want_first: Option<usize>,
                     nlocal: usize)
                     -> Result<(WorldEndpoints, Vec<u8>)> {
    let addr = resolve(server)?;
    let mut ctl = TcpStream::connect_timeout(&addr, RENDEZVOUS_TIMEOUT)
        .map_err(|e| {
            Error::Invalid(format!(
                "comms launcher: cannot reach rank server {server}: {e}"
            ))
        })?;
    ctl.set_read_timeout(Some(RENDEZVOUS_TIMEOUT))?;
    // accept higher peers on the interface that routes to the driver
    // (its IP is how they will see us in the roster)
    let listener =
        TcpListener::bind(SocketAddr::new(ctl.local_addr()?.ip(), 0))?;
    let listen_port = listener.local_addr()?.port();
    write_hello(&mut ctl, want_first, listen_port, nlocal, &rank_host())?;
    match read_welcome(&mut ctl)? {
        WelcomeMsg::Socket { rank, nranks, payload, roster } => {
            if nlocal != 1 {
                return Err(Error::Invalid(format!(
                    "comms launcher: the driver runs a socket world but \
                     this process carries {nlocal} ranks"
                )));
            }
            check_assignment(want_first, rank)?;
            if roster.len() != nranks {
                return Err(Error::Invalid(format!(
                    "comms launcher: roster of {} for {nranks} ranks",
                    roster.len()
                )));
            }
            let mut conns: Vec<(usize, TcpStream)> =
                Vec::with_capacity(nranks);
            // connect downward: every lower rank is already listening
            // (its listener was bound before its Hello was sent)
            for (j, peer_addr) in roster.iter().enumerate().take(rank) {
                let a = resolve(peer_addr)?;
                let mut s =
                    TcpStream::connect_timeout(&a, RENDEZVOUS_TIMEOUT)
                        .map_err(|e| {
                            Error::Invalid(format!(
                                "comms launcher: rank {rank} cannot \
                                 reach rank {j} at {peer_addr}: {e}"
                            ))
                        })?;
                s.set_read_timeout(Some(RENDEZVOUS_TIMEOUT))?;
                write_peer_hello(&mut s, rank)?;
                conns.push((j, s));
            }
            // accept upward
            let deadline = Instant::now() + RENDEZVOUS_TIMEOUT;
            let mut seen = vec![false; nranks];
            for _ in rank + 1..nranks {
                let what = format!("higher-rank peers of rank {rank}");
                let (mut stream, _) =
                    accept_deadline(&listener, deadline, &what)?;
                let j = read_peer_hello(&mut stream)?;
                if j <= rank || j >= nranks || seen[j] {
                    return Err(Error::Invalid(format!(
                        "comms launcher: rank {rank} got a peer hello \
                         from invalid rank {j}"
                    )));
                }
                seen[j] = true;
                conns.push((j, stream));
            }
            // the rendezvous connection doubles as the control link
            conns.push((nranks, ctl));
            let transport = SocketTransport::assemble(rank, nranks,
                                                      conns)?;
            Ok((WorldEndpoints::Socket(transport), payload))
        }
        WelcomeMsg::Hybrid { first, nranks, payload, blocks } => {
            check_assignment(want_first, first)?;
            let mine = blocks
                .iter()
                .find(|b| b.first == first)
                .ok_or_else(|| {
                    Error::Invalid(format!(
                        "comms launcher: Welcome assigns block {first} \
                         but no host block starts there"
                    ))
                })?
                .clone();
            if mine.count != nlocal {
                return Err(Error::Invalid(format!(
                    "comms launcher: driver assigned a {}-rank block to \
                     a process carrying {nlocal} ranks",
                    mine.count
                )));
            }
            let locals: Vec<usize> = mine.ranks().collect();
            let mut links = Vec::with_capacity(blocks.len());
            // host-pair links, lower-first connects / higher accepts —
            // the socket world's deadlock-free rule, per host pair
            for b in blocks.iter().filter(|b| b.first < first) {
                let a = resolve(&b.addr)?;
                let mut s =
                    TcpStream::connect_timeout(&a, RENDEZVOUS_TIMEOUT)
                        .map_err(|e| {
                            Error::Invalid(format!(
                                "comms launcher: host block {first} \
                                 cannot reach host block {} at {}: {e}",
                                b.first, b.addr
                            ))
                        })?;
                s.set_read_timeout(Some(RENDEZVOUS_TIMEOUT))?;
                write_peer_hello(&mut s, first)?;
                links.push(HostLink {
                    stream: s,
                    peers: b.ranks().collect(),
                    eof: EofPolicy::Silent,
                });
            }
            let higher =
                blocks.iter().filter(|b| b.first > first).count();
            let deadline = Instant::now() + RENDEZVOUS_TIMEOUT;
            let mut seen = vec![false; blocks.len()];
            for _ in 0..higher {
                let what =
                    format!("higher host blocks of block {first}");
                let (mut stream, _) =
                    accept_deadline(&listener, deadline, &what)?;
                let j = read_peer_hello(&mut stream)?;
                let bi = blocks
                    .iter()
                    .position(|b| b.first == j)
                    .filter(|&bi| j > first && !seen[bi])
                    .ok_or_else(|| {
                        Error::Invalid(format!(
                            "comms launcher: host block {first} got a \
                             peer hello from invalid block {j}"
                        ))
                    })?;
                seen[bi] = true;
                links.push(HostLink {
                    stream,
                    peers: blocks[bi].ranks().collect(),
                    eof: EofPolicy::Silent,
                });
            }
            // the rendezvous connection doubles as the control link;
            // its clean close before Shutdown means the driver is gone
            links.push(HostLink {
                stream: ctl,
                peers: vec![nranks],
                eof: EofPolicy::Always(
                    "comms hybrid: the session controller closed the \
                     connection without Shutdown — driver gone"
                        .to_string(),
                ),
            });
            let eps = hybrid::assemble(nranks, &locals, links)?;
            Ok((WorldEndpoints::Hybrid(eps), payload))
        }
    }
}

fn check_assignment(want: Option<usize>, got: usize) -> Result<()> {
    match want {
        Some(w) if w != got => Err(Error::Invalid(format!(
            "comms launcher: asked for rank {w}, driver assigned {got}"
        ))),
        _ => Ok(()),
    }
}

/// The rank process's side of a **socket**-world rendezvous: dial the
/// driver, optionally requesting a specific rank id, and build this
/// rank's per-peer socket mesh. Returns the transport plus the
/// driver's opaque setup payload. The returned endpoint is what
/// [`crate::comms::serve_rank`] runs on. Errors if the driver runs a
/// hybrid world — use [`connect_world`] (or [`connect_host`]) there.
pub fn connect_rank(server: &str, want_rank: Option<usize>)
                    -> Result<(SocketTransport, Vec<u8>)> {
    match connect_world(server, want_rank, 1)? {
        (WorldEndpoints::Socket(t), payload) => Ok((t, payload)),
        (WorldEndpoints::Hybrid(_), _) => Err(Error::Invalid(
            "comms launcher: the driver runs a hybrid world; \
             connect_rank builds socket worlds only"
                .into(),
        )),
    }
}

/// The host process's side of a **hybrid**-world rendezvous: dial the
/// driver, declare a block of `nlocal` ranks (optionally pinned to
/// start at `want_first`), and build one [`HybridTransport`] endpoint
/// per resident rank — each to be driven by its own
/// [`crate::comms::serve_rank`] thread. Errors if the driver runs a
/// socket world.
pub fn connect_host(server: &str, want_first: Option<usize>,
                    nlocal: usize)
                    -> Result<(Vec<HybridTransport>, Vec<u8>)> {
    match connect_world(server, want_first, nlocal)? {
        (WorldEndpoints::Hybrid(eps), payload) => Ok((eps, payload)),
        (WorldEndpoints::Socket(_), _) => Err(Error::Invalid(
            "comms launcher: the driver runs a socket world; \
             connect_host builds hybrid host processes only"
                .into(),
        )),
    }
}

/// Spawn `nranks` local rank processes of **this executable** on this
/// host, each invoked as `<current_exe> <extra...> --connect <connect>
/// --rank <i>`. The children inherit stdio so rank-side errors stay
/// visible. Used by `targetdp run --transport socket` (extra =
/// `["rank"]`) and by examples that re-enter themselves in a child role.
pub fn spawn_local(nranks: usize, connect: &str, extra: &[String])
                   -> Result<Vec<Child>> {
    let exe = std::env::current_exe().map_err(|e| {
        Error::Invalid(format!(
            "comms launcher: cannot find this executable to spawn ranks: \
             {e}"
        ))
    })?;
    let mut children = Vec::with_capacity(nranks);
    for r in 0..nranks {
        let spawned = std::process::Command::new(&exe)
            .args(extra)
            .arg("--connect")
            .arg(connect)
            .arg("--rank")
            .arg(r.to_string())
            .spawn();
        match spawned {
            Ok(child) => children.push(child),
            Err(e) => {
                for c in &mut children {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                return Err(Error::Invalid(format!(
                    "comms launcher: failed to spawn rank process {r}: {e}"
                )));
            }
        }
    }
    Ok(children)
}

/// One host process to spawn for a hybrid world: which contiguous rank
/// block it carries and any extra environment variables (the hybrid
/// smoke tests use `TARGETDP_HOST` here to give loopback children
/// distinct host tags).
pub struct HostSpec {
    /// First rank id of the block.
    pub first: usize,
    /// Number of resident ranks (>= 1).
    pub count: usize,
    /// Extra environment variables for the child process.
    pub env: Vec<(String, String)>,
}

/// Spawn one local **host process** of this executable per [`HostSpec`],
/// each invoked as `<current_exe> <extra...> --connect <connect>
/// --rank <first> --local-ranks <count>` with the spec's extra
/// environment applied. The hybrid counterpart of [`spawn_local`]: one
/// child per host, not per rank.
pub fn spawn_local_hosts(hosts: &[HostSpec], connect: &str,
                         extra: &[String]) -> Result<Vec<Child>> {
    let exe = std::env::current_exe().map_err(|e| {
        Error::Invalid(format!(
            "comms launcher: cannot find this executable to spawn hosts: \
             {e}"
        ))
    })?;
    let mut children = Vec::with_capacity(hosts.len());
    for h in hosts {
        let mut cmd = std::process::Command::new(&exe);
        cmd.args(extra)
            .arg("--connect")
            .arg(connect)
            .arg("--rank")
            .arg(h.first.to_string())
            .arg("--local-ranks")
            .arg(h.count.to_string());
        for (k, v) in &h.env {
            cmd.env(k, v);
        }
        match cmd.spawn() {
            Ok(child) => children.push(child),
            Err(e) => {
                for c in &mut children {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                return Err(Error::Invalid(format!(
                    "comms launcher: failed to spawn host process for \
                     ranks {}..={}: {e}",
                    h.first,
                    h.first + h.count - 1
                )));
            }
        }
    }
    Ok(children)
}

/// Owner of spawn-local rank processes: [`LocalRanks::wait`] reaps them
/// and fails if any exited non-zero; dropping unawaited kills the
/// stragglers so an aborted driver never leaks rank processes.
pub struct LocalRanks {
    children: Vec<Child>,
}

impl LocalRanks {
    /// [`spawn_local`] wrapped in the reaping owner.
    pub fn spawn(nranks: usize, connect: &str, extra: &[String])
                 -> Result<LocalRanks> {
        Ok(LocalRanks { children: spawn_local(nranks, connect, extra)? })
    }

    /// [`spawn_local_hosts`] wrapped in the reaping owner: one child
    /// per host process of a hybrid world.
    pub fn spawn_hosts(hosts: &[HostSpec], connect: &str,
                       extra: &[String]) -> Result<LocalRanks> {
        Ok(LocalRanks {
            children: spawn_local_hosts(hosts, connect, extra)?,
        })
    }

    /// Block until every rank process exits; error if any failed.
    pub fn wait(mut self) -> Result<()> {
        let children = std::mem::take(&mut self.children);
        let mut failures = Vec::new();
        for (r, mut c) in children.into_iter().enumerate() {
            match c.wait() {
                Ok(status) if status.success() => {}
                Ok(status) => failures
                    .push(format!("rank process {r} exited with {status}")),
                Err(e) => failures.push(format!("rank process {r}: {e}")),
            }
        }
        if failures.is_empty() {
            Ok(())
        } else {
            Err(Error::Invalid(format!(
                "comms launcher: {}",
                failures.join("; ")
            )))
        }
    }
}

impl Drop for LocalRanks {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
        }
        for c in &mut self.children {
            let _ = c.wait();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::transport::Transport;

    /// Full loopback rendezvous: N connect_rank threads + the server.
    fn loopback(nranks: usize, wants: Vec<Option<usize>>)
                -> (Vec<SocketTransport>, SocketTransport, Vec<Vec<u8>>) {
        let server = RankServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let joins: Vec<_> = wants
            .into_iter()
            .map(|want| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    connect_rank(&addr, want).unwrap()
                })
            })
            .collect();
        let ctl = server.rendezvous(nranks, b"setup-blob").unwrap();
        let mut ranks: Vec<Option<SocketTransport>> =
            (0..nranks).map(|_| None).collect();
        let mut payloads = Vec::new();
        for j in joins {
            let (t, payload) = j.join().unwrap();
            payloads.push(payload);
            let r = t.rank();
            assert!(ranks[r].is_none(), "duplicate rank {r}");
            ranks[r] = Some(t);
        }
        (ranks.into_iter().map(Option::unwrap).collect(), ctl, payloads)
    }

    #[test]
    fn rendezvous_assigns_requested_ranks_and_ships_payload() {
        let (ranks, ctl, payloads) =
            loopback(3, vec![Some(2), Some(0), Some(1)]);
        assert_eq!(ranks.len(), 3);
        assert_eq!(ctl.rank(), 3, "controller id is nranks");
        assert_eq!(ctl.nranks(), 3);
        for (r, t) in ranks.iter().enumerate() {
            assert_eq!(t.rank(), r);
            assert_eq!(t.nranks(), 3);
        }
        for p in payloads {
            assert_eq!(p, b"setup-blob");
        }
    }

    #[test]
    fn anonymous_ranks_get_distinct_ids() {
        let (ranks, _ctl, _) = loopback(2, vec![None, None]);
        let ids: Vec<usize> = ranks.iter().map(|t| t.rank()).collect();
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn mesh_routes_rank_to_rank_and_controller_both_ways() {
        let (mut ranks, mut ctl, _) = loopback(3, vec![Some(0), Some(1),
                                                       Some(2)]);
        // rank 0 -> rank 2 (a connection rank 2 initiated)
        ranks[0].send_bytes(2, vec![1]).unwrap();
        assert_eq!(ranks[2].recv_bytes().unwrap(), vec![1]);
        // rank 2 -> rank 0 (same connection, other direction)
        ranks[2].send_bytes(0, vec![2]).unwrap();
        assert_eq!(ranks[0].recv_bytes().unwrap(), vec![2]);
        // controller -> rank and back over the rendezvous link
        ctl.send_bytes(1, vec![3]).unwrap();
        assert_eq!(ranks[1].recv_bytes().unwrap(), vec![3]);
        ranks[1].send_bytes(3, vec![4]).unwrap();
        assert_eq!(ctl.recv_bytes().unwrap(), vec![4]);
    }

    #[test]
    fn single_rank_rendezvous_works() {
        let (mut ranks, _ctl, _) = loopback(1, vec![None]);
        // no peer sockets, but the periodic self-seam still loops back
        ranks[0].send_bytes(0, vec![9]).unwrap();
        assert_eq!(ranks[0].recv_bytes().unwrap(), vec![9]);
    }

    #[test]
    fn host_grouping_colocates_each_hosts_ranks() {
        let h = |s: &str| s.to_string();
        // interleaved arrivals from two hosts: each host's ranks end up
        // on consecutive ids, hosts in first-arrival order
        let hosts = vec![h("a"), h("b"), h("a"), h("b")];
        assert_eq!(host_grouped_order(&hosts), vec![0, 2, 1, 3]);
        // three hosts, uneven counts
        let hosts = vec![h("n1"), h("n2"), h("n3"), h("n2"), h("n2")];
        assert_eq!(host_grouped_order(&hosts), vec![0, 1, 3, 4, 2]);
        // one host degenerates to arrival order
        let hosts = vec![h("x"), h("x"), h("x")];
        assert_eq!(host_grouped_order(&hosts), vec![0, 1, 2]);
        assert_eq!(host_grouped_order(&[]), Vec::<usize>::new());
    }

    #[test]
    fn rank_host_is_never_empty() {
        // env override > kernel hostname > "localhost" — whichever arm
        // fires, every Hello carries a usable placement tag
        assert!(!rank_host().is_empty());
    }

    #[test]
    fn out_of_range_rank_request_fails_rendezvous() {
        let server = RankServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let child = std::thread::spawn(move || {
            // the server rejects the request, so this side sees an error
            // (a dropped connection mid-handshake) rather than a world
            connect_rank(&addr, Some(7))
        });
        assert!(server.rendezvous(1, &[]).is_err());
        assert!(child.join().unwrap().is_err());
    }

    /// Full loopback **hybrid** rendezvous: one connect_host thread per
    /// `(want_first, nlocal)` spec + the driver. Returns every rank
    /// endpoint in rank order plus the controller.
    fn hybrid_loopback(nranks: usize, specs: Vec<(Option<usize>, usize)>)
                       -> (Vec<HybridTransport>, HybridTransport,
                           Vec<Vec<u8>>) {
        let server = RankServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let joins: Vec<_> = specs
            .into_iter()
            .map(|(want, nlocal)| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    connect_host(&addr, want, nlocal).unwrap()
                })
            })
            .collect();
        let ctl = server.rendezvous_hosts(nranks, b"hy-blob").unwrap();
        let mut ranks: Vec<Option<HybridTransport>> =
            (0..nranks).map(|_| None).collect();
        let mut payloads = Vec::new();
        for j in joins {
            let (eps, payload) = j.join().unwrap();
            payloads.push(payload);
            for t in eps {
                let r = t.rank();
                assert!(ranks[r].is_none(), "duplicate rank {r}");
                ranks[r] = Some(t);
            }
        }
        (ranks.into_iter().map(Option::unwrap).collect(), ctl, payloads)
    }

    #[test]
    fn hybrid_rendezvous_routes_channels_inside_and_sockets_between() {
        // 2 hosts x 2 ranks: blocks [0,1] and [2,3]
        let (mut ranks, mut ctl, payloads) =
            hybrid_loopback(4, vec![(Some(0), 2), (Some(2), 2)]);
        for p in payloads {
            assert_eq!(p, b"hy-blob");
        }
        for (r, t) in ranks.iter().enumerate() {
            assert_eq!(t.rank(), r);
            assert_eq!(t.nranks(), 4);
        }
        // co-hosted peers are channel links, cross-host ones sockets
        assert!(ranks[0].peer_is_intra(1));
        assert!(!ranks[0].peer_is_intra(2));
        assert!(ranks[3].peer_is_intra(2));
        assert!(!ranks[3].peer_is_intra(1));
        // intra-host hop
        ranks[0].send_bytes(1, vec![1]).unwrap();
        assert_eq!(ranks[1].recv_bytes().unwrap(), vec![1]);
        // inter-host hop, both directions over the one host-pair stream
        ranks[1].send_bytes(2, vec![2]).unwrap();
        assert_eq!(ranks[2].recv_bytes().unwrap(), vec![2]);
        ranks[3].send_bytes(0, vec![3]).unwrap();
        assert_eq!(ranks[0].recv_bytes().unwrap(), vec![3]);
        // controller <-> rank over each host's driver link
        ctl.send_bytes(3, vec![4]).unwrap();
        assert_eq!(ranks[3].recv_bytes().unwrap(), vec![4]);
        ranks[3].send_bytes(4, vec![5]).unwrap();
        assert_eq!(ctl.recv_bytes().unwrap(), vec![5]);
    }

    #[test]
    fn hybrid_rendezvous_serves_single_rank_blocks_too() {
        // a hybrid world where every host carries one rank degenerates
        // to the socket shape, but over host links
        let (mut ranks, mut ctl, _) =
            hybrid_loopback(2, vec![(Some(0), 1), (Some(1), 1)]);
        assert!(!ranks[0].peer_is_intra(1));
        ranks[0].send_bytes(1, vec![7]).unwrap();
        assert_eq!(ranks[1].recv_bytes().unwrap(), vec![7]);
        ctl.send_bytes(0, vec![8]).unwrap();
        assert_eq!(ranks[0].recv_bytes().unwrap(), vec![8]);
    }

    #[test]
    fn hybrid_rendezvous_rejects_overlapping_blocks() {
        let server = RankServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let a = addr.clone();
        let h1 = std::thread::spawn(move || connect_host(&a, Some(0), 3));
        let h2 =
            std::thread::spawn(move || connect_host(&addr, Some(2), 2));
        // 3 + 2 = 5 ranks declared for a 4-rank world: the driver
        // rejects before placement even considers the overlap
        assert!(server.rendezvous_hosts(4, &[]).is_err());
        assert!(h1.join().unwrap().is_err()
                    || h2.join().unwrap().is_err());
    }

    #[test]
    fn socket_rendezvous_rejects_host_processes() {
        let server = RankServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let child =
            std::thread::spawn(move || connect_host(&addr, Some(0), 2));
        assert!(server.rendezvous(2, &[]).is_err());
        assert!(child.join().unwrap().is_err());
    }

    #[test]
    fn find_free_run_picks_lowest_fit() {
        let c = |bits: &[u8]| -> Vec<bool> {
            bits.iter().map(|&b| b == 1).collect()
        };
        assert_eq!(find_free_run(&c(&[0, 0, 0, 0]), 2), Some(0));
        assert_eq!(find_free_run(&c(&[1, 0, 0, 1]), 2), Some(1));
        assert_eq!(find_free_run(&c(&[1, 0, 1, 0, 0]), 2), Some(3));
        assert_eq!(find_free_run(&c(&[1, 0, 1, 0]), 2), None);
        assert_eq!(find_free_run(&c(&[0]), 1), Some(0));
    }
}
