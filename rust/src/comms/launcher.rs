//! Rank launcher: rendezvous that turns N OS processes into a socket
//! world, plus a local process spawner.
//!
//! A socket run has one **driver** process (holding the session
//! controller endpoint — the analog of
//! [`crate::comms::transport::ChannelTransport::mesh_with_controller`]'s
//! controller) and N **rank** processes. Only the driver's address must
//! be known up front; everything else is negotiated:
//!
//! ```text
//! driver                                rank process (x N)
//! ──────────────────────────            ─────────────────────────────
//! RankServer::bind(addr)
//!                                       connect_rank(addr, want_rank):
//!                                         connect to the driver,
//!                                         bind an ephemeral listener,
//!                              ◄─ Hello   {want_rank, listen_port, host}
//! rendezvous(n, payload):
//!   accept n Hellos,
//!   assign rank ids
//!   (host-grouped),
//!   Welcome ─►                            {rank, nranks, payload,
//!                                          roster of rank addresses}
//!                                         peer mesh: connect to every
//!                                         lower rank (PeerHello{rank}),
//!                                         accept every higher rank
//!   returns the controller              returns (SocketTransport,
//!   SocketTransport                              payload)
//! ```
//!
//! The `payload` is an opaque setup blob the driver broadcasts in the
//! `Welcome` — the CLI ships the full run configuration (TOML) through
//! it so every rank process rebuilds an identical simulation from one
//! source of truth, and an example can ship nothing and parameterise its
//! children by argv instead.
//!
//! Rank ids: a rank may request a specific id (`want_rank`, what
//! [`spawn_local`] children do) or leave it to the driver (what manually
//! started multi-host ranks do). Requesting a taken or out-of-range id
//! fails the whole rendezvous.
//!
//! Anonymous id assignment is **topology-aware**: every `Hello` carries
//! the sender's host tag ([`rank_host`]: `TARGETDP_HOST`, else the
//! kernel hostname, else `"localhost"`), and the driver hands each
//! host's ranks *consecutive* free ids, hosts in first-arrival order
//! ([`host_grouped_order`]). Grid worlds number ranks z-fastest
//! (`rank = (cx·py + cy)·pz + cz`), so consecutive ids are grid
//! neighbours — host-grouped blocks keep as many of a rank's six face
//! exchanges as possible on intra-host sockets instead of the network.
//!
//! The peer mesh cannot deadlock: a rank's listener is bound *before*
//! its `Hello` is sent, so every address in the roster is already
//! accepting by the time any peer sees it; lower ranks accept while
//! higher ranks connect, and the driver writes all `Welcome`s without
//! waiting on any rank.
//!
//! Deployment shapes (see `docs/architecture.md` for the walkthrough):
//!
//! * **spawn-local** — the driver binds `127.0.0.1:0` and spawns N
//!   children of its own executable ([`spawn_local`] /
//!   [`LocalRanks::spawn`]): `targetdp run --transport socket`.
//! * **multi-host** — the driver binds a routable address
//!   (`--rank-server host:port`) and the operator starts
//!   `targetdp rank --connect host:port` on each host.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::process::Child;
use std::time::{Duration, Instant};

use crate::comms::socket::SocketTransport;
use crate::error::{Error, Result};

/// How long the whole rendezvous (and each handshake read inside it) may
/// take before a missing rank process is reported instead of waited on.
pub const RENDEZVOUS_TIMEOUT: Duration = Duration::from_secs(60);

/// Cap on the `Welcome` setup payload (a run config is a few hundred
/// bytes; anything larger than this is corruption).
const MAX_PAYLOAD_LEN: usize = 16 << 20;
/// Cap on one roster address string.
const MAX_ADDR_LEN: usize = 256;
/// Cap on the world size a `Welcome` may announce.
const MAX_NRANKS: usize = 1 << 16;

const HELLO_MAGIC: [u8; 4] = *b"TDPH";
const WELCOME_MAGIC: [u8; 4] = *b"TDPR";
const PEER_MAGIC: [u8; 4] = *b"TDPP";
const HANDSHAKE_VERSION: u8 = 2;
/// Cap on the `Hello` host tag string.
const MAX_HOST_LEN: usize = 256;

fn resolve(addr: &str) -> Result<SocketAddr> {
    addr.to_socket_addrs()
        .map_err(|e| {
            Error::Invalid(format!(
                "comms launcher: cannot resolve {addr:?}: {e}"
            ))
        })?
        .next()
        .ok_or_else(|| {
            Error::Invalid(format!(
                "comms launcher: {addr:?} resolves to no address"
            ))
        })
}

fn read_exact_checked(stream: &mut TcpStream, buf: &mut [u8], what: &str)
                      -> Result<()> {
    stream.read_exact(buf).map_err(|e| {
        Error::Invalid(format!(
            "comms launcher: short read in {what} handshake: {e}"
        ))
    })
}

fn check_magic(got: &[u8; 4], want: &[u8; 4], version: u8, what: &str)
               -> Result<()> {
    if got != want {
        return Err(Error::Invalid(format!(
            "comms launcher: bad {what} magic {got:02x?}"
        )));
    }
    if version != HANDSHAKE_VERSION {
        return Err(Error::Invalid(format!(
            "comms launcher: {what} handshake version {version} (want \
             {HANDSHAKE_VERSION})"
        )));
    }
    Ok(())
}

/// The host tag this process advertises in its `Hello`: the
/// `TARGETDP_HOST` env var if set (the operator's override for
/// placement experiments), else the kernel hostname, else
/// `"localhost"`.
pub fn rank_host() -> String {
    if let Ok(h) = std::env::var("TARGETDP_HOST") {
        let h = h.trim().to_string();
        if !h.is_empty() {
            return h;
        }
    }
    if let Ok(h) = std::fs::read_to_string("/proc/sys/kernel/hostname") {
        let h = h.trim().to_string();
        if !h.is_empty() {
            return h;
        }
    }
    "localhost".to_string()
}

/// Topology-aware placement order for anonymous ranks: given the host
/// tags in arrival order, return the arrival indices reordered so each
/// host's ranks are consecutive (hosts kept in first-arrival order).
/// Filling free rank slots in this order co-locates grid-neighbour
/// ranks: ids are z-fastest on the Cartesian grid, so a host's
/// consecutive block shares the most faces.
pub fn host_grouped_order(hosts: &[String]) -> Vec<usize> {
    let mut groups: Vec<(&str, Vec<usize>)> = Vec::new();
    for (i, h) in hosts.iter().enumerate() {
        match groups.iter_mut().find(|(name, _)| *name == h.as_str()) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((h.as_str(), vec![i])),
        }
    }
    groups.into_iter().flat_map(|(_, idxs)| idxs).collect()
}

/// `Hello`: magic(4) version(1) want_rank(i64, -1 = any) listen_port(u16)
/// host_len(u16) host (UTF-8).
fn write_hello(stream: &mut TcpStream, want_rank: Option<usize>,
               listen_port: u16, host: &str) -> Result<()> {
    let mut cut = host.len().min(MAX_HOST_LEN);
    while !host.is_char_boundary(cut) {
        cut -= 1;
    }
    let host = &host.as_bytes()[..cut];
    let mut buf = Vec::with_capacity(17 + host.len());
    buf.extend_from_slice(&HELLO_MAGIC);
    buf.push(HANDSHAKE_VERSION);
    let want: i64 = match want_rank {
        Some(r) => i64::try_from(r).map_err(|_| {
            Error::Invalid(format!("comms launcher: rank {r} out of range"))
        })?,
        None => -1,
    };
    buf.extend_from_slice(&want.to_le_bytes());
    buf.extend_from_slice(&listen_port.to_le_bytes());
    buf.extend_from_slice(&(host.len() as u16).to_le_bytes());
    buf.extend_from_slice(host);
    stream.write_all(&buf).map_err(Error::from)
}

fn read_hello(stream: &mut TcpStream)
              -> Result<(Option<usize>, u16, String)> {
    let mut buf = [0u8; 17];
    read_exact_checked(stream, &mut buf, "Hello")?;
    check_magic(&buf[..4].try_into().unwrap(), &HELLO_MAGIC, buf[4],
                "Hello")?;
    let want = i64::from_le_bytes(buf[5..13].try_into().unwrap());
    let port = u16::from_le_bytes(buf[13..15].try_into().unwrap());
    let hlen = u16::from_le_bytes(buf[15..17].try_into().unwrap()) as usize;
    if hlen > MAX_HOST_LEN {
        return Err(Error::Invalid(format!(
            "comms launcher: Hello host tag of {hlen} bytes"
        )));
    }
    let mut host = vec![0u8; hlen];
    read_exact_checked(stream, &mut host, "Hello host")?;
    let host = String::from_utf8(host).map_err(|_| {
        Error::Invalid("comms launcher: Hello host is not UTF-8".into())
    })?;
    let want = if want < 0 { None } else { Some(want as usize) };
    Ok((want, port, host))
}

/// `Welcome`: magic(4) version(1) rank(u32) nranks(u32) payload_len(u32)
/// payload, then `nranks` length-prefixed (u16) UTF-8 `ip:port` roster
/// entries, rank order.
fn write_welcome(stream: &mut TcpStream, rank: usize, nranks: usize,
                 payload: &[u8], roster: &[SocketAddr]) -> Result<()> {
    let mut buf = Vec::with_capacity(17 + payload.len() + 24 * nranks);
    buf.extend_from_slice(&WELCOME_MAGIC);
    buf.push(HANDSHAKE_VERSION);
    buf.extend_from_slice(&(rank as u32).to_le_bytes());
    buf.extend_from_slice(&(nranks as u32).to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
    for addr in roster {
        let s = addr.to_string();
        buf.extend_from_slice(&(s.len() as u16).to_le_bytes());
        buf.extend_from_slice(s.as_bytes());
    }
    stream.write_all(&buf).map_err(Error::from)
}

fn read_welcome(stream: &mut TcpStream)
                -> Result<(usize, usize, Vec<u8>, Vec<String>)> {
    let mut head = [0u8; 17];
    read_exact_checked(stream, &mut head, "Welcome")?;
    check_magic(&head[..4].try_into().unwrap(), &WELCOME_MAGIC, head[4],
                "Welcome")?;
    let rank = u32::from_le_bytes(head[5..9].try_into().unwrap()) as usize;
    let nranks = u32::from_le_bytes(head[9..13].try_into().unwrap()) as usize;
    let plen = u32::from_le_bytes(head[13..17].try_into().unwrap()) as usize;
    if nranks == 0 || nranks > MAX_NRANKS || rank >= nranks {
        return Err(Error::Invalid(format!(
            "comms launcher: Welcome assigns rank {rank} of {nranks}"
        )));
    }
    if plen > MAX_PAYLOAD_LEN {
        return Err(Error::Invalid(format!(
            "comms launcher: Welcome payload of {plen} bytes exceeds cap"
        )));
    }
    let mut payload = vec![0u8; plen];
    read_exact_checked(stream, &mut payload, "Welcome")?;
    let mut roster = Vec::with_capacity(nranks);
    for _ in 0..nranks {
        let mut len = [0u8; 2];
        read_exact_checked(stream, &mut len, "Welcome roster")?;
        let len = u16::from_le_bytes(len) as usize;
        if len > MAX_ADDR_LEN {
            return Err(Error::Invalid(format!(
                "comms launcher: roster address of {len} bytes"
            )));
        }
        let mut addr = vec![0u8; len];
        read_exact_checked(stream, &mut addr, "Welcome roster")?;
        roster.push(String::from_utf8(addr).map_err(|_| {
            Error::Invalid(
                "comms launcher: roster address is not UTF-8".into(),
            )
        })?);
    }
    Ok((rank, nranks, payload, roster))
}

/// `PeerHello`: magic(4) version(1) rank(u32) — sent by the connecting
/// (higher-id peers are connected *to*) side of a rank↔rank link.
fn write_peer_hello(stream: &mut TcpStream, rank: usize) -> Result<()> {
    let mut buf = Vec::with_capacity(9);
    buf.extend_from_slice(&PEER_MAGIC);
    buf.push(HANDSHAKE_VERSION);
    buf.extend_from_slice(&(rank as u32).to_le_bytes());
    stream.write_all(&buf).map_err(Error::from)
}

fn read_peer_hello(stream: &mut TcpStream) -> Result<usize> {
    let mut buf = [0u8; 9];
    read_exact_checked(stream, &mut buf, "PeerHello")?;
    check_magic(&buf[..4].try_into().unwrap(), &PEER_MAGIC, buf[4],
                "PeerHello")?;
    Ok(u32::from_le_bytes(buf[5..9].try_into().unwrap()) as usize)
}

/// Accept one connection with a deadline (the listener is switched to
/// non-blocking and polled so a missing peer cannot hang the rendezvous
/// forever).
fn accept_deadline(listener: &TcpListener, deadline: Instant, what: &str)
                   -> Result<(TcpStream, SocketAddr)> {
    listener.set_nonblocking(true)?;
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                stream.set_nonblocking(false)?;
                stream.set_read_timeout(Some(RENDEZVOUS_TIMEOUT))?;
                return Ok((stream, peer));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(Error::Invalid(format!(
                        "comms launcher: timed out waiting for {what}"
                    )));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// The driver's side of the rendezvous: a bound listener waiting for N
/// rank processes.
pub struct RankServer {
    listener: TcpListener,
}

impl RankServer {
    /// Bind the rank server. `"127.0.0.1:0"` picks a free loopback port
    /// for a spawn-local run; a routable `host:port` serves a multi-host
    /// one.
    pub fn bind(addr: &str) -> Result<RankServer> {
        let listener = TcpListener::bind(addr).map_err(|e| {
            Error::Invalid(format!(
                "comms launcher: cannot bind rank server on {addr:?}: {e}"
            ))
        })?;
        Ok(RankServer { listener })
    }

    /// The bound address — what rank processes pass to `--connect` (and
    /// what [`spawn_local`] forwards for you).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().map_err(Error::from)
    }

    /// Run the rendezvous: accept `nranks` Hellos, assign rank ids
    /// (explicit requests first; anonymous ranks host-grouped into the
    /// free slots, [`host_grouped_order`]), broadcast the `Welcome`
    /// (with `payload` and the full roster), and return the
    /// **controller** transport (endpoint id `nranks`) the driver
    /// hands to [`crate::comms::CommsWorld::remote_session`].
    pub fn rendezvous(self, nranks: usize, payload: &[u8])
                      -> Result<SocketTransport> {
        if nranks == 0 || nranks > MAX_NRANKS {
            return Err(Error::Invalid(format!(
                "comms launcher: cannot rendezvous {nranks} ranks"
            )));
        }
        let deadline = Instant::now() + RENDEZVOUS_TIMEOUT;
        let mut pending: Vec<(TcpStream, Option<usize>, SocketAddr,
                              String)> = Vec::with_capacity(nranks);
        while pending.len() < nranks {
            let what = format!(
                "rank processes ({}/{nranks} connected)",
                pending.len()
            );
            let (mut stream, peer) =
                accept_deadline(&self.listener, deadline, &what)?;
            let (want, port, host) = read_hello(&mut stream)?;
            // the roster advertises the rank's listener on the address
            // this connection actually came from — the interface peers
            // can route to
            pending.push((stream, want, SocketAddr::new(peer.ip(), port),
                          host));
        }
        // explicit requests claim their slots first ...
        let mut by_rank: Vec<Option<(TcpStream, SocketAddr)>> =
            (0..nranks).map(|_| None).collect();
        let mut anonymous = Vec::new();
        let mut hosts = Vec::new();
        for (stream, want, addr, host) in pending {
            match want {
                Some(r) => {
                    if r >= nranks {
                        return Err(Error::Invalid(format!(
                            "comms launcher: a process asked for rank {r} \
                             of a {nranks}-rank world"
                        )));
                    }
                    if by_rank[r].is_some() {
                        return Err(Error::Invalid(format!(
                            "comms launcher: two processes asked for rank \
                             {r}"
                        )));
                    }
                    by_rank[r] = Some((stream, addr));
                }
                None => {
                    anonymous.push(Some((stream, addr)));
                    hosts.push(host);
                }
            }
        }
        // ... then host-grouped blocks fill the gaps: each host's ranks
        // land on consecutive ids, which are z-neighbours on the grid
        let order = host_grouped_order(&hosts);
        let mut order = order.into_iter();
        for slot in by_rank.iter_mut() {
            if slot.is_none() {
                *slot = anonymous[order.next().expect("counts match")]
                    .take();
            }
        }
        debug_assert!(order.next().is_none(), "counts match");
        let roster: Vec<SocketAddr> = by_rank
            .iter()
            .map(|s| s.as_ref().expect("every slot filled").1)
            .collect();
        let mut conns = Vec::with_capacity(nranks);
        for (r, slot) in by_rank.into_iter().enumerate() {
            let (mut stream, _) = slot.expect("every slot filled");
            write_welcome(&mut stream, r, nranks, payload, &roster)?;
            conns.push((r, stream));
        }
        SocketTransport::assemble(nranks, nranks, conns)
    }
}

/// The rank process's side of the rendezvous: dial the driver at
/// `server` (`host:port`), optionally requesting a specific rank id, and
/// build this rank's full socket world. Returns the transport plus the
/// driver's opaque setup payload. The returned endpoint is what
/// [`crate::comms::serve_rank`] runs on.
pub fn connect_rank(server: &str, want_rank: Option<usize>)
                    -> Result<(SocketTransport, Vec<u8>)> {
    let addr = resolve(server)?;
    let mut ctl = TcpStream::connect_timeout(&addr, RENDEZVOUS_TIMEOUT)
        .map_err(|e| {
            Error::Invalid(format!(
                "comms launcher: cannot reach rank server {server}: {e}"
            ))
        })?;
    ctl.set_read_timeout(Some(RENDEZVOUS_TIMEOUT))?;
    // accept higher-id peers on the interface that routes to the driver
    // (its IP is how they will see us in the roster)
    let listener =
        TcpListener::bind(SocketAddr::new(ctl.local_addr()?.ip(), 0))?;
    let listen_port = listener.local_addr()?.port();
    write_hello(&mut ctl, want_rank, listen_port, &rank_host())?;
    let (rank, nranks, payload, roster) = read_welcome(&mut ctl)?;
    if let Some(want) = want_rank {
        if want != rank {
            return Err(Error::Invalid(format!(
                "comms launcher: asked for rank {want}, driver assigned \
                 {rank}"
            )));
        }
    }
    if roster.len() != nranks {
        return Err(Error::Invalid(format!(
            "comms launcher: roster of {} for {nranks} ranks",
            roster.len()
        )));
    }
    let mut conns: Vec<(usize, TcpStream)> = Vec::with_capacity(nranks);
    // connect downward: every lower rank is already listening (its
    // listener was bound before its Hello was sent)
    for (j, peer_addr) in roster.iter().enumerate().take(rank) {
        let a = resolve(peer_addr)?;
        let mut s = TcpStream::connect_timeout(&a, RENDEZVOUS_TIMEOUT)
            .map_err(|e| {
                Error::Invalid(format!(
                    "comms launcher: rank {rank} cannot reach rank {j} at \
                     {peer_addr}: {e}"
                ))
            })?;
        s.set_read_timeout(Some(RENDEZVOUS_TIMEOUT))?;
        write_peer_hello(&mut s, rank)?;
        conns.push((j, s));
    }
    // accept upward
    let deadline = Instant::now() + RENDEZVOUS_TIMEOUT;
    let mut seen = vec![false; nranks];
    for _ in rank + 1..nranks {
        let what = format!("higher-rank peers of rank {rank}");
        let (mut stream, _) =
            accept_deadline(&listener, deadline, &what)?;
        let j = read_peer_hello(&mut stream)?;
        if j <= rank || j >= nranks || seen[j] {
            return Err(Error::Invalid(format!(
                "comms launcher: rank {rank} got a peer hello from \
                 invalid rank {j}"
            )));
        }
        seen[j] = true;
        conns.push((j, stream));
    }
    // the rendezvous connection doubles as the control-plane link
    conns.push((nranks, ctl));
    let transport = SocketTransport::assemble(rank, nranks, conns)?;
    Ok((transport, payload))
}

/// Spawn `nranks` local rank processes of **this executable** on this
/// host, each invoked as `<current_exe> <extra...> --connect <connect>
/// --rank <i>`. The children inherit stdio so rank-side errors stay
/// visible. Used by `targetdp run --transport socket` (extra =
/// `["rank"]`) and by examples that re-enter themselves in a child role.
pub fn spawn_local(nranks: usize, connect: &str, extra: &[String])
                   -> Result<Vec<Child>> {
    let exe = std::env::current_exe().map_err(|e| {
        Error::Invalid(format!(
            "comms launcher: cannot find this executable to spawn ranks: \
             {e}"
        ))
    })?;
    let mut children = Vec::with_capacity(nranks);
    for r in 0..nranks {
        let spawned = std::process::Command::new(&exe)
            .args(extra)
            .arg("--connect")
            .arg(connect)
            .arg("--rank")
            .arg(r.to_string())
            .spawn();
        match spawned {
            Ok(child) => children.push(child),
            Err(e) => {
                for c in &mut children {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                return Err(Error::Invalid(format!(
                    "comms launcher: failed to spawn rank process {r}: {e}"
                )));
            }
        }
    }
    Ok(children)
}

/// Owner of spawn-local rank processes: [`LocalRanks::wait`] reaps them
/// and fails if any exited non-zero; dropping unawaited kills the
/// stragglers so an aborted driver never leaks rank processes.
pub struct LocalRanks {
    children: Vec<Child>,
}

impl LocalRanks {
    /// [`spawn_local`] wrapped in the reaping owner.
    pub fn spawn(nranks: usize, connect: &str, extra: &[String])
                 -> Result<LocalRanks> {
        Ok(LocalRanks { children: spawn_local(nranks, connect, extra)? })
    }

    /// Block until every rank process exits; error if any failed.
    pub fn wait(mut self) -> Result<()> {
        let children = std::mem::take(&mut self.children);
        let mut failures = Vec::new();
        for (r, mut c) in children.into_iter().enumerate() {
            match c.wait() {
                Ok(status) if status.success() => {}
                Ok(status) => failures
                    .push(format!("rank process {r} exited with {status}")),
                Err(e) => failures.push(format!("rank process {r}: {e}")),
            }
        }
        if failures.is_empty() {
            Ok(())
        } else {
            Err(Error::Invalid(format!(
                "comms launcher: {}",
                failures.join("; ")
            )))
        }
    }
}

impl Drop for LocalRanks {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
        }
        for c in &mut self.children {
            let _ = c.wait();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::transport::Transport;

    /// Full loopback rendezvous: N connect_rank threads + the server.
    fn loopback(nranks: usize, wants: Vec<Option<usize>>)
                -> (Vec<SocketTransport>, SocketTransport, Vec<Vec<u8>>) {
        let server = RankServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let joins: Vec<_> = wants
            .into_iter()
            .map(|want| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    connect_rank(&addr, want).unwrap()
                })
            })
            .collect();
        let ctl = server.rendezvous(nranks, b"setup-blob").unwrap();
        let mut ranks: Vec<Option<SocketTransport>> =
            (0..nranks).map(|_| None).collect();
        let mut payloads = Vec::new();
        for j in joins {
            let (t, payload) = j.join().unwrap();
            payloads.push(payload);
            let r = t.rank();
            assert!(ranks[r].is_none(), "duplicate rank {r}");
            ranks[r] = Some(t);
        }
        (ranks.into_iter().map(Option::unwrap).collect(), ctl, payloads)
    }

    #[test]
    fn rendezvous_assigns_requested_ranks_and_ships_payload() {
        let (ranks, ctl, payloads) =
            loopback(3, vec![Some(2), Some(0), Some(1)]);
        assert_eq!(ranks.len(), 3);
        assert_eq!(ctl.rank(), 3, "controller id is nranks");
        assert_eq!(ctl.nranks(), 3);
        for (r, t) in ranks.iter().enumerate() {
            assert_eq!(t.rank(), r);
            assert_eq!(t.nranks(), 3);
        }
        for p in payloads {
            assert_eq!(p, b"setup-blob");
        }
    }

    #[test]
    fn anonymous_ranks_get_distinct_ids() {
        let (ranks, _ctl, _) = loopback(2, vec![None, None]);
        let ids: Vec<usize> = ranks.iter().map(|t| t.rank()).collect();
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn mesh_routes_rank_to_rank_and_controller_both_ways() {
        let (mut ranks, mut ctl, _) = loopback(3, vec![Some(0), Some(1),
                                                       Some(2)]);
        // rank 0 -> rank 2 (a connection rank 2 initiated)
        ranks[0].send_bytes(2, vec![1]).unwrap();
        assert_eq!(ranks[2].recv_bytes().unwrap(), vec![1]);
        // rank 2 -> rank 0 (same connection, other direction)
        ranks[2].send_bytes(0, vec![2]).unwrap();
        assert_eq!(ranks[0].recv_bytes().unwrap(), vec![2]);
        // controller -> rank and back over the rendezvous link
        ctl.send_bytes(1, vec![3]).unwrap();
        assert_eq!(ranks[1].recv_bytes().unwrap(), vec![3]);
        ranks[1].send_bytes(3, vec![4]).unwrap();
        assert_eq!(ctl.recv_bytes().unwrap(), vec![4]);
    }

    #[test]
    fn single_rank_rendezvous_works() {
        let (mut ranks, _ctl, _) = loopback(1, vec![None]);
        // no peer sockets, but the periodic self-seam still loops back
        ranks[0].send_bytes(0, vec![9]).unwrap();
        assert_eq!(ranks[0].recv_bytes().unwrap(), vec![9]);
    }

    #[test]
    fn host_grouping_colocates_each_hosts_ranks() {
        let h = |s: &str| s.to_string();
        // interleaved arrivals from two hosts: each host's ranks end up
        // on consecutive ids, hosts in first-arrival order
        let hosts = vec![h("a"), h("b"), h("a"), h("b")];
        assert_eq!(host_grouped_order(&hosts), vec![0, 2, 1, 3]);
        // three hosts, uneven counts
        let hosts = vec![h("n1"), h("n2"), h("n3"), h("n2"), h("n2")];
        assert_eq!(host_grouped_order(&hosts), vec![0, 1, 3, 4, 2]);
        // one host degenerates to arrival order
        let hosts = vec![h("x"), h("x"), h("x")];
        assert_eq!(host_grouped_order(&hosts), vec![0, 1, 2]);
        assert_eq!(host_grouped_order(&[]), Vec::<usize>::new());
    }

    #[test]
    fn rank_host_is_never_empty() {
        // env override > kernel hostname > "localhost" — whichever arm
        // fires, every Hello carries a usable placement tag
        assert!(!rank_host().is_empty());
    }

    #[test]
    fn out_of_range_rank_request_fails_rendezvous() {
        let server = RankServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let child = std::thread::spawn(move || {
            // the server rejects the request, so this side sees an error
            // (a dropped connection mid-handshake) rather than a world
            connect_rank(&addr, Some(7))
        });
        assert!(server.rendezvous(1, &[]).is_err());
        assert!(child.join().unwrap().is_err());
    }
}
