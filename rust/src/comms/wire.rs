//! Serialized halo-plane wire format.
//!
//! Every message between ranks is one x-plane of one SoA field, tagged
//! with enough metadata for the receiver to match it against the exchange
//! it is waiting on — the envelope an MPI implementation carries as
//! `(source, tag, communicator)`. Payload doubles travel as little-endian
//! `f64::to_le_bytes` images, so a decoded plane is **bit-identical** to
//! the sent one: the multidomain parity guarantee survives serialization.
//!
//! The in-process [`crate::comms::transport::ChannelTransport`] ships
//! these exact bytes through channels, so the wire format is exercised on
//! every run; a socket transport writes the same frames to a TCP stream
//! (ROADMAP follow-up).
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//!      0     4  magic  "TDPW"
//!      4     1  version (1)
//!      5     1  phase   (0 = Moments, 1 = Stream)
//!      6     1  field   (0 = F, 1 = G)
//!      7     1  side    (0 = Low halo, 1 = High halo, at the receiver)
//!      8     4  src rank
//!     12     8  step index
//!     20     4  payload element count
//!     24  8*ec  payload (f64 LE)
//! ```

use crate::error::{Error, Result};

/// Frame magic: "targetDP wire".
pub const MAGIC: [u8; 4] = *b"TDPW";
/// Wire format version.
pub const VERSION: u8 = 1;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 24;

/// Which of the two per-step exchanges a plane belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Pre-collision exchange of post-stream `g` boundary planes — feeds
    /// the phi moment / gradient stencil at the subdomain edge.
    Moments = 0,
    /// Pre-stream exchange of post-collision `f` and `g` boundary planes
    /// — feeds the pull-streaming of the edge destination planes.
    Stream = 1,
}

/// Which distribution field a plane carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FieldId {
    F = 0,
    G = 1,
}

/// Which halo plane the payload fills **at the receiver**: `Low` arrives
/// from the left neighbour (its high boundary plane), `High` from the
/// right.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Side {
    Low = 0,
    High = 1,
}

/// Message envelope: the MPI `(tag)` analog the receiver matches on.
/// Unique per (step, exchange phase, field, halo side), so out-of-order
/// arrival — a neighbour running up to a step ahead — is unambiguous.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tag {
    pub step: u64,
    pub phase: Phase,
    pub field: FieldId,
    pub side: Side,
}

/// One halo plane in flight: envelope + payload.
#[derive(Debug, Clone, PartialEq)]
pub struct PlaneMsg {
    /// Sending rank (diagnostics; matching is by [`Tag`]).
    pub src: u32,
    pub tag: Tag,
    /// `ncomp * plane_sites` doubles, SoA component-major (the
    /// `halo::pack_x_plane` layout).
    pub data: Vec<f64>,
}

impl PlaneMsg {
    /// Encoded frame size for a payload of `count` doubles.
    pub fn frame_len(count: usize) -> usize {
        HEADER_LEN + 8 * count
    }

    /// Serialize to the wire frame.
    pub fn encode(&self) -> Vec<u8> {
        Self::encode_from(self.src, self.tag, &self.data)
    }

    /// Build the wire frame straight from a borrowed payload — the
    /// zero-intermediate-copy form the send hot path uses (no `PlaneMsg`
    /// with an owned `Vec<f64>` needs to exist on the sender side).
    pub fn encode_from(src: u32, tag: Tag, data: &[f64]) -> Vec<u8> {
        let mut out = Vec::with_capacity(Self::frame_len(data.len()));
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.push(tag.phase as u8);
        out.push(tag.field as u8);
        out.push(tag.side as u8);
        out.extend_from_slice(&src.to_le_bytes());
        out.extend_from_slice(&tag.step.to_le_bytes());
        out.extend_from_slice(&(data.len() as u32).to_le_bytes());
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Parse a wire frame (strict: magic, version, enum ranges and exact
    /// length are all validated — a socket transport feeds this arbitrary
    /// bytes).
    pub fn decode(bytes: &[u8]) -> Result<PlaneMsg> {
        let bad = |m: String| Error::Invalid(format!("comms wire: {m}"));
        if bytes.len() < HEADER_LEN {
            return Err(bad(format!("frame too short ({} B)", bytes.len())));
        }
        if bytes[..4] != MAGIC {
            return Err(bad(format!("bad magic {:02x?}", &bytes[..4])));
        }
        if bytes[4] != VERSION {
            return Err(bad(format!(
                "version {} (want {VERSION})", bytes[4]
            )));
        }
        let phase = match bytes[5] {
            0 => Phase::Moments,
            1 => Phase::Stream,
            v => return Err(bad(format!("unknown phase {v}"))),
        };
        let field = match bytes[6] {
            0 => FieldId::F,
            1 => FieldId::G,
            v => return Err(bad(format!("unknown field {v}"))),
        };
        let side = match bytes[7] {
            0 => Side::Low,
            1 => Side::High,
            v => return Err(bad(format!("unknown side {v}"))),
        };
        let le32 = |o: usize| {
            u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap())
        };
        let src = le32(8);
        let step = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
        let count = le32(20) as usize;
        // checked: an arbitrary (socket-fed) count must not overflow the
        // expected-length computation on 32-bit targets
        let expected = count
            .checked_mul(8)
            .and_then(|p| p.checked_add(HEADER_LEN));
        if expected != Some(bytes.len()) {
            return Err(bad(format!(
                "length {} != header + {count} doubles", bytes.len()
            )));
        }
        let data = bytes[HEADER_LEN..]
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(PlaneMsg {
            src,
            tag: Tag { step, phase, field, side },
            data,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> PlaneMsg {
        PlaneMsg {
            src: 3,
            tag: Tag {
                step: 41,
                phase: Phase::Stream,
                field: FieldId::G,
                side: Side::High,
            },
            data: vec![0.0, -1.5, f64::MIN_POSITIVE, 1.0 / 3.0, -0.0,
                       f64::MAX, 1e-300],
        }
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let msg = sample();
        let back = PlaneMsg::decode(&msg.encode()).unwrap();
        assert_eq!(back.src, msg.src);
        assert_eq!(back.tag, msg.tag);
        assert_eq!(back.data.len(), msg.data.len());
        for (a, b) in back.data.iter().zip(&msg.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "bitwise f64 transport");
        }
    }

    #[test]
    fn empty_payload_round_trips() {
        let msg = PlaneMsg {
            src: 0,
            tag: Tag {
                step: 0,
                phase: Phase::Moments,
                field: FieldId::F,
                side: Side::Low,
            },
            data: vec![],
        };
        let bytes = msg.encode();
        assert_eq!(bytes.len(), HEADER_LEN);
        assert_eq!(PlaneMsg::decode(&bytes).unwrap(), msg);
    }

    #[test]
    fn corrupt_frames_rejected() {
        let good = sample().encode();
        // truncated header
        assert!(PlaneMsg::decode(&good[..10]).is_err());
        // bad magic
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(PlaneMsg::decode(&bad).is_err());
        // bad version
        let mut bad = good.clone();
        bad[4] = 9;
        assert!(PlaneMsg::decode(&bad).is_err());
        // enum out of range
        let mut bad = good.clone();
        bad[5] = 7;
        assert!(PlaneMsg::decode(&bad).is_err());
        // payload length mismatch
        let mut bad = good.clone();
        bad.pop();
        assert!(PlaneMsg::decode(&bad).is_err());
        // declared count larger than payload
        let mut bad = good.clone();
        bad[20] = bad[20].wrapping_add(1);
        assert!(PlaneMsg::decode(&bad).is_err());
    }
}
